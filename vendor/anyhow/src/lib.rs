//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no vendored registry,
//! so this path dependency provides the (small) slice of anyhow's API the
//! repo uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros and
//! the [`Context`] extension trait.  Errors are stored as rendered
//! strings — backtraces, downcasting and error chains are out of scope
//! for this codebase, which only ever formats its errors.

use std::fmt;

/// A rendered error message (anyhow::Error analogue).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (anyhow::Error::msg analogue).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with context, outermost first (mirrors anyhow's rendering of
    /// `context: source`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that keeps this blanket `?`-conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result` analogue.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result`/`Option` failures (anyhow::Context analogue).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] (anyhow::bail analogue).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse()?; // via the blanket From<E>
        if n == 0 {
            bail!("zero is not allowed (got {s:?})");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_and_bail() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        let e = parse("0").unwrap_err();
        assert!(e.to_string().contains("zero is not allowed"));
    }

    #[test]
    fn context_on_option_and_result() {
        let o: Option<i32> = None;
        let e = o.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");

        let r: std::result::Result<i32, String> = Err("inner".into());
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("n = {}", 3).to_string(), "n = 3");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }
}

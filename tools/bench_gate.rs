//! Bench regression gate: compare a freshly-emitted `BENCH_*.json`
//! against its committed `BENCH_*.baseline.json` and fail (exit 1) when
//! a gated metric regresses beyond the allowed percentage.
//!
//! ```text
//! bench_gate <baseline.json> <current.json> [--max-regress-pct 15] [--only <substr>]
//! ```
//!
//! Rows are matched by their `label` field inside the top-level `cases`
//! array.  For every baseline row (optionally filtered to labels
//! containing the `--only` substring), two metric families are gated:
//!
//! * timing fields (`ns_per_iter`, or any field ending in `_ns`) —
//!   regress when the current value exceeds `baseline · (1 + pct/100)`;
//! * ratio fields (any field starting with `speedup`) — regress when
//!   the current value falls below `baseline / (1 + pct/100)`.
//!
//! A baseline row whose label is missing from the current run fails the
//! gate (a silently-dropped shape is a regression too); extra current
//! rows are ignored, so the baseline file only needs to carry the gated
//! rows.  The gate also fails when it checked nothing — a filter typo
//! must not produce a green step.
//!
//! CI wires this after both bench smoke steps (`fused_gemm` on the
//! headline 4096×4096 M=1 decode shape, `prefix_prefill` on the
//! skip-vs-recompute row).  To refresh a baseline, copy a
//! representative run's JSON artifact over the `.baseline.json` file —
//! absolute ns/iter is machine-dependent, so tighten it from the CI
//! runner's own numbers, not a dev box's.
//!
//! The JSON reader below is a ~100-line recursive-descent parser for
//! the subset these bench records use (no external crates are available
//! offline); it is unit-tested under `cargo test`.

use std::process::exit;

/// Minimal JSON value tree.
#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Parser<'a> {
        Parser { bytes: text.as_bytes(), pos: 0 }
    }

    fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser::new(text);
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, want: u8) -> Result<(), String> {
        if self.peek() == Some(want) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", want as char, self.pos))
        }
    }

    fn eat_lit(&mut self, lit: &str) -> Result<(), String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(format!("expected '{lit}' at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_lit("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.eat_lit("false").map(|_| Json::Bool(false)),
            Some(b'n') => self.eat_lit("null").map(|_| Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            pairs.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => return Err(format!("unexpected {other:?} in object at {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("unexpected {other:?} in array at {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            // Bench records never emit \u escapes;
                            // decode the BMP code point anyway.
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 code point (the input came from
                    // a &str, so boundaries are valid).
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && self.bytes[self.pos] & 0xC0 == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn load(path: &str) -> Json {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench_gate: cannot read {path}: {e}");
            exit(2);
        }
    };
    match Parser::parse(&text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("bench_gate: cannot parse {path}: {e}");
            exit(2);
        }
    }
}

/// Labeled rows of the file's top-level `cases` array.
fn labeled_cases(doc: &Json) -> Vec<(&str, &Json)> {
    doc.get("cases")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(|row| row.get("label").and_then(Json::as_str).map(|l| (l, row)))
        .collect()
}

/// Whether `field` is gated, and in which direction:
/// `Some(true)` = higher-is-worse (timings), `Some(false)` =
/// lower-is-worse (speedup ratios), `None` = not gated.
fn gated_direction(field: &str) -> Option<bool> {
    if field == "ns_per_iter" || field.ends_with("_ns") {
        Some(true)
    } else if field.starts_with("speedup") {
        Some(false)
    } else {
        None
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: bench_gate <baseline.json> <current.json> \
         [--max-regress-pct <pct>] [--only <label-substring>]"
    );
    exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths: Vec<&str> = Vec::new();
    let mut max_regress_pct = 15.0f64;
    let mut only: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--max-regress-pct" => {
                i += 1;
                max_regress_pct = args
                    .get(i)
                    .and_then(|v| v.parse::<f64>().ok())
                    .unwrap_or_else(|| usage());
            }
            "--only" => {
                i += 1;
                only = Some(args.get(i).cloned().unwrap_or_else(|| usage()));
            }
            flag if flag.starts_with("--") => usage(),
            path => paths.push(path),
        }
        i += 1;
    }
    let [baseline_path, current_path] = paths[..] else { usage() };

    let baseline = load(baseline_path);
    let current = load(current_path);
    let current_rows = labeled_cases(&current);

    let slack = 1.0 + max_regress_pct / 100.0;
    let mut checked = 0usize;
    let mut failures: Vec<String> = Vec::new();

    println!("bench_gate: {current_path} vs baseline {baseline_path} (max regress {max_regress_pct}%)");
    for (label, base_row) in labeled_cases(&baseline) {
        if let Some(filter) = &only {
            if !label.contains(filter.as_str()) {
                continue;
            }
        }
        let Some((_, cur_row)) = current_rows.iter().find(|(l, _)| *l == label) else {
            failures.push(format!("row {label:?} is missing from {current_path}"));
            continue;
        };
        let Json::Obj(fields) = base_row else { continue };
        for (field, base_val) in fields {
            let Some(higher_is_worse) = gated_direction(field) else { continue };
            let Some(base) = base_val.as_num() else { continue };
            // A gated field the current run no longer emits is itself a
            // regression — a renamed metric must not silently un-gate.
            let Some(cur) = cur_row.get(field).and_then(Json::as_num) else {
                failures.push(format!(
                    "{label} :: gated field {field:?} is missing from {current_path}"
                ));
                continue;
            };
            checked += 1;
            let (limit, regressed, change_pct) = if higher_is_worse {
                (base * slack, cur > base * slack, (cur / base - 1.0) * 100.0)
            } else {
                (base / slack, cur < base / slack, (1.0 - cur / base) * 100.0)
            };
            let verdict = if regressed { "REGRESSED" } else { "ok" };
            println!(
                "  {label} :: {field}: baseline {base:.1}, current {cur:.1}, \
                 limit {limit:.1}  [{verdict}]"
            );
            if regressed {
                failures.push(format!(
                    "{label} :: {field} regressed {change_pct:.1}% \
                     (baseline {base:.1}, current {cur:.1}, allowed {max_regress_pct}%)"
                ));
            }
        }
    }

    if checked == 0 && failures.is_empty() {
        eprintln!(
            "bench_gate: no gated metrics matched (filter: {only:?}) — refusing to pass \
             an empty gate"
        );
        exit(1);
    }
    if failures.is_empty() {
        println!("bench_gate: OK ({checked} metrics within {max_regress_pct}%)");
    } else {
        println!("bench_gate: FAILED:");
        for f in &failures {
            println!("  - {f}");
        }
        exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_bench_record() {
        let doc = Parser::parse(
            r#"{
  "bench": "fused_gemm",
  "smoke": true,
  "cases": [
    {"label": "decode M=1 4096x4096 g128", "ns_per_iter": 1500000, "speedup_vs_oracle": 12.5},
    {"label": "batch", "act_order": false, "chunk_budget": null, "ns_per_iter": 3e6}
  ]
}"#,
        )
        .unwrap();
        let rows = labeled_cases(&doc);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "decode M=1 4096x4096 g128");
        assert_eq!(rows[0].1.get("ns_per_iter").and_then(Json::as_num), Some(1_500_000.0));
        assert_eq!(rows[1].1.get("ns_per_iter").and_then(Json::as_num), Some(3_000_000.0));
        assert_eq!(rows[1].1.get("chunk_budget"), Some(&Json::Null));
        assert_eq!(doc.get("smoke"), Some(&Json::Bool(true)));
    }

    #[test]
    fn parses_escapes_and_negative_numbers() {
        let doc = Parser::parse(r#"{"s": "a\"b\\c\nd", "v": -2.5e-1}"#).unwrap();
        assert_eq!(doc.get("s").and_then(Json::as_str), Some("a\"b\\c\nd"));
        assert_eq!(doc.get("v").and_then(Json::as_num), Some(-0.25));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Parser::parse("{").is_err());
        assert!(Parser::parse("[1, 2,]").is_err());
        assert!(Parser::parse("{\"a\": 1} extra").is_err());
    }

    #[test]
    fn gating_directions() {
        assert_eq!(gated_direction("ns_per_iter"), Some(true));
        assert_eq!(gated_direction("recompute_ns"), Some(true));
        assert_eq!(gated_direction("skip_ns"), Some(true));
        assert_eq!(gated_direction("speedup_vs_oracle"), Some(false));
        assert_eq!(gated_direction("speedup_best_of"), Some(false));
        assert_eq!(gated_direction("gb_per_s"), None);
        assert_eq!(gated_direction("prefix_len"), None);
    }
}

//! Cross-layer parity harness: the fused dequantize-GEMM fast path
//! (`gptq::fused`) pinned against the dense oracle
//! (`gptq::gemm::{gemv_f32, gemm_f32}`) over a seeded shape sweep —
//! K ∈ {64, 128, 4096}, N ∈ {8, 32, 40, 256}, group ∈ {32, 64, 128},
//! M ∈ {1, 8, 64}, with and without act-order (`b_q_perm`) — and, since
//! the kernel dispatch landed, under **every dispatch path this host can
//! run**: the sweep iterates the kernel registry (forced scalar
//! everywhere, forced AVX2 and forced AVX-512 where detected).  N = 8
//! pins the AVX-512 kernel's degenerate pure-tail matrix, N = 40 the
//! mixed full-hexadectet + trailing-octet layout (`N % 16 == 8` with
//! `full_hex > 0` — the tail stream base and scratch offsets only
//! diverge from zero there), and N ∈ {32, 256} the tail-free path.
//!
//! Tensors are synthesized directly in the packed layout (random codes,
//! zeros, scales, permutation): parity must hold for *every* valid
//! packed tensor, not just those a particular quantizer emits, and it
//! keeps the 4096-row shapes affordable (a real act-order GPTQ pass is
//! O(K³) in the Cholesky).  Activations are scaled by 1/√K so outputs
//! stay O(1); the sweep tolerance is **1e-4 relative** to the oracle
//! row's largest magnitude (floored at 1), tight enough to catch any
//! structural divergence while absorbing re-association rounding.
//!
//! Two bit-level pins ride along:
//! * the scalar path must be bit-stable across worker counts and
//!   M-batching (its accumulation order is frozen — the scalar loop is
//!   the unchanged pre-dispatch kernel, so these invariants pin its
//!   results to today's);
//! * on exactly-representable data (unit scales, integer activations)
//!   every kernel, the oracle, and an integer-arithmetic reference must
//!   agree **bitwise** — nibble decode order, zero handling and group
//!   mapping have no rounding to hide behind there.

use opt4gptq::gptq::{
    available_kernels, gemm_f32, gemm_fused_opt, gemv_f32, gemv_fused_opt, kernel_registry,
    pack, supports, FusedInput, FusedOpts, Kernel, Matrix, QuantizedTensor,
};
use opt4gptq::rng::Rng;

/// Collapsed-surface shorthand: force `kernel` and `threads` on a raw
/// tensor (what the old `gemv_fused_with` / `gemm_fused_with` did).
fn gemv_with(x: &[f32], q: &QuantizedTensor, kernel: Kernel, threads: usize) -> Vec<f32> {
    gemv_fused_opt(
        x,
        FusedInput::Raw(q),
        FusedOpts { kernel: Some(kernel), threads: Some(threads) },
    )
}

fn gemm_with(x: &Matrix, q: &QuantizedTensor, kernel: Kernel, threads: usize) -> Matrix {
    gemm_fused_opt(
        x,
        FusedInput::Raw(q),
        FusedOpts { kernel: Some(kernel), threads: Some(threads) },
    )
}

const KS: [usize; 3] = [64, 128, 4096];
const NS: [usize; 4] = [8, 32, 40, 256];
const GROUPS: [usize; 3] = [32, 64, 128];
const MS: [usize; 3] = [1, 8, 64];
/// Relative tolerance vs the oracle (of the output's ∞-norm, floored at
/// 1 so near-zero rows don't blow the ratio up).
const REL_TOL: f32 = 1e-4;

/// Unoptimized-build budget: the oracle re-unpacks the full K×N matrix
/// per GEMV row, so cases are capped at ~9M element-ops each.  Skips are
/// counted and reported — nothing is dropped silently.
const MAX_ELEMS: usize = 9_000_000;

/// Build a random valid packed tensor directly in the storage layout.
fn synth_tensor(k: usize, n: usize, g: usize, act_order: bool, rng: &mut Rng) -> QuantizedTensor {
    let codes: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
    let groups = k / g;
    let zeros: Vec<u8> = (0..groups * n).map(|_| rng.below(16) as u8).collect();
    let scales: Vec<f32> = (0..groups * n).map(|_| 0.01 + 0.1 * rng.f32()).collect();
    let q = QuantizedTensor {
        k,
        n,
        group_size: g,
        qweight: pack::pack_rows(&codes, k, n),
        scales,
        qzeros: pack::pack_cols(&zeros, groups, n),
        perm: None,
    };
    if act_order {
        let mut perm: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut perm);
        q.with_perm(perm)
    } else {
        q
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// `max |got − want| ≤ REL_TOL · max(1, ‖want‖∞)`.
fn assert_close(got: &[f32], want: &[f32], label: &str) {
    let winf = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let diff = max_abs_diff(got, want);
    assert!(
        diff <= REL_TOL * winf.max(1.0),
        "{label}: max diff {diff} exceeds {REL_TOL} relative (|want|max = {winf})"
    );
}

fn shape_sweep() -> Vec<(usize, usize, usize, bool)> {
    let mut shapes = Vec::new();
    for &k in &KS {
        for &n in &NS {
            for &g in &GROUPS {
                if g > k || k % g != 0 {
                    continue;
                }
                for act_order in [false, true] {
                    shapes.push((k, n, g, act_order));
                }
            }
        }
    }
    shapes
}

#[test]
fn kernel_sweep_iterates_the_full_registry() {
    // The sweeps below run `available_kernels()`; pin that it is the
    // registry filtered by host support, that the registry names all
    // three kernels, and that on an AVX-512 host the avx512 leg cannot
    // silently vanish from the sweep.
    let names: Vec<&str> = kernel_registry().iter().map(|info| info.name).collect();
    assert_eq!(names, ["scalar", "avx2", "avx512"]);
    let avail = available_kernels();
    assert!(avail.contains(&Kernel::Scalar));
    for info in kernel_registry() {
        assert_eq!(
            avail.contains(&info.kernel),
            supports(info.kernel),
            "available_kernels must list exactly the supported registry rows ({})",
            info.name
        );
    }
    #[cfg(all(target_arch = "x86_64", opt4gptq_avx512_intrinsics))]
    if is_x86_feature_detected!("avx512f")
        && is_x86_feature_detected!("avx512bw")
        && is_x86_feature_detected!("avx2")
        && is_x86_feature_detected!("fma")
    {
        assert!(
            avail.contains(&Kernel::Avx512),
            "host reports avx512f/bw but the sweep would skip the avx512 kernel"
        );
    }
}

#[test]
fn fused_gemv_matches_oracle_over_sweep_per_kernel() {
    let kernels = available_kernels();
    let mut rng = Rng::new(0x9a11_17ee);
    let mut cases = 0;
    for (k, n, g, act_order) in shape_sweep() {
        let q = synth_tensor(k, n, g, act_order, &mut rng);
        let std = 1.0 / (k as f32).sqrt();
        let x = rng.normal_vec_f32(k, std);
        // One oracle evaluation per shape; every dispatch path must hit it.
        let want = gemv_f32(&x, &q);
        for &kernel in &kernels {
            let got = gemv_with(&x, &q, kernel, 1);
            assert_close(
                &got,
                &want,
                &format!("gemv k={k} n={n} g={g} act_order={act_order} kernel={kernel}"),
            );
            cases += 1;
        }
    }
    println!("gemv parity: {cases} (shape × kernel) cases across {} kernels", kernels.len());
    assert!(cases >= 40 * kernels.len(), "sweep unexpectedly small: {cases} cases");
}

#[test]
fn fused_gemm_matches_oracle_over_sweep_per_kernel() {
    let kernels = available_kernels();
    let mut rng = Rng::new(0x6e33_a271);
    let (mut cases, mut skipped) = (0, 0);
    for (k, n, g, act_order) in shape_sweep() {
        for &m in &MS {
            if m * k * n > MAX_ELEMS {
                skipped += 1;
                continue;
            }
            let q = synth_tensor(k, n, g, act_order, &mut rng);
            let std = 1.0 / (k as f32).sqrt();
            let x = Matrix::from_vec(m, k, rng.normal_vec_f32(m * k, std));
            let want = gemm_f32(&x, &q);
            for &kernel in &kernels {
                let got = gemm_with(&x, &q, kernel, 1);
                assert_close(
                    &got.data,
                    &want.data,
                    &format!("gemm m={m} k={k} n={n} g={g} act_order={act_order} kernel={kernel}"),
                );
                cases += 1;
            }
        }
    }
    println!("gemm parity: {cases} (shape × kernel) cases checked, {skipped} oversized cases skipped (> {MAX_ELEMS} element-ops; the shapes themselves are covered at smaller M)");
    assert!(cases >= 100 * kernels.len(), "sweep unexpectedly small: {cases} cases");
}

#[test]
fn fused_gemm_rows_equal_fused_gemv_rows_per_kernel() {
    // The batched path must be bitwise row-equivalent to the single-row
    // path (rows of an M-block share weight passes but not accumulators)
    // — for every kernel: the SIMD M-tiling must not leak across rows.
    let mut rng = Rng::new(0x70_0b5);
    for act_order in [false, true] {
        let q = synth_tensor(128, 32, 64, act_order, &mut rng);
        let x = Matrix::from_vec(11, 128, rng.normal_vec_f32(11 * 128, 0.1));
        for kernel in available_kernels() {
            let out = gemm_with(&x, &q, kernel, 1);
            for mi in 0..x.rows {
                let y = gemv_with(x.row(mi), &q, kernel, 1);
                assert_eq!(out.row(mi), &y[..], "row {mi} act_order={act_order} kernel={kernel}");
            }
        }
    }
}

#[test]
fn scalar_path_is_bit_stable_across_threads() {
    // The scalar kernel is the unchanged pre-dispatch loop; its results
    // are additionally invariant to the column split (K is never
    // partitioned), pinning them to today's values bit for bit.
    let mut rng = Rng::new(0x5ca1a7);
    let q = synth_tensor(256, 640, 64, false, &mut rng);
    let x = rng.normal_vec_f32(256, 0.1);
    let serial = gemv_with(&x, &q, Kernel::Scalar, 1);
    for threads in [2, 3, 7, 16] {
        assert_eq!(
            serial,
            gemv_with(&x, &q, Kernel::Scalar, threads),
            "scalar gemv changed under threads={threads}"
        );
    }
    let xm = Matrix::from_vec(13, 256, rng.normal_vec_f32(13 * 256, 0.1));
    let serial_m = gemm_with(&xm, &q, Kernel::Scalar, 1);
    for threads in [2, 5] {
        assert_eq!(
            serial_m.data,
            gemm_with(&xm, &q, Kernel::Scalar, threads).data,
            "scalar gemm changed under threads={threads}"
        );
    }
}

#[test]
fn kernels_agree_bitwise_on_exactly_representable_data() {
    // Unit scales + integer activations: every product, partial sum and
    // flush is an integer far below 2^24, so f32 arithmetic is exact in
    // any association and FMA changes nothing.  Every kernel, the
    // oracle, and a direct i64 reference must agree BITWISE — this pins
    // nibble decode order, zero-point handling and group mapping with no
    // rounding slack, independent of which kernel a host dispatches.
    let (k, n, g) = (256, 40, 32);
    let groups = k / g;
    let mut rng = Rng::new(0xb17_901d);
    let codes: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
    let zeros: Vec<u8> = (0..groups * n).map(|_| rng.below(16) as u8).collect();
    for act_order in [false, true] {
        let mut q = QuantizedTensor {
            k,
            n,
            group_size: g,
            qweight: pack::pack_rows(&codes, k, n),
            scales: vec![1.0; groups * n],
            qzeros: pack::pack_cols(&zeros, groups, n),
            perm: None,
        };
        let mut perm: Vec<usize> = (0..k).collect();
        if act_order {
            rng.shuffle(&mut perm);
            q = q.with_perm(perm.clone());
        }
        // Integer activations in [-8, 8).
        let x: Vec<f32> = (0..k).map(|_| (rng.below(16) as i64 - 8) as f32).collect();
        // i64 reference straight off the unpacked definition:
        // y[col] = Σ_r x[perm[r]] · (code[r,col] − zero[r/g,col]).
        let expect: Vec<f32> = (0..n)
            .map(|col| {
                let mut acc = 0i64;
                for r in 0..k {
                    let xv = x[perm[r]] as i64;
                    let c = codes[r * n + col] as i64;
                    let z = zeros[(r / g) * n + col] as i64;
                    acc += xv * (c - z);
                }
                acc as f32
            })
            .collect();
        assert_eq!(gemv_f32(&x, &q), expect, "oracle vs i64 reference (act_order={act_order})");
        for kernel in available_kernels() {
            for threads in [1, 3] {
                assert_eq!(
                    gemv_with(&x, &q, kernel, threads),
                    expect,
                    "kernel={kernel} threads={threads} act_order={act_order}"
                );
            }
        }
    }
}

#[test]
fn sparse_activations_agree_with_oracle_per_kernel() {
    // The scalar kernel short-circuits all-zero 8-row spans (the SIMD
    // path does not); parity must survive highly sparse inputs.
    let mut rng = Rng::new(0x51a3);
    let q = synth_tensor(256, 32, 64, false, &mut rng);
    let mut x = vec![0.0f32; 256];
    for _ in 0..10 {
        x[rng.range_usize(0, 255)] = rng.normal() as f32 * 0.1;
    }
    let want = gemv_f32(&x, &q);
    for kernel in available_kernels() {
        assert_close(&gemv_with(&x, &q, kernel, 1), &want, &format!("sparse {kernel}"));
    }
}

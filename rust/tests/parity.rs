//! Cross-layer parity harness: the fused dequantize-GEMM fast path
//! (`gptq::fused`) pinned against the dense oracle
//! (`gptq::gemm::{gemv_f32, gemm_f32}`) over a seeded shape sweep —
//! K ∈ {64, 128, 4096}, N ∈ {8, 32, 256}, group ∈ {32, 64, 128},
//! M ∈ {1, 8, 64}, with and without act-order (`b_q_perm`).
//!
//! Tensors are synthesized directly in the packed layout (random codes,
//! zeros, scales, permutation): parity must hold for *every* valid
//! packed tensor, not just those a particular quantizer emits, and it
//! keeps the 4096-row shapes affordable (a real act-order GPTQ pass is
//! O(K³) in the Cholesky).  Activations are scaled by 1/√K so outputs
//! stay O(1) and the 1e-3 tolerance measures implementation divergence,
//! not accumulated f32 noise.

use opt4gptq::gptq::{gemm_f32, gemm_fused, gemv_f32, gemv_fused, pack, Matrix, QuantizedTensor};
use opt4gptq::rng::Rng;

const KS: [usize; 3] = [64, 128, 4096];
const NS: [usize; 3] = [8, 32, 256];
const GROUPS: [usize; 3] = [32, 64, 128];
const MS: [usize; 3] = [1, 8, 64];
const TOL: f32 = 1e-3;

/// Unoptimized-build budget: the oracle re-unpacks the full K×N matrix
/// per GEMV row, so cases are capped at ~9M element-ops each.  Skips are
/// counted and reported — nothing is dropped silently.
const MAX_ELEMS: usize = 9_000_000;

/// Build a random valid packed tensor directly in the storage layout.
fn synth_tensor(k: usize, n: usize, g: usize, act_order: bool, rng: &mut Rng) -> QuantizedTensor {
    let codes: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
    let groups = k / g;
    let zeros: Vec<u8> = (0..groups * n).map(|_| rng.below(16) as u8).collect();
    let scales: Vec<f32> = (0..groups * n).map(|_| 0.01 + 0.1 * rng.f32()).collect();
    let q = QuantizedTensor {
        k,
        n,
        group_size: g,
        qweight: pack::pack_rows(&codes, k, n),
        scales,
        qzeros: pack::pack_cols(&zeros, groups, n),
        perm: None,
    };
    if act_order {
        let mut perm: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut perm);
        q.with_perm(perm)
    } else {
        q
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn shape_sweep() -> Vec<(usize, usize, usize, bool)> {
    let mut shapes = Vec::new();
    for &k in &KS {
        for &n in &NS {
            for &g in &GROUPS {
                if g > k || k % g != 0 {
                    continue;
                }
                for act_order in [false, true] {
                    shapes.push((k, n, g, act_order));
                }
            }
        }
    }
    shapes
}

#[test]
fn fused_gemv_matches_oracle_over_sweep() {
    let mut rng = Rng::new(0x9a11_17ee);
    let mut cases = 0;
    for (k, n, g, act_order) in shape_sweep() {
        let q = synth_tensor(k, n, g, act_order, &mut rng);
        let std = 1.0 / (k as f32).sqrt();
        let x = rng.normal_vec_f32(k, std);
        let got = gemv_fused(&x, &q);
        let want = gemv_f32(&x, &q);
        let diff = max_abs_diff(&got, &want);
        assert!(
            diff < TOL,
            "gemv k={k} n={n} g={g} act_order={act_order}: max diff {diff}"
        );
        cases += 1;
    }
    assert!(cases >= 40, "sweep unexpectedly small: {cases} cases");
}

#[test]
fn fused_gemm_matches_oracle_over_sweep() {
    let mut rng = Rng::new(0x6e33_a271);
    let (mut cases, mut skipped) = (0, 0);
    for (k, n, g, act_order) in shape_sweep() {
        for &m in &MS {
            if m * k * n > MAX_ELEMS {
                skipped += 1;
                continue;
            }
            let q = synth_tensor(k, n, g, act_order, &mut rng);
            let std = 1.0 / (k as f32).sqrt();
            let x = Matrix::from_vec(m, k, rng.normal_vec_f32(m * k, std));
            let got = gemm_fused(&x, &q);
            let want = gemm_f32(&x, &q);
            let diff = max_abs_diff(&got.data, &want.data);
            assert!(
                diff < TOL,
                "gemm m={m} k={k} n={n} g={g} act_order={act_order}: max diff {diff}"
            );
            cases += 1;
        }
    }
    println!("gemm parity: {cases} cases checked, {skipped} oversized cases skipped (> {MAX_ELEMS} element-ops; the shapes themselves are covered at smaller M)");
    assert!(cases >= 100, "sweep unexpectedly small: {cases} cases");
}

#[test]
fn fused_gemm_rows_equal_fused_gemv_rows() {
    // The batched path must be bitwise row-equivalent to the single-row
    // path (rows of an M-block share weight passes but not accumulators).
    let mut rng = Rng::new(0x70_0b5);
    for act_order in [false, true] {
        let q = synth_tensor(128, 32, 64, act_order, &mut rng);
        let x = Matrix::from_vec(11, 128, rng.normal_vec_f32(11 * 128, 0.1));
        let out = gemm_fused(&x, &q);
        for mi in 0..x.rows {
            let y = gemv_fused(x.row(mi), &q);
            assert_eq!(out.row(mi), &y[..], "row {mi} act_order={act_order}");
        }
    }
}

#[test]
fn sparse_activations_agree_with_oracle() {
    // The fused kernel short-circuits all-zero 8-row spans; parity must
    // survive highly sparse inputs (and exact zeros).
    let mut rng = Rng::new(0x51a3);
    let q = synth_tensor(256, 32, 64, false, &mut rng);
    let mut x = vec![0.0f32; 256];
    for _ in 0..10 {
        x[rng.range_usize(0, 255)] = rng.normal() as f32 * 0.1;
    }
    let diff = max_abs_diff(&gemv_fused(&x, &q), &gemv_f32(&x, &q));
    assert!(diff < TOL, "sparse parity diff {diff}");
}

//! Property-based tests (homegrown `qcheck` kit, proptest-style) on the
//! coordinator and substrate invariants:
//!
//! * block-manager refcount/free-list consistency under arbitrary
//!   alloc/append/free interleavings;
//! * scheduler queue/block-table consistency under random request streams,
//!   including the preemption path;
//! * GPTQ pack/unpack as exact inverses on arbitrary codes;
//! * f16 rounding invariants (monotonicity, idempotence);
//! * engine conservation: every admitted request finishes exactly once
//!   with exactly `max_tokens` tokens;
//! * trace-replay equivalence: batched serving under arrivals,
//!   priorities, preemption (swap or recompute) AND a randomized
//!   recoverable fault schedule yields per-request tokens bit-identical
//!   to a fault-free serial one-request-at-a-time replay, resolves every
//!   request as Completed, and passes the post-drain invariant audit.

use opt4gptq::engine::block_manager::BlockManager;
use opt4gptq::engine::{
    Engine, EngineConfig, FaultPlan, KvDtype, Request, SamplingParams, SimBackend,
};
use opt4gptq::f16::{self, F16};
use opt4gptq::gptq::{pack, quantize_rtn, Matrix};
use opt4gptq::models::by_name;
use opt4gptq::qcheck::{check, ensure, Config};
use opt4gptq::rng::Rng;
use opt4gptq::OptConfig;

#[test]
fn prop_block_manager_invariants_hold_under_chaos() {
    #[derive(Debug)]
    struct Ops(Vec<(u8, usize, usize)>); // (op, seq, len)

    check(
        "block_manager chaos",
        Config { cases: 60, seed: 0xb10c },
        |r| {
            let n = r.range_usize(5, 60);
            Ops((0..n)
                .map(|_| (r.below(4) as u8, r.range_usize(0, 9), r.range_usize(1, 70)))
                .collect())
        },
        |Ops(ops)| {
            let mut bm = BlockManager::new(32, 4);
            let mut live: Vec<Option<usize>> = vec![None; 10]; // seq -> tokens
            for (i, &(op, seq, len)) in ops.iter().enumerate() {
                match op {
                    0 => {
                        if live[seq].is_none() {
                            let prompt: Vec<u32> =
                                (0..len).map(|j| (seq * 1000 + j * 7 + i) as u32).collect();
                            if bm.allocate(seq, &prompt).is_some() {
                                live[seq] = Some(len);
                            }
                        }
                    }
                    1 => {
                        if let Some(t) = live[seq] {
                            if bm.append_token(seq, t + 1) {
                                live[seq] = Some(t + 1);
                            }
                        }
                    }
                    2 => {
                        // Prefill progress: marking computed blocks must
                        // never break refcount/free-list consistency.
                        if let Some(t) = live[seq] {
                            bm.mark_computed(seq, len.min(t));
                        }
                    }
                    _ => {
                        if live[seq].take().is_some() {
                            bm.free_sequence(seq);
                        }
                    }
                }
                bm.check_invariants()?;
            }
            // free everything: the pool must be whole again
            for (seq, t) in live.iter().enumerate() {
                if t.is_some() {
                    bm.free_sequence(seq);
                }
            }
            bm.check_invariants()?;
            ensure(bm.free_blocks() == 32, format!("leak: {} free of 32", bm.free_blocks()))
        },
    );
}

#[test]
fn prop_pack_unpack_roundtrip() {
    check(
        "pack/unpack inverse",
        Config { cases: 100, seed: 0x9ac4 },
        |r| {
            let kw = r.range_usize(1, 8);
            let n = r.range_usize(1, 24);
            let codes: Vec<u8> = (0..kw * 8 * n).map(|_| r.below(16) as u8).collect();
            (kw, n, codes)
        },
        |(kw, n, codes)| {
            let packed = pack::pack_rows(codes, kw * 8, *n);
            ensure(
                pack::unpack_rows(&packed, *kw, *n) == *codes,
                "row pack/unpack mismatch",
            )
        },
    );
}

#[test]
fn prop_zeros_pack_unpack_roundtrip() {
    check(
        "cols pack/unpack inverse",
        Config { cases: 100, seed: 0x2e05 },
        |r| {
            let g = r.range_usize(1, 6);
            let nw = r.range_usize(1, 8);
            let zeros: Vec<u8> = (0..g * nw * 8).map(|_| r.below(16) as u8).collect();
            (g, nw, zeros)
        },
        |(g, nw, zeros)| {
            let packed = pack::pack_cols(zeros, *g, nw * 8);
            ensure(
                pack::unpack_cols(&packed, *g, *nw) == *zeros,
                "col pack/unpack mismatch",
            )
        },
    );
}

#[test]
fn prop_rtn_error_bounded() {
    check(
        "RTN quantization error <= scale/2 + eps",
        Config { cases: 40, seed: 0x47e0 },
        |r| {
            let groups = r.range_usize(1, 4);
            let n = r.range_usize(1, 3) * 8;
            let g = 32;
            let std = 0.2 + 3.0 * r.f32();
            let w = Matrix::from_vec(groups * g, n, r.normal_vec_f32(groups * g * n, std));
            (g, w)
        },
        |(g, w)| {
            let q = quantize_rtn(w, *g);
            let deq = opt4gptq::gptq::dequantize(&q);
            for kk in 0..w.rows {
                let gi = kk / g;
                for col in 0..w.cols {
                    let err = (w.at(kk, col) - deq.at(kk, col)).abs();
                    let bound = q.scales[gi * w.cols + col] * 0.5 + 1e-4;
                    if err > bound {
                        return Err(format!("err {err} > bound {bound} at ({kk},{col})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_f16_roundtrip_and_monotone() {
    check(
        "f16 conversions",
        Config { cases: 200, seed: 0xf16 },
        |r| (r.f64() * 100000.0 - 50000.0, r.f64() * 2.0 - 1.0),
        |&(big, small)| {
            // idempotence: converting a converted value is exact
            let h = F16::from_f64(big);
            if !h.is_infinite() {
                ensure(F16::from_f64(h.to_f64()).0 == h.0, "idempotence")?;
            }
            // monotonicity on a pair
            let a = F16::from_f64(small);
            let b = F16::from_f64(small + 0.25);
            ensure(a.to_f64() <= b.to_f64(), "monotonicity")?;
            // addition commutes
            ensure(f16::add(a, b).0 == f16::add(b, a).0, "commutativity")
        },
    );
}

#[test]
fn prop_engine_conservation() {
    // Every admitted request finishes exactly once with exactly
    // max_tokens generated, regardless of batch/blocks/trace shape —
    // including configurations that force preemption.
    check(
        "engine conservation",
        Config { cases: 25, seed: 0xe27 },
        |r| {
            let n_req = r.range_usize(1, 12);
            let max_batch = r.range_usize(1, 6);
            let total_blocks = r.range_usize(24, 200);
            // Budgets below the block size (4) and above any prompt are
            // both in range: chunked and one-shot prefill paths.
            let prefill_budget = r.range_usize(1, 48);
            let reqs: Vec<(usize, usize)> = (0..n_req)
                .map(|_| (r.range_usize(1, 30), r.range_usize(1, 20)))
                .collect();
            (max_batch, total_blocks, prefill_budget, reqs)
        },
        |(max_batch, total_blocks, prefill_budget, reqs)| {
            let model = by_name("Qwen1.5-1.8B-Chat-GPTQ-Int4").unwrap();
            let backend = SimBackend::new(model, OptConfig::OPT4GPTQ, *max_batch);
            let mut e = Engine::new(
                EngineConfig {
                    max_batch: *max_batch,
                    block_size: 4,
                    total_blocks: *total_blocks,
                    max_seq_len: 256,
                    prefill_budget: *prefill_budget,
                    // env-inherited: the forced-recompute CI job must
                    // reach this property on the recompute path too
                    ..Default::default()
                },
                backend,
            );
            let mut rng = Rng::new(1);
            for (i, &(plen, gen)) in reqs.iter().enumerate() {
                let prompt: Vec<u32> = (0..plen).map(|_| rng.next_u32() % 500).collect();
                e.add_request(Request::new(
                    i,
                    prompt,
                    SamplingParams { max_tokens: gen, ..Default::default() },
                ));
            }
            let report = e.run().map_err(|er| er.to_string())?;
            ensure(report.outputs.len() == reqs.len(), format!(
                "finished {} of {}", report.outputs.len(), reqs.len()))?;
            for out in &report.outputs {
                let want = reqs[out.id].1;
                ensure(
                    out.tokens.len() == want,
                    format!("req {}: {} tokens, wanted {want}", out.id, out.tokens.len()),
                )?;
            }
            e.scheduler.check_invariants()?;
            ensure(
                report.metrics.output_tokens == reqs.iter().map(|r| r.1).sum::<usize>(),
                "token accounting",
            )
        },
    );
}

#[test]
fn prop_trace_replay_matches_serial() {
    // Continuous batching is an *optimization*: whatever the scheduler
    // does — arrival gating, priority admission, chunked prefill, swap
    // or recompute preemption, even under an injected recoverable fault
    // schedule — each request's sampled tokens must be exactly what a
    // fault-free serial one-request-at-a-time replay produces, and the
    // pool must be whole once everything drains.
    //
    // Sizing keeps every request admittable (max 22 total tokens = 6
    // blocks of 4, pool ≥ 7) so "all complete" is a hard invariant,
    // not a statement about rejects.
    check(
        "batched trace replay == serial replay",
        Config { cases: 20, seed: 0x7ace },
        |r| {
            let n_req = r.range_usize(2, 10);
            let max_batch = r.range_usize(1, 4);
            let total_blocks = r.range_usize(7, 40);
            let prefill_budget = r.range_usize(1, 24);
            let swap = r.below(2) == 0;
            // Random KV dtype per case, applied to BOTH engines: replay
            // parity must hold at every pool dtype (the sim backend's
            // spill pricing changes with it, its logits do not).
            let kv_dtype = KvDtype::ALL[r.range_usize(0, KvDtype::ALL.len() - 1)];
            // Randomized recoverable-only fault schedule for the batched
            // engine: transient step errors, spill write/restore failures
            // and allocation refusals.  No permanent faults — every
            // request must still complete, bit-identically.
            let faults = FaultPlan {
                seed: r.next_u64(),
                step_transient: r.f64() * 0.15,
                spill_out: r.f64() * 0.2,
                spill_in: r.f64() * 0.2,
                alloc: r.f64() * 0.1,
                ..FaultPlan::NONE
            };
            let reqs: Vec<(usize, usize, i32, f64)> = (0..n_req)
                .map(|_| {
                    let plen = r.range_usize(1, 12);
                    let gen = r.range_usize(1, 10);
                    let priority = r.range_usize(0, 4) as i32 - 2;
                    // Mix bursts at t=0 with spread-out arrivals.
                    let arrival = if r.below(2) == 0 { 0.0 } else { r.f64() * 2.0 };
                    (plen, gen, priority, arrival)
                })
                .collect();
            (max_batch, total_blocks, prefill_budget, swap, kv_dtype, faults, reqs)
        },
        |(max_batch, total_blocks, prefill_budget, swap, kv_dtype, faults, reqs)| {
            let mk_req = |i: usize, plen: usize, gen: usize, priority: i32, arrival: f64| {
                // Distinct per-request prompts: prefix sharing may still
                // occur on accidental overlaps, which is the point.
                let mut rng = Rng::new(0x5eed ^ i as u64);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.next_u32() % 500).collect();
                let mut req = Request::new(
                    i,
                    prompt,
                    SamplingParams {
                        max_tokens: gen,
                        temperature: 0.7,
                        top_k: 16,
                        seed: 11,
                        ..Default::default()
                    },
                );
                req.priority = priority;
                req.arrival = arrival;
                req
            };
            // Batched replay under block pressure.
            let mut e = Engine::new(
                EngineConfig {
                    model: Default::default(),
                    max_batch: *max_batch,
                    block_size: 4,
                    total_blocks: *total_blocks,
                    max_seq_len: 256,
                    prefill_budget: *prefill_budget,
                    prefix_skip: true,
                    swap_preempt: *swap,
                    kv_dtype: *kv_dtype,
                    max_waiting: usize::MAX,
                    faults: *faults,
                },
                SimBackend::new(
                    by_name("Qwen1.5-1.8B-Chat-GPTQ-Int4").unwrap(),
                    OptConfig::OPT4GPTQ,
                    *max_batch,
                ),
            );
            for (i, &(plen, gen, priority, arrival)) in reqs.iter().enumerate() {
                e.add_request(mk_req(i, plen, gen, priority, arrival));
            }
            let report = e.run().map_err(|er| er.to_string())?;
            ensure(
                report.outputs.len() == reqs.len(),
                format!("finished {} of {}", report.outputs.len(), reqs.len()),
            )?;
            e.scheduler.check_invariants()?;
            ensure(
                e.scheduler.blocks.free_blocks() == *total_blocks,
                format!(
                    "block leak after drain: {} free of {}",
                    e.scheduler.blocks.free_blocks(),
                    total_blocks
                ),
            )?;
            // Every request must resolve as Completed (the fault plan is
            // recoverable-only), and the full post-drain auditor — block
            // manager, spill ledger, physical pool — must come up clean.
            for (id, outcome) in &report.outcomes {
                if *outcome != opt4gptq::engine::RequestOutcome::Completed {
                    return Err(format!("req {id}: non-Completed outcome {outcome:?}"));
                }
            }
            e.audit()?;
            // Serial reference: each request alone in a roomy engine,
            // arriving at t=0 — no chunking pressure, no preemption, and
            // (pinned) no faults: this is the ground truth the faulty
            // batched run must reproduce bit-for-bit.
            for (i, &(plen, gen, priority, _)) in reqs.iter().enumerate() {
                let mut solo = Engine::new(
                    EngineConfig {
                        model: Default::default(),
                        max_batch: 1,
                        block_size: 4,
                        total_blocks: 256,
                        max_seq_len: 256,
                        prefill_budget: 64,
                        prefix_skip: true,
                        swap_preempt: false,
                        kv_dtype: *kv_dtype,
                        max_waiting: usize::MAX,
                        faults: FaultPlan::NONE,
                    },
                    SimBackend::new(
                        by_name("Qwen1.5-1.8B-Chat-GPTQ-Int4").unwrap(),
                        OptConfig::OPT4GPTQ,
                        1,
                    ),
                );
                solo.add_request(mk_req(i, plen, gen, priority, 0.0));
                let serial = solo.run().map_err(|er| er.to_string())?;
                let batched = report
                    .outputs
                    .iter()
                    .find(|o| o.id == i)
                    .ok_or(format!("req {i} missing from batched outputs"))?;
                ensure(
                    serial.outputs[0].tokens == batched.tokens,
                    format!(
                        "req {i}: batched tokens diverge from serial replay \
                         (batched {:?} vs serial {:?})",
                        batched.tokens, serial.outputs[0].tokens
                    ),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sampler_top_k_support() {
    check(
        "sampler stays in top-k support",
        Config { cases: 50, seed: 0x5a3 },
        |r| {
            let n = r.range_usize(4, 100);
            let k = r.range_usize(1, n.min(10));
            let logits: Vec<f32> = (0..n).map(|_| r.normal() as f32).collect();
            (k, logits, r.next_u64())
        },
        |(k, logits, seed)| {
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            let allowed: std::collections::HashSet<u32> =
                idx[..*k].iter().map(|&i| i as u32).collect();
            let p = SamplingParams { temperature: 1.0, top_k: *k, ..Default::default() };
            let mut rng = Rng::new(*seed);
            for _ in 0..20 {
                let t = opt4gptq::engine::sampler::sample(logits, &p, &mut rng);
                if !allowed.contains(&t) {
                    return Err(format!("sampled {t} outside top-{k}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_speedup_structure_generalizes() {
    // For arbitrary kernel-aligned shapes: every optimization helps, the
    // combined config is fastest, ILA ≥ VML.
    check(
        "sim speedup structure",
        Config { cases: 30, seed: 0xd1c },
        |r| {
            let m = [1usize, 2, 4, 8, 16, 32][r.below(6) as usize];
            let k = r.range_usize(2, 40) * 256;
            let n = r.range_usize(2, 40) * 256;
            (m, k, n)
        },
        |&(m, k, n)| {
            let d = opt4gptq::dcusim::Device::z100();
            let p = opt4gptq::dcusim::kernels::KernelParams { m, k, n, group_size: 128 };
            let t = |o| {
                d.simulate(&opt4gptq::dcusim::GemvKernel::new(p, o)).seconds
            };
            let base = t(OptConfig::BASELINE);
            let (smb, vml, ila, opt4) = (
                t(OptConfig::SMB),
                t(OptConfig::VML),
                t(OptConfig::ILA),
                t(OptConfig::OPT4GPTQ),
            );
            ensure(smb < base, format!("SMB {smb} !< {base}"))?;
            ensure(vml <= base, format!("VML {vml} !<= {base}"))?;
            ensure(ila < base, format!("ILA {ila} !< {base}"))?;
            ensure(opt4 <= smb.min(vml).min(ila), "combined must be fastest")?;
            ensure(ila <= vml, "ILA must beat VML")
        },
    );
}

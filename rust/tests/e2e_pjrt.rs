//! End-to-end tests through the **real PJRT runtime**: load the AOT HLO
//! artifacts, execute, and check numerics against the shipped oracle.
//!
//! These tests require `make artifacts`; they are skipped (with a clear
//! message) when `artifacts/manifest.txt` is absent so that `cargo test`
//! still passes on a fresh checkout.  The whole target additionally
//! requires the `pjrt` feature (the `xla` bindings are not available
//! offline) and compiles to an empty test crate without it.

#![cfg(feature = "pjrt")]

use std::collections::HashMap;

use opt4gptq::engine::backend::{Backend, DecodeDesc, PrefillDesc};
use opt4gptq::engine::tokenizer::ByteTokenizer;
use opt4gptq::engine::Backend as _;
use opt4gptq::engine::{Engine, EngineConfig, Request, SamplingParams};
use opt4gptq::runtime::{PjrtBackend, Runtime};

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

/// The standalone GPTQ-GEMM artifact must reproduce the expected output
/// shipped by aot.py (kernel numerics survive the full AOT round trip:
/// Pallas -> StableHLO -> HLO text -> xla parse -> PJRT execute).
#[test]
fn gemm_artifact_matches_shipped_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let art = rt.manifest.artifact("gemm_tiny").unwrap().clone();
    let (m, k, n, g) = (
        art.attr_usize("m").unwrap(),
        art.attr_usize("k").unwrap(),
        art.attr_usize("n").unwrap(),
        art.attr_usize("g").unwrap(),
    );
    // io blob layout: x f32[m,k], qw u32[k/8,n], s f32[k/g,n],
    // qz u32[k/g,n/8], expect f32[m,n] (all stored as f32 words).
    let blob = std::fs::read(format!("{dir}/gemm_tiny_io.bin")).unwrap();
    let words: Vec<u32> = blob
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let mut off = 0;
    let mut take = |len: usize| {
        let s = &words[off..off + len];
        off += len;
        s.to_vec()
    };
    let x = take(m * k);
    let qw = take(k / 8 * n);
    let s = take(k / g * n);
    let qz = take(k / g * n / 8);
    let expect: Vec<f32> = take(m * n).iter().map(|&w| f32::from_bits(w)).collect();

    let as_bytes = |v: &[u32]| -> Vec<u8> { v.iter().flat_map(|w| w.to_le_bytes()).collect() };
    // The smoke artifact records no per-arg metadata; its argument order
    // is (x, qweight, scales, qzeros) by construction in aot.py.
    let payloads = [as_bytes(&x), as_bytes(&qw), as_bytes(&s), as_bytes(&qz)];
    let dims: [Vec<usize>; 4] =
        [vec![m, k], vec![k / 8, n], vec![k / g, n], vec![k / g, n / 8]];
    let exe_inputs: Vec<xla::Literal> = payloads
        .iter()
        .zip([
            xla::ElementType::F32,
            xla::ElementType::U32,
            xla::ElementType::F32,
            xla::ElementType::U32,
        ])
        .zip(dims.iter())
        .map(|((bytes, ty), d)| {
            xla::Literal::create_from_shape_and_untyped_data(ty, d, bytes).unwrap()
        })
        .collect();
    let exe = rt.executable("gemm_tiny").unwrap();
    let out = exe.execute::<xla::Literal>(&exe_inputs).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let out = out.to_tuple1().unwrap();
    let got = out.to_vec::<f32>().unwrap();
    assert_eq!(got.len(), expect.len());
    for (a, b) in got.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

/// Real generation through the engine: byte-tokenized prompts, greedy
/// sampling must be deterministic across two engine runs.
#[test]
fn pjrt_generation_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let run = || {
        let mut backend = PjrtBackend::load(&dir).unwrap();
        backend.warmup().unwrap();
        let tok = ByteTokenizer;
        let mut engine = Engine::new(
            EngineConfig {
                model: Default::default(),
                max_batch: backend.max_batch(),
                max_seq_len: backend.max_seq_len(),
                block_size: 16,
                total_blocks: 128,
                // Dense-lane HLO artifacts need whole prompts: no
                // chunking, no cached-prefix skipping, and no swap
                // resume (its start > 0 chunks would be rejected).
                prefill_budget: 4096,
                prefix_skip: false,
                swap_preempt: false,
                kv_dtype: opt4gptq::engine::KvDtype::F32,
                max_waiting: usize::MAX,
                // Pinned: injected faults would force chunk-resume paths
                // the dense-lane HLO artifacts cannot express.
                faults: opt4gptq::engine::FaultPlan::NONE,
            },
            backend,
        );
        for (i, text) in ["hello world", "quantized inference"].iter().enumerate() {
            engine.add_request(Request::new(
                i,
                tok.encode(text),
                SamplingParams { max_tokens: 6, ..Default::default() },
            ));
        }
        let report = engine.run().unwrap();
        let mut outs: Vec<(usize, Vec<u32>)> = report
            .outputs
            .iter()
            .map(|o| (o.id, o.tokens.clone()))
            .collect();
        outs.sort();
        outs
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "greedy decoding must be deterministic");
    assert_eq!(a.len(), 2);
    for (_, tokens) in &a {
        assert_eq!(tokens.len(), 6);
        assert!(tokens.iter().all(|&t| t < 256));
    }
}

/// Prefill-then-decode through PJRT must agree with a longer prefill
/// (KV-cache correctness through the *runtime*, mirroring the python
/// test at the jax level).
#[test]
fn pjrt_kv_cache_consistency() {
    let Some(dir) = artifacts_dir() else { return };
    let mut backend = PjrtBackend::load(&dir).unwrap();

    let prompt = [10u32, 20, 30, 40, 50];
    // Path A: prefill all 5 tokens; logits predict token 6.
    let (logits_a, _) = backend
        .prefill(PrefillDesc { seq_id: 0, tokens: &prompt, start: 0, is_last: true, block_table: &[] })
        .unwrap();
    // Path B: prefill 4, decode the 5th.
    let (_, _) = backend
        .prefill(PrefillDesc { seq_id: 1, tokens: &prompt[..4], start: 0, is_last: true, block_table: &[] })
        .unwrap();
    let (rows, _) = backend
        .decode(&[DecodeDesc { seq_id: 1, context_len: 4, token: 50, block_table: &[] }])
        .unwrap();
    let logits_b = &rows[0];
    assert_eq!(logits_a.len(), logits_b.len());
    let max_diff = logits_a
        .iter()
        .zip(logits_b)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "prefill-vs-decode max diff {max_diff}");
}

/// Batched decode must equal single-sequence decode lane by lane.
#[test]
fn pjrt_batch_lanes_are_independent() {
    let Some(dir) = artifacts_dir() else { return };
    let mut backend = PjrtBackend::load(&dir).unwrap();
    let p0 = [1u32, 2, 3];
    let p1 = [9u32, 8, 7, 6];
    backend.prefill(PrefillDesc { seq_id: 0, tokens: &p0, start: 0, is_last: true, block_table: &[] }).unwrap();
    backend.prefill(PrefillDesc { seq_id: 1, tokens: &p1, start: 0, is_last: true, block_table: &[] }).unwrap();

    let (single0, _) = backend
        .decode(&[DecodeDesc { seq_id: 0, context_len: 3, token: 3, block_table: &[] }])
        .unwrap();
    // reset seq 0's cache by re-prefilling (decode above mutated it)
    backend.prefill(PrefillDesc { seq_id: 0, tokens: &p0, start: 0, is_last: true, block_table: &[] }).unwrap();
    let (batch, _) = backend
        .decode(&[
            DecodeDesc { seq_id: 0, context_len: 3, token: 3, block_table: &[] },
            DecodeDesc { seq_id: 1, context_len: 4, token: 6, block_table: &[] },
        ])
        .unwrap();
    let max_diff = single0[0]
        .iter()
        .zip(&batch[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "lane 0 differs in batch: {max_diff}");
}

//! Engine integration across execution backends: the same seed and trace
//! through `SimBackend` (virtual clock, synthetic logits) and
//! `CpuBackend` (real fused-kernel math over physically-paged KV) must
//! give deterministic, reproducible per-request token counts and
//! monotone metrics — with no panics on the preemption/block-release
//! paths — and prefix-cache hits must be *physical*: shared block-table
//! entries aliasing the same pool memory with oracle-identical logits.

use opt4gptq::engine::{
    Backend, BlockManager, CpuBackend, CpuModelConfig, Engine, EngineConfig, FaultPlan,
    PrefillDesc, Request, SamplingParams, SimBackend,
};
use opt4gptq::models::by_name;
use opt4gptq::OptConfig;

type Workload = Vec<(Vec<u32>, usize)>;

/// Light trace: six short requests (vocab-256 safe prompts).
fn light_workload() -> Workload {
    (0..6usize)
        .map(|i| {
            let plen = 5 + 3 * i;
            let prompt: Vec<u32> = (0..plen).map(|j| ((i * 41 + j * 7) % 256) as u32).collect();
            (prompt, 4 + i % 5)
        })
        .collect()
}

/// Heavy trace: long generations with distinct prompts (no prefix
/// sharing), sized so the cramped config *must* preempt.
fn heavy_workload() -> Workload {
    (0..5usize)
        .map(|i| {
            let prompt: Vec<u32> = (0..12).map(|j| ((i * 53 + j * 11 + 1) % 256) as u32).collect();
            (prompt, 22 + i)
        })
        .collect()
}

fn run_engine<B: Backend>(
    backend: B,
    cfg: EngineConfig,
    workload: &Workload,
) -> (Vec<(usize, Vec<u32>)>, usize) {
    let mut e = Engine::new(cfg, backend);
    for (i, (prompt, max_tokens)) in workload.iter().enumerate() {
        e.add_request(Request::new(
            i,
            prompt.clone(),
            SamplingParams {
                max_tokens: *max_tokens,
                temperature: 0.7,
                top_k: 16,
                seed: 9,
                ..Default::default()
            },
        ));
    }
    let report = e.run().unwrap();
    e.scheduler.check_invariants().unwrap();
    assert!(report.metrics.elapsed >= 0.0);
    assert_eq!(
        report.metrics.output_tokens,
        workload.iter().map(|(_, g)| *g).sum::<usize>(),
        "token accounting must be exact"
    );
    // Metrics monotonicity: every request's latency bounds its TTFT.
    for o in &report.outputs {
        assert!(o.ttft >= 0.0 && o.latency >= o.ttft, "req {}: ttft/latency order", o.id);
    }
    let mut outs: Vec<(usize, Vec<u32>)> =
        report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
    outs.sort();
    (outs, report.metrics.preemptions)
}

fn roomy() -> EngineConfig {
    EngineConfig { max_batch: 4, total_blocks: 512, max_seq_len: 128, ..Default::default() }
}

/// Tiny KV pool: 26 blocks of 4 tokens cannot hold four of the heavy
/// trace's ~34-token sequences at once — forces preemption/recompute.
fn cramped() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 4,
        total_blocks: 26,
        max_seq_len: 128,
        prefill_budget: 64,
        // Inherited from the environment so the CI forced-recompute job
        // (OPT4GPTQ_PREFIX_SKIP=0) exercises this suite on both paths.
        ..Default::default()
    }
}

fn cpu_backend() -> CpuBackend {
    CpuBackend::new(CpuModelConfig { max_batch: 4, max_seq: 128, ..Default::default() }).unwrap()
}

fn sim_backend() -> SimBackend {
    SimBackend::new(by_name("Llama-2-7B-GPTQ").unwrap(), OptConfig::OPT4GPTQ, 4)
}

#[test]
fn cpu_backend_run_is_deterministic() {
    let w = light_workload();
    let (a, _) = run_engine(cpu_backend(), roomy(), &w);
    let (b, _) = run_engine(cpu_backend(), roomy(), &w);
    assert_eq!(a, b, "identical seed + trace must replay token-for-token");
}

#[test]
fn sim_and_cpu_backends_agree_on_token_counts() {
    let w = light_workload();
    let (sim, _) = run_engine(sim_backend(), roomy(), &w);
    let (cpu, _) = run_engine(cpu_backend(), roomy(), &w);
    assert_eq!(sim.len(), cpu.len());
    for ((sid, stoks), (cid, ctoks)) in sim.iter().zip(&cpu) {
        assert_eq!(sid, cid);
        // Logits differ across backends (synthetic vs real math), but the
        // forced generation lengths are a backend-independent contract.
        assert_eq!(stoks.len(), ctoks.len(), "req {sid}: token count diverges");
    }
}

#[test]
fn cpu_backend_survives_preemption_and_block_release() {
    let w = heavy_workload();
    let (a, preemptions) = run_engine(cpu_backend(), cramped(), &w);
    assert!(preemptions > 0, "this config must preempt to prove the recompute path");
    // Preemption changes scheduling, not accounting (run_engine already
    // pinned exact totals); replay must also be stable.
    let (b, _) = run_engine(cpu_backend(), cramped(), &w);
    assert_eq!(a, b);
    // And the sim backend under the identical squeeze preempts too,
    // finishing with the same per-request counts.
    let (sim, sim_pre) = run_engine(sim_backend(), cramped(), &w);
    assert!(sim_pre > 0);
    for ((_, c), (_, s)) in a.iter().zip(&sim) {
        assert_eq!(c.len(), s.len());
    }
}

#[test]
fn greedy_cpu_serving_is_deterministic_across_engine_configs() {
    // Greedy sampling through real logits: decode *batching* differs
    // between configs, but each sequence's math is independent (private
    // block tables, row-independent fused GEMM), so outputs must match
    // token-for-token.
    let run = |cfg: EngineConfig| {
        let mut e = Engine::new(cfg, cpu_backend());
        for (i, (prompt, _)) in light_workload().into_iter().enumerate() {
            e.add_request(Request::new(
                i,
                prompt,
                SamplingParams { max_tokens: 6, ..Default::default() },
            ));
        }
        let report = e.run().unwrap();
        let mut outs: Vec<(usize, Vec<u32>)> =
            report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
        outs.sort();
        outs
    };
    let a = run(roomy());
    let b = run(EngineConfig { max_batch: 2, ..roomy() });
    assert_eq!(a, b, "greedy decoding must not depend on batch composition");
}

/// Physical prefix sharing at the backend level: two sequences whose
/// block tables share prefix blocks must consume fewer blocks *and*
/// produce logits bit-identical to a fresh, unshared run.
#[test]
fn prefix_sharing_is_physical_and_bit_exact() {
    let block_size = 16;
    let mut bm = BlockManager::new(64, block_size);
    let mut be = cpu_backend();
    be.bind_kv(64, block_size, opt4gptq::engine::kv_dtype_default());

    // 36 tokens: two full (shareable) blocks + a private tail block.
    let prompt: Vec<u32> = (0..36).map(|i| ((i * 13 + 5) % 256) as u32).collect();
    assert!(bm.allocate(1, &prompt).is_some());
    let free_after_first = bm.free_blocks();
    assert!(bm.allocate(2, &prompt).is_some());
    // Prefix hit accounting must coincide with real block savings: the
    // second sequence only consumed its private tail block.
    assert!(bm.prefix_hits >= 2, "full prefix blocks must hit the cache");
    assert_eq!(
        free_after_first - bm.free_blocks(),
        1,
        "a prefix-cache hit must reduce blocks consumed, not just count hits"
    );
    let t1: Vec<usize> = bm.table(1).unwrap().to_vec();
    let t2: Vec<usize> = bm.table(2).unwrap().to_vec();
    assert_eq!(t1[..2], t2[..2], "shared prefix must reference the same physical blocks");
    assert_ne!(t1[2], t2[2], "partial tail must stay private");

    // Execute both through their tables; then compare against a fresh
    // backend that never shared anything (the oracle).
    let (l1, _) =
        be.prefill(PrefillDesc { seq_id: 1, tokens: &prompt, start: 0, is_last: true, block_table: &t1 }).unwrap();
    let (l2, _) =
        be.prefill(PrefillDesc { seq_id: 2, tokens: &prompt, start: 0, is_last: true, block_table: &t2 }).unwrap();
    let mut fresh = cpu_backend();
    fresh.bind_kv(64, block_size, opt4gptq::engine::kv_dtype_default());
    let fresh_table: Vec<usize> = (10..13).collect();
    let (oracle, _) = fresh
        .prefill(PrefillDesc { seq_id: 9, tokens: &prompt, start: 0, is_last: true, block_table: &fresh_table })
        .unwrap();
    assert_eq!(l1, oracle, "sharing must not perturb the first sequence");
    assert_eq!(l2, oracle, "a shared-prefix run must be bit-identical to a fresh run");
    bm.check_invariants().unwrap();
}

/// Prefix sharing through the whole engine: identical greedy prompts
/// must generate identical tokens whether or not they shared blocks,
/// and the run must actually exercise the prefix cache.
#[test]
fn engine_prefix_sharing_preserves_greedy_tokens() {
    let prompt: Vec<u32> = (0..20).map(|i| ((i * 7 + 3) % 256) as u32).collect();
    let run = |n_requests: usize| {
        let mut e = Engine::new(roomy(), cpu_backend());
        for i in 0..n_requests {
            e.add_request(Request::new(
                i,
                prompt.clone(),
                SamplingParams { max_tokens: 8, ..Default::default() },
            ));
        }
        let report = e.run().unwrap();
        e.scheduler.check_invariants().unwrap();
        let hits = e.scheduler.blocks.prefix_hits;
        let mut outs: Vec<(usize, Vec<u32>)> =
            report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
        outs.sort();
        (outs, hits)
    };
    let (solo, solo_hits) = run(1);
    assert_eq!(solo_hits, 0, "a single request has nothing to share");
    let (pair, pair_hits) = run(2);
    assert!(pair_hits > 0, "identical prompts must hit the prefix cache");
    assert_eq!(pair.len(), 2);
    assert_eq!(pair[0].1, solo[0].1, "sharing must not change greedy generation");
    assert_eq!(pair[1].1, solo[0].1, "both shared sequences must match the fresh run");
}

/// Greedy generation through the whole engine with prefix-skip enabled
/// must be token-identical to the forced-recompute path
/// (`OPT4GPTQ_PREFIX_SKIP=0` semantics), while actually skipping work.
#[test]
fn prefix_skip_engine_matches_forced_recompute() {
    // Shared 32-token prefix (2 full blocks of 16), distinct tails, plus
    // one unrelated prompt — mixed sharing in one continuous batch.
    let shared: Vec<u32> = (0..32).map(|i| ((i * 13 + 5) % 256) as u32).collect();
    let workload: Vec<Vec<u32>> = (0..3)
        .map(|i| {
            let mut p = shared.clone();
            p.extend((0..4).map(|j| ((i * 61 + j * 17 + 9) % 256) as u32));
            p
        })
        .chain(std::iter::once((0..20).map(|i| ((i * 31 + 2) % 256) as u32).collect()))
        .collect();
    let run = |prefix_skip: bool| {
        let mut e = Engine::new(
            EngineConfig {
                prefill_budget: 48,
                prefix_skip,
                // Pinned: the exact skipped-token counts below assert the
                // fault-free prefill schedule; an env-injected fault's
                // preemptions would legitimately change them.
                faults: FaultPlan::NONE,
                ..roomy()
            },
            cpu_backend(),
        );
        for (i, prompt) in workload.iter().enumerate() {
            e.add_request(Request::new(
                i,
                prompt.clone(),
                SamplingParams { max_tokens: 8, ..Default::default() },
            ));
        }
        let report = e.run().unwrap();
        e.scheduler.check_invariants().unwrap();
        let mut outs: Vec<(usize, Vec<u32>)> =
            report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
        outs.sort();
        (outs, report.metrics.prefill_tokens_skipped)
    };
    let (skip, skipped) = run(true);
    let (recompute, recomputed_skips) = run(false);
    assert_eq!(recomputed_skips, 0, "forced recompute must never skip");
    assert!(skipped > 0, "shared prefixes must be skipped when enabled");
    assert_eq!(skip, recompute, "prefix skip changed greedy generation");
}

/// Chunked prefill under any token budget — including budgets smaller
/// than the block size — must generate exactly the tokens a one-shot
/// prefill generates (real math, greedy sampling pins the logits).
#[test]
fn chunked_prefill_engine_matches_one_shot() {
    let workload: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..37 + i).map(|j| ((i * 41 + j * 7 + 3) % 256) as u32).collect())
        .collect();
    let run = |prefill_budget: usize| {
        let mut e = Engine::new(
            // Pinned fault-free: the exact chunk counts below describe
            // the undisturbed prefill schedule.
            EngineConfig { prefill_budget, faults: FaultPlan::NONE, ..roomy() },
            cpu_backend(),
        );
        for (i, prompt) in workload.iter().enumerate() {
            e.add_request(Request::new(
                i,
                prompt.clone(),
                SamplingParams { max_tokens: 6, ..Default::default() },
            ));
        }
        let report = e.run().unwrap();
        e.scheduler.check_invariants().unwrap();
        let mut outs: Vec<(usize, Vec<u32>)> =
            report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
        outs.sort();
        (outs, report.metrics.prefill_chunks)
    };
    let (one_shot, one_shot_chunks) = run(1000);
    assert_eq!(one_shot_chunks, 3, "huge budget must prefill each prompt in one chunk");
    // 7 < block_size (16): the unaligned-chunk edge case stays exact.
    for budget in [7, 16, 24] {
        let (chunked, chunks) = run(budget);
        assert!(chunks > 3, "budget {budget} must actually chunk ({chunks} chunks)");
        assert_eq!(chunked, one_shot, "budget {budget} changed greedy generation");
    }
}

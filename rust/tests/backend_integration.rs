//! Engine integration across execution backends: the same seed and trace
//! through `SimBackend` (virtual clock, synthetic logits) and
//! `CpuBackend` (real fused-kernel math) must give deterministic,
//! reproducible per-request token counts and monotone metrics — with no
//! panics on the preemption/slot-release paths.

use opt4gptq::engine::{
    Backend, CpuBackend, CpuModelConfig, Engine, EngineConfig, Request, SamplingParams,
    SimBackend,
};
use opt4gptq::models::by_name;
use opt4gptq::OptConfig;

type Workload = Vec<(Vec<u32>, usize)>;

/// Light trace: six short requests (vocab-256 safe prompts).
fn light_workload() -> Workload {
    (0..6usize)
        .map(|i| {
            let plen = 5 + 3 * i;
            let prompt: Vec<u32> = (0..plen).map(|j| ((i * 41 + j * 7) % 256) as u32).collect();
            (prompt, 4 + i % 5)
        })
        .collect()
}

/// Heavy trace: long generations with distinct prompts (no prefix
/// sharing), sized so the cramped config *must* preempt.
fn heavy_workload() -> Workload {
    (0..5usize)
        .map(|i| {
            let prompt: Vec<u32> = (0..12).map(|j| ((i * 53 + j * 11 + 1) % 256) as u32).collect();
            (prompt, 22 + i)
        })
        .collect()
}

fn run_engine<B: Backend>(
    backend: B,
    cfg: EngineConfig,
    workload: &Workload,
) -> (Vec<(usize, Vec<u32>)>, usize) {
    let mut e = Engine::new(cfg, backend);
    for (i, (prompt, max_tokens)) in workload.iter().enumerate() {
        e.add_request(Request::new(
            i,
            prompt.clone(),
            SamplingParams {
                max_tokens: *max_tokens,
                temperature: 0.7,
                top_k: 16,
                seed: 9,
                ..Default::default()
            },
        ));
    }
    let report = e.run().unwrap();
    e.scheduler.check_invariants().unwrap();
    assert!(report.metrics.elapsed >= 0.0);
    assert_eq!(
        report.metrics.output_tokens,
        workload.iter().map(|(_, g)| *g).sum::<usize>(),
        "token accounting must be exact"
    );
    // Metrics monotonicity: every request's latency bounds its TTFT.
    for o in &report.outputs {
        assert!(o.ttft >= 0.0 && o.latency >= o.ttft, "req {}: ttft/latency order", o.id);
    }
    let mut outs: Vec<(usize, Vec<u32>)> =
        report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
    outs.sort();
    (outs, report.metrics.preemptions)
}

fn roomy() -> EngineConfig {
    EngineConfig { max_batch: 4, total_blocks: 512, max_seq_len: 128, ..Default::default() }
}

/// Tiny KV pool: 26 blocks of 4 tokens cannot hold four of the heavy
/// trace's ~34-token sequences at once — forces preemption/recompute.
fn cramped() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 4,
        total_blocks: 26,
        max_seq_len: 128,
        max_prefills_per_step: 4,
    }
}

fn cpu_backend() -> CpuBackend {
    CpuBackend::new(CpuModelConfig { max_batch: 4, max_seq: 128, ..Default::default() }).unwrap()
}

fn sim_backend() -> SimBackend {
    SimBackend::new(by_name("Llama-2-7B-GPTQ").unwrap(), OptConfig::OPT4GPTQ, 4)
}

#[test]
fn cpu_backend_run_is_deterministic() {
    let w = light_workload();
    let (a, _) = run_engine(cpu_backend(), roomy(), &w);
    let (b, _) = run_engine(cpu_backend(), roomy(), &w);
    assert_eq!(a, b, "identical seed + trace must replay token-for-token");
}

#[test]
fn sim_and_cpu_backends_agree_on_token_counts() {
    let w = light_workload();
    let (sim, _) = run_engine(sim_backend(), roomy(), &w);
    let (cpu, _) = run_engine(cpu_backend(), roomy(), &w);
    assert_eq!(sim.len(), cpu.len());
    for ((sid, stoks), (cid, ctoks)) in sim.iter().zip(&cpu) {
        assert_eq!(sid, cid);
        // Logits differ across backends (synthetic vs real math), but the
        // forced generation lengths are a backend-independent contract.
        assert_eq!(stoks.len(), ctoks.len(), "req {sid}: token count diverges");
    }
}

#[test]
fn cpu_backend_survives_preemption_and_slot_release() {
    let w = heavy_workload();
    let (a, preemptions) = run_engine(cpu_backend(), cramped(), &w);
    assert!(preemptions > 0, "this config must preempt to prove the recompute path");
    // Preemption changes scheduling, not accounting (run_engine already
    // pinned exact totals); replay must also be stable.
    let (b, _) = run_engine(cpu_backend(), cramped(), &w);
    assert_eq!(a, b);
    // And the sim backend under the identical squeeze preempts too,
    // finishing with the same per-request counts.
    let (sim, sim_pre) = run_engine(sim_backend(), cramped(), &w);
    assert!(sim_pre > 0);
    for ((_, c), (_, s)) in a.iter().zip(&sim) {
        assert_eq!(c.len(), s.len());
    }
}

#[test]
fn greedy_cpu_serving_is_deterministic_across_engine_configs() {
    // Greedy sampling through real logits: decode *batching* differs
    // between configs, but each sequence's math is independent (dense
    // per-slot KV, row-independent fused GEMM), so outputs must match
    // token-for-token.
    let run = |cfg: EngineConfig| {
        let mut e = Engine::new(cfg, cpu_backend());
        for (i, (prompt, _)) in light_workload().into_iter().enumerate() {
            e.add_request(Request::new(
                i,
                prompt,
                SamplingParams { max_tokens: 6, ..Default::default() },
            ));
        }
        let report = e.run().unwrap();
        let mut outs: Vec<(usize, Vec<u32>)> =
            report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
        outs.sort();
        outs
    };
    let a = run(roomy());
    let b = run(EngineConfig { max_batch: 2, ..roomy() });
    assert_eq!(a, b, "greedy decoding must not depend on batch composition");
}

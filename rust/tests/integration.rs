//! Cross-module integration tests: GPTQ substrate ↔ engine ↔ simulator ↔
//! reproduction drivers (no PJRT — see `e2e_pjrt.rs` for that).

use opt4gptq::dcusim::kernels::KernelParams;
use opt4gptq::dcusim::{Device, GemvKernel};
use opt4gptq::engine::{Engine, EngineConfig, Request, SamplingParams, SimBackend};
use opt4gptq::eval::accuracy::evaluate;
use opt4gptq::eval::numerics::gemv_f16_variant;
use opt4gptq::gptq::{
    dequantize, gemv_f32, quantize_gptq, quantize_rtn, GptqConfig, Matrix,
};
use opt4gptq::models::{by_name, PAPER_MODELS};
use opt4gptq::rng::Rng;
use opt4gptq::trace::arc::ArcSplit;
use opt4gptq::trace::RequestTrace;
use opt4gptq::OptConfig;

/// GPTQ-quantized weights flow through all three numeric paths
/// consistently: dense dequant, f32 GEMV, and variant-f16 GEMV.
#[test]
fn gptq_tensor_flows_through_all_numeric_paths() {
    let mut rng = Rng::new(1);
    let (k, n, g) = (128, 16, 64);
    let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 0.5));
    let x_cal = Matrix::from_vec(64, k, rng.normal_vec_f32(64 * k, 1.0));
    let q = quantize_gptq(w.clone(), &x_cal, GptqConfig { group_size: g, percdamp: 0.01, act_order: false });

    let act = rng.normal_vec_f32(k, 1.0);
    let dense = dequantize(&q);
    let via_gemv = gemv_f32(&act, &q);
    let via_f16 = gemv_f16_variant(&act, &q, OptConfig::BASELINE, 0);

    for col in 0..n {
        let mut expect = 0.0f32;
        for kk in 0..k {
            expect += act[kk] * dense.at(kk, col);
        }
        assert!((via_gemv[col] - expect).abs() < 1e-3);
        // f16 path within ~1% of f32 for this scale of problem
        assert!(
            (via_f16[col] - expect).abs() < 0.05 * expect.abs().max(1.0),
            "col {col}: f16 {} vs f32 {expect}",
            via_f16[col]
        );
    }
}

/// The serving engine is agnostic to model identity but sensitive to its
/// cost: a bigger model must serve the same trace strictly slower.
#[test]
fn engine_times_scale_with_model_cost() {
    let trace = RequestTrace::generate(8, 9);
    let run = |name: &str| {
        let model = by_name(name).unwrap();
        let backend = SimBackend::new(model, OptConfig::BASELINE, 8);
        let mut e = Engine::new(
            // Pinned fault-free: this compares virtual elapsed times, and
            // injected-fault retry backoffs would distort the ratio.
            EngineConfig {
                max_batch: 8,
                total_blocks: 8192,
                faults: opt4gptq::engine::FaultPlan::NONE,
                ..Default::default()
            },
            backend,
        );
        for r in &trace.requests {
            e.add_request(Request::new(
                r.id,
                r.prompt.clone(),
                SamplingParams { max_tokens: r.response_len.min(32), ..Default::default() },
            ));
        }
        e.run().unwrap().metrics.elapsed
    };
    let small = run("Qwen1.5-1.8B-Chat-GPTQ-Int4");
    let big = run("LLaMa-13B-GPTQ");
    assert!(big > 2.0 * small, "13B {big} vs 1.8B {small}");
}

/// Kernel-level gains must survive to engine-level throughput for every
/// model (the Amdahl filter of the perf model keeps them positive).
#[test]
fn kernel_gains_survive_to_serving_for_all_models() {
    let trace = RequestTrace::generate(8, 4);
    for model in PAPER_MODELS.iter() {
        let mut tputs = Vec::new();
        for opt in [OptConfig::BASELINE, OptConfig::OPT4GPTQ] {
            let backend = SimBackend::new(model, opt, 8);
            let mut e = Engine::new(
                // Pinned fault-free: the gain band asserts the undisturbed
                // cost model, not serving-under-chaos throughput.
                EngineConfig {
                    max_batch: 8,
                    total_blocks: 8192,
                    faults: opt4gptq::engine::FaultPlan::NONE,
                    ..Default::default()
                },
                backend,
            );
            for r in &trace.requests {
                e.add_request(Request::new(
                    r.id,
                    r.prompt.clone(),
                    SamplingParams { max_tokens: r.response_len.min(24), ..Default::default() },
                ));
            }
            tputs.push(e.run().unwrap().metrics.throughput());
        }
        let gain = tputs[1] / tputs[0] - 1.0;
        assert!(
            gain > 0.10 && gain < 1.5,
            "{}: end-to-end gain {:.1}% out of plausible band",
            model.name,
            gain * 100.0
        );
    }
}

/// The decode-GEMV simulation must be monotone in every problem dim.
#[test]
fn simulator_monotonicity() {
    let d = Device::z100();
    let t = |m, k, n| {
        d.simulate(&GemvKernel::new(
            KernelParams { m, k, n, group_size: 128 },
            OptConfig::BASELINE,
        ))
        .seconds
    };
    assert!(t(1, 4096, 4096) < t(1, 8192, 4096));
    assert!(t(1, 4096, 4096) < t(1, 4096, 8192));
    assert!(t(1, 4096, 4096) < t(64, 4096, 4096));
}

/// Accuracy evaluation composes with every model and both splits without
/// drifting more than the paper's 1 pp.
#[test]
fn accuracy_grid_within_one_point_everywhere() {
    for model in PAPER_MODELS.iter() {
        for split in [ArcSplit::Challenge, ArcSplit::Easy] {
            let results = evaluate(model.name, split);
            assert_eq!(results.len(), 5);
            let base = results[0].accuracy();
            for r in &results {
                assert!(
                    (r.accuracy() - base).abs() < 0.01,
                    "{} {:?} {}: {:.4} vs {:.4}",
                    model.name,
                    split,
                    r.opt.label(),
                    r.accuracy(),
                    base
                );
            }
        }
    }
}

/// RTN grids are a valid starting point for every model's layer shapes.
#[test]
fn quantization_covers_model_layer_shapes() {
    let mut rng = Rng::new(12);
    // use scaled-down versions of each model's K dims (same divisibility)
    for model in PAPER_MODELS.iter().take(3) {
        for p in model.layer_gemms(1) {
            // scaled-down K, snapped to the group size
            let k = ((p.k / 8).max(128) / 64) * 64;
            let n = 16;
            let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0));
            let q = quantize_rtn(&w, 64);
            assert_eq!(q.k, k);
            let deq = dequantize(&q);
            assert!(deq.frob_dist(&w) / (k as f64 * n as f64).sqrt() < 0.2);
        }
    }
}

/// Reproduction drivers run end to end on a reduced workload.
#[test]
fn repro_drivers_compose() {
    let grid = opt4gptq::repro::serving_grid(6, 11).unwrap();
    assert_eq!(grid.len(), 6);
    let problems = opt4gptq::repro::check_fig2_shape(&grid);
    assert!(problems.is_empty(), "{problems:?}");
    let t = opt4gptq::repro::fig2_table(&grid).render();
    assert!(t.contains("Qwen1.5-4B-Chat-GPTQ-Int4"));
}

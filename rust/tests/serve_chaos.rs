//! Swap-storm chaos test: an adversarial burst through a KV pool far too
//! small for the offered load, on the **real** CPU backend (physical
//! paged K/V, fused kernels, debug NaN-poisoning of freed blocks).
//!
//! The pool is sized so that even two fully-grown sequences cannot
//! coexist (6 requests × 14 blocks of demand through a 24-block pool),
//! which forces preemption over and over — hitting victims both
//! mid-prefill (tiny chunk budget keeps a prefill in flight for six
//! steps while admitted decodes grow) and mid-decode (pure-decode
//! phases between admissions).  Under swap-preemption every eviction
//! spills real K/V and every resume restores it onto fresh blocks.
//!
//! The teeth: per-request generated tokens must be **bit-identical**
//! across (a) a roomy run that never preempts, (b) the storm with
//! swap-preemption, and (c) the storm with discard-and-recompute.  Any
//! stale read through a recycled block surfaces as NaN logits in debug
//! builds (the sampler panics on NaN) or as a token divergence — either
//! way, loudly.
//!
//! The whole triple runs at **every [`KvDtype`]**: per-row write-once
//! quantization makes stored K/V a pure function of the written values,
//! so roomy/swap/recompute replays stay bit-identical *within* each
//! dtype (f16 and kv4 drift from the f32 tokens, but never from their
//! own unpreempted runs) — and the spill path moves packed payloads
//! whose restore must be byte-exact.

use opt4gptq::engine::{
    CpuBackend, CpuModelConfig, Engine, EngineConfig, FaultPlan, KvDtype, Request,
    RequestOutcome, SamplingParams,
};

const N_REQ: usize = 6;
const PLEN: usize = 24; // 6 blocks of 4
const GEN: usize = 32; // grows each sequence to 14 blocks

fn backend() -> CpuBackend {
    CpuBackend::new(CpuModelConfig { max_batch: 4, ..Default::default() }).unwrap()
}

fn requests() -> Vec<Request> {
    (0..N_REQ)
        .map(|i| {
            // Distinct leading tokens: no prefix sharing softens the
            // block pressure (vocab is 256 — the byte tokenizer range).
            let prompt: Vec<u32> =
                (0..PLEN).map(|j| ((i * 37 + j * 11 + 5) % 256) as u32).collect();
            Request::new(
                i,
                prompt,
                SamplingParams {
                    max_tokens: GEN,
                    temperature: 0.9,
                    top_k: 24,
                    seed: 3,
                    ..Default::default()
                },
            )
        })
        .collect()
}

fn run(cfg: EngineConfig) -> (Vec<(usize, Vec<u32>)>, Engine<CpuBackend>) {
    let mut e = Engine::new(cfg, backend());
    for r in requests() {
        e.add_request(r);
    }
    let report = e.run().unwrap();
    assert_eq!(report.outputs.len(), N_REQ, "every request must complete");
    for o in &report.outputs {
        assert_eq!(o.tokens.len(), GEN, "req {} generated {}", o.id, o.tokens.len());
        assert!(o.tokens.iter().all(|&t| t < 256), "req {} sampled out-of-vocab", o.id);
    }
    e.scheduler.check_invariants().unwrap();
    let mut toks: Vec<(usize, Vec<u32>)> =
        report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
    toks.sort();
    (toks, e)
}

fn storm_cfg(swap_preempt: bool, kv_dtype: KvDtype) -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 4,
        total_blocks: 24,
        max_seq_len: 128,
        // One block per step: a 24-token prompt prefills across six
        // steps, so exhaustion keeps catching sequences mid-prefill.
        prefill_budget: 4,
        prefix_skip: true,
        swap_preempt,
        kv_dtype,
        max_waiting: usize::MAX,
        // Pinned fault-free: the storm triple pins swap/recompute/roomy
        // bit-identity on its own; the fault-storm tests below inject on
        // top of this same workload.
        faults: FaultPlan::NONE,
    }
}

fn roomy_cfg(kv_dtype: KvDtype) -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 4,
        total_blocks: 512,
        max_seq_len: 128,
        prefill_budget: 64,
        prefix_skip: true,
        swap_preempt: true,
        kv_dtype,
        max_waiting: usize::MAX,
        // Pinned: this reference run asserts preemption_count == 0,
        // which an env-injected alloc/step fault would break.
        faults: FaultPlan::NONE,
    }
}

#[test]
fn swap_storm_is_bit_identical_to_unpreempted_run() {
    for kv_dtype in KvDtype::ALL {
        // (a) Roomy reference: same workload, pool big enough to never
        // evict.  Per dtype — f16/kv4 legitimately sample different
        // tokens than f32, so each storm compares against its own
        // dtype's unpreempted run.
        let (reference, ref_engine) = run(roomy_cfg(kv_dtype));
        assert_eq!(
            ref_engine.scheduler.preemption_count, 0,
            "[{kv_dtype}] the reference run must not preempt at all"
        );

        // (b) The storm under swap-preemption.
        let (swapped, e) = run(storm_cfg(true, kv_dtype));
        let s = &e.scheduler;
        assert!(s.swap_out_count > 0, "[{kv_dtype}] the storm must force swap-outs");
        assert!(
            s.swap_out_mid_prefill > 0,
            "[{kv_dtype}] no victim was caught mid-prefill (budget/pool sizing drifted?)"
        );
        assert!(
            s.swap_out_mid_decode > 0,
            "[{kv_dtype}] no victim was caught mid-decode (budget/pool sizing drifted?)"
        );
        assert!(
            s.swap_in_count > 0,
            "[{kv_dtype}] swapped victims must resume by restoring spill"
        );
        assert!(s.swap_restored_tokens > 0);
        assert_eq!(
            s.blocks.free_blocks(),
            24,
            "[{kv_dtype}] the drained pool must be whole — no spilled-and-lost blocks"
        );
        assert_eq!(
            swapped, reference,
            "[{kv_dtype}] swap-preempted replay diverged from the unpreempted run"
        );
        // Swap traffic must be accounted in packed bytes: with 4-token
        // blocks and the default tiny model (2 layers, d_model 64),
        // every swapped block moves exactly block_bytes of payload.
        let spilled = e.metrics.swap_spilled_bytes;
        assert!(spilled > 0, "[{kv_dtype}] spill volume must be accounted");
        assert_eq!(
            spilled % kv_dtype.block_bytes(4, 2, 64),
            0,
            "[{kv_dtype}] spill volume must be whole packed blocks"
        );

        // (c) The same storm under discard-and-recompute: same tokens, no
        // spills (differential check that swap vs recompute is purely a
        // performance choice, never a correctness one).
        let (recomputed, e) = run(storm_cfg(false, kv_dtype));
        assert_eq!(e.scheduler.swap_out_count, 0);
        assert!(
            e.scheduler.preemption_count > 0,
            "[{kv_dtype}] the storm must still preempt"
        );
        assert_eq!(e.metrics.swap_spilled_bytes, 0, "[{kv_dtype}] recompute must not spill");
        assert_eq!(
            recomputed, reference,
            "[{kv_dtype}] recompute-preempted replay diverged from the unpreempted run"
        );
    }
}

#[test]
fn fault_storm_keeps_completed_tokens_bit_identical() {
    // The swap storm again, now with a recoverable-only fault plan
    // injected on top: transient step errors (discard + bounded-backoff
    // retry), spill write/restore failures (demote to recompute) and
    // allocation refusals (admission stalls, append preemptions).  Every
    // request must still complete, with tokens bit-identical to the
    // fault-free storm, and the pool must drain clean — at every dtype.
    for kv_dtype in KvDtype::ALL {
        let (reference, _) = run(storm_cfg(true, kv_dtype));
        let plan = FaultPlan {
            seed: 20260808,
            step_transient: 0.08,
            spill_out: 0.15,
            spill_in: 0.15,
            alloc: 0.08,
            ..FaultPlan::NONE
        };
        let (faulty, e) = run(EngineConfig { faults: plan, ..storm_cfg(true, kv_dtype) });
        assert!(
            e.scheduler.faults.total_fired() > 0,
            "[{kv_dtype}] the plan must actually inject faults"
        );
        assert!(
            e.metrics.step_retries > 0,
            "[{kv_dtype}] transient step errors must drive retries"
        );
        assert_eq!(
            faulty, reference,
            "[{kv_dtype}] fault recovery diverged from the fault-free storm"
        );
        e.audit().unwrap();
    }
}

#[test]
fn fault_storm_with_permanent_faults_deadlines_and_shedding_types_every_outcome() {
    // The harshest plane: permanent step faults (batch members fail for
    // good), per-request deadlines on the accumulated clock, and a
    // bounded waiting queue that sheds the overflow.  Which requests
    // time out depends on wall time (the CPU backend's clock is real),
    // so the assertions are structural: exactly one typed outcome per
    // request, shed count exact, completed requests bit-identical to
    // the fault-free storm, pool drained clean.
    let (reference, _) = run(storm_cfg(true, KvDtype::F32));
    let plan = FaultPlan {
        seed: 7,
        step_transient: 0.05,
        step_permanent: 0.02,
        spill_out: 0.1,
        spill_in: 0.1,
        alloc: 0.05,
        ..FaultPlan::NONE
    };
    let cfg =
        EngineConfig { faults: plan, max_waiting: 4, ..storm_cfg(true, KvDtype::F32) };
    let mut e = Engine::new(cfg, backend());
    for mut r in requests() {
        r.deadline = Some(r.arrival + 5.0);
        e.add_request(r);
    }
    let report = e.run().unwrap();
    assert_eq!(report.outcomes.len(), N_REQ, "one typed outcome per request");
    let mut ids: Vec<usize> = report.outcomes.iter().map(|(id, _)| *id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), N_REQ, "duplicate or missing outcomes");
    let shed = report
        .outcomes
        .iter()
        .filter(|(_, o)| matches!(o, RequestOutcome::Rejected { .. }))
        .count();
    assert_eq!(shed, N_REQ - 4, "max_waiting=4 must shed exactly the overflow");
    for o in &report.outputs {
        let (_, want) = reference.iter().find(|(id, _)| *id == o.id).unwrap();
        assert_eq!(&o.tokens, want, "req {} diverged under faults", o.id);
    }
    for (id, outcome) in &report.outcomes {
        let has_output = report.outputs.iter().any(|o| o.id == *id);
        assert_eq!(
            has_output,
            *outcome == RequestOutcome::Completed,
            "request {id}: outputs/outcome disagree ({outcome:?})"
        );
    }
    e.audit().unwrap();
}

#[test]
fn storm_spill_volume_shrinks_with_the_dtype() {
    // The same storm (same schedule, same evictions — the scheduler is
    // dtype-blind) must move proportionally fewer spill bytes as the
    // pool dtype narrows: the payload is packed, not dequantized.
    let spilled: Vec<usize> = KvDtype::ALL
        .into_iter()
        .map(|kv_dtype| run(storm_cfg(true, kv_dtype)).1.metrics.swap_spilled_bytes)
        .collect();
    let per_block: Vec<usize> =
        KvDtype::ALL.into_iter().map(|d| d.block_bytes(4, 2, 64)).collect();
    // Exact proportionality can only be asserted if the eviction
    // schedules coincide, which dtype-driven token divergence may break;
    // blocks-moved is schedule-dependent, bytes-per-block is not.  So
    // pin the invariant that holds regardless: every run's volume is a
    // whole multiple of its dtype's packed block size, and narrower
    // dtypes move fewer bytes per swapped block.
    for (s, pb) in spilled.iter().zip(&per_block) {
        assert!(s > &0 && s % pb == 0, "volume {s} not whole blocks of {pb}");
    }
    let blocks_moved: Vec<usize> =
        spilled.iter().zip(&per_block).map(|(s, pb)| s / pb).collect();
    // If the schedules did coincide (common in practice), the byte
    // ratios collapse to the block_bytes ratios.
    for i in 1..3 {
        assert!(
            spilled[i] < spilled[0] || blocks_moved[i] > blocks_moved[0],
            "narrower dtype {} moved {} bytes vs f32's {} without moving more blocks",
            KvDtype::ALL[i],
            spilled[i],
            spilled[0],
        );
    }
}

//! Swap-storm chaos test: an adversarial burst through a KV pool far too
//! small for the offered load, on the **real** CPU backend (physical
//! paged K/V, fused kernels, debug NaN-poisoning of freed blocks).
//!
//! The pool is sized so that even two fully-grown sequences cannot
//! coexist (6 requests × 14 blocks of demand through a 24-block pool),
//! which forces preemption over and over — hitting victims both
//! mid-prefill (tiny chunk budget keeps a prefill in flight for six
//! steps while admitted decodes grow) and mid-decode (pure-decode
//! phases between admissions).  Under swap-preemption every eviction
//! spills real K/V and every resume restores it onto fresh blocks.
//!
//! The teeth: per-request generated tokens must be **bit-identical**
//! across (a) a roomy run that never preempts, (b) the storm with
//! swap-preemption, and (c) the storm with discard-and-recompute.  Any
//! stale read through a recycled block surfaces as NaN logits in debug
//! builds (the sampler panics on NaN) or as a token divergence — either
//! way, loudly.
//!
//! The whole triple runs at **every [`KvDtype`]**: per-row write-once
//! quantization makes stored K/V a pure function of the written values,
//! so roomy/swap/recompute replays stay bit-identical *within* each
//! dtype (f16 and kv4 drift from the f32 tokens, but never from their
//! own unpreempted runs) — and the spill path moves packed payloads
//! whose restore must be byte-exact.

use opt4gptq::engine::{
    CpuBackend, CpuModelConfig, Engine, EngineConfig, FaultPlan, KvDtype, Request,
    RequestOutcome, SamplingParams,
};

const N_REQ: usize = 6;
const PLEN: usize = 24; // 6 blocks of 4
const GEN: usize = 32; // grows each sequence to 14 blocks

/// The storm's model shape: the process default (so the CI model-shape
/// matrix flips this whole file between tiny-mha and tiny-gqa via
/// `OPT4GPTQ_MODEL`), capped to a 4-wide batch.
fn model() -> CpuModelConfig {
    CpuModelConfig { max_batch: 4, ..Default::default() }
}

fn backend() -> CpuBackend {
    CpuBackend::new(model()).unwrap()
}

fn requests() -> Vec<Request> {
    (0..N_REQ)
        .map(|i| {
            // Distinct leading tokens: no prefix sharing softens the
            // block pressure (vocab is 256 — the byte tokenizer range).
            let prompt: Vec<u32> =
                (0..PLEN).map(|j| ((i * 37 + j * 11 + 5) % 256) as u32).collect();
            Request::new(
                i,
                prompt,
                SamplingParams {
                    max_tokens: GEN,
                    temperature: 0.9,
                    top_k: 24,
                    seed: 3,
                    ..Default::default()
                },
            )
        })
        .collect()
}

fn run(cfg: EngineConfig) -> (Vec<(usize, Vec<u32>)>, Engine<CpuBackend>) {
    let mut e = Engine::new(cfg, backend());
    for r in requests() {
        e.add_request(r);
    }
    let report = e.run().unwrap();
    assert_eq!(report.outputs.len(), N_REQ, "every request must complete");
    for o in &report.outputs {
        assert_eq!(o.tokens.len(), GEN, "req {} generated {}", o.id, o.tokens.len());
        assert!(o.tokens.iter().all(|&t| t < 256), "req {} sampled out-of-vocab", o.id);
    }
    e.scheduler.check_invariants().unwrap();
    let mut toks: Vec<(usize, Vec<u32>)> =
        report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
    toks.sort();
    (toks, e)
}

fn storm_cfg(swap_preempt: bool, kv_dtype: KvDtype) -> EngineConfig {
    EngineConfig {
        model: model(),
        max_batch: 4,
        block_size: 4,
        total_blocks: 24,
        max_seq_len: 128,
        // One block per step: a 24-token prompt prefills across six
        // steps, so exhaustion keeps catching sequences mid-prefill.
        prefill_budget: 4,
        prefix_skip: true,
        swap_preempt,
        kv_dtype,
        max_waiting: usize::MAX,
        // Pinned fault-free: the storm triple pins swap/recompute/roomy
        // bit-identity on its own; the fault-storm tests below inject on
        // top of this same workload.
        faults: FaultPlan::NONE,
    }
}

fn roomy_cfg(kv_dtype: KvDtype) -> EngineConfig {
    EngineConfig {
        model: model(),
        max_batch: 4,
        block_size: 4,
        total_blocks: 512,
        max_seq_len: 128,
        prefill_budget: 64,
        prefix_skip: true,
        swap_preempt: true,
        kv_dtype,
        max_waiting: usize::MAX,
        // Pinned: this reference run asserts preemption_count == 0,
        // which an env-injected alloc/step fault would break.
        faults: FaultPlan::NONE,
    }
}

#[test]
fn swap_storm_is_bit_identical_to_unpreempted_run() {
    for kv_dtype in KvDtype::ALL {
        // (a) Roomy reference: same workload, pool big enough to never
        // evict.  Per dtype — f16/kv4 legitimately sample different
        // tokens than f32, so each storm compares against its own
        // dtype's unpreempted run.
        let (reference, ref_engine) = run(roomy_cfg(kv_dtype));
        assert_eq!(
            ref_engine.scheduler.preemption_count, 0,
            "[{kv_dtype}] the reference run must not preempt at all"
        );

        // (b) The storm under swap-preemption.
        let (swapped, e) = run(storm_cfg(true, kv_dtype));
        let s = &e.scheduler;
        assert!(s.swap_out_count > 0, "[{kv_dtype}] the storm must force swap-outs");
        assert!(
            s.swap_out_mid_prefill > 0,
            "[{kv_dtype}] no victim was caught mid-prefill (budget/pool sizing drifted?)"
        );
        assert!(
            s.swap_out_mid_decode > 0,
            "[{kv_dtype}] no victim was caught mid-decode (budget/pool sizing drifted?)"
        );
        assert!(
            s.swap_in_count > 0,
            "[{kv_dtype}] swapped victims must resume by restoring spill"
        );
        assert!(s.swap_restored_tokens > 0);
        assert_eq!(
            s.blocks.free_blocks(),
            24,
            "[{kv_dtype}] the drained pool must be whole — no spilled-and-lost blocks"
        );
        assert_eq!(
            swapped, reference,
            "[{kv_dtype}] swap-preempted replay diverged from the unpreempted run"
        );
        // Swap traffic must be accounted in packed bytes: every swapped
        // block moves exactly block_bytes of payload, with rows sized by
        // the model's kv_dim (narrower under GQA, not d_model).
        let m = model();
        let spilled = e.metrics.swap_spilled_bytes;
        assert!(spilled > 0, "[{kv_dtype}] spill volume must be accounted");
        assert_eq!(
            spilled % kv_dtype.block_bytes(4, m.n_layers, m.kv_dim()),
            0,
            "[{kv_dtype}] spill volume must be whole packed blocks"
        );

        // (c) The same storm under discard-and-recompute: same tokens, no
        // spills (differential check that swap vs recompute is purely a
        // performance choice, never a correctness one).
        let (recomputed, e) = run(storm_cfg(false, kv_dtype));
        assert_eq!(e.scheduler.swap_out_count, 0);
        assert!(
            e.scheduler.preemption_count > 0,
            "[{kv_dtype}] the storm must still preempt"
        );
        assert_eq!(e.metrics.swap_spilled_bytes, 0, "[{kv_dtype}] recompute must not spill");
        assert_eq!(
            recomputed, reference,
            "[{kv_dtype}] recompute-preempted replay diverged from the unpreempted run"
        );
    }
}

#[test]
fn fault_storm_keeps_completed_tokens_bit_identical() {
    // The swap storm again, now with a recoverable-only fault plan
    // injected on top: transient step errors (discard + bounded-backoff
    // retry), spill write/restore failures (demote to recompute) and
    // allocation refusals (admission stalls, append preemptions).  Every
    // request must still complete, with tokens bit-identical to the
    // fault-free storm, and the pool must drain clean — at every dtype.
    for kv_dtype in KvDtype::ALL {
        let (reference, _) = run(storm_cfg(true, kv_dtype));
        let plan = FaultPlan {
            seed: 20260808,
            step_transient: 0.08,
            spill_out: 0.15,
            spill_in: 0.15,
            alloc: 0.08,
            ..FaultPlan::NONE
        };
        let (faulty, e) = run(EngineConfig { faults: plan, ..storm_cfg(true, kv_dtype) });
        assert!(
            e.scheduler.faults.total_fired() > 0,
            "[{kv_dtype}] the plan must actually inject faults"
        );
        assert!(
            e.metrics.step_retries > 0,
            "[{kv_dtype}] transient step errors must drive retries"
        );
        assert_eq!(
            faulty, reference,
            "[{kv_dtype}] fault recovery diverged from the fault-free storm"
        );
        e.audit().unwrap();
    }
}

#[test]
fn fault_storm_with_permanent_faults_deadlines_and_shedding_types_every_outcome() {
    // The harshest plane: permanent step faults (batch members fail for
    // good), per-request deadlines on the accumulated clock, and a
    // bounded waiting queue that sheds the overflow.  Which requests
    // time out depends on wall time (the CPU backend's clock is real),
    // so the assertions are structural: exactly one typed outcome per
    // request, shed count exact, completed requests bit-identical to
    // the fault-free storm, pool drained clean.
    let (reference, _) = run(storm_cfg(true, KvDtype::F32));
    let plan = FaultPlan {
        seed: 7,
        step_transient: 0.05,
        step_permanent: 0.02,
        spill_out: 0.1,
        spill_in: 0.1,
        alloc: 0.05,
        ..FaultPlan::NONE
    };
    let cfg =
        EngineConfig { faults: plan, max_waiting: 4, ..storm_cfg(true, KvDtype::F32) };
    let mut e = Engine::new(cfg, backend());
    for mut r in requests() {
        r.deadline = Some(r.arrival + 5.0);
        e.add_request(r);
    }
    let report = e.run().unwrap();
    assert_eq!(report.outcomes.len(), N_REQ, "one typed outcome per request");
    let mut ids: Vec<usize> = report.outcomes.iter().map(|(id, _)| *id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), N_REQ, "duplicate or missing outcomes");
    let shed = report
        .outcomes
        .iter()
        .filter(|(_, o)| matches!(o, RequestOutcome::Rejected { .. }))
        .count();
    assert_eq!(shed, N_REQ - 4, "max_waiting=4 must shed exactly the overflow");
    for o in &report.outputs {
        let (_, want) = reference.iter().find(|(id, _)| *id == o.id).unwrap();
        assert_eq!(&o.tokens, want, "req {} diverged under faults", o.id);
    }
    for (id, outcome) in &report.outcomes {
        let has_output = report.outputs.iter().any(|o| o.id == *id);
        assert_eq!(
            has_output,
            *outcome == RequestOutcome::Completed,
            "request {id}: outputs/outcome disagree ({outcome:?})"
        );
    }
    e.audit().unwrap();
}

/// Fresh scratch directory for snapshot tests (unique per test + pid so
/// parallel test binaries cannot collide; wiped on entry so a previous
/// failed run's leftovers cannot leak in).
fn snap_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("o4g-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sorted_tokens(report: &opt4gptq::engine::EngineReport) -> Vec<(usize, Vec<u32>)> {
    let mut toks: Vec<(usize, Vec<u32>)> =
        report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
    toks.sort();
    toks
}

#[test]
fn kill_point_matrix_restores_bit_identically() {
    // Crash at both checkpoint-bracketing seams × every KV dtype ×
    // both preemption modes, on the full swap storm.  Phase A commits
    // snapshots and hard-dies mid-flight (the engine is just dropped);
    // phase B restores under an always-firing crash plan and is killed
    // at the seam (crash_before dies with nothing new committed,
    // crash_after right after a commit); phase C restores crash-free
    // and must finish with tokens bit-identical to an uninterrupted
    // run — whichever snapshot generation it came back from.
    for kv_dtype in KvDtype::ALL {
        for swap_preempt in [true, false] {
            let (reference, _) = run(storm_cfg(swap_preempt, kv_dtype));
            for (seam, plan) in [
                ("crash_before", FaultPlan { seed: 11, crash_before: 1.0, ..FaultPlan::NONE }),
                ("crash_after", FaultPlan { seed: 11, crash_after: 1.0, ..FaultPlan::NONE }),
            ] {
                let mode = if swap_preempt { "swap" } else { "recompute" };
                let tag = format!("{seam}-{kv_dtype}-{mode}");
                let dir = snap_dir(&format!("kill-{tag}"));
                {
                    let mut e = Engine::new(storm_cfg(swap_preempt, kv_dtype), backend());
                    e.enable_checkpoints(&dir, 2);
                    for r in requests() {
                        e.add_request(r);
                    }
                    for _ in 0..7 {
                        assert!(e.step().unwrap(), "[{tag}] storm finished suspiciously early");
                    }
                    assert!(e.metrics.checkpoints_written > 0, "[{tag}] no snapshot committed");
                }
                {
                    let cfg =
                        EngineConfig { faults: plan, ..storm_cfg(swap_preempt, kv_dtype) };
                    let mut e = Engine::restore(cfg, backend(), &dir).unwrap();
                    e.enable_checkpoints(&dir, 2);
                    let err = e.run().unwrap_err().to_string();
                    assert!(err.contains("injected crash"), "[{tag}] unexpected error: {err}");
                }
                let mut e =
                    Engine::restore(storm_cfg(swap_preempt, kv_dtype), backend(), &dir).unwrap();
                e.enable_checkpoints(&dir, 2);
                let report = e.run().unwrap();
                assert_eq!(
                    sorted_tokens(&report),
                    reference,
                    "[{tag}] restored run diverged from the uninterrupted one"
                );
                e.audit().unwrap();
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

#[test]
fn torn_or_corrupt_tail_snapshot_falls_back_to_an_older_valid_one() {
    // The atomic tmp-write + rename makes a torn committed snapshot
    // "impossible", so simulate a filesystem that lied about
    // durability: truncate the newest snapshot mid-record, then flip a
    // payload byte in the next one.  Restore must reject each damaged
    // generation (CRC / missing END record) and rehydrate the newest
    // *valid* snapshot — finishing bit-identical either way, just
    // replaying a little more work.
    let kv_dtype = KvDtype::Kv4;
    let (reference, _) = run(storm_cfg(true, kv_dtype));
    let dir = snap_dir("torn");
    {
        let mut e = Engine::new(storm_cfg(true, kv_dtype), backend());
        e.enable_checkpoints(&dir, 2);
        for r in requests() {
            e.add_request(r);
        }
        for _ in 0..8 {
            assert!(e.step().unwrap());
        }
        assert!(e.metrics.checkpoints_written >= 3, "need several snapshot generations");
    }
    let mut snaps: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|entry| entry.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "bin"))
        .collect();
    snaps.sort();
    assert!(snaps.len() >= 3);

    // Torn write: drop the END record (9 trailing bytes) of the newest.
    let newest = &snaps[snaps.len() - 1];
    let bytes = std::fs::read(newest).unwrap();
    std::fs::write(newest, &bytes[..bytes.len() - 9]).unwrap();
    let mut e = Engine::restore(storm_cfg(true, kv_dtype), backend(), &dir).unwrap();
    let report = e.run().unwrap();
    assert_eq!(
        sorted_tokens(&report),
        reference,
        "fallback restore (torn tail) diverged from the uninterrupted run"
    );
    e.audit().unwrap();

    // Silent bit rot: flip one payload byte mid-file in the next-newest.
    let rotted = &snaps[snaps.len() - 2];
    let mut bytes = std::fs::read(rotted).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x41;
    std::fs::write(rotted, &bytes).unwrap();
    let mut e = Engine::restore(storm_cfg(true, kv_dtype), backend(), &dir).unwrap();
    let report = e.run().unwrap();
    assert_eq!(
        sorted_tokens(&report),
        reference,
        "fallback restore (bit rot) diverged from the uninterrupted run"
    );
    e.audit().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mid_layer_poison_is_caught_loudly_at_every_dtype() {
    // An always-firing MidLayerPoison plan NaN-corrupts one attention
    // tile *inside* every forward pass.  The NaN propagates through
    // the causal attention of the next layer into the sampled logits,
    // where the backend's output check turns it into a terminal step
    // error — every request must resolve as a typed Failed naming the
    // detector, never as silent token garbage, and the drained pool
    // must still audit clean (poisoned K/V never outlives its batch).
    for kv_dtype in KvDtype::ALL {
        let plan = FaultPlan { seed: 5, mid_layer_poison: 1.0, ..FaultPlan::NONE };
        let mut e =
            Engine::new(EngineConfig { faults: plan, ..roomy_cfg(kv_dtype) }, backend());
        for r in requests() {
            e.add_request(r);
        }
        let report = e.run().unwrap();
        assert!(
            report.outputs.is_empty(),
            "[{kv_dtype}] poisoned batches must not complete: {:?}",
            report.outputs.iter().map(|o| o.id).collect::<Vec<_>>()
        );
        assert_eq!(report.outcomes.len(), N_REQ);
        for (id, outcome) in &report.outcomes {
            match outcome {
                RequestOutcome::Failed { reason } => assert!(
                    reason.contains("non-finite logits"),
                    "[{kv_dtype}] req {id} failed for the wrong reason: {reason}"
                ),
                other => panic!("[{kv_dtype}] req {id}: expected Failed, got {other:?}"),
            }
        }
        e.audit().unwrap();
    }
}

#[test]
fn restore_rehydrates_computed_prefix_blocks_across_runs() {
    // Cross-run prefix persistence: run 1 serves two requests sharing a
    // 16-token system prompt with checkpointing on and dies mid-decode
    // (the shared blocks are computed and referenced, so their packed
    // K/V payloads travel in the snapshot).  Run 2 restores into a
    // fresh engine and submits a *new* request with the same system
    // prompt: its whole shared span must be served from the rehydrated
    // blocks — skipped outright, zero re-prefill — and its tokens must
    // match a fresh single-run reference exactly (the rehydrated K/V
    // is bit-exact, not merely shape-compatible).
    let kv_dtype = KvDtype::F32;
    let shared: Vec<u32> = (0..16u32).map(|j| (j * 7 + 3) % 256).collect(); // 4 full blocks
    let mk = |id: usize, tail_seed: u32| {
        let mut prompt = shared.clone();
        prompt.extend((0..8u32).map(|j| (tail_seed + j * 5) % 256));
        Request::new(
            id,
            prompt,
            SamplingParams {
                max_tokens: 8,
                temperature: 0.9,
                top_k: 24,
                seed: 3,
                ..Default::default()
            },
        )
    };
    let mut reference = Engine::new(roomy_cfg(kv_dtype), backend());
    for i in 0..2 {
        reference.add_request(mk(i, 100 + i as u32 * 40));
    }
    reference.add_request(mk(7, 210));
    let ref_report = reference.run().unwrap();
    assert_eq!(ref_report.outputs.len(), 3);

    let dir = snap_dir("prefix");
    {
        let mut e = Engine::new(roomy_cfg(kv_dtype), backend());
        e.enable_checkpoints(&dir, 1);
        for i in 0..2 {
            e.add_request(mk(i, 100 + i as u32 * 40));
        }
        // Step past the prefills into decode, then hard-die: the last
        // snapshot holds both sequences mid-generation with the shared
        // blocks computed.
        for _ in 0..4 {
            assert!(e.step().unwrap());
        }
        assert!(e.metrics.checkpoints_written > 0);
    }
    let mut e = Engine::restore(roomy_cfg(kv_dtype), backend(), &dir).unwrap();
    let skipped_at_restore = e.scheduler.prefill_tokens_skipped;
    let hits_at_restore = e.scheduler.blocks.prefix_hits;
    e.add_request(mk(7, 210));
    let report = e.run().unwrap();
    assert_eq!(report.outputs.len(), 3, "both restored requests + the new one must finish");
    assert!(
        e.scheduler.blocks.prefix_hits > hits_at_restore,
        "the new request must hit the rehydrated prefix blocks"
    );
    assert_eq!(
        e.scheduler.prefill_tokens_skipped - skipped_at_restore,
        shared.len(),
        "the whole shared span must be skipped, not re-prefilled"
    );
    assert_eq!(
        sorted_tokens(&report),
        sorted_tokens(&ref_report),
        "tokens served through rehydrated prefix K/V diverged from a fresh run"
    );
    e.audit().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gqa_swap_storm_and_kill_points_replay_bit_identically() {
    // The swap storm and the checkpoint kill-point matrix again, pinned
    // to the tiny-gqa registry entry (1 KV head shared by 4 Q heads,
    // RoPE on) regardless of OPT4GPTQ_MODEL.  GQA rows are 4x narrower
    // (kv_dim 16 vs 64) and K is stored pre-rotated, so this leg proves
    // swap spill, recompute replay and snapshot restore stay
    // bit-identical when the spilled payload is a shared rotated row —
    // at every pool dtype.
    let gqa = CpuModelConfig { max_batch: 4, ..opt4gptq::models::TINY_GQA };
    let gqa_backend = || CpuBackend::new(gqa).unwrap();
    let gqa_cfg = |swap: bool, kv_dtype: KvDtype| EngineConfig {
        model: gqa,
        ..storm_cfg(swap, kv_dtype)
    };
    let gqa_run = |cfg: EngineConfig| -> (Vec<(usize, Vec<u32>)>, Engine<CpuBackend>) {
        let mut e = Engine::new(cfg, gqa_backend());
        for r in requests() {
            e.add_request(r);
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), N_REQ, "[gqa] every request must complete");
        e.scheduler.check_invariants().unwrap();
        (sorted_tokens(&report), e)
    };
    for kv_dtype in KvDtype::ALL {
        let (reference, ref_e) = gqa_run(EngineConfig { model: gqa, ..roomy_cfg(kv_dtype) });
        assert_eq!(
            ref_e.scheduler.preemption_count, 0,
            "[gqa {kv_dtype}] the reference run must not preempt"
        );
        let (swapped, e) = gqa_run(gqa_cfg(true, kv_dtype));
        assert!(e.scheduler.swap_out_count > 0, "[gqa {kv_dtype}] storm must force swap-outs");
        let spilled = e.metrics.swap_spilled_bytes;
        let pb = kv_dtype.block_bytes(4, gqa.n_layers, gqa.kv_dim());
        assert!(
            spilled > 0 && spilled % pb == 0,
            "[gqa {kv_dtype}] spill volume {spilled} not whole kv_dim-sized blocks of {pb}"
        );
        assert_eq!(
            swapped, reference,
            "[gqa {kv_dtype}] swap-preempted replay diverged from the unpreempted run"
        );
        let (recomputed, e2) = gqa_run(gqa_cfg(false, kv_dtype));
        assert!(e2.scheduler.preemption_count > 0, "[gqa {kv_dtype}] storm must still preempt");
        assert_eq!(
            recomputed, reference,
            "[gqa {kv_dtype}] recompute-preempted replay diverged from the unpreempted run"
        );
        e2.audit().unwrap();
    }
    // Kill-point crash matrix at kv4 (the densest packed payload), swap
    // mode, both checkpoint-bracketing seams.
    let kv_dtype = KvDtype::Kv4;
    let (reference, _) = gqa_run(gqa_cfg(true, kv_dtype));
    for (seam, plan) in [
        ("crash_before", FaultPlan { seed: 11, crash_before: 1.0, ..FaultPlan::NONE }),
        ("crash_after", FaultPlan { seed: 11, crash_after: 1.0, ..FaultPlan::NONE }),
    ] {
        let dir = snap_dir(&format!("gqa-kill-{seam}"));
        {
            let mut e = Engine::new(gqa_cfg(true, kv_dtype), gqa_backend());
            e.enable_checkpoints(&dir, 2);
            for r in requests() {
                e.add_request(r);
            }
            for _ in 0..7 {
                assert!(e.step().unwrap(), "[gqa {seam}] storm finished suspiciously early");
            }
            assert!(e.metrics.checkpoints_written > 0, "[gqa {seam}] no snapshot committed");
        }
        {
            let cfg = EngineConfig { faults: plan, ..gqa_cfg(true, kv_dtype) };
            let mut e = Engine::restore(cfg, gqa_backend(), &dir).unwrap();
            e.enable_checkpoints(&dir, 2);
            let err = e.run().unwrap_err().to_string();
            assert!(err.contains("injected crash"), "[gqa {seam}] unexpected error: {err}");
        }
        let mut e = Engine::restore(gqa_cfg(true, kv_dtype), gqa_backend(), &dir).unwrap();
        e.enable_checkpoints(&dir, 2);
        let report = e.run().unwrap();
        assert_eq!(
            sorted_tokens(&report),
            reference,
            "[gqa {seam}] restored run diverged from the uninterrupted one"
        );
        e.audit().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn storm_spill_volume_shrinks_with_the_dtype() {
    // The same storm (same schedule, same evictions — the scheduler is
    // dtype-blind) must move proportionally fewer spill bytes as the
    // pool dtype narrows: the payload is packed, not dequantized.
    let spilled: Vec<usize> = KvDtype::ALL
        .into_iter()
        .map(|kv_dtype| run(storm_cfg(true, kv_dtype)).1.metrics.swap_spilled_bytes)
        .collect();
    let m = model();
    let per_block: Vec<usize> =
        KvDtype::ALL.into_iter().map(|d| d.block_bytes(4, m.n_layers, m.kv_dim())).collect();
    // Exact proportionality can only be asserted if the eviction
    // schedules coincide, which dtype-driven token divergence may break;
    // blocks-moved is schedule-dependent, bytes-per-block is not.  So
    // pin the invariant that holds regardless: every run's volume is a
    // whole multiple of its dtype's packed block size, and narrower
    // dtypes move fewer bytes per swapped block.
    for (s, pb) in spilled.iter().zip(&per_block) {
        assert!(s > &0 && s % pb == 0, "volume {s} not whole blocks of {pb}");
    }
    let blocks_moved: Vec<usize> =
        spilled.iter().zip(&per_block).map(|(s, pb)| s / pb).collect();
    // If the schedules did coincide (common in practice), the byte
    // ratios collapse to the block_bytes ratios.
    for i in 1..3 {
        assert!(
            spilled[i] < spilled[0] || blocks_moved[i] > blocks_moved[0],
            "narrower dtype {} moved {} bytes vs f32's {} without moving more blocks",
            KvDtype::ALL[i],
            spilled[i],
            spilled[0],
        );
    }
}

//! Swap-storm chaos test: an adversarial burst through a KV pool far too
//! small for the offered load, on the **real** CPU backend (physical
//! paged K/V, fused kernels, debug NaN-poisoning of freed blocks).
//!
//! The pool is sized so that even two fully-grown sequences cannot
//! coexist (6 requests × 14 blocks of demand through a 24-block pool),
//! which forces preemption over and over — hitting victims both
//! mid-prefill (tiny chunk budget keeps a prefill in flight for six
//! steps while admitted decodes grow) and mid-decode (pure-decode
//! phases between admissions).  Under swap-preemption every eviction
//! spills real K/V and every resume restores it onto fresh blocks.
//!
//! The teeth: per-request generated tokens must be **bit-identical**
//! across (a) a roomy run that never preempts, (b) the storm with
//! swap-preemption, and (c) the storm with discard-and-recompute.  Any
//! stale read through a recycled block surfaces as NaN logits in debug
//! builds (the sampler panics on NaN) or as a token divergence — either
//! way, loudly.

use opt4gptq::engine::{
    CpuBackend, CpuModelConfig, Engine, EngineConfig, Request, SamplingParams,
};

const N_REQ: usize = 6;
const PLEN: usize = 24; // 6 blocks of 4
const GEN: usize = 32; // grows each sequence to 14 blocks

fn backend() -> CpuBackend {
    CpuBackend::new(CpuModelConfig { max_batch: 4, ..Default::default() }).unwrap()
}

fn requests() -> Vec<Request> {
    (0..N_REQ)
        .map(|i| {
            // Distinct leading tokens: no prefix sharing softens the
            // block pressure (vocab is 256 — the byte tokenizer range).
            let prompt: Vec<u32> =
                (0..PLEN).map(|j| ((i * 37 + j * 11 + 5) % 256) as u32).collect();
            Request::new(
                i,
                prompt,
                SamplingParams {
                    max_tokens: GEN,
                    temperature: 0.9,
                    top_k: 24,
                    seed: 3,
                    ..Default::default()
                },
            )
        })
        .collect()
}

fn run(cfg: EngineConfig) -> (Vec<(usize, Vec<u32>)>, Engine<CpuBackend>) {
    let mut e = Engine::new(cfg, backend());
    for r in requests() {
        e.add_request(r);
    }
    let report = e.run().unwrap();
    assert_eq!(report.outputs.len(), N_REQ, "every request must complete");
    for o in &report.outputs {
        assert_eq!(o.tokens.len(), GEN, "req {} generated {}", o.id, o.tokens.len());
        assert!(o.tokens.iter().all(|&t| t < 256), "req {} sampled out-of-vocab", o.id);
    }
    e.scheduler.check_invariants().unwrap();
    let mut toks: Vec<(usize, Vec<u32>)> =
        report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
    toks.sort();
    (toks, e)
}

fn storm_cfg(swap_preempt: bool) -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        block_size: 4,
        total_blocks: 24,
        max_seq_len: 128,
        // One block per step: a 24-token prompt prefills across six
        // steps, so exhaustion keeps catching sequences mid-prefill.
        prefill_budget: 4,
        prefix_skip: true,
        swap_preempt,
    }
}

#[test]
fn swap_storm_is_bit_identical_to_unpreempted_run() {
    // (a) Roomy reference: same workload, pool big enough to never evict.
    let (reference, ref_engine) = run(EngineConfig {
        max_batch: 4,
        block_size: 4,
        total_blocks: 512,
        max_seq_len: 128,
        prefill_budget: 64,
        prefix_skip: true,
        swap_preempt: true,
    });
    assert_eq!(
        ref_engine.scheduler.preemption_count, 0,
        "the reference run must not preempt at all"
    );

    // (b) The storm under swap-preemption.
    let (swapped, e) = run(storm_cfg(true));
    let s = &e.scheduler;
    assert!(s.swap_out_count > 0, "the storm must force swap-outs");
    assert!(
        s.swap_out_mid_prefill > 0,
        "no victim was caught mid-prefill (budget/pool sizing drifted?)"
    );
    assert!(
        s.swap_out_mid_decode > 0,
        "no victim was caught mid-decode (budget/pool sizing drifted?)"
    );
    assert!(s.swap_in_count > 0, "swapped victims must resume by restoring spill");
    assert!(s.swap_restored_tokens > 0);
    assert_eq!(
        s.blocks.free_blocks(),
        24,
        "the drained pool must be whole — no spilled-and-lost blocks"
    );
    assert_eq!(
        swapped, reference,
        "swap-preempted replay diverged from the unpreempted run"
    );

    // (c) The same storm under discard-and-recompute: same tokens, no
    // spills (differential check that swap vs recompute is purely a
    // performance choice, never a correctness one).
    let (recomputed, e) = run(storm_cfg(false));
    assert_eq!(e.scheduler.swap_out_count, 0);
    assert!(e.scheduler.preemption_count > 0, "the storm must still preempt");
    assert_eq!(
        recomputed, reference,
        "recompute-preempted replay diverged from the unpreempted run"
    );
}

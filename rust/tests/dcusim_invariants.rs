//! dcusim invariants across the five optimization configs.
//!
//! The paper's optimizations change *how* bytes move and instructions
//! issue, never *what* must move: for a fixed `KernelParams`, every
//! variant owes the same minimum traffic (packed weights + activations +
//! outputs) and the same flops, and SMB's whole effect on the write path
//! is to divide the per-block global atomics by exactly `SPLIT_K`.

use opt4gptq::dcusim::isa::IsaCostModel;
use opt4gptq::dcusim::kernels::gemv::SPLIT_K;
use opt4gptq::dcusim::kernels::KernelParams;
use opt4gptq::dcusim::{DcuConfig, Device, GemvKernel};
use opt4gptq::OptConfig;

fn shapes() -> Vec<KernelParams> {
    vec![
        KernelParams { m: 1, k: 4096, n: 4096, group_size: 128 },
        KernelParams { m: 8, k: 2048, n: 2560, group_size: 64 },
        KernelParams { m: 32, k: 5120, n: 13824, group_size: 128 },
        KernelParams { m: 64, k: 4096, n: 11008, group_size: 128 },
    ]
}

#[test]
fn min_bytes_and_flops_identical_across_all_variants() {
    let cfg = DcuConfig::z100();
    let isa = IsaCostModel::default();
    for p in shapes() {
        let kernels: Vec<GemvKernel> =
            OptConfig::ALL.iter().map(|&o| GemvKernel::new(p, o)).collect();
        // The roofline numerator is a property of the shape alone.
        let min_bytes: Vec<u64> = kernels.iter().map(|k| k.params.min_bytes()).collect();
        let flops: Vec<u64> = kernels.iter().map(|k| k.params.flops()).collect();
        assert!(min_bytes.windows(2).all(|w| w[0] == w[1]), "{p:?}: min_bytes {min_bytes:?}");
        assert!(flops.windows(2).all(|w| w[0] == w[1]), "{p:?}: flops {flops:?}");

        // The *useful* bytes each variant's block actually accounts for
        // must also agree — optimizations may change transaction counts
        // and issue cycles, never the useful traffic.
        let useful: Vec<u64> = kernels
            .iter()
            .map(|k| {
                let bw = k.block_work(&cfg, &isa);
                bw.mem.read_bytes_useful + bw.mem.write_bytes_useful
            })
            .collect();
        assert!(
            useful.windows(2).all(|w| w[0] == w[1]),
            "{p:?}: useful bytes diverge across variants: {useful:?}"
        );
    }
}

#[test]
fn smb_reduces_block_atomics_by_exactly_split_k() {
    let cfg = DcuConfig::z100();
    let isa = IsaCostModel::default();
    for p in shapes() {
        let base = GemvKernel::new(p, OptConfig::BASELINE).block_work(&cfg, &isa);
        for smb_opt in [OptConfig::SMB, OptConfig::OPT4GPTQ] {
            let smb = GemvKernel::new(p, smb_opt).block_work(&cfg, &isa);
            assert_eq!(
                base.atomics_per_block,
                smb.atomics_per_block * SPLIT_K as u64,
                "{p:?} {}: atomics {} vs {}",
                smb_opt.label(),
                base.atomics_per_block,
                smb.atomics_per_block
            );
        }
        // Non-SMB variants keep the baseline atomic count.
        for other in [OptConfig::VML, OptConfig::ILA] {
            let bw = GemvKernel::new(p, other).block_work(&cfg, &isa);
            assert_eq!(bw.atomics_per_block, base.atomics_per_block, "{p:?} {}", other.label());
        }
    }
}

#[test]
fn simulated_reports_stay_internally_consistent() {
    // The per-variant reports must expose the same problem-level totals
    // the invariants above pin, end to end through Device::simulate.
    let d = Device::z100();
    for p in shapes() {
        let reports: Vec<_> =
            OptConfig::ALL.iter().map(|&o| d.simulate(&GemvKernel::new(p, o))).collect();
        for r in &reports {
            assert!(r.seconds > 0.0 && r.seconds.is_finite());
            assert!(r.mem_efficiency > 0.0 && r.mem_efficiency <= 1.0);
        }
        // Identical flops + differing seconds ⇒ achieved tflops ordering
        // must invert the seconds ordering.
        for w in reports.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            assert_eq!(
                (a.seconds < b.seconds),
                (a.achieved_tflops > b.achieved_tflops),
                "{p:?}: {} vs {}",
                a.label,
                b.label
            );
        }
    }
}

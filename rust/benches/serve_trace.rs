//! Trace-driven serving bench: replay a seeded ShareGPT-like trace with
//! Poisson arrivals through the engine under **block pressure**, once
//! with swap-preemption and once with discard-and-recompute, on the
//! simulated backend's virtual clock (deterministic — every number in
//! the JSON replays exactly).
//!
//! Acceptance floor (full mode only): the pressured config must
//! actually preempt, and swap-preemption must beat recompute on
//! generation tokens/s — a preempted victim resumes from its frozen
//! cursor instead of re-prefilling its whole effective prompt.  The
//! virtual clock prices *compute*, not spill copies, so the reported
//! speedup is the compute-side bound of the swap win; the wall-clock
//! cost of the copies themselves is covered by `engine_hotpath` and the
//! correctness of the spill path by `rust/tests/serve_chaos.rs`.
//!
//! Parity is asserted before any number is reported: both modes must
//! generate bit-identical per-request tokens (a fast wrong scheduler is
//! not a speedup).
//!
//! Every measurement lands in `BENCH_serve_trace.json` under stable
//! `label` keys; CI's `tools/bench_gate.rs` step gates the
//! `swap_vs_recompute pressured` row's `speedup_tokens_per_s` against
//! the committed `BENCH_serve_trace.baseline.json`.  The pressured swap
//! run is additionally replayed at every compressed [`KvDtype`]: tokens
//! must be identical (the sim backend is dtype-blind) while accounted
//! spill traffic shrinks in exact packed-block proportion.  Run: `cargo
//! bench --bench serve_trace` — or with `-- --smoke` for the CI-sized
//! run (fewer requests, no perf floors, JSON still emitted).

use opt4gptq::benchkit::Table;
use opt4gptq::engine::{
    Engine, EngineConfig, EngineReport, KvDtype, Request, SamplingParams, SimBackend,
};
use opt4gptq::models::by_name;
use opt4gptq::trace::{RequestTrace, TraceConfig};
use opt4gptq::OptConfig;

const ARRIVAL_RATE: f64 = 50.0; // req/s, open-loop
const MAX_BATCH: usize = 16;

fn trace(n: usize) -> RequestTrace {
    // Clamped lengths keep per-sequence demand ≤ 5 blocks of 16, so the
    // 48-block pool below is real pressure (16 × 5 = 80 blocks of
    // concurrent demand), not instant rejection.
    let cfg = TraceConfig { prompt_max: 48, response_max: 32, ..Default::default() };
    RequestTrace::generate_with(n, 7, cfg).with_arrivals(ARRIVAL_RATE, 42)
}

fn run(
    trace: &RequestTrace,
    model_name: &str,
    swap_preempt: bool,
    kv_dtype: KvDtype,
) -> (Vec<(usize, Vec<u32>)>, EngineReport) {
    let model = by_name(model_name).unwrap();
    let mut e = Engine::new(
        EngineConfig {
            model: *model,
            max_batch: MAX_BATCH,
            block_size: 16,
            total_blocks: 48,
            max_seq_len: 256,
            prefill_budget: 64,
            prefix_skip: true,
            swap_preempt,
            kv_dtype,
            max_waiting: usize::MAX,
            // Pinned fault-free: this is a performance benchmark; an
            // env-injected fault plan would poison the gated numbers.
            faults: opt4gptq::engine::FaultPlan::NONE,
        },
        SimBackend::new(model, OptConfig::OPT4GPTQ, MAX_BATCH),
    );
    for r in &trace.requests {
        let mut req = Request::new(
            r.id,
            r.prompt.clone(),
            SamplingParams {
                max_tokens: r.response_len,
                temperature: 0.8,
                top_k: 32,
                seed: 7,
                ..Default::default()
            },
        );
        req.arrival = r.arrival;
        e.add_request(req);
    }
    let report = e.run().expect("engine run");
    assert_eq!(
        report.outputs.len(),
        trace.requests.len(),
        "every trace request must complete"
    );
    e.scheduler.check_invariants().expect("scheduler invariants");
    let mut toks: Vec<(usize, Vec<u32>)> =
        report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
    toks.sort();
    (toks, report)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 96 } else { 1000 };
    println!(
        "trace-driven serving bench: {n} requests, Poisson {ARRIVAL_RATE} req/s, \
         48-block KV pool (virtual clock){}",
        if smoke { "  [smoke mode: reduced trace, no perf floors]" } else { "" }
    );

    let t = trace(n);
    let (swap_toks, swap) = run(&t, "Llama-2-7B-GPTQ", true, KvDtype::F32);
    let (rec_toks, rec) = run(&t, "Llama-2-7B-GPTQ", false, KvDtype::F32);
    assert_eq!(
        swap_toks, rec_toks,
        "swap and recompute replays must generate bit-identical tokens"
    );
    assert_eq!(rec.metrics.swap_outs, 0, "recompute mode must never spill");

    let speedup = swap.metrics.throughput() / rec.metrics.throughput();
    let mut table = Table::new(
        "swap-preemption vs discard-and-recompute under block pressure",
        &["mode", "tok/s", "p99 TTFT", "p99 TPOT", "p99 queue", "preempts", "swaps"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for (mode, rep) in [("swap", &swap), ("recompute", &rec)] {
        let m = &rep.metrics;
        let (ttft, tpot, queue) =
            (m.ttft_quantiles(), m.tpot_quantiles(), m.queue_time_quantiles());
        table.row(vec![
            mode.to_string(),
            format!("{:.1}", m.throughput()),
            format!("{:.3}s", ttft.p99),
            format!("{:.4}s", tpot.p99),
            format!("{:.3}s", queue.p99),
            format!("{}", m.preemptions),
            format!("{}/{}", m.swap_outs, m.swap_ins),
        ]);
        json_rows.push(format!(
            "    {{\"label\": \"serve_trace {mode}\", \"mode\": \"{mode}\", \
             \"requests\": {n}, \"arrival_rate\": {ARRIVAL_RATE}, \
             \"tokens_per_s\": {:.3}, \"total_tokens_per_s\": {:.3}, \
             \"elapsed_virtual_s\": {:.4}, \
             \"p50_ttft_s\": {:.6}, \"p99_ttft_s\": {:.6}, \
             \"p50_tpot_s\": {:.6}, \"p99_tpot_s\": {:.6}, \
             \"p50_queue_s\": {:.6}, \"p99_queue_s\": {:.6}, \
             \"preemptions\": {}, \"preempt_rate\": {:.4}, \
             \"swap_outs\": {}, \"swap_ins\": {}, \"swap_restored_tokens\": {}}}",
            m.throughput(),
            m.total_throughput(),
            m.elapsed,
            ttft.p50,
            ttft.p99,
            tpot.p50,
            tpot.p99,
            queue.p50,
            queue.p99,
            m.preemptions,
            m.preemptions as f64 / n as f64,
            m.swap_outs,
            m.swap_ins,
            m.swap_restored_tokens,
        ));
    }
    json_rows.push(format!(
        "    {{\"label\": \"swap_vs_recompute pressured\", \
         \"speedup_tokens_per_s\": {speedup:.4}, \
         \"swap_tokens_per_s\": {:.3}, \"recompute_tokens_per_s\": {:.3}}}",
        swap.metrics.throughput(),
        rec.metrics.throughput(),
    ));
    table.print();
    println!("\nswap vs recompute: {speedup:.3}x generation tokens/s");

    // The same pressured swap run at the compressed KV dtypes: the sim
    // backend's logits are dtype-blind, so tokens — and therefore the
    // whole eviction schedule — must be identical, while the accounted
    // spill traffic shrinks in *exact* proportion to the packed block
    // size (asserted by cross-multiplication, which also holds at zero
    // spills in smoke mode).
    let model = by_name("Llama-2-7B-GPTQ").unwrap();
    let block_bytes = |d: KvDtype| d.block_bytes(16, model.n_layers, model.kv_dim());
    let f32_spilled = swap.metrics.swap_spilled_bytes;
    let mut spill_rows: Vec<(KvDtype, usize)> = vec![(KvDtype::F32, f32_spilled)];
    for kv_dtype in [KvDtype::F16, KvDtype::Kv4] {
        let (toks, rep) = run(&t, "Llama-2-7B-GPTQ", true, kv_dtype);
        assert_eq!(
            toks, swap_toks,
            "{kv_dtype}: the sim backend's tokens must not depend on the KV dtype"
        );
        let spilled = rep.metrics.swap_spilled_bytes;
        assert_eq!(
            spilled as u128 * block_bytes(KvDtype::F32) as u128,
            f32_spilled as u128 * block_bytes(kv_dtype) as u128,
            "{kv_dtype}: spill traffic must shrink in exact packed-block proportion"
        );
        if f32_spilled > 0 {
            assert!(
                spilled < f32_spilled,
                "{kv_dtype}: spill volume {spilled} did not shrink below f32's {f32_spilled}"
            );
        }
        spill_rows.push((kv_dtype, spilled));
    }
    println!("spill traffic under pressure:");
    for (kv_dtype, spilled) in &spill_rows {
        println!(
            "  {kv_dtype:>4}: {:.1} KiB ({:.2}x f32)",
            *spilled as f64 / 1024.0,
            if f32_spilled > 0 { *spilled as f64 / f32_spilled as f64 } else { 0.0 },
        );
    }
    json_rows.push(format!(
        "    {{\"label\": \"kv_dtype spill pressured\", \
         \"spilled_bytes_f32\": {f32_spilled}, \
         \"spilled_bytes_f16\": {}, \"spilled_bytes_kv4\": {}, \
         \"shrink_f16\": {:.4}, \"shrink_kv4\": {:.4}}}",
        spill_rows[1].1,
        spill_rows[2].1,
        block_bytes(KvDtype::F16) as f64 / block_bytes(KvDtype::F32) as f64,
        block_bytes(KvDtype::Kv4) as f64 / block_bytes(KvDtype::F32) as f64,
    ));

    // Informational GQA row (ungated, baseline untouched): the same
    // pressured swap replay on the paper's GQA checkpoint
    // (Meta-Llama-3-8B, 32 Q heads over 8 KV heads).  Spilled blocks
    // carry kv_dim-wide rows, so the accounted bytes per swapped block
    // are 4× smaller than the MHA checkpoint's at equal dtype.
    let gqa_name = "Meta-Llama-3-8B-GPTQ";
    let (_, gqa) = run(&t, gqa_name, true, KvDtype::F32);
    let gqa_model = by_name(gqa_name).unwrap();
    let gqa_block_bytes = KvDtype::F32.block_bytes(16, gqa_model.n_layers, gqa_model.kv_dim());
    println!(
        "GQA checkpoint ({gqa_name}, {}q/{}kv): {:.1} tok/s, spill {:.1} KiB \
         ({} B/block vs MHA's {})",
        gqa_model.n_heads,
        gqa_model.n_kv_heads,
        gqa.metrics.throughput(),
        gqa.metrics.swap_spilled_bytes as f64 / 1024.0,
        gqa_block_bytes,
        block_bytes(KvDtype::F32),
    );
    json_rows.push(format!(
        "    {{\"label\": \"serve_trace gqa swap\", \"model\": \"{gqa_name}\", \
         \"n_heads\": {}, \"n_kv_heads\": {}, \
         \"tokens_per_s_ungated\": {:.3}, \"swap_spilled_bytes\": {}, \
         \"kv_block_bytes_f32\": {gqa_block_bytes}, \"mha_kv_block_bytes_f32\": {}}}",
        gqa_model.n_heads,
        gqa_model.n_kv_heads,
        gqa.metrics.throughput(),
        gqa.metrics.swap_spilled_bytes,
        block_bytes(KvDtype::F32),
    ));

    let json = format!(
        "{{\n  \"bench\": \"serve_trace\",\n  \"smoke\": {smoke},\n  \
         \"requests\": {n},\n  \"arrival_rate\": {ARRIVAL_RATE},\n  \
         \"cases\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n"),
    );
    std::fs::write("BENCH_serve_trace.json", &json)
        .expect("failed to write BENCH_serve_trace.json");
    println!("wrote BENCH_serve_trace.json ({} rows)", json_rows.len());

    let mut failures: Vec<String> = Vec::new();
    if !smoke {
        if rec.metrics.preemptions == 0 {
            failures.push("pressured config did not preempt (pool sizing drifted?)".into());
        }
        if swap.metrics.swap_outs == 0 {
            failures.push("swap mode never spilled under pressure".into());
        }
        if speedup <= 1.0 {
            failures.push(format!(
                "swap-preemption must beat recompute on tokens/s under pressure \
                 ({speedup:.4}x)"
            ));
        }
    }
    if failures.is_empty() {
        if smoke {
            println!("\nshape check: smoke mode (perf floors skipped; parity asserts passed)");
        } else {
            println!("\nshape check: OK (swap beats recompute at {speedup:.3}x, bit-identical)");
        }
    } else {
        println!("\nshape check FAILED:");
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}

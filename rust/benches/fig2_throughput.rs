//! Regenerates **Figure 2** — inference throughput of vLLM across the six
//! GPTQ models before/after SMB-Opt, VML-Opt, ILA-Opt and Opt4GPTQ.
//!
//! Run: `cargo bench --bench fig2_throughput`

use opt4gptq::benchkit;
use opt4gptq::repro;

fn main() -> opt4gptq::Result<()> {
    let t0 = std::time::Instant::now();
    // Paper setup: one batch of 32 ShareGPT prompts (§IV-B).
    let grid = repro::serving_grid(32, 2025)?;
    repro::fig2_table(&grid).print();

    let problems = repro::check_fig2_shape(&grid);
    if problems.is_empty() {
        println!("\nshape check: OK (ILA > SMB > VML, combined largest, 13B > 1.8B)");
    } else {
        println!("\nshape check FAILED:");
        for p in &problems {
            println!("  - {p}");
        }
        std::process::exit(1);
    }

    // Wall-clock of the reproduction itself (simulator throughput).
    println!(
        "\nbench wall time: {} (30 engine runs, 6 models x 5 configs)",
        benchkit::fmt_duration(t0.elapsed().as_secs_f64())
    );
    Ok(())
}

//! Fused dequant-GEMM vs the dense oracle, decode and prefill shapes,
//! plus the kernel-dispatch face-off: every registry kernel this host
//! runs (scalar, AVX2, AVX-512), and the active kernel's
//! swizzle-prepacked serve path.
//!
//! The oracle (`gptq::gemm`) re-materializes the dense `K×N` weight
//! matrix on every call; the fused path (`gptq::fused`) unpacks nibbles
//! on the fly per tile through the runtime-dispatched kernel.  Headline
//! number: the 4096×4096, group-128, M = 1 decode GEMV, where the fused
//! kernel must be ≥ 10× faster (this bench exits non-zero if it is not,
//! like the figure benches' shape checks).
//!
//! Three more floors on the same decode shape (full mode only):
//! * the scoped-thread column split must never be slower than serial
//!   (best-of-N);
//! * on hosts with AVX2+FMA, the explicit SIMD path (best of raw and
//!   swizzle-prepacked) must never be slower than the forced-scalar
//!   path (best-of-N);
//! * on hosts with AVX-512F/BW, the 16-lane kernel must never be slower
//!   than the 8-lane AVX2 one (best-of-N, raw storage layout on both
//!   sides so lane width is the only variable) — the paper's
//!   wider-vector claim, pinned.
//!
//! Every measurement is also written to `BENCH_fused_gemm.json` (shape,
//! ns/iter, GB/s, dispatch path) to seed the perf trajectory across PRs.
//! The headline decode shape is measured in smoke mode too: CI's
//! `tools/bench_gate.rs` step compares its ns/iter (and speedup) against
//! the committed `BENCH_fused_gemm.baseline.json` and fails on a > 15%
//! regression.
//!
//! Run: `cargo bench --bench fused_gemm` — or with `-- --smoke` for the
//! CI-sized run (reduced shapes, no perf floors, JSON still emitted)
//! that keeps the bench path itself exercised.

use opt4gptq::benchkit::{bench, fmt_duration, Stats, Table};
use opt4gptq::gptq::{
    available_kernels, fused_threads, gemm_f32, gemm_fused_opt, gemv_f32, gemv_fused_opt,
    quantize_rtn, FusedInput, FusedOpts, Kernel, KernelDispatch, Matrix, PreparedTensor,
    QuantizedTensor,
};
use opt4gptq::rng::Rng;

/// Collapsed-surface shorthand: auto kernel + auto split on a raw tensor.
fn gemv_auto(x: &[f32], q: &QuantizedTensor) -> Vec<f32> {
    gemv_fused_opt(x, FusedInput::Raw(q), FusedOpts::default())
}

fn gemm_auto(x: &Matrix, q: &QuantizedTensor) -> Matrix {
    gemm_fused_opt(x, FusedInput::Raw(q), FusedOpts::default())
}

/// Auto kernel, forced worker count.
fn gemv_threads(x: &[f32], q: &QuantizedTensor, threads: usize) -> Vec<f32> {
    gemv_fused_opt(x, FusedInput::Raw(q), FusedOpts { kernel: None, threads: Some(threads) })
}

struct Case {
    label: &'static str,
    m: usize,
    k: usize,
    n: usize,
    group: usize,
    act_order: bool,
    /// The acceptance floor applies only to the headline decode shape
    /// (and never in smoke mode).
    required_speedup: Option<f64>,
}

fn make_tensor(k: usize, n: usize, group: usize, rng: &mut Rng) -> QuantizedTensor {
    let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0 / (k as f32).sqrt()));
    quantize_rtn(&w, group)
}

/// Keep the best-of-N winner (by min — scheduling noise must not decide
/// a face-off) in `slot`.
fn take_best(slot: &mut Option<Stats>, stats: &Stats) {
    let better = match slot {
        None => true,
        Some(best) => stats.min < best.min,
    };
    if better {
        *slot = Some(stats.clone());
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let dispatch = KernelDispatch::get();
    println!(
        "kernel dispatch: {} (source: {}){}",
        dispatch.kernel.name(),
        dispatch.source,
        if smoke { "  [smoke mode: reduced shapes, no perf floors]" } else { "" }
    );

    let smoke_cases = [
        Case {
            label: "decode M=1 1024x1024 g128 (smoke)",
            m: 1,
            k: 1024,
            n: 1024,
            group: 128,
            act_order: false,
            required_speedup: None,
        },
        Case {
            label: "batch M=8 512x512 g64 (smoke)",
            m: 8,
            k: 512,
            n: 512,
            group: 64,
            act_order: true,
            required_speedup: None,
        },
        // The headline decode shape rides along in smoke mode (no perf
        // floor) so CI's bench-regression gate always has the
        // "decode M=1 4096x4096 g128" row to compare against baseline.
        Case {
            label: "decode M=1 4096x4096 g128",
            m: 1,
            k: 4096,
            n: 4096,
            group: 128,
            act_order: false,
            required_speedup: None,
        },
    ];
    let full_cases = [
        Case {
            label: "decode M=1 4096x4096 g128",
            m: 1,
            k: 4096,
            n: 4096,
            group: 128,
            act_order: false,
            required_speedup: Some(10.0),
        },
        Case {
            label: "decode M=1 4096x4096 g128 act-order",
            m: 1,
            k: 4096,
            n: 4096,
            group: 128,
            act_order: true,
            required_speedup: None,
        },
        Case {
            label: "decode M=1 4096x4096 g64",
            m: 1,
            k: 4096,
            n: 4096,
            group: 64,
            act_order: false,
            required_speedup: None,
        },
        Case {
            label: "prefill M=64 2048x2048 g128",
            m: 64,
            k: 2048,
            n: 2048,
            group: 128,
            act_order: false,
            required_speedup: None,
        },
        Case {
            label: "batch M=8 4096x4096 g128",
            m: 8,
            k: 4096,
            n: 4096,
            group: 128,
            act_order: false,
            required_speedup: None,
        },
    ];
    let cases: &[Case] = if smoke { &smoke_cases } else { &full_cases };

    let mut table = Table::new(
        "fused dequant-GEMM vs dense oracle (wall clock)",
        &["shape", "oracle p50", "fused p50", "speedup", "GB/s", "max |Δ|", "required"],
    );
    let mut failures = Vec::new();
    let mut case_json: Vec<String> = Vec::new();

    for case in cases {
        let mut rng = Rng::new(0xf05e_d000 ^ case.k as u64 ^ (case.m as u64) << 32);
        let mut q = make_tensor(case.k, case.n, case.group, &mut rng);
        if case.act_order {
            let mut perm: Vec<usize> = (0..case.k).collect();
            rng.shuffle(&mut perm);
            q = q.with_perm(perm);
        }
        let x = Matrix::from_vec(
            case.m,
            case.k,
            rng.normal_vec_f32(case.m * case.k, 1.0 / (case.k as f32).sqrt()),
        );

        // Correctness first: a fast wrong kernel is not a speedup.
        let (want, got) = if case.m == 1 {
            (gemv_f32(x.row(0), &q), gemv_auto(x.row(0), &q))
        } else {
            (gemm_f32(&x, &q).data, gemm_auto(&x, &q).data)
        };
        let max_diff =
            want.iter().zip(&got).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "{}: parity broken, max diff {max_diff}", case.label);

        let iters = if smoke || case.m >= 8 { 3 } else { 5 };
        let oracle = if case.m == 1 {
            bench(&format!("oracle {}", case.label), 1, iters, || {
                std::hint::black_box(gemv_f32(x.row(0), &q));
            })
        } else {
            bench(&format!("oracle {}", case.label), 1, iters, || {
                std::hint::black_box(gemm_f32(&x, &q));
            })
        };
        let fused = if case.m == 1 {
            bench(&format!("fused  {}", case.label), 1, iters, || {
                std::hint::black_box(gemv_auto(x.row(0), &q));
            })
        } else {
            bench(&format!("fused  {}", case.label), 1, iters, || {
                std::hint::black_box(gemm_auto(&x, &q));
            })
        };

        let speedup = oracle.p50 / fused.p50;
        let gbps = q.fused_traffic_bytes(case.m) as f64 / fused.p50 / 1e9;
        if let Some(floor) = case.required_speedup {
            if speedup < floor {
                failures.push(format!(
                    "{}: {speedup:.2}x is below the required {floor:.0}x",
                    case.label
                ));
            }
        }
        table.row(vec![
            case.label.to_string(),
            fmt_duration(oracle.p50),
            fmt_duration(fused.p50),
            format!("{speedup:.2}x"),
            format!("{gbps:.2}"),
            format!("{max_diff:.2e}"),
            case.required_speedup.map_or("-".into(), |f| format!(">= {f:.0}x")),
        ]);
        case_json.push(format!(
            "    {{\"label\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"group\": {}, \
             \"act_order\": {}, \"dispatch\": \"{}\", \"ns_per_iter\": {:.0}, \
             \"gb_per_s\": {:.3}, \"speedup_vs_oracle\": {:.3}}}",
            case.label,
            case.m,
            case.k,
            case.n,
            case.group,
            case.act_order,
            dispatch.kernel.name(),
            fused.p50 * 1e9,
            gbps,
            speedup
        ));
    }

    table.print();

    // ---- kernel face-off: forced dispatch paths, headline decode shape ----
    let (k, n, group) = if smoke { (1024, 1024, 128) } else { (4096usize, 4096usize, 128usize) };
    let mut rng = Rng::new(0x9a7a_11e1);
    let q = make_tensor(k, n, group, &mut rng);
    let x = rng.normal_vec_f32(k, 1.0 / (k as f32).sqrt());
    let face_iters = if smoke { 3 } else { 7 };
    let mut kernel_json: Vec<String> = Vec::new();
    let traffic = q.fused_traffic_bytes(1) as f64;
    let mut scalar_stats: Option<Stats> = None;
    let mut avx2_stats: Option<Stats> = None;
    let mut avx512_stats: Option<Stats> = None;
    // Best SIMD path overall (any vector kernel, raw or prepacked) for
    // the SIMD-vs-scalar floor.  The avx512-vs-avx2 width floor instead
    // compares the two raw storage-layout rows only: both kernels
    // stream unaligned there, so lane width is the sole variable (the
    // swizzle row would hand AVX-512 an aligned-load advantage AVX2 is
    // never benched with).
    let mut best_simd: Option<Stats> = None;

    for kernel in available_kernels() {
        let stats = bench(
            &format!("kernel {:<14} M=1 {k}x{n} g{group} serial", kernel.name()),
            1,
            face_iters,
            || {
                std::hint::black_box(gemv_fused_opt(&x, FusedInput::Raw(&q), FusedOpts { kernel: Some(kernel), threads: Some(1) }));
            },
        );
        kernel_json.push(format!(
            "    {{\"kernel\": \"{}\", \"ns_per_iter\": {:.0}, \"gb_per_s\": {:.3}}}",
            kernel.name(),
            stats.p50 * 1e9,
            traffic / stats.p50 / 1e9
        ));
        match kernel {
            Kernel::Scalar => scalar_stats = Some(stats),
            Kernel::Avx2 => {
                take_best(&mut best_simd, &stats);
                avx2_stats = Some(stats);
            }
            Kernel::Avx512 => {
                take_best(&mut best_simd, &stats);
                avx512_stats = Some(stats);
            }
        }
    }
    // The serve path: swizzle-prepacked aligned streaming loads at the
    // active kernel's lane width.  Only meaningful when the *active*
    // dispatch is a vector kernel — prepared calls follow the dispatch
    // table, so under a forced-scalar run this row would silently
    // measure the scalar kernel again.
    if dispatch.kernel.swizzle_width().is_some() {
        let prep = PreparedTensor::new(q.clone());
        let swz_name = format!("{}+swizzle", dispatch.kernel.name());
        let stats = bench(
            &format!("kernel {swz_name:<14} M=1 {k}x{n} g{group} serial"),
            1,
            face_iters,
            || {
                std::hint::black_box(gemv_fused_opt(&x, FusedInput::Prepared(&prep), FusedOpts { kernel: None, threads: Some(1) }));
            },
        );
        kernel_json.push(format!(
            "    {{\"kernel\": \"{swz_name}\", \"ns_per_iter\": {:.0}, \"gb_per_s\": {:.3}}}",
            stats.p50 * 1e9,
            traffic / stats.p50 / 1e9
        ));
        take_best(&mut best_simd, &stats);
    }
    if let (Some(scalar), Some(simd)) = (&scalar_stats, &best_simd) {
        // Best-of-N: scheduling noise must not fail the floor.
        let ratio = scalar.min / simd.min;
        println!(
            "\nkernel face-off: scalar p50 {} vs SIMD p50 {}  ({ratio:.2}x best-of)",
            fmt_duration(scalar.p50),
            fmt_duration(simd.p50),
        );
        if !smoke && ratio < 1.0 {
            failures.push(format!(
                "SIMD fused GEMV is slower than scalar on the {k}x{n} decode shape: {ratio:.2}x"
            ));
        }
    }
    // The wider-vector floor: where AVX-512 is detected, the 16-lane
    // kernel must be at least as fast as the 8-lane AVX2 one, best-of-N,
    // raw-vs-raw (see above — like-for-like load alignment).
    if let (Some(a2), Some(a512)) = (&avx2_stats, &avx512_stats) {
        let ratio = a2.min / a512.min;
        println!(
            "kernel face-off: avx2 p50 {} vs avx512 p50 {}  ({ratio:.2}x best-of)",
            fmt_duration(a2.p50),
            fmt_duration(a512.p50),
        );
        if !smoke && ratio < 1.0 {
            failures.push(format!(
                "AVX-512 fused GEMV is slower than AVX2 on the {k}x{n} decode shape: {ratio:.2}x"
            ));
        }
    }

    // ---- parallel vs serial fused path, headline decode shape ----
    let workers = fused_threads(1, k, n);

    // Bit-exactness first (always checkable): a racy fast path is not a
    // speedup.  Force 2 workers for the parity check even on one core.
    let serial_y = gemv_threads(&x, &q, 1);
    let parallel_y = gemv_threads(&x, &q, workers.max(2));
    assert_eq!(serial_y, parallel_y, "column split changed the numerics");

    let parallel_json;
    if workers > 1 {
        let serial = bench(&format!("fused serial   M=1 {k}x{n} g{group}"), 2, face_iters, || {
            std::hint::black_box(gemv_threads(&x, &q, 1));
        });
        let parallel =
            bench(&format!("fused parallel M=1 {k}x{n} g{group} (t={workers})"), 2, face_iters, || {
                std::hint::black_box(gemv_threads(&x, &q, workers));
            });
        // Best-of-N comparison: scheduling noise must not fail the floor.
        let par_speedup = serial.min / parallel.min;
        println!(
            "\nparallel column split: serial p50 {} vs parallel p50 {}  ({:.2}x best-of)",
            fmt_duration(serial.p50),
            fmt_duration(parallel.p50),
            par_speedup
        );
        if !smoke && par_speedup < 1.0 {
            failures.push(format!(
                "parallel fused GEMV is slower than serial at N={n}: {par_speedup:.2}x"
            ));
        }
        parallel_json = format!(
            "{{\"workers\": {workers}, \"serial_ns\": {:.0}, \"parallel_ns\": {:.0}, \
             \"speedup_best_of\": {:.3}}}",
            serial.p50 * 1e9,
            parallel.p50 * 1e9,
            par_speedup
        );
    } else {
        // fused_threads correctly refuses to split (single core, or the
        // smoke shape is under the work floor) — no parallel path to race.
        println!("\nparallel column split: skipped (auto-split stays serial here)");
        parallel_json = "{\"skipped\": true}".to_string();
    }

    // ---- machine-readable record for the perf trajectory ----
    let json = format!(
        "{{\n  \"bench\": \"fused_gemm\",\n  \"smoke\": {smoke},\n  \"dispatch\": \
         {{\"kernel\": \"{}\", \"source\": \"{}\"}},\n  \"auto_workers\": {workers},\n  \
         \"cases\": [\n{}\n  ],\n  \"kernels\": [\n{}\n  ],\n  \"parallel\": {parallel_json}\n}}\n",
        dispatch.kernel.name(),
        dispatch.source,
        case_json.join(",\n"),
        kernel_json.join(",\n"),
    );
    std::fs::write("BENCH_fused_gemm.json", &json)
        .expect("failed to write BENCH_fused_gemm.json");
    println!(
        "\nwrote BENCH_fused_gemm.json ({} cases, {} kernel rows)",
        case_json.len(),
        kernel_json.len()
    );

    if failures.is_empty() {
        if smoke {
            println!("\nshape check: smoke mode (perf floors skipped; parity asserts passed)");
        } else {
            println!(
                "\nshape check: OK (headline >=10x floor; SIMD >= scalar; avx512 >= avx2 \
                 where detected; parallel >= serial at N={n})"
            );
        }
    } else {
        println!("\nshape check FAILED:");
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}

//! Fused dequant-GEMM vs the dense oracle, decode and prefill shapes.
//!
//! The oracle (`gptq::gemm`) re-materializes the dense `K×N` weight
//! matrix on every call; the fused path (`gptq::fused`) unpacks nibbles
//! on the fly per tile.  Headline number: the 4096×4096, group-128,
//! M = 1 decode GEMV, where the fused kernel must be ≥ 10× faster
//! (this bench exits non-zero if it is not, like the figure benches'
//! shape checks).
//!
//! A second section pits the scoped-thread column-split parallel path
//! against the serial path on the same headline decode shape: the
//! parallel path must never be slower there (best-of-N, exits non-zero
//! on regression) and must stay bit-identical.
//!
//! Run: `cargo bench --bench fused_gemm`

use opt4gptq::benchkit::{bench, fmt_duration, Table};
use opt4gptq::gptq::{
    fused_threads, gemm_f32, gemm_fused, gemv_f32, gemv_fused, gemv_fused_threads, quantize_rtn,
    Matrix,
};
use opt4gptq::rng::Rng;

struct Case {
    label: &'static str,
    m: usize,
    k: usize,
    n: usize,
    group: usize,
    act_order: bool,
    /// The acceptance floor applies only to the headline decode shape.
    required_speedup: Option<f64>,
}

fn main() {
    let cases = [
        Case {
            label: "decode M=1 4096x4096 g128",
            m: 1,
            k: 4096,
            n: 4096,
            group: 128,
            act_order: false,
            required_speedup: Some(10.0),
        },
        Case {
            label: "decode M=1 4096x4096 g128 act-order",
            m: 1,
            k: 4096,
            n: 4096,
            group: 128,
            act_order: true,
            required_speedup: None,
        },
        Case {
            label: "decode M=1 4096x4096 g64",
            m: 1,
            k: 4096,
            n: 4096,
            group: 64,
            act_order: false,
            required_speedup: None,
        },
        Case {
            label: "prefill M=64 2048x2048 g128",
            m: 64,
            k: 2048,
            n: 2048,
            group: 128,
            act_order: false,
            required_speedup: None,
        },
        Case {
            label: "batch M=8 4096x4096 g128",
            m: 8,
            k: 4096,
            n: 4096,
            group: 128,
            act_order: false,
            required_speedup: None,
        },
    ];

    let mut table = Table::new(
        "fused dequant-GEMM vs dense oracle (wall clock)",
        &["shape", "oracle p50", "fused p50", "speedup", "max |Δ|", "required"],
    );
    let mut failures = Vec::new();

    for case in &cases {
        let mut rng = Rng::new(0xf05e_d000 ^ case.k as u64 ^ (case.m as u64) << 32);
        let w = Matrix::from_vec(
            case.k,
            case.n,
            rng.normal_vec_f32(case.k * case.n, 1.0 / (case.k as f32).sqrt()),
        );
        let mut q = quantize_rtn(&w, case.group);
        if case.act_order {
            let mut perm: Vec<usize> = (0..case.k).collect();
            rng.shuffle(&mut perm);
            q = q.with_perm(perm);
        }
        let x = Matrix::from_vec(
            case.m,
            case.k,
            rng.normal_vec_f32(case.m * case.k, 1.0 / (case.k as f32).sqrt()),
        );

        // Correctness first: a fast wrong kernel is not a speedup.
        let (want, got) = if case.m == 1 {
            (gemv_f32(x.row(0), &q), gemv_fused(x.row(0), &q))
        } else {
            (gemm_f32(&x, &q).data, gemm_fused(&x, &q).data)
        };
        let max_diff =
            want.iter().zip(&got).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "{}: parity broken, max diff {max_diff}", case.label);

        let iters = if case.m >= 8 { 3 } else { 5 };
        let oracle = if case.m == 1 {
            bench(&format!("oracle {}", case.label), 1, iters, || {
                std::hint::black_box(gemv_f32(x.row(0), &q));
            })
        } else {
            bench(&format!("oracle {}", case.label), 1, iters, || {
                std::hint::black_box(gemm_f32(&x, &q));
            })
        };
        let fused = if case.m == 1 {
            bench(&format!("fused  {}", case.label), 1, iters, || {
                std::hint::black_box(gemv_fused(x.row(0), &q));
            })
        } else {
            bench(&format!("fused  {}", case.label), 1, iters, || {
                std::hint::black_box(gemm_fused(&x, &q));
            })
        };

        let speedup = oracle.p50 / fused.p50;
        if let Some(floor) = case.required_speedup {
            if speedup < floor {
                failures.push(format!(
                    "{}: {speedup:.2}x is below the required {floor:.0}x",
                    case.label
                ));
            }
        }
        table.row(vec![
            case.label.to_string(),
            fmt_duration(oracle.p50),
            fmt_duration(fused.p50),
            format!("{speedup:.2}x"),
            format!("{max_diff:.2e}"),
            case.required_speedup.map_or("-".into(), |f| format!(">= {f:.0}x")),
        ]);
    }

    table.print();

    // ---- parallel vs serial fused path, headline decode shape ----
    let (k, n, group) = (4096usize, 4096usize, 128usize);
    let mut rng = Rng::new(0x9a7a_11e1);
    let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0 / (k as f32).sqrt()));
    let q = quantize_rtn(&w, group);
    let x = rng.normal_vec_f32(k, 1.0 / (k as f32).sqrt());
    let workers = fused_threads(1, k, n);

    // Bit-exactness first (always checkable): a racy fast path is not a
    // speedup.  Force 2 workers for the parity check even on one core.
    let serial_y = gemv_fused_threads(&x, &q, 1);
    let parallel_y = gemv_fused_threads(&x, &q, workers.max(2));
    assert_eq!(serial_y, parallel_y, "column split changed the numerics");

    if workers > 1 {
        let serial = bench("fused serial   M=1 4096x4096 g128", 2, 7, || {
            std::hint::black_box(gemv_fused_threads(&x, &q, 1));
        });
        let parallel =
            bench(&format!("fused parallel M=1 4096x4096 g128 (t={workers})"), 2, 7, || {
                std::hint::black_box(gemv_fused_threads(&x, &q, workers));
            });
        // Best-of-N comparison: scheduling noise must not fail the floor.
        let par_speedup = serial.min / parallel.min;
        println!(
            "\nparallel column split: serial p50 {} vs parallel p50 {}  ({:.2}x best-of)",
            fmt_duration(serial.p50),
            fmt_duration(parallel.p50),
            par_speedup
        );
        if par_speedup < 1.0 {
            failures.push(format!(
                "parallel fused GEMV is slower than serial at N=4096: {par_speedup:.2}x"
            ));
        }
    } else {
        // One core: fused_threads correctly refuses to split, so there
        // is no parallel path to race — nothing to assert.
        println!("\nparallel column split: skipped (single-core machine, auto-split stays serial)");
    }

    if failures.is_empty() {
        println!("\nshape check: OK (headline >=10x floor; parallel >= serial at N=4096)");
    } else {
        println!("\nshape check FAILED:");
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}

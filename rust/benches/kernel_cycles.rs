//! Supporting bench: per-kernel simulated cycles behind Figures 2–3 —
//! every GEMM shape of every paper model, across the five configs.
//!
//! Run: `cargo bench --bench kernel_cycles`

use opt4gptq::benchkit::Table;
use opt4gptq::dcusim::{Device, GemvKernel};
use opt4gptq::models::PAPER_MODELS;
use opt4gptq::OptConfig;

fn main() {
    let device = Device::z100();
    let batch = 32;
    let mut t = Table::new(
        &format!("Per-shape kernel time (µs), decode batch {batch}, {}", device.cfg.name),
        &["model", "shape (K→N)", "Baseline", "SMB", "VML", "ILA", "Opt4", "speedup", "base bound"],
    );
    for model in PAPER_MODELS.iter() {
        let mut shapes = model.layer_gemms(batch);
        shapes.dedup();
        for p in shapes {
            let mut cells = vec![model.name.to_string(), format!("{}→{}", p.k, p.n)];
            let mut base = None;
            let mut bound = "";
            let mut last = 0.0;
            for opt in OptConfig::ALL {
                let r = device.simulate(&GemvKernel::new(p, opt));
                if base.is_none() {
                    base = Some(r.seconds);
                    bound = r.bound;
                }
                last = r.seconds;
                cells.push(format!("{:.1}", r.seconds * 1e6));
            }
            cells.push(format!("{:.2}x", base.unwrap() / last));
            cells.push(bound.to_string());
            t.row(cells);
        }
    }
    t.print();

    // Roofline summary for the headline shape (13B hidden GEMV).
    let p = opt4gptq::dcusim::kernels::KernelParams { m: batch, k: 5120, n: 5120, group_size: 128 };
    println!("\nroofline @ 13B qkv shape (m={batch}):");
    for opt in OptConfig::ALL {
        let r = device.simulate(&GemvKernel::new(p, opt));
        println!(
            "  {:<10} {:6.2} TFLOPS ({:4.1}% of peak)  {:7.1} GB/s useful  mem-eff {:.2}",
            r.label,
            r.achieved_tflops,
            r.roofline_fraction * 100.0,
            r.achieved_gbps,
            r.mem_efficiency
        );
    }
}

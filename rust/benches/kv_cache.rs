//! Quantized paged-KV bench: the attention block walk at every
//! [`KvDtype`], plus the capacity win that motivates compressing the
//! pool in the first place.
//!
//! Two measurement families:
//!
//! * **`kv_walk <dtype>`** — single-sequence decode on the real
//!   [`CpuBackend`] at a fixed context length: every step walks the
//!   whole paged K/V through the dtype's read path (f32 borrow, f16
//!   `vcvtph2ps` slice dequant, kv4 nibble dequant into the scratch
//!   tile).  Wall-clock, machine-dependent — reported, not gated.
//!
//! * **`kv_capacity`** — resident tokens a fixed byte budget holds per
//!   dtype, straight from [`KvDtype`] layout arithmetic.  Fully
//!   deterministic, so CI gates it tightly (`tools/bench_gate.rs
//!   --only kv_capacity`) against `BENCH_kv_cache.baseline.json`, and
//!   this bench itself enforces the acceptance floors in *both* modes:
//!   f16 must hold ≥ 1.9× the f32 tokens, kv4 ≥ 3.5×.
//!
//! Run: `cargo bench --bench kv_cache` — or with `-- --smoke` for the
//! CI-sized run (short context, fewer iters, floors still enforced
//! because they are layout facts, not timings).

use opt4gptq::benchkit::{bench, fmt_duration, Table};
use opt4gptq::engine::{Backend, CpuBackend, CpuModelConfig, DecodeDesc, KvDtype, PrefillDesc};

const BLOCK_SIZE: usize = 16;
const N_LAYERS: usize = 2;
const D_MODEL: usize = 128;
/// Capacity budget the `kv_capacity` row is computed against.
const BUDGET_BYTES: usize = 1 << 20;

fn backend() -> CpuBackend {
    CpuBackend::new(CpuModelConfig {
        max_seq: 512,
        d_model: D_MODEL,
        n_layers: N_LAYERS,
        n_heads: 4,
        d_ff: 256,
        ..Default::default()
    })
    .expect("backend config")
}

/// Same dims under grouped-query attention: 4 Q heads share 1 KV head
/// (`kv_dim` 32 vs 128) with RoPE on — the pool rows this walk reads
/// are 4× narrower at f32/f16 and carry the same per-row kv4 header.
fn gqa_backend() -> CpuBackend {
    CpuBackend::new(CpuModelConfig {
        max_seq: 512,
        d_model: D_MODEL,
        n_layers: N_LAYERS,
        n_heads: 4,
        n_kv_heads: 1,
        rope: true,
        d_ff: 256,
        ..opt4gptq::models::TINY_GQA
    })
    .expect("gqa backend config")
}

/// `kv_dim` of [`gqa_backend`]'s shape (1 KV head × d_head 32).
const GQA_KV_DIM: usize = D_MODEL / 4;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "quantized paged-KV bench{}",
        if smoke { "  [smoke mode: reduced shapes]" } else { "" }
    );

    let ctx = if smoke { 48 } else { 192 };
    let iters = if smoke { 3 } else { 9 };
    let prompt: Vec<u32> = (0..ctx).map(|i| ((i * 37 + 11) % 256) as u32).collect();
    let table_blocks: Vec<usize> = (0..(ctx + 1).div_ceil(BLOCK_SIZE)).collect();

    let mut out = Table::new(
        "attention block walk by KV dtype (CpuBackend wall clock)",
        &["dtype", "ctx", "decode p50", "tok/s", "pool bytes", "B/token"],
    );
    let mut failures: Vec<String> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();

    for dtype in KvDtype::ALL {
        let mut be = backend();
        be.bind_kv(table_blocks.len(), BLOCK_SIZE, dtype);
        let (logits, _) = be
            .prefill(PrefillDesc {
                seq_id: 0,
                tokens: &prompt,
                start: 0,
                is_last: true,
                block_table: &table_blocks,
            })
            .expect("prefill");
        if !logits.iter().all(|v| v.is_finite()) {
            failures.push(format!("{dtype}: prefill produced non-finite logits"));
        }
        // Same position every iteration: each decode re-walks the full
        // context through the dtype's read path, which is the measured
        // cost; the rewritten row just requantizes in place.
        let desc = DecodeDesc { seq_id: 0, context_len: ctx, token: 7, block_table: &table_blocks };
        let stats = bench(&format!("kv_walk {dtype} ctx {ctx}"), 1, iters, || {
            std::hint::black_box(be.decode(&[desc]).expect("decode").0);
        });
        let tok_per_s = 1.0 / stats.p50;
        let pool_bytes = be.kv().bytes();
        let bytes_per_token = be.kv().bytes_per_token();
        out.row(vec![
            dtype.to_string(),
            format!("{ctx}"),
            fmt_duration(stats.p50),
            format!("{tok_per_s:.0}"),
            format!("{pool_bytes}"),
            format!("{bytes_per_token}"),
        ]);
        json_rows.push(format!(
            "    {{\"label\": \"kv_walk {dtype}\", \"dtype\": \"{dtype}\", \
             \"ctx\": {ctx}, \"walk_p50_ns_ungated\": {:.0}, \
             \"walk_tok_per_s\": {tok_per_s:.1}, \"pool_bytes\": {pool_bytes}, \
             \"bytes_per_token\": {bytes_per_token}}}",
            stats.p50 * 1e9,
        ));
    }
    out.print();

    // The same walk at the GQA shape: every Q head reads the one shared
    // KV head's slice, so the context bytes streamed per step shrink by
    // the group ratio.  Wall-clock — reported, not gated.
    let mut gqa_out = Table::new(
        "attention block walk, GQA 4q/1kv + RoPE (CpuBackend wall clock)",
        &["dtype", "ctx", "decode p50", "tok/s", "pool bytes", "B/token"],
    );
    for dtype in KvDtype::ALL {
        let mut be = gqa_backend();
        be.bind_kv(table_blocks.len(), BLOCK_SIZE, dtype);
        let (logits, _) = be
            .prefill(PrefillDesc {
                seq_id: 0,
                tokens: &prompt,
                start: 0,
                is_last: true,
                block_table: &table_blocks,
            })
            .expect("gqa prefill");
        if !logits.iter().all(|v| v.is_finite()) {
            failures.push(format!("gqa {dtype}: prefill produced non-finite logits"));
        }
        let desc = DecodeDesc { seq_id: 0, context_len: ctx, token: 7, block_table: &table_blocks };
        let stats = bench(&format!("kv_walk gqa {dtype} ctx {ctx}"), 1, iters, || {
            std::hint::black_box(be.decode(&[desc]).expect("gqa decode").0);
        });
        let tok_per_s = 1.0 / stats.p50;
        let pool_bytes = be.kv().bytes();
        let bytes_per_token = be.kv().bytes_per_token();
        gqa_out.row(vec![
            dtype.to_string(),
            format!("{ctx}"),
            fmt_duration(stats.p50),
            format!("{tok_per_s:.0}"),
            format!("{pool_bytes}"),
            format!("{bytes_per_token}"),
        ]);
        json_rows.push(format!(
            "    {{\"label\": \"kv_walk gqa {dtype}\", \"dtype\": \"{dtype}\", \
             \"ctx\": {ctx}, \"walk_p50_ns_ungated\": {:.0}, \
             \"walk_tok_per_s\": {tok_per_s:.1}, \"pool_bytes\": {pool_bytes}, \
             \"bytes_per_token\": {bytes_per_token}}}",
            stats.p50 * 1e9,
        ));
    }
    gqa_out.print();

    // Capacity: tokens a fixed budget keeps resident, per dtype.  Pure
    // layout arithmetic — deterministic across machines, so the floors
    // hold in smoke mode too and CI can gate the row at 1%.
    let tokens_of = |d: KvDtype| BUDGET_BYTES / (2 * N_LAYERS * d.row_bytes(D_MODEL));
    let (t32, t16, t4) = (tokens_of(KvDtype::F32), tokens_of(KvDtype::F16), tokens_of(KvDtype::Kv4));
    let cap_f16 = t16 as f64 / t32 as f64;
    let cap_kv4 = t4 as f64 / t32 as f64;
    println!(
        "\ncapacity at {} KiB: f32 {t32} tokens, f16 {t16} ({cap_f16:.2}x), kv4 {t4} ({cap_kv4:.2}x)",
        BUDGET_BYTES / 1024
    );
    if cap_f16 < 1.9 {
        failures.push(format!("f16 capacity {cap_f16:.3}x is below the 1.9x floor"));
    }
    if cap_kv4 < 3.5 {
        failures.push(format!("kv4 capacity {cap_kv4:.3}x is below the 3.5x floor"));
    }
    json_rows.push(format!(
        "    {{\"label\": \"kv_capacity\", \"budget_bytes\": {BUDGET_BYTES}, \
         \"d_model\": {D_MODEL}, \"n_layers\": {N_LAYERS}, \
         \"tokens_f32\": {t32}, \"tokens_f16\": {t16}, \"tokens_kv4\": {t4}, \
         \"speedup_capacity_f16\": {cap_f16:.3}, \"speedup_capacity_kv4\": {cap_kv4:.3}}}"
    ));

    // GQA capacity: the same budget with kv_dim-wide rows (32 vs 128).
    // The gated multiplier is resident tokens at the GQA shape over the
    // MHA shape *at equal dtype* — the paper's GQA memory win, layout
    // arithmetic only.  Floor 1.9× at every dtype (kv4's per-row
    // scale/zero header dilutes the 4× row shrink to ~3×).
    let gqa_tokens_of = |d: KvDtype| BUDGET_BYTES / (2 * N_LAYERS * d.row_bytes(GQA_KV_DIM));
    let (g32, g16, g4) =
        (gqa_tokens_of(KvDtype::F32), gqa_tokens_of(KvDtype::F16), gqa_tokens_of(KvDtype::Kv4));
    let gqa_f32 = g32 as f64 / t32 as f64;
    let gqa_f16 = g16 as f64 / t16 as f64;
    let gqa_kv4 = g4 as f64 / t4 as f64;
    println!(
        "capacity at {} KiB, GQA kv_dim {GQA_KV_DIM}: f32 {g32} tokens ({gqa_f32:.2}x MHA), \
         f16 {g16} ({gqa_f16:.2}x), kv4 {g4} ({gqa_kv4:.2}x)",
        BUDGET_BYTES / 1024
    );
    for (name, mult) in [("f32", gqa_f32), ("f16", gqa_f16), ("kv4", gqa_kv4)] {
        if mult < 1.9 {
            failures.push(format!(
                "GQA {name} capacity {mult:.3}x MHA is below the 1.9x floor"
            ));
        }
    }
    json_rows.push(format!(
        "    {{\"label\": \"kv_capacity gqa\", \"budget_bytes\": {BUDGET_BYTES}, \
         \"d_model\": {D_MODEL}, \"kv_dim\": {GQA_KV_DIM}, \"n_layers\": {N_LAYERS}, \
         \"tokens_gqa_f32\": {g32}, \"tokens_gqa_f16\": {g16}, \"tokens_gqa_kv4\": {g4}, \
         \"speedup_capacity_gqa_f32\": {gqa_f32:.3}, \"speedup_capacity_gqa_f16\": {gqa_f16:.3}, \
         \"speedup_capacity_gqa_kv4\": {gqa_kv4:.3}}}"
    ));

    let json = format!(
        "{{\n  \"bench\": \"kv_cache\",\n  \"smoke\": {smoke},\n  \
         \"block_size\": {BLOCK_SIZE},\n  \"cases\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n"),
    );
    std::fs::write("BENCH_kv_cache.json", &json).expect("failed to write BENCH_kv_cache.json");
    println!("\nwrote BENCH_kv_cache.json ({} rows)", json_rows.len());

    if failures.is_empty() {
        println!(
            "\nshape check: OK (capacity floors f16 >= 1.9x, kv4 >= 3.5x, \
             GQA >= 1.9x MHA at every dtype; walks finite)"
        );
    } else {
        println!("\nshape check FAILED:");
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}

//! Quantized paged-KV bench: the attention block walk at every
//! [`KvDtype`], plus the capacity win that motivates compressing the
//! pool in the first place.
//!
//! Two measurement families:
//!
//! * **`kv_walk <dtype>`** — single-sequence decode on the real
//!   [`CpuBackend`] at a fixed context length: every step walks the
//!   whole paged K/V through the dtype's read path (f32 borrow, f16
//!   `vcvtph2ps` slice dequant, kv4 nibble dequant into the scratch
//!   tile).  Wall-clock, machine-dependent — reported, not gated.
//!
//! * **`kv_capacity`** — resident tokens a fixed byte budget holds per
//!   dtype, straight from [`KvDtype`] layout arithmetic.  Fully
//!   deterministic, so CI gates it tightly (`tools/bench_gate.rs
//!   --only kv_capacity`) against `BENCH_kv_cache.baseline.json`, and
//!   this bench itself enforces the acceptance floors in *both* modes:
//!   f16 must hold ≥ 1.9× the f32 tokens, kv4 ≥ 3.5×.
//!
//! Run: `cargo bench --bench kv_cache` — or with `-- --smoke` for the
//! CI-sized run (short context, fewer iters, floors still enforced
//! because they are layout facts, not timings).

use opt4gptq::benchkit::{bench, fmt_duration, Table};
use opt4gptq::engine::{Backend, CpuBackend, CpuModelConfig, DecodeDesc, KvDtype, PrefillDesc};

const BLOCK_SIZE: usize = 16;
const N_LAYERS: usize = 2;
const D_MODEL: usize = 128;
/// Capacity budget the `kv_capacity` row is computed against.
const BUDGET_BYTES: usize = 1 << 20;

fn backend() -> CpuBackend {
    CpuBackend::new(CpuModelConfig {
        max_seq: 512,
        d_model: D_MODEL,
        n_layers: N_LAYERS,
        n_heads: 4,
        d_ff: 256,
        ..Default::default()
    })
    .expect("backend config")
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "quantized paged-KV bench{}",
        if smoke { "  [smoke mode: reduced shapes]" } else { "" }
    );

    let ctx = if smoke { 48 } else { 192 };
    let iters = if smoke { 3 } else { 9 };
    let prompt: Vec<u32> = (0..ctx).map(|i| ((i * 37 + 11) % 256) as u32).collect();
    let table_blocks: Vec<usize> = (0..(ctx + 1).div_ceil(BLOCK_SIZE)).collect();

    let mut out = Table::new(
        "attention block walk by KV dtype (CpuBackend wall clock)",
        &["dtype", "ctx", "decode p50", "tok/s", "pool bytes", "B/token"],
    );
    let mut failures: Vec<String> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();

    for dtype in KvDtype::ALL {
        let mut be = backend();
        be.bind_kv(table_blocks.len(), BLOCK_SIZE, dtype);
        let (logits, _) = be
            .prefill(PrefillDesc {
                seq_id: 0,
                tokens: &prompt,
                start: 0,
                is_last: true,
                block_table: &table_blocks,
            })
            .expect("prefill");
        if !logits.iter().all(|v| v.is_finite()) {
            failures.push(format!("{dtype}: prefill produced non-finite logits"));
        }
        // Same position every iteration: each decode re-walks the full
        // context through the dtype's read path, which is the measured
        // cost; the rewritten row just requantizes in place.
        let desc = DecodeDesc { seq_id: 0, context_len: ctx, token: 7, block_table: &table_blocks };
        let stats = bench(&format!("kv_walk {dtype} ctx {ctx}"), 1, iters, || {
            std::hint::black_box(be.decode(&[desc]).expect("decode").0);
        });
        let tok_per_s = 1.0 / stats.p50;
        let pool_bytes = be.kv().bytes();
        let bytes_per_token = be.kv().bytes_per_token();
        out.row(vec![
            dtype.to_string(),
            format!("{ctx}"),
            fmt_duration(stats.p50),
            format!("{tok_per_s:.0}"),
            format!("{pool_bytes}"),
            format!("{bytes_per_token}"),
        ]);
        json_rows.push(format!(
            "    {{\"label\": \"kv_walk {dtype}\", \"dtype\": \"{dtype}\", \
             \"ctx\": {ctx}, \"walk_p50_ns_ungated\": {:.0}, \
             \"walk_tok_per_s\": {tok_per_s:.1}, \"pool_bytes\": {pool_bytes}, \
             \"bytes_per_token\": {bytes_per_token}}}",
            stats.p50 * 1e9,
        ));
    }
    out.print();

    // Capacity: tokens a fixed budget keeps resident, per dtype.  Pure
    // layout arithmetic — deterministic across machines, so the floors
    // hold in smoke mode too and CI can gate the row at 1%.
    let tokens_of = |d: KvDtype| BUDGET_BYTES / (2 * N_LAYERS * d.row_bytes(D_MODEL));
    let (t32, t16, t4) = (tokens_of(KvDtype::F32), tokens_of(KvDtype::F16), tokens_of(KvDtype::Kv4));
    let cap_f16 = t16 as f64 / t32 as f64;
    let cap_kv4 = t4 as f64 / t32 as f64;
    println!(
        "\ncapacity at {} KiB: f32 {t32} tokens, f16 {t16} ({cap_f16:.2}x), kv4 {t4} ({cap_kv4:.2}x)",
        BUDGET_BYTES / 1024
    );
    if cap_f16 < 1.9 {
        failures.push(format!("f16 capacity {cap_f16:.3}x is below the 1.9x floor"));
    }
    if cap_kv4 < 3.5 {
        failures.push(format!("kv4 capacity {cap_kv4:.3}x is below the 3.5x floor"));
    }
    json_rows.push(format!(
        "    {{\"label\": \"kv_capacity\", \"budget_bytes\": {BUDGET_BYTES}, \
         \"d_model\": {D_MODEL}, \"n_layers\": {N_LAYERS}, \
         \"tokens_f32\": {t32}, \"tokens_f16\": {t16}, \"tokens_kv4\": {t4}, \
         \"speedup_capacity_f16\": {cap_f16:.3}, \"speedup_capacity_kv4\": {cap_kv4:.3}}}"
    ));

    let json = format!(
        "{{\n  \"bench\": \"kv_cache\",\n  \"smoke\": {smoke},\n  \
         \"block_size\": {BLOCK_SIZE},\n  \"cases\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n"),
    );
    std::fs::write("BENCH_kv_cache.json", &json).expect("failed to write BENCH_kv_cache.json");
    println!("\nwrote BENCH_kv_cache.json ({} rows)", json_rows.len());

    if failures.is_empty() {
        println!("\nshape check: OK (capacity floors f16 >= 1.9x, kv4 >= 3.5x; walks finite)");
    } else {
        println!("\nshape check FAILED:");
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}

//! Regenerates **Table I** — inference accuracy on ARC_C across the six
//! models and five kernel configurations.
//!
//! Run: `cargo bench --bench table1_arc_c`

use opt4gptq::repro;
use opt4gptq::trace::arc::ArcSplit;

fn main() {
    let table = repro::accuracy_table(ArcSplit::Challenge);
    table.print();
    println!("\nshape check: accuracy variations must stay within 1pp of baseline");
    // The render embeds the max delta column; re-verify programmatically.
    for (model, _) in repro::PAPER_TABLE1_ARC_C {
        let results = opt4gptq::eval::accuracy::evaluate(model, ArcSplit::Challenge);
        let base = results[0].accuracy();
        for r in &results {
            assert!(
                (r.accuracy() - base).abs() < 0.01,
                "{model} {}: drift {:.3}",
                r.opt.label(),
                (r.accuracy() - base).abs()
            );
        }
    }
    println!("shape check: OK");
}

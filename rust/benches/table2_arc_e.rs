//! Regenerates **Table II** — inference accuracy on ARC_E across the six
//! models and five kernel configurations.
//!
//! Run: `cargo bench --bench table2_arc_e`

use opt4gptq::repro;
use opt4gptq::trace::arc::ArcSplit;

fn main() {
    let table = repro::accuracy_table(ArcSplit::Easy);
    table.print();
    for (model, _) in repro::PAPER_TABLE2_ARC_E {
        let results = opt4gptq::eval::accuracy::evaluate(model, ArcSplit::Easy);
        let base = results[0].accuracy();
        for r in &results {
            assert!(
                (r.accuracy() - base).abs() < 0.01,
                "{model} {}: drift {:.3}",
                r.opt.label(),
                (r.accuracy() - base).abs()
            );
        }
    }
    println!("\nshape check: OK (all variants within 1pp of baseline)");
}

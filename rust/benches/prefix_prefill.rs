//! Prefix-aware chunked prefill vs forced recompute on the real
//! [`CpuBackend`]: the paper's "never pay for work the platform can
//! remember" discipline applied to the serving layer.
//!
//! Two measurements per (prefix length, chunk budget) point:
//!
//! * **recompute** — prefill the whole prompt through a table that
//!   shares the prefix blocks (what `OPT4GPTQ_PREFIX_SKIP=0` does:
//!   shared memory, duplicated compute);
//! * **skip** — prefill only the tail (`start = prefix_len`), reading
//!   the cached prefix K/V through the shared blocks.
//!
//! Acceptance floor (full mode only): with a shared prefix spanning
//! ≥ 2 blocks, the skip path must be **strictly faster** than forced
//! recompute (best-of-N), and both paths must produce bit-identical
//! logits.  Chunked prefill is additionally swept across budgets —
//! including one below the block size — and must stay bit-identical to
//! the one-shot pass.
//!
//! Every measurement lands in `BENCH_prefix_prefill.json` (prefix
//! length, chunk budget, tokens/s, skipped fraction), each row under a
//! stable `label` key — CI's `tools/bench_gate.rs` step compares the
//! smoke run's skip-vs-recompute row against the committed
//! `BENCH_prefix_prefill.baseline.json` and fails on a > 15%
//! regression.  Run: `cargo bench --bench prefix_prefill` — or with
//! `-- --smoke` for the CI-sized run (tiny shapes, no perf floors, JSON
//! still emitted).

use opt4gptq::benchkit::{bench, fmt_duration, Table};
use opt4gptq::engine::{Backend, CpuBackend, CpuModelConfig, PrefillDesc};

const BLOCK_SIZE: usize = 16;

fn backend(max_seq: usize) -> CpuBackend {
    let mut be = CpuBackend::new(CpuModelConfig {
        max_seq,
        // A bit wider than the default test model so each prefill does
        // measurable work while the bench stays CI-friendly.
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        d_ff: 256,
        ..Default::default()
    })
    .expect("backend config");
    be.bind_kv(64, BLOCK_SIZE, opt4gptq::engine::kv_dtype_default());
    be
}

fn prompt(len: usize) -> Vec<u32> {
    (0..len).map(|i| ((i * 37 + 11) % 256) as u32).collect()
}

fn table_for(len: usize, first_block: usize) -> Vec<usize> {
    (0..len.div_ceil(BLOCK_SIZE)).map(|b| first_block + b).collect()
}

/// One-shot prefill of `tokens[start..]` through `table`; returns the
/// final-token logits.
fn prefill_span(be: &mut CpuBackend, tokens: &[u32], start: usize, table: &[usize]) -> Vec<f32> {
    let (logits, _) = be
        .prefill(PrefillDesc {
            seq_id: 0,
            tokens: &tokens[start..],
            start,
            is_last: true,
            block_table: table,
        })
        .expect("prefill");
    logits
}

/// Chunked prefill under `budget` tokens per step; returns the final
/// chunk's logits.
fn prefill_chunked(
    be: &mut CpuBackend,
    tokens: &[u32],
    start: usize,
    budget: usize,
    table: &[usize],
) -> Vec<f32> {
    let mut pos = start;
    let mut last = Vec::new();
    while pos < tokens.len() {
        let end = (pos + budget).min(tokens.len());
        let out = be
            .step(
                &[PrefillDesc {
                    seq_id: 0,
                    tokens: &tokens[pos..end],
                    start: pos,
                    is_last: end == tokens.len(),
                    block_table: table,
                }],
                &[],
            )
            .expect("chunked prefill");
        if end == tokens.len() {
            last = out.prefill_logits[0].clone().expect("final chunk logits");
        }
        pos = end;
    }
    last
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "prefix-aware chunked prefill bench{}",
        if smoke { "  [smoke mode: reduced shapes, no perf floors]" } else { "" }
    );

    // (prompt_len, prefix_len) grid; prefixes are whole blocks.
    let cases: &[(usize, usize)] = if smoke {
        &[(48, 32)]
    } else {
        &[(96, 32), (96, 64), (160, 128)]
    };
    let budgets: &[usize] = if smoke { &[8, 48] } else { &[8, 16, 48, 4096] };
    let iters = if smoke { 3 } else { 9 };

    let mut table = Table::new(
        "cached-prefix prefill vs forced recompute (CpuBackend wall clock)",
        &["prompt", "prefix", "recompute p50", "skip p50", "speedup", "skipped"],
    );
    let mut failures: Vec<String> = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();

    for &(prompt_len, prefix_len) in cases {
        assert_eq!(prefix_len % BLOCK_SIZE, 0, "prefixes must be whole blocks");
        let toks = prompt(prompt_len);
        let mut be = backend(prompt_len.max(64));

        // Warm sequence: fills the shared prefix blocks (and the rest of
        // its own table) exactly as a first request would.
        let warm_table = table_for(prompt_len, 0);
        let warm = prefill_span(&mut be, &toks, 0, &warm_table);

        // A second sequence sharing the prefix blocks, private tail.
        let shared_blocks = prefix_len / BLOCK_SIZE;
        let mut shared_table: Vec<usize> = warm_table[..shared_blocks].to_vec();
        shared_table.extend(table_for(prompt_len - prefix_len, 32));

        // Parity first: a fast wrong prefill is not a speedup.
        let recompute_logits = prefill_span(&mut be, &toks, 0, &shared_table);
        let skip_logits = prefill_span(&mut be, &toks, prefix_len, &shared_table);
        assert_eq!(recompute_logits, warm, "recompute through shared blocks diverged");
        assert_eq!(skip_logits, warm, "prefix-skip logits diverged from full prefill");

        let recompute = bench(
            &format!("recompute {prompt_len}t (prefix {prefix_len})"),
            1,
            iters,
            || {
                std::hint::black_box(prefill_span(&mut be, &toks, 0, &shared_table));
            },
        );
        let skip = bench(
            &format!("skip      {prompt_len}t (prefix {prefix_len})"),
            1,
            iters,
            || {
                std::hint::black_box(prefill_span(&mut be, &toks, prefix_len, &shared_table));
            },
        );
        let speedup = recompute.min / skip.min;
        let skipped_fraction = prefix_len as f64 / prompt_len as f64;
        // Strict floor: a cached prefix of >= 2 blocks must make prefill
        // faster, not just not-slower (best-of-N absorbs noise).
        if !smoke && prefix_len >= 2 * BLOCK_SIZE && speedup <= 1.0 {
            failures.push(format!(
                "prefix {prefix_len}/{prompt_len}: skip is not faster ({speedup:.3}x best-of)"
            ));
        }
        table.row(vec![
            format!("{prompt_len}"),
            format!("{prefix_len} ({shared_blocks} blocks)"),
            fmt_duration(recompute.p50),
            fmt_duration(skip.p50),
            format!("{speedup:.2}x"),
            format!("{:.0}%", skipped_fraction * 100.0),
        ]);
        json_rows.push(format!(
            "    {{\"label\": \"skip_vs_recompute {prompt_len}t prefix{prefix_len}\", \
             \"prompt_len\": {prompt_len}, \"prefix_len\": {prefix_len}, \
             \"chunk_budget\": null, \"mode\": \"skip_vs_recompute\", \
             \"recompute_ns\": {:.0}, \"skip_ns\": {:.0}, \
             \"recompute_tok_per_s\": {:.1}, \"skip_tok_per_s\": {:.1}, \
             \"speedup_best_of\": {speedup:.3}, \"skipped_fraction\": {skipped_fraction:.3}}}",
            recompute.p50 * 1e9,
            skip.p50 * 1e9,
            prompt_len as f64 / recompute.p50,
            (prompt_len - prefix_len) as f64 / skip.p50,
        ));

        // Chunk-budget sweep on the same prompt (no prefix skip: isolate
        // the chunking cost/parity from the skip win).
        for &budget in budgets {
            let chunked_logits = prefill_chunked(&mut be, &toks, 0, budget, &shared_table);
            assert_eq!(
                chunked_logits, warm,
                "budget {budget}: chunked prefill diverged from one-shot"
            );
            let chunked = bench(
                &format!("chunked   {prompt_len}t budget {budget}"),
                1,
                iters,
                || {
                    std::hint::black_box(prefill_chunked(&mut be, &toks, 0, budget, &shared_table));
                },
            );
            json_rows.push(format!(
                "    {{\"label\": \"chunked {prompt_len}t prefix{prefix_len} budget{budget}\", \
                 \"prompt_len\": {prompt_len}, \"prefix_len\": {prefix_len}, \
                 \"chunk_budget\": {budget}, \"mode\": \"chunked\", \
                 \"chunked_ns\": {:.0}, \"chunked_tok_per_s\": {:.1}, \
                 \"skipped_fraction\": 0.0}}",
                chunked.p50 * 1e9,
                prompt_len as f64 / chunked.p50,
            ));
        }
    }

    table.print();

    let json = format!(
        "{{\n  \"bench\": \"prefix_prefill\",\n  \"smoke\": {smoke},\n  \
         \"block_size\": {BLOCK_SIZE},\n  \"cases\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n"),
    );
    std::fs::write("BENCH_prefix_prefill.json", &json)
        .expect("failed to write BENCH_prefix_prefill.json");
    println!("\nwrote BENCH_prefix_prefill.json ({} rows)", json_rows.len());

    if failures.is_empty() {
        if smoke {
            println!("\nshape check: smoke mode (perf floors skipped; parity asserts passed)");
        } else {
            println!(
                "\nshape check: OK (prefix-skip strictly faster at >= 2 shared blocks; \
                 chunked bit-identical)"
            );
        }
    } else {
        println!("\nshape check FAILED:");
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}

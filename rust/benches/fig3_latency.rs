//! Regenerates **Figure 3** — inference latency of vLLM across the six
//! GPTQ models before/after each optimization.
//!
//! Run: `cargo bench --bench fig3_latency`

use opt4gptq::repro;

fn main() -> opt4gptq::Result<()> {
    let grid = repro::serving_grid(32, 2025)?;
    repro::fig3_table(&grid).print();

    // Shape assertions specific to the latency figure.
    let mut failures = Vec::new();
    for row in &grid {
        for ci in 1..5 {
            if row.latency_reduction_pct(ci) <= 0.0 {
                failures.push(format!(
                    "{}: config {ci} did not reduce latency",
                    row.model.name
                ));
            }
        }
        if row.latency_reduction_pct(4) < row.latency_reduction_pct(1) {
            failures.push(format!("{}: combined < SMB alone", row.model.name));
        }
    }
    if failures.is_empty() {
        println!("\nshape check: OK (all configs reduce latency; combined strongest)");
    } else {
        for f in &failures {
            println!("shape check FAILED: {f}");
        }
        std::process::exit(1);
    }
    Ok(())
}

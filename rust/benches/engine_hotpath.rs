//! L3 hot-path microbenchmarks (wall clock): scheduler step, block
//! manager churn, sampler, f16 GEMV, DCU simulation itself.  These are
//! the targets of the §Perf optimization pass (EXPERIMENTS.md).
//!
//! Run: `cargo bench --bench engine_hotpath`

use opt4gptq::benchkit::bench;
use opt4gptq::engine::block_manager::BlockManager;
use opt4gptq::engine::{Engine, EngineConfig, Request, SamplingParams, SimBackend};
use opt4gptq::eval::numerics::gemv_f16_variant;
use opt4gptq::gptq::{quantize_rtn, Matrix};
use opt4gptq::models::by_name;
use opt4gptq::rng::Rng;
use opt4gptq::OptConfig;

fn main() {
    // --- full serving run (the Figure-2 inner loop) --------------------
    let model = by_name("Llama-2-7B-GPTQ").unwrap();
    bench("engine: 32-request serving run (sim backend)", 2, 10, || {
        let be = SimBackend::new(model, OptConfig::OPT4GPTQ, 32);
        let mut e = Engine::new(
            EngineConfig { max_batch: 32, total_blocks: 8192, ..Default::default() },
            be,
        );
        let trace = opt4gptq::trace::RequestTrace::generate(32, 1);
        for r in &trace.requests {
            e.add_request(Request::new(
                r.id,
                r.prompt.clone(),
                SamplingParams { max_tokens: r.response_len.min(64), ..Default::default() },
            ));
        }
        let _ = e.run().unwrap();
    });

    // --- block manager churn -------------------------------------------
    bench("block_manager: 1k alloc/append/free cycles", 2, 20, || {
        let mut bm = BlockManager::new(4096, 16);
        let mut rng = Rng::new(7);
        for i in 0..1000usize {
            let plen = rng.range_usize(1, 120);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.next_u32() % 1000).collect();
            assert!(bm.allocate(i, &prompt).is_some());
            for t in 0..rng.range_usize(0, 40) {
                if !bm.append_token(i, plen + t + 1) {
                    break;
                }
            }
            if i >= 16 {
                bm.free_sequence(i - 16);
            }
        }
    });

    // --- sampler ---------------------------------------------------------
    let logits: Vec<f32> = {
        let mut rng = Rng::new(3);
        (0..32000).map(|_| rng.normal() as f32).collect()
    };
    let params = SamplingParams { temperature: 0.8, top_k: 50, ..Default::default() };
    let mut rng = Rng::new(4);
    bench("sampler: top-k=50 over 32k logits", 5, 50, || {
        std::hint::black_box(opt4gptq::engine::sampler::sample(&logits, &params, &mut rng));
    });

    // --- f16 GEMV (accuracy-harness inner loop) -------------------------
    let mut wrng = Rng::new(5);
    let w = Matrix::from_vec(64, 8, wrng.normal_vec_f32(64 * 8, 0.4));
    let q = quantize_rtn(&w, 64);
    let x = wrng.normal_vec_f32(64, 1.0);
    bench("eval: f16 variant GEMV 64x8", 10, 100, || {
        std::hint::black_box(gemv_f16_variant(&x, &q, OptConfig::OPT4GPTQ, 1));
    });

    // --- DCU simulation -------------------------------------------------
    let device = opt4gptq::dcusim::Device::z100();
    let p = opt4gptq::dcusim::kernels::KernelParams { m: 32, k: 5120, n: 5120, group_size: 128 };
    bench("dcusim: simulate one 13B GEMM launch", 10, 200, || {
        std::hint::black_box(device.simulate(&opt4gptq::dcusim::GemvKernel::new(p, OptConfig::BASELINE)));
    });

    // --- accuracy harness (one model/split) ------------------------------
    bench("eval: full ARC_C evaluation of one model", 1, 3, || {
        std::hint::black_box(opt4gptq::eval::accuracy::evaluate(
            "Qwen1.5-1.8B-Chat-GPTQ-Int4",
            opt4gptq::trace::arc::ArcSplit::Challenge,
        ));
    });
}

//! PJRT client wrapper: weight literals + compiled executables.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::Result;

use super::manifest::{self, Manifest};

/// Owns the PJRT CPU client, the tiny model's weight literals (loaded
/// once from `weights.bin`) and the compiled executables (compiled
/// lazily, cached per artifact tag).
pub struct Runtime {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub client: xla::PjRtClient,
    weights: HashMap<String, xla::Literal>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load manifest + weights from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt (run `make artifacts`)", dir.display()))?;
        let manifest = manifest::parse(&text)?;
        let blob = std::fs::read(dir.join(&manifest.weights_file))
            .with_context(|| format!("reading {}", manifest.weights_file))?;

        let mut weights = HashMap::new();
        for t in &manifest.tensors {
            let raw = blob
                .get(t.offset..t.offset + t.nbytes)
                .with_context(|| format!("tensor {} out of bounds in weights.bin", t.name))?;
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                t.dtype.element_type(),
                &t.shape,
                raw,
            )?;
            weights.insert(t.name.clone(), lit);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { dir, manifest, client, weights, exes: HashMap::new() })
    }

    /// Compile (or fetch the cached) executable for an artifact tag.
    pub fn executable(&mut self, tag: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(tag) {
            let art = self.manifest.artifact(tag)?.clone();
            let path = self.dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.exes.insert(tag.to_string(), exe);
        }
        Ok(&self.exes[tag])
    }

    /// Execute an artifact: `inputs` supplies the non-weight arguments by
    /// manifest name; weights come from the cache.  Returns the flattened
    /// tuple outputs.
    pub fn execute(
        &mut self,
        tag: &str,
        inputs: &HashMap<String, xla::Literal>,
    ) -> Result<Vec<xla::Literal>> {
        self.executable(tag)?; // ensure compiled before borrowing weights
        let art = self.manifest.artifact(tag)?.clone();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(art.args.len());
        for a in &art.args {
            let lit = if a.is_weight {
                self.weights
                    .get(&a.name)
                    .with_context(|| format!("weight {} not loaded", a.name))?
            } else {
                inputs
                    .get(&a.name)
                    .with_context(|| format!("input {} not supplied for {tag}", a.name))?
            };
            args.push(lit);
        }
        let exe = &self.exes[tag];
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: single tuple output.
        Ok(result.to_tuple()?)
    }

    /// Access a loaded weight literal by name.
    pub fn weight_literal(&self, name: &str) -> Result<&xla::Literal> {
        self.weights
            .get(name)
            .with_context(|| format!("weight {name} not loaded"))
    }

    /// Convenience: build an f32 literal.
    pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )?)
    }

    /// Convenience: build an i32 literal.
    pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            dims,
            bytes,
        )?)
    }
}

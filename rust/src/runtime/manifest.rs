//! Parser for `artifacts/manifest.txt` (written by `python/compile/aot.py`).
//!
//! Line-based format, one record per line:
//! ```text
//! model <name> vocab=256 d_model=512 ... prefill_slots=64
//! weights weights.bin
//! tensor <name> dtype=f32 shape=8x512 offset=0 nbytes=16384
//! artifact <tag> file=<file> [batch=N] [slots=N] ...
//! arg <i> kind=weight|input name=<n> dtype=<d> shape=<s>
//! out <i> name=<n> dtype=<d> shape=<s>
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context};

use crate::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "u32" => Dtype::U32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    #[cfg(feature = "pjrt")]
    pub fn element_type(&self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::U32 => xla::ElementType::U32,
            Dtype::I32 => xla::ElementType::S32,
        }
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

/// A tensor stored in `weights.bin`.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// One argument of an artifact's entry computation.
#[derive(Debug, Clone)]
pub struct ArgMeta {
    pub index: usize,
    pub is_weight: bool,
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

/// One output of an artifact (flattened tuple order).
#[derive(Debug, Clone)]
pub struct OutMeta {
    pub index: usize,
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

/// One lowered HLO entry point.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub tag: String,
    pub file: String,
    pub attrs: HashMap<String, String>,
    pub args: Vec<ArgMeta>,
    pub outs: Vec<OutMeta>,
}

impl ArtifactMeta {
    pub fn attr_usize(&self, key: &str) -> Option<usize> {
        self.attrs.get(key).and_then(|v| v.parse().ok())
    }
}

/// The full parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model_name: String,
    pub model_attrs: HashMap<String, usize>,
    pub weights_file: String,
    pub tensors: Vec<TensorMeta>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn model_dim(&self, key: &str) -> Result<usize> {
        self.model_attrs
            .get(key)
            .copied()
            .with_context(|| format!("manifest model line missing {key}"))
    }

    pub fn artifact(&self, tag: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.tag == tag)
            .with_context(|| format!("artifact {tag:?} not in manifest"))
    }

    pub fn tensor(&self, name: &str) -> Result<&TensorMeta> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("tensor {name:?} not in manifest"))
    }

    /// Decode artifact tags present, sorted by batch size.
    pub fn decode_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.tag.starts_with("decode_b"))
            .filter_map(|a| a.attr_usize("batch"))
            .collect();
        v.sort_unstable();
        v
    }
}

fn kv_pairs(parts: &[&str]) -> HashMap<String, String> {
    parts
        .iter()
        .filter_map(|p| p.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

fn parse_shape(s: &str) -> Vec<usize> {
    if s == "scalar" {
        return vec![];
    }
    s.split('x').map(|d| d.parse().expect("bad shape dim")).collect()
}

/// Parse manifest text.
pub fn parse(text: &str) -> Result<Manifest> {
    let mut model_name = String::new();
    let mut model_attrs = HashMap::new();
    let mut weights_file = String::new();
    let mut tensors = Vec::new();
    let mut artifacts: Vec<ArtifactMeta> = Vec::new();

    for (lineno, line) in text.lines().enumerate() {
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.is_empty() {
            continue;
        }
        let ctx = || format!("manifest line {}: {line:?}", lineno + 1);
        match parts[0] {
            "model" => {
                model_name = parts.get(1).with_context(ctx)?.to_string();
                for (k, v) in kv_pairs(&parts[2..]) {
                    if let Ok(n) = v.parse::<usize>() {
                        model_attrs.insert(k, n);
                    }
                }
            }
            "weights" => weights_file = parts.get(1).with_context(ctx)?.to_string(),
            "tensor" => {
                let kv = kv_pairs(&parts[2..]);
                tensors.push(TensorMeta {
                    name: parts.get(1).with_context(ctx)?.to_string(),
                    dtype: Dtype::parse(kv.get("dtype").with_context(ctx)?)?,
                    shape: parse_shape(kv.get("shape").with_context(ctx)?),
                    offset: kv.get("offset").with_context(ctx)?.parse()?,
                    nbytes: kv.get("nbytes").with_context(ctx)?.parse()?,
                });
            }
            "artifact" => {
                let kv = kv_pairs(&parts[2..]);
                artifacts.push(ArtifactMeta {
                    tag: parts.get(1).with_context(ctx)?.to_string(),
                    file: kv.get("file").cloned().unwrap_or_default(),
                    attrs: kv,
                    args: Vec::new(),
                    outs: Vec::new(),
                });
            }
            "arg" => {
                let kv = kv_pairs(&parts[2..]);
                let art = artifacts.last_mut().with_context(|| "arg before artifact")?;
                art.args.push(ArgMeta {
                    index: parts.get(1).with_context(ctx)?.parse()?,
                    is_weight: kv.get("kind").map(|k| k == "weight").unwrap_or(false),
                    name: kv.get("name").with_context(ctx)?.clone(),
                    dtype: Dtype::parse(kv.get("dtype").with_context(ctx)?)?,
                    shape: parse_shape(kv.get("shape").with_context(ctx)?),
                });
            }
            "out" => {
                let kv = kv_pairs(&parts[2..]);
                let art = artifacts.last_mut().with_context(|| "out before artifact")?;
                art.outs.push(OutMeta {
                    index: parts.get(1).with_context(ctx)?.parse()?,
                    name: kv.get("name").with_context(ctx)?.clone(),
                    dtype: Dtype::parse(kv.get("dtype").with_context(ctx)?)?,
                    shape: parse_shape(kv.get("shape").with_context(ctx)?),
                });
            }
            // informational records (smoke-test blobs etc.)
            _ => {}
        }
    }
    if model_name.is_empty() {
        bail!("manifest has no model line");
    }
    Ok(Manifest { model_name, model_attrs, weights_file, tensors, artifacts })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model tiny vocab=256 d_model=64 n_layers=2 n_heads=2 d_head=32 d_ff=128 group_size=64 max_seq=32 prefill_slots=16
weights weights.bin
tensor params.embed dtype=f32 shape=256x64 offset=0 nbytes=65536
tensor params.layers.wq.qweight dtype=u32 shape=2x8x64 offset=65536 nbytes=4096
artifact decode_b1 file=d1.hlo.txt batch=1
arg 0 kind=weight name=params.embed dtype=f32 shape=256x64
arg 1 kind=input name=kv.k dtype=f32 shape=2x1x2x32x32
out 0 name=out.0 dtype=f32 shape=1x256
artifact decode_b4 file=d4.hlo.txt batch=4
arg 0 kind=weight name=params.embed dtype=f32 shape=256x64
out 0 name=out.0 dtype=f32 shape=4x256
";

    #[test]
    fn parses_model_line() {
        let m = parse(SAMPLE).unwrap();
        assert_eq!(m.model_name, "tiny");
        assert_eq!(m.model_dim("vocab").unwrap(), 256);
        assert_eq!(m.model_dim("prefill_slots").unwrap(), 16);
        assert!(m.model_dim("nonexistent").is_err());
    }

    #[test]
    fn parses_tensors() {
        let m = parse(SAMPLE).unwrap();
        let t = m.tensor("params.layers.wq.qweight").unwrap();
        assert_eq!(t.dtype, Dtype::U32);
        assert_eq!(t.shape, vec![2, 8, 64]);
        assert_eq!(t.offset, 65536);
    }

    #[test]
    fn parses_artifacts_with_args_and_outs() {
        let m = parse(SAMPLE).unwrap();
        let a = m.artifact("decode_b1").unwrap();
        assert_eq!(a.file, "d1.hlo.txt");
        assert_eq!(a.attr_usize("batch"), Some(1));
        assert_eq!(a.args.len(), 2);
        assert!(a.args[0].is_weight);
        assert!(!a.args[1].is_weight);
        assert_eq!(a.outs[0].shape, vec![1, 256]);
    }

    #[test]
    fn decode_batches_sorted() {
        let m = parse(SAMPLE).unwrap();
        assert_eq!(m.decode_batches(), vec![1, 4]);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Integration hook: when `make artifacts` has run, check the real
        // manifest round-trips.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.txt");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = parse(&text).unwrap();
            assert!(!m.tensors.is_empty());
            assert!(m.artifact("prefill_b1_s64").is_ok());
            assert!(!m.decode_batches().is_empty());
        }
    }

    #[test]
    fn rejects_empty() {
        assert!(parse("").is_err());
    }
}

//! [`crate::engine::Backend`] implementation over the PJRT runtime:
//! real token generation with the AOT-compiled tiny GPTQ Llama.
//!
//! KV layout: the HLO decode artifacts operate on a dense batched cache
//! `f32[L, B, H, S, D]`, so this backend cannot execute through block
//! tables directly; instead it maps each sequence id from the paged
//! [`PrefillDesc`]/[`DecodeDesc`] contract onto a private dense lane
//! (`lanes`), releasing the lane when the engine retires the sequence
//! via [`Backend::release_seq`].  The paging machinery is still
//! exercised and tested at the scheduler/CpuBackend level; here the
//! tables are accepted and ignored.
//!
//! Perf (EXPERIMENTS.md §Perf): the decode hot path keeps the batched KV
//! cache as PJRT **literals handed from step output to step input** —
//! zero host-side KV copies while decoding.  Only a prefill (one per
//! request) re-materializes the host mirror to splice the new sequence's
//! cache into its lane.  `execute_b`/device-resident buffers are not
//! usable here: xla_extension 0.5.1's `execute_b` aborts on tuple-rooted
//! executables (`shape_util.cc pointer_size > 0` check), documented as a
//! platform limitation.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context};

use crate::engine::backend::{Backend, DecodeDesc, PrefillDesc, StepError, StepOutput};
use crate::Result;

use super::client::Runtime;

/// Dimensions of the tiny model, read from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct TinyDims {
    pub vocab: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub prefill_slots: usize,
}

/// PJRT-backed engine backend.
pub struct PjrtBackend {
    pub runtime: Runtime,
    pub dims: TinyDims,
    max_batch: usize,
    /// sequence id -> dense KV lane (the paged contract adapter).
    lanes: HashMap<usize, usize>,
    free_lanes: Vec<usize>,
    /// Batched KV cache literals `[L, B, H, S, D]` (k, v), handed from
    /// decode output to decode input without touching the host.
    kv_lit: Option<(xla::Literal, xla::Literal)>,
    /// Host mirrors, used only when splicing a prefilled sequence in.
    mirror_k: Vec<f32>,
    mirror_v: Vec<f32>,
    /// True when `kv_lit` is newer than the mirrors.
    mirror_stale: bool,
    /// Wall seconds spent inside PJRT execute calls (perf accounting).
    pub execute_seconds: f64,
    pub execute_calls: usize,
}

impl PjrtBackend {
    pub fn load(artifacts_dir: &str) -> Result<PjrtBackend> {
        let runtime = Runtime::load(artifacts_dir)?;
        let m = &runtime.manifest;
        let dims = TinyDims {
            vocab: m.model_dim("vocab")?,
            n_layers: m.model_dim("n_layers")?,
            n_heads: m.model_dim("n_heads")?,
            d_head: m.model_dim("d_head")?,
            max_seq: m.model_dim("max_seq")?,
            prefill_slots: m.model_dim("prefill_slots")?,
        };
        let decode_batches = m.decode_batches();
        if decode_batches.is_empty() {
            bail!("no decode artifacts in manifest");
        }
        let max_batch = *decode_batches.last().unwrap();
        let total = dims.n_layers * max_batch * dims.n_heads * dims.max_seq * dims.d_head;
        Ok(PjrtBackend {
            runtime,
            dims,
            max_batch,
            lanes: HashMap::new(),
            free_lanes: (0..max_batch).rev().collect(),
            kv_lit: None,
            mirror_k: vec![0.0; total],
            mirror_v: vec![0.0; total],
            mirror_stale: false,
            execute_seconds: 0.0,
            execute_calls: 0,
        })
    }

    /// Pre-compile all artifacts (avoids first-request latency spikes).
    pub fn warmup(&mut self) -> Result<()> {
        let tags: Vec<String> = self
            .runtime
            .manifest
            .artifacts
            .iter()
            .map(|a| a.tag.clone())
            .filter(|t| t == &format!("decode_b{}", self.max_batch) || t.starts_with("prefill_"))
            .collect();
        for tag in tags {
            self.runtime.executable(&tag)?;
        }
        Ok(())
    }

    fn layer_stride(&self) -> usize {
        self.dims.n_heads * self.dims.max_seq * self.dims.d_head
    }

    fn kv_dims(&self) -> [usize; 5] {
        [self.dims.n_layers, self.max_batch, self.dims.n_heads, self.dims.max_seq, self.dims.d_head]
    }

    /// Refresh host mirrors from the literals if they are stale.
    fn refresh_mirrors(&mut self) -> Result<()> {
        if self.mirror_stale {
            let (k, v) = self.kv_lit.as_ref().expect("stale without literals");
            self.mirror_k = k.to_vec::<f32>()?;
            self.mirror_v = v.to_vec::<f32>()?;
            self.mirror_stale = false;
        }
        Ok(())
    }

    /// Splice a single-sequence cache `[L, 1, H, S, D]` into lane `slot`
    /// of the host mirrors, then rebuild the batch literals.
    fn splice_slot(&mut self, slot: usize, kk: &[f32], vv: &[f32]) -> Result<()> {
        let ls = self.layer_stride();
        let b = self.max_batch;
        assert!(slot < b);
        assert_eq!(kk.len(), self.dims.n_layers * ls);
        for l in 0..self.dims.n_layers {
            let dst = (l * b + slot) * ls;
            self.mirror_k[dst..dst + ls].copy_from_slice(&kk[l * ls..(l + 1) * ls]);
            self.mirror_v[dst..dst + ls].copy_from_slice(&vv[l * ls..(l + 1) * ls]);
        }
        let dims = self.kv_dims();
        self.kv_lit = Some((
            Runtime::f32_literal(&self.mirror_k, &dims)?,
            Runtime::f32_literal(&self.mirror_v, &dims)?,
        ));
        Ok(())
    }

    /// Lane already owned by `seq_id`, or a freshly assigned one.
    fn lane_for(&mut self, seq_id: usize) -> Result<usize> {
        if let Some(&lane) = self.lanes.get(&seq_id) {
            return Ok(lane);
        }
        match self.free_lanes.pop() {
            Some(lane) => {
                self.lanes.insert(seq_id, lane);
                Ok(lane)
            }
            None => bail!("no free KV lane for sequence {seq_id} (max_batch {})", self.max_batch),
        }
    }

    fn timed_execute(
        &mut self,
        tag: &str,
        inputs: &HashMap<String, xla::Literal>,
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let outs = self.runtime.execute(tag, inputs)?;
        self.execute_seconds += t0.elapsed().as_secs_f64();
        self.execute_calls += 1;
        Ok(outs)
    }
}

impl Backend for PjrtBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn max_seq_len(&self) -> usize {
        self.dims.max_seq
    }

    fn vocab(&self) -> usize {
        self.dims.vocab
    }

    fn step(
        &mut self,
        prefills: &[PrefillDesc<'_>],
        decodes: &[DecodeDesc<'_>],
    ) -> Result<StepOutput, StepError> {
        let t0 = Instant::now();
        let mut prefill_logits = Vec::with_capacity(prefills.len());
        for p in prefills {
            // The HLO prefill artifacts run a whole prompt into a fresh
            // dense lane: chunk resumption and cached-prefix skipping
            // have no lane-level representation here.  Serve this
            // backend with a prefill budget ≥ the longest prompt and
            // `prefix_skip` off (see `cmd_serve_pjrt`).  A chunked or
            // resumed span is a configuration error, not a glitch —
            // permanent, so the engine fails the batch instead of
            // retrying the same impossible call.
            if p.start != 0 || !p.is_last {
                return Err(StepError::Permanent(format!(
                    "PjrtBackend cannot resume a prefill chunk at position {} \
                     (dense-lane HLO artifacts need whole prompts; disable \
                     prefix skip and raise --prefill-budget)",
                    p.start
                )));
            }
            prefill_logits.push(Some(
                self.prefill_whole(p).map_err(|e| StepError::Permanent(e.to_string()))?,
            ));
        }
        let decode_logits = if decodes.is_empty() {
            Vec::new()
        } else {
            self.decode_batch(decodes).map_err(|e| StepError::Permanent(e.to_string()))?
        };
        Ok(StepOutput { prefill_logits, decode_logits, secs: t0.elapsed().as_secs_f64() })
    }

    fn release_seq(&mut self, seq_id: usize) {
        if let Some(lane) = self.lanes.remove(&seq_id) {
            self.free_lanes.push(lane);
        }
    }
}

impl PjrtBackend {
    /// Run one whole prompt into the sequence's dense lane.
    fn prefill_whole(&mut self, req: &PrefillDesc<'_>) -> Result<Vec<f32>> {
        let d = self.dims;
        let tokens = req.tokens;
        if tokens.is_empty() || tokens.len() > d.prefill_slots {
            bail!("prefill length {} outside 1..={}", tokens.len(), d.prefill_slots);
        }
        let slot = self.lane_for(req.seq_id)?;
        let mut padded = vec![0i32; d.prefill_slots];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let slot_len = self.layer_stride() * d.n_layers;
        let kv1_dims = [d.n_layers, 1, d.n_heads, d.max_seq, d.d_head];
        let mut inputs = HashMap::new();
        inputs.insert("kv.k".into(), Runtime::f32_literal(&vec![0.0; slot_len], &kv1_dims)?);
        inputs.insert("kv.v".into(), Runtime::f32_literal(&vec![0.0; slot_len], &kv1_dims)?);
        inputs.insert("lengths".into(), Runtime::i32_literal(&[tokens.len() as i32], &[1])?);
        inputs.insert("tokens".into(), Runtime::i32_literal(&padded, &[1, d.prefill_slots])?);

        let outs = self.timed_execute("prefill_b1_s64", &inputs)?;
        let (logits, kk, vv) = unpack3(outs)?;
        let logits_row = logits.to_vec::<f32>()?;
        self.refresh_mirrors()?;
        let kk = kk.to_vec::<f32>()?;
        let vv = vv.to_vec::<f32>()?;
        self.splice_slot(slot, &kk, &vv)?;
        Ok(logits_row)
    }

    fn decode_batch(&mut self, batch: &[DecodeDesc<'_>]) -> Result<Vec<Vec<f32>>> {
        let d = self.dims;
        let b = self.max_batch;
        assert!(!batch.is_empty() && batch.len() <= b);
        // Idle lanes run masked at position 0.
        let mut lanes = Vec::with_capacity(batch.len());
        let mut lengths = vec![0i32; b];
        let mut tokens = vec![0i32; b];
        for e in batch {
            let lane = self.lane_for(e.seq_id)?;
            lengths[lane] = e.context_len as i32;
            tokens[lane] = e.token as i32;
            lanes.push(lane);
        }
        if self.kv_lit.is_none() {
            let dims = self.kv_dims();
            self.kv_lit = Some((
                Runtime::f32_literal(&self.mirror_k, &dims)?,
                Runtime::f32_literal(&self.mirror_v, &dims)?,
            ));
        }
        let (kv_k, kv_v) = self.kv_lit.take().unwrap();
        let mut inputs = HashMap::new();
        inputs.insert("kv.k".into(), kv_k);
        inputs.insert("kv.v".into(), kv_v);
        inputs.insert("lengths".into(), Runtime::i32_literal(&lengths, &[b])?);
        inputs.insert("tokens".into(), Runtime::i32_literal(&tokens, &[b])?);

        let tag = format!("decode_b{b}");
        let outs = self.timed_execute(&tag, &inputs)?;
        let (logits, new_k, new_v) = unpack3(outs)?;
        // Hand the updated cache straight to the next step (no host copy).
        self.kv_lit = Some((new_k, new_v));
        self.mirror_stale = true;

        let all_logits = logits.to_vec::<f32>()?;
        let rows = lanes
            .iter()
            .map(|&lane| all_logits[lane * d.vocab..(lane + 1) * d.vocab].to_vec())
            .collect();
        Ok(rows)
    }
}

fn unpack3(mut outs: Vec<xla::Literal>) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
    if outs.len() != 3 {
        bail!("expected 3 outputs (logits, kv.k, kv.v), got {}", outs.len());
    }
    let v = outs.pop().unwrap();
    let k = outs.pop().unwrap();
    let logits = outs.pop().unwrap();
    Ok((logits, k, v))
}

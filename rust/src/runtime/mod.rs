//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and runs
//! real token generation on the CPU PJRT client.
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py` and
//! /opt/xla-example/README.md).
//!
//! * [`manifest`] parses the line-based `artifacts/manifest.txt` the AOT
//!   step writes (tensor table into `weights.bin`, per-artifact argument
//!   order, model dims);
//! * [`client`] owns the PJRT client, the weight literals and the
//!   compiled executables;
//! * [`backend`] implements [`crate::engine::Backend`] on top — the
//!   engine serves the tiny GPTQ Llama end-to-end through it.

pub mod backend;
pub mod client;
pub mod manifest;

pub use backend::PjrtBackend;
pub use client::Runtime;
pub use manifest::{ArtifactMeta, Dtype, Manifest, TensorMeta};

//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and runs
//! real token generation on the CPU PJRT client.
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py` and
//! /opt/xla-example/README.md).
//!
//! * [`manifest`] parses the line-based `artifacts/manifest.txt` the AOT
//!   step writes (tensor table into `weights.bin`, per-artifact argument
//!   order, model dims);
//! * [`client`] owns the PJRT client, the weight literals and the
//!   compiled executables;
//! * [`backend`] implements [`crate::engine::Backend`] on top — the
//!   engine serves the tiny GPTQ Llama end-to-end through it.

// The manifest parser is dependency-free and always available (the AOT
// artifact format is part of the repo contract); the PJRT client and the
// backend over it need the `xla` bindings crate, which is not available
// offline — they are gated behind the `pjrt` feature (see Cargo.toml).
#[cfg(feature = "pjrt")]
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use client::Runtime;
pub use manifest::{ArtifactMeta, Dtype, Manifest, TensorMeta};

//! Maps simulated kernel times onto end-to-end model step times.
//!
//! The paper's Figures 2–3 are *serving* numbers: the kernel speedups are
//! filtered through everything else a decode step does (attention over
//! the KV cache, norms/rope/residuals, the fp16 lm_head, kernel-launch
//! overhead).  This module prices one prefill/decode step of each paper
//! model under each optimization config; the serving engine integrates
//! these step times over a request trace with continuous batching.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::dcusim::kernels::KernelParams;
use crate::dcusim::{Device, GemvKernel};
use crate::models::ModelSpec;
use crate::OptConfig;

/// Non-GEMM cost parameters (bandwidth-bound estimates).
///
/// Calibrated to the DCU's poorly-optimized aux path the paper itself
/// motivates: attention/norm/rope kernels reach only a small fraction of
/// HBM bandwidth, launches cost tens of µs through the ROCm-compatible
/// stack, and vLLM's Python-side scheduling/sampling adds a per-step
/// constant.  These set the *Amdahl slack* around the quantized GEMMs —
/// the quantity that turns kernel speedups into the paper's end-to-end
/// gains (biggest for 13B, smallest for 1.8B).
#[derive(Debug, Clone, Copy)]
pub struct OverheadModel {
    /// Kernel launch + runtime dispatch overhead per kernel call, seconds.
    pub launch_s: f64,
    /// Fraction of HBM bandwidth achievable by the memory-bound misc ops.
    pub misc_bw_fraction: f64,
    /// Engine-side (CPU) overhead per decode step: scheduling, sampling,
    /// detokenization — vLLM's measured per-step cost class.
    pub step_cpu_s: f64,
}

impl Default for OverheadModel {
    fn default() -> Self {
        OverheadModel { launch_s: 20e-6, misc_bw_fraction: 0.12, step_cpu_s: 12e-3 }
    }
}

/// Cached, device-backed step-time model.
pub struct PerfModel {
    pub device: Device,
    pub overhead: OverheadModel,
    cache: Mutex<HashMap<(KernelParams, OptConfig), f64>>,
}

impl PerfModel {
    pub fn new(device: Device) -> PerfModel {
        PerfModel { device, overhead: OverheadModel::default(), cache: Mutex::new(HashMap::new()) }
    }

    pub fn z100() -> PerfModel {
        PerfModel::new(Device::z100())
    }

    /// Simulated seconds of one quantized GEMM call (memoized by shape).
    pub fn gemm_seconds(&self, params: KernelParams, opt: OptConfig) -> f64 {
        let key = (params, opt);
        if let Some(&v) = self.cache.lock().unwrap().get(&key) {
            return v;
        }
        let report = self.device.simulate(&GemvKernel::new(params, opt));
        let v = report.seconds;
        self.cache.lock().unwrap().insert(key, v);
        v
    }

    /// Seconds the memory-bound non-GEMM work takes to move `bytes`.
    fn misc_seconds(&self, bytes: f64) -> f64 {
        bytes / (self.device.cfg.mem_bw_bytes * self.overhead.misc_bw_fraction)
    }

    /// One decode step for `batch` sequences at mean context `ctx` tokens.
    pub fn decode_step_seconds(
        &self,
        model: &ModelSpec,
        batch: usize,
        ctx: f64,
        opt: OptConfig,
    ) -> f64 {
        assert!(batch > 0);
        let gemms: f64 = model
            .layer_gemms(batch)
            .into_iter()
            .map(|p| self.gemm_seconds(p, opt))
            .sum::<f64>()
            * model.n_layers as f64;

        // Attention: read K and V for the whole context, per sequence and
        // layer (fp16), write one row.
        let kv_bytes = 2.0
            * (model.kv_dim() * 2) as f64
            * ctx
            * batch as f64
            * model.n_layers as f64;
        // Norms / rope / residual / activation traffic: ~10 d-vectors per
        // layer per sequence.
        let misc_bytes =
            (10 * model.d_model * 2 * batch * model.n_layers) as f64;
        // lm_head: fp16 weight matrix streamed once per step (batch
        // amortizes it), plus logits out.
        let lm_head_bytes =
            (model.d_model * model.vocab * 2) as f64 + (batch * model.vocab * 2) as f64;

        // Launches: 7 quantized GEMMs + ~5 aux kernels per layer + head.
        let launches = (model.n_layers * 12 + 2) as f64 * self.overhead.launch_s;

        gemms
            + self.misc_seconds(kv_bytes + misc_bytes + lm_head_bytes)
            + launches
            + self.overhead.step_cpu_s
    }

    /// Prefill of one sequence of `prompt_len` tokens.
    pub fn prefill_seconds(&self, model: &ModelSpec, prompt_len: usize, opt: OptConfig) -> f64 {
        assert!(prompt_len > 0);
        let gemms: f64 = model
            .layer_gemms(prompt_len)
            .into_iter()
            .map(|p| self.gemm_seconds(p, opt))
            .sum::<f64>()
            * model.n_layers as f64;
        // Causal attention: scores + weighted sum touch ~s²·d_head·heads
        // fp16 values per layer (flash-style streaming, bandwidth-priced).
        let attn_bytes = (prompt_len * prompt_len) as f64
            * (model.n_heads * 2) as f64
            * model.n_layers as f64
            + 2.0 * (prompt_len * model.kv_dim() * 2 * model.n_layers) as f64;
        let launches = (model.n_layers * 12 + 2) as f64 * self.overhead.launch_s;
        gemms + self.misc_seconds(attn_bytes) + launches + self.overhead.step_cpu_s
    }

    /// Fraction of a decode step spent in the quantized GEMMs — the paper
    /// optimizes only this part, so it bounds the end-to-end gain
    /// (Amdahl).
    pub fn gemm_fraction(&self, model: &ModelSpec, batch: usize, ctx: f64, opt: OptConfig) -> f64 {
        let gemms: f64 = model
            .layer_gemms(batch)
            .into_iter()
            .map(|p| self.gemm_seconds(p, opt))
            .sum::<f64>()
            * model.n_layers as f64;
        gemms / self.decode_step_seconds(model, batch, ctx, opt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{by_name, PAPER_MODELS};

    fn pm() -> PerfModel {
        PerfModel::z100()
    }

    #[test]
    fn decode_step_time_positive_and_ordered_by_model_size() {
        let pm = pm();
        let t13 = pm.decode_step_seconds(by_name("LLaMa-13B-GPTQ").unwrap(), 32, 200.0, OptConfig::BASELINE);
        let t18 = pm.decode_step_seconds(
            by_name("Qwen1.5-1.8B-Chat-GPTQ-Int4").unwrap(),
            32,
            200.0,
            OptConfig::BASELINE,
        );
        assert!(t13 > t18, "13B step must cost more than 1.8B: {t13} vs {t18}");
        assert!(t13 > 0.0 && t13 < 1.0, "sane step time, got {t13}");
    }

    #[test]
    fn optimizations_reduce_step_time_for_all_models() {
        let pm = pm();
        for m in PAPER_MODELS.iter() {
            let base = pm.decode_step_seconds(m, 32, 200.0, OptConfig::BASELINE);
            for opt in [OptConfig::SMB, OptConfig::VML, OptConfig::ILA, OptConfig::OPT4GPTQ] {
                let t = pm.decode_step_seconds(m, 32, 200.0, opt);
                assert!(t < base, "{} {}: {t} !< {base}", m.name, opt.label());
            }
        }
    }

    #[test]
    fn cache_hits_are_consistent() {
        let pm = pm();
        let p = KernelParams { m: 8, k: 4096, n: 4096, group_size: 128 };
        let a = pm.gemm_seconds(p, OptConfig::ILA);
        let b = pm.gemm_seconds(p, OptConfig::ILA);
        assert_eq!(a, b);
    }

    #[test]
    fn prefill_scales_with_prompt_length() {
        let pm = pm();
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let t64 = pm.prefill_seconds(m, 64, OptConfig::BASELINE);
        let t512 = pm.prefill_seconds(m, 512, OptConfig::BASELINE);
        assert!(t512 > 2.0 * t64);
    }

    #[test]
    fn gemm_fraction_is_majority_for_large_models() {
        // The paper's premise: the GPTQ GEMM dominates the decode step.
        let pm = pm();
        let m = by_name("LLaMa-13B-GPTQ").unwrap();
        let f = pm.gemm_fraction(m, 32, 200.0, OptConfig::BASELINE);
        assert!(f > 0.5, "GEMM fraction should dominate, got {f}");
        assert!(f < 1.0);
    }

    #[test]
    fn longer_context_costs_more() {
        let pm = pm();
        let m = by_name("Meta-Llama-3-8B-GPTQ").unwrap();
        let short = pm.decode_step_seconds(m, 8, 64.0, OptConfig::OPT4GPTQ);
        let long = pm.decode_step_seconds(m, 8, 1024.0, OptConfig::OPT4GPTQ);
        assert!(long > short);
    }
}

#[cfg(test)]
mod calib_tests {
    use super::*;
    use crate::models::PAPER_MODELS;
    use crate::OptConfig;

    #[test]
    fn dump_fractions() {
        let pm = PerfModel::z100();
        for m in PAPER_MODELS.iter() {
            let base = pm.decode_step_seconds(m, 32, 200.0, OptConfig::BASELINE);
            let opt = pm.decode_step_seconds(m, 32, 200.0, OptConfig::OPT4GPTQ);
            let ila = pm.decode_step_seconds(m, 32, 200.0, OptConfig::ILA);
            let f = pm.gemm_fraction(m, 32, 200.0, OptConfig::BASELINE);
            println!("{:<30} step={:.4}s f={:.3} gain_opt4={:+.1}% gain_ila={:+.1}%",
                m.name, base, f, (base/opt-1.0)*100.0, (base/ila-1.0)*100.0);
        }
    }
}

//! Paper-experiment reproduction drivers (Figures 2–3, Tables I–II).
//!
//! Each figure/table has one function that runs the full experiment and
//! returns structured rows; the bench targets (`rust/benches/*`) and
//! `examples/paper_figures.rs` are thin wrappers that print them next to
//! the paper's reported numbers.  The paper's setup (§IV-B): one batch of
//! 32 ShareGPT prompts, GPTQ-4bit, vLLM defaults — mirrored here with the
//! simulated backend on the simulated Z100.

use crate::benchkit::Table;
use crate::engine::{Engine, EngineConfig, Request, SamplingParams, SimBackend};
use crate::eval::accuracy::evaluate;
use crate::models::{ModelSpec, PAPER_MODELS};
use crate::trace::arc::ArcSplit;
use crate::trace::RequestTrace;
use crate::OptConfig;
use crate::Result;

/// The paper's reported *throughput improvement* percentages (Figure 2),
/// rows in paper model order, columns SMB/VML/ILA/Opt4GPTQ.
pub const PAPER_FIG2_GAINS: [[f64; 4]; 6] = [
    [6.83, 3.11, 28.74, 41.77],   // Qwen1.5-4B
    [4.94, 1.36, 16.75, 21.93],   // Qwen1.5-1.8B
    [17.98, 11.03, 57.19, 84.42], // LLaMa-13B
    [14.74, 5.88, 46.30, 67.55],  // CodeLlama-7B
    [9.50, 4.91, 37.26, 54.55],   // Llama-2-7B
    [16.43, 5.89, 44.81, 61.78],  // Meta-Llama-3-8B
];

/// The paper's reported *latency reduction* percentages (Figure 3).
pub const PAPER_FIG3_REDUCTIONS: [[f64; 4]; 6] = [
    [5.21, 1.93, 30.91, 47.96],
    [4.62, 2.67, 19.42, 25.18],
    [12.41, 1.21, 36.97, 51.35],
    [11.86, 2.33, 36.98, 49.73],
    [11.39, 2.39, 37.00, 49.81],
    [7.48, 0.55, 31.18, 41.23],
];

/// Paper Tables I and II (accuracy %), columns Baseline/SMB/VML/ILA/Opt4.
pub const PAPER_TABLE1_ARC_C: [(&str, [f64; 5]); 6] = [
    ("Meta-Llama-3-8B-GPTQ", [75.25, 74.92, 74.92, 74.92, 75.25]),
    ("Llama-2-7B-GPTQ", [35.59, 36.27, 35.25, 35.25, 35.59]),
    ("CodeLlama-7B-GPTQ", [27.81, 28.47, 28.47, 28.47, 29.15]),
    ("LLaMa-13B-GPTQ", [39.32, 39.66, 39.66, 40.00, 39.32]),
    ("Qwen1.5-1.8B-Chat-GPTQ-Int4", [48.81, 48.81, 48.81, 48.79, 48.81]),
    ("Qwen1.5-4B-Chat-GPTQ-Int4", [56.27, 55.59, 56.27, 56.27, 55.59]),
];

pub const PAPER_TABLE2_ARC_E: [(&str, [f64; 5]); 6] = [
    ("Meta-Llama-3-8B-GPTQ", [87.30, 87.48, 87.30, 87.30, 87.30]),
    ("Llama-2-7B-GPTQ", [47.80, 47.97, 48.59, 48.15, 47.44]),
    ("CodeLlama-7B-GPTQ", [27.51, 27.87, 27.87, 27.87, 27.87]),
    ("LLaMa-13B-GPTQ", [50.79, 51.68, 51.68, 51.50, 50.79]),
    ("Qwen1.5-1.8B-Chat-GPTQ-Int4", [69.49, 69.14, 69.49, 69.14, 69.14]),
    ("Qwen1.5-4B-Chat-GPTQ-Int4", [70.19, 70.19, 70.19, 70.19, 70.19]),
];

/// One serving measurement.
#[derive(Debug, Clone, Copy)]
pub struct ServingPoint {
    pub throughput: f64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub mean_ttft: f64,
}

/// Serving results for one model across the five configs (paper order).
#[derive(Debug, Clone)]
pub struct ServingRow {
    pub model: &'static ModelSpec,
    pub points: [ServingPoint; 5],
}

impl ServingRow {
    pub fn throughput_gain_pct(&self, config_idx: usize) -> f64 {
        (self.points[config_idx].throughput / self.points[0].throughput - 1.0) * 100.0
    }

    pub fn latency_reduction_pct(&self, config_idx: usize) -> f64 {
        (1.0 - self.points[config_idx].mean_latency / self.points[0].mean_latency) * 100.0
    }
}

/// Run the paper's serving experiment: `requests` ShareGPT-like prompts
/// in one batch (paper: 32), all five configs, one model.
pub fn serve_model(model: &'static ModelSpec, requests: usize, seed: u64) -> Result<ServingRow> {
    let trace = RequestTrace::generate(requests, seed);
    let mut points = Vec::with_capacity(5);
    for opt in OptConfig::ALL {
        let backend = SimBackend::new(model, opt, 32);
        let mut engine = Engine::new(
            EngineConfig { max_batch: 32, total_blocks: 8192, ..Default::default() },
            backend,
        );
        for r in &trace.requests {
            engine.add_request(Request::new(
                r.id,
                r.prompt.clone(),
                SamplingParams { max_tokens: r.response_len, ..Default::default() },
            ));
        }
        let report = engine.run()?;
        points.push(ServingPoint {
            throughput: report.metrics.throughput(),
            mean_latency: report.metrics.mean_latency(),
            p95_latency: report.metrics.p95_latency(),
            mean_ttft: report.metrics.mean_ttft(),
        });
    }
    Ok(ServingRow { model, points: points.try_into().map_err(|_| anyhow::anyhow!("arity")).unwrap() })
}

/// Run the full 6-model grid (Figures 2 and 3 share it).
pub fn serving_grid(requests: usize, seed: u64) -> Result<Vec<ServingRow>> {
    PAPER_MODELS.iter().map(|m| serve_model(m, requests, seed)).collect()
}

/// Figure 2: generation throughput per model per config.
pub fn fig2_table(grid: &[ServingRow]) -> Table {
    let mut t = Table::new(
        "Figure 2 — inference throughput (tok/s), simulated DCU Z100, batch 32 ShareGPT-like",
        &["model", "Baseline", "SMB-Opt", "VML-Opt", "ILA-Opt", "Opt4GPTQ",
          "gain% (SMB/VML/ILA/Opt4)", "paper gain%"],
    );
    for (i, row) in grid.iter().enumerate() {
        let mut cells = vec![row.model.name.to_string()];
        for p in &row.points {
            cells.push(format!("{:.1}", p.throughput));
        }
        cells.push(format!(
            "{:+.1}/{:+.1}/{:+.1}/{:+.1}",
            row.throughput_gain_pct(1),
            row.throughput_gain_pct(2),
            row.throughput_gain_pct(3),
            row.throughput_gain_pct(4)
        ));
        let p = PAPER_FIG2_GAINS[i];
        cells.push(format!("{:+.1}/{:+.1}/{:+.1}/{:+.1}", p[0], p[1], p[2], p[3]));
        t.row(cells);
    }
    t
}

/// Figure 3: mean request latency per model per config.
pub fn fig3_table(grid: &[ServingRow]) -> Table {
    let mut t = Table::new(
        "Figure 3 — inference latency (s/request mean), simulated DCU Z100, batch 32",
        &["model", "Baseline", "SMB-Opt", "VML-Opt", "ILA-Opt", "Opt4GPTQ",
          "reduction% (SMB/VML/ILA/Opt4)", "paper reduction%"],
    );
    for (i, row) in grid.iter().enumerate() {
        let mut cells = vec![row.model.name.to_string()];
        for p in &row.points {
            cells.push(format!("{:.2}", p.mean_latency));
        }
        cells.push(format!(
            "{:.1}/{:.1}/{:.1}/{:.1}",
            row.latency_reduction_pct(1),
            row.latency_reduction_pct(2),
            row.latency_reduction_pct(3),
            row.latency_reduction_pct(4)
        ));
        let p = PAPER_FIG3_REDUCTIONS[i];
        cells.push(format!("{:.1}/{:.1}/{:.1}/{:.1}", p[0], p[1], p[2], p[3]));
        t.row(cells);
    }
    t
}

/// Tables I/II: accuracy per model per config, printed next to the paper.
pub fn accuracy_table(split: ArcSplit) -> Table {
    let paper = match split {
        ArcSplit::Challenge => &PAPER_TABLE1_ARC_C,
        ArcSplit::Easy => &PAPER_TABLE2_ARC_E,
    };
    let title = match split {
        ArcSplit::Challenge => "Table I — inference accuracy on ARC_C (ours / paper)",
        ArcSplit::Easy => "Table II — inference accuracy on ARC_E (ours / paper)",
    };
    let mut t = Table::new(
        title,
        &["model", "Baseline", "SMB-Opt", "VML-Opt", "ILA-Opt", "Opt4GPTQ", "max |Δbase|"],
    );
    for (model, paper_row) in paper {
        let results = evaluate(model, split);
        let mut cells = vec![model.to_string()];
        let base = results[0].accuracy() * 100.0;
        let mut max_delta: f64 = 0.0;
        for (r, pv) in results.iter().zip(paper_row) {
            let acc = r.accuracy() * 100.0;
            max_delta = max_delta.max((acc - base).abs());
            cells.push(format!("{acc:.2}%/{pv:.2}%"));
        }
        cells.push(format!("{max_delta:.2}pp"));
        t.row(cells);
    }
    t
}

/// Shape checks shared by the bench targets and integration tests: the
/// reproduction must preserve the paper's qualitative findings.
pub fn check_fig2_shape(grid: &[ServingRow]) -> Vec<String> {
    let mut problems = Vec::new();
    for row in grid {
        let (smb, vml, ila, opt4) = (
            row.throughput_gain_pct(1),
            row.throughput_gain_pct(2),
            row.throughput_gain_pct(3),
            row.throughput_gain_pct(4),
        );
        if !(ila > smb && smb > vml && vml > -0.5) {
            problems.push(format!(
                "{}: ordering ILA({ila:.1}) > SMB({smb:.1}) > VML({vml:.1}) violated",
                row.model.name
            ));
        }
        if opt4 < ila {
            problems.push(format!("{}: combined below ILA", row.model.name));
        }
        if !(5.0..=120.0).contains(&opt4) {
            problems.push(format!("{}: combined gain {opt4:.1}% out of band", row.model.name));
        }
    }
    // Larger models must gain more from the combined optimization than the
    // smallest model (paper: 13B's 84.4% vs 1.8B's 21.9%).
    let by_name = |n: &str| grid.iter().find(|r| r.model.name.contains(n)).unwrap();
    if by_name("13B").throughput_gain_pct(4) <= by_name("1.8B").throughput_gain_pct(4) {
        problems.push("13B should gain more than 1.8B".into());
    }
    problems
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_grid_preserves_paper_shape() {
        let grid = serving_grid(16, 7).unwrap();
        let problems = check_fig2_shape(&grid);
        assert!(problems.is_empty(), "{problems:?}");
    }

    #[test]
    fn latency_reductions_positive_and_bounded() {
        let row = serve_model(&PAPER_MODELS[2], 16, 3).unwrap(); // 13B
        for ci in 1..5 {
            let red = row.latency_reduction_pct(ci);
            assert!(red > 0.0 && red < 70.0, "config {ci}: {red}");
        }
        // combined reduces latency the most
        assert!(row.latency_reduction_pct(4) >= row.latency_reduction_pct(3));
    }

    #[test]
    fn tables_render() {
        let grid = serving_grid(8, 1).unwrap();
        assert!(fig2_table(&grid).render().contains("LLaMa-13B"));
        assert!(fig3_table(&grid).render().contains("paper"));
    }

    #[test]
    fn deterministic_grid() {
        let a = serve_model(&PAPER_MODELS[0], 8, 5).unwrap();
        let b = serve_model(&PAPER_MODELS[0], 8, 5).unwrap();
        assert_eq!(a.points[0].throughput, b.points[0].throughput);
    }
}

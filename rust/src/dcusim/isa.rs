//! GCN/VOP3-flavoured instruction cost model.
//!
//! We do not interpret an ISA; kernels emit *instruction counts* per
//! thread/wave and this table prices them.  The key distinction the paper
//! exploits (ILA-Opt) is between the **compiler-lowered intrinsic
//! sequences** and the **native instructions**:
//!
//! * `__hfma2` through the DCU's HIP toolchain lowers to an unpack /
//!   convert / two-FMA / repack sequence (the "compiler abstraction
//!   overhead" of §III-C) — modelled as [`IsaCostModel::compiler_hfma2_valu`]
//!   VALU slots plus a register move;
//! * inline `v_mad_f16` / `v_add_f16` (VOP3) execute as a single VALU
//!   slot with VGPR-resident operands.

/// One dynamic instruction class, as counted by the kernel models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instr {
    /// 32-bit vector ALU op (address math, unpack shifts/masks, cvt).
    Valu,
    /// Packed half2 FMA via the compiler intrinsic (`__hfma2`).
    CompilerHfma2,
    /// Packed half2 ADD via the compiler intrinsic (`__hadd2`).
    CompilerHadd2,
    /// Native `v_mad_f16`-class VOP3 op (ILA-Opt inline assembly).
    NativeMadF16,
    /// Native `v_add_f16`-class VOP3 op.
    NativeAddF16,
    /// Scalar ALU op (loop counters, branches).
    Salu,
    /// LDS read (per-thread).
    LdsRead,
    /// LDS write (per-thread).
    LdsWrite,
    /// Global load, 2 bytes per lane (scalar half).
    GlobalLoadHalf,
    /// Global load, 4 bytes per lane (half2 vectorized — VML-Opt).
    GlobalLoadHalf2,
    /// Global load, 4 bytes per lane (u32 word: qweight/qzeros/scales).
    GlobalLoadWord,
    /// Global atomic add (contended accumulation into C).
    GlobalAtomicAdd,
    /// Workgroup barrier (`__syncthreads`).
    Barrier,
}

/// Issue/latency costs, in cycles, at wavefront granularity.
#[derive(Debug, Clone, Copy)]
pub struct IsaCostModel {
    /// Cycles to issue one full-rate VALU op for a 64-wide wave
    /// (64 lanes / 16-wide SIMD = 4).
    pub valu_issue: u64,
    /// VALU slots consumed by a compiler-lowered `__hfma2`.
    pub compiler_hfma2_valu: u64,
    /// VALU slots consumed by a compiler-lowered `__hadd2`.
    pub compiler_hadd2_valu: u64,
    /// VALU slots for native packed f16 ops (VOP3, the ILA-Opt path).
    pub native_f16_valu: u64,
    pub salu_issue: u64,
    pub lds_issue: u64,
    pub vmem_issue: u64,
    pub barrier_cost: u64,
}

impl Default for IsaCostModel {
    fn default() -> Self {
        IsaCostModel {
            valu_issue: 4,
            // Observed shape of hipcc's lowering for packed-half intrinsics
            // on gfx906-class targets when it cannot prove VGPR residency:
            // unpack (cvt) + two scalar-half ops + repack + register moves
            // ≈ 6 VALU slots per __hfma2 (the "compiler abstraction
            // overhead" the paper's §III-C measures).
            compiler_hfma2_valu: 6,
            compiler_hadd2_valu: 5,
            native_f16_valu: 1,
            salu_issue: 1,
            lds_issue: 1,
            // Global load instruction: issue + address coalescing logic
            // occupy the vmem port for ~16 cycles per wave.
            vmem_issue: 16,
            barrier_cost: 8,
        }
    }
}

impl IsaCostModel {
    /// Wave-issue cycles for `count` dynamic instances of `instr`
    /// (memory latency is priced separately by the machine model).
    pub fn issue_cycles(&self, instr: Instr, count: u64) -> u64 {
        let per = match instr {
            Instr::Valu => self.valu_issue,
            Instr::CompilerHfma2 => self.compiler_hfma2_valu * self.valu_issue,
            Instr::CompilerHadd2 => self.compiler_hadd2_valu * self.valu_issue,
            Instr::NativeMadF16 | Instr::NativeAddF16 => {
                self.native_f16_valu * self.valu_issue
            }
            Instr::Salu => self.salu_issue,
            Instr::LdsRead | Instr::LdsWrite => self.lds_issue,
            Instr::GlobalLoadHalf | Instr::GlobalLoadHalf2 | Instr::GlobalLoadWord => {
                self.vmem_issue
            }
            Instr::GlobalAtomicAdd => self.vmem_issue,
            Instr::Barrier => self.barrier_cost,
        };
        per * count
    }

    /// Bytes moved from global memory per *lane* for one instance.
    pub fn bytes_per_lane(&self, instr: Instr) -> u64 {
        match instr {
            Instr::GlobalLoadHalf => 2,
            Instr::GlobalLoadHalf2 | Instr::GlobalLoadWord => 4,
            Instr::GlobalAtomicAdd => 4, // read-modify-write rounds to a word
            _ => 0,
        }
    }

    pub fn is_valu(&self, instr: Instr) -> bool {
        matches!(
            instr,
            Instr::Valu
                | Instr::CompilerHfma2
                | Instr::CompilerHadd2
                | Instr::NativeMadF16
                | Instr::NativeAddF16
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ila_is_cheaper_than_compiler_lowering() {
        let m = IsaCostModel::default();
        assert!(
            m.issue_cycles(Instr::NativeMadF16, 1) < m.issue_cycles(Instr::CompilerHfma2, 1)
        );
        assert!(
            m.issue_cycles(Instr::NativeAddF16, 1) < m.issue_cycles(Instr::CompilerHadd2, 1)
        );
    }

    #[test]
    fn issue_scales_linearly() {
        let m = IsaCostModel::default();
        assert_eq!(
            m.issue_cycles(Instr::Valu, 10),
            10 * m.issue_cycles(Instr::Valu, 1)
        );
    }

    #[test]
    fn vectorized_load_moves_twice_the_bytes() {
        let m = IsaCostModel::default();
        assert_eq!(m.bytes_per_lane(Instr::GlobalLoadHalf) * 2,
                   m.bytes_per_lane(Instr::GlobalLoadHalf2));
    }
}

//! Device-level execution model: occupancy, per-CU resource bounds,
//! latency hiding, and the global bandwidth / atomic-chain floors.

use super::isa::IsaCostModel;
use super::kernels::GemvKernel;
use super::memory;
use super::report::KernelReport;
use super::DcuConfig;

/// A configured simulated device.
#[derive(Debug, Clone)]
pub struct Device {
    pub cfg: DcuConfig,
    pub isa: IsaCostModel,
}

/// Raw bound breakdown of one simulated launch (cycles).
#[derive(Debug, Clone, Copy)]
pub struct SimOutcome {
    pub cycles: f64,
    pub compute_bound_cycles: f64,
    pub lds_bound_cycles: f64,
    pub vmem_issue_cycles: f64,
    pub bandwidth_cycles: f64,
    pub atomic_chain_cycles: f64,
    pub atomic_throughput_cycles: f64,
    pub latency_exposure_cycles: f64,
    pub blocks_per_cu: usize,
}

impl Device {
    pub fn new(cfg: DcuConfig) -> Device {
        Device { cfg, isa: IsaCostModel::default() }
    }

    pub fn z100() -> Device {
        Device::new(DcuConfig::z100())
    }

    /// Resident blocks per CU, limited by LDS, waves and VGPRs.
    pub fn occupancy(&self, lds_bytes: usize, waves_per_block: usize, vgprs_per_thread: usize, threads: usize) -> usize {
        let by_lds = if lds_bytes == 0 { usize::MAX } else { self.cfg.lds_bytes / lds_bytes };
        let wave_capacity = self.cfg.max_waves_per_simd * self.cfg.simds_per_cu;
        let by_waves = wave_capacity / waves_per_block.max(1);
        let vgpr_capacity = self.cfg.vgprs_per_simd * self.cfg.simds_per_cu;
        let by_vgpr = vgpr_capacity / (vgprs_per_thread * threads).max(1);
        by_lds.min(by_waves).min(by_vgpr).max(1)
    }

    /// Simulate one kernel launch, returning the full report.
    pub fn simulate(&self, kernel: &GemvKernel) -> KernelReport {
        let cfg = &self.cfg;
        let block = kernel.block_work(cfg, &self.isa);
        let blocks = kernel.blocks();

        let r = self.occupancy(block.lds_bytes, block.waves, block.vgprs_per_thread, block.threads);
        let cus = cfg.compute_units as f64;
        let rounds = (blocks as f64 / (r as f64 * cus)).ceil().max(1.0);

        // Per-CU pipeline model: resident blocks keep the VALU, LDS and
        // vmem-issue pipes busy; these costs *add* at the CU (the paper's
        // additive gains — ILA removes VALU slots, SMB removes atomic
        // service, VML removes load issue — require an additive model;
        // a pure max-bound model would hide all but one optimization).
        let compute = rounds * (r as f64 * block.valu_cycles as f64) / cfg.simds_per_cu as f64;
        let lds_time = rounds * r as f64 * block.lds_cycles as f64;
        // Atomic service occupies the CU's memory port per operation.
        let atomic_cu = rounds
            * r as f64
            * block.atomics_per_block as f64
            * (cfg.atomic_service_cycles as f64 / 8.0);
        let vmem_issue = rounds * r as f64 * block.vmem_issue_cycles as f64 + atomic_cu;
        // Dependency latency is hidden by resident waves; the unhidden
        // fraction shrinks with occupancy.
        let latency_exposure =
            rounds * block.dep_latency as f64 / (r * block.waves).max(1) as f64;

        // Device-wide floors.
        let total_bytes = block.mem.total_transaction_bytes() as f64 * blocks as f64;
        let bw = memory::bandwidth_cycles(cfg, total_bytes as u64);
        let hot_chain =
            memory::atomic_chain_cycles(cfg, kernel.hot_address_contention()) as f64;
        // Atomic throughput across the device's address-parallel channels.
        let total_atomics = block.mem.atomic_ops as f64 * blocks as f64;
        let atomic_tp = total_atomics * cfg.atomic_service_cycles as f64 / 512.0;

        // The three pipes (VALU SIMDs, LDS, vmem) issue concurrently; the
        // additive sum above assumes full serialization.  Real CUs overlap
        // them — PIPE_OVERLAP is the empirical ILP factor (calibrated so
        // absolute GEMM times land in the DCU's observed range; it scales
        // all configs equally and does not affect the optimization ratios).
        const PIPE_OVERLAP: f64 = 3.0;
        let per_cu =
            (compute + lds_time + vmem_issue) / PIPE_OVERLAP + latency_exposure;
        let cycles = per_cu.max(bw).max(hot_chain).max(atomic_tp);

        let outcome = SimOutcome {
            cycles,
            compute_bound_cycles: compute,
            lds_bound_cycles: lds_time,
            vmem_issue_cycles: vmem_issue,
            bandwidth_cycles: bw,
            atomic_chain_cycles: hot_chain,
            atomic_throughput_cycles: atomic_tp,
            latency_exposure_cycles: latency_exposure,
            blocks_per_cu: r,
        };
        KernelReport::build(cfg, kernel, &block, outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcusim::kernels::KernelParams;
    use crate::OptConfig;

    fn dev() -> Device {
        Device::z100()
    }

    fn shape(m: usize, k: usize, n: usize) -> KernelParams {
        KernelParams { m, k, n, group_size: 128 }
    }

    #[test]
    fn all_optimizations_speed_up_decode_gemv() {
        let d = dev();
        let p = shape(1, 4096, 4096);
        let base = d.simulate(&GemvKernel::new(p, OptConfig::BASELINE));
        for opt in [OptConfig::SMB, OptConfig::VML, OptConfig::ILA, OptConfig::OPT4GPTQ] {
            let r = d.simulate(&GemvKernel::new(p, opt));
            assert!(
                r.seconds < base.seconds,
                "{} must beat baseline: {} vs {}",
                opt.label(),
                r.seconds,
                base.seconds
            );
        }
    }

    #[test]
    fn combined_is_fastest() {
        let d = dev();
        let p = shape(1, 4096, 4096);
        let results: Vec<f64> = OptConfig::ALL
            .iter()
            .map(|o| d.simulate(&GemvKernel::new(p, *o)).seconds)
            .collect();
        let combined = results[4];
        for (i, &r) in results.iter().enumerate().take(4) {
            assert!(combined <= r, "Opt4GPTQ must be fastest (vs idx {i})");
        }
    }

    #[test]
    fn ila_gains_exceed_vml_gains() {
        // The paper's ordering: ILA >> SMB > VML.
        let d = dev();
        let p = shape(1, 5120, 5120);
        let base = d.simulate(&GemvKernel::new(p, OptConfig::BASELINE)).seconds;
        let ila = d.simulate(&GemvKernel::new(p, OptConfig::ILA)).seconds;
        let vml = d.simulate(&GemvKernel::new(p, OptConfig::VML)).seconds;
        let smb = d.simulate(&GemvKernel::new(p, OptConfig::SMB)).seconds;
        let gain = |t: f64| base / t - 1.0;
        assert!(gain(ila) > gain(smb), "ILA {} vs SMB {}", gain(ila), gain(smb));
        assert!(gain(smb) > gain(vml), "SMB {} vs VML {}", gain(smb), gain(vml));
    }

    #[test]
    fn bigger_problems_take_longer() {
        let d = dev();
        let small = d.simulate(&GemvKernel::new(shape(1, 2048, 2048), OptConfig::BASELINE));
        let large = d.simulate(&GemvKernel::new(shape(1, 8192, 8192), OptConfig::BASELINE));
        assert!(large.seconds > 2.0 * small.seconds);
    }

    #[test]
    fn occupancy_respects_limits() {
        let d = dev();
        let r = d.occupancy(16 * 1024, 2, 84, 128);
        assert!(r >= 1 && r <= 4, "16KiB LDS blocks: at most 4 per 64KiB CU, got {r}");
        let r2 = d.occupancy(1024, 2, 64, 128);
        assert!(r2 > r);
    }

    #[test]
    fn batch_scaling_sublinear() {
        // Doubling M within the m_count window must not double time
        // (rows share the staged weights).
        let d = dev();
        let t1 = d.simulate(&GemvKernel::new(shape(1, 4096, 4096), OptConfig::BASELINE)).seconds;
        let t8 = d.simulate(&GemvKernel::new(shape(8, 4096, 4096), OptConfig::BASELINE)).seconds;
        assert!(t8 < 8.0 * t1, "t8={t8} t1={t1}");
        assert!(t8 > t1);
    }

    #[test]
    fn report_fields_populated() {
        let d = dev();
        let r = d.simulate(&GemvKernel::new(shape(4, 4096, 4096), OptConfig::OPT4GPTQ));
        assert!(r.seconds > 0.0);
        assert!(r.achieved_tflops > 0.0);
        assert!(r.achieved_gbps > 0.0);
        assert!(r.occupancy_blocks >= 1);
        assert!(r.mem_efficiency > 0.0 && r.mem_efficiency <= 1.0);
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;
    use crate::dcusim::kernels::KernelParams;
    use crate::OptConfig;

    #[test]
    fn dump_breakdown() {
        let d = Device::z100();
        for (m, k, n) in [(1usize, 5120usize, 5120usize), (32, 2560, 2560)] {
            println!("== m={m} k={k} n={n}");
            for opt in OptConfig::ALL {
                let kern = GemvKernel::new(KernelParams { m, k, n, group_size: 128 }, opt);
                let r = d.simulate(&kern);
                let o = r.outcome;
                println!("{:10} cyc={:>9.0} comp={:>9.0} lds={:>7.0} vmem={:>8.0} bw={:>8.0} chain={:>7.0} atp={:>9.0} occ={} bound={}",
                    r.label, o.cycles, o.compute_bound_cycles, o.lds_bound_cycles, o.vmem_issue_cycles, o.bandwidth_cycles, o.atomic_chain_cycles, o.atomic_throughput_cycles, o.blocks_per_cu, r.bound);
            }
        }
    }
}

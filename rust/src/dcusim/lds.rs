//! LDS (shared-memory) model: bank conflicts and same-address
//! serialization — the costs SMB-Opt trades global atomics against.

use super::DcuConfig;

/// Access pattern of a wavefront-wide LDS access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LdsPattern {
    /// Lane i accesses word (base + i*stride_words) — conflict-free when
    /// stride is odd / unit.
    Strided { stride_words: u64 },
    /// All lanes read the same word — broadcast, conflict-free on GCN.
    Broadcast,
    /// All lanes *accumulate into* the same word — full serialization
    /// (the SMB shared accumulator before the tree/sequential reduction).
    SameAddressAccumulate,
}

/// Cycles of extra serialization (multiplier on the base issue cost) a
/// wavefront access suffers from bank conflicts.
pub fn conflict_factor(cfg: &DcuConfig, pattern: LdsPattern, wavefront: u64) -> u64 {
    let banks = cfg.lds_banks as u64;
    match pattern {
        LdsPattern::Strided { stride_words } => {
            if stride_words == 0 {
                return 1; // broadcast-like
            }
            // lanes hitting the same bank: gcd-based cyclic collision
            let g = gcd(stride_words % banks, banks);
            if g == 0 { 1 } else { (wavefront.min(banks) / (banks / g.max(1))).max(1) }
        }
        LdsPattern::Broadcast => 1,
        LdsPattern::SameAddressAccumulate => wavefront,
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if a == 0 { b } else { gcd(b % a, a) }
}

/// Cycles one wavefront LDS access occupies the LDS pipe: one issue slot
/// multiplied by the conflict serialization factor (the access *latency*
/// is hidden by other waves and priced in the machine's dependency term).
pub fn access_cycles(cfg: &DcuConfig, pattern: LdsPattern, wavefront: u64) -> u64 {
    conflict_factor(cfg, pattern, wavefront)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_stride_is_conflict_free() {
        let cfg = DcuConfig::z100();
        assert_eq!(conflict_factor(&cfg, LdsPattern::Strided { stride_words: 1 }, 64), 1);
    }

    #[test]
    fn power_of_two_stride_conflicts() {
        let cfg = DcuConfig::z100();
        let f32_stride = conflict_factor(&cfg, LdsPattern::Strided { stride_words: 32 }, 64);
        assert!(f32_stride >= 32, "stride-32 over 32 banks must serialize, got {f32_stride}");
    }

    #[test]
    fn broadcast_free_same_address_accumulate_serializes() {
        let cfg = DcuConfig::z100();
        assert_eq!(conflict_factor(&cfg, LdsPattern::Broadcast, 64), 1);
        assert_eq!(conflict_factor(&cfg, LdsPattern::SameAddressAccumulate, 64), 64);
    }

    #[test]
    fn lds_serialization_far_cheaper_than_global_atomics() {
        // The core SMB-Opt economics: a 64-way LDS serialization must cost
        // far less than a 64-way global atomic chain.
        let cfg = DcuConfig::z100();
        let lds = access_cycles(&cfg, LdsPattern::SameAddressAccumulate, 64);
        let global = super::super::memory::atomic_chain_cycles(&cfg, 64);
        assert!(lds * 4 < global, "lds={lds} global={global}");
    }
}

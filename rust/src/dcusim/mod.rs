//! Cycle-approximate simulator of a HYGON DCU Z100-class accelerator.
//!
//! The paper's three optimizations are *memory-transaction and
//! instruction-count* effects on a GCN-architecture GPGPU:
//!
//! * **SMB-Opt** removes intra-block global-atomic contention by reducing
//!   partial sums through LDS (shared memory) and flushing once;
//! * **VML-Opt** halves the instruction/transaction count of the
//!   activation staging loads (half2 vectorized loads);
//! * **ILA-Opt** collapses the compiler-lowered `__hfma2`/`__hadd2`
//!   intrinsic sequences into single native `v_mad_f16`/`v_add_f16`
//!   VALU instructions and keeps operands VGPR-resident.
//!
//! The simulator therefore models exactly those quantities: per-block
//! VALU/SALU issue cycles, LDS traffic with bank-conflict and same-address
//! serialization, global-memory transactions with coalescing, atomic
//! contention chains, occupancy, and a wavefront latency-hiding model
//! (see [`machine`]).  It is calibrated to Z100-class parameters
//! ([`DcuConfig::z100`]) and is *cycle-approximate*: relative effects
//! (who wins, by what factor) are meaningful; absolute cycles are
//! estimates.  DESIGN.md records this as the substitution for the real
//! hardware the paper used.

pub mod isa;
pub mod kernels;
pub mod lds;
pub mod machine;
pub mod memory;
pub mod report;

pub use isa::{Instr, IsaCostModel};
pub use kernels::{GemvKernel, KernelParams};
pub use machine::{Device, SimOutcome};
pub use report::KernelReport;

/// Device parameters for a Z100-class DCU.
///
/// Public numbers for the Z100 are sparse; these values follow its
/// gfx906-class lineage (Vega/MI50-like: 60-64 CUs, 64-wide wavefronts,
/// 64 KiB LDS with 32 banks, ~1 TB/s HBM2).  Absolute numbers only scale
/// the results; the optimization *ratios* are driven by the counts.
#[derive(Debug, Clone, Copy)]
pub struct DcuConfig {
    pub name: &'static str,
    pub compute_units: usize,
    pub simds_per_cu: usize,
    pub wavefront: usize,
    /// Engine clock in Hz.
    pub clock_hz: f64,
    /// Device HBM bandwidth, bytes/s.
    pub mem_bw_bytes: f64,
    /// Global memory round-trip latency, cycles.
    pub mem_latency_cycles: u64,
    /// Service cost of one contended global atomic at the memory
    /// controller (serialized per address), cycles.
    pub atomic_service_cycles: u64,
    /// LDS capacity per CU, bytes.
    pub lds_bytes: usize,
    pub lds_banks: usize,
    /// LDS access latency, cycles.
    pub lds_latency_cycles: u64,
    /// Max resident waves per SIMD (occupancy ceiling).
    pub max_waves_per_simd: usize,
    /// VGPRs per SIMD (occupancy limiter).
    pub vgprs_per_simd: usize,
}

impl DcuConfig {
    pub fn z100() -> DcuConfig {
        DcuConfig {
            name: "HYGON DCU Z100 (simulated)",
            compute_units: 60,
            simds_per_cu: 4,
            wavefront: 64,
            clock_hz: 1.32e9,
            mem_bw_bytes: 1.0e12,
            mem_latency_cycles: 350,
            atomic_service_cycles: 6,
            lds_bytes: 64 * 1024,
            lds_banks: 32,
            lds_latency_cycles: 24,
            max_waves_per_simd: 10,
            vgprs_per_simd: 256 * 64, // 256 VGPRs × 64 lanes
        }
    }

    /// A bandwidth-starved edge variant used by ablation benches.
    pub fn z100_edge() -> DcuConfig {
        DcuConfig {
            name: "edge DCU (simulated)",
            compute_units: 16,
            mem_bw_bytes: 2.0e11,
            ..Self::z100()
        }
    }
}

//! Global-memory model: coalescing, transaction counting, atomic
//! contention chains, and the device bandwidth bound.

use super::DcuConfig;

/// Access pattern of one wavefront-wide global access, used to compute
/// the number of memory transactions it generates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    /// Lane i accesses base + i*stride_bytes (unit-stride when
    /// `stride == elem_size`).
    Strided { elem_bytes: u64, stride_bytes: u64 },
    /// Every lane hits the same address (broadcast / same-word atomic).
    SameAddress { elem_bytes: u64 },
    /// Data-dependent gather (e.g. `b_q_perm` activation reordering).
    Gather { elem_bytes: u64 },
}

pub const TRANSACTION_BYTES: u64 = 64;

/// Number of memory transactions a 64-lane wavefront access generates.
pub fn transactions_per_wave(pattern: AccessPattern, wavefront: u64) -> u64 {
    match pattern {
        AccessPattern::Strided { elem_bytes, stride_bytes } => {
            let span = stride_bytes.max(elem_bytes) * (wavefront - 1) + elem_bytes;
            span.div_ceil(TRANSACTION_BYTES).max(1)
        }
        AccessPattern::SameAddress { .. } => 1,
        AccessPattern::Gather { .. } => wavefront, // worst-case: one per lane
    }
}

/// Aggregate global-memory traffic of one thread block.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemTraffic {
    /// Read transactions issued by the block.
    pub read_transactions: u64,
    /// Bytes actually needed (useful bytes, for roofline/efficiency).
    pub read_bytes_useful: u64,
    /// Write/atomic transactions.
    pub write_transactions: u64,
    pub write_bytes_useful: u64,
    /// Number of global atomic operations (each serializes per address).
    pub atomic_ops: u64,
}

impl MemTraffic {
    pub fn total_transaction_bytes(&self) -> u64 {
        (self.read_transactions + self.write_transactions) * TRANSACTION_BYTES
    }

    pub fn add(&mut self, other: &MemTraffic) {
        self.read_transactions += other.read_transactions;
        self.read_bytes_useful += other.read_bytes_useful;
        self.write_transactions += other.write_transactions;
        self.write_bytes_useful += other.write_bytes_useful;
        self.atomic_ops += other.atomic_ops;
    }
}

/// Atomic contention: `ops_per_address` operations target each hot
/// address; they serialize at the memory controller.  Returns the length
/// of the serialization chain in cycles — a *global* critical-path bound
/// that batching/occupancy cannot hide.
pub fn atomic_chain_cycles(cfg: &DcuConfig, ops_per_address: u64) -> u64 {
    ops_per_address.saturating_mul(cfg.atomic_service_cycles)
}

/// Device-level bandwidth bound: cycles to move `bytes` at full HBM rate.
pub fn bandwidth_cycles(cfg: &DcuConfig, bytes: u64) -> f64 {
    bytes as f64 / cfg.mem_bw_bytes * cfg.clock_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_stride_half_coalesces() {
        // 64 lanes × 2B contiguous = 128 B = 2 transactions of 64 B.
        let t = transactions_per_wave(
            AccessPattern::Strided { elem_bytes: 2, stride_bytes: 2 }, 64);
        assert_eq!(t, 2);
    }

    #[test]
    fn unit_stride_word_coalesces() {
        // 64 lanes × 4B contiguous = 256 B = 4 transactions.
        let t = transactions_per_wave(
            AccessPattern::Strided { elem_bytes: 4, stride_bytes: 4 }, 64);
        assert_eq!(t, 4);
    }

    #[test]
    fn vectorized_half2_halves_instructions_not_bytes() {
        // One half2 access by 32 lanes covers the same 128 B:
        let t = transactions_per_wave(
            AccessPattern::Strided { elem_bytes: 4, stride_bytes: 4 }, 32);
        assert_eq!(t, 2);
    }

    #[test]
    fn large_stride_wastes_transactions() {
        let t = transactions_per_wave(
            AccessPattern::Strided { elem_bytes: 2, stride_bytes: 256 }, 64);
        assert!(t > 60, "strided-by-256B should be ~1 transaction per lane, got {t}");
    }

    #[test]
    fn gather_is_worst_case() {
        let t = transactions_per_wave(AccessPattern::Gather { elem_bytes: 2 }, 64);
        assert_eq!(t, 64);
    }

    #[test]
    fn same_address_is_single_transaction() {
        let t = transactions_per_wave(AccessPattern::SameAddress { elem_bytes: 4 }, 64);
        assert_eq!(t, 1);
    }

    #[test]
    fn atomic_chain_scales_with_contention() {
        let cfg = DcuConfig::z100();
        assert!(atomic_chain_cycles(&cfg, 128) > 10 * atomic_chain_cycles(&cfg, 8));
    }

    #[test]
    fn bandwidth_bound_sane() {
        let cfg = DcuConfig::z100();
        // 1 GB at 1 TB/s = 1 ms = ~1.32M cycles.
        let cyc = bandwidth_cycles(&cfg, 1 << 30);
        assert!((cyc - 1.32e9 * ((1u64 << 30) as f64 / 1e12)).abs() < 1e3);
    }
}

//! The GPTQ 4-bit dequantize-GEMV/GEMM kernel model (all five paper
//! variants).
//!
//! Geometry (documented in DESIGN.md): each thread block has
//! `T = SPLIT_K × PAIRS` threads covering a `K_SLAB × N_TILE` tile of the
//! weight matrix, where `N_TILE = 2 × PAIRS` (each thread owns one half2
//! column pair) and the K slab is split `SPLIT_K` ways across threads
//! (each thread accumulates `K_SLAB / SPLIT_K` products).  The grid is
//! `(K / K_SLAB) × (N / N_TILE) × ceil(M / M_COUNT)`; split-K blocks
//! accumulate into the same C tile — the atomicAdd the paper's SMB-Opt
//! targets.
//!
//! Per-variant differences (paper §III):
//! * baseline: every thread atomicAdds its half2 partial per row —
//!   `SPLIT_K`-way same-address contention inside the block, times the
//!   K-grid across blocks;
//! * **SMB**: partials reduced through an LDS accumulator (same-address
//!   LDS serialization, two barriers), then *one* thread per column pair
//!   flushes — global atomic count drops by `SPLIT_K`;
//! * **VML**: the cooperative staging of the activation slab into LDS
//!   uses half2 loads — half the load/store instructions, same bytes;
//! * **ILA**: the dequant/accumulate intrinsic sequence (`__hsub2`,
//!   `__hmul2`, `__hfma2`) is replaced by native VOP3 packed-f16 ops —
//!   one VALU slot each instead of the compiler's lowering, and the
//!   enforced VGPR residency lowers the per-thread register count.

use crate::dcusim::isa::{Instr, IsaCostModel};
use crate::dcusim::lds::{self, LdsPattern};
use crate::dcusim::memory::{self, AccessPattern, MemTraffic};
use crate::dcusim::DcuConfig;
use crate::OptConfig;

/// Block geometry constants (see module docs).
pub const K_SLAB: usize = 128;
pub const SPLIT_K: usize = 8;
pub const PAIRS: usize = 16;
pub const N_TILE: usize = 2 * PAIRS; // 32 columns
pub const THREADS: usize = SPLIT_K * PAIRS; // 128
pub const M_COUNT_MAX: usize = 8;

/// Problem shape of one quantized GEMM call.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelParams {
    /// Rows of the activation matrix (decode: batch size; prefill: tokens).
    pub m: usize,
    /// In-features.
    pub k: usize,
    /// Out-features.
    pub n: usize,
    /// Quantization group size.
    pub group_size: usize,
}

impl KernelParams {
    pub fn flops(&self) -> u64 {
        2 * self.m as u64 * self.k as u64 * self.n as u64
    }

    /// Bytes that *must* move for this call (packed weights + activations
    /// + outputs) — the roofline numerator.
    pub fn min_bytes(&self) -> u64 {
        let wq = (self.k / 2 * self.n) as u64; // 4-bit weights
        let scales = (self.k / self.group_size * self.n * 2) as u64;
        let zeros = (self.k / self.group_size * self.n / 2) as u64;
        let act = (self.m * self.k * 2) as u64;
        let out = (self.m * self.n * 2) as u64;
        wq + scales + zeros + act + out
    }
}

/// Per-block cost summary produced by the kernel model.
#[derive(Debug, Clone)]
pub struct BlockWork {
    pub threads: usize,
    pub waves: usize,
    pub lds_bytes: usize,
    pub vgprs_per_thread: usize,
    /// Wave-issue cycles for VALU work, summed over the block's waves.
    pub valu_cycles: u64,
    /// LDS pipe cycles (issue × conflict factors), per block.
    pub lds_cycles: u64,
    /// Memory instruction issue cycles, per block.
    pub vmem_issue_cycles: u64,
    /// One-trip dependency latency (staging load -> use), cycles.
    pub dep_latency: u64,
    pub mem: MemTraffic,
    /// Global atomic ops issued by this block.
    pub atomics_per_block: u64,
    /// Contending atomic ops per hottest C address *within* this block.
    pub intra_block_contention: u64,
}

/// The modelled kernel: shape + optimization toggles.
#[derive(Debug, Clone, Copy)]
pub struct GemvKernel {
    pub params: KernelParams,
    pub opt: OptConfig,
    /// Activation-order checkpoints carry `b_q_perm`: the staging loads
    /// become data-dependent gathers (paper Algorithm 2's perm branch),
    /// which defeats half2 vectorization and coalescing.
    pub act_order: bool,
}

impl GemvKernel {
    pub fn new(params: KernelParams, opt: OptConfig) -> GemvKernel {
        assert_eq!(params.k % K_SLAB, 0, "K must be a multiple of {K_SLAB}");
        assert_eq!(params.n % N_TILE, 0, "N must be a multiple of {N_TILE}");
        GemvKernel { params, opt, act_order: false }
    }

    /// Kernel over an act-order (`desc_act`) checkpoint.
    pub fn with_act_order(params: KernelParams, opt: OptConfig) -> GemvKernel {
        GemvKernel { act_order: true, ..Self::new(params, opt) }
    }

    /// Rows processed per block.
    pub fn m_count(&self) -> usize {
        self.params.m.min(M_COUNT_MAX)
    }

    /// Grid dimensions (gk, gn, gm).
    pub fn grid(&self) -> (usize, usize, usize) {
        let gk = self.params.k / K_SLAB;
        let gn = self.params.n / N_TILE;
        let gm = self.params.m.div_ceil(self.m_count());
        (gk, gn, gm)
    }

    pub fn blocks(&self) -> u64 {
        let (gk, gn, gm) = self.grid();
        (gk * gn * gm) as u64
    }

    /// Total atomic ops contending on the hottest single C address across
    /// the whole grid (the serialization chain the memory controller sees).
    pub fn hot_address_contention(&self) -> u64 {
        let (gk, _, _) = self.grid();
        self.block_contention_per_address() * gk as u64
    }

    fn block_contention_per_address(&self) -> u64 {
        if self.opt.smb {
            1 // one flush per column pair per block
        } else {
            SPLIT_K as u64
        }
    }

    /// Build the per-block cost summary under the device/ISA models.
    pub fn block_work(&self, cfg: &DcuConfig, isa: &IsaCostModel) -> BlockWork {
        let wave = cfg.wavefront as u64;
        let waves = THREADS / cfg.wavefront;
        let mc = self.m_count() as u64;
        let kpt = (K_SLAB / SPLIT_K) as u64; // k-iterations per thread

        let mut valu_instr: u64 = 0; // per-thread VALU slots
        let mut lds_cycles: u64 = 0;
        let mut vmem_issue: u64 = 0;
        let mut mem = MemTraffic::default();

        // ---------------- Phase A: stage activations into LDS -----------
        // K_SLAB halves per row m, loaded cooperatively.  Wave-level issue
        // count: 128 half loads need 2 wave-issues (64 lanes each); half2
        // vectorization (VML) covers them in 1.
        // Act-order checkpoints gather through b_q_perm: no half2
        // vectorization possible (Algorithm 2 falls back to scalar loads)
        // and the accesses stop coalescing.
        let vectorized = self.opt.vml && !self.act_order;
        let stage_wave_issues: u64 =
            mc * (K_SLAB as u64 / wave) / if vectorized { 2 } else { 1 };
        let stage_instr = if vectorized {
            Instr::GlobalLoadHalf2
        } else {
            Instr::GlobalLoadHalf
        };
        vmem_issue += stage_wave_issues.max(1) * isa.issue_cycles(stage_instr, 1);
        let stage_pattern = if self.act_order {
            AccessPattern::Gather { elem_bytes: 2 }
        } else if vectorized {
            AccessPattern::Strided { elem_bytes: 4, stride_bytes: 4 }
        } else {
            AccessPattern::Strided { elem_bytes: 2, stride_bytes: 2 }
        };
        // Transactions: per row m, one wave-front sweep over K_SLAB halves.
        let waves_touching = (K_SLAB as u64 * if vectorized { 1 } else { 2 } / 2).div_ceil(wave);
        mem.read_transactions +=
            mc * waves_touching * memory::transactions_per_wave(stage_pattern, wave);
        mem.read_bytes_useful += mc * (K_SLAB as u64) * 2;
        // VML pays 2 extra VALU (low2half/high2half splits) per load.
        if vectorized {
            valu_instr += 2 * stage_wave_issues.max(1);
        }
        // LDS writes for the staged slab (unit stride, conflict-free).
        let lds_writes = mc * K_SLAB as u64 / THREADS as u64;
        lds_cycles += lds_writes
            * lds::access_cycles(cfg, LdsPattern::Strided { stride_words: 1 }, wave)
            * waves as u64;
        // Barrier after staging.
        valu_instr += 0;
        let mut barriers: u64 = 1;

        // ---------------- Phase B: dequantize + accumulate --------------
        // Weight loads per thread: 2 qweight words per column × 2 columns
        // (kpt=16 rows span 2 packed words), 1 scales half2, 1 qzeros word.
        let weight_loads: u64 = 4 + 1 + 1;
        vmem_issue += weight_loads * isa.issue_cycles(Instr::GlobalLoadWord, 1);
        // qweight layout is row-major [K/8, N]: within one packed k-row,
        // the block's N_TILE consecutive columns are contiguous (128 B =
        // 2 transactions); the block touches K_SLAB/8 packed rows.
        let qw_words_per_block = (K_SLAB / 8 * N_TILE) as u64;
        let row_txns = ((N_TILE * 4) as u64).div_ceil(memory::TRANSACTION_BYTES);
        mem.read_transactions += (K_SLAB / 8) as u64 * row_txns;
        mem.read_bytes_useful += qw_words_per_block * 4;
        // scales + zeros (amortized per group; K_SLAB <= group_size here).
        mem.read_transactions += 2;
        mem.read_bytes_useful += (N_TILE * 2 + N_TILE / 2) as u64;

        // Dequant per (k, pair): unpack 4 VALU; then packed sub2 + mul2.
        let sub2 = if self.opt.ila { Instr::NativeAddF16 } else { Instr::CompilerHadd2 };
        let mul2 = if self.opt.ila { Instr::NativeAddF16 } else { Instr::CompilerHadd2 };
        let fma2 = if self.opt.ila { Instr::NativeMadF16 } else { Instr::CompilerHfma2 };
        let unpack_valu = 4 * kpt;
        valu_instr += unpack_valu;
        let dequant_packed = kpt; // one sub2+mul2 pair per k
        let dequant_cycles_per_thread = dequant_packed
            * (isa.issue_cycles(sub2, 1) + isa.issue_cycles(mul2, 1))
            / isa.issue_cycles(Instr::Valu, 1).max(1);
        valu_instr += dequant_cycles_per_thread;
        // LDS broadcast reads of the staged activation + fma per (m, k).
        let lds_reads = mc * kpt;
        lds_cycles += lds_reads
            * lds::access_cycles(cfg, LdsPattern::Broadcast, wave)
            * waves as u64;
        let fma_cycles_per_thread =
            mc * kpt * isa.issue_cycles(fma2, 1) / isa.issue_cycles(Instr::Valu, 1).max(1);
        valu_instr += fma_cycles_per_thread;
        // Loop/address overhead.
        valu_instr += 8 + kpt;

        // ---------------- Phase C: write back ---------------------------
        let atomics_per_block: u64;
        if self.opt.smb {
            // LDS same-address accumulation (SPLIT_K-way serialization per
            // column pair), two barriers, then one flush per pair per m by
            // the designated thread (paper Algorithm 1: single-threaded
            // writes).
            lds_cycles += mc
                * lds::access_cycles(cfg, LdsPattern::SameAddressAccumulate, SPLIT_K as u64)
                * waves as u64;
            barriers += 2;
            atomics_per_block = mc * PAIRS as u64;
        } else {
            // Every thread atomicAdds its half2 partial per row.
            atomics_per_block = mc * THREADS as u64;
        }
        vmem_issue += atomics_per_block / THREADS as u64 * isa.issue_cycles(Instr::GlobalAtomicAdd, 1)
            + 1;
        mem.atomic_ops += atomics_per_block;
        // Atomics to the block's output tile coalesce in L2: the DRAM
        // traffic is one cache line per row (N_TILE f16 = 64 B); the
        // *serialization* cost is priced by the machine's atomic terms.
        mem.write_transactions += mc;
        mem.write_bytes_useful += mc * N_TILE as u64 * 2;

        valu_instr += barriers * isa.barrier_cost / isa.issue_cycles(Instr::Valu, 1).max(1);

        // VALU wave-issue cycles over the block.
        let valu_cycles = valu_instr * isa.issue_cycles(Instr::Valu, 1) * waves as u64;

        // One-trip dependency latency: staging load -> LDS -> dequant load.
        let dep_latency = cfg.mem_latency_cycles + cfg.lds_latency_cycles + cfg.mem_latency_cycles;

        // ILA's register-residency constraint lowers VGPR pressure.
        let vgprs = if self.opt.ila { 64 } else { 84 };

        BlockWork {
            threads: THREADS,
            waves,
            lds_bytes: self.m_count() * K_SLAB * 2 + if self.opt.smb { PAIRS * 4 * self.m_count() } else { 0 },
            vgprs_per_thread: vgprs,
            valu_cycles,
            lds_cycles,
            vmem_issue_cycles: vmem_issue,
            dep_latency,
            mem,
            atomics_per_block,
            intra_block_contention: self.block_contention_per_address(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> KernelParams {
        KernelParams { m: 1, k: 4096, n: 4096, group_size: 128 }
    }

    #[test]
    fn grid_covers_problem() {
        let k = GemvKernel::new(params(), OptConfig::BASELINE);
        let (gk, gn, gm) = k.grid();
        assert_eq!(gk * K_SLAB, 4096);
        assert_eq!(gn * N_TILE, 4096);
        assert_eq!(gm, 1);
    }

    #[test]
    fn smb_cuts_global_atomics_by_split_factor() {
        let cfg = DcuConfig::z100();
        let isa = IsaCostModel::default();
        let base = GemvKernel::new(params(), OptConfig::BASELINE).block_work(&cfg, &isa);
        let smb = GemvKernel::new(params(), OptConfig::SMB).block_work(&cfg, &isa);
        assert_eq!(base.atomics_per_block / smb.atomics_per_block, SPLIT_K as u64);
        assert!(smb.lds_cycles > base.lds_cycles, "SMB pays LDS serialization");
    }

    #[test]
    fn vml_cuts_staging_issue() {
        let cfg = DcuConfig::z100();
        let isa = IsaCostModel::default();
        let base = GemvKernel::new(params(), OptConfig::BASELINE).block_work(&cfg, &isa);
        let vml = GemvKernel::new(params(), OptConfig::VML).block_work(&cfg, &isa);
        assert!(vml.vmem_issue_cycles < base.vmem_issue_cycles);
        // same useful bytes either way
        assert_eq!(vml.mem.read_bytes_useful, base.mem.read_bytes_useful);
    }

    #[test]
    fn ila_cuts_valu_cycles() {
        let cfg = DcuConfig::z100();
        let isa = IsaCostModel::default();
        let base = GemvKernel::new(params(), OptConfig::BASELINE).block_work(&cfg, &isa);
        let ila = GemvKernel::new(params(), OptConfig::ILA).block_work(&cfg, &isa);
        assert!(
            (ila.valu_cycles as f64) < 0.8 * base.valu_cycles as f64,
            "ILA should cut VALU cycles substantially: {} vs {}",
            ila.valu_cycles,
            base.valu_cycles
        );
        assert!(ila.vgprs_per_thread < base.vgprs_per_thread);
    }

    #[test]
    fn hot_address_contention_scales_with_split_k_grid() {
        let p1 = KernelParams { m: 1, k: 4096, n: 4096, group_size: 128 };
        let p2 = KernelParams { m: 1, k: 8192, n: 4096, group_size: 128 };
        let k1 = GemvKernel::new(p1, OptConfig::BASELINE).hot_address_contention();
        let k2 = GemvKernel::new(p2, OptConfig::BASELINE).hot_address_contention();
        assert_eq!(k2, 2 * k1);
    }

    #[test]
    fn min_bytes_is_quarter_of_fp16_weights() {
        let p = params();
        let fp16_weights = (p.k * p.n * 2) as u64;
        assert!(p.min_bytes() < fp16_weights / 3, "4-bit packing ~4x smaller");
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn rejects_unaligned_shapes() {
        GemvKernel::new(KernelParams { m: 1, k: 100, n: 64, group_size: 50 },
                        OptConfig::BASELINE);
    }
}

#[cfg(test)]
mod act_order_tests {
    use super::*;
    use crate::dcusim::Device;

    #[test]
    fn act_order_defeats_vml() {
        // With b_q_perm gathers, VML's speedup over baseline must vanish
        // (Algorithm 2 falls back to scalar gathered loads).
        let d = Device::z100();
        let p = KernelParams { m: 32, k: 4096, n: 4096, group_size: 128 };
        let base = d.simulate(&GemvKernel::with_act_order(p, OptConfig::BASELINE)).seconds;
        let vml = d.simulate(&GemvKernel::with_act_order(p, OptConfig::VML)).seconds;
        assert!((vml / base - 1.0).abs() < 0.005, "VML must be neutral under act-order");
        // ILA cannot hurt, but its compute savings are largely hidden
        // behind the gather-inflated bandwidth floor — act-order makes
        // the kernel memory-bound.
        let ila = d.simulate(&GemvKernel::with_act_order(p, OptConfig::ILA)).seconds;
        assert!(ila <= base);
        let ila_seq = d.simulate(&GemvKernel::new(p, OptConfig::ILA)).seconds;
        let base_seq = d.simulate(&GemvKernel::new(p, OptConfig::BASELINE)).seconds;
        assert!(
            base_seq / ila_seq > base / ila,
            "ILA's relative gain must shrink under act-order"
        );
    }

    #[test]
    fn act_order_costs_bandwidth() {
        let d = Device::z100();
        let p = KernelParams { m: 8, k: 4096, n: 4096, group_size: 128 };
        let seq = d.simulate(&GemvKernel::new(p, OptConfig::BASELINE));
        let act = d.simulate(&GemvKernel::with_act_order(p, OptConfig::BASELINE));
        assert!(
            act.total_read_transactions > seq.total_read_transactions,
            "gathers must generate more transactions"
        );
        assert!(act.seconds >= seq.seconds);
    }
}

//! Kernel programs for the simulator.
//!
//! [`GemvKernel`] is the paper's GPTQ dequantize-GEMM (the vLLM/exllama
//! `gemm_half_q_half` family) expressed as per-block instruction and
//! memory-traffic counts, with the three optimizations as toggles
//! ([`crate::OptConfig`]).  The counts follow the kernel structure in the
//! paper's Algorithms 1–3; the geometry constants are documented in
//! DESIGN.md §Per-experiment-index.

pub mod gemv;

pub use gemv::{GemvKernel, KernelParams};

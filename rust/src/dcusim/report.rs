//! Kernel launch report: the quantities the paper's evaluation reasons
//! about (time, bound breakdown, memory efficiency, roofline position).

use super::kernels::gemv::BlockWork;
use super::kernels::GemvKernel;
use super::machine::SimOutcome;
use super::DcuConfig;

#[derive(Debug, Clone)]
pub struct KernelReport {
    pub label: String,
    pub cycles: f64,
    pub seconds: f64,
    /// Which resource bound won (for the §Perf iteration log).
    pub bound: &'static str,
    pub outcome: SimOutcome,
    pub blocks: u64,
    pub occupancy_blocks: usize,
    pub total_atomics: u64,
    pub total_read_transactions: u64,
    pub achieved_tflops: f64,
    pub achieved_gbps: f64,
    /// useful bytes / transaction bytes.
    pub mem_efficiency: f64,
    /// fraction of device peak f16 throughput achieved.
    pub roofline_fraction: f64,
}

impl KernelReport {
    pub fn build(
        cfg: &DcuConfig,
        kernel: &GemvKernel,
        block: &BlockWork,
        outcome: SimOutcome,
    ) -> KernelReport {
        let seconds = outcome.cycles / cfg.clock_hz;
        let blocks = kernel.blocks();
        let flops = kernel.params.flops() as f64;
        let useful_bytes =
            (block.mem.read_bytes_useful + block.mem.write_bytes_useful) as f64 * blocks as f64;
        let transaction_bytes = block.mem.total_transaction_bytes() as f64 * blocks as f64;
        // Peak packed-f16 rate: 2 ops/lane/cycle × lanes × CUs × 2 (fma).
        let peak_flops = cfg.clock_hz
            * (cfg.compute_units * cfg.simds_per_cu * 16) as f64
            * 2.0
            * 2.0;

        let bounds = [
            ("compute", outcome.compute_bound_cycles),
            ("lds", outcome.lds_bound_cycles),
            ("vmem-issue", outcome.vmem_issue_cycles),
            ("bandwidth", outcome.bandwidth_cycles),
            ("atomic-chain", outcome.atomic_chain_cycles),
            ("atomic-throughput", outcome.atomic_throughput_cycles),
        ];
        let bound = bounds
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0;

        KernelReport {
            label: kernel.opt.label().to_string(),
            cycles: outcome.cycles,
            seconds,
            bound,
            outcome,
            blocks,
            occupancy_blocks: outcome.blocks_per_cu,
            total_atomics: block.mem.atomic_ops * blocks,
            total_read_transactions: block.mem.read_transactions * blocks,
            achieved_tflops: flops / seconds / 1e12,
            achieved_gbps: useful_bytes / seconds / 1e9,
            mem_efficiency: (useful_bytes / transaction_bytes).min(1.0),
            roofline_fraction: (flops / seconds) / peak_flops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcusim::kernels::KernelParams;
    use crate::dcusim::Device;
    use crate::OptConfig;

    #[test]
    fn bound_is_one_of_the_known_resources() {
        let d = Device::z100();
        let r = d.simulate(&GemvKernel::new(
            KernelParams { m: 1, k: 4096, n: 4096, group_size: 128 },
            OptConfig::BASELINE,
        ));
        assert!(
            ["compute", "lds", "vmem-issue", "bandwidth", "atomic-chain", "atomic-throughput"]
                .contains(&r.bound)
        );
    }

    #[test]
    fn roofline_fraction_below_one() {
        let d = Device::z100();
        for opt in OptConfig::ALL {
            let r = d.simulate(&GemvKernel::new(
                KernelParams { m: 8, k: 4096, n: 4096, group_size: 128 },
                opt,
            ));
            assert!(r.roofline_fraction < 1.0, "{}: {}", r.label, r.roofline_fraction);
        }
    }
}

//! `opt4gptq` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   serve        serve a synthetic trace with a real executable backend
//!                (default: the in-crate fused-kernel cpu transformer;
//!                `--backend pjrt` needs the `pjrt` build feature)
//!   simulate     run a serving simulation of a paper model on the DCU sim
//!   kernel       simulate one GPTQ-GEMM shape across all five configs
//!   accuracy     regenerate Tables I/II (ARC_C / ARC_E)
//!   figures      regenerate Figures 2-3 + Tables I-II (all experiments)
//!   quantize     demo: GPTQ-quantize a random layer, report error vs RTN

use opt4gptq::benchkit::Table;
use opt4gptq::cli::Args;
use opt4gptq::dcusim::kernels::KernelParams;
use opt4gptq::dcusim::{Device, GemvKernel};
use opt4gptq::engine::{
    Backend, CpuBackend, CpuModelConfig, Engine, EngineConfig, FaultPlan, KvDtype, Request,
    RequestOutcome, SamplingParams, SimBackend,
};
use opt4gptq::eval::accuracy::evaluate;
use opt4gptq::gptq::{quantize_gptq, quantize_rtn, reconstruction_error, GptqConfig, Matrix};
use opt4gptq::models::{by_name, PAPER_MODELS};
use opt4gptq::rng::Rng;
use opt4gptq::trace::arc::ArcSplit;
use opt4gptq::trace::RequestTrace;
use opt4gptq::OptConfig;

fn main() -> opt4gptq::Result<()> {
    let args = Args::parse();
    match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("kernel") => cmd_kernel(&args),
        Some("accuracy") => cmd_accuracy(&args),
        Some("quantize") => cmd_quantize(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            usage();
            std::process::exit(2);
        }
        None => {
            usage();
            Ok(())
        }
    }
}

fn usage() {
    eprintln!(
        "usage: opt4gptq <serve|simulate|kernel|accuracy|quantize> [options]
  serve     --backend cpu|pjrt --requests N --max-tokens N [--temperature T]
            [--model NAME]  (named config from the model registry, e.g.
             tiny-mha|tiny-gqa|mini-llama2-7b; GQA entries shrink the
             KV pool to n_kv_heads·d_head per row and turn on RoPE)
            [--blocks N --block-size N]  (paged-KV pool geometry)
            [--prefill-budget N]  (prefill chunk tokens per mixed step)
            [--arrival-rate R]  (Poisson arrivals, req/s; 0 = all at t=0)
            [--preempt swap|recompute]  (KV spill vs discard on eviction)
            [--kv-dtype f32|f16|kv4]  (paged-KV storage dtype; kv4 packs
             4-bit rows + per-row scale/zero — ~6.4x denser than f32)
            [--deadline SECS]  (per-request SLO: cancel as timed-out when
             not finished within SECS of arrival)
            [--max-waiting N]  (bounded waiting queue: shed the least
             valuable fresh request past N waiters)
            [--faults SPEC]  (seeded fault injection, e.g.
             seed=42,step=0.05,spill_out=0.1,spill_in=0.1,alloc=0.05;
             poison/crash_before/crash_after add mid-layer corruption
             and checkpoint-bracketing kill points)
            [--checkpoint-dir DIR]  (crash-consistent snapshots of the
             full engine state, atomic-rename commits)
            [--checkpoint-every N]  (steps between commits; default 8)
            [--restore]  (resume from the newest valid snapshot in
             --checkpoint-dir instead of starting the trace fresh;
             also rehydrates computed prefix blocks for new requests)
            [--cancel ID,ID,...]  (cooperatively cancel these request
             ids at the first step boundary — front-end abort demo)
            (cpu: in-crate fused-kernel transformer over paged KV;
             pjrt: --artifacts DIR, needs the `pjrt` build feature;
             OPT4GPTQ_PREFIX_SKIP=0 forces cached-prefix recompute;
             OPT4GPTQ_SWAP=0 flips the default to discard-and-recompute;
             OPT4GPTQ_KV=f32|f16|kv4 overrides the KV dtype default;
             OPT4GPTQ_MODEL=NAME overrides the model-config default;
             OPT4GPTQ_FAULTS=SPEC sets the fault-plan default;
             OPT4GPTQ_PERSIST=0 disables checkpoint persistence)
  simulate  --model NAME --requests N [--opt baseline|smb|vml|ila|opt4gptq]
  kernel    --m M --k K --n N [--group G]
  accuracy  --model NAME [--split arc_c|arc_e]
  quantize  --k K --n N --group G"
    );
}

fn parse_opt(s: &str) -> OptConfig {
    match s {
        "baseline" => OptConfig::BASELINE,
        "smb" => OptConfig::SMB,
        "vml" => OptConfig::VML,
        "ila" => OptConfig::ILA,
        "opt4gptq" | "all" => OptConfig::OPT4GPTQ,
        other => panic!("unknown opt config {other:?}"),
    }
}

fn cmd_serve(args: &Args) -> opt4gptq::Result<()> {
    match args.get_or("backend", "cpu") {
        "cpu" => {
            // `--model` beats `OPT4GPTQ_MODEL` beats tiny-mha; unknown
            // flag values are hard errors (env values only warn — the
            // flag is deliberate, the env may be inherited).
            let base: &opt4gptq::models::ModelConfig = match args.get("model") {
                Some(name) => match opt4gptq::models::registry_by_name(name) {
                    Some(m) => m,
                    None => {
                        eprintln!(
                            "unknown --model {name:?} (registry: {})",
                            opt4gptq::models::registry_names().join("|")
                        );
                        std::process::exit(2);
                    }
                },
                None => opt4gptq::models::default_model(),
            };
            let cfg = CpuModelConfig { seed: args.get_u64("seed", base.seed), ..*base };
            println!(
                "cpu backend: model `{}` — in-crate fused-kernel transformer \
                 (vocab={} layers={} d_model={} heads={}q/{}kv rope={} group={})",
                cfg.name,
                cfg.vocab,
                cfg.n_layers,
                cfg.d_model,
                cfg.n_heads,
                cfg.n_kv_heads,
                if cfg.rope { "on" } else { "off" },
                cfg.group_size
            );
            let backend = CpuBackend::new(cfg)?;
            serve_with(backend, cfg, args, false)
        }
        "pjrt" => cmd_serve_pjrt(args),
        other => {
            eprintln!("unknown backend {other:?} (expected cpu|pjrt)");
            std::process::exit(2);
        }
    }
}

#[cfg(feature = "pjrt")]
fn cmd_serve_pjrt(args: &Args) -> opt4gptq::Result<()> {
    use opt4gptq::runtime::PjrtBackend;
    let dir = args.get_or("artifacts", "artifacts");
    println!("loading PJRT backend from {dir}/ ...");
    let mut backend = PjrtBackend::load(dir)?;
    backend.warmup()?;
    println!(
        "tiny model: vocab={} layers={} heads={} max_seq={}",
        backend.dims.vocab, backend.dims.n_layers, backend.dims.n_heads, backend.dims.max_seq
    );
    // Dense-lane HLO artifacts execute whole prompts only: no chunk
    // resumption, no cached-prefix skipping (the backend bails on both).
    // The model fingerprint is the process default — PJRT dims live in
    // the compiled artifacts, not the registry.
    serve_with(backend, CpuModelConfig::default(), args, true)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_pjrt(_args: &Args) -> opt4gptq::Result<()> {
    eprintln!(
        "the pjrt backend is not compiled in: vendor an `xla` crate next to \
         vendor/anyhow, add it as a dependency of the `pjrt` feature (see \
         Cargo.toml), and build with --features pjrt; or use `--backend cpu` \
         for the in-crate executable path"
    );
    std::process::exit(2);
}

/// Drive the engine over a ShareGPT-like trace on any executable
/// backend.  `whole_prompt_only` pins one-shot prefill semantics for
/// backends that cannot resume chunks or skip cached prefixes (PJRT's
/// dense-lane artifacts): the budget is raised past any prompt and
/// prefix skip is forced off, whatever the flags/env say.
fn serve_with<B: Backend>(
    backend: B,
    model: CpuModelConfig,
    args: &Args,
    whole_prompt_only: bool,
) -> opt4gptq::Result<()> {
    let n = args.get_usize("requests", 8);
    let max_tokens = args.get_usize("max-tokens", 16);
    let temperature = args.get_f64("temperature", 0.0) as f32;
    let max_batch = backend.max_batch();
    let max_seq_len = backend.max_seq_len();
    let vocab = backend.vocab() as u32;
    // Paged-KV pool geometry: Engine::new binds it into the backend, so
    // these flags directly size the physical block pool.
    let default_cfg = EngineConfig::default();
    let total_blocks = args.get_usize("blocks", default_cfg.total_blocks);
    let block_size = args.get_usize("block-size", default_cfg.block_size);
    let mut prefill_budget = args.get_usize("prefill-budget", default_cfg.prefill_budget);
    let mut prefix_skip = default_cfg.prefix_skip;
    let mut swap_preempt = match args.get("preempt") {
        Some("swap") => true,
        Some("recompute") => false,
        Some(other) => {
            eprintln!("unknown --preempt {other:?} (expected swap|recompute)");
            std::process::exit(2);
        }
        None => default_cfg.swap_preempt,
    };
    let kv_dtype = match args.get("kv-dtype") {
        Some(raw) => match KvDtype::parse(raw) {
            Some(dtype) => dtype,
            None => {
                eprintln!("unknown --kv-dtype {raw:?} (expected f32|f16|kv4)");
                std::process::exit(2);
            }
        },
        None => default_cfg.kv_dtype,
    };
    let arrival_rate = args.get_f64("arrival-rate", 0.0);
    let deadline_secs = args.get_f64("deadline", 0.0);
    let max_waiting = args.get_usize("max-waiting", default_cfg.max_waiting);
    let faults = match args.get("faults") {
        Some(spec) => match FaultPlan::parse(spec) {
            Ok(plan) => plan,
            Err(e) => {
                eprintln!("bad --faults spec: {e}");
                std::process::exit(2);
            }
        },
        None => default_cfg.faults,
    };
    let checkpoint_dir = args.get("checkpoint-dir").map(str::to_string);
    let checkpoint_every = args.get_usize("checkpoint-every", 8);
    let restore = args.switch("restore");
    if (restore || args.get("checkpoint-every").is_some()) && checkpoint_dir.is_none() {
        eprintln!("--restore / --checkpoint-every need --checkpoint-dir DIR");
        std::process::exit(2);
    }
    if whole_prompt_only {
        // Unbounded: the budget is shared across same-step admissions,
        // so anything finite could still split a second prompt.  Swap
        // resume would also create mid-prompt chunks (start > 0), which
        // whole-prompt backends reject — recompute preemption only.
        prefill_budget = usize::MAX;
        prefix_skip = false;
        swap_preempt = false;
    }
    let budget_label = if prefill_budget == usize::MAX {
        "unbounded".to_string()
    } else {
        format!("{prefill_budget} tok/step")
    };
    println!(
        "paged KV: {total_blocks} blocks x {block_size} tokens ({} max cached tokens, dtype {kv_dtype}); \
         prefill budget {budget_label}, prefix skip {}, preempt by {}",
        total_blocks * block_size,
        if prefix_skip { "on" } else { "off" },
        if swap_preempt { "swap" } else { "recompute" },
    );
    if !faults.is_none() {
        println!(
            "fault injection: seed={} step={}/{} spill={}/{} alloc={}",
            faults.seed,
            faults.step_transient,
            faults.step_permanent,
            faults.spill_out,
            faults.spill_in,
            faults.alloc,
        );
    }
    let engine_cfg = EngineConfig {
        model,
        max_batch,
        max_seq_len,
        total_blocks,
        block_size,
        prefill_budget,
        prefix_skip,
        swap_preempt,
        kv_dtype,
        max_waiting,
        faults,
    };
    let mut engine = if restore {
        let dir = checkpoint_dir.as_deref().unwrap();
        let e = Engine::restore(engine_cfg, backend, std::path::Path::new(dir))?;
        println!(
            "restored from {dir}/: {} in-flight requests at clock {:.3}s \
             ({} checkpoints committed so far, {} prompt tokens already prefix-skipped)",
            e.metrics.restored_requests,
            e.clock,
            e.metrics.checkpoints_written,
            e.scheduler.prefill_tokens_skipped,
        );
        e
    } else {
        Engine::new(engine_cfg, backend)
    };
    if let Some(dir) = checkpoint_dir.as_deref() {
        engine.enable_checkpoints(dir, checkpoint_every);
        println!(
            "checkpointing to {dir}/ every {checkpoint_every} steps \
             (atomic commits; OPT4GPTQ_PERSIST=0 disables)"
        );
    }

    if !restore {
        // A restored engine resumes the snapshot's own trace — its
        // requests (pending ones included) travel inside the snapshot.
        let mut trace = RequestTrace::generate_with(
            n,
            42,
            opt4gptq::trace::sharegpt::TraceConfig {
                prompt_max: 48,
                response_max: 32,
                vocab,
                ..Default::default()
            },
        );
        if arrival_rate > 0.0 {
            trace = trace.with_arrivals(arrival_rate, 42);
            println!("arrivals: Poisson at {arrival_rate} req/s (virtual clock)");
        }
        for r in &trace.requests {
            let mut req = Request::new(
                r.id,
                r.prompt.clone(),
                SamplingParams {
                    max_tokens: r.response_len.min(max_tokens),
                    temperature,
                    top_k: 40,
                    seed: r.id as u64,
                    ..Default::default()
                },
            );
            req.arrival = r.arrival;
            if deadline_secs > 0.0 {
                req.deadline = Some(r.arrival + deadline_secs);
            }
            engine.add_request(req);
        }
    }
    if let Some(spec) = args.get("cancel") {
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            match part.trim().parse::<usize>() {
                Ok(id) => engine.cancel(id),
                Err(_) => {
                    eprintln!("--cancel expects comma-separated request ids, got {part:?}");
                    std::process::exit(2);
                }
            }
        }
    }
    let report = engine.run()?;
    let count = |f: fn(&RequestOutcome) -> bool| {
        report.outcomes.iter().filter(|(_, o)| f(o)).count()
    };
    let completed = count(|o| matches!(o, RequestOutcome::Completed));
    println!(
        "served {} requests: {completed} completed, {} rejected/shed, {} timed out, \
         {} cancelled, {} failed",
        report.outcomes.len(),
        count(|o| matches!(o, RequestOutcome::Rejected { .. })),
        count(|o| matches!(o, RequestOutcome::TimedOut)),
        count(|o| matches!(o, RequestOutcome::Cancelled)),
        count(|o| matches!(o, RequestOutcome::Failed { .. })),
    );
    for (id, outcome) in &report.outcomes {
        match outcome {
            RequestOutcome::Completed => {}
            RequestOutcome::Rejected { reason } | RequestOutcome::Failed { reason } => {
                println!("  request {id}: {} ({reason})", outcome.label());
            }
            RequestOutcome::TimedOut => {
                println!("  request {id}: {} (deadline {deadline_secs}s)", outcome.label());
            }
            RequestOutcome::Cancelled => {
                println!("  request {id}: {} (front-end abort)", outcome.label());
            }
        }
    }
    // Stable per-request digests so a restored run can be diffed against
    // an uninterrupted one from the terminal (the CI restart smoke greps
    // these lines).
    let mut outputs: Vec<_> = report.outputs.iter().collect();
    outputs.sort_by_key(|o| o.id);
    for o in &outputs {
        println!(
            "  request {}: {} tokens, digest {:016x}",
            o.id,
            o.tokens.len(),
            token_digest(&o.tokens)
        );
    }
    if report.metrics.checkpoints_written > 0 {
        println!("checkpoints committed: {}", report.metrics.checkpoints_written);
    }
    println!(
        "throughput: {:.1} tok/s gen ({:.1} tok/s goodput), {:.1} tok/s total, mean latency {:.3}s, mean TTFT {:.3}s, mean batch {:.2}",
        report.metrics.throughput(),
        report.metrics.goodput(),
        report.metrics.total_throughput(),
        report.metrics.mean_latency(),
        report.metrics.mean_ttft(),
        report.metrics.mean_decode_batch(),
    );
    if report.metrics.step_retries > 0 || report.metrics.spill_faults > 0 {
        println!(
            "faults survived: {} step retries, {} spill faults recovered by recompute",
            report.metrics.step_retries, report.metrics.spill_faults,
        );
    }
    let ttft = report.metrics.ttft_quantiles();
    let tpot = report.metrics.tpot_quantiles();
    let queue = report.metrics.queue_time_quantiles();
    println!(
        "SLO: TTFT p50 {:.3}s p99 {:.3}s; TPOT p50 {:.4}s p99 {:.4}s; queue p50 {:.3}s p99 {:.3}s",
        ttft.p50, ttft.p99, tpot.p50, tpot.p99, queue.p50, queue.p99,
    );
    println!(
        "preemptions: {} total ({} swapped out, {} swapped in, {} tokens restored from spill)",
        report.metrics.preemptions,
        report.metrics.swap_outs,
        report.metrics.swap_ins,
        report.metrics.swap_restored_tokens,
    );
    if report.metrics.kv_pool_bytes > 0 {
        println!(
            "KV memory ({kv_dtype}): pool {:.1} KiB, {} B/resident token, \
             spill traffic {:.1} KiB (peak resident {:.1} KiB)",
            report.metrics.kv_pool_bytes as f64 / 1024.0,
            report.metrics.kv_bytes_per_token,
            report.metrics.swap_spilled_bytes as f64 / 1024.0,
            report.metrics.kv_spill_peak_bytes as f64 / 1024.0,
        );
    }
    println!(
        "prefix-cache hits: {} (shared blocks are physically shared in the paged pool)",
        engine.scheduler.blocks.prefix_hits
    );
    println!(
        "prefill: {} chunks, {} tokens skipped via cached prefixes ({:.1}% prefix hit rate)",
        report.metrics.prefill_chunks,
        report.metrics.prefill_tokens_skipped,
        report.metrics.prefix_skip_rate() * 100.0
    );
    Ok(())
}

/// FNV-1a 64 over the little-endian token bytes: a short stable
/// fingerprint of one request's generated tokens, printed by `serve` so
/// crash/restore runs can be diffed against uninterrupted ones without
/// dumping whole token streams.
fn token_digest(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

fn cmd_simulate(args: &Args) -> opt4gptq::Result<()> {
    let model_name = args.get_or("model", "Llama-2-7B-GPTQ");
    let model = by_name(model_name)
        .unwrap_or_else(|| panic!("unknown model {model_name:?}; see --help"));
    let n = args.get_usize("requests", 32);
    let opts: Vec<OptConfig> = match args.get("opt") {
        Some(o) => vec![parse_opt(o)],
        None => OptConfig::ALL.to_vec(),
    };
    let trace = RequestTrace::generate(n, 2025);
    let mut table = Table::new(
        &format!("{model_name} — simulated serving ({n} requests, batch 32)"),
        &["config", "tok/s", "vs base", "mean lat (s)", "lat vs base"],
    );
    let mut base: Option<(f64, f64)> = None;
    for opt in opts {
        let be = SimBackend::new(model, opt, 32);
        let mut engine = Engine::new(EngineConfig::default(), be);
        for r in &trace.requests {
            engine.add_request(Request::new(
                r.id,
                r.prompt.clone(),
                SamplingParams { max_tokens: r.response_len, ..Default::default() },
            ));
        }
        let report = engine.run()?;
        let tput = report.metrics.throughput();
        let lat = report.metrics.mean_latency();
        let b = *base.get_or_insert((tput, lat));
        table.row(vec![
            opt.label().to_string(),
            format!("{tput:.1}"),
            format!("{:+.2}%", (tput / b.0 - 1.0) * 100.0),
            format!("{lat:.3}"),
            format!("{:+.2}%", (lat / b.1 - 1.0) * 100.0),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_kernel(args: &Args) -> opt4gptq::Result<()> {
    let p = KernelParams {
        m: args.get_usize("m", 1),
        k: args.get_usize("k", 4096),
        n: args.get_usize("n", 4096),
        group_size: args.get_usize("group", 128),
    };
    let device = Device::z100();
    let mut table = Table::new(
        &format!("GPTQ GEMV m={} k={} n={} g={} on {}", p.m, p.k, p.n, p.group_size, device.cfg.name),
        &["config", "µs", "speedup", "bound", "atomics", "occupancy", "mem eff"],
    );
    let mut base = None;
    for opt in OptConfig::ALL {
        let r = device.simulate(&GemvKernel::new(p, opt));
        let b = *base.get_or_insert(r.seconds);
        table.row(vec![
            r.label.clone(),
            format!("{:.2}", r.seconds * 1e6),
            format!("{:.3}x", b / r.seconds),
            r.bound.to_string(),
            r.total_atomics.to_string(),
            r.occupancy_blocks.to_string(),
            format!("{:.2}", r.mem_efficiency),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_accuracy(args: &Args) -> opt4gptq::Result<()> {
    let splits: Vec<ArcSplit> = match args.get("split") {
        Some("arc_c") => vec![ArcSplit::Challenge],
        Some("arc_e") => vec![ArcSplit::Easy],
        _ => vec![ArcSplit::Challenge, ArcSplit::Easy],
    };
    let models: Vec<&str> = match args.get("model") {
        Some(m) => vec![by_name(m).expect("unknown model").name],
        None => PAPER_MODELS.iter().map(|m| m.name).collect(),
    };
    for split in splits {
        let mut table = Table::new(
            &format!("Inference accuracy on {}", split.label()),
            &["model", "Baseline", "SMB-Opt", "VML-Opt", "ILA-Opt", "Opt4GPTQ"],
        );
        for model in &models {
            let results = evaluate(model, split);
            let mut row = vec![model.to_string()];
            row.extend(results.iter().map(|r| format!("{:.2}%", r.accuracy() * 100.0)));
            table.row(row);
        }
        table.print();
    }
    Ok(())
}

fn cmd_quantize(args: &Args) -> opt4gptq::Result<()> {
    let k = args.get_usize("k", 512);
    let n = args.get_usize("n", 128);
    let g = args.get_usize("group", 128);
    let mut rng = Rng::new(args.get_u64("seed", 0));
    let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0));
    // Correlated calibration activations (where GPTQ shines).
    let s = 512;
    let mut x = Matrix::zeros(s, k);
    let basis = Matrix::from_vec(16, k, rng.normal_vec_f32(16 * k, 1.0));
    for i in 0..s {
        let coef = rng.normal_vec_f32(16, 1.0);
        for j in 0..k {
            let mut acc = 0.0;
            for (c, &cv) in coef.iter().enumerate() {
                acc += cv * basis.at(c, j);
            }
            x.data[i * k + j] = acc + 0.05 * rng.normal() as f32;
        }
    }
    let rtn = quantize_rtn(&w, g);
    let gptq = quantize_gptq(w.clone(), &x, GptqConfig { group_size: g, percdamp: 0.01, act_order: false });
    let e_rtn = reconstruction_error(&x, &w, &rtn);
    let e_gptq = reconstruction_error(&x, &w, &gptq);
    println!("layer {k}x{n}, group {g}:");
    println!("  RTN  reconstruction error ‖XW - XQ‖_F = {e_rtn:.4}");
    println!("  GPTQ reconstruction error ‖XW - XQ‖_F = {e_gptq:.4}  ({:.1}% lower)",
             (1.0 - e_gptq / e_rtn) * 100.0);
    println!("  packed size: {} bytes ({}x smaller than f32)",
             gptq.packed_bytes(), k * n * 4 / gptq.packed_bytes());
    Ok(())
}

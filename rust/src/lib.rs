//! # opt4gptq
//!
//! Reproduction of **Opt4GPTQ: Co-Optimizing Memory and Computation for
//! 4-bit GPTQ Quantized LLM Inference on Heterogeneous Platforms**
//! (CS.DC 2025).
//!
//! The paper optimizes the 4-bit GPTQ dequantize-GEMM kernel inside the
//! vLLM serving system for the HYGON DCU Z100 accelerator via three
//! techniques — shared-memory buffering (SMB-Opt), vectorized memory
//! loading (VML-Opt) and inline GCN/VOP3 assembly (ILA-Opt) — and reports
//! end-to-end serving throughput/latency/accuracy across six GPTQ models.
//!
//! This crate is the Layer-3 rust coordinator of a three-layer stack (see
//! `DESIGN.md`):
//!
//! * [`engine`] — a vLLM-style serving engine (paged KV cache, continuous
//!   batching, prefill/decode scheduling, sampling, metrics) over three
//!   pluggable backends: the simulated DCU ([`engine::SimBackend`]), the
//!   in-crate fused-kernel transformer ([`engine::CpuBackend`]) and the
//!   PJRT artifact runtime (feature `pjrt`);
//! * [`runtime`] — PJRT CPU client that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes real token generation
//!   (the manifest parser is always built; the xla-backed client needs
//!   the `pjrt` feature);
//! * [`gptq`] — the GPTQ quantization substrate (packing, RTN and the full
//!   Hessian/Cholesky GPTQ algorithm, the quantized CPU GEMM oracle in
//!   [`gptq::gemm`], and the cache-blocked fused dequantize-GEMM fast
//!   path in [`gptq::fused`] that unpacks nibbles on the fly);
//! * [`dcusim`] — a cycle-approximate simulator of the DCU Z100 class of
//!   GPGPU accelerators plus the paper's five kernel variants;
//! * [`perfmodel`] — maps simulated kernel cycles onto per-model serving
//!   throughput/latency (regenerates the paper's Figures 2–3);
//! * [`eval`] — the ARC-style accuracy harness with variant-faithful fp16
//!   numerics (regenerates Tables I–II);
//! * [`models`], [`trace`] — the six paper model architectures and the
//!   ShareGPT/ARC-like synthetic workloads.

pub mod benchkit;
pub mod cli;
pub mod dcusim;
pub mod engine;
pub mod envcfg;
pub mod eval;
pub mod f16;
pub mod gptq;
pub mod models;
pub mod perfmodel;
pub mod qcheck;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod trace;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// The paper's four optimization configurations plus the baseline; every
/// figure/table is a sweep over these.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OptConfig {
    /// SMB-Opt: shared-memory buffering of partial sums, single-thread
    /// atomic flush per block (paper Algorithm 1).
    pub smb: bool,
    /// VML-Opt: half2 vectorized global loads of the activation matrix
    /// (paper Algorithm 2).
    pub vml: bool,
    /// ILA-Opt: inline `v_mad_f16`/`v_add_f16` GCN assembly replacing the
    /// compiler-lowered intrinsics (paper Algorithm 3).
    pub ila: bool,
}

impl OptConfig {
    pub const BASELINE: OptConfig = OptConfig { smb: false, vml: false, ila: false };
    pub const SMB: OptConfig = OptConfig { smb: true, vml: false, ila: false };
    pub const VML: OptConfig = OptConfig { smb: false, vml: true, ila: false };
    pub const ILA: OptConfig = OptConfig { smb: false, vml: false, ila: true };
    pub const OPT4GPTQ: OptConfig = OptConfig { smb: true, vml: true, ila: true };

    /// The five configurations in the order the paper reports them.
    pub const ALL: [OptConfig; 5] =
        [Self::BASELINE, Self::SMB, Self::VML, Self::ILA, Self::OPT4GPTQ];

    pub fn label(&self) -> &'static str {
        match (self.smb, self.vml, self.ila) {
            (false, false, false) => "Baseline",
            (true, false, false) => "SMB-Opt",
            (false, true, false) => "VML-Opt",
            (false, false, true) => "ILA-Opt",
            (true, true, true) => "Opt4GPTQ",
            _ => "custom",
        }
    }
}

//! Minimal argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! every binary in this repo builds its CLI from [`Args`].

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// A boolean switch that works both as a bare `--name` flag and as
    /// an explicit `--name true|false` / `--name=1` option.  The parser
    /// greedily binds `--name <next>` whenever `<next>` is not itself a
    /// `--` token, so a switch followed by a value-like argument would
    /// otherwise silently swallow it; accepting both spellings makes
    /// switches position-robust.
    pub fn switch(&self, name: &str) -> bool {
        if self.flag(name) {
            return true;
        }
        self.get(name)
            .map(|v| matches!(v.to_ascii_lowercase().as_str(), "1" | "true" | "on" | "yes"))
            .unwrap_or(false)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = parse(&["serve", "--model", "tiny", "--steps=50", "--verbose"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 0), 50);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 2.5), 2.5);
        assert_eq!(a.subcommand(), None);
    }

    #[test]
    fn switch_accepts_flag_and_option_spellings() {
        assert!(parse(&["--restore"]).switch("restore"));
        assert!(parse(&["--restore", "--other"]).switch("restore"));
        // Greedy binding turns `--restore true` into an option; the
        // switch accessor must still see it.
        assert!(parse(&["--restore", "true"]).switch("restore"));
        assert!(parse(&["--restore=1"]).switch("restore"));
        assert!(!parse(&["--restore", "false"]).switch("restore"));
        assert!(!parse(&[]).switch("restore"));
    }
}

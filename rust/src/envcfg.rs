//! Warn-once environment-override resolution shared by every
//! `OPT4GPTQ_*` knob.
//!
//! Before this module each override (`OPT4GPTQ_KERNEL`, `OPT4GPTQ_KV`,
//! `OPT4GPTQ_SWAP`, `OPT4GPTQ_PREFIX_SKIP`) carried its own copy of the
//! same pattern: read the variable once through a `OnceLock`, treat
//! empty/`auto` as "use the default", warn **once** on stderr for an
//! invalid value and fall back.  [`env_override`] is that pattern,
//! factored: callers supply the cell, the variable name and a parse
//! closure; the closure's `Err` message *is* the one-time warning.
//! `OPT4GPTQ_FAULTS` (the fault-injection plane) resolves through the
//! same helper.
//!
//! The pure half, [`resolve`], takes the raw value explicitly so unit
//! tests can cover every branch without mutating process-global
//! environment state.

use std::sync::OnceLock;

/// The resolved state of one environment override.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EnvOverride<T> {
    /// The variable is not set.
    Unset,
    /// The variable is set to `""` or `auto` — an explicit request for
    /// the built-in default.
    Auto,
    /// A parsed override value.
    Value(T),
    /// The variable is set to something the parser rejected; the
    /// warning has been emitted (once) and the caller's default applies.
    Invalid,
}

impl<T> EnvOverride<T> {
    /// The override value, if one parsed (`Unset`/`Auto`/`Invalid` all
    /// mean "use the default").
    pub fn value(&self) -> Option<&T> {
        match self {
            EnvOverride::Value(v) => Some(v),
            _ => None,
        }
    }
}

/// Pure resolution: map a raw variable value (or `None` = unset) through
/// `parse`.  Returns the override plus the warning the process-global
/// wrapper should print once, if any.  `parse` receives the trimmed
/// value and returns `Err(message)` to reject it — the message is the
/// full warning text (minus the `opt4gptq: ` prefix).
pub fn resolve<T>(
    raw: Option<&str>,
    parse: impl FnOnce(&str) -> Result<T, String>,
) -> (EnvOverride<T>, Option<String>) {
    let Some(raw) = raw else {
        return (EnvOverride::Unset, None);
    };
    let trimmed = raw.trim();
    if trimmed.is_empty() || trimmed.eq_ignore_ascii_case("auto") {
        return (EnvOverride::Auto, None);
    }
    match parse(trimmed) {
        Ok(v) => (EnvOverride::Value(v), None),
        Err(msg) => (EnvOverride::Invalid, Some(msg)),
    }
}

/// Resolve `name` exactly once per process through `cell`: the
/// environment is read on first call, the parse runs on first call, and
/// an invalid value warns on stderr exactly once — later calls return
/// the cached resolution whatever the environment says now.
pub fn env_override<T>(
    cell: &'static OnceLock<EnvOverride<T>>,
    name: &str,
    parse: impl FnOnce(&str) -> Result<T, String>,
) -> &'static EnvOverride<T> {
    cell.get_or_init(|| {
        let raw = std::env::var(name).ok();
        let (resolved, warning) = resolve(raw.as_deref(), parse);
        if let Some(msg) = warning {
            eprintln!("opt4gptq: {msg}");
        }
        resolved
    })
}

/// Shared boolean parser for on/off knobs (`OPT4GPTQ_SWAP`,
/// `OPT4GPTQ_PREFIX_SKIP`): `0|false|off|no` disable, `1|true|on|yes`
/// enable, anything else is invalid (warn once, keep the default).
pub fn parse_bool(raw: &str) -> Result<bool, String> {
    match raw.to_ascii_lowercase().as_str() {
        "0" | "false" | "off" | "no" => Ok(false),
        "1" | "true" | "on" | "yes" => Ok(true),
        other => Err(format!(
            "unrecognized boolean {other:?} (expected 0|false|off|no or 1|true|on|yes); \
             keeping the default"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_digit(raw: &str) -> Result<u32, String> {
        raw.parse().map_err(|_| format!("bad digit {raw:?}"))
    }

    #[test]
    fn unset_resolves_unset_without_warning() {
        let (r, warn) = resolve(None, parse_digit);
        assert_eq!(r, EnvOverride::Unset);
        assert_eq!(warn, None);
        assert_eq!(r.value(), None);
    }

    #[test]
    fn empty_and_auto_resolve_auto() {
        for raw in ["", "  ", "auto", "AUTO", " Auto "] {
            let (r, warn) = resolve(Some(raw), parse_digit);
            assert_eq!(r, EnvOverride::Auto, "raw={raw:?}");
            assert_eq!(warn, None);
        }
    }

    #[test]
    fn valid_value_parses_trimmed() {
        let (r, warn) = resolve(Some(" 7 "), parse_digit);
        assert_eq!(r, EnvOverride::Value(7));
        assert_eq!(r.value(), Some(&7));
        assert_eq!(warn, None);
    }

    #[test]
    fn invalid_value_warns_once_with_the_parser_message() {
        let (r, warn) = resolve(Some("seven"), parse_digit);
        assert_eq!(r, EnvOverride::Invalid);
        assert_eq!(warn.as_deref(), Some("bad digit \"seven\""));
        assert_eq!(r.value(), None);
    }

    #[test]
    fn bool_parser_accepts_the_documented_spellings() {
        for raw in ["0", "false", "OFF", "no"] {
            assert_eq!(parse_bool(raw), Ok(false), "raw={raw:?}");
        }
        for raw in ["1", "true", "ON", "yes"] {
            assert_eq!(parse_bool(raw), Ok(true), "raw={raw:?}");
        }
        assert!(parse_bool("maybe").is_err());
    }

    #[test]
    fn env_override_caches_the_first_resolution() {
        static CELL: OnceLock<EnvOverride<u32>> = OnceLock::new();
        // The variable name is unique to this test and never set, so the
        // first read resolves Unset and later reads return the cache
        // (parse is never consulted again).
        let a = env_override(&CELL, "OPT4GPTQ_TEST_NEVER_SET", parse_digit);
        assert_eq!(*a, EnvOverride::Unset);
        let b = env_override(&CELL, "OPT4GPTQ_TEST_NEVER_SET", |_| {
            panic!("cached resolution must not re-parse")
        });
        assert_eq!(*b, EnvOverride::Unset);
    }

    #[test]
    fn composed_invalid_and_valid_overrides_resolve_independently() {
        // One process, several knobs set at once, some invalid: each
        // cell resolves (and warns) on its own — an invalid
        // OPT4GPTQ_FAULTS spec must not disturb a valid OPT4GPTQ_KV, and
        // each invalid knob warns exactly once even when re-read.  Uses
        // test-local cells + test-only variable names with the *real*
        // production parsers so the composition is faithful.
        static FAULTS_CELL: OnceLock<EnvOverride<crate::engine::FaultPlan>> = OnceLock::new();
        static KV_CELL: OnceLock<EnvOverride<crate::engine::KvDtype>> = OnceLock::new();
        static PERSIST_CELL: OnceLock<EnvOverride<bool>> = OnceLock::new();
        std::env::set_var("OPT4GPTQ_TEST_COMPOSED_FAULTS", "seed=x,step=banana");
        std::env::set_var("OPT4GPTQ_TEST_COMPOSED_KV", "kv4");
        std::env::set_var("OPT4GPTQ_TEST_COMPOSED_PERSIST", "maybe");

        let faults = env_override(&FAULTS_CELL, "OPT4GPTQ_TEST_COMPOSED_FAULTS", |raw| {
            crate::engine::FaultPlan::parse(raw)
        });
        assert_eq!(*faults, EnvOverride::Invalid, "bad fault spec must resolve Invalid");

        let kv = env_override(&KV_CELL, "OPT4GPTQ_TEST_COMPOSED_KV", |raw| {
            crate::engine::KvDtype::parse(raw).ok_or_else(|| format!("bad dtype {raw:?}"))
        });
        assert_eq!(
            kv.value(),
            Some(&crate::engine::KvDtype::Kv4),
            "a sibling knob's invalid value must not poison this one"
        );

        let persist =
            env_override(&PERSIST_CELL, "OPT4GPTQ_TEST_COMPOSED_PERSIST", parse_bool);
        assert_eq!(*persist, EnvOverride::Invalid);
        // The caller's default applies for invalid knobs.
        assert!(*persist.value().unwrap_or(&true));

        // Re-reads hit the cache: parse never runs again (no second
        // warning), and the resolutions stay what they were.
        let faults2 = env_override(&FAULTS_CELL, "OPT4GPTQ_TEST_COMPOSED_FAULTS", |_| {
            panic!("cached resolution must not re-parse")
        });
        assert_eq!(*faults2, EnvOverride::Invalid);
        std::env::remove_var("OPT4GPTQ_TEST_COMPOSED_FAULTS");
        std::env::remove_var("OPT4GPTQ_TEST_COMPOSED_KV");
        std::env::remove_var("OPT4GPTQ_TEST_COMPOSED_PERSIST");
    }
}

//! Software IEEE-754 binary16 with *controlled rounding*.
//!
//! The accuracy study (paper Tables I–II) hinges on the numeric differences
//! between kernel variants:
//!
//! * the stock CUDA intrinsic `__hfma2` is a **fused** multiply-add (one
//!   rounding of `a*b+c`);
//! * the paper's ILA-Opt replaces it with the GCN `v_mad_f16` instruction,
//!   which on gfx9-class parts is a **non-fused** MAD (the product is
//!   rounded to f16 before the add);
//! * SMB-Opt changes the **accumulation order** (per-thread partials are
//!   reduced through shared memory before one atomic flush, instead of
//!   per-thread atomics arriving in scheduler order).
//!
//! A `half`-crate dependency would not give us fused-vs-non-fused control,
//! so we implement binary16 directly.  All arithmetic is computed exactly
//! in f64 (binary16 products are exact in f64; sums of two halves are
//! exact; the fused `a*b+c` is exact except astronomically rare sticky-bit
//! cases) and rounded **once** to half precision with round-to-nearest-even.

/// IEEE-754 binary16 value (bit pattern).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct F16(pub u16);

pub const F16_MAX: f64 = 65504.0;
const EXP_BIAS: i32 = 15;

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);

    /// Round an f64 to binary16 with a single round-to-nearest-even.
    ///
    /// This deliberately avoids the usual double-rounding through f32.
    pub fn from_f64(x: f64) -> F16 {
        if x.is_nan() {
            return F16::NAN;
        }
        let sign: u16 = if x.is_sign_negative() { 0x8000 } else { 0 };
        let mag = x.abs();
        if mag == 0.0 {
            return F16(sign);
        }
        // Threshold for rounding to infinity: halfway between 65504
        // (f16::MAX) and the next representable step (65536).
        if mag >= 65520.0 {
            return F16(sign | 0x7C00);
        }
        // Unbiased exponent of the f64 magnitude.
        let e2 = {
            let bits = mag.to_bits();
            let raw = ((bits >> 52) & 0x7FF) as i32;
            // inputs here are far from f64-subnormal range
            raw - 1023
        };
        // Quantum exponent: normals have a 10-bit mantissa at exponent e,
        // subnormals sit at fixed quantum 2^-24.
        let e = e2.max(-14);
        let quantum_exp = e - 10;
        // Exact power-of-two scaling, then round ties-to-even.
        // (pow2 via exponent bits: ~6x faster than f64::powi on the
        // accuracy-harness hot path, see EXPERIMENTS.md §Perf.)
        let m = mag * pow2(-quantum_exp);
        let r = m.round_ties_even() as u64;
        debug_assert!(r <= 2048);
        if e2 < -14 {
            // Subnormal (or rounds up into the smallest normal at r==1024).
            if r >= 1024 {
                return F16(sign | 0x0400);
            }
            return F16(sign | r as u16);
        }
        if r == 2048 {
            // Mantissa overflow bumps the exponent.
            let exp_field = (e + 1 + EXP_BIAS) as u16;
            if exp_field >= 31 {
                return F16(sign | 0x7C00);
            }
            return F16(sign | (exp_field << 10));
        }
        let exp_field = (e + EXP_BIAS) as u16;
        F16(sign | (exp_field << 10) | (r as u16 - 1024))
    }

    pub fn from_f32(x: f32) -> F16 {
        F16::from_f64(x as f64)
    }

    pub fn to_f64(self) -> f64 {
        let sign = if self.0 & 0x8000 != 0 { -1.0 } else { 1.0 };
        let exp = ((self.0 >> 10) & 0x1F) as i32;
        let mant = (self.0 & 0x3FF) as f64;
        match exp {
            0 => sign * mant * pow2(-24),
            31 => {
                if mant == 0.0 {
                    sign * f64::INFINITY
                } else {
                    f64::NAN
                }
            }
            _ => sign * (1024.0 + mant) * pow2(exp - EXP_BIAS - 10),
        }
    }

    pub fn to_f32(self) -> f32 {
        self.to_f64() as f32
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x3FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

/// Exact power of two via exponent bits (valid for |e| < 1022 — far
/// beyond any exponent binary16 arithmetic can produce).
#[inline]
fn pow2(e: i32) -> f64 {
    debug_assert!((-1022..1024).contains(&e));
    f64::from_bits(((e + 1023) as u64) << 52)
}

/// `a + b`, one rounding (hardware `v_add_f16` / `__hadd`).
pub fn add(a: F16, b: F16) -> F16 {
    F16::from_f64(a.to_f64() + b.to_f64())
}

/// `a * b`, one rounding (hardware `v_mul_f16`).
pub fn mul(a: F16, b: F16) -> F16 {
    F16::from_f64(a.to_f64() * b.to_f64())
}

/// Fused `a*b + c`, one rounding — the CUDA `__hfma` semantics the
/// baseline kernel's intrinsics lower to.
pub fn fma(a: F16, b: F16, c: F16) -> F16 {
    // The product of two binary16 values is exact in f64 (22 mantissa
    // bits); the subsequent add is correct to f64, and the final single
    // rounding gives fused semantics (double-rounding cases require >53
    // significant bits and are unreachable with binary16 inputs).
    F16::from_f64(a.to_f64() * b.to_f64() + c.to_f64())
}

/// Non-fused MAD: product rounded to f16, then the add rounded again —
/// the GCN `v_mad_f16` semantics ILA-Opt's inline assembly executes.
pub fn mad(a: F16, b: F16, c: F16) -> F16 {
    add(mul(a, b), c)
}

/// Element-wise packed half2 FMA (the `__hfma2` / `v_pk_fma_f16` shape the
/// paper's kernel uses: two lanes per instruction).
pub fn fma2(a: [F16; 2], b: [F16; 2], c: [F16; 2]) -> [F16; 2] {
    [fma(a[0], b[0], c[0]), fma(a[1], b[1], c[1])]
}

/// Packed half2 add (`__hadd2` / `v_add_f16` pair).
pub fn add2(a: [F16; 2], b: [F16; 2]) -> [F16; 2] {
    [add(a[0], b[0]), add(a[1], b[1])]
}

/// Sum a slice sequentially in half precision (one rounding per step) —
/// models a single thread's accumulator loop.
pub fn sum_sequential(xs: &[F16]) -> F16 {
    let mut acc = F16::ZERO;
    for &x in xs {
        acc = add(acc, x);
    }
    acc
}

/// Sum in the given order — models nondeterministic atomicAdd arrival
/// order (the order is the schedule, not the data layout).
pub fn sum_in_order(xs: &[F16], order: &[usize]) -> F16 {
    let mut acc = F16::ZERO;
    for &i in order {
        acc = add(acc, xs[i]);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_finite_halves() {
        // Exhaustive: every finite f16 must round-trip through f64.
        for bits in 0u16..=0xFFFF {
            let h = F16(bits);
            if h.is_nan() {
                continue;
            }
            let back = F16::from_f64(h.to_f64());
            assert_eq!(back.0, bits, "bits={bits:#06x}");
        }
    }

    #[test]
    fn roundtrip_all_bit_patterns_through_f32() {
        // Exhaustive over every one of the 65536 bit patterns, through the
        // *f32* conversion pair the KV pool uses (`to_f32` → `from_f32`):
        // finite values (normals, subnormals, ±0) must round-trip to the
        // identical bit pattern, ±inf must map to the canonical infinities,
        // and every NaN encoding must come back as *some* NaN (payloads are
        // canonicalised to 0x7E00, not preserved).
        for bits in 0u16..=0xFFFF {
            let h = F16(bits);
            let x = h.to_f32();
            let back = F16::from_f32(x);
            if h.is_nan() {
                assert!(x.is_nan(), "bits={bits:#06x} NaN decoded as {x}");
                assert!(back.is_nan(), "bits={bits:#06x} NaN class lost");
                assert_eq!(back.0, F16::NAN.0, "bits={bits:#06x} not canonicalised");
            } else if h.is_infinite() {
                assert!(x.is_infinite(), "bits={bits:#06x} decoded as {x}");
                assert_eq!(x.is_sign_negative(), bits & 0x8000 != 0);
                assert_eq!(back.0, bits, "bits={bits:#06x}");
            } else {
                assert!(x.is_finite(), "bits={bits:#06x} decoded as {x}");
                // f32 has 24 mantissa bits and covers the full f16 exponent
                // range, so the decode is exact — including subnormals.
                assert_eq!(back.0, bits, "bits={bits:#06x} via {x}");
                // Sign must survive even at zero (−0 keeps its bit).
                assert_eq!(x.is_sign_negative(), bits & 0x8000 != 0, "bits={bits:#06x}");
            }
        }
    }

    #[test]
    fn known_encodings() {
        assert_eq!(F16::from_f64(1.0).0, 0x3C00);
        assert_eq!(F16::from_f64(-2.0).0, 0xC000);
        assert_eq!(F16::from_f64(65504.0).0, 0x7BFF);
        assert_eq!(F16::from_f64(65520.0).0, 0x7C00); // rounds to +inf
        assert_eq!(F16::from_f64(6.103515625e-05).0, 0x0400); // min normal
        assert_eq!(F16::from_f64(5.960464477539063e-08).0, 0x0001); // min subnormal
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16; ties
        // to even must pick 1.0 (even mantissa).
        assert_eq!(F16::from_f64(1.0 + f64::powi(2.0, -11)).0, 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: even is 1+2^-9.
        assert_eq!(F16::from_f64(1.0 + 3.0 * f64::powi(2.0, -11)).0, 0x3C02);
    }

    #[test]
    fn subnormal_rounding() {
        let q = f64::powi(2.0, -24);
        assert_eq!(F16::from_f64(0.5 * q).0, 0x0000); // tie to even -> 0
        assert_eq!(F16::from_f64(0.75 * q).0, 0x0001);
        assert_eq!(F16::from_f64(1.5 * q).0, 0x0002); // tie to even -> 2
    }

    #[test]
    fn fused_vs_mad_differ() {
        // a = 1 + 2^-10, b = 1 - 2^-11:
        // exact a*b = 1 + 2^-11 - 2^-21, which rounds DOWN to 1.0 in f16
        // (just below the halfway point).  With c = -1:
        //   mad   : round(a*b) + c = 1.0 - 1.0 = 0
        //   fused : round(a*b + c) = round(2^-11 - 2^-21) ≈ 2^-11
        let a = F16::from_f64(1.0 + f64::powi(2.0, -10));
        let b = F16::from_f64(1.0 - f64::powi(2.0, -11));
        let c = F16::from_f64(-1.0);
        let fused = fma(a, b, c).to_f64();
        let madded = mad(a, b, c).to_f64();
        assert_eq!(madded, 0.0, "product must round to exactly 1.0");
        assert!(fused > 0.0, "fused keeps the residual, got {fused}");
        assert!((fused - f64::powi(2.0, -11)).abs() < 1e-6);
    }

    #[test]
    fn addition_is_correctly_rounded() {
        // 2048 + 1 = 2049 is not representable (quantum is 2 there);
        // 2049 is halfway and ties-to-even picks 2048 (even mantissa 0).
        assert_eq!(add(F16::from_f64(2048.0), F16::ONE).to_f64(), 2048.0);
        // 2048 + 3 = 2051, halfway between 2050 (odd mantissa) and 2052
        // (even mantissa): ties-to-even picks 2052.
        assert_eq!(add(F16::from_f64(2048.0), F16::from_f64(3.0)).to_f64(), 2052.0);
    }

    #[test]
    fn accumulation_order_matters() {
        // Big + many smalls: sequential order loses the smalls one by one,
        // pairing the smalls first retains them.
        let xs: Vec<F16> = std::iter::once(F16::from_f64(2048.0))
            .chain(std::iter::repeat(F16::ONE).take(64))
            .collect();
        let fwd = sum_sequential(&xs).to_f64();
        let rev: Vec<usize> = (0..xs.len()).rev().collect();
        let bwd = sum_in_order(&xs, &rev).to_f64();
        assert_ne!(fwd, bwd, "fwd={fwd} bwd={bwd}");
        assert_eq!(fwd, 2048.0); // each +1 is individually absorbed
        assert_eq!(bwd, 2112.0); // smalls first: 64 + 2048
    }

    #[test]
    fn overflow_saturates_to_inf() {
        let big = F16::from_f64(60000.0);
        assert!(add(big, big).is_infinite());
        assert!(mul(big, big).is_infinite());
    }

    #[test]
    fn nan_propagates() {
        assert!(F16::from_f64(f64::NAN).is_nan());
        assert!(add(F16::NAN, F16::ONE).is_nan());
    }

    #[test]
    fn signed_zero() {
        assert_eq!(F16::from_f64(-0.0).0, 0x8000);
        assert_eq!(F16::from_f64(0.0).0, 0x0000);
    }

    #[test]
    fn matches_native_f32_conversion_smoke() {
        // Sanity vs rust's own f32 rounding for values where a single
        // rounding through f32 is exact (f32 round-trips all f16 exactly).
        for i in 0..1000 {
            let x = (i as f64) * 0.37 - 185.0;
            let via64 = F16::from_f64(x);
            // reference: round via f32-representable check
            assert!((via64.to_f64() - x).abs() <= (x.abs() * f64::powi(2.0, -11)).max(f64::powi(2.0, -24)) + 1e-12);
        }
    }
}

//! ARC-style multiple-choice question sets (synthetic).
//!
//! The accuracy harness ([`crate::eval`]) scores each question by running
//! a GPTQ-quantized scoring head in variant-faithful fp16 arithmetic; a
//! question is "answered correctly" when the argmax over the four option
//! scores hits the label.  Question *difficulty* (how close the top two
//! option scores are) is what makes some questions flip under the tiny
//! numeric perturbations the kernel variants introduce — exactly the
//! <1 pp fluctuation behaviour the paper's Tables I–II report.

use crate::rng::{hash64, Rng};

/// ARC has a Challenge split (hard) and an Easy split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArcSplit {
    Challenge,
    Easy,
}

impl ArcSplit {
    pub fn label(&self) -> &'static str {
        match self {
            ArcSplit::Challenge => "ARC_C",
            ArcSplit::Easy => "ARC_E",
        }
    }

    /// Official test-split sizes (Clark et al., 2018).
    pub fn size(&self) -> usize {
        match self {
            ArcSplit::Challenge => 1172,
            ArcSplit::Easy => 2376,
        }
    }
}

/// One four-option question: an embedded "stem" feature vector plus the
/// gold label.  `margin` encodes how decisively a competent model should
/// separate the gold option from the runner-up (small margin ⇒ the
/// question sits near the model's decision boundary).
#[derive(Debug, Clone)]
pub struct ArcQuestion {
    pub id: usize,
    /// Stem feature vector (activation input to the scoring head).
    pub features: Vec<f32>,
    pub label: usize,
    /// Decision margin in score units; near-zero margins flip easily.
    pub margin: f32,
}

#[derive(Debug, Clone)]
pub struct ArcDataset {
    pub split: ArcSplit,
    pub questions: Vec<ArcQuestion>,
}

impl ArcDataset {
    /// Build the split for a given model: per-model difficulty is encoded
    /// in the margin distribution so that the *baseline* accuracy matches
    /// the paper's Table I/II baseline for that model (the generator is
    /// calibrated against `eval::accuracy`'s scoring rule).
    ///
    /// `feature_dim` is the scoring head's K (multiple of 64).
    pub fn generate(split: ArcSplit, model_name: &str, feature_dim: usize) -> ArcDataset {
        let n = split.size();
        let seed = hash64(model_name) ^ hash64(split.label());
        let mut rng = Rng::new(seed);
        let target = baseline_target(split, model_name);
        let mut questions = Vec::with_capacity(n);
        for id in 0..n {
            let mut r = rng.fork(id as u64);
            let features = r.normal_vec_f32(feature_dim, 1.0);
            let label = r.below(4) as usize;
            // A fraction `target` of questions get a clearly positive
            // margin; the rest get a negative one (model prefers a wrong
            // option).  Margins are concentrated near zero so a sliver of
            // questions sits within fp16-rounding distance of flipping.
            let correct = r.chance(target);
            let magnitude = (r.f64().powf(1.5) * 0.12 + 0.0004) as f32;
            let margin = if correct { magnitude } else { -magnitude };
            questions.push(ArcQuestion { id, features, label, margin });
        }
        ArcDataset { split, questions }
    }
}

/// Paper Table I/II baseline accuracies (fractions) per model and split.
pub fn baseline_target(split: ArcSplit, model_name: &str) -> f64 {
    let table: &[(&str, f64, f64)] = &[
        // (model, ARC_C, ARC_E) — Tables I and II, "Baseline" column.
        ("Meta-Llama-3-8B-GPTQ", 0.7525, 0.8730),
        ("Llama-2-7B-GPTQ", 0.3559, 0.4780),
        ("CodeLlama-7B-GPTQ", 0.2781, 0.2751),
        ("LLaMa-13B-GPTQ", 0.3932, 0.5079),
        ("Qwen1.5-1.8B-Chat-GPTQ-Int4", 0.4881, 0.6949),
        ("Qwen1.5-4B-Chat-GPTQ-Int4", 0.5627, 0.7019),
    ];
    for (name, c, e) in table {
        if *name == model_name {
            return match split {
                ArcSplit::Challenge => *c,
                ArcSplit::Easy => *e,
            };
        }
    }
    0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_sizes_match_arc() {
        assert_eq!(ArcSplit::Challenge.size(), 1172);
        assert_eq!(ArcSplit::Easy.size(), 2376);
    }

    #[test]
    fn deterministic_per_model() {
        let a = ArcDataset::generate(ArcSplit::Challenge, "Llama-2-7B-GPTQ", 64);
        let b = ArcDataset::generate(ArcSplit::Challenge, "Llama-2-7B-GPTQ", 64);
        assert_eq!(a.questions.len(), b.questions.len());
        assert_eq!(a.questions[10].label, b.questions[10].label);
        assert_eq!(a.questions[10].features, b.questions[10].features);
    }

    #[test]
    fn different_models_get_different_questions() {
        let a = ArcDataset::generate(ArcSplit::Easy, "Llama-2-7B-GPTQ", 64);
        let b = ArcDataset::generate(ArcSplit::Easy, "CodeLlama-7B-GPTQ", 64);
        assert_ne!(a.questions[0].features, b.questions[0].features);
    }

    #[test]
    fn margin_sign_rate_tracks_target() {
        let d = ArcDataset::generate(ArcSplit::Easy, "Meta-Llama-3-8B-GPTQ", 64);
        let positive = d.questions.iter().filter(|q| q.margin > 0.0).count();
        let rate = positive as f64 / d.questions.len() as f64;
        let target = baseline_target(ArcSplit::Easy, "Meta-Llama-3-8B-GPTQ");
        assert!((rate - target).abs() < 0.03, "rate {rate} vs target {target}");
    }

    #[test]
    fn some_questions_sit_near_the_boundary() {
        let d = ArcDataset::generate(ArcSplit::Challenge, "LLaMa-13B-GPTQ", 64);
        let near = d.questions.iter().filter(|q| q.margin.abs() < 0.002).count();
        assert!(near > 0, "need near-boundary questions for fp16 flips");
        assert!(near < d.questions.len() / 10);
    }

    #[test]
    fn labels_are_valid_options() {
        let d = ArcDataset::generate(ArcSplit::Easy, "Qwen1.5-4B-Chat-GPTQ-Int4", 64);
        assert!(d.questions.iter().all(|q| q.label < 4));
    }
}

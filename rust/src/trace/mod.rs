//! Synthetic workloads standing in for the paper's datasets.
//!
//! * [`sharegpt`] — request traces with ShareGPT-like prompt/response
//!   length distributions (throughput/latency evaluation, Figures 2–3);
//! * [`arc`] — ARC-style multiple-choice question sets (accuracy
//!   evaluation, Tables I–II).
//!
//! Both are deterministic in their seeds; DESIGN.md documents them as the
//! substitutions for `ShareGPT_V3_unfiltered_cleaned_split` and ARC_C/E.

pub mod arc;
pub mod sharegpt;

pub use arc::{ArcDataset, ArcQuestion, ArcSplit};
pub use sharegpt::{RequestTrace, TraceConfig, TraceRequest};

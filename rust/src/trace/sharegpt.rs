//! ShareGPT-like request trace generator.
//!
//! The published ShareGPT_V3 length statistics are roughly log-normal:
//! prompts with a median around ~35 tokens and a heavy tail into the
//! hundreds, responses with a median around ~150 tokens and tails past
//! 1k.  The vLLM benchmark (and the paper's §IV-B setup) samples prompts
//! from that distribution and generates until each response completes;
//! the throughput number is total generated tokens over wall time for a
//! 32-prompt batch.

use crate::rng::Rng;

/// One serving request of the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    pub id: usize,
    /// Prompt token ids (synthetic, uniform over the tokenizer range).
    pub prompt: Vec<u32>,
    /// Number of tokens the "conversation" answer has — the generation
    /// length the serving engine must produce.
    pub response_len: usize,
    /// Virtual arrival time, seconds since trace start.  `generate`
    /// emits 0.0 (batch workload); [`RequestTrace::with_arrivals`]
    /// stamps Poisson arrivals for trace-driven replay.
    pub arrival: f64,
}

/// A deterministic batch of requests.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub requests: Vec<TraceRequest>,
    pub seed: u64,
}

/// Length-distribution parameters (log-normal, clamped).
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub response_mu: f64,
    pub response_sigma: f64,
    pub response_min: usize,
    pub response_max: usize,
    pub vocab: u32,
}

impl Default for TraceConfig {
    fn default() -> Self {
        // Medians: e^3.6 ≈ 36 prompt tokens, e^5.0 ≈ 148 response tokens.
        TraceConfig {
            prompt_mu: 3.6,
            prompt_sigma: 0.9,
            prompt_min: 4,
            prompt_max: 1024,
            response_mu: 5.0,
            response_sigma: 0.7,
            response_min: 8,
            response_max: 1024,
            vocab: 32000,
        }
    }
}

impl RequestTrace {
    /// Generate `n` requests with ShareGPT-like lengths.
    pub fn generate(n: usize, seed: u64) -> RequestTrace {
        Self::generate_with(n, seed, TraceConfig::default())
    }

    pub fn generate_with(n: usize, seed: u64, cfg: TraceConfig) -> RequestTrace {
        let mut rng = Rng::new(seed);
        let mut requests = Vec::with_capacity(n);
        for id in 0..n {
            let mut r = rng.fork(id as u64);
            let plen = (r.lognormal(cfg.prompt_mu, cfg.prompt_sigma) as usize)
                .clamp(cfg.prompt_min, cfg.prompt_max);
            let rlen = (r.lognormal(cfg.response_mu, cfg.response_sigma) as usize)
                .clamp(cfg.response_min, cfg.response_max);
            let prompt = (0..plen).map(|_| r.next_u32() % cfg.vocab).collect();
            requests.push(TraceRequest { id, prompt, response_len: rlen, arrival: 0.0 });
        }
        RequestTrace { requests, seed }
    }

    /// Stamp Poisson arrivals at `rate` requests/second (exponential
    /// inter-arrival gaps), deterministically in `seed`.  Arrivals are
    /// non-decreasing and independent of the length sampling, so the
    /// same trace can be replayed open-loop at different loads.
    pub fn with_arrivals(mut self, rate: f64, seed: u64) -> RequestTrace {
        assert!(rate > 0.0, "arrival rate must be positive");
        let mut rng = Rng::new(seed ^ 0xa441_7a1e_5eed_0001);
        let mut t = 0.0;
        for r in &mut self.requests {
            // Inverse-CDF exponential; (1 - f64()) keeps ln's argument
            // in (0, 1].
            t += -(1.0 - rng.f64()).ln() / rate;
            r.arrival = t;
        }
        self
    }

    pub fn total_prompt_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.prompt.len()).sum()
    }

    pub fn total_response_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.response_len).sum()
    }

    /// Mean context length while decoding (used by the perf model for the
    /// attention-bandwidth term): prompt + half the response, averaged.
    pub fn mean_decode_context(&self) -> f64 {
        let s: f64 = self
            .requests
            .iter()
            .map(|r| r.prompt.len() as f64 + r.response_len as f64 / 2.0)
            .sum();
        s / self.requests.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = RequestTrace::generate(32, 7);
        let b = RequestTrace::generate(32, 7);
        assert_eq!(a.requests, b.requests);
        let c = RequestTrace::generate(32, 8);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn lengths_within_bounds() {
        let cfg = TraceConfig::default();
        let t = RequestTrace::generate(500, 1);
        for r in &t.requests {
            assert!((cfg.prompt_min..=cfg.prompt_max).contains(&r.prompt.len()));
            assert!((cfg.response_min..=cfg.response_max).contains(&r.response_len));
        }
    }

    #[test]
    fn medians_look_sharegpt_like() {
        let t = RequestTrace::generate(2000, 2);
        let mut plens: Vec<usize> = t.requests.iter().map(|r| r.prompt.len()).collect();
        let mut rlens: Vec<usize> = t.requests.iter().map(|r| r.response_len).collect();
        plens.sort_unstable();
        rlens.sort_unstable();
        let pmed = plens[plens.len() / 2];
        let rmed = rlens[rlens.len() / 2];
        assert!((20..=60).contains(&pmed), "prompt median {pmed}");
        assert!((100..=220).contains(&rmed), "response median {rmed}");
        // heavy tail: p95 >> median
        assert!(plens[plens.len() * 95 / 100] > 3 * pmed);
    }

    #[test]
    fn responses_longer_than_prompts_on_average() {
        let t = RequestTrace::generate(1000, 3);
        assert!(t.total_response_tokens() > t.total_prompt_tokens());
    }

    #[test]
    fn per_request_fork_is_order_independent() {
        // Request #5 must be identical whether we generate 10 or 100.
        let a = RequestTrace::generate(10, 9);
        let b = RequestTrace::generate(100, 9);
        assert_eq!(a.requests[5], b.requests[5]);
    }

    #[test]
    fn golden_stats_generate() {
        // Pinned per seed: any change to the RNG, the fork scheme, or
        // the length sampling shows up here before it silently shifts
        // every serving benchmark built on these traces.
        let t = RequestTrace::generate(100, 42);
        assert_eq!(t.total_prompt_tokens(), 5304);
        assert_eq!(t.total_response_tokens(), 17715);
        assert!((t.mean_decode_context() - 141.615).abs() < 1e-9);
    }

    #[test]
    fn golden_stats_generate_with_clamped_config() {
        // The serve-path configuration (short prompts and responses).
        let cfg = TraceConfig { prompt_max: 48, response_max: 32, ..Default::default() };
        let t = RequestTrace::generate_with(64, 7, cfg);
        assert_eq!(t.total_prompt_tokens(), 2014);
        assert_eq!(t.total_response_tokens(), 2048);
        assert!((t.mean_decode_context() - 47.46875).abs() < 1e-9);
    }

    #[test]
    fn arrivals_are_monotone_deterministic_and_rate_scaled() {
        let t = RequestTrace::generate(64, 7).with_arrivals(20.0, 11);
        let arr: Vec<f64> = t.requests.iter().map(|r| r.arrival).collect();
        assert!(arr[0] > 0.0);
        for w in arr.windows(2) {
            assert!(w[0] <= w[1], "arrivals must be non-decreasing");
        }
        // Deterministic in (trace, rate, seed)...
        let t2 = RequestTrace::generate(64, 7).with_arrivals(20.0, 11);
        assert_eq!(t.requests, t2.requests);
        // ...independent of the length sampling (same arrival seed,
        // different trace seed → same stamps)...
        let t3 = RequestTrace::generate(64, 3).with_arrivals(20.0, 11);
        assert_eq!(t3.requests[63].arrival, arr[63]);
        // ...and pinned golden: 64 arrivals at 20 req/s span ~3.2 s.
        assert!((arr[0] - 0.018447980744852613).abs() < 1e-9);
        assert!((arr[63] - 3.0056598433548283).abs() < 1e-9);
        // Doubling the rate halves every gap exactly (same exp draws).
        let fast = RequestTrace::generate(64, 7).with_arrivals(40.0, 11);
        assert!((fast.requests[63].arrival - arr[63] / 2.0).abs() < 1e-9);
    }
}

//! ShareGPT-like request trace generator.
//!
//! The published ShareGPT_V3 length statistics are roughly log-normal:
//! prompts with a median around ~35 tokens and a heavy tail into the
//! hundreds, responses with a median around ~150 tokens and tails past
//! 1k.  The vLLM benchmark (and the paper's §IV-B setup) samples prompts
//! from that distribution and generates until each response completes;
//! the throughput number is total generated tokens over wall time for a
//! 32-prompt batch.

use crate::rng::Rng;

/// One serving request of the trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRequest {
    pub id: usize,
    /// Prompt token ids (synthetic, uniform over the tokenizer range).
    pub prompt: Vec<u32>,
    /// Number of tokens the "conversation" answer has — the generation
    /// length the serving engine must produce.
    pub response_len: usize,
}

/// A deterministic batch of requests.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub requests: Vec<TraceRequest>,
    pub seed: u64,
}

/// Length-distribution parameters (log-normal, clamped).
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub response_mu: f64,
    pub response_sigma: f64,
    pub response_min: usize,
    pub response_max: usize,
    pub vocab: u32,
}

impl Default for TraceConfig {
    fn default() -> Self {
        // Medians: e^3.6 ≈ 36 prompt tokens, e^5.0 ≈ 148 response tokens.
        TraceConfig {
            prompt_mu: 3.6,
            prompt_sigma: 0.9,
            prompt_min: 4,
            prompt_max: 1024,
            response_mu: 5.0,
            response_sigma: 0.7,
            response_min: 8,
            response_max: 1024,
            vocab: 32000,
        }
    }
}

impl RequestTrace {
    /// Generate `n` requests with ShareGPT-like lengths.
    pub fn generate(n: usize, seed: u64) -> RequestTrace {
        Self::generate_with(n, seed, TraceConfig::default())
    }

    pub fn generate_with(n: usize, seed: u64, cfg: TraceConfig) -> RequestTrace {
        let mut rng = Rng::new(seed);
        let mut requests = Vec::with_capacity(n);
        for id in 0..n {
            let mut r = rng.fork(id as u64);
            let plen = (r.lognormal(cfg.prompt_mu, cfg.prompt_sigma) as usize)
                .clamp(cfg.prompt_min, cfg.prompt_max);
            let rlen = (r.lognormal(cfg.response_mu, cfg.response_sigma) as usize)
                .clamp(cfg.response_min, cfg.response_max);
            let prompt = (0..plen).map(|_| r.next_u32() % cfg.vocab).collect();
            requests.push(TraceRequest { id, prompt, response_len: rlen });
        }
        RequestTrace { requests, seed }
    }

    pub fn total_prompt_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.prompt.len()).sum()
    }

    pub fn total_response_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.response_len).sum()
    }

    /// Mean context length while decoding (used by the perf model for the
    /// attention-bandwidth term): prompt + half the response, averaged.
    pub fn mean_decode_context(&self) -> f64 {
        let s: f64 = self
            .requests
            .iter()
            .map(|r| r.prompt.len() as f64 + r.response_len as f64 / 2.0)
            .sum();
        s / self.requests.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = RequestTrace::generate(32, 7);
        let b = RequestTrace::generate(32, 7);
        assert_eq!(a.requests, b.requests);
        let c = RequestTrace::generate(32, 8);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn lengths_within_bounds() {
        let cfg = TraceConfig::default();
        let t = RequestTrace::generate(500, 1);
        for r in &t.requests {
            assert!((cfg.prompt_min..=cfg.prompt_max).contains(&r.prompt.len()));
            assert!((cfg.response_min..=cfg.response_max).contains(&r.response_len));
        }
    }

    #[test]
    fn medians_look_sharegpt_like() {
        let t = RequestTrace::generate(2000, 2);
        let mut plens: Vec<usize> = t.requests.iter().map(|r| r.prompt.len()).collect();
        let mut rlens: Vec<usize> = t.requests.iter().map(|r| r.response_len).collect();
        plens.sort_unstable();
        rlens.sort_unstable();
        let pmed = plens[plens.len() / 2];
        let rmed = rlens[rlens.len() / 2];
        assert!((20..=60).contains(&pmed), "prompt median {pmed}");
        assert!((100..=220).contains(&rmed), "response median {rmed}");
        // heavy tail: p95 >> median
        assert!(plens[plens.len() * 95 / 100] > 3 * pmed);
    }

    #[test]
    fn responses_longer_than_prompts_on_average() {
        let t = RequestTrace::generate(1000, 3);
        assert!(t.total_response_tokens() > t.total_prompt_tokens());
    }

    #[test]
    fn per_request_fork_is_order_independent() {
        // Request #5 must be identical whether we generate 10 or 100.
        let a = RequestTrace::generate(10, 9);
        let b = RequestTrace::generate(100, 9);
        assert_eq!(a.requests[5], b.requests[5]);
    }
}

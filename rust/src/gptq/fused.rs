//! Fused dequantize-GEMM/GEMV on the CPU: unpack nibbles on the fly per
//! tile, never materialize the dense weight matrix.
//!
//! The reference oracle ([`super::gemm::gemv_f32`]) calls
//! [`super::pack::unpack_rows`] on *every* invocation — a `K×N` byte
//! allocation plus a full extra pass over the weights before any math
//! happens.  This module is the executable analogue of the paper's kernel
//! structure (it is what [`crate::engine::cpu_backend::CpuBackend`] serves
//! real tokens through), with the paper's three platform-level strategies
//! mapped onto their CPU embodiments:
//!
//! * **Runtime kernel dispatch.**  Every fused call runs through one
//!   kernel of the [`simd::kernel_registry`] — portable scalar, 8-lane
//!   AVX2+FMA, or 16-lane AVX-512F/BW — selected once per process by
//!   [`simd::KernelDispatch`] (the CPU analogue of the paper's
//!   per-platform kernel binding): auto-detection picks the widest
//!   kernel the host runs, `OPT4GPTQ_KERNEL=scalar|avx2|avx512` forces
//!   a path for testing.  All kernels share the identical tile geometry
//!   and group-factored math; the scalar loop is untouched by dispatch —
//!   its results stay bit-identical to previous releases.
//!
//! * **Tile geometry (SMB-Opt).**  The K axis is walked in *group slabs*
//!   (one quantization group, `group_size` rows — the dequant parameters
//!   are constant across a slab, mirroring how the DCU kernel's
//!   `K_SLAB = 128` stays within one group; see `dcusim::kernels::gemv`).
//!   The N axis is blocked so the per-tile accumulator state plus the
//!   activation slab stays L1-resident — the scalar path keeps an
//!   `M_BLOCK × N_tile` partial-dot buffer and unpacked zero row
//!   ([`col_block`] budgets all three); the SIMD path keeps a stack
//!   scratch flush tile and holds the running sums in vector registers.
//!   M is blocked by [`M_BLOCK`]` = 8`, matching the simulator's
//!   `M_COUNT_MAX` (rows of a block share one pass over the weights).
//!
//! * **Vector loads (VML-Opt).**  Each packed `u32` word holds 8 nibbles
//!   (8 K-rows of one column).  The scalar loop accumulates them as four
//!   explicitly paired products — the half2-analogue of the paper's
//!   inner loop, which gives the autovectorizer independent chains.  The
//!   SIMD kernels instead load eight (AVX2) or sixteen (AVX-512)
//!   *columns'* words with one 256/512-bit load — aligned when the
//!   tensor is prepacked into the column-interleaved
//!   [`super::pack::SwizzledWeights`] swizzle at the kernel's lane
//!   width (built once per [`PreparedTensor`], so serve-path
//!   projections never re-swizzle) — and unpack 8 or 16 lanes at a time
//!   with shift/mask.
//!
//! * **Vector FMA (ILA-Opt).**  Within a group, `Σ x·s·(c − z)` is
//!   computed as `s·(Σ x·c − z·Σ x)`: the scale multiply and zero
//!   subtract are hoisted out of the K loop entirely (one flush per
//!   group per column), so the hot loop is shift/mask/convert/fma only —
//!   `vfmadd231ps` on the SIMD path, with the flush kept in vector
//!   registers.
//!
//! * **Act-order.**  `b_q_perm` checkpoints gather the activations once
//!   per panel (`xg[k] = x[perm[k]]`, the load pattern Algorithm 2
//!   branches on), after which both kernels are permutation-oblivious.
//!
//! * **Column-split parallelism.**  Large shapes are N-partitioned over
//!   scoped threads (rayon-style work stealing is unavailable offline):
//!   each worker owns a nibble-aligned column slab and runs the
//!   dispatched kernel over it, so the parallel path is **bit-identical**
//!   to the serial one (per-column accumulation order is unchanged — K is
//!   never split).  [`fused_threads`] gates the split: small shapes (the
//!   tiny CpuBackend model, unit-test sizes) stay on the spawn-free
//!   serial path.  The hardware width is resolved once per process
//!   (`available_parallelism` is a syscall; `OPT4GPTQ_THREADS`
//!   overrides).  `gemv` slabs are contiguous output chunks (zero-copy
//!   via `split_at_mut`); `gemm` workers fill thread-local `[M, slab]`
//!   tiles merged after the join.
//!
//! The public surface is **two entry points** — [`gemv_fused_opt`] /
//! [`gemm_fused_opt`], one per rank, taking a [`FusedInput`] (`Raw`
//! storage-layout tensor or `Prepared` prepack) and [`FusedOpts`]
//! kernel/thread overrides (`None` = the process-wide defaults) — plus
//! the two hot serve-path names [`gemv_fused_prepared`] /
//! [`gemm_fused_prepared`] kept as `#[inline]` wrappers.  The former
//! 10-way `{gemv,gemm}_fused{,_threads,_with,_prepared,_prepared_threads}`
//! combinatorial surface is gone.
//!
//! Parity with the oracle across shapes, groups, batch sizes, act-order
//! and **every dispatchable kernel** is pinned by `rust/tests/parity.rs`;
//! speed is measured by `rust/benches/fused_gemm.rs` (≥10× over the
//! oracle on the 4096×4096 decode shape, parallel ≥ serial, and SIMD ≥
//! scalar on the same shape).

use std::sync::OnceLock;

use super::pack::{swizzle_weights_width, SwizzledWeights, NIBBLES_PER_WORD};
use super::quantize::QuantizedTensor;
use super::simd::{self, Kernel};
use super::Matrix;

/// Rows of the activation matrix processed per pass over the packed
/// weights (mirrors `dcusim::kernels::gemv::M_COUNT_MAX`).
pub const M_BLOCK: usize = 8;

/// Column-block size for the scalar kernel: keep the `mb`-row accumulator
/// tile, the zero row, *and* the `mb × group` activation slab within
/// ~16 KiB so the per-tile working set is L1-resident (the slab was
/// unaccounted before, letting large-M prefill tiles spill).
fn col_block(n: usize, mb: usize, g: usize) -> usize {
    let floats = 16 * 1024 / 4;
    let budget = floats.saturating_sub(mb * g) / (mb + 1);
    let nb = budget.max(64) & !7; // multiple of the nibble width
    nb.min(n)
}

/// One fused panel invocation's resolved operands: the packed tensor,
/// the kernel the dispatcher chose for it, and (when prepacked) the
/// swizzled weight view the SIMD path streams from.
#[derive(Clone, Copy)]
pub(crate) struct KernelCall<'a> {
    pub(crate) q: &'a QuantizedTensor,
    /// Only the x86-64 SIMD kernel reads the swizzle; other targets
    /// carry it dead (the scalar loop streams the storage layout).
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    pub(crate) swz: Option<&'a SwizzledWeights>,
    pub(crate) kernel: Kernel,
}

/// The single weight layout a [`PreparedTensor`] holds — exactly one
/// copy of the packed words, in whichever order the active kernel
/// streams them.
enum WeightLayout {
    /// Storage-layout `qweight` served as-is (scalar hosts).
    Raw,
    /// Column-interleaved prepack for aligned vector loads, at the
    /// active kernel's lane width (8 on AVX2 hosts, 16 on AVX-512
    /// hosts).  The tensor's `qweight` is **dropped** — the swizzle is
    /// the only weight copy, halving packed-weight residency on serve
    /// hosts; raw-layout consumers rebuild it through
    /// [`PreparedTensor::to_raw`].
    Swizzled(SwizzledWeights),
}

/// A [`QuantizedTensor`] held in the **single** layout the active kernel
/// wants, converted **once** at construction (model build time in
/// `CpuBackend`) so serve-path projections never re-swizzle.  On scalar
/// hosts the tensor is served as-is; on SIMD hosts the packed words live
/// only in the swizzled order, at the lane width the resolved dispatch
/// streams (8 for AVX2, 16 for AVX-512 — `Kernel::swizzle_width`); the
/// duplicate `qweight` copy previous releases kept alongside it is gone
/// (~0.5 byte/weight saved, i.e. packed-weight residency halves).
/// Scales, zeros and the act-order permutation are layout-independent
/// and kept verbatim.
///
/// Raw-layout consumers (the `gptq::gemm` oracle, checkpoint writers)
/// use the explicit accessor [`Self::to_raw`], which un-swizzles on
/// demand — a cold path by construction.
pub struct PreparedTensor {
    /// `qweight` is empty when `layout` is [`WeightLayout::Swizzled`];
    /// all other fields are always valid.
    q: QuantizedTensor,
    layout: WeightLayout,
}

impl PreparedTensor {
    pub fn new(mut q: QuantizedTensor) -> PreparedTensor {
        let layout = match simd::active_kernel().swizzle_width() {
            Some(width) => {
                let swz = swizzle_weights_width(&q.qweight, q.k / NIBBLES_PER_WORD, q.n, width);
                // Single-layout invariant: the swizzle replaces the
                // storage copy instead of shadowing it.
                q.qweight = Vec::new();
                WeightLayout::Swizzled(swz)
            }
            None => WeightLayout::Raw,
        };
        PreparedTensor { q, layout }
    }

    /// Rebuild the complete storage-layout [`QuantizedTensor`] (the
    /// oracle/checkpoint interchange format).  Cheap clone on scalar
    /// hosts; an un-swizzle pass on AVX2 hosts.
    pub fn to_raw(&self) -> QuantizedTensor {
        let mut q = self.q.clone();
        if let WeightLayout::Swizzled(swz) = &self.layout {
            q.qweight = super::pack::unswizzle_weights(swz);
        }
        q
    }

    /// In-features of the packed tensor.
    pub fn k(&self) -> usize {
        self.q.k
    }

    /// Out-features of the packed tensor.
    pub fn n(&self) -> usize {
        self.q.n
    }

    /// The act-order permutation (`b_q_perm`), if this is a `desc_act`
    /// checkpoint.
    pub fn perm(&self) -> Option<&[usize]> {
        self.q.perm.as_deref()
    }

    /// Bytes resident for the packed representation (weights in their
    /// single layout + scales + zeros).
    pub fn packed_bytes(&self) -> usize {
        let weight_words = match &self.layout {
            WeightLayout::Raw => self.q.qweight.len(),
            WeightLayout::Swizzled(swz) => swz.kw() * swz.n(),
        };
        (weight_words + self.q.qzeros.len()) * 4 + self.q.scales.len() * 4
    }

    /// Whether the single held layout is the vector-friendly swizzle
    /// (i.e. the active kernel streams aligned 256- or 512-bit loads).
    pub fn is_swizzled(&self) -> bool {
        matches!(self.layout, WeightLayout::Swizzled(_))
    }

    fn call(&self) -> KernelCall<'_> {
        let swz = match &self.layout {
            WeightLayout::Raw => None,
            WeightLayout::Swizzled(s) => Some(s),
        };
        KernelCall { q: &self.q, swz, kernel: simd::active_kernel() }
    }
}

/// Worker count the auto-dispatched entry points use for an
/// `mb × K × N` call: an N-partitioned column split, engaged only when
/// every worker gets a meaningful slab (1 = stay serial).
pub fn fused_threads(mb: usize, k: usize, n: usize) -> usize {
    /// Per-worker column-slab floor: below this the spawn overhead and
    /// shared-activation traffic beat the win.
    const MIN_COLS: usize = 512;
    /// Fused MAC floor: tiny calls (the tiny-model projections, unit
    /// tests) never leave the serial path.
    const MIN_WORK: usize = 1 << 21;
    if n % NIBBLES_PER_WORD != 0 || mb.saturating_mul(k).saturating_mul(n) < MIN_WORK {
        return 1;
    }
    hw_threads().min(n / MIN_COLS).max(1)
}

/// Hardware worker-pool width, resolved **once** per process:
/// `available_parallelism` is a syscall, and it used to run once per
/// projection per token on the decode path.  `OPT4GPTQ_THREADS` (≥ 1)
/// overrides detection for benchmarking; invalid values fall back.
/// `pub(crate)` so the engine's batch-parallel attention walk shares
/// the same resolution (one worker-pool width per process).
pub(crate) fn hw_threads() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| {
        std::env::var("OPT4GPTQ_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1))
    })
}

/// Per-call options for the collapsed fused entry points
/// ([`gemv_fused_opt`] / [`gemm_fused_opt`]): each `None` axis means
/// "the process-wide default" — the dispatched kernel and the
/// [`fused_threads`] auto split.  Results are bit-identical across
/// thread counts by construction, and kernel-equivalent only to oracle
/// tolerance.
#[derive(Clone, Copy, Default)]
pub struct FusedOpts {
    /// Kernel override (parity tests, benches, the CI forced-kernel
    /// matrix).  Panics if the host cannot run it, or if the input is a
    /// [`FusedInput::Prepared`] tensor prepacked for a different
    /// kernel.
    pub kernel: Option<Kernel>,
    /// Worker count for the column split (`Some(1)` = serial).
    pub threads: Option<usize>,
}

/// The weight operand of a collapsed fused call.
#[derive(Clone, Copy)]
pub enum FusedInput<'a> {
    /// A storage-layout [`QuantizedTensor`], streamed as-is (no aligned
    /// prepack — the oracle-interchange format).
    Raw(&'a QuantizedTensor),
    /// A [`PreparedTensor`] in the single layout the dispatched kernel
    /// wants — the serve path.
    Prepared(&'a PreparedTensor),
}

impl<'a> FusedInput<'a> {
    /// Resolve the operand + `opts` into one kernel invocation.
    fn resolve(&self, opts: FusedOpts) -> KernelCall<'a> {
        match *self {
            FusedInput::Raw(q) => {
                let kernel = opts.kernel.unwrap_or_else(simd::active_kernel);
                assert!(
                    simd::supports(kernel),
                    "kernel '{kernel}' is not available on this host"
                );
                KernelCall { q, swz: None, kernel }
            }
            FusedInput::Prepared(p) => {
                if let Some(kernel) = opts.kernel {
                    assert_eq!(
                        kernel,
                        simd::active_kernel(),
                        "a PreparedTensor is prepacked for the dispatched kernel; \
                         force other kernels through FusedInput::Raw"
                    );
                }
                p.call()
            }
        }
    }

    /// `(K, N)` of the packed operand.
    fn dims(&self) -> (usize, usize) {
        match *self {
            FusedInput::Raw(q) => (q.k, q.n),
            FusedInput::Prepared(p) => (p.q.k, p.q.n),
        }
    }
}

/// `y[N] = x[K] · deq(Q)[K, N]` — fused single-row (decode) GEMV.  The
/// one GEMV entry point: operand layout via [`FusedInput`], kernel and
/// worker count via [`FusedOpts`] (default = dispatched kernel, auto
/// column split).
pub fn gemv_fused_opt(x: &[f32], input: FusedInput<'_>, opts: FusedOpts) -> Vec<f32> {
    let call = input.resolve(opts);
    let (k, n) = input.dims();
    let threads = opts.threads.unwrap_or_else(|| fused_threads(1, k, n));
    gemv_run(x, &call, threads)
}

/// Hot legacy name: [`gemv_fused_opt`] over a [`PreparedTensor`] with
/// default options — the serve-path decode projection.
#[inline]
pub fn gemv_fused_prepared(x: &[f32], p: &PreparedTensor) -> Vec<f32> {
    gemv_fused_opt(x, FusedInput::Prepared(p), FusedOpts::default())
}

fn gemv_run(x: &[f32], call: &KernelCall<'_>, threads: usize) -> Vec<f32> {
    let q = call.q;
    assert_eq!(x.len(), q.k);
    let mut y = vec![0.0f32; q.n];
    let gathered;
    let xg: &[f32] = match &q.perm {
        None => x,
        Some(p) => {
            // Act-order gather (Algorithm 2's b_q_perm branch).
            gathered = p.iter().map(|&src| x[src]).collect::<Vec<f32>>();
            &gathered
        }
    };
    let xsum = activation_group_sums(xg, 1, q.k, q.group_size);
    run_col_split(xg, &xsum, 1, call, threads, &mut y);
    y
}

/// `Y[M, N] = X[M, K] · deq(Q)` — fused batched (prefill) GEMM; the one
/// GEMM entry point (see [`gemv_fused_opt`]).
pub fn gemm_fused_opt(x: &Matrix, input: FusedInput<'_>, opts: FusedOpts) -> Matrix {
    let call = input.resolve(opts);
    let (k, n) = input.dims();
    let threads = opts.threads.unwrap_or_else(|| fused_threads(x.rows, k, n));
    gemm_run(x, &call, threads)
}

/// Hot legacy name: [`gemm_fused_opt`] over a [`PreparedTensor`] with
/// default options — every `CpuBackend` projection runs through here.
#[inline]
pub fn gemm_fused_prepared(x: &Matrix, p: &PreparedTensor) -> Matrix {
    gemm_fused_opt(x, FusedInput::Prepared(p), FusedOpts::default())
}

fn gemm_run(x: &Matrix, call: &KernelCall<'_>, threads: usize) -> Matrix {
    let q = call.q;
    assert_eq!(x.cols, q.k);
    let (k, n) = (q.k, q.n);
    let mut out = Matrix::zeros(x.rows, n);
    let mut gather: Vec<f32> = Vec::new();
    let mut m0 = 0;
    while m0 < x.rows {
        let mb = M_BLOCK.min(x.rows - m0);
        let xs = &x.data[m0 * k..(m0 + mb) * k];
        let ys = &mut out.data[m0 * n..(m0 + mb) * n];
        let xg: &[f32] = match &q.perm {
            None => xs,
            Some(p) => {
                gather.clear();
                gather.reserve(mb * k);
                for mi in 0..mb {
                    let row = &xs[mi * k..(mi + 1) * k];
                    gather.extend(p.iter().map(|&src| row[src]));
                }
                &gather
            }
        };
        let xsum = activation_group_sums(xg, mb, k, q.group_size);
        run_col_split(xg, &xsum, mb, call, threads, ys);
        m0 += mb;
    }
    out
}

/// Per-(row, group) activation sums for the zero-point term, `[mb, K/g]`.
fn activation_group_sums(xg: &[f32], mb: usize, k: usize, g: usize) -> Vec<f32> {
    debug_assert_eq!(xg.len(), mb * k);
    let groups = k / g;
    let mut xsum = vec![0.0f32; mb * groups];
    for mi in 0..mb {
        for gi in 0..groups {
            xsum[mi * groups + gi] = xg[mi * k + gi * g..mi * k + (gi + 1) * g].iter().sum();
        }
    }
    xsum
}

/// Run the dispatched kernel over one column window.
fn panel_any(
    call: &KernelCall<'_>,
    xg: &[f32],
    xsum: &[f32],
    mb: usize,
    c0: usize,
    cn: usize,
    out: &mut [f32],
) {
    match call.kernel {
        Kernel::Scalar => fused_panel_cols(xg, xsum, mb, call.q, c0, cn, out),
        Kernel::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                simd::panel_avx2(call, xg, xsum, mb, c0, cn, out)
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                // Unreachable through public entry points (`supports`
                // rejects Avx2 off x86-64); degrade gracefully anyway.
                fused_panel_cols(xg, xsum, mb, call.q, c0, cn, out)
            }
        }
        Kernel::Avx512 => {
            #[cfg(all(target_arch = "x86_64", opt4gptq_avx512_intrinsics))]
            {
                simd::panel_avx512(call, xg, xsum, mb, c0, cn, out)
            }
            #[cfg(not(all(target_arch = "x86_64", opt4gptq_avx512_intrinsics)))]
            {
                // Unreachable through public entry points (`supports`
                // rejects Avx512 off x86-64 and on toolchains that
                // compile the kernel out); degrade gracefully anyway.
                fused_panel_cols(xg, xsum, mb, call.q, c0, cn, out)
            }
        }
    }
}

/// N-partitioned dispatch over one gathered M-block: split the column
/// axis into nibble-aligned slabs, one scoped thread per slab (serial
/// when `threads <= 1`).  `out` is `[mb, N]` row-major, zeroed.
fn run_col_split(
    xg: &[f32],
    xsum: &[f32],
    mb: usize,
    call: &KernelCall<'_>,
    threads: usize,
    out: &mut [f32],
) {
    let n = call.q.n;
    // Slabs are aligned to the dispatched kernel's column granularity
    // (the packed nibble width for scalar/AVX2, a full hexadectet for
    // AVX-512) so every worker's window keeps the kernel's load
    // alignment — split points never change per-column accumulation
    // order, so the result stays bit-identical to serial.
    let align = call.kernel.col_align();
    let threads = if n % NIBBLES_PER_WORD == 0 { threads.min(n / align) } else { 1 };
    if threads <= 1 {
        panel_any(call, xg, xsum, mb, 0, n, out);
        return;
    }
    // Slab bounds, aligned down to the kernel granularity; the last
    // bound absorbs the remainder.
    let mut bounds = Vec::with_capacity(threads + 1);
    for t in 0..=threads {
        bounds.push((n * t / threads) / align * align);
    }
    bounds[threads] = n;
    if mb == 1 {
        // GEMV: one output row — column slabs are contiguous chunks.
        std::thread::scope(|s| {
            let mut rest: &mut [f32] = out;
            for t in 0..threads {
                let (c0, c1) = (bounds[t], bounds[t + 1]);
                if c1 == c0 {
                    continue;
                }
                let (chunk, tail) = rest.split_at_mut(c1 - c0);
                rest = tail;
                let call = *call;
                s.spawn(move || panel_any(&call, xg, xsum, 1, c0, c1 - c0, chunk));
            }
        });
    } else {
        // GEMM: workers fill thread-local `[mb, slab]` tiles, merged
        // into the strided output after the join.  The scope (and the
        // tiles) are re-created per 8-row M-block: hoisting one pool
        // over all blocks would require gathering the whole act-order
        // activation matrix up front instead of one M-block at a time —
        // a deliberate trade-off, since the serving hot path this split
        // exists for is decode (M ≤ batch ≤ 8: exactly one block).
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .filter(|&t| bounds[t + 1] > bounds[t])
                .map(|t| {
                    let (c0, c1) = (bounds[t], bounds[t + 1]);
                    let call = *call;
                    s.spawn(move || {
                        let mut tile = vec![0.0f32; mb * (c1 - c0)];
                        panel_any(&call, xg, xsum, mb, c0, c1 - c0, &mut tile);
                        (c0, c1, tile)
                    })
                })
                .collect();
            for h in handles {
                let (c0, c1, tile) = h.join().expect("fused worker panicked");
                let cn = c1 - c0;
                for mi in 0..mb {
                    out[mi * n + c0..mi * n + c1].copy_from_slice(&tile[mi * cn..(mi + 1) * cn]);
                }
            }
        });
    }
}

/// Portable scalar tile loop over one M-block of (already gathered)
/// activations, restricted to the column window `[c0, c0 + cn)` of the
/// tensor.  This is the dispatch fallback and the bit-identity baseline:
/// its accumulation order is frozen (the parity suite pins it), and the
/// SIMD kernel in [`super::simd`] must match it to oracle tolerance.
///
/// `xg` is `[mb, K]` row-major, `xsum` the `[mb, K/g]` group sums, and
/// `out` is the `[mb, cn]` row-major window (stride `cn`), *accumulated
/// into* (callers pass zeroed output).  `c0` must be nibble-aligned.
fn fused_panel_cols(
    xg: &[f32],
    xsum: &[f32],
    mb: usize,
    q: &QuantizedTensor,
    c0: usize,
    cn: usize,
    out: &mut [f32],
) {
    let (k, n, g) = (q.k, q.n, q.group_size);
    debug_assert_eq!(xg.len(), mb * k);
    debug_assert_eq!(out.len(), mb * cn);
    debug_assert_eq!(c0 % NIBBLES_PER_WORD, 0, "column window must be nibble-aligned");
    assert_eq!(g % NIBBLES_PER_WORD, 0, "group size must be a multiple of 8");
    assert_eq!(k % g, 0, "group size must divide K");
    let groups = k / g;
    let words_per_group = g / NIBBLES_PER_WORD;
    let nw = n / NIBBLES_PER_WORD;

    let nb_max = col_block(cn, mb, g);
    let mut dot = vec![0.0f32; mb * nb_max];
    let mut zrow = vec![0.0f32; nb_max];

    let mut cb = 0;
    while cb < cn {
        let nb = nb_max.min(cn - cb);
        let ca = c0 + cb; // absolute first column of this tile
        for gi in 0..groups {
            for mi in 0..mb {
                dot[mi * nb_max..mi * nb_max + nb].fill(0.0);
            }
            // Unpack this group's zero points for the column block.
            for wz in 0..nb / NIBBLES_PER_WORD {
                let word = q.qzeros[gi * nw + ca / NIBBLES_PER_WORD + wz];
                for j in 0..NIBBLES_PER_WORD {
                    zrow[wz * NIBBLES_PER_WORD + j] = ((word >> (4 * j)) & 0xF) as f32;
                }
            }
            // Accumulate Σ x·code over the group slab, word by word.
            let w0 = gi * words_per_group;
            for dw in 0..words_per_group {
                let w = w0 + dw;
                let row = &q.qweight[w * n + ca..w * n + ca + nb];
                for mi in 0..mb {
                    let xr = &xg[mi * k + w * NIBBLES_PER_WORD
                        ..mi * k + (w + 1) * NIBBLES_PER_WORD];
                    if xr.iter().all(|&v| v == 0.0) {
                        continue;
                    }
                    let (x0, x1, x2, x3) = (xr[0], xr[1], xr[2], xr[3]);
                    let (x4, x5, x6, x7) = (xr[4], xr[5], xr[6], xr[7]);
                    let drow = &mut dot[mi * nb_max..mi * nb_max + nb];
                    for (d, &wrd) in drow.iter_mut().zip(row.iter()) {
                        // Four half2-analogue lane pairs per packed word.
                        *d += (x0 * (wrd & 0xF) as f32
                            + x1 * ((wrd >> 4) & 0xF) as f32)
                            + (x2 * ((wrd >> 8) & 0xF) as f32
                                + x3 * ((wrd >> 12) & 0xF) as f32)
                            + (x4 * ((wrd >> 16) & 0xF) as f32
                                + x5 * ((wrd >> 20) & 0xF) as f32)
                            + (x6 * ((wrd >> 24) & 0xF) as f32
                                + x7 * ((wrd >> 28) & 0xF) as f32);
                    }
                }
            }
            // Flush: y += s·(dot − z·Σx), once per group per column.
            let srow = &q.scales[gi * n + ca..gi * n + ca + nb];
            for mi in 0..mb {
                let xs = xsum[mi * groups + gi];
                let drow = &dot[mi * nb_max..mi * nb_max + nb];
                let yrow = &mut out[mi * cn + cb..mi * cn + cb + nb];
                for c in 0..nb {
                    yrow[c] += srow[c] * (drow[c] - zrow[c] * xs);
                }
            }
        }
        cb += nb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gptq::gemm::{dequantize, gemm_f32, gemv_f32};
    use crate::gptq::quantize::{quantize_gptq, quantize_rtn, GptqConfig};
    use crate::rng::Rng;

    fn random_quantized(k: usize, n: usize, g: usize, seed: u64) -> QuantizedTensor {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0));
        quantize_rtn(&w, g)
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    // Compact call forms over the collapsed two-entry-point surface.
    fn gemv(x: &[f32], q: &QuantizedTensor) -> Vec<f32> {
        gemv_fused_opt(x, FusedInput::Raw(q), FusedOpts::default())
    }
    fn gemv_k(x: &[f32], q: &QuantizedTensor, kernel: Kernel, threads: usize) -> Vec<f32> {
        gemv_fused_opt(
            x,
            FusedInput::Raw(q),
            FusedOpts { kernel: Some(kernel), threads: Some(threads) },
        )
    }
    fn gemv_t(x: &[f32], q: &QuantizedTensor, threads: usize) -> Vec<f32> {
        gemv_fused_opt(x, FusedInput::Raw(q), FusedOpts { kernel: None, threads: Some(threads) })
    }
    fn gemm(x: &Matrix, q: &QuantizedTensor) -> Matrix {
        gemm_fused_opt(x, FusedInput::Raw(q), FusedOpts::default())
    }
    fn gemm_k(x: &Matrix, q: &QuantizedTensor, kernel: Kernel, threads: usize) -> Matrix {
        gemm_fused_opt(
            x,
            FusedInput::Raw(q),
            FusedOpts { kernel: Some(kernel), threads: Some(threads) },
        )
    }

    #[test]
    fn gemv_matches_oracle() {
        for (k, n, g, seed) in [(64, 8, 32, 1), (128, 24, 64, 2), (256, 32, 128, 3)] {
            let q = random_quantized(k, n, g, seed);
            let mut rng = Rng::new(seed + 100);
            let x = rng.normal_vec_f32(k, 1.0);
            let got = gemv(&x, &q);
            let want = gemv_f32(&x, &q);
            assert!(
                max_abs_diff(&got, &want) < 1e-3,
                "k={k} n={n} g={g}: diff {}",
                max_abs_diff(&got, &want)
            );
        }
    }

    #[test]
    fn gemv_matches_dense_dequant_matmul() {
        let q = random_quantized(128, 16, 32, 7);
        let mut rng = Rng::new(8);
        let x = rng.normal_vec_f32(128, 1.0);
        let y = gemv(&x, &q);
        let wq = dequantize(&q);
        for col in 0..q.n {
            let mut expect = 0.0f32;
            for kk in 0..q.k {
                expect += x[kk] * wq.at(kk, col);
            }
            assert!((y[col] - expect).abs() < 1e-3, "col {col}");
        }
    }

    #[test]
    fn gemm_matches_oracle_across_m_block_boundaries() {
        let q = random_quantized(64, 16, 32, 4);
        let mut rng = Rng::new(5);
        // 1, exactly M_BLOCK, and a ragged tail past two blocks.
        for m in [1, M_BLOCK, 2 * M_BLOCK + 3] {
            let x = Matrix::from_vec(m, 64, rng.normal_vec_f32(m * 64, 1.0));
            let got = gemm(&x, &q);
            let want = gemm_f32(&x, &q);
            assert!(
                max_abs_diff(&got.data, &want.data) < 1e-3,
                "m={m}: diff {}",
                max_abs_diff(&got.data, &want.data)
            );
        }
    }

    #[test]
    fn every_available_kernel_matches_oracle() {
        // The dispatch table must never change *what* is computed — only
        // how fast.  Sweep every runnable kernel against the oracle.
        let q = random_quantized(256, 64, 64, 17);
        let mut rng = Rng::new(18);
        let x = rng.normal_vec_f32(256, 1.0);
        let want = gemv_f32(&x, &q);
        let xm = Matrix::from_vec(11, 256, rng.normal_vec_f32(11 * 256, 1.0));
        let want_m = gemm_f32(&xm, &q);
        for kernel in simd::available_kernels() {
            let got = gemv_k(&x, &q, kernel, 1);
            assert!(
                max_abs_diff(&got, &want) < 1e-3,
                "kernel {kernel}: gemv diff {}",
                max_abs_diff(&got, &want)
            );
            let got_m = gemm_k(&xm, &q, kernel, 1);
            assert!(
                max_abs_diff(&got_m.data, &want_m.data) < 1e-3,
                "kernel {kernel}: gemm diff {}",
                max_abs_diff(&got_m.data, &want_m.data)
            );
        }
    }

    #[test]
    fn prepared_path_is_bit_identical_to_unprepared() {
        // The swizzled prepack reorders *loads*, never math: a prepared
        // tensor must reproduce the plain path exactly, bit for bit.
        let q = random_quantized(256, 64, 64, 51);
        let mut rng = Rng::new(52);
        let x = rng.normal_vec_f32(256, 1.0);
        let plain = gemv(&x, &q);
        let p = PreparedTensor::new(q.clone());
        assert_eq!(plain, gemv_fused_prepared(&x, &p), "gemv prepared path diverged");
        let xm = Matrix::from_vec(9, 256, rng.normal_vec_f32(9 * 256, 1.0));
        assert_eq!(
            gemm(&xm, &q).data,
            gemm_fused_prepared(&xm, &p).data,
            "gemm prepared path diverged"
        );
        // Prepared + explicit threads too (the bench path).
        assert_eq!(plain, gemv_fused_opt(&x, FusedInput::Prepared(&p), FusedOpts { kernel: None, threads: Some(2) }));
    }

    #[test]
    fn prepared_tensor_holds_a_single_weight_layout() {
        // The prepack must *replace* the storage copy, not shadow it:
        // resident packed bytes never exceed the raw tensor's, and on
        // swizzled hosts the duplicate qweight words are gone.
        let q = random_quantized(256, 64, 64, 61);
        let raw_bytes = q.packed_bytes();
        let p = PreparedTensor::new(q.clone());
        assert_eq!(p.packed_bytes(), raw_bytes, "one layout = one copy of the words");
        assert_eq!((p.k(), p.n()), (256, 64));
        if p.is_swizzled() {
            // The raw words were dropped; only the swizzle remains.
            assert!(p.q.qweight.is_empty(), "swizzled tensor must not keep raw qweight");
        }
    }

    #[test]
    fn to_raw_rebuilds_the_storage_layout_exactly() {
        // Oracle/checkpoint consumers get the canonical tensor back
        // bit-for-bit, whatever layout the host serves from.
        let mut rng = Rng::new(62);
        let mut perm: Vec<usize> = (0..128).collect();
        rng.shuffle(&mut perm);
        let q = random_quantized(128, 24, 64, 63).with_perm(perm);
        let p = PreparedTensor::new(q.clone());
        let raw = p.to_raw();
        assert_eq!(raw.qweight, q.qweight);
        assert_eq!(raw.scales, q.scales);
        assert_eq!(raw.qzeros, q.qzeros);
        assert_eq!(raw.perm, q.perm);
        // And the rebuilt tensor drives the oracle to the same answer
        // the prepared fast path computes.
        let x = rng.normal_vec_f32(128, 1.0);
        let fast = gemv_fused_prepared(&x, &p);
        let oracle = crate::gptq::gemm::gemv_f32(&x, &raw);
        assert!(max_abs_diff(&fast, &oracle) < 1e-3);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        // The column split must not change results at all: per-column
        // accumulation order is untouched (K is never partitioned).
        // Pinned per kernel — the SIMD path must honor it too.
        let q = random_quantized(256, 640, 64, 21);
        let mut rng = Rng::new(22);
        let x = rng.normal_vec_f32(256, 1.0);
        let xm = Matrix::from_vec(11, 256, rng.normal_vec_f32(11 * 256, 1.0));
        for kernel in simd::available_kernels() {
            let serial = gemv_k(&x, &q, kernel, 1);
            for threads in [2, 3, 5, 8] {
                assert_eq!(
                    serial,
                    gemv_k(&x, &q, kernel, threads),
                    "gemv kernel={kernel} threads={threads}"
                );
            }
            let serial_m = gemm_k(&xm, &q, kernel, 1);
            for threads in [2, 4, 7] {
                assert_eq!(
                    serial_m.data,
                    gemm_k(&xm, &q, kernel, threads).data,
                    "gemm kernel={kernel} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn parallel_act_order_matches_serial_and_oracle() {
        let mut rng = Rng::new(31);
        let mut perm: Vec<usize> = (0..128).collect();
        rng.shuffle(&mut perm);
        let q = random_quantized(128, 264, 64, 32).with_perm(perm);
        let x = rng.normal_vec_f32(128, 1.0);
        let serial = gemv_t(&x, &q, 1);
        // 264 % 8 == 0: the split engages and must stay aligned.
        assert_eq!(serial, gemv_t(&x, &q, 4));
        assert!(max_abs_diff(&serial, &gemv_f32(&x, &q)) < 1e-3);
    }

    #[test]
    fn oversubscribed_thread_count_is_clamped() {
        let q = random_quantized(64, 16, 32, 41);
        let mut rng = Rng::new(42);
        let x = rng.normal_vec_f32(64, 1.0);
        // More workers than nibble-words of output: must clamp, not hang
        // or emit empty slabs.
        assert_eq!(gemv_t(&x, &q, 1), gemv_t(&x, &q, 64));
    }

    #[test]
    fn auto_threads_stays_serial_for_tiny_shapes() {
        assert_eq!(fused_threads(1, 64, 64), 1, "tiny-model shapes must not spawn");
        assert_eq!(fused_threads(8, 64, 256), 1);
        // Misaligned N can never split.
        assert_eq!(fused_threads(64, 4096, 4095), 1);
    }

    #[test]
    fn col_block_budget_accounts_for_activation_slab() {
        // Accumulator tile (mb·nb) + zero row (nb) + activation slab
        // (mb·g) must fit the 16 KiB budget, and nb stays nibble-aligned
        // with the floor respected.
        for (mb, g) in [(1, 32), (1, 128), (8, 32), (8, 128)] {
            let nb = col_block(1 << 20, mb, g);
            assert_eq!(nb % 8, 0, "mb={mb} g={g}: nb={nb} must be a multiple of 8");
            assert!(nb >= 64, "mb={mb} g={g}: nb={nb} below floor");
            if nb > 64 {
                let floats = nb * (mb + 1) + mb * g;
                assert!(
                    floats <= 16 * 1024 / 4,
                    "mb={mb} g={g}: working set {floats} floats exceeds L1 budget"
                );
            }
        }
        // Small N is clamped to N exactly as before.
        assert_eq!(col_block(40, 1, 32), 40);
    }

    #[test]
    fn act_order_gemv_matches_oracle() {
        // Real act-order tensor from the GPTQ quantizer (carries b_q_perm).
        let mut rng = Rng::new(11);
        let w = Matrix::from_vec(64, 16, rng.normal_vec_f32(64 * 16, 0.7));
        let x_cal = Matrix::from_vec(96, 64, rng.normal_vec_f32(96 * 64, 1.0));
        let q = quantize_gptq(
            w,
            &x_cal,
            GptqConfig { group_size: 32, percdamp: 0.01, act_order: true },
        );
        assert!(q.perm.is_some());
        let x = rng.normal_vec_f32(64, 1.0);
        let got = gemv(&x, &q);
        let want = gemv_f32(&x, &q);
        assert!(max_abs_diff(&got, &want) < 1e-3);
    }

    #[test]
    fn synthetic_perm_matches_oracle() {
        let mut rng = Rng::new(12);
        let mut perm: Vec<usize> = (0..128).collect();
        rng.shuffle(&mut perm);
        let q = random_quantized(128, 16, 64, 13).with_perm(perm);
        let x = rng.normal_vec_f32(128, 1.0);
        assert!(max_abs_diff(&gemv(&x, &q), &gemv_f32(&x, &q)) < 1e-3);
        let xm = Matrix::from_vec(5, 128, rng.normal_vec_f32(5 * 128, 1.0));
        let got = gemm(&xm, &q);
        let want = gemm_f32(&xm, &q);
        assert!(max_abs_diff(&got.data, &want.data) < 1e-3);
    }

    #[test]
    fn zero_activation_gives_zero_output() {
        let q = random_quantized(64, 8, 64, 6);
        for kernel in simd::available_kernels() {
            let y = gemv_k(&vec![0.0; 64], &q, kernel, 1);
            assert!(y.iter().all(|&v| v == 0.0), "kernel {kernel}");
        }
    }

    #[test]
    fn no_rows_is_fine() {
        let q = random_quantized(64, 8, 64, 9);
        let x = Matrix::zeros(0, 64);
        let out = gemm(&x, &q);
        assert_eq!(out.rows, 0);
    }
}

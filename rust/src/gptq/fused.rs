//! Fused dequantize-GEMM/GEMV on the CPU: unpack nibbles on the fly per
//! tile, never materialize the dense weight matrix.
//!
//! The reference oracle ([`super::gemm::gemv_f32`]) calls
//! [`super::pack::unpack_rows`] on *every* invocation — a `K×N` byte
//! allocation plus a full extra pass over the weights before any math
//! happens.  This module is the executable analogue of the paper's kernel
//! structure (it is what [`crate::engine::cpu_backend::CpuBackend`] serves
//! real tokens through):
//!
//! * **Tile geometry.**  The K axis is walked in *group slabs* (one
//!   quantization group, `group_size` rows — the dequant parameters are
//!   constant across a slab, mirroring how the DCU kernel's `K_SLAB = 128`
//!   stays within one group; see `dcusim::kernels::gemv`).  The N axis is
//!   blocked so the per-tile accumulator (`M_BLOCK × N` partial dots plus
//!   the unpacked zero row) stays L1-resident — the CPU cache analogue of
//!   the SMB-Opt LDS accumulator tile.  M is blocked by [`M_BLOCK`]` = 8`,
//!   matching the simulator's `M_COUNT_MAX` (rows of a block share one
//!   pass over the packed weights).
//!
//! * **Lane pairs.**  Each packed `u32` word holds 8 nibbles (8 K-rows of
//!   one column); the inner loop accumulates them as four explicitly
//!   paired products — the half2-analogue of the paper's VML/ILA inner
//!   loop — which both mirrors the kernel and gives the autovectorizer
//!   independent chains.
//!
//! * **Group factorization.**  Within a group, `Σ x·s·(c − z)` is computed
//!   as `s·(Σ x·c − z·Σ x)`: the scale multiply and zero subtract are
//!   hoisted out of the K loop entirely (one flush per group per column),
//!   so the hot loop is shift/mask/convert/fma only.
//!
//! * **Act-order.**  `b_q_perm` checkpoints gather the activations once
//!   per panel (`xg[k] = x[perm[k]]`, the load pattern Algorithm 2
//!   branches on), after which the kernel is permutation-oblivious.
//!
//! Parity with the oracle across shapes, groups, batch sizes and
//! act-order is pinned by `rust/tests/parity.rs`; speed is measured by
//! `rust/benches/fused_gemm.rs` (≥10× over the oracle on the 4096×4096
//! decode shape).

use super::pack::NIBBLES_PER_WORD;
use super::quantize::QuantizedTensor;
use super::Matrix;

/// Rows of the activation matrix processed per pass over the packed
/// weights (mirrors `dcusim::kernels::gemv::M_COUNT_MAX`).
pub const M_BLOCK: usize = 8;

/// Column-block size: keep the `mb`-row accumulator tile plus the zero
/// row within ~16 KiB so the per-tile state is L1-resident.
fn col_block(n: usize, mb: usize) -> usize {
    let budget = (16 * 1024 / 4) / (mb + 1);
    let nb = budget.max(64) & !7; // multiple of the nibble width
    nb.min(n)
}

/// `y[N] = x[K] · deq(Q)[K, N]` — fused single-row (decode) GEMV.
pub fn gemv_fused(x: &[f32], q: &QuantizedTensor) -> Vec<f32> {
    assert_eq!(x.len(), q.k);
    let mut y = vec![0.0f32; q.n];
    match &q.perm {
        None => fused_panel(x, 1, q, &mut y),
        Some(p) => {
            // Act-order gather (Algorithm 2's b_q_perm branch).
            let xg: Vec<f32> = p.iter().map(|&src| x[src]).collect();
            fused_panel(&xg, 1, q, &mut y);
        }
    }
    y
}

/// `Y[M, N] = X[M, K] · deq(Q)` — fused batched (prefill) GEMM.
pub fn gemm_fused(x: &Matrix, q: &QuantizedTensor) -> Matrix {
    assert_eq!(x.cols, q.k);
    let (k, n) = (q.k, q.n);
    let mut out = Matrix::zeros(x.rows, n);
    let mut gather: Vec<f32> = Vec::new();
    let mut m0 = 0;
    while m0 < x.rows {
        let mb = M_BLOCK.min(x.rows - m0);
        let xs = &x.data[m0 * k..(m0 + mb) * k];
        let ys = &mut out.data[m0 * n..(m0 + mb) * n];
        match &q.perm {
            None => fused_panel(xs, mb, q, ys),
            Some(p) => {
                gather.clear();
                gather.reserve(mb * k);
                for mi in 0..mb {
                    let row = &xs[mi * k..(mi + 1) * k];
                    gather.extend(p.iter().map(|&src| row[src]));
                }
                fused_panel(&gather, mb, q, ys);
            }
        }
        m0 += mb;
    }
    out
}

/// Core tile loop over one M-block of (already gathered) activations.
///
/// `xg` is `[mb, K]` row-major, `out` is `[mb, N]` row-major and is
/// *accumulated into* (callers pass zeroed output).
fn fused_panel(xg: &[f32], mb: usize, q: &QuantizedTensor, out: &mut [f32]) {
    let (k, n, g) = (q.k, q.n, q.group_size);
    debug_assert_eq!(xg.len(), mb * k);
    debug_assert_eq!(out.len(), mb * n);
    assert_eq!(g % NIBBLES_PER_WORD, 0, "group size must be a multiple of 8");
    assert_eq!(k % g, 0, "group size must divide K");
    let groups = k / g;
    let words_per_group = g / NIBBLES_PER_WORD;
    let nw = n / NIBBLES_PER_WORD;

    // Per-(row, group) activation sums for the zero-point term.
    let mut xsum = vec![0.0f32; mb * groups];
    for mi in 0..mb {
        for gi in 0..groups {
            xsum[mi * groups + gi] =
                xg[mi * k + gi * g..mi * k + (gi + 1) * g].iter().sum();
        }
    }

    let nb_max = col_block(n, mb);
    let mut dot = vec![0.0f32; mb * nb_max];
    let mut zrow = vec![0.0f32; nb_max];

    let mut cb = 0;
    while cb < n {
        let nb = nb_max.min(n - cb);
        for gi in 0..groups {
            for mi in 0..mb {
                dot[mi * nb_max..mi * nb_max + nb].fill(0.0);
            }
            // Unpack this group's zero points for the column block.
            for wz in 0..nb / NIBBLES_PER_WORD {
                let word = q.qzeros[gi * nw + cb / NIBBLES_PER_WORD + wz];
                for j in 0..NIBBLES_PER_WORD {
                    zrow[wz * NIBBLES_PER_WORD + j] = ((word >> (4 * j)) & 0xF) as f32;
                }
            }
            // Accumulate Σ x·code over the group slab, word by word.
            let w0 = gi * words_per_group;
            for dw in 0..words_per_group {
                let w = w0 + dw;
                let row = &q.qweight[w * n + cb..w * n + cb + nb];
                for mi in 0..mb {
                    let xr = &xg[mi * k + w * NIBBLES_PER_WORD
                        ..mi * k + (w + 1) * NIBBLES_PER_WORD];
                    if xr.iter().all(|&v| v == 0.0) {
                        continue;
                    }
                    let (x0, x1, x2, x3) = (xr[0], xr[1], xr[2], xr[3]);
                    let (x4, x5, x6, x7) = (xr[4], xr[5], xr[6], xr[7]);
                    let drow = &mut dot[mi * nb_max..mi * nb_max + nb];
                    for (d, &wrd) in drow.iter_mut().zip(row.iter()) {
                        // Four half2-analogue lane pairs per packed word.
                        *d += (x0 * (wrd & 0xF) as f32
                            + x1 * ((wrd >> 4) & 0xF) as f32)
                            + (x2 * ((wrd >> 8) & 0xF) as f32
                                + x3 * ((wrd >> 12) & 0xF) as f32)
                            + (x4 * ((wrd >> 16) & 0xF) as f32
                                + x5 * ((wrd >> 20) & 0xF) as f32)
                            + (x6 * ((wrd >> 24) & 0xF) as f32
                                + x7 * ((wrd >> 28) & 0xF) as f32);
                    }
                }
            }
            // Flush: y += s·(dot − z·Σx), once per group per column.
            let srow = &q.scales[gi * n + cb..gi * n + cb + nb];
            for mi in 0..mb {
                let xs = xsum[mi * groups + gi];
                let drow = &dot[mi * nb_max..mi * nb_max + nb];
                let yrow = &mut out[mi * n + cb..mi * n + cb + nb];
                for c in 0..nb {
                    yrow[c] += srow[c] * (drow[c] - zrow[c] * xs);
                }
            }
        }
        cb += nb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gptq::gemm::{dequantize, gemm_f32, gemv_f32};
    use crate::gptq::quantize::{quantize_gptq, quantize_rtn, GptqConfig};
    use crate::rng::Rng;

    fn random_quantized(k: usize, n: usize, g: usize, seed: u64) -> QuantizedTensor {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0));
        quantize_rtn(&w, g)
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn gemv_matches_oracle() {
        for (k, n, g, seed) in [(64, 8, 32, 1), (128, 24, 64, 2), (256, 32, 128, 3)] {
            let q = random_quantized(k, n, g, seed);
            let mut rng = Rng::new(seed + 100);
            let x = rng.normal_vec_f32(k, 1.0);
            let got = gemv_fused(&x, &q);
            let want = gemv_f32(&x, &q);
            assert!(
                max_abs_diff(&got, &want) < 1e-3,
                "k={k} n={n} g={g}: diff {}",
                max_abs_diff(&got, &want)
            );
        }
    }

    #[test]
    fn gemv_matches_dense_dequant_matmul() {
        let q = random_quantized(128, 16, 32, 7);
        let mut rng = Rng::new(8);
        let x = rng.normal_vec_f32(128, 1.0);
        let y = gemv_fused(&x, &q);
        let wq = dequantize(&q);
        for col in 0..q.n {
            let mut expect = 0.0f32;
            for kk in 0..q.k {
                expect += x[kk] * wq.at(kk, col);
            }
            assert!((y[col] - expect).abs() < 1e-3, "col {col}");
        }
    }

    #[test]
    fn gemm_matches_oracle_across_m_block_boundaries() {
        let q = random_quantized(64, 16, 32, 4);
        let mut rng = Rng::new(5);
        // 1, exactly M_BLOCK, and a ragged tail past two blocks.
        for m in [1, M_BLOCK, 2 * M_BLOCK + 3] {
            let x = Matrix::from_vec(m, 64, rng.normal_vec_f32(m * 64, 1.0));
            let got = gemm_fused(&x, &q);
            let want = gemm_f32(&x, &q);
            assert!(
                max_abs_diff(&got.data, &want.data) < 1e-3,
                "m={m}: diff {}",
                max_abs_diff(&got.data, &want.data)
            );
        }
    }

    #[test]
    fn act_order_gemv_matches_oracle() {
        // Real act-order tensor from the GPTQ quantizer (carries b_q_perm).
        let mut rng = Rng::new(11);
        let w = Matrix::from_vec(64, 16, rng.normal_vec_f32(64 * 16, 0.7));
        let x_cal = Matrix::from_vec(96, 64, rng.normal_vec_f32(96 * 64, 1.0));
        let q = quantize_gptq(
            w,
            &x_cal,
            GptqConfig { group_size: 32, percdamp: 0.01, act_order: true },
        );
        assert!(q.perm.is_some());
        let x = rng.normal_vec_f32(64, 1.0);
        let got = gemv_fused(&x, &q);
        let want = gemv_f32(&x, &q);
        assert!(max_abs_diff(&got, &want) < 1e-3);
    }

    #[test]
    fn synthetic_perm_matches_oracle() {
        let mut rng = Rng::new(12);
        let mut perm: Vec<usize> = (0..128).collect();
        rng.shuffle(&mut perm);
        let q = random_quantized(128, 16, 64, 13).with_perm(perm);
        let x = rng.normal_vec_f32(128, 1.0);
        assert!(max_abs_diff(&gemv_fused(&x, &q), &gemv_f32(&x, &q)) < 1e-3);
        let xm = Matrix::from_vec(5, 128, rng.normal_vec_f32(5 * 128, 1.0));
        let got = gemm_fused(&xm, &q);
        let want = gemm_f32(&xm, &q);
        assert!(max_abs_diff(&got.data, &want.data) < 1e-3);
    }

    #[test]
    fn zero_activation_gives_zero_output() {
        let q = random_quantized(64, 8, 64, 6);
        let y = gemv_fused(&vec![0.0; 64], &q);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn no_rows_is_fine() {
        let q = random_quantized(64, 8, 64, 9);
        let x = Matrix::zeros(0, 64);
        let out = gemm_fused(&x, &q);
        assert_eq!(out.rows, 0);
    }
}

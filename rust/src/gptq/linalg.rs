//! Small dense linear algebra needed by the GPTQ algorithm (f64).
//!
//! GPTQ needs: `H = 2 XᵀX + λI` (symmetric positive definite), `H⁻¹`, and
//! the **upper** Cholesky factor of `H⁻¹` whose rows drive the error
//! propagation.  Sizes are the layer in-feature counts (≤ a few thousand),
//! so straightforward O(n³) loops are fine.

/// Cholesky decomposition `A = L Lᵀ` (lower-triangular, row-major n×n).
/// Returns `None` if `A` is not positive definite.
pub fn cholesky_lower(a: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Invert a lower-triangular matrix in place (forward substitution).
pub fn invert_lower(l: &[f64], n: usize) -> Vec<f64> {
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0 / l[i * n + i];
        for j in 0..i {
            let mut sum = 0.0;
            for k in j..i {
                sum += l[i * n + k] * inv[k * n + j];
            }
            inv[i * n + j] = -sum / l[i * n + i];
        }
    }
    inv
}

/// Inverse of an SPD matrix via Cholesky: `A⁻¹ = L⁻ᵀ L⁻¹`.
pub fn invert_spd(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky_lower(a, n)?;
    let linv = invert_lower(&l, n);
    // A^{-1} = L^{-T} L^{-1}; entry (i,j) = sum_k linv[k,i] * linv[k,j]
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = 0.0;
            for k in i.max(j)..n {
                sum += linv[k * n + i] * linv[k * n + j];
            }
            inv[i * n + j] = sum;
            inv[j * n + i] = sum;
        }
    }
    Some(inv)
}

/// Upper Cholesky factor `U` with `A = Uᵀ U` (what GPTQ's error
/// propagation indexes): computed as the transpose of the lower factor of
/// the *reversed* matrix trick is unnecessary — we use `A = L Lᵀ` and
/// return `U = Lᵀ`.
pub fn cholesky_upper(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky_lower(a, n)?;
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l[i * n + j];
        }
    }
    Some(u)
}

/// `C = AᵀA` for row-major A (rows m, cols n) -> n×n.
pub fn gram(a: &[f32], m: usize, n: usize) -> Vec<f64> {
    assert_eq!(a.len(), m * n);
    let mut g = vec![0.0f64; n * n];
    for r in 0..m {
        let row = &a[r * n..(r + 1) * n];
        for i in 0..n {
            let ai = row[i] as f64;
            if ai == 0.0 {
                continue;
            }
            for j in i..n {
                g[i * n + j] += ai * row[j] as f64;
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            g[i * n + j] = g[j * n + i];
        }
    }
    g
}

/// Max |A·A⁻¹ − I| — used by tests to validate inversion accuracy.
pub fn inverse_residual(a: &[f64], inv: &[f64], n: usize) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let mut sum = 0.0;
            for k in 0..n {
                sum += a[i * n + k] * inv[k * n + j];
            }
            let expect = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((sum - expect).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let m: Vec<f32> = (0..(2 * n * n)).map(|_| rng.normal() as f32).collect();
        let mut g = gram(&m, 2 * n, n);
        for i in 0..n {
            g[i * n + i] += 0.5; // damping for conditioning
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let n = 16;
        let a = random_spd(n, 1);
        let l = cholesky_lower(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut sum = 0.0;
                for k in 0..n {
                    sum += l[i * n + k] * l[j * n + k];
                }
                assert!((sum - a[i * n + j]).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        // [[1, 2], [2, 1]] has eigenvalues 3 and -1.
        let a = vec![1.0, 2.0, 2.0, 1.0];
        assert!(cholesky_lower(&a, 2).is_none());
    }

    #[test]
    fn spd_inverse_accurate() {
        let n = 24;
        let a = random_spd(n, 2);
        let inv = invert_spd(&a, n).unwrap();
        assert!(inverse_residual(&a, &inv, n) < 1e-6);
    }

    #[test]
    fn upper_factor_reconstructs() {
        let n = 12;
        let a = random_spd(n, 3);
        let u = cholesky_upper(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut sum = 0.0;
                for k in 0..n {
                    sum += u[k * n + i] * u[k * n + j];
                }
                assert!((sum - a[i * n + j]).abs() < 1e-8);
            }
        }
        // strictly upper: entries below the diagonal are zero
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn gram_matches_naive() {
        let a: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3x2
        let g = gram(&a, 3, 2);
        assert_eq!(g, vec![35.0, 44.0, 44.0, 56.0]);
    }
}

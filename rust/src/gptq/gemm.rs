//! Dense CPU reference for the quantized GEMM/GEMV (f32).
//!
//! This is the *correctness* oracle on the rust side (mirroring
//! `python/compile/kernels/ref.py`); the performance-modelled kernel lives
//! in `dcusim::kernels`, and the f16-faithful numerics used by the
//! accuracy study live in `eval::numerics`.

use super::pack;
use super::quantize::QuantizedTensor;
use super::Matrix;

/// Expand a packed tensor to a dense f32 matrix `W[K, N]`.
pub fn dequantize(q: &QuantizedTensor) -> Matrix {
    let (k, n, g) = (q.k, q.n, q.group_size);
    let codes = pack::unpack_rows(&q.qweight, k / pack::NIBBLES_PER_WORD, n);
    let zeros = pack::unpack_cols(&q.qzeros, q.groups(), n / pack::NIBBLES_PER_WORD);
    let mut w = Matrix::zeros(k, n);
    for kk in 0..k {
        let gi = kk / g;
        // Act-order: packed row kk stores original in-feature perm[kk].
        let dst = q.perm.as_ref().map_or(kk, |p| p[kk]);
        for col in 0..n {
            let code = codes[kk * n + col] as i32;
            let zero = zeros[gi * n + col] as i32;
            let scale = q.scales[gi * n + col];
            w.data[dst * n + col] = scale * (code - zero) as f32;
        }
    }
    w
}

/// `y[N] = x[K] · deq(Q)[K, N]` — single-row (decode) GEMV.
pub fn gemv_f32(x: &[f32], q: &QuantizedTensor) -> Vec<f32> {
    assert_eq!(x.len(), q.k);
    let n = q.n;
    let g = q.group_size;
    let codes = pack::unpack_rows(&q.qweight, q.k / pack::NIBBLES_PER_WORD, n);
    let zeros = pack::unpack_cols(&q.qzeros, q.groups(), n / pack::NIBBLES_PER_WORD);
    let mut y = vec![0.0f32; n];
    for kk in 0..q.k {
        // Act-order: gather the activation through b_q_perm (the load
        // pattern the paper's Algorithm 2 branches on).
        let xv = x[q.perm.as_ref().map_or(kk, |p| p[kk])];
        if xv == 0.0 {
            continue;
        }
        let gi = kk / g;
        let crow = &codes[kk * n..(kk + 1) * n];
        let zrow = &zeros[gi * n..(gi + 1) * n];
        let srow = &q.scales[gi * n..(gi + 1) * n];
        for col in 0..n {
            y[col] += xv * srow[col] * (crow[col] as i32 - zrow[col] as i32) as f32;
        }
    }
    y
}

/// `Y[M, N] = X[M, K] · deq(Q)` — batched GEMM.
pub fn gemm_f32(x: &Matrix, q: &QuantizedTensor) -> Matrix {
    assert_eq!(x.cols, q.k);
    let mut out = Matrix::zeros(x.rows, q.n);
    for m in 0..x.rows {
        let y = gemv_f32(x.row(m), q);
        out.data[m * q.n..(m + 1) * q.n].copy_from_slice(&y);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gptq::quantize::{quantize_rtn, QMAX};
    use crate::rng::Rng;

    fn random_quantized(k: usize, n: usize, g: usize, seed: u64) -> (Matrix, QuantizedTensor) {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 1.0));
        let q = quantize_rtn(&w, g);
        (w, q)
    }

    #[test]
    fn gemv_matches_dense_dequant_matmul() {
        let (_, q) = random_quantized(128, 24, 64, 1);
        let mut rng = Rng::new(2);
        let x = rng.normal_vec_f32(128, 1.0);
        let y = gemv_f32(&x, &q);
        let wq = dequantize(&q);
        for col in 0..q.n {
            let mut expect = 0.0f32;
            for kk in 0..q.k {
                expect += x[kk] * wq.at(kk, col);
            }
            assert!((y[col] - expect).abs() < 1e-3, "col {col}: {} vs {expect}", y[col]);
        }
    }

    #[test]
    fn gemm_rows_are_independent_gemvs() {
        let (_, q) = random_quantized(64, 16, 64, 3);
        let mut rng = Rng::new(4);
        let x = Matrix::from_vec(3, 64, rng.normal_vec_f32(3 * 64, 1.0));
        let out = gemm_f32(&x, &q);
        for m in 0..3 {
            let y = gemv_f32(x.row(m), &q);
            assert_eq!(out.row(m), &y[..]);
        }
    }

    #[test]
    fn dequantize_respects_grid() {
        let (_, q) = random_quantized(64, 8, 32, 5);
        let w = dequantize(&q);
        // every dequantized value must be scale * integer in [-zero, 15-zero]
        let zeros = pack::unpack_cols(&q.qzeros, q.groups(), 1);
        for kk in 0..q.k {
            let gi = kk / q.group_size;
            for col in 0..q.n {
                let s = q.scales[gi * q.n + col];
                let z = zeros[gi * q.n + col] as i32;
                let steps = w.at(kk, col) / s;
                let nearest = steps.round();
                assert!((steps - nearest).abs() < 1e-3);
                let code = nearest as i32 + z;
                assert!((0..=QMAX).contains(&code));
            }
        }
    }

    #[test]
    fn zero_activation_gives_zero_output() {
        let (_, q) = random_quantized(64, 8, 64, 6);
        let y = gemv_f32(&vec![0.0; 64], &q);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}

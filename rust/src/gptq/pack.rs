//! 4-bit nibble packing (the exllama-style GPTQ storage layout).

pub const NIBBLES_PER_WORD: usize = 8;

/// Pack codes `u8[K, N]` (values 0..=15) into `u32[K/8, N]`:
/// nibble `j` (bits `4j..4j+4`) of word `w` holds row `8w + j`.
pub fn pack_rows(codes: &[u8], k: usize, n: usize) -> Vec<u32> {
    assert_eq!(codes.len(), k * n);
    assert_eq!(k % NIBBLES_PER_WORD, 0, "K must be a multiple of 8");
    let kw = k / NIBBLES_PER_WORD;
    let mut out = vec![0u32; kw * n];
    for w in 0..kw {
        for j in 0..NIBBLES_PER_WORD {
            let row = w * NIBBLES_PER_WORD + j;
            for col in 0..n {
                let c = codes[row * n + col] as u32;
                debug_assert!(c <= 0xF);
                out[w * n + col] |= c << (4 * j);
            }
        }
    }
    out
}

/// Inverse of [`pack_rows`].
pub fn unpack_rows(qweight: &[u32], kw: usize, n: usize) -> Vec<u8> {
    assert_eq!(qweight.len(), kw * n);
    let k = kw * NIBBLES_PER_WORD;
    let mut out = vec![0u8; k * n];
    for w in 0..kw {
        for col in 0..n {
            let word = qweight[w * n + col];
            for j in 0..NIBBLES_PER_WORD {
                out[(w * NIBBLES_PER_WORD + j) * n + col] = ((word >> (4 * j)) & 0xF) as u8;
            }
        }
    }
    out
}

/// Pack zero-points `u8[G, N]` into `u32[G, N/8]`:
/// nibble `j` of word `w` holds column `8w + j`.
pub fn pack_cols(zeros: &[u8], g: usize, n: usize) -> Vec<u32> {
    assert_eq!(zeros.len(), g * n);
    assert_eq!(n % NIBBLES_PER_WORD, 0, "N must be a multiple of 8");
    let nw = n / NIBBLES_PER_WORD;
    let mut out = vec![0u32; g * nw];
    for gi in 0..g {
        for w in 0..nw {
            let mut word = 0u32;
            for j in 0..NIBBLES_PER_WORD {
                let z = zeros[gi * n + w * NIBBLES_PER_WORD + j] as u32;
                debug_assert!(z <= 0xF);
                word |= z << (4 * j);
            }
            out[gi * nw + w] = word;
        }
    }
    out
}

/// Inverse of [`pack_cols`].
pub fn unpack_cols(qzeros: &[u32], g: usize, nw: usize) -> Vec<u8> {
    assert_eq!(qzeros.len(), g * nw);
    let n = nw * NIBBLES_PER_WORD;
    let mut out = vec![0u8; g * n];
    for gi in 0..g {
        for w in 0..nw {
            let word = qzeros[gi * nw + w];
            for j in 0..NIBBLES_PER_WORD {
                out[gi * n + w * NIBBLES_PER_WORD + j] = ((word >> (4 * j)) & 0xF) as u8;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn pack_rows_nibble_order() {
        // Single column, rows 0..16 hold codes 0..16 (mod 16).
        let codes: Vec<u8> = (0..16).map(|i| (i % 16) as u8).collect();
        let packed = pack_rows(&codes, 16, 1);
        assert_eq!(packed.len(), 2);
        let expect0: u32 = (0..8).map(|j| (j as u32) << (4 * j)).sum();
        assert_eq!(packed[0], expect0);
    }

    #[test]
    fn pack_cols_nibble_order() {
        let zeros: Vec<u8> = (0..8).collect();
        let packed = pack_cols(&zeros, 1, 8);
        let expect: u32 = (0..8).map(|j| (j as u32) << (4 * j)).sum();
        assert_eq!(packed, vec![expect]);
    }

    #[test]
    fn roundtrip_rows() {
        let mut rng = Rng::new(1);
        let (k, n) = (64, 24);
        let codes: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let packed = pack_rows(&codes, k, n);
        assert_eq!(unpack_rows(&packed, k / 8, n), codes);
    }

    #[test]
    fn roundtrip_cols() {
        let mut rng = Rng::new(2);
        let (g, n) = (5, 32);
        let zeros: Vec<u8> = (0..g * n).map(|_| rng.below(16) as u8).collect();
        let packed = pack_cols(&zeros, g, n);
        assert_eq!(unpack_cols(&packed, g, n / 8), zeros);
    }

    #[test]
    #[should_panic(expected = "multiple of 8")]
    fn pack_rows_rejects_bad_k() {
        pack_rows(&[0u8; 12], 12, 1);
    }
}

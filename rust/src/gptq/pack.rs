//! 4-bit nibble packing (the exllama-style GPTQ storage layout), plus the
//! vector-friendly prepacked ("swizzled") copy the explicit-SIMD kernels
//! stream from.
//!
//! The storage layout (`qweight: u32[K/8, N]`) is row-major over word
//! rows: walking one column-octet down the K axis touches one 32-byte
//! span per word row at an `N`-word stride.  [`SwizzledWeights`] is the
//! VML-Opt analogue of the paper's coalesced vector loads: a
//! column-interleaved copy in which a column-octet's entire K walk is one
//! contiguous, 32-byte-aligned stream, so each step of the fused inner
//! loop is a single aligned 256-bit load feeding all 8 lanes.

pub const NIBBLES_PER_WORD: usize = 8;

/// Eight consecutive columns' packed words for one word row — the unit a
/// 256-bit vector load feeds.  `repr(align(32))` keeps every element of a
/// `Vec<Lane8>` load-aligned (size 32 = align 32, no padding).
#[repr(C, align(32))]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lane8(pub [u32; 8]);

/// Column-interleaved prepack of a `u32[K/8, N]` weight matrix:
/// `octet(o, w)` holds word row `w` of columns `8o..8o+8`, laid out so
/// octet `o`'s word rows `0..K/8` are contiguous (`lanes[o * K/8 + w]`).
/// Computed once per tensor (see `fused::PreparedTensor`) and reused by
/// every serve-path projection — the swizzle never runs on the hot path.
#[derive(Debug, Clone)]
pub struct SwizzledWeights {
    kw: usize,
    nw: usize,
    lanes: Vec<Lane8>,
}

impl SwizzledWeights {
    /// Word rows per column (`K / 8`).
    pub fn kw(&self) -> usize {
        self.kw
    }

    /// Columns covered (`N`).
    pub fn n(&self) -> usize {
        self.nw * NIBBLES_PER_WORD
    }

    /// Word row `w` of column-octet `o` (columns `8o..8o+8`).
    #[inline]
    pub fn octet(&self, o: usize, w: usize) -> &[u32; 8] {
        &self.lanes[o * self.kw + w].0
    }

    /// Flat 32-byte-aligned word view: octet `(o, w)` starts at index
    /// `(o * kw + w) * 8`.  The SIMD kernels index this directly.
    pub fn words(&self) -> &[u32] {
        // SAFETY: Lane8 is repr(C) over [u32; 8] with no padding (size 32
        // == align 32), so the Vec's backing store is a valid contiguous
        // [u32] of 8 * len elements.
        unsafe {
            std::slice::from_raw_parts(
                self.lanes.as_ptr() as *const u32,
                self.lanes.len() * NIBBLES_PER_WORD,
            )
        }
    }
}

/// Build the column-interleaved prepack of `qweight` (`u32[kw, n]`).
pub fn swizzle_weights(qweight: &[u32], kw: usize, n: usize) -> SwizzledWeights {
    assert_eq!(qweight.len(), kw * n);
    assert_eq!(n % NIBBLES_PER_WORD, 0, "N must be a multiple of 8");
    let nw = n / NIBBLES_PER_WORD;
    let mut lanes = vec![Lane8([0; NIBBLES_PER_WORD]); nw * kw];
    for o in 0..nw {
        for w in 0..kw {
            let src = w * n + o * NIBBLES_PER_WORD;
            lanes[o * kw + w].0.copy_from_slice(&qweight[src..src + NIBBLES_PER_WORD]);
        }
    }
    SwizzledWeights { kw, nw, lanes }
}

/// Inverse of [`swizzle_weights`]: rebuild the storage-layout
/// `qweight` (`u32[kw, n]`) from the prepack.  Cold path — used only
/// when a raw-layout consumer (oracle parity, checkpointing) needs the
/// canonical tensor back from a serve-host [`SwizzledWeights`]-only
/// `PreparedTensor`.
pub fn unswizzle_weights(swz: &SwizzledWeights) -> Vec<u32> {
    let (kw, n) = (swz.kw, swz.nw * NIBBLES_PER_WORD);
    let mut qweight = vec![0u32; kw * n];
    for o in 0..swz.nw {
        for w in 0..kw {
            let dst = w * n + o * NIBBLES_PER_WORD;
            qweight[dst..dst + NIBBLES_PER_WORD].copy_from_slice(&swz.lanes[o * kw + w].0);
        }
    }
    qweight
}

/// Pack codes `u8[K, N]` (values 0..=15) into `u32[K/8, N]`:
/// nibble `j` (bits `4j..4j+4`) of word `w` holds row `8w + j`.
pub fn pack_rows(codes: &[u8], k: usize, n: usize) -> Vec<u32> {
    assert_eq!(codes.len(), k * n);
    assert_eq!(k % NIBBLES_PER_WORD, 0, "K must be a multiple of 8");
    let kw = k / NIBBLES_PER_WORD;
    let mut out = vec![0u32; kw * n];
    for w in 0..kw {
        for j in 0..NIBBLES_PER_WORD {
            let row = w * NIBBLES_PER_WORD + j;
            for col in 0..n {
                let c = codes[row * n + col] as u32;
                debug_assert!(c <= 0xF);
                out[w * n + col] |= c << (4 * j);
            }
        }
    }
    out
}

/// Inverse of [`pack_rows`].
pub fn unpack_rows(qweight: &[u32], kw: usize, n: usize) -> Vec<u8> {
    assert_eq!(qweight.len(), kw * n);
    let k = kw * NIBBLES_PER_WORD;
    let mut out = vec![0u8; k * n];
    for w in 0..kw {
        for col in 0..n {
            let word = qweight[w * n + col];
            for j in 0..NIBBLES_PER_WORD {
                out[(w * NIBBLES_PER_WORD + j) * n + col] = ((word >> (4 * j)) & 0xF) as u8;
            }
        }
    }
    out
}

/// Pack zero-points `u8[G, N]` into `u32[G, N/8]`:
/// nibble `j` of word `w` holds column `8w + j`.
pub fn pack_cols(zeros: &[u8], g: usize, n: usize) -> Vec<u32> {
    assert_eq!(zeros.len(), g * n);
    assert_eq!(n % NIBBLES_PER_WORD, 0, "N must be a multiple of 8");
    let nw = n / NIBBLES_PER_WORD;
    let mut out = vec![0u32; g * nw];
    for gi in 0..g {
        for w in 0..nw {
            let mut word = 0u32;
            for j in 0..NIBBLES_PER_WORD {
                let z = zeros[gi * n + w * NIBBLES_PER_WORD + j] as u32;
                debug_assert!(z <= 0xF);
                word |= z << (4 * j);
            }
            out[gi * nw + w] = word;
        }
    }
    out
}

/// Inverse of [`pack_cols`].
pub fn unpack_cols(qzeros: &[u32], g: usize, nw: usize) -> Vec<u8> {
    assert_eq!(qzeros.len(), g * nw);
    let n = nw * NIBBLES_PER_WORD;
    let mut out = vec![0u8; g * n];
    for gi in 0..g {
        for w in 0..nw {
            let word = qzeros[gi * nw + w];
            for j in 0..NIBBLES_PER_WORD {
                out[gi * n + w * NIBBLES_PER_WORD + j] = ((word >> (4 * j)) & 0xF) as u8;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn pack_rows_nibble_order() {
        // Single column, rows 0..16 hold codes 0..16 (mod 16).
        let codes: Vec<u8> = (0..16).map(|i| (i % 16) as u8).collect();
        let packed = pack_rows(&codes, 16, 1);
        assert_eq!(packed.len(), 2);
        let expect0: u32 = (0..8).map(|j| (j as u32) << (4 * j)).sum();
        assert_eq!(packed[0], expect0);
    }

    #[test]
    fn pack_cols_nibble_order() {
        let zeros: Vec<u8> = (0..8).collect();
        let packed = pack_cols(&zeros, 1, 8);
        let expect: u32 = (0..8).map(|j| (j as u32) << (4 * j)).sum();
        assert_eq!(packed, vec![expect]);
    }

    #[test]
    fn roundtrip_rows() {
        let mut rng = Rng::new(1);
        let (k, n) = (64, 24);
        let codes: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let packed = pack_rows(&codes, k, n);
        assert_eq!(unpack_rows(&packed, k / 8, n), codes);
    }

    #[test]
    fn roundtrip_cols() {
        let mut rng = Rng::new(2);
        let (g, n) = (5, 32);
        let zeros: Vec<u8> = (0..g * n).map(|_| rng.below(16) as u8).collect();
        let packed = pack_cols(&zeros, g, n);
        assert_eq!(unpack_cols(&packed, g, n / 8), zeros);
    }

    #[test]
    #[should_panic(expected = "multiple of 8")]
    fn pack_rows_rejects_bad_k() {
        pack_rows(&[0u8; 12], 12, 1);
    }

    #[test]
    fn swizzle_octets_match_storage_layout() {
        let mut rng = Rng::new(3);
        let (k, n) = (64, 40);
        let kw = k / NIBBLES_PER_WORD;
        let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
        let swz = swizzle_weights(&qweight, kw, n);
        assert_eq!(swz.kw(), kw);
        assert_eq!(swz.n(), n);
        for o in 0..n / NIBBLES_PER_WORD {
            for w in 0..kw {
                let src = w * n + o * NIBBLES_PER_WORD;
                assert_eq!(
                    &swz.octet(o, w)[..],
                    &qweight[src..src + NIBBLES_PER_WORD],
                    "o={o} w={w}"
                );
            }
        }
    }

    #[test]
    fn unswizzle_is_the_exact_inverse() {
        let mut rng = Rng::new(5);
        let (kw, n) = (8, 48);
        let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
        let swz = swizzle_weights(&qweight, kw, n);
        assert_eq!(unswizzle_weights(&swz), qweight);
    }

    #[test]
    fn swizzle_flat_view_is_aligned_and_consistent() {
        let mut rng = Rng::new(4);
        let (kw, n) = (16, 24);
        let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
        let swz = swizzle_weights(&qweight, kw, n);
        let words = swz.words();
        assert_eq!(words.len(), kw * n);
        assert_eq!(words.as_ptr() as usize % 32, 0, "flat view must be 32-byte aligned");
        for o in 0..n / NIBBLES_PER_WORD {
            for w in 0..kw {
                let base = (o * kw + w) * NIBBLES_PER_WORD;
                assert_eq!(&words[base..base + NIBBLES_PER_WORD], &swz.octet(o, w)[..]);
            }
        }
    }
}

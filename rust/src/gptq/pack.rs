//! 4-bit nibble packing (the exllama-style GPTQ storage layout), plus the
//! vector-friendly prepacked ("swizzled") copy the explicit-SIMD kernels
//! stream from.
//!
//! The storage layout (`qweight: u32[K/8, N]`) is row-major over word
//! rows: walking one column-octet down the K axis touches one 32-byte
//! span per word row at an `N`-word stride.  [`SwizzledWeights`] is the
//! VML-Opt analogue of the paper's coalesced vector loads: a
//! column-interleaved copy in which a column group's entire K walk is one
//! contiguous, load-aligned stream, so each step of the fused inner loop
//! is a single aligned vector load feeding every lane.
//!
//! The interleave is parameterized by **lane width** — one prepack
//! routine ([`swizzle_weights_width`]) serves both SIMD kernels:
//!
//! * width 8 (AVX2): column-octet groups, each group's word rows
//!   contiguous and 32-byte aligned (one `ymm` load per step);
//! * width 16 (AVX-512): column-hexadectet groups, contiguous and
//!   64-byte aligned (one `zmm` load per step).  When `N % 16 == 8`,
//!   the odd trailing octet is laid out after the full groups as a
//!   32-byte-aligned octet stream (the kernel's `ymm` tail path).
//!
//! [`unswizzle_weights`] is the exact inverse at both widths — the cold
//! path raw-layout consumers (oracle parity, checkpointing) rebuild the
//! storage tensor through.

pub const NIBBLES_PER_WORD: usize = 8;

/// Backing storage block of the swizzle: sized and aligned for one
/// 512-bit load, which also satisfies the 256-bit alignment the 8-lane
/// layout needs (size 64 = align 64, no padding, so a `Vec<AlignBlock>`
/// is a contiguous aligned `[u32]`).
#[repr(C, align(64))]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct AlignBlock([u32; 16]);

/// Column-interleaved prepack of a `u32[K/8, N]` weight matrix at a
/// given lane width `L ∈ {8, 16}`: column group `g` (columns
/// `L·g..L·g+L`) holds its word rows `0..K/8` contiguously, one aligned
/// `L`-word vector load per row.  Computed once per tensor (see
/// `fused::PreparedTensor`) and reused by every serve-path projection —
/// the swizzle never runs on the hot path.
#[derive(Debug, Clone)]
pub struct SwizzledWeights {
    kw: usize,
    n: usize,
    lane_width: usize,
    blocks: Vec<AlignBlock>,
}

impl SwizzledWeights {
    /// Word rows per column (`K / 8`).
    pub fn kw(&self) -> usize {
        self.kw
    }

    /// Columns covered (`N`).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Column-interleave width of this prepack (8 or 16 lanes).
    pub fn lane_width(&self) -> usize {
        self.lane_width
    }

    /// Flat word index of `(col, word_row)` in [`Self::words`]: full
    /// `lane_width`-column groups first (group `g` row `w` starts at
    /// `(g·kw + w)·lane_width`), then — for the 16-lane layout of an
    /// `N % 16 == 8` tensor — the trailing octet as its own contiguous
    /// stream.  Exposed so tests can pin the layout/alignment contract;
    /// the SIMD kernels inline the same arithmetic.
    pub fn word_index(&self, col: usize, w: usize) -> usize {
        debug_assert!(col < self.n && w < self.kw);
        let full = self.n / self.lane_width;
        let g = col / self.lane_width;
        if g < full {
            (g * self.kw + w) * self.lane_width + col % self.lane_width
        } else {
            let tail = self.n % self.lane_width;
            full * self.kw * self.lane_width + w * tail + col % self.lane_width
        }
    }

    /// Word row `w` of column-octet `o` (columns `8o..8o+8`) — octets
    /// are contiguous 8-word spans at both lane widths, and never
    /// straddle an [`AlignBlock`] (indices are 8-aligned, blocks hold
    /// 16 words).
    #[inline]
    pub fn octet(&self, o: usize, w: usize) -> &[u32; 8] {
        let i = self.word_index(o * NIBBLES_PER_WORD, w);
        let lane = i % 16;
        self.blocks[i / 16].0[lane..lane + NIBBLES_PER_WORD].try_into().unwrap()
    }

    /// Flat 64-byte-aligned word view (`kw · n` words); the SIMD kernels
    /// index this directly via the [`Self::word_index`] arithmetic.
    pub fn words(&self) -> &[u32] {
        // SAFETY: AlignBlock is repr(C) over [u32; 16] with no padding
        // (size 64 == align 64), so the Vec's backing store is a valid
        // contiguous [u32] of 16 * blocks.len() >= kw * n elements.
        unsafe { std::slice::from_raw_parts(self.blocks.as_ptr() as *const u32, self.kw * self.n) }
    }

    fn words_mut(&mut self) -> &mut [u32] {
        // SAFETY: as in `words`.
        unsafe {
            std::slice::from_raw_parts_mut(self.blocks.as_mut_ptr() as *mut u32, self.kw * self.n)
        }
    }
}

/// Build the column-interleaved prepack of `qweight` (`u32[kw, n]`) at
/// `lane_width` ∈ {8, 16}.  `n` must be a multiple of 8; at width 16 an
/// `n % 16 == 8` tensor gets the trailing-octet layout (see the module
/// docs) so every valid packed tensor prepacks at either width.
pub fn swizzle_weights_width(
    qweight: &[u32],
    kw: usize,
    n: usize,
    lane_width: usize,
) -> SwizzledWeights {
    assert_eq!(qweight.len(), kw * n);
    assert!(lane_width == 8 || lane_width == 16, "lane width must be 8 or 16");
    assert_eq!(n % NIBBLES_PER_WORD, 0, "N must be a multiple of 8");
    let total = kw * n;
    let blocks = vec![AlignBlock([0; 16]); total.div_ceil(16)];
    let mut swz = SwizzledWeights { kw, n, lane_width, blocks };
    for o in 0..n / NIBBLES_PER_WORD {
        for w in 0..kw {
            let src = w * n + o * NIBBLES_PER_WORD;
            let dst = swz.word_index(o * NIBBLES_PER_WORD, w);
            swz.words_mut()[dst..dst + NIBBLES_PER_WORD]
                .copy_from_slice(&qweight[src..src + NIBBLES_PER_WORD]);
        }
    }
    swz
}

/// [`swizzle_weights_width`] at the 8-lane (AVX2) width.
pub fn swizzle_weights(qweight: &[u32], kw: usize, n: usize) -> SwizzledWeights {
    swizzle_weights_width(qweight, kw, n, NIBBLES_PER_WORD)
}

/// Inverse of [`swizzle_weights_width`] at either lane width: rebuild
/// the storage-layout `qweight` (`u32[kw, n]`) from the prepack.  Cold
/// path — used only when a raw-layout consumer (oracle parity,
/// checkpointing) needs the canonical tensor back from a serve-host
/// [`SwizzledWeights`]-only `PreparedTensor`.
pub fn unswizzle_weights(swz: &SwizzledWeights) -> Vec<u32> {
    let (kw, n) = (swz.kw(), swz.n());
    let mut qweight = vec![0u32; kw * n];
    for o in 0..n / NIBBLES_PER_WORD {
        for w in 0..kw {
            let dst = w * n + o * NIBBLES_PER_WORD;
            qweight[dst..dst + NIBBLES_PER_WORD].copy_from_slice(swz.octet(o, w));
        }
    }
    qweight
}

/// Pack codes `u8[K, N]` (values 0..=15) into `u32[K/8, N]`:
/// nibble `j` (bits `4j..4j+4`) of word `w` holds row `8w + j`.
pub fn pack_rows(codes: &[u8], k: usize, n: usize) -> Vec<u32> {
    assert_eq!(codes.len(), k * n);
    assert_eq!(k % NIBBLES_PER_WORD, 0, "K must be a multiple of 8");
    let kw = k / NIBBLES_PER_WORD;
    let mut out = vec![0u32; kw * n];
    for w in 0..kw {
        for j in 0..NIBBLES_PER_WORD {
            let row = w * NIBBLES_PER_WORD + j;
            for col in 0..n {
                let c = codes[row * n + col] as u32;
                debug_assert!(c <= 0xF);
                out[w * n + col] |= c << (4 * j);
            }
        }
    }
    out
}

/// Inverse of [`pack_rows`].
pub fn unpack_rows(qweight: &[u32], kw: usize, n: usize) -> Vec<u8> {
    assert_eq!(qweight.len(), kw * n);
    let k = kw * NIBBLES_PER_WORD;
    let mut out = vec![0u8; k * n];
    for w in 0..kw {
        for col in 0..n {
            let word = qweight[w * n + col];
            for j in 0..NIBBLES_PER_WORD {
                out[(w * NIBBLES_PER_WORD + j) * n + col] = ((word >> (4 * j)) & 0xF) as u8;
            }
        }
    }
    out
}

/// Pack zero-points `u8[G, N]` into `u32[G, N/8]`:
/// nibble `j` of word `w` holds column `8w + j`.
pub fn pack_cols(zeros: &[u8], g: usize, n: usize) -> Vec<u32> {
    assert_eq!(zeros.len(), g * n);
    assert_eq!(n % NIBBLES_PER_WORD, 0, "N must be a multiple of 8");
    let nw = n / NIBBLES_PER_WORD;
    let mut out = vec![0u32; g * nw];
    for gi in 0..g {
        for w in 0..nw {
            let mut word = 0u32;
            for j in 0..NIBBLES_PER_WORD {
                let z = zeros[gi * n + w * NIBBLES_PER_WORD + j] as u32;
                debug_assert!(z <= 0xF);
                word |= z << (4 * j);
            }
            out[gi * nw + w] = word;
        }
    }
    out
}

/// Inverse of [`pack_cols`].
pub fn unpack_cols(qzeros: &[u32], g: usize, nw: usize) -> Vec<u8> {
    assert_eq!(qzeros.len(), g * nw);
    let n = nw * NIBBLES_PER_WORD;
    let mut out = vec![0u8; g * n];
    for gi in 0..g {
        for w in 0..nw {
            let word = qzeros[gi * nw + w];
            for j in 0..NIBBLES_PER_WORD {
                out[gi * n + w * NIBBLES_PER_WORD + j] = ((word >> (4 * j)) & 0xF) as u8;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn pack_rows_nibble_order() {
        // Single column, rows 0..16 hold codes 0..16 (mod 16).
        let codes: Vec<u8> = (0..16).map(|i| (i % 16) as u8).collect();
        let packed = pack_rows(&codes, 16, 1);
        assert_eq!(packed.len(), 2);
        let expect0: u32 = (0..8).map(|j| (j as u32) << (4 * j)).sum();
        assert_eq!(packed[0], expect0);
    }

    #[test]
    fn pack_cols_nibble_order() {
        let zeros: Vec<u8> = (0..8).collect();
        let packed = pack_cols(&zeros, 1, 8);
        let expect: u32 = (0..8).map(|j| (j as u32) << (4 * j)).sum();
        assert_eq!(packed, vec![expect]);
    }

    #[test]
    fn roundtrip_rows() {
        let mut rng = Rng::new(1);
        let (k, n) = (64, 24);
        let codes: Vec<u8> = (0..k * n).map(|_| rng.below(16) as u8).collect();
        let packed = pack_rows(&codes, k, n);
        assert_eq!(unpack_rows(&packed, k / 8, n), codes);
    }

    #[test]
    fn roundtrip_cols() {
        let mut rng = Rng::new(2);
        let (g, n) = (5, 32);
        let zeros: Vec<u8> = (0..g * n).map(|_| rng.below(16) as u8).collect();
        let packed = pack_cols(&zeros, g, n);
        assert_eq!(unpack_cols(&packed, g, n / 8), zeros);
    }

    #[test]
    #[should_panic(expected = "multiple of 8")]
    fn pack_rows_rejects_bad_k() {
        pack_rows(&[0u8; 12], 12, 1);
    }

    #[test]
    fn swizzle_octets_match_storage_layout_at_both_widths() {
        let mut rng = Rng::new(3);
        let (k, n) = (64, 40);
        let kw = k / NIBBLES_PER_WORD;
        let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
        for width in [8, 16] {
            let swz = swizzle_weights_width(&qweight, kw, n, width);
            assert_eq!(swz.kw(), kw);
            assert_eq!(swz.n(), n);
            assert_eq!(swz.lane_width(), width);
            for o in 0..n / NIBBLES_PER_WORD {
                for w in 0..kw {
                    let src = w * n + o * NIBBLES_PER_WORD;
                    assert_eq!(
                        &swz.octet(o, w)[..],
                        &qweight[src..src + NIBBLES_PER_WORD],
                        "width={width} o={o} w={w}"
                    );
                }
            }
        }
    }

    #[test]
    fn unswizzle_is_the_exact_inverse_at_both_widths() {
        let mut rng = Rng::new(5);
        // n = 48: a multiple of 16; n = 40: exercises the 16-lane
        // layout's trailing octet.
        for (kw, n) in [(8usize, 48usize), (8, 40), (16, 8)] {
            let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
            for width in [8, 16] {
                let swz = swizzle_weights_width(&qweight, kw, n, width);
                assert_eq!(unswizzle_weights(&swz), qweight, "kw={kw} n={n} width={width}");
            }
        }
    }

    #[test]
    fn default_swizzle_is_the_eight_lane_layout() {
        let mut rng = Rng::new(6);
        let (kw, n) = (8, 48);
        let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
        let swz = swizzle_weights(&qweight, kw, n);
        assert_eq!(swz.lane_width(), 8);
        assert_eq!(unswizzle_weights(&swz), qweight);
    }

    #[test]
    fn swizzle_flat_view_is_aligned_and_consistent() {
        let mut rng = Rng::new(4);
        let (kw, n) = (16, 24);
        let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
        let swz = swizzle_weights(&qweight, kw, n);
        let words = swz.words();
        assert_eq!(words.len(), kw * n);
        assert_eq!(words.as_ptr() as usize % 64, 0, "flat view must be 64-byte aligned");
        for o in 0..n / NIBBLES_PER_WORD {
            for w in 0..kw {
                let base = (o * kw + w) * NIBBLES_PER_WORD;
                assert_eq!(swz.word_index(o * NIBBLES_PER_WORD, w), base);
                assert_eq!(&words[base..base + NIBBLES_PER_WORD], &swz.octet(o, w)[..]);
            }
        }
    }

    #[test]
    fn sixteen_lane_rows_are_zmm_aligned_and_tail_octets_ymm_aligned() {
        // The zmm-load contract of the 16-lane layout: every full
        // hexadectet's word row starts on a 64-byte boundary, and the
        // trailing octet rows (n % 16 == 8) on a 32-byte one.
        let mut rng = Rng::new(7);
        let (kw, n) = (8, 40); // 2 full hexadectets + trailing octet
        let qweight: Vec<u32> = (0..kw * n).map(|_| rng.next_u32()).collect();
        let swz = swizzle_weights_width(&qweight, kw, n, 16);
        let words = swz.words();
        assert_eq!(words.as_ptr() as usize % 64, 0);
        for h in 0..n / 16 {
            for w in 0..kw {
                let i = swz.word_index(h * 16, w);
                assert_eq!(i, (h * kw + w) * 16);
                let addr = unsafe { words.as_ptr().add(i) } as usize;
                assert_eq!(addr % 64, 0, "hexadectet h={h} w={w} must be zmm-aligned");
                // One contiguous 16-word row holds columns 16h..16h+16.
                for lane in 0..16 {
                    assert_eq!(words[i + lane], qweight[w * n + h * 16 + lane]);
                }
            }
        }
        let tail_col = n / 16 * 16;
        for w in 0..kw {
            let i = swz.word_index(tail_col, w);
            assert_eq!(i, (n / 16) * kw * 16 + w * 8);
            let addr = unsafe { words.as_ptr().add(i) } as usize;
            assert_eq!(addr % 32, 0, "tail octet w={w} must be ymm-aligned");
            for lane in 0..8 {
                assert_eq!(words[i + lane], qweight[w * n + tail_col + lane]);
            }
        }
    }
}

//! Runtime-dispatched explicit-SIMD kernels for [`super::fused`] — the
//! CPU embodiment of the paper's heterogeneous platform adaptation.
//!
//! The paper's three platform-level strategies map onto this module as:
//!
//! * **VML-Opt** (vectorized memory loads): each inner-loop step is one
//!   vector load of a column group's packed word row — 256-bit for the
//!   8-lane kernel, 512-bit for the 16-lane one — aligned when the
//!   tensor carries a [`SwizzledWeights`](super::pack::SwizzledWeights)
//!   prepack at the kernel's lane width, unaligned but still contiguous
//!   straight from the storage layout otherwise.
//! * **ILA-Opt** (native vector FMA): nibbles are unpacked 8 or 16 lanes
//!   at a time with shift/mask, converted once, and accumulated with
//!   `vfmadd231ps` (ymm or zmm); the group-factored flush
//!   `s·(Σx·c − z·Σx)` is evaluated entirely in vector registers.
//! * **SMB-Opt** (shared-memory tile buffering): per-column-tile partial
//!   outputs live in a stack scratch tile (`M_BLOCK × TILE_COLS`), so one
//!   group's activation slab plus the flush tile stay L1-resident.
//!
//! # The kernel registry
//!
//! Kernel selection happens **once** per process through
//! [`KernelDispatch`], which resolves against the [`kernel_registry`]
//! (ascending preference — auto-detection picks the widest supported
//! row):
//!
//! | kernel   | lanes | swizzle layout                     | required CPU features            | env override              |
//! |----------|-------|------------------------------------|----------------------------------|---------------------------|
//! | `scalar` | 1     | none (streams storage layout)      | —                                | `OPT4GPTQ_KERNEL=scalar`  |
//! | `avx2`   | 8     | 8-lane interleave, 32-byte aligned | `avx2`, `fma`                    | `OPT4GPTQ_KERNEL=avx2`    |
//! | `avx512` | 16    | 16-lane interleave, 64-byte aligned (odd trailing octet as a ymm stream) | `avx512f`, `avx512bw` (+`avx2`, `fma` for the tail path) | `OPT4GPTQ_KERNEL=avx512` |
//!
//! `OPT4GPTQ_KERNEL=<name>|auto` overrides detection for testing (the
//! CI forced-kernel matrix runs the full suite once per leg); requesting
//! a kernel the host cannot run — or an unknown name — falls back with a
//! single warning on stderr (emitted once, through the `OnceLock`
//! resolution) naming the valid set and the kernel actually chosen,
//! rather than faulting.  The AVX-512 kernel additionally requires a
//! toolchain with stable `_mm512_*` intrinsics (rustc ≥ 1.89, probed by
//! `build.rs`); older toolchains compile it out and the registry reports
//! it unsupported — the same graceful path as missing CPU features.
//!
//! A NEON port for aarch64 is the remaining open slot: the registry, the
//! width-parameterized swizzle, and the panel contract are ready for it.
//!
//! Parity across dispatch paths is pinned by `rust/tests/parity.rs`
//! (forced sweeps of every registry kernel against the dense oracle);
//! relative speed by `rust/benches/fused_gemm.rs`, which asserts SIMD ≥
//! scalar and (where detected) AVX-512 ≥ AVX2 on the headline decode
//! shape, best-of-N.

use std::sync::OnceLock;

use super::pack::NIBBLES_PER_WORD;

/// One fused-kernel implementation the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar tile loop (`fused::fused_panel_cols`) — relies on
    /// autovectorization, runs everywhere, bit-identical across releases.
    Scalar,
    /// Explicit AVX2+FMA octet kernel (x86-64 only, runtime-detected).
    Avx2,
    /// Explicit AVX-512F/BW hexadectet kernel (x86-64 only,
    /// runtime-detected, compiled only on toolchains with stable
    /// AVX-512 intrinsics).
    Avx512,
}

impl Kernel {
    /// The registry row describing this kernel — the single source of
    /// truth for its name, lane width, swizzle layout and required
    /// features.
    pub fn info(self) -> &'static KernelInfo {
        kernel_registry()
            .iter()
            .find(|info| info.kernel == self)
            .expect("every kernel has a registry row")
    }

    /// Stable lowercase name (used by `OPT4GPTQ_KERNEL`, the CI matrix,
    /// and bench JSON).
    pub fn name(self) -> &'static str {
        self.info().name
    }

    /// f32 lanes per vector FMA (1 = scalar autovectorization).
    pub fn lanes(self) -> usize {
        self.info().lanes
    }

    /// Column-interleave width of the swizzled prepack this kernel
    /// streams aligned loads from (`None`: streams the storage layout).
    /// `fused::PreparedTensor` builds the swizzle at this width once at
    /// model build, so the serve path never re-swizzles.
    pub fn swizzle_width(self) -> Option<usize> {
        self.info().swizzle_width
    }

    /// Column granularity the threaded column split must respect so
    /// every worker's slab keeps this kernel's load alignment (the
    /// packed nibble width for scalar/AVX2, a full hexadectet for
    /// AVX-512).
    pub fn col_align(self) -> usize {
        self.swizzle_width().unwrap_or(NIBBLES_PER_WORD)
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One row of the kernel registry: everything the dispatcher, the docs
/// table, and the CI forced-kernel matrix need to know about a kernel.
#[derive(Debug, Clone, Copy)]
pub struct KernelInfo {
    pub kernel: Kernel,
    /// Stable name (`OPT4GPTQ_KERNEL` value, bench JSON, CI matrix leg).
    pub name: &'static str,
    /// f32 lanes per vector FMA.
    pub lanes: usize,
    /// Column-interleave width of the aligned prepack (`None` = raw).
    pub swizzle_width: Option<usize>,
    /// CPU features [`supports`] requires at runtime.
    pub required_features: &'static [&'static str],
}

static REGISTRY: [KernelInfo; 3] = [
    KernelInfo {
        kernel: Kernel::Scalar,
        name: "scalar",
        lanes: 1,
        swizzle_width: None,
        required_features: &[],
    },
    KernelInfo {
        kernel: Kernel::Avx2,
        name: "avx2",
        lanes: 8,
        swizzle_width: Some(8),
        required_features: &["avx2", "fma"],
    },
    KernelInfo {
        kernel: Kernel::Avx512,
        name: "avx512",
        lanes: 16,
        swizzle_width: Some(16),
        required_features: &["avx512f", "avx512bw", "avx2", "fma"],
    },
];

/// The kernel registry, in ascending preference order: auto-detection
/// picks the **last** supported row, `OPT4GPTQ_KERNEL` values resolve
/// against the `name` column, and tests/CI iterate it so a new kernel
/// is swept the moment it is registered.
pub fn kernel_registry() -> &'static [KernelInfo] {
    &REGISTRY
}

/// Whether `kernel` can run on this host (CPU features present and the
/// kernel compiled in).
pub fn supports(kernel: Kernel) -> bool {
    match kernel {
        Kernel::Scalar => true,
        Kernel::Avx2 => avx2_supported(),
        Kernel::Avx512 => avx512_supported(),
    }
}

/// Every kernel this host can run (scalar always; wider ones when
/// detected), in registry order.  Tests iterate this to sweep all
/// dispatchable paths.
pub fn available_kernels() -> Vec<Kernel> {
    kernel_registry().iter().map(|info| info.kernel).filter(|&k| supports(k)).collect()
}

fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn avx512_supported() -> bool {
    #[cfg(all(target_arch = "x86_64", opt4gptq_avx512_intrinsics))]
    {
        is_x86_feature_detected!("avx512f")
            && is_x86_feature_detected!("avx512bw")
            && is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
    }
    #[cfg(not(all(target_arch = "x86_64", opt4gptq_avx512_intrinsics)))]
    {
        false
    }
}

/// Process-wide kernel selection, resolved once on first use: the
/// registry analogue of the paper's per-platform kernel binding.
#[derive(Debug, Clone, Copy)]
pub struct KernelDispatch {
    /// The kernel every auto-dispatched fused call runs through.
    pub kernel: Kernel,
    /// How it was chosen: `"auto"` (feature detection), `"env"`
    /// (`OPT4GPTQ_KERNEL`), or `"fallback"` (env requested an
    /// unavailable or unknown kernel).
    pub source: &'static str,
}

static DISPATCH: OnceLock<KernelDispatch> = OnceLock::new();
static DISPATCH_ENV: OnceLock<crate::envcfg::EnvOverride<Kernel>> = OnceLock::new();

impl KernelDispatch {
    /// The resolved process-wide dispatch entry.  The environment is read
    /// exactly once through [`crate::envcfg`] — later changes to
    /// `OPT4GPTQ_KERNEL` have no effect, and any override warning is
    /// emitted exactly once.  Empty and `auto` mean feature detection; a
    /// known-but-unsupported or unknown kernel name warns and falls back
    /// to detection (`source: "fallback"`).
    pub fn get() -> KernelDispatch {
        *DISPATCH.get_or_init(|| {
            let resolved =
                crate::envcfg::env_override(&DISPATCH_ENV, "OPT4GPTQ_KERNEL", |raw| {
                    let requested = raw.to_ascii_lowercase();
                    match kernel_registry().iter().find(|info| info.name == requested) {
                        Some(info) if supports(info.kernel) => Ok(info.kernel),
                        Some(info) => Err(format!(
                            "OPT4GPTQ_KERNEL={} requested, but this host cannot run \
                             it (needs {}, or the toolchain compiled it out); falling \
                             back to auto-detected '{}'",
                            info.name,
                            info.required_features.join("+"),
                            KernelDispatch::auto().kernel,
                        )),
                        None => {
                            let valid: Vec<&str> =
                                kernel_registry().iter().map(|i| i.name).collect();
                            Err(format!(
                                "unknown OPT4GPTQ_KERNEL={requested:?} (valid values: \
                                 {}|auto); falling back to auto-detected '{}'",
                                valid.join("|"),
                                KernelDispatch::auto().kernel,
                            ))
                        }
                    }
                });
            match resolved {
                crate::envcfg::EnvOverride::Value(k) => {
                    KernelDispatch { kernel: *k, source: "env" }
                }
                crate::envcfg::EnvOverride::Invalid => {
                    KernelDispatch { kernel: KernelDispatch::auto().kernel, source: "fallback" }
                }
                crate::envcfg::EnvOverride::Unset | crate::envcfg::EnvOverride::Auto => {
                    KernelDispatch::auto()
                }
            }
        })
    }

    fn auto() -> KernelDispatch {
        let kernel = kernel_registry()
            .iter()
            .rev()
            .map(|info| info.kernel)
            .find(|&k| supports(k))
            .unwrap_or(Kernel::Scalar);
        KernelDispatch { kernel, source: "auto" }
    }
}

/// The kernel auto-dispatched fused calls run through.
pub fn active_kernel() -> Kernel {
    KernelDispatch::get().kernel
}

/// Process-wide binary16 slice converter, resolved once like
/// [`KernelDispatch`]: the F16C `vcvtph2ps`/`vcvtps2ph` fast path when
/// the host has it **and** the active kernel is vectorized, the
/// software [`crate::f16::F16`] converter otherwise — so
/// `OPT4GPTQ_KERNEL=scalar` forces the scalar converter too and the CI
/// forced-kernel matrix sweeps both paths.  The two agree bitwise on
/// every non-NaN value (NaNs stay NaNs but may differ in payload);
/// pinned by `f16_slice_converters_match_software` below.
struct F16Converter {
    dequant: fn(&[u16], &mut [f32]),
    quant: fn(&[f32], &mut [u16]),
    name: &'static str,
}

static F16_CONVERTER: OnceLock<F16Converter> = OnceLock::new();

fn f16_converter() -> &'static F16Converter {
    F16_CONVERTER.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if active_kernel() != Kernel::Scalar && is_x86_feature_detected!("f16c") {
                return F16Converter {
                    dequant: f16c::dequant_slice,
                    quant: f16c::quant_slice,
                    name: "f16c",
                };
            }
        }
        F16Converter {
            dequant: f16_dequant_scalar,
            quant: f16_quant_scalar,
            name: "scalar",
        }
    })
}

/// Name of the resolved binary16 converter (`"f16c"` or `"scalar"`).
pub fn f16_converter_name() -> &'static str {
    f16_converter().name
}

/// Convert a slice of IEEE binary16 bit patterns to f32 (the quantized
/// KV cache's hot read path — one call per block tile, never a
/// per-element scalar round-trip under a vector kernel).
pub fn f16_dequant_slice(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "f16 dequant length mismatch");
    (f16_converter().dequant)(src, dst)
}

/// Convert a slice of f32 values to IEEE binary16 bit patterns
/// (round-to-nearest-even; the KV cache's append path).
pub fn f16_quant_slice(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len(), "f16 quant length mismatch");
    (f16_converter().quant)(src, dst)
}

/// Software converter half of the dispatch (also the sub-octet tail of
/// the F16C path).
fn f16_dequant_scalar(src: &[u16], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = crate::f16::F16(s).to_f32();
    }
}

fn f16_quant_scalar(src: &[f32], dst: &mut [u16]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = crate::f16::F16::from_f32(s).0;
    }
}

#[cfg(target_arch = "x86_64")]
mod f16c {
    use std::arch::x86_64::*;

    pub(super) fn dequant_slice(src: &[u16], dst: &mut [f32]) {
        assert!(
            is_x86_feature_detected!("f16c"),
            "F16C converter dispatched on a host without f16c"
        );
        // SAFETY: F16C presence asserted above.
        unsafe { dequant_impl(src, dst) }
    }

    pub(super) fn quant_slice(src: &[f32], dst: &mut [u16]) {
        assert!(
            is_x86_feature_detected!("f16c"),
            "F16C converter dispatched on a host without f16c"
        );
        // SAFETY: F16C presence asserted above.
        unsafe { quant_impl(src, dst) }
    }

    /// # Safety
    /// Caller must have verified F16C at runtime; `src.len() == dst.len()`.
    #[target_feature(enable = "f16c")]
    unsafe fn dequant_impl(src: &[u16], dst: &mut [f32]) {
        let n8 = src.len() / 8 * 8;
        let mut i = 0;
        while i < n8 {
            let h = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_cvtph_ps(h));
            i += 8;
        }
        super::f16_dequant_scalar(&src[n8..], &mut dst[n8..]);
    }

    /// # Safety
    /// Caller must have verified F16C at runtime; `src.len() == dst.len()`.
    #[target_feature(enable = "f16c")]
    unsafe fn quant_impl(src: &[f32], dst: &mut [u16]) {
        let n8 = src.len() / 8 * 8;
        let mut i = 0;
        while i < n8 {
            let v = _mm256_loadu_ps(src.as_ptr().add(i));
            let h = _mm256_cvtps_ph::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(v);
            _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, h);
            i += 8;
        }
        super::f16_quant_scalar(&src[n8..], &mut dst[n8..]);
    }
}

/// AVX2+FMA panel kernel: same contract as `fused::fused_panel_cols`
/// (column window `[c0, c0+cn)` of one gathered M-block, `out` a zeroed
/// `[mb, cn]` window), plus an optional swizzled weight view for aligned
/// streaming loads.  Caller must have verified [`supports`]`(Avx2)`.
#[cfg(target_arch = "x86_64")]
pub(crate) fn panel_avx2(
    call: &super::fused::KernelCall<'_>,
    xg: &[f32],
    xsum: &[f32],
    mb: usize,
    c0: usize,
    cn: usize,
    out: &mut [f32],
) {
    let q = call.q;
    assert!(avx2_supported(), "AVX2 kernel dispatched on a host without AVX2+FMA");
    assert!(mb <= super::fused::M_BLOCK);
    assert_eq!(xg.len(), mb * q.k);
    assert_eq!(out.len(), mb * cn);
    assert_eq!(c0 % 8, 0, "column window must be octet-aligned");
    assert_eq!(cn % 8, 0, "column window width must be a multiple of 8");
    assert_eq!(q.group_size % 8, 0, "group size must be a multiple of 8");
    assert_eq!(q.k % q.group_size, 0, "group size must divide K");
    if cn == 0 || mb == 0 {
        return;
    }
    let geom = x86::Geom {
        qweight: &q.qweight,
        qzeros: &q.qzeros,
        scales: &q.scales,
        swz: call.swz.map(|s| s.words()).unwrap_or(&[]),
        k: q.k,
        n: q.n,
        kw: q.k / 8,
        nw: q.n / 8,
        wpg: q.group_size / 8,
        groups: q.k / q.group_size,
    };
    if let Some(s) = call.swz {
        assert_eq!(s.lane_width(), 8, "AVX2 kernel needs the 8-lane swizzle");
        assert_eq!(s.kw(), geom.kw, "swizzle K mismatch");
        assert_eq!(s.n(), q.n, "swizzle N mismatch");
        // SAFETY: AVX2+FMA presence asserted above.
        unsafe { x86::tiles::<true>(&geom, xg, xsum, mb, c0, cn, out) }
    } else {
        // SAFETY: AVX2+FMA presence asserted above.
        unsafe { x86::tiles::<false>(&geom, xg, xsum, mb, c0, cn, out) }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use crate::gptq::fused::M_BLOCK;
    use std::arch::x86_64::*;

    /// Column-tile width of the SIMD path: the `M_BLOCK × TILE_COLS` f32
    /// flush tile (8 KiB — the SMB-Opt stack scratch) plus one group's
    /// activation slab stays L1-resident while weights stream through.
    pub(super) const TILE_COLS: usize = 256;

    /// Octet-group width for the `mb = 1` decode GEMV: four independent
    /// accumulator chains hide the FMA latency a single running sum
    /// would serialize on.
    const GEMV_OG: usize = 4;

    /// Resolved tensor geometry shared by the tile and octet loops.
    pub(super) struct Geom<'a> {
        pub qweight: &'a [u32],
        pub qzeros: &'a [u32],
        pub scales: &'a [f32],
        /// Flat swizzled view (`pack::SwizzledWeights::words`); empty
        /// when streaming straight from the storage layout.
        pub swz: &'a [u32],
        pub k: usize,
        pub n: usize,
        pub kw: usize,
        pub nw: usize,
        /// Words per group slab (`group_size / 8`).
        pub wpg: usize,
        pub groups: usize,
    }

    /// Tile loop over the column window: walk `[c0, c0+cn)` in
    /// `TILE_COLS` tiles, K in group slabs, flushing each group's
    /// register accumulators into the stack scratch tile.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA at runtime and the geometry
    /// invariants checked by [`super::panel_avx2`].
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn tiles<const SWZ: bool>(
        geom: &Geom<'_>,
        xg: &[f32],
        xsum: &[f32],
        mb: usize,
        c0: usize,
        cn: usize,
        out: &mut [f32],
    ) {
        let mut ytile = [0.0f32; M_BLOCK * TILE_COLS];
        let mut cb = 0usize;
        while cb < cn {
            let nb = TILE_COLS.min(cn - cb);
            let octs = nb / 8;
            let oct0 = (c0 + cb) / 8; // absolute first octet of this tile
            for mi in 0..mb {
                ytile[mi * TILE_COLS..mi * TILE_COLS + nb].fill(0.0);
            }
            for gi in 0..geom.groups {
                let mut oi = 0usize;
                if mb == 1 {
                    // Decode GEMV: 4-octet groups, 4 independent chains.
                    while oi + GEMV_OG <= octs {
                        group_octets::<1, GEMV_OG, SWZ>(
                            geom,
                            xg,
                            xsum,
                            gi,
                            oct0 + oi,
                            &mut ytile,
                            oi * 8,
                        );
                        oi += GEMV_OG;
                    }
                }
                while oi < octs {
                    let o0 = oct0 + oi;
                    let yc = oi * 8;
                    match mb {
                        1 => group_octets::<1, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        2 => group_octets::<2, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        3 => group_octets::<3, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        4 => group_octets::<4, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        5 => group_octets::<5, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        6 => group_octets::<6, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        7 => group_octets::<7, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        8 => group_octets::<8, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        _ => unreachable!("mb is capped at M_BLOCK"),
                    }
                    oi += 1;
                }
            }
            for mi in 0..mb {
                out[mi * cn + cb..mi * cn + cb + nb]
                    .copy_from_slice(&ytile[mi * TILE_COLS..mi * TILE_COLS + nb]);
            }
            cb += nb;
        }
    }

    /// One group slab × `OG` column-octets × `MB` activation rows, fully
    /// register-resident: `MB×OG` running sums accumulate `Σ x·code`
    /// with `vfmadd231ps` over the slab's word rows (8-lane nibble
    /// unpack via shift/mask per row), then the group-factored flush
    /// `y += s·(acc − z·Σx)` lands in the scratch tile at `ycol`.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA at runtime; `o0 + OG` octets
    /// and `ycol + OG*8` columns must be in bounds.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn group_octets<const MB: usize, const OG: usize, const SWZ: bool>(
        geom: &Geom<'_>,
        xg: &[f32],
        xsum: &[f32],
        gi: usize,
        o0: usize,
        ytile: &mut [f32],
        ycol: usize,
    ) {
        let mask = _mm256_set1_epi32(0xF);
        let w0 = gi * geom.wpg;
        let mut acc = [[_mm256_setzero_ps(); OG]; MB];
        for dw in 0..geom.wpg {
            let w = w0 + dw;
            // One 256-bit load per octet feeds all 8 lanes (VML-Opt):
            // aligned from the swizzled stream, unaligned-contiguous
            // straight from the storage layout otherwise.
            let mut words = [_mm256_setzero_si256(); OG];
            for (oc, wrd) in words.iter_mut().enumerate() {
                *wrd = if SWZ {
                    _mm256_load_si256(
                        geom.swz.as_ptr().add(((o0 + oc) * geom.kw + w) * 8) as *const __m256i
                    )
                } else {
                    _mm256_loadu_si256(
                        geom.qweight.as_ptr().add(w * geom.n + (o0 + oc) * 8) as *const __m256i
                    )
                };
            }
            // Eight nibble rows per word: shift/mask unpack, convert
            // once, FMA into every row's accumulator (ILA-Opt).
            for j in 0..8 {
                let mut nib = [_mm256_setzero_ps(); OG];
                for (oc, nb) in nib.iter_mut().enumerate() {
                    *nb = _mm256_cvtepi32_ps(_mm256_and_si256(words[oc], mask));
                    words[oc] = _mm256_srli_epi32::<4>(words[oc]);
                }
                for (mi, arow) in acc.iter_mut().enumerate() {
                    let xv = _mm256_set1_ps(*xg.get_unchecked(mi * geom.k + w * 8 + j));
                    for (oc, a) in arow.iter_mut().enumerate() {
                        *a = _mm256_fmadd_ps(xv, nib[oc], *a);
                    }
                }
            }
        }
        // Group-factored flush, entirely in vector registers:
        // y += s·(acc − z·Σx).
        let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
        for oc in 0..OG {
            let o = o0 + oc;
            let zword = *geom.qzeros.get_unchecked(gi * geom.nw + o) as i32;
            let z = _mm256_cvtepi32_ps(_mm256_and_si256(
                _mm256_srlv_epi32(_mm256_set1_epi32(zword), shifts),
                mask,
            ));
            let s = _mm256_loadu_ps(geom.scales.as_ptr().add(gi * geom.n + o * 8));
            for (mi, arow) in acc.iter().enumerate() {
                let xs = _mm256_set1_ps(*xsum.get_unchecked(mi * geom.groups + gi));
                let yp = ytile.as_mut_ptr().add(mi * TILE_COLS + ycol + oc * 8);
                let y = _mm256_loadu_ps(yp);
                _mm256_storeu_ps(
                    yp,
                    _mm256_fmadd_ps(s, _mm256_sub_ps(arow[oc], _mm256_mul_ps(z, xs)), y),
                );
            }
        }
    }
}

/// AVX-512F/BW panel kernel: same contract as [`panel_avx2`], 16 lanes
/// wide — one 512-bit load per hexadectet (16 columns) per word row,
/// zmm shift/mask nibble unpack and `vfmadd231ps`, the group-factored
/// flush held in zmm registers, and a widened register tile (4
/// independent zmm chains, 64 columns in flight) on the M=1 decode
/// path.  An `N % 16 == 8` tensor's trailing octet runs through a ymm
/// tail path so every octet-aligned window is accepted.  Caller must
/// have verified [`supports`]`(Avx512)`.
#[cfg(all(target_arch = "x86_64", opt4gptq_avx512_intrinsics))]
pub(crate) fn panel_avx512(
    call: &super::fused::KernelCall<'_>,
    xg: &[f32],
    xsum: &[f32],
    mb: usize,
    c0: usize,
    cn: usize,
    out: &mut [f32],
) {
    let q = call.q;
    assert!(avx512_supported(), "AVX-512 kernel dispatched on a host without AVX-512F/BW");
    assert!(mb <= super::fused::M_BLOCK);
    assert_eq!(xg.len(), mb * q.k);
    assert_eq!(out.len(), mb * cn);
    // The column split aligns slabs to `Kernel::col_align() == 16`, so
    // windows start hexadectet-aligned; only the matrix's trailing octet
    // (N % 16 == 8, always at the end of the last window) is narrower.
    assert_eq!(c0 % 16, 0, "column window must be hexadectet-aligned");
    assert_eq!(cn % 8, 0, "column window width must be a multiple of 8");
    assert_eq!(q.group_size % 8, 0, "group size must be a multiple of 8");
    assert_eq!(q.k % q.group_size, 0, "group size must divide K");
    if cn % 16 != 0 {
        assert_eq!(c0 + cn, q.n, "an octet-ragged window must end the matrix");
    }
    if cn == 0 || mb == 0 {
        return;
    }
    let geom = x86_512::Geom {
        qweight: &q.qweight,
        qzeros: &q.qzeros,
        scales: &q.scales,
        swz: call.swz.map(|s| s.words()).unwrap_or(&[]),
        k: q.k,
        n: q.n,
        nw: q.n / 8,
        kw: q.k / 8,
        wpg: q.group_size / 8,
        groups: q.k / q.group_size,
        full_hex: q.n / 16,
    };
    if let Some(s) = call.swz {
        assert_eq!(s.lane_width(), 16, "AVX-512 kernel needs the 16-lane swizzle");
        assert_eq!(s.kw(), geom.kw, "swizzle K mismatch");
        assert_eq!(s.n(), q.n, "swizzle N mismatch");
        // SAFETY: AVX-512F/BW (+AVX2/FMA) presence asserted above.
        unsafe { x86_512::tiles::<true>(&geom, xg, xsum, mb, c0, cn, out) }
    } else {
        // SAFETY: AVX-512F/BW (+AVX2/FMA) presence asserted above.
        unsafe { x86_512::tiles::<false>(&geom, xg, xsum, mb, c0, cn, out) }
    }
}

#[cfg(all(target_arch = "x86_64", opt4gptq_avx512_intrinsics))]
mod x86_512 {
    use crate::gptq::fused::M_BLOCK;
    use std::arch::x86_64::*;

    /// Column-tile width, shared with the AVX2 path: the flush tile is
    /// the same 8 KiB `M_BLOCK × 256` f32 SMB-Opt stack scratch.
    pub(super) const TILE_COLS: usize = 256;

    /// Hexadectet-group width for the `mb = 1` decode GEMV: four
    /// independent zmm accumulator chains (64 columns in flight) hide
    /// the FMA latency — the widened-register-tile analogue of the AVX2
    /// path's 4-octet grouping.
    const GEMV_HG: usize = 4;

    /// Resolved tensor geometry shared by the tile, hexadectet, and
    /// tail-octet loops.
    pub(super) struct Geom<'a> {
        pub qweight: &'a [u32],
        pub qzeros: &'a [u32],
        pub scales: &'a [f32],
        /// Flat 16-lane swizzled view; empty when streaming straight
        /// from the storage layout.
        pub swz: &'a [u32],
        pub k: usize,
        pub n: usize,
        pub kw: usize,
        pub nw: usize,
        /// Words per group slab (`group_size / 8`).
        pub wpg: usize,
        pub groups: usize,
        /// Full 16-column groups of the swizzle layout (`N / 16`); the
        /// odd trailing octet (when `N % 16 == 8`) lives after them.
        pub full_hex: usize,
    }

    /// Tile loop over the column window: walk `[c0, c0+cn)` in
    /// `TILE_COLS` tiles, K in group slabs, flushing each group's zmm
    /// accumulators into the stack scratch tile; an octet-ragged final
    /// tile finishes through the ymm tail path.
    ///
    /// # Safety
    /// Caller must have verified AVX-512F/BW (+AVX2/FMA) at runtime and
    /// the geometry invariants checked by [`super::panel_avx512`].
    #[target_feature(enable = "avx512f,avx512bw,avx2,fma")]
    pub(super) unsafe fn tiles<const SWZ: bool>(
        geom: &Geom<'_>,
        xg: &[f32],
        xsum: &[f32],
        mb: usize,
        c0: usize,
        cn: usize,
        out: &mut [f32],
    ) {
        let mut ytile = [0.0f32; M_BLOCK * TILE_COLS];
        let mut cb = 0usize;
        while cb < cn {
            let nb = TILE_COLS.min(cn - cb);
            let hexes = nb / 16;
            let tail = nb % 16; // 0, or 8: the matrix's trailing octet
            let hex0 = (c0 + cb) / 16; // absolute first hexadectet
            for mi in 0..mb {
                ytile[mi * TILE_COLS..mi * TILE_COLS + nb].fill(0.0);
            }
            for gi in 0..geom.groups {
                let mut hi = 0usize;
                if mb == 1 {
                    // Decode GEMV: 4-hexadectet groups, 4 independent
                    // zmm chains (the widened register tile).
                    while hi + GEMV_HG <= hexes {
                        group_hexes::<1, GEMV_HG, SWZ>(
                            geom,
                            xg,
                            xsum,
                            gi,
                            hex0 + hi,
                            &mut ytile,
                            hi * 16,
                        );
                        hi += GEMV_HG;
                    }
                }
                while hi < hexes {
                    let h0 = hex0 + hi;
                    let yc = hi * 16;
                    match mb {
                        1 => group_hexes::<1, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        2 => group_hexes::<2, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        3 => group_hexes::<3, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        4 => group_hexes::<4, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        5 => group_hexes::<5, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        6 => group_hexes::<6, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        7 => group_hexes::<7, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        8 => group_hexes::<8, 1, SWZ>(geom, xg, xsum, gi, h0, &mut ytile, yc),
                        _ => unreachable!("mb is capped at M_BLOCK"),
                    }
                    hi += 1;
                }
                if tail != 0 {
                    let col = c0 + cb + hexes * 16; // absolute tail column
                    let yc = hexes * 16;
                    match mb {
                        1 => tail_octet::<1, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        2 => tail_octet::<2, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        3 => tail_octet::<3, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        4 => tail_octet::<4, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        5 => tail_octet::<5, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        6 => tail_octet::<6, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        7 => tail_octet::<7, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        8 => tail_octet::<8, SWZ>(geom, xg, xsum, gi, col, &mut ytile, yc),
                        _ => unreachable!("mb is capped at M_BLOCK"),
                    }
                }
            }
            for mi in 0..mb {
                out[mi * cn + cb..mi * cn + cb + nb]
                    .copy_from_slice(&ytile[mi * TILE_COLS..mi * TILE_COLS + nb]);
            }
            cb += nb;
        }
    }

    /// One group slab × `HG` column-hexadectets × `MB` activation rows,
    /// fully register-resident: `MB×HG` zmm running sums accumulate
    /// `Σ x·code` with `vfmadd231ps` over the slab's word rows (16-lane
    /// nibble unpack via shift/mask per row), then the group-factored
    /// flush `y += s·(acc − z·Σx)` lands in the scratch tile at `ycol`.
    /// Per column the operation sequence is identical to the AVX2
    /// kernel's, so the two agree bitwise.
    ///
    /// # Safety
    /// Caller must have verified AVX-512F/BW at runtime; `h0 + HG`
    /// hexadectets and `ycol + HG*16` columns must be in bounds.
    #[target_feature(enable = "avx512f,avx512bw,avx2,fma")]
    unsafe fn group_hexes<const MB: usize, const HG: usize, const SWZ: bool>(
        geom: &Geom<'_>,
        xg: &[f32],
        xsum: &[f32],
        gi: usize,
        h0: usize,
        ytile: &mut [f32],
        ycol: usize,
    ) {
        let mask = _mm512_set1_epi32(0xF);
        let w0 = gi * geom.wpg;
        let mut acc = [[_mm512_setzero_ps(); HG]; MB];
        for dw in 0..geom.wpg {
            let w = w0 + dw;
            // One 512-bit load per hexadectet feeds all 16 lanes
            // (VML-Opt): aligned from the 16-lane swizzled stream,
            // unaligned-contiguous from the storage layout otherwise.
            let mut words = [_mm512_setzero_si512(); HG];
            for (hc, wrd) in words.iter_mut().enumerate() {
                // `.cast()` lets inference pick the load's pointer
                // parameter type (it differs across stdarch releases).
                *wrd = if SWZ {
                    _mm512_load_si512(geom.swz.as_ptr().add(((h0 + hc) * geom.kw + w) * 16).cast())
                } else {
                    _mm512_loadu_si512(
                        geom.qweight.as_ptr().add(w * geom.n + (h0 + hc) * 16).cast(),
                    )
                };
            }
            // Eight nibble rows per word: shift/mask unpack, convert
            // once, FMA into every row's accumulator (ILA-Opt).
            for j in 0..8 {
                let mut nib = [_mm512_setzero_ps(); HG];
                for (hc, nb) in nib.iter_mut().enumerate() {
                    *nb = _mm512_cvtepi32_ps(_mm512_and_si512(words[hc], mask));
                    words[hc] = _mm512_srli_epi32::<4>(words[hc]);
                }
                for (mi, arow) in acc.iter_mut().enumerate() {
                    let xv = _mm512_set1_ps(*xg.get_unchecked(mi * geom.k + w * 8 + j));
                    for (hc, a) in arow.iter_mut().enumerate() {
                        *a = _mm512_fmadd_ps(xv, nib[hc], *a);
                    }
                }
            }
        }
        // Group-factored flush, entirely in zmm registers:
        // y += s·(acc − z·Σx).  A hexadectet's 16 zero nibbles live in
        // TWO qzeros words — broadcast each into one 256-bit half, then
        // shift/mask decode all 16 lanes at once.
        let shifts = _mm512_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28, 0, 4, 8, 12, 16, 20, 24, 28);
        for hc in 0..HG {
            let h = h0 + hc;
            let zlo = *geom.qzeros.get_unchecked(gi * geom.nw + h * 2) as i32;
            let zhi = *geom.qzeros.get_unchecked(gi * geom.nw + h * 2 + 1) as i32;
            let zwords = _mm512_inserti64x4::<1>(
                _mm512_castsi256_si512(_mm256_set1_epi32(zlo)),
                _mm256_set1_epi32(zhi),
            );
            let z = _mm512_cvtepi32_ps(_mm512_and_si512(_mm512_srlv_epi32(zwords, shifts), mask));
            let s = _mm512_loadu_ps(geom.scales.as_ptr().add(gi * geom.n + h * 16));
            for (mi, arow) in acc.iter().enumerate() {
                let xs = _mm512_set1_ps(*xsum.get_unchecked(mi * geom.groups + gi));
                let yp = ytile.as_mut_ptr().add(mi * TILE_COLS + ycol + hc * 16);
                let y = _mm512_loadu_ps(yp);
                _mm512_storeu_ps(
                    yp,
                    _mm512_fmadd_ps(s, _mm512_sub_ps(arow[hc], _mm512_mul_ps(z, xs)), y),
                );
            }
        }
    }

    /// The trailing octet of an `N % 16 == 8` tensor: one group slab ×
    /// 1 octet × `MB` rows through ymm ops (same per-column operation
    /// sequence as the AVX2 kernel, so parity is preserved bitwise).
    /// In the 16-lane swizzle the tail stream lives after the full
    /// hexadectet groups, 32-byte aligned.
    ///
    /// # Safety
    /// Caller must have verified AVX-512F/BW (+AVX2/FMA) at runtime;
    /// `col` must be the matrix's final octet and `ycol + 8` columns of
    /// the tile in bounds.
    #[target_feature(enable = "avx512f,avx512bw,avx2,fma")]
    unsafe fn tail_octet<const MB: usize, const SWZ: bool>(
        geom: &Geom<'_>,
        xg: &[f32],
        xsum: &[f32],
        gi: usize,
        col: usize,
        ytile: &mut [f32],
        ycol: usize,
    ) {
        debug_assert_eq!(col, geom.full_hex * 16, "tail octet must be the matrix's last");
        let mask = _mm256_set1_epi32(0xF);
        let w0 = gi * geom.wpg;
        let tail_base = geom.full_hex * geom.kw * 16;
        let mut acc = [_mm256_setzero_ps(); MB];
        for dw in 0..geom.wpg {
            let w = w0 + dw;
            let mut word = if SWZ {
                _mm256_load_si256(geom.swz.as_ptr().add(tail_base + w * 8) as *const __m256i)
            } else {
                _mm256_loadu_si256(geom.qweight.as_ptr().add(w * geom.n + col) as *const __m256i)
            };
            for j in 0..8 {
                let nib = _mm256_cvtepi32_ps(_mm256_and_si256(word, mask));
                word = _mm256_srli_epi32::<4>(word);
                for (mi, a) in acc.iter_mut().enumerate() {
                    let xv = _mm256_set1_ps(*xg.get_unchecked(mi * geom.k + w * 8 + j));
                    *a = _mm256_fmadd_ps(xv, nib, *a);
                }
            }
        }
        let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
        let zword = *geom.qzeros.get_unchecked(gi * geom.nw + col / 8) as i32;
        let z = _mm256_cvtepi32_ps(_mm256_and_si256(
            _mm256_srlv_epi32(_mm256_set1_epi32(zword), shifts),
            mask,
        ));
        let s = _mm256_loadu_ps(geom.scales.as_ptr().add(gi * geom.n + col));
        for (mi, a) in acc.iter().enumerate() {
            let xs = _mm256_set1_ps(*xsum.get_unchecked(mi * geom.groups + gi));
            let yp = ytile.as_mut_ptr().add(mi * TILE_COLS + ycol);
            let y = _mm256_loadu_ps(yp);
            _mm256_storeu_ps(yp, _mm256_fmadd_ps(s, _mm256_sub_ps(*a, _mm256_mul_ps(z, xs)), y));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available() {
        let kernels = available_kernels();
        assert!(kernels.contains(&Kernel::Scalar));
        assert!(supports(Kernel::Scalar));
        for k in kernels {
            assert!(supports(k), "listed kernel {k} must be runnable");
        }
    }

    #[test]
    fn dispatch_selects_a_supported_kernel() {
        let d = KernelDispatch::get();
        assert!(supports(d.kernel), "dispatched kernel {} must be runnable", d.kernel);
        assert!(matches!(d.source, "auto" | "env" | "fallback"));
        // The table resolves once: repeated reads agree.
        assert_eq!(KernelDispatch::get().kernel, d.kernel);
        assert_eq!(active_kernel(), d.kernel);
    }

    #[test]
    fn kernel_names_are_stable() {
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert_eq!(Kernel::Avx2.name(), "avx2");
        assert_eq!(Kernel::Avx512.name(), "avx512");
        assert_eq!(format!("{}", Kernel::Avx512), "avx512");
    }

    #[test]
    fn registry_covers_every_kernel() {
        let names: Vec<&str> = kernel_registry().iter().map(|info| info.name).collect();
        assert_eq!(names, ["scalar", "avx2", "avx512"], "registry must name all kernels");
        // Kernel methods delegate to the registry; `info()` must resolve
        // for every variant (a variant without a row would panic here).
        for kernel in [Kernel::Scalar, Kernel::Avx2, Kernel::Avx512] {
            assert_eq!(kernel.info().kernel, kernel);
            assert_eq!(kernel.col_align(), kernel.swizzle_width().unwrap_or(NIBBLES_PER_WORD));
        }
    }

    #[test]
    fn f16_slice_converters_match_software() {
        // Dequant: every one of the 65536 bit patterns must agree with
        // the software converter bitwise (NaNs: class only — hardware
        // preserves payloads, software canonicalizes).
        let src: Vec<u16> = (0..=u16::MAX).collect();
        let mut dst = vec![0f32; src.len()];
        f16_dequant_slice(&src, &mut dst);
        for (i, &x) in dst.iter().enumerate() {
            let sw = crate::f16::F16(i as u16).to_f32();
            if sw.is_nan() {
                assert!(x.is_nan(), "pattern {i:#06x} must dequantize to NaN");
            } else {
                assert_eq!(
                    x.to_bits(),
                    sw.to_bits(),
                    "pattern {i:#06x}: dispatched {x} vs software {sw}"
                );
            }
        }
        // Quant: every exactly-representable value round-trips to its
        // own bit pattern; rounding behavior on arbitrary f32s matches
        // the software converter (single RNE, overflow >= 65520 -> inf).
        let mut back = vec![0u16; dst.len()];
        f16_quant_slice(&dst, &mut back);
        for (i, &b) in back.iter().enumerate() {
            let h = crate::f16::F16(i as u16);
            if h.is_nan() {
                assert!(crate::f16::F16(b).is_nan());
            } else {
                assert_eq!(b, i as u16, "pattern {i:#06x} failed the quant round-trip");
            }
        }
        let mut rng = crate::rng::Rng::new(0xf16c);
        let mut vals = rng.normal_vec_f32(4096, 100.0);
        vals.extend_from_slice(&[
            0.0,
            -0.0,
            65519.9,
            65520.0,
            -65520.0,
            1e-8,
            -1e-8,
            6.1e-5, // around the subnormal boundary
            f32::MAX,
            f32::MIN,
        ]);
        let mut dispatched = vec![0u16; vals.len()];
        f16_quant_slice(&vals, &mut dispatched);
        for (&x, &got) in vals.iter().zip(&dispatched) {
            let sw = crate::f16::F16::from_f32(x).0;
            assert_eq!(got, sw, "quant({x}) = {got:#06x}, software says {sw:#06x}");
        }
    }

    #[test]
    fn f16_converter_resolution_is_stable() {
        let name = f16_converter_name();
        assert!(matches!(name, "f16c" | "scalar"));
        assert_eq!(f16_converter_name(), name, "resolution must be process-wide");
        // Under scalar kernel dispatch the converter must be scalar too
        // (the forced-kernel CI matrix relies on this coupling).
        if active_kernel() == Kernel::Scalar {
            assert_eq!(name, "scalar");
        }
    }

    #[test]
    fn auto_detection_prefers_the_widest_supported_kernel() {
        // available_kernels is registry-ordered (ascending preference),
        // so auto dispatch must pick its last element — scalar only when
        // nothing wider runs here.
        let widest = *available_kernels().last().unwrap();
        let auto = KernelDispatch::auto();
        assert_eq!(auto.kernel, widest);
        assert_eq!(auto.source, "auto");
    }
}

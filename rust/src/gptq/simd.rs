//! Runtime-dispatched explicit-SIMD kernels for [`super::fused`] — the
//! CPU embodiment of the paper's heterogeneous platform adaptation.
//!
//! The paper's three platform-level strategies map onto this module as:
//!
//! * **VML-Opt** (vectorized memory loads): each inner-loop step is one
//!   256-bit load of a column-octet's packed word row — aligned when the
//!   tensor carries a [`SwizzledWeights`] prepack (see `pack`), unaligned
//!   but still contiguous straight from the storage layout otherwise.
//! * **ILA-Opt** (native vector FMA): nibbles are unpacked 8 lanes at a
//!   time with shift/mask, converted once, and accumulated with
//!   `vfmadd231ps`; the group-factored flush `s·(Σx·c − z·Σx)` is
//!   evaluated entirely in vector registers.
//! * **SMB-Opt** (shared-memory tile buffering): per-column-tile partial
//!   outputs live in a stack scratch tile (`M_BLOCK × TILE_COLS`), so one
//!   group's activation slab plus the flush tile stay L1-resident.
//!
//! Kernel selection happens **once** per process through
//! [`KernelDispatch`]: AVX2+FMA hosts get the explicit path, everything
//! else transparently falls back to the portable scalar loop in
//! `fused` (which stays bit-identical to previous releases).  Set
//! `OPT4GPTQ_KERNEL=scalar|avx2|auto` to override detection for testing;
//! an `avx2` request on a host without the features falls back to scalar
//! with a warning rather than faulting.
//!
//! Parity across dispatch paths is pinned by `rust/tests/parity.rs`
//! (forced-scalar and forced-SIMD sweeps against the dense oracle);
//! relative speed by `rust/benches/fused_gemm.rs`, which asserts the SIMD
//! path is never slower than scalar on the headline decode shape.

use std::sync::OnceLock;

/// One fused-kernel implementation the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar tile loop (`fused::fused_panel_cols`) — relies on
    /// autovectorization, runs everywhere, bit-identical across releases.
    Scalar,
    /// Explicit AVX2+FMA octet kernel (x86-64 only, runtime-detected).
    Avx2,
}

impl Kernel {
    /// Stable lowercase name (used by `OPT4GPTQ_KERNEL` and bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
        }
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Whether `kernel` can run on this host.
pub fn supports(kernel: Kernel) -> bool {
    match kernel {
        Kernel::Scalar => true,
        Kernel::Avx2 => avx2_supported(),
    }
}

/// Every kernel this host can run (scalar always; AVX2 when detected).
/// Tests iterate this to sweep all dispatchable paths.
pub fn available_kernels() -> Vec<Kernel> {
    let mut v = vec![Kernel::Scalar];
    if avx2_supported() {
        v.push(Kernel::Avx2);
    }
    v
}

fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Process-wide kernel selection, resolved once on first use: the
/// dispatch-table analogue of the paper's per-platform kernel binding.
#[derive(Debug, Clone, Copy)]
pub struct KernelDispatch {
    /// The kernel every auto-dispatched fused call runs through.
    pub kernel: Kernel,
    /// How it was chosen: `"auto"` (feature detection), `"env"`
    /// (`OPT4GPTQ_KERNEL`), or `"fallback"` (env requested an
    /// unavailable or unknown kernel).
    pub source: &'static str,
}

static DISPATCH: OnceLock<KernelDispatch> = OnceLock::new();

impl KernelDispatch {
    /// The resolved process-wide dispatch entry.  The environment is read
    /// exactly once; later changes to `OPT4GPTQ_KERNEL` have no effect.
    pub fn get() -> KernelDispatch {
        *DISPATCH.get_or_init(|| match std::env::var("OPT4GPTQ_KERNEL") {
            Ok(v) => match v.to_ascii_lowercase().as_str() {
                "scalar" => KernelDispatch { kernel: Kernel::Scalar, source: "env" },
                "avx2" if avx2_supported() => {
                    KernelDispatch { kernel: Kernel::Avx2, source: "env" }
                }
                "avx2" => {
                    eprintln!(
                        "opt4gptq: OPT4GPTQ_KERNEL=avx2 but AVX2+FMA are not \
                         available on this host; falling back to scalar"
                    );
                    KernelDispatch { kernel: Kernel::Scalar, source: "fallback" }
                }
                "auto" | "" => KernelDispatch::auto(),
                other => {
                    eprintln!(
                        "opt4gptq: unknown OPT4GPTQ_KERNEL={other:?} \
                         (expected scalar|avx2|auto); using auto detection"
                    );
                    KernelDispatch { kernel: KernelDispatch::auto().kernel, source: "fallback" }
                }
            },
            Err(_) => KernelDispatch::auto(),
        })
    }

    fn auto() -> KernelDispatch {
        if avx2_supported() {
            KernelDispatch { kernel: Kernel::Avx2, source: "auto" }
        } else {
            KernelDispatch { kernel: Kernel::Scalar, source: "auto" }
        }
    }
}

/// The kernel auto-dispatched fused calls run through.
pub fn active_kernel() -> Kernel {
    KernelDispatch::get().kernel
}

/// AVX2+FMA panel kernel: same contract as `fused::fused_panel_cols`
/// (column window `[c0, c0+cn)` of one gathered M-block, `out` a zeroed
/// `[mb, cn]` window), plus an optional swizzled weight view for aligned
/// streaming loads.  Caller must have verified [`supports`]`(Avx2)`.
#[cfg(target_arch = "x86_64")]
pub(crate) fn panel_avx2(
    call: &super::fused::KernelCall<'_>,
    xg: &[f32],
    xsum: &[f32],
    mb: usize,
    c0: usize,
    cn: usize,
    out: &mut [f32],
) {
    let q = call.q;
    assert!(avx2_supported(), "AVX2 kernel dispatched on a host without AVX2+FMA");
    assert!(mb <= super::fused::M_BLOCK);
    assert_eq!(xg.len(), mb * q.k);
    assert_eq!(out.len(), mb * cn);
    assert_eq!(c0 % 8, 0, "column window must be octet-aligned");
    assert_eq!(cn % 8, 0, "column window width must be a multiple of 8");
    assert_eq!(q.group_size % 8, 0, "group size must be a multiple of 8");
    assert_eq!(q.k % q.group_size, 0, "group size must divide K");
    if cn == 0 || mb == 0 {
        return;
    }
    let geom = x86::Geom {
        qweight: &q.qweight,
        qzeros: &q.qzeros,
        scales: &q.scales,
        swz: call.swz.map(|s| s.words()).unwrap_or(&[]),
        k: q.k,
        n: q.n,
        kw: q.k / 8,
        nw: q.n / 8,
        wpg: q.group_size / 8,
        groups: q.k / q.group_size,
    };
    if let Some(s) = call.swz {
        assert_eq!(s.kw(), geom.kw, "swizzle K mismatch");
        assert_eq!(s.n(), q.n, "swizzle N mismatch");
        // SAFETY: AVX2+FMA presence asserted above.
        unsafe { x86::tiles::<true>(&geom, xg, xsum, mb, c0, cn, out) }
    } else {
        // SAFETY: AVX2+FMA presence asserted above.
        unsafe { x86::tiles::<false>(&geom, xg, xsum, mb, c0, cn, out) }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use crate::gptq::fused::M_BLOCK;
    use std::arch::x86_64::*;

    /// Column-tile width of the SIMD path: the `M_BLOCK × TILE_COLS` f32
    /// flush tile (8 KiB — the SMB-Opt stack scratch) plus one group's
    /// activation slab stays L1-resident while weights stream through.
    pub(super) const TILE_COLS: usize = 256;

    /// Octet-group width for the `mb = 1` decode GEMV: four independent
    /// accumulator chains hide the FMA latency a single running sum
    /// would serialize on.
    const GEMV_OG: usize = 4;

    /// Resolved tensor geometry shared by the tile and octet loops.
    pub(super) struct Geom<'a> {
        pub qweight: &'a [u32],
        pub qzeros: &'a [u32],
        pub scales: &'a [f32],
        /// Flat swizzled view (`pack::SwizzledWeights::words`); empty
        /// when streaming straight from the storage layout.
        pub swz: &'a [u32],
        pub k: usize,
        pub n: usize,
        pub kw: usize,
        pub nw: usize,
        /// Words per group slab (`group_size / 8`).
        pub wpg: usize,
        pub groups: usize,
    }

    /// Tile loop over the column window: walk `[c0, c0+cn)` in
    /// `TILE_COLS` tiles, K in group slabs, flushing each group's
    /// register accumulators into the stack scratch tile.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA at runtime and the geometry
    /// invariants checked by [`super::panel_avx2`].
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn tiles<const SWZ: bool>(
        geom: &Geom<'_>,
        xg: &[f32],
        xsum: &[f32],
        mb: usize,
        c0: usize,
        cn: usize,
        out: &mut [f32],
    ) {
        let mut ytile = [0.0f32; M_BLOCK * TILE_COLS];
        let mut cb = 0usize;
        while cb < cn {
            let nb = TILE_COLS.min(cn - cb);
            let octs = nb / 8;
            let oct0 = (c0 + cb) / 8; // absolute first octet of this tile
            for mi in 0..mb {
                ytile[mi * TILE_COLS..mi * TILE_COLS + nb].fill(0.0);
            }
            for gi in 0..geom.groups {
                let mut oi = 0usize;
                if mb == 1 {
                    // Decode GEMV: 4-octet groups, 4 independent chains.
                    while oi + GEMV_OG <= octs {
                        group_octets::<1, GEMV_OG, SWZ>(
                            geom,
                            xg,
                            xsum,
                            gi,
                            oct0 + oi,
                            &mut ytile,
                            oi * 8,
                        );
                        oi += GEMV_OG;
                    }
                }
                while oi < octs {
                    let o0 = oct0 + oi;
                    let yc = oi * 8;
                    match mb {
                        1 => group_octets::<1, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        2 => group_octets::<2, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        3 => group_octets::<3, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        4 => group_octets::<4, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        5 => group_octets::<5, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        6 => group_octets::<6, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        7 => group_octets::<7, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        8 => group_octets::<8, 1, SWZ>(geom, xg, xsum, gi, o0, &mut ytile, yc),
                        _ => unreachable!("mb is capped at M_BLOCK"),
                    }
                    oi += 1;
                }
            }
            for mi in 0..mb {
                out[mi * cn + cb..mi * cn + cb + nb]
                    .copy_from_slice(&ytile[mi * TILE_COLS..mi * TILE_COLS + nb]);
            }
            cb += nb;
        }
    }

    /// One group slab × `OG` column-octets × `MB` activation rows, fully
    /// register-resident: `MB×OG` running sums accumulate `Σ x·code`
    /// with `vfmadd231ps` over the slab's word rows (8-lane nibble
    /// unpack via shift/mask per row), then the group-factored flush
    /// `y += s·(acc − z·Σx)` lands in the scratch tile at `ycol`.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA at runtime; `o0 + OG` octets
    /// and `ycol + OG*8` columns must be in bounds.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn group_octets<const MB: usize, const OG: usize, const SWZ: bool>(
        geom: &Geom<'_>,
        xg: &[f32],
        xsum: &[f32],
        gi: usize,
        o0: usize,
        ytile: &mut [f32],
        ycol: usize,
    ) {
        let mask = _mm256_set1_epi32(0xF);
        let w0 = gi * geom.wpg;
        let mut acc = [[_mm256_setzero_ps(); OG]; MB];
        for dw in 0..geom.wpg {
            let w = w0 + dw;
            // One 256-bit load per octet feeds all 8 lanes (VML-Opt):
            // aligned from the swizzled stream, unaligned-contiguous
            // straight from the storage layout otherwise.
            let mut words = [_mm256_setzero_si256(); OG];
            for (oc, wrd) in words.iter_mut().enumerate() {
                *wrd = if SWZ {
                    _mm256_load_si256(
                        geom.swz.as_ptr().add(((o0 + oc) * geom.kw + w) * 8) as *const __m256i
                    )
                } else {
                    _mm256_loadu_si256(
                        geom.qweight.as_ptr().add(w * geom.n + (o0 + oc) * 8) as *const __m256i
                    )
                };
            }
            // Eight nibble rows per word: shift/mask unpack, convert
            // once, FMA into every row's accumulator (ILA-Opt).
            for j in 0..8 {
                let mut nib = [_mm256_setzero_ps(); OG];
                for (oc, nb) in nib.iter_mut().enumerate() {
                    *nb = _mm256_cvtepi32_ps(_mm256_and_si256(words[oc], mask));
                    words[oc] = _mm256_srli_epi32::<4>(words[oc]);
                }
                for (mi, arow) in acc.iter_mut().enumerate() {
                    let xv = _mm256_set1_ps(*xg.get_unchecked(mi * geom.k + w * 8 + j));
                    for (oc, a) in arow.iter_mut().enumerate() {
                        *a = _mm256_fmadd_ps(xv, nib[oc], *a);
                    }
                }
            }
        }
        // Group-factored flush, entirely in vector registers:
        // y += s·(acc − z·Σx).
        let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
        for oc in 0..OG {
            let o = o0 + oc;
            let zword = *geom.qzeros.get_unchecked(gi * geom.nw + o) as i32;
            let z = _mm256_cvtepi32_ps(_mm256_and_si256(
                _mm256_srlv_epi32(_mm256_set1_epi32(zword), shifts),
                mask,
            ));
            let s = _mm256_loadu_ps(geom.scales.as_ptr().add(gi * geom.n + o * 8));
            for (mi, arow) in acc.iter().enumerate() {
                let xs = _mm256_set1_ps(*xsum.get_unchecked(mi * geom.groups + gi));
                let yp = ytile.as_mut_ptr().add(mi * TILE_COLS + ycol + oc * 8);
                let y = _mm256_loadu_ps(yp);
                _mm256_storeu_ps(
                    yp,
                    _mm256_fmadd_ps(s, _mm256_sub_ps(arow[oc], _mm256_mul_ps(z, xs)), y),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available() {
        let kernels = available_kernels();
        assert!(kernels.contains(&Kernel::Scalar));
        assert!(supports(Kernel::Scalar));
        for k in kernels {
            assert!(supports(k), "listed kernel {k} must be runnable");
        }
    }

    #[test]
    fn dispatch_selects_a_supported_kernel() {
        let d = KernelDispatch::get();
        assert!(supports(d.kernel), "dispatched kernel {} must be runnable", d.kernel);
        assert!(matches!(d.source, "auto" | "env" | "fallback"));
        // The table resolves once: repeated reads agree.
        assert_eq!(KernelDispatch::get().kernel, d.kernel);
        assert_eq!(active_kernel(), d.kernel);
    }

    #[test]
    fn kernel_names_are_stable() {
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert_eq!(Kernel::Avx2.name(), "avx2");
        assert_eq!(format!("{}", Kernel::Avx2), "avx2");
    }
}

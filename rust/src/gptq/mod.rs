//! GPTQ 4-bit quantization substrate.
//!
//! Everything the paper *depends on* but does not contribute: the GPTQ
//! one-shot quantization algorithm itself (Frantar et al., 2022 — Hessian
//! accumulation from calibration activations plus Cholesky-based error
//! propagation), the 4-bit packing layout shared with the Python/Pallas
//! layer (plus its vector-friendly [`pack::SwizzledWeights`] prepack), a
//! dense CPU reference for the quantized GEMM ([`gemm`], the correctness
//! oracle) and the fused dequantize-on-the-fly fast path ([`fused`], the
//! kernel [`crate::engine::cpu_backend::CpuBackend`] serves through),
//! runtime-dispatched across the kernel registry in [`simd`]: a portable
//! scalar loop, the 8-lane AVX2+FMA kernel, and the 16-lane AVX-512F/BW
//! kernel.
//!
//! Layout contract (identical to `python/compile/quant_ref.py` and
//! `python/compile/kernels/ref.py`):
//!
//! * `qweight: u32[K/8, N]` — nibble `j` of word `w` holds row `8w + j`;
//! * `scales:  f32[K/g, N]`;
//! * `qzeros:  u32[K/g, N/8]` — nibble `j` of word `w` holds column `8w+j`;
//! * `W[k,n] = scales[k/g, n] * (code[k,n] - zero[k/g, n])`.

pub mod fused;
pub mod gemm;
pub mod linalg;
pub mod pack;
pub mod quantize;
pub mod simd;

pub use fused::{
    fused_threads, gemm_fused_opt, gemm_fused_prepared, gemv_fused_opt, gemv_fused_prepared,
    FusedInput, FusedOpts, PreparedTensor,
};
pub use gemm::{dequantize, gemm_f32, gemv_f32};
pub use pack::{
    pack_cols, pack_rows, swizzle_weights, swizzle_weights_width, unpack_cols, unpack_rows,
    unswizzle_weights, SwizzledWeights, NIBBLES_PER_WORD,
};
pub use quantize::{
    quantize_gptq, quantize_rtn, reconstruction_error, GptqConfig, QuantizedTensor,
};
pub use simd::{
    active_kernel, available_kernels, kernel_registry, supports, Kernel, KernelDispatch,
    KernelInfo,
};

/// A dense row-major f32 matrix (minimal, no external crates).
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Frobenius norm of (self - other).
    pub fn frob_dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }
}

//! The GPTQ one-shot quantization algorithm (Frantar et al., 2022) and the
//! round-to-nearest baseline it is compared against.
//!
//! GPTQ quantizes a weight matrix `W[K, N]` (in-features × out-features)
//! one in-feature at a time; the rounding error of row `k` is propagated
//! into the not-yet-quantized rows using the inverse-Hessian Cholesky
//! factor, where `H = 2 XᵀX + λI` is accumulated from calibration
//! activations `X[S, K]`.  This is the "approximate second-order
//! information" the paper's §I refers to.

use super::linalg;
use super::pack;
use super::Matrix;

pub const QMAX: i32 = 15; // unsigned 4-bit codes

/// Packed GPTQ tensor in the repo-wide layout (see `gptq` module docs).
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub k: usize,
    pub n: usize,
    pub group_size: usize,
    pub qweight: Vec<u32>, // [K/8 * N]
    pub scales: Vec<f32>,  // [K/g * N]
    pub qzeros: Vec<u32>,  // [K/g * N/8]
    /// Activation-order permutation (`b_q_perm`): packed row `r` holds
    /// original in-feature `perm[r]`.  `None` for sequential order.
    pub perm: Option<Vec<usize>>,
}

impl QuantizedTensor {
    pub fn groups(&self) -> usize {
        self.k / self.group_size
    }

    /// Attach an activation-order permutation (`b_q_perm`) to this
    /// tensor: packed row `r` is reinterpreted as original in-feature
    /// `perm[r]`.  Used by the parity tests and benches to exercise the
    /// act-order gather path without paying a full GPTQ quantization.
    pub fn with_perm(mut self, perm: Vec<usize>) -> QuantizedTensor {
        assert_eq!(perm.len(), self.k, "perm must cover all K in-features");
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert!(
            sorted.iter().enumerate().all(|(i, &p)| i == p),
            "perm must be a permutation of 0..K"
        );
        self.perm = Some(perm);
        self
    }

    /// Bytes of the packed representation (weights + scales + zeros).
    pub fn packed_bytes(&self) -> usize {
        self.qweight.len() * 4 + self.scales.len() * 4 + self.qzeros.len() * 4
    }

    /// Minimum bytes one fused `M×K×N` evaluation must move: the packed
    /// tensor once (weights stream, never re-read across column tiles of
    /// the same pass) plus the `M×K` activations and `M×N` outputs.
    /// The bench's GB/s accounting divides this by wall time, so the
    /// number is a *floor* on realized bandwidth, not a cache-traffic
    /// measurement.
    pub fn fused_traffic_bytes(&self, m: usize) -> usize {
        self.packed_bytes() + (m * self.k + m * self.n) * 4
    }
}

/// GPTQ hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct GptqConfig {
    pub group_size: usize,
    /// Relative Hessian damping (`percdamp` in the reference code).
    pub percdamp: f64,
    /// Activation-order quantization (`desc_act`): process in-features by
    /// decreasing Hessian diagonal.  This is the mode that produces the
    /// `b_q_perm` permutation the paper's Algorithm 2 special-cases — the
    /// activation loads become gathers, which is exactly what limits
    /// VML-Opt there.
    pub act_order: bool,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { group_size: 128, percdamp: 0.01, act_order: false }
    }
}

/// Per-(group, column) asymmetric 4-bit grid from the current row block.
fn find_grid(w: &Matrix, k0: usize, g: usize, scales: &mut [f32], zeros: &mut [u8]) {
    let n = w.cols;
    for col in 0..n {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for k in k0..k0 + g {
            let v = w.at(k, col);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        lo = lo.min(0.0);
        hi = hi.max(0.0);
        let mut scale = (hi - lo) / QMAX as f32;
        if scale <= 1e-8 {
            scale = 1.0;
        }
        let zero = (-lo / scale).round().clamp(0.0, QMAX as f32) as u8;
        scales[col] = scale;
        zeros[col] = zero;
    }
}

#[inline]
fn quantize_value(v: f32, scale: f32, zero: u8) -> (u8, f32) {
    let q = (v / scale).round() + zero as f32;
    let q = q.clamp(0.0, QMAX as f32) as u8;
    let deq = scale * (q as i32 - zero as i32) as f32;
    (q, deq)
}

/// Round-to-nearest group quantization (the no-second-order baseline).
pub fn quantize_rtn(w: &Matrix, group_size: usize) -> QuantizedTensor {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(k % group_size, 0, "group size must divide K");
    let groups = k / group_size;
    let mut codes = vec![0u8; k * n];
    let mut scales = vec![0f32; groups * n];
    let mut zeros = vec![0u8; groups * n];
    for gi in 0..groups {
        let k0 = gi * group_size;
        find_grid(w, k0, group_size, &mut scales[gi * n..(gi + 1) * n], &mut zeros[gi * n..(gi + 1) * n]);
        for kk in k0..k0 + group_size {
            for col in 0..n {
                let (q, _) = quantize_value(w.at(kk, col), scales[gi * n + col], zeros[gi * n + col]);
                codes[kk * n + col] = q;
            }
        }
    }
    QuantizedTensor {
        k,
        n,
        group_size,
        qweight: pack::pack_rows(&codes, k, n),
        scales,
        qzeros: pack::pack_cols(&zeros, groups, n),
        perm: None,
    }
}

/// Full GPTQ: quantize `w` (K×N, in×out) against calibration activations
/// `x` (S×K).  Returns the packed tensor; `w` is consumed as scratch.
///
/// Follows the reference implementation's structure: Hessian from the
/// calibration gram matrix, damped, inverted, upper-Cholesky factored;
/// rows are processed in order with in-group error feedback and
/// cross-group propagation.
pub fn quantize_gptq(mut w: Matrix, x: &Matrix, cfg: GptqConfig) -> QuantizedTensor {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(x.cols, k, "calibration activations must be S×K");
    assert_eq!(k % cfg.group_size, 0);
    let groups = k / cfg.group_size;

    // H = 2 XᵀX, damped on the diagonal (percdamp × mean diag).
    let mut h = linalg::gram(&x.data, x.rows, k);

    // Activation order (`desc_act`): sort in-features by decreasing
    // Hessian diagonal so high-impact features quantize first (their
    // error propagates into the most remaining slack).  Both W's rows and
    // H's rows+columns are permuted; the permutation ships with the
    // tensor as `b_q_perm`.
    let perm: Option<Vec<usize>> = if cfg.act_order {
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| {
            h[b * k + b].partial_cmp(&h[a * k + a]).unwrap().then(a.cmp(&b))
        });
        let mut wp = Matrix::zeros(k, n);
        for (r, &src) in order.iter().enumerate() {
            wp.data[r * n..(r + 1) * n].copy_from_slice(w.row(src));
        }
        w = wp;
        let mut hp = vec![0.0f64; k * k];
        for (ri, &si) in order.iter().enumerate() {
            for (rj, &sj) in order.iter().enumerate() {
                hp[ri * k + rj] = h[si * k + sj];
            }
        }
        h = hp;
        Some(order)
    } else {
        None
    };
    for v in h.iter_mut() {
        *v *= 2.0;
    }
    let mean_diag: f64 = (0..k).map(|i| h[i * k + i]).sum::<f64>() / k as f64;
    let damp = (cfg.percdamp * mean_diag).max(1e-8);
    for i in 0..k {
        h[i * k + i] += damp;
    }

    // Hinv's upper Cholesky factor U (so Hinv = Uᵀ? no: Hinv = ... we use
    // the reference's convention: U = cholesky(Hinv, upper), and the error
    // propagation uses rows of U).
    let hinv = linalg::invert_spd(&h, k).expect("damped Hessian must be SPD");
    let u = linalg::cholesky_upper(&hinv, k).expect("Hinv must be SPD");

    let mut codes = vec![0u8; k * n];
    let mut scales = vec![0f32; groups * n];
    let mut zeros = vec![0u8; groups * n];

    for gi in 0..groups {
        let k0 = gi * cfg.group_size;
        let k1 = k0 + cfg.group_size;
        find_grid(&w, k0, cfg.group_size, &mut scales[gi * n..(gi + 1) * n], &mut zeros[gi * n..(gi + 1) * n]);

        for kk in k0..k1 {
            let d = u[kk * k + kk];
            for col in 0..n {
                let v = w.at(kk, col);
                let (q, deq) = quantize_value(v, scales[gi * n + col], zeros[gi * n + col]);
                codes[kk * n + col] = q;
                // Normalized error for propagation (reference: err = (w-q)/d).
                let err = (v - deq) / d as f32;
                // In-group feedback: update remaining rows of this group.
                for kj in kk + 1..k1 {
                    let factor = u[kk * k + kj] as f32;
                    if factor != 0.0 {
                        *w.at_mut(kj, col) -= err * factor;
                    }
                }
                // Cross-group propagation to all later rows.
                for kj in k1..k {
                    let factor = u[kk * k + kj] as f32;
                    if factor != 0.0 {
                        *w.at_mut(kj, col) -= err * factor;
                    }
                }
            }
        }
    }

    QuantizedTensor {
        k,
        n,
        group_size: cfg.group_size,
        qweight: pack::pack_rows(&codes, k, n),
        scales,
        qzeros: pack::pack_cols(&zeros, groups, n),
        perm,
    }
}

/// Layer-output reconstruction error `‖X·W − X·deq(Q)‖_F` — the quantity
/// GPTQ minimizes; used by tests to check GPTQ beats RTN.
pub fn reconstruction_error(x: &Matrix, w: &Matrix, q: &QuantizedTensor) -> f64 {
    let wq = super::gemm::dequantize(q);
    let ref_out = matmul(x, w);
    let q_out = matmul(x, &wq);
    ref_out.frob_dist(&q_out)
}

fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut out = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for kk in 0..a.cols {
            let av = a.at(i, kk);
            if av == 0.0 {
                continue;
            }
            for j in 0..b.cols {
                *out.at_mut(i, j) += av * b.at(kk, j);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_matrix(rows: usize, cols: usize, seed: u64, std: f32) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normal_vec_f32(rows * cols, std))
    }

    #[test]
    fn rtn_dequant_error_bounded_by_half_scale() {
        let w = random_matrix(128, 16, 1, 1.0);
        let q = quantize_rtn(&w, 64);
        let wq = super::super::gemm::dequantize(&q);
        for k in 0..w.rows {
            let gi = k / 64;
            for col in 0..w.cols {
                let err = (w.at(k, col) - wq.at(k, col)).abs();
                let bound = q.scales[gi * w.cols + col] * 0.5 + 1e-5;
                assert!(err <= bound, "err {err} > {bound} at ({k},{col})");
            }
        }
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_activations() {
        // Correlated calibration data is where second-order info pays off.
        let k = 64;
        let n = 16;
        let s = 256;
        let mut rng = Rng::new(7);
        // Activations with strong column correlation.
        let base = random_matrix(s, 8, 8, 1.0);
        let mixer = random_matrix(8, k, 9, 1.0);
        let mut x = Matrix::zeros(s, k);
        for i in 0..s {
            for j in 0..k {
                let mut acc = 0.0;
                for c in 0..8 {
                    acc += base.at(i, c) * mixer.at(c, j);
                }
                x.data[i * k + j] = acc + 0.1 * rng.normal() as f32;
            }
        }
        let w = random_matrix(k, n, 10, 0.5);
        let rtn = quantize_rtn(&w, 32);
        let gptq = quantize_gptq(w.clone(), &x, GptqConfig { group_size: 32, percdamp: 0.01, act_order: false });
        let e_rtn = reconstruction_error(&x, &w, &rtn);
        let e_gptq = reconstruction_error(&x, &w, &gptq);
        assert!(
            e_gptq < e_rtn * 0.9,
            "GPTQ ({e_gptq:.3}) should beat RTN ({e_rtn:.3}) by >10%"
        );
    }

    #[test]
    fn gptq_equals_rtn_shapes() {
        let k = 64;
        let w = random_matrix(k, 8, 3, 1.0);
        let x = random_matrix(32, k, 4, 1.0);
        let q = quantize_gptq(w, &x, GptqConfig { group_size: 32, percdamp: 0.01, act_order: false });
        assert_eq!(q.qweight.len(), (k / 8) * 8);
        assert_eq!(q.scales.len(), (k / 32) * 8);
        assert_eq!(q.qzeros.len(), (k / 32) * 1);
        assert_eq!(q.groups(), 2);
        assert!(q.packed_bytes() < k * 8 * 4 / 4); // >4x compression vs f32
        // Traffic floor: packed tensor + f32 activations and outputs.
        assert_eq!(q.fused_traffic_bytes(1), q.packed_bytes() + (k + 8) * 4);
        assert_eq!(q.fused_traffic_bytes(4), q.packed_bytes() + 4 * (k + 8) * 4);
    }

    #[test]
    fn degenerate_constant_weight_is_finite() {
        let w = Matrix::from_vec(32, 8, vec![1.5; 32 * 8]);
        let x = random_matrix(16, 32, 5, 1.0);
        let q = quantize_gptq(w, &x, GptqConfig { group_size: 32, percdamp: 0.01, act_order: false });
        assert!(q.scales.iter().all(|s| s.is_finite() && *s > 0.0));
    }

    #[test]
    fn codes_within_4bit_range() {
        let w = random_matrix(64, 16, 6, 3.0);
        let q = quantize_rtn(&w, 64);
        let codes = pack::unpack_rows(&q.qweight, 64 / 8, 16);
        assert!(codes.iter().all(|&c| c <= 15));
    }
}

#[cfg(test)]
mod act_order_tests {
    use super::*;
    use crate::rng::Rng;

    fn setup(k: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 0.7));
        // heteroscedastic activations: some features much hotter
        let mut x = Matrix::zeros(128, k);
        for i in 0..128 {
            for j in 0..k {
                let scale = 1.0 + 4.0 * ((j * 37) % 7) as f32 / 7.0;
                x.data[i * k + j] = scale * rng.normal() as f32;
            }
        }
        (w, x)
    }

    #[test]
    fn act_order_ships_a_valid_permutation() {
        let (w, x) = setup(64, 16, 1);
        let q = quantize_gptq(w, &x, GptqConfig { group_size: 32, percdamp: 0.01, act_order: true });
        let perm = q.perm.as_ref().expect("act_order must produce b_q_perm");
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>(), "must be a permutation");
    }

    #[test]
    fn act_order_dequantizes_to_original_feature_positions() {
        // A near-exactly-representable W must round-trip even when rows
        // were processed out of order.
        let mut rng = Rng::new(2);
        let k = 64;
        let n = 8;
        let codes: Vec<f32> = (0..k * n).map(|_| rng.below(16) as f32).collect();
        let w = Matrix::from_vec(k, n, codes.iter().map(|c| 0.5 * (c - 7.0)).collect());
        let (_, x) = setup(k, n, 3);
        let q = quantize_gptq(w.clone(), &x, GptqConfig { group_size: 64, percdamp: 0.01, act_order: true });
        let deq = super::super::gemm::dequantize(&q);
        for kk in 0..k {
            for col in 0..n {
                assert!(
                    (deq.at(kk, col) - w.at(kk, col)).abs() < 0.3,
                    "({kk},{col}): {} vs {}", deq.at(kk, col), w.at(kk, col)
                );
            }
        }
    }

    #[test]
    fn act_order_gemv_matches_dequant_matmul() {
        let (w, x) = setup(64, 16, 4);
        let q = quantize_gptq(w, &x, GptqConfig { group_size: 32, percdamp: 0.01, act_order: true });
        let mut rng = Rng::new(5);
        let act = rng.normal_vec_f32(64, 1.0);
        let y = super::super::gemm::gemv_f32(&act, &q);
        let deq = super::super::gemm::dequantize(&q);
        for col in 0..16 {
            let mut expect = 0.0f32;
            for kk in 0..64 {
                expect += act[kk] * deq.at(kk, col);
            }
            assert!((y[col] - expect).abs() < 1e-3, "col {col}");
        }
    }

    #[test]
    fn act_order_not_worse_than_sequential_on_heteroscedastic_data() {
        let (w, x) = setup(128, 16, 6);
        let seq = quantize_gptq(w.clone(), &x, GptqConfig { group_size: 64, percdamp: 0.01, act_order: false });
        let act = quantize_gptq(w.clone(), &x, GptqConfig { group_size: 64, percdamp: 0.01, act_order: true });
        let e_seq = reconstruction_error(&x, &w, &seq);
        let e_act = reconstruction_error(&x, &w, &act);
        // act-order should help (or at least not catastrophically hurt)
        assert!(e_act < e_seq * 1.15, "act {e_act} vs seq {e_seq}");
    }
}

//! Deterministic PRNG + distributions (no external crates offline).
//!
//! SplitMix64 for seeding, xoshiro256** as the workhorse generator, and the
//! handful of distributions the workload generators need (uniform, normal,
//! log-normal, categorical).  Everything in this repo that needs randomness
//! threads one of these through explicitly — experiments are reproducible
//! bit-for-bit from their seeds.

/// SplitMix64: used to expand a single u64 seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, tiny.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // xoshiro must not start from the all-zero state.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-request / per-question
    /// determinism independent of draw order).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Full generator state (xoshiro words + Box–Muller spare) — what a
    /// checkpoint must persist for a restored stream to continue
    /// bit-identically mid-sequence.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from persisted [`Self::state`] output.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Rng {
        assert!(s.iter().any(|&x| x != 0), "xoshiro state must be nonzero");
        Rng { s, gauss_spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the *underlying* normal's (mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of standard-normal f32 values (weight synthesis).
    pub fn normal_vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }
}

/// Stable 64-bit FNV-1a hash for deriving seeds from names.
pub fn hash64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_smoke() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(11);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn hash64_stable() {
        assert_eq!(hash64("Meta-Llama-3-8B-GPTQ"), hash64("Meta-Llama-3-8B-GPTQ"));
        assert_ne!(hash64("a"), hash64("b"));
    }
}

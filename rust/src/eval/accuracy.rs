//! ARC-style accuracy harness (regenerates Tables I–II).
//!
//! Per question: a 4-option scoring head (GPTQ-quantized, seeded by the
//! question) maps the stem features to option scores; the prediction is
//! the argmax computed in the *variant's* fp16 numerics.  The gold label
//! is the exact-arithmetic argmax for "should-answer-correctly"
//! questions (margin > 0) and the exact runner-up otherwise — so the
//! baseline accuracy tracks the paper's baseline, and variants flip only
//! the questions whose exact top-two scores are within fp16-rounding
//! distance.

use crate::gptq::{dequantize, quantize_rtn, Matrix, QuantizedTensor};
use crate::rng::{hash64, Rng};
use crate::trace::arc::{ArcDataset, ArcSplit};
use crate::OptConfig;

use super::numerics::gemv_f16_variant;

/// Feature dimension of the scoring head (kernel-friendly multiple of 64).
pub const FEATURE_DIM: usize = 64;
pub const OPTIONS: usize = 4;
/// Packed width of the head (the GPTQ layout needs N % 8 == 0); only the
/// first [`OPTIONS`] columns are option scores.
pub const HEAD_WIDTH: usize = 8;

/// One (model, split, config) accuracy measurement.
#[derive(Debug, Clone)]
pub struct AccuracyResult {
    pub model: String,
    pub split: ArcSplit,
    pub opt: OptConfig,
    pub correct: usize,
    pub total: usize,
    /// Questions whose prediction differs from the Baseline config's.
    pub flips_vs_baseline: usize,
}

impl AccuracyResult {
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total as f64
    }
}

/// Build the per-question quantized scoring head.
fn question_head(model_seed: u64, qid: usize) -> QuantizedTensor {
    let mut rng = Rng::new(model_seed ^ (qid as u64).wrapping_mul(0x9E37_79B9));
    let w = Matrix::from_vec(
        FEATURE_DIM,
        HEAD_WIDTH,
        rng.normal_vec_f32(FEATURE_DIM * HEAD_WIDTH, 0.4),
    );
    quantize_rtn(&w, FEATURE_DIM)
}

/// Exact (f64) scores through the dequantized head.
fn exact_scores(x: &[f32], q: &QuantizedTensor) -> [f64; OPTIONS] {
    let wq = dequantize(q);
    let mut s = [0.0f64; OPTIONS];
    for (kk, &xv) in x.iter().enumerate() {
        for (col, sc) in s.iter_mut().enumerate() {
            *sc += xv as f64 * wq.at(kk, col) as f64;
        }
    }
    s
}

fn rank(scores: &[f64; OPTIONS]) -> (usize, usize) {
    let mut idx = [0usize, 1, 2, 3];
    idx.sort_unstable_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    (idx[0], idx[1])
}

fn argmax_f32(scores: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..scores.len() {
        if scores[i] > scores[best] {
            best = i;
        }
    }
    best
}

/// Evaluate one (model, split) across all five configs.
pub fn evaluate(model_name: &str, split: ArcSplit) -> Vec<AccuracyResult> {
    let dataset = ArcDataset::generate(split, model_name, FEATURE_DIM);
    let model_seed = hash64(model_name);

    // Per-question gold labels + per-config predictions.
    let mut predictions: Vec<Vec<usize>> = vec![Vec::new(); OptConfig::ALL.len()];
    let mut labels: Vec<usize> = Vec::with_capacity(dataset.questions.len());

    for q in &dataset.questions {
        let head = question_head(model_seed, q.id);
        let exact = exact_scores(&q.features, &head);
        let (top, second) = rank(&exact);
        labels.push(if q.margin > 0.0 { top } else { second });
        for (ci, opt) in OptConfig::ALL.iter().enumerate() {
            let scores = gemv_f16_variant(&q.features, &head, *opt, q.id as u64);
            predictions[ci].push(argmax_f32(&scores[..OPTIONS]));
        }
    }

    OptConfig::ALL
        .iter()
        .enumerate()
        .map(|(ci, opt)| {
            let correct = predictions[ci]
                .iter()
                .zip(&labels)
                .filter(|(p, l)| p == l)
                .count();
            let flips = predictions[ci]
                .iter()
                .zip(&predictions[0])
                .filter(|(a, b)| a != b)
                .count();
            AccuracyResult {
                model: model_name.to_string(),
                split,
                opt: *opt,
                correct,
                total: labels.len(),
                flips_vs_baseline: flips,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::arc::baseline_target;

    #[test]
    fn baseline_accuracy_tracks_paper_target() {
        for (model, split) in [
            ("Llama-2-7B-GPTQ", ArcSplit::Challenge),
            ("Meta-Llama-3-8B-GPTQ", ArcSplit::Easy),
        ] {
            let results = evaluate(model, split);
            let base = &results[0];
            let target = baseline_target(split, model);
            assert!(
                (base.accuracy() - target).abs() < 0.03,
                "{model} {split:?}: {} vs target {target}",
                base.accuracy()
            );
        }
    }

    #[test]
    fn variants_stay_within_one_point() {
        let results = evaluate("LLaMa-13B-GPTQ", ArcSplit::Challenge);
        let base = results[0].accuracy();
        for r in &results[1..] {
            assert!(
                (r.accuracy() - base).abs() < 0.01,
                "{}: {} vs base {base}",
                r.opt.label(),
                r.accuracy()
            );
        }
    }

    #[test]
    fn some_variant_differs_somewhere() {
        // The tables are not all identical columns: at least one config
        // flips at least one question on at least one model.
        let mut any = 0;
        for model in ["Qwen1.5-1.8B-Chat-GPTQ-Int4", "CodeLlama-7B-GPTQ"] {
            for r in evaluate(model, ArcSplit::Challenge) {
                any += r.flips_vs_baseline;
            }
        }
        assert!(any > 0, "expected at least one prediction flip");
    }

    #[test]
    fn evaluation_is_deterministic() {
        let a = evaluate("Llama-2-7B-GPTQ", ArcSplit::Challenge);
        let b = evaluate("Llama-2-7B-GPTQ", ArcSplit::Challenge);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn smb_and_opt4_are_schedule_stable() {
        // Ordered-reduction configs produce identical predictions across
        // runs by construction (already covered by determinism) and their
        // flip count must be small relative to the dataset.
        let results = evaluate("Qwen1.5-4B-Chat-GPTQ-Int4", ArcSplit::Easy);
        for r in results.iter().skip(1) {
            assert!(
                r.flips_vs_baseline < r.total / 50,
                "{}: {} flips of {}",
                r.opt.label(),
                r.flips_vs_baseline,
                r.total
            );
        }
    }
}

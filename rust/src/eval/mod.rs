//! Accuracy evaluation (paper Tables I–II).
//!
//! * [`numerics`] — the GPTQ GEMV executed in *variant-faithful* binary16
//!   arithmetic: fused (`__hfma2`) vs non-fused (`v_mad_f16`) multiply-
//!   accumulate, per-thread partial accumulation, and the combination
//!   order of split-K partials (atomic arrival order vs the SMB LDS
//!   reduction);
//! * [`accuracy`] — the ARC-style harness: scores each question's four
//!   options through the quantized head and checks the argmax.
//!
//! The paper's finding is that accuracies fluctuate *within one
//! percentage point, with no consistent direction*, across the kernel
//! variants.  Those fluctuations are rounding/order artifacts on
//! questions whose top-two option scores nearly tie; this harness
//! reproduces exactly that mechanism.

pub mod accuracy;
pub mod numerics;

pub use accuracy::{evaluate, AccuracyResult};
pub use numerics::{gemv_f16_variant, kv_dtype_drift, kv_dtype_drift_at, VariantNumerics};

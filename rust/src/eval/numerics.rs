//! Variant-faithful fp16 execution of the GPTQ GEMV.
//!
//! Models the numeric (not performance) semantics of the five kernel
//! configurations:
//!
//! | config    | multiply-accumulate        | partial combination order    |
//! |-----------|----------------------------|------------------------------|
//! | Baseline  | fused `__hfma2`            | atomic arrival (schedule-dependent) |
//! | SMB-Opt   | fused `__hfma2`            | LDS reduction, thread order  |
//! | VML-Opt   | fused `__hfma2`            | atomic arrival (different schedule) |
//! | ILA-Opt   | non-fused `v_mad_f16`      | atomic arrival (different schedule) |
//! | Opt4GPTQ  | non-fused `v_mad_f16`      | LDS reduction, thread order  |
//!
//! "Atomic arrival order" is nondeterministic on real hardware (warp
//! scheduling); we model it as a deterministic pseudo-random permutation
//! seeded by the (config, call) pair — the honest simulator analogue of
//! re-running the experiment on a machine whose schedule shifted.

use crate::engine::{Backend, CpuBackend, CpuModelConfig, DecodeDesc, KvDtype, PrefillDesc};
use crate::f16::{self, F16};
use crate::gptq::{pack, QuantizedTensor};
use crate::rng::{hash64, Rng};
use crate::OptConfig;

/// Split-K factor of the modelled kernel (see `dcusim::kernels::gemv`).
pub const SPLIT_K: usize = 8;

/// Numeric behaviour derived from an [`OptConfig`].
#[derive(Debug, Clone, Copy)]
pub struct VariantNumerics {
    /// Non-fused MAD (product rounded before add) — the ILA path.
    pub non_fused: bool,
    /// Deterministic LDS-reduction order instead of arrival order.
    pub ordered_reduction: bool,
    /// Schedule seed (distinct per config: different binaries schedule
    /// differently even when arithmetic is identical).
    pub schedule_seed: u64,
}

impl VariantNumerics {
    pub fn of(opt: OptConfig) -> VariantNumerics {
        VariantNumerics {
            non_fused: opt.ila,
            ordered_reduction: opt.smb,
            schedule_seed: hash64(opt.label()),
        }
    }
}

/// `y[N] = x[K] · deq(Q)[K, N]` in variant-faithful fp16.
///
/// `call_seed` identifies the call (e.g. question id) so arrival-order
/// nondeterminism is deterministic per (config, call).
pub fn gemv_f16_variant(
    x: &[f32],
    q: &QuantizedTensor,
    opt: OptConfig,
    call_seed: u64,
) -> Vec<f32> {
    let v = VariantNumerics::of(opt);
    let k = q.k;
    let n = q.n;
    assert_eq!(x.len(), k);
    let codes = pack::unpack_rows(&q.qweight, k / pack::NIBBLES_PER_WORD, n);
    let zeros = pack::unpack_cols(&q.qzeros, q.groups(), n / pack::NIBBLES_PER_WORD);
    let xh: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();

    // Perf (§Perf item 3): (code - zero) ∈ [-15, 15] — precompute the 31
    // exact f16 encodings once instead of a float conversion per weight,
    // cache the per-(group, col) scale conversion, and reuse one
    // permutation buffer across columns.
    let diff_f16: [F16; 31] =
        std::array::from_fn(|i| F16::from_f64(i as f64 - 15.0));
    let mut scale_cache: Vec<F16> = Vec::with_capacity(q.groups());
    let mut order: Vec<usize> = (0..SPLIT_K).collect();

    let mut out = Vec::with_capacity(n);
    for col in 0..n {
        scale_cache.clear();
        scale_cache.extend(
            (0..q.groups()).map(|gi| F16::from_f32(q.scales[gi * n + col])),
        );
        // Per-thread partials: thread j owns the strided slice k ≡ j.
        let mut partials = [F16::ZERO; SPLIT_K];
        for (j, partial) in partials.iter_mut().enumerate() {
            let mut acc = F16::ZERO;
            let mut kk = j;
            while kk < k {
                let gi = kk / q.group_size;
                // Dequant in f16: w = scale * (code - zero), as the
                // kernel's __hsub2/__hmul2 sequence computes it.
                let code = codes[kk * n + col] as i32;
                let zero = zeros[gi * n + col] as i32;
                let w = f16::mul(scale_cache[gi], diff_f16[(code - zero + 15) as usize]);
                acc = if v.non_fused {
                    f16::mad(xh[kk], w, acc)
                } else {
                    f16::fma(xh[kk], w, acc)
                };
                kk += SPLIT_K;
            }
            *partial = acc;
        }
        // Combine the partials.
        for (i, o) in order.iter_mut().enumerate() {
            *o = i;
        }
        if !v.ordered_reduction {
            let mut rng = Rng::new(v.schedule_seed ^ call_seed.wrapping_mul(0x9E37) ^ col as u64);
            rng.shuffle(&mut order);
        }
        let mut total = F16::ZERO;
        for &j in order.iter() {
            total = f16::add(total, partials[j]);
        }
        out.push(total.to_f32());
    }
    out
}

/// Worst relative logit drift a compressed KV pool introduces on the CPU
/// backend, against a bit-identical f32-pool run of the same workload
/// (48-token prefill + 16 greedy decode steps, tokens chosen from the f32
/// run so both backends always feed the same inputs).
///
/// The committed accuracy pins (asserted in this module's tests) are:
///
/// | dtype | pinned bound | expectation |
/// |-------|--------------|-------------|
/// | `f32` | exactly 0.0  | pool layout is internal; math unchanged |
/// | `f16` | ≤ 1e-2       | ≤2^-11 per-element rounding, accumulated |
/// | `kv4` | ≤ 0.35       | empirical: 4-bit affine KV on the tiny model |
///
/// Drift is `max_i |a_i - b_i| / max(max_i |a_i|, 1e-6)`, maximised over
/// the prefill logits and every decode step's logits.
///
/// Runs at the process-default model config; [`kv_dtype_drift_at`] pins
/// the same bounds at an explicit config (the GQA+RoPE leg).
pub fn kv_dtype_drift(dtype: KvDtype) -> f64 {
    kv_dtype_drift_at(CpuModelConfig::default(), dtype)
}

/// [`kv_dtype_drift`] at an explicit model config — GQA shapes share KV
/// heads across Q heads and RoPE rotates rows before the pool write, so
/// the compression-drift pins have to hold there too, not just at the
/// MHA default.
pub fn kv_dtype_drift_at(cfg: CpuModelConfig, dtype: KvDtype) -> f64 {
    const BLOCK: usize = 16;
    let vocab = cfg.vocab as u32;
    let backend = move || CpuBackend::new(cfg).unwrap();
    let mut base = backend();
    base.bind_kv(8, BLOCK, KvDtype::F32);
    let mut test = backend();
    test.bind_kv(8, BLOCK, dtype);

    let prompt: Vec<u32> = (0..48u32).map(|i| (i * 29 + 7) % vocab).collect();
    let table: Vec<usize> = (0..5).collect(); // 80 positions: 48 + 16 decodes
    let prefill = |be: &mut CpuBackend| {
        be.prefill(PrefillDesc {
            seq_id: 0,
            tokens: &prompt,
            start: 0,
            is_last: true,
            block_table: &table,
        })
        .unwrap()
        .0
    };
    let rel_drift = |a: &[f32], b: &[f32]| -> f64 {
        let denom = a.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6) as f64;
        a.iter()
            .zip(b)
            .fold(0.0f64, |m, (x, y)| m.max((x - y).abs() as f64))
            / denom
    };
    let argmax = |l: &[f32]| -> u32 {
        let mut best = 0usize;
        for (i, v) in l.iter().enumerate() {
            if *v > l[best] {
                best = i;
            }
        }
        best as u32
    };

    let la = prefill(&mut base);
    let lb = prefill(&mut test);
    let mut worst = rel_drift(&la, &lb);
    let mut ctx = prompt.len();
    let mut token = argmax(&la);
    for _ in 0..16 {
        let step = |be: &mut CpuBackend| {
            be.decode(&[DecodeDesc { seq_id: 0, context_len: ctx, token, block_table: &table }])
                .unwrap()
                .0
                .remove(0)
        };
        let da = step(&mut base);
        let db = step(&mut test);
        worst = worst.max(rel_drift(&da, &db));
        ctx += 1;
        token = argmax(&da);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gptq::{quantize_rtn, Matrix};
    use crate::rng::Rng;

    fn quantized_head(k: usize, n: usize, seed: u64) -> QuantizedTensor {
        assert_eq!(n % 8, 0, "packed layout needs N % 8 == 0");
        let mut rng = Rng::new(seed);
        let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, 0.5));
        quantize_rtn(&w, k.min(64))
    }

    #[test]
    fn close_to_f32_reference() {
        let q = quantized_head(64, 8, 1);
        let mut rng = Rng::new(2);
        let x = rng.normal_vec_f32(64, 1.0);
        let f32_ref = crate::gptq::gemv_f32(&x, &q);
        for opt in crate::OptConfig::ALL {
            let y = gemv_f16_variant(&x, &q, opt, 0);
            for (a, b) in y.iter().zip(&f32_ref) {
                assert!((a - b).abs() < 0.05 * b.abs().max(1.0),
                        "{}: {a} vs {b}", opt.label());
            }
        }
    }

    #[test]
    fn variants_differ_slightly_but_not_wildly() {
        let q = quantized_head(64, 8, 3);
        let mut rng = Rng::new(4);
        let mut any_diff = false;
        for call in 0..50u64 {
            let x = rng.normal_vec_f32(64, 1.0);
            let base = gemv_f16_variant(&x, &q, crate::OptConfig::BASELINE, call);
            for opt in [crate::OptConfig::SMB, crate::OptConfig::ILA, crate::OptConfig::OPT4GPTQ] {
                let y = gemv_f16_variant(&x, &q, opt, call);
                for (a, b) in y.iter().zip(&base) {
                    if a != b {
                        any_diff = true;
                    }
                    assert!((a - b).abs() < 0.02 * b.abs().max(1.0));
                }
            }
        }
        assert!(any_diff, "numeric variants must not be bitwise identical");
    }

    #[test]
    fn deterministic_per_config_and_call() {
        let q = quantized_head(64, 8, 5);
        let mut rng = Rng::new(6);
        let x = rng.normal_vec_f32(64, 1.0);
        let a = gemv_f16_variant(&x, &q, crate::OptConfig::VML, 7);
        let b = gemv_f16_variant(&x, &q, crate::OptConfig::VML, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn smb_is_schedule_independent() {
        // Ordered reduction: same result regardless of call seed.
        let q = quantized_head(64, 8, 8);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec_f32(64, 1.0);
        let a = gemv_f16_variant(&x, &q, crate::OptConfig::SMB, 1);
        let b = gemv_f16_variant(&x, &q, crate::OptConfig::SMB, 2);
        assert_eq!(a, b);
    }

    #[test]
    fn kv_dtype_drift_pins() {
        // The committed accuracy pins of the quantized KV pool (see the
        // table on `kv_dtype_drift`).  f32 is a layout change only, so it
        // must be *exactly* zero — any nonzero drift means the tile walk
        // reordered floating-point operations.
        assert_eq!(kv_dtype_drift(KvDtype::F32), 0.0, "f32 pool must be bit-identical");
        let f16 = kv_dtype_drift(KvDtype::F16);
        assert!(f16 > 0.0, "f16 KV should measurably round");
        assert!(f16 <= 1e-2, "f16 relative logit drift {f16} exceeds the 1e-2 pin");
        let kv4 = kv_dtype_drift(KvDtype::Kv4);
        assert!(kv4 >= f16, "4-bit KV ({kv4}) should drift at least as much as f16 ({f16})");
        assert!(kv4 <= 0.35, "kv4 relative logit drift {kv4} exceeds the 0.35 pin");
    }

    #[test]
    fn kv_dtype_drift_pins_hold_under_gqa_rope() {
        // Same pins at the tiny-gqa registry entry (1 KV head shared by
        // 4 Q heads, RoPE on): sharing rows and pre-rotating K must not
        // widen the compression drift envelope.  f32 stays *exactly*
        // zero — GQA indexing and RoPE are pool-dtype-independent.
        let gqa = crate::models::TINY_GQA;
        assert_eq!(
            kv_dtype_drift_at(gqa, KvDtype::F32),
            0.0,
            "f32 pool must be bit-identical under GQA+RoPE"
        );
        let f16 = kv_dtype_drift_at(gqa, KvDtype::F16);
        assert!(f16 > 0.0, "f16 KV should measurably round under GQA");
        assert!(f16 <= 1e-2, "GQA f16 relative logit drift {f16} exceeds the 1e-2 pin");
        let kv4 = kv_dtype_drift_at(gqa, KvDtype::Kv4);
        assert!(kv4 >= f16, "GQA 4-bit KV ({kv4}) should drift at least as much as f16 ({f16})");
        assert!(kv4 <= 0.35, "GQA kv4 relative logit drift {kv4} exceeds the 0.35 pin");
    }

    #[test]
    fn kv_dtype_drift_is_deterministic() {
        // The harness drives both backends with tokens picked from the f32
        // run, so repeated measurements are exactly reproducible — the pins
        // above are stable numbers, not flaky samples.
        assert_eq!(kv_dtype_drift(KvDtype::Kv4), kv_dtype_drift(KvDtype::Kv4));
    }

    #[test]
    fn baseline_is_schedule_dependent() {
        // Arrival order differs across calls; some outputs must differ.
        let q = quantized_head(256, 8, 10);
        let mut rng = Rng::new(11);
        let mut diffs = 0;
        for call in 0..20u64 {
            let x = rng.normal_vec_f32(256, 1.0);
            let a = gemv_f16_variant(&x, &q, crate::OptConfig::BASELINE, call);
            let b = gemv_f16_variant(&x, &q, crate::OptConfig::BASELINE, call + 1000);
            if a != b {
                diffs += 1;
            }
        }
        assert!(diffs > 0, "arrival order must matter sometimes");
    }
}

//! Homegrown benchmark harness (criterion is not available offline).
//!
//! Provides wall-clock measurement with warmup, robust summary statistics,
//! and the fixed-width table printer every `benches/*.rs` target uses to
//! regenerate the paper's figures/tables as text.

use std::time::Instant;

/// Summary statistics over a sample of measurements (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            max: xs[n - 1],
            p50: percentile(&xs, 0.50),
            p95: percentile(&xs, 0.95),
        }
    }
}

/// Linear-interpolated percentile of a **sorted** slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Run `f` with warmup, measuring wall time per iteration.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = Stats::from_samples(samples);
    println!(
        "{name:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  (n={})",
        fmt_duration(s.mean),
        fmt_duration(s.p50),
        fmt_duration(s.p95),
        s.n
    );
    s
}

pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Fixed-width text table used by the figure/table reproduction benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![0.0, 10.0];
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("333"));
        assert_eq!(r.lines().filter(|l| !l.is_empty()).count(), 5);
    }

    #[test]
    fn bench_runs_and_reports() {
        let s = bench("noop", 1, 5, || {});
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }
}

//! Minimal property-based testing kit (proptest is not available offline).
//!
//! Seeded generators + a runner that, on failure, reports the seed and the
//! case index so the exact input can be replayed deterministically.  Used
//! by `rust/tests/properties.rs` for the coordinator/gptq/f16 invariants.

use crate::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0x7461_c0de } // deterministic default
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with replay info on
/// the first failure.
pub fn check<T, G, P>(name: &str, cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (seed {:#x}):\n  input: {input:?}\n  {msg}",
                cfg.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Convenience: assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "trivial",
            Config { cases: 17, seed: 1 },
            |r| r.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property \"fails\"")]
    fn failing_property_reports() {
        check(
            "fails",
            Config { cases: 10, seed: 2 },
            |r| r.below(100),
            |&x| ensure(x < 10, format!("{x} >= 10")),
        );
    }
}

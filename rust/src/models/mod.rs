//! Architectures of the six GPTQ models the paper evaluates.
//!
//! The throughput/latency figures' per-model variation is driven entirely
//! by the transformer dimensions (which GEMM shapes run, how many times,
//! per token); we reproduce those dims exactly from the public model
//! cards.  Weights are *not* needed for the performance study — the
//! executable tiny model used by the PJRT path is described by the AOT
//! manifest instead (see [`crate::runtime`]).

use crate::dcusim::kernels::KernelParams;

/// Transformer architecture (decoder-only, Llama/Qwen style).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (GQA when < n_heads, e.g. Llama-3).
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// GPTQ group size of the public checkpoints (128 for all six).
    pub group_size: usize,
}

impl ModelSpec {
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.d_head
    }

    /// Approximate parameter count (billions), for reporting.
    pub fn params_b(&self) -> f64 {
        let attn = self.d_model * self.d_model * 2
            + self.d_model * self.kv_dim() * 2;
        let mlp = 3 * self.d_model * self.d_ff;
        let emb = 2 * self.vocab * self.d_model;
        (self.n_layers * (attn + mlp) + emb) as f64 / 1e9
    }

    /// The quantized GEMM shapes one token's decode step runs **per
    /// layer** (the kernel calls the paper's optimizations accelerate).
    pub fn layer_gemms(&self, m: usize) -> Vec<KernelParams> {
        let d = self.d_model;
        let g = self.group_size;
        vec![
            KernelParams { m, k: d, n: d, group_size: g },            // wq
            KernelParams { m, k: d, n: self.kv_dim(), group_size: g }, // wk
            KernelParams { m, k: d, n: self.kv_dim(), group_size: g }, // wv
            KernelParams { m, k: d, n: d, group_size: g },            // wo
            KernelParams { m, k: d, n: self.d_ff, group_size: g },    // gate
            KernelParams { m, k: d, n: self.d_ff, group_size: g },    // up
            KernelParams { m, k: self.d_ff, n: d, group_size: g },    // down
        ]
    }

    /// Bytes of packed GPTQ weights per layer (drives cache/bandwidth).
    pub fn layer_weight_bytes(&self) -> u64 {
        self.layer_gemms(1).iter().map(|p| p.min_bytes() - (p.m * (p.k + p.n) * 2) as u64).sum()
    }
}

/// The six models of the paper's evaluation, in the paper's order
/// (Figures 2–3 and Tables I–II iterate Qwen-4B, Qwen-1.8B, LLaMa-13B,
/// CodeLlama-7B, Llama-2-7B, Meta-Llama-3-8B).
pub const PAPER_MODELS: [ModelSpec; 6] = [
    ModelSpec {
        name: "Qwen1.5-4B-Chat-GPTQ-Int4",
        n_layers: 40, d_model: 2560, n_heads: 20, n_kv_heads: 20,
        d_head: 128, d_ff: 6912, vocab: 151936, group_size: 128,
    },
    ModelSpec {
        name: "Qwen1.5-1.8B-Chat-GPTQ-Int4",
        n_layers: 24, d_model: 2048, n_heads: 16, n_kv_heads: 16,
        d_head: 128, d_ff: 5504, vocab: 151936, group_size: 128,
    },
    ModelSpec {
        name: "LLaMa-13B-GPTQ",
        n_layers: 40, d_model: 5120, n_heads: 40, n_kv_heads: 40,
        d_head: 128, d_ff: 13824, vocab: 32000, group_size: 128,
    },
    ModelSpec {
        name: "CodeLlama-7B-GPTQ",
        n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32,
        d_head: 128, d_ff: 11008, vocab: 32016, group_size: 128,
    },
    ModelSpec {
        name: "Llama-2-7B-GPTQ",
        n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32,
        d_head: 128, d_ff: 11008, vocab: 32000, group_size: 128,
    },
    ModelSpec {
        name: "Meta-Llama-3-8B-GPTQ",
        n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 8,
        d_head: 128, d_ff: 14336, vocab: 128256, group_size: 128,
    },
];

pub fn by_name(name: &str) -> Option<&'static ModelSpec> {
    PAPER_MODELS.iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_model_names() {
        let approx: Vec<(f64, f64)> = PAPER_MODELS
            .iter()
            .map(|m| (m.params_b(), expected(m.name)))
            .collect();
        for ((got, want), m) in approx.iter().zip(PAPER_MODELS.iter()) {
            assert!(
                (got - want).abs() / want < 0.20,
                "{}: computed {got:.2}B vs nominal {want}B",
                m.name
            );
        }
        fn expected(name: &str) -> f64 {
            if name.contains("13B") { 13.0 }
            else if name.contains("1.8B") { 1.8 }
            else if name.contains("8B") { 8.0 }
            else if name.contains("7B") { 6.7 }
            else { 3.9 }
        }
    }

    #[test]
    fn gemm_shapes_align_with_kernel_constraints() {
        use crate::dcusim::kernels::gemv::{K_SLAB, N_TILE};
        for m in PAPER_MODELS {
            for p in m.layer_gemms(1) {
                assert_eq!(p.k % K_SLAB, 0, "{}: K={} not /{K_SLAB}", m.name, p.k);
                assert_eq!(p.n % N_TILE, 0, "{}: N={} not /{N_TILE}", m.name, p.n);
                assert_eq!(p.k % p.group_size, 0);
            }
        }
    }

    #[test]
    fn llama3_uses_gqa() {
        let m = by_name("Meta-Llama-3-8B-GPTQ").unwrap();
        assert_eq!(m.n_kv_heads, 8);
        assert_eq!(m.kv_dim(), 1024);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("LLaMa-13B-GPTQ").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn thirteen_b_has_most_gemm_work() {
        let work = |m: &ModelSpec| -> u64 {
            m.layer_gemms(1).iter().map(|p| p.flops()).sum::<u64>() * m.n_layers as u64
        };
        let m13 = by_name("LLaMa-13B-GPTQ").unwrap();
        for m in PAPER_MODELS.iter() {
            if m.name != m13.name {
                assert!(work(m13) > work(m), "{}", m.name);
            }
        }
    }
}

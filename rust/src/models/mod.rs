//! The unified model-config registry: every transformer shape in the
//! repo — the six paper checkpoints, the executable tiny configs, and
//! the scaled-down Llama-shaped minis — is one [`ModelConfig`].
//!
//! Before this module unified them, the repo carried **two** config
//! types: `engine::cpu_backend::CpuModelConfig` (executable, but MHA
//! with learned positions only) and `models::ModelSpec` (the paper's
//! GQA dims, never executed).  [`ModelConfig`] merges them: it carries
//! the architecture (`n_kv_heads` for grouped-query attention, `rope`
//! for rotary embeddings) *and* the execution envelope
//! (`max_seq`/`max_batch`/`seed`), so the same value drives
//! `engine::CpuBackend` weight synthesis, `engine::backend::SimBackend`
//! perf modeling, `PagedKvCache` pool sizing (`kv_dim = n_kv_heads ·
//! d_head` — the GQA pool shrink), and the `serve --model` CLI.
//!
//! # Named registry (executable configs)
//!
//! Resolved by [`registry_by_name`] / `serve --model <name>` /
//! `OPT4GPTQ_MODEL` (warn-once fallback to `tiny-mha` on unknown
//! values, like `OPT4GPTQ_KERNEL`/`OPT4GPTQ_KV`).  Pool bytes/token is
//! `2 · n_layers · row_bytes(kv_dim)` (both cache sides, all layers):
//!
//! | name               | heads | kv heads | RoPE | kv_dim | bytes/token f32 | f16 | kv4 |
//! |--------------------|-------|----------|------|--------|-----------------|-----|-----|
//! | `tiny-mha`         | 4     | 4        | no   | 64     | 1024            | 512 | 160 |
//! | `tiny-gqa`         | 4     | 1        | yes  | 16     | 256             | 128 | 64  |
//! | `mini-qwen-4b`     | 4     | 4        | yes  | 64     | 1024            | 512 | 160 |
//! | `mini-qwen-1.8b`   | 4     | 4        | yes  | 64     | 1024            | 512 | 160 |
//! | `mini-llama-13b`   | 4     | 4        | yes  | 64     | 1024            | 512 | 160 |
//! | `mini-codellama-7b`| 4     | 4        | yes  | 64     | 1024            | 512 | 160 |
//! | `mini-llama2-7b`   | 4     | 4        | yes  | 64     | 1024            | 512 | 160 |
//! | `mini-llama3-8b`   | 4     | 1        | yes  | 16     | 256             | 128 | 64  |
//!
//! `tiny-mha` is bit-for-bit the pre-registry `CpuModelConfig::default()`
//! (MHA, learned positions), so every golden recorded against it stays
//! valid.  `tiny-gqa` is the same envelope with `n_kv_heads = 1` and
//! RoPE on — the 4× KV-pool shrink the `kv_cache` bench gates.  The
//! `mini-*` entries scale each paper checkpoint down to the executable
//! tiny envelope while preserving its GQA ratio (`mini-llama3-8b` keeps
//! Llama-3's 4:1 grouping; the rest are 1:1).
//!
//! Every named config (registry **and** paper specs) is checked against
//! the kernel constraints at registry load: `d_model % n_heads == 0`,
//! `n_heads % n_kv_heads == 0`, the GPTQ group size dividing both GEMM
//! K-dims (`d_model`, `d_ff`), and an even `d_head` wherever RoPE is on
//! (rotation works on lane pairs).
//!
//! The paper checkpoints ([`PAPER_MODELS`]) drive the perf study via
//! `SimBackend`; weights are *not* needed there — per-token GEMM shapes
//! and byte traffic ([`ModelConfig::layer_gemms`]) are what the figures
//! consume.

use std::sync::OnceLock;

use crate::dcusim::kernels::KernelParams;
use crate::envcfg::{env_override, EnvOverride};

/// One transformer shape (decoder-only, Llama/Qwen style) plus its
/// execution envelope.  See the module docs for the named registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (grouped-query attention when < n_heads, e.g. Llama-3).
    /// Sizes the K/V projections and the paged pool: `kv_dim =
    /// n_kv_heads · d_head`.
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// GPTQ group size of the checkpoints (128 for all six paper
    /// models; 32 for the tiny configs so two groups fit in `d_model`).
    pub group_size: usize,
    /// Rotary position embeddings, applied at K/V-append time.  Off =
    /// the pre-registry learned-position model (additive table).
    pub rope: bool,
    /// Longest sequence the executable backend admits.
    pub max_seq: usize,
    /// Widest batch the executable backend admits.
    pub max_batch: usize,
    /// Weight-synthesis RNG seed (`CpuBackend` derives every tensor
    /// from it; same seed + same dims ⇒ bit-identical weights).
    pub seed: u64,
}

/// The old name for the executable config, kept as an alias so call
/// sites read naturally next to `SimBackend`'s perf-model usage.
pub type ModelSpec = ModelConfig;

impl ModelConfig {
    /// Per-head width, derived: every named config keeps
    /// `d_model = n_heads · d_head` exactly.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Width of one K (or V) row — what the paged pool stores per
    /// position per layer.  Equals `d_model` for MHA, shrinks by the
    /// GQA ratio below it.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.d_head()
    }

    /// Q heads per KV head (1 for MHA).
    pub fn gqa_ratio(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Kernel-constraint check run over every named config at registry
    /// load (and by `CpuBackend::new` before synthesizing weights).
    pub fn validate(&self) -> Result<(), String> {
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            return Err(format!(
                "d_model {} must be a positive multiple of n_heads {}",
                self.d_model, self.n_heads
            ));
        }
        if self.n_kv_heads == 0 || self.n_heads % self.n_kv_heads != 0 {
            return Err(format!(
                "n_heads {} must be a positive multiple of n_kv_heads {}",
                self.n_heads, self.n_kv_heads
            ));
        }
        if self.group_size == 0
            || self.d_model % self.group_size != 0
            || self.d_ff % self.group_size != 0
        {
            return Err(format!(
                "group size {} must divide both GEMM K-dims (d_model {}, d_ff {})",
                self.group_size, self.d_model, self.d_ff
            ));
        }
        if self.rope && self.d_head() % 2 != 0 {
            return Err(format!(
                "RoPE rotates lane pairs: d_head {} must be even",
                self.d_head()
            ));
        }
        Ok(())
    }

    /// Approximate parameter count (billions), for reporting.
    pub fn params_b(&self) -> f64 {
        let attn = self.d_model * self.d_model * 2 + self.d_model * self.kv_dim() * 2;
        let mlp = 3 * self.d_model * self.d_ff;
        let emb = 2 * self.vocab * self.d_model;
        (self.n_layers * (attn + mlp) + emb) as f64 / 1e9
    }

    /// The quantized GEMM shapes one token's decode step runs **per
    /// layer** (the kernel calls the paper's optimizations accelerate).
    pub fn layer_gemms(&self, m: usize) -> Vec<KernelParams> {
        let d = self.d_model;
        let g = self.group_size;
        vec![
            KernelParams { m, k: d, n: d, group_size: g },             // wq
            KernelParams { m, k: d, n: self.kv_dim(), group_size: g }, // wk
            KernelParams { m, k: d, n: self.kv_dim(), group_size: g }, // wv
            KernelParams { m, k: d, n: d, group_size: g },             // wo
            KernelParams { m, k: d, n: self.d_ff, group_size: g },     // gate
            KernelParams { m, k: d, n: self.d_ff, group_size: g },     // up
            KernelParams { m, k: self.d_ff, n: d, group_size: g },     // down
        ]
    }

    /// Bytes of packed GPTQ weights per layer (drives cache/bandwidth).
    pub fn layer_weight_bytes(&self) -> u64 {
        self.layer_gemms(1).iter().map(|p| p.min_bytes() - (p.m * (p.k + p.n) * 2) as u64).sum()
    }
}

/// The default executable config — bit-for-bit the pre-registry
/// `CpuModelConfig::default()`, so every golden recorded before the
/// registry stays valid.
pub const TINY_MHA: ModelConfig = ModelConfig {
    name: "tiny-mha",
    n_layers: 2,
    d_model: 64,
    n_heads: 4,
    n_kv_heads: 4,
    d_ff: 128,
    vocab: 256,
    group_size: 32,
    rope: false,
    max_seq: 256,
    max_batch: 8,
    seed: 0x0c17_0b0d,
};

/// `tiny-mha`'s envelope with grouped-query attention (4 Q heads onto
/// 1 KV head — a 4× pool shrink) and RoPE on.
pub const TINY_GQA: ModelConfig = ModelConfig {
    name: "tiny-gqa",
    n_kv_heads: 1,
    rope: true,
    ..TINY_MHA
};

const fn mini(name: &'static str, n_kv_heads: usize) -> ModelConfig {
    ModelConfig { name, n_kv_heads, rope: true, ..TINY_MHA }
}

/// The executable named registry (`serve --model`, `OPT4GPTQ_MODEL`).
/// Validated against the kernel constraints on first resolution — see
/// [`registry`].
pub const REGISTRY: [ModelConfig; 8] = [
    TINY_MHA,
    TINY_GQA,
    // The six paper checkpoints scaled to the tiny executable envelope,
    // preserving each one's GQA grouping (see PAPER_MODELS below).
    mini("mini-qwen-4b", 4),
    mini("mini-qwen-1.8b", 4),
    mini("mini-llama-13b", 4),
    mini("mini-codellama-7b", 4),
    mini("mini-llama2-7b", 4),
    mini("mini-llama3-8b", 1),
];

/// The registry, kernel-constraint-checked (registry **and** paper
/// specs) exactly once per process.
pub fn registry() -> &'static [ModelConfig] {
    static CHECKED: OnceLock<()> = OnceLock::new();
    CHECKED.get_or_init(|| {
        for m in REGISTRY.iter().chain(PAPER_MODELS.iter()) {
            if let Err(e) = m.validate() {
                panic!("model config {:?} violates kernel constraints: {e}", m.name);
            }
        }
    });
    &REGISTRY
}

/// Resolve an executable registry name (`tiny-mha`, `tiny-gqa`, ...).
pub fn registry_by_name(name: &str) -> Option<&'static ModelConfig> {
    registry().iter().find(|m| m.name == name)
}

/// Every name [`registry_by_name`] accepts, for error messages.
pub fn registry_names() -> Vec<&'static str> {
    registry().iter().map(|m| m.name).collect()
}

/// Resolve any named config — executable registry first, then the
/// paper checkpoints (snapshot fingerprints round-trip through this).
pub fn static_by_name(name: &str) -> Option<&'static ModelConfig> {
    registry_by_name(name).or_else(|| by_name(name))
}

static MODEL_ENV: OnceLock<EnvOverride<&'static ModelConfig>> = OnceLock::new();

/// The process-default executable config: `OPT4GPTQ_MODEL` if set to a
/// registry name, else [`TINY_MHA`].  Unknown values warn once on
/// stderr and fall back — the same graceful-degradation contract as
/// `OPT4GPTQ_KERNEL` / `OPT4GPTQ_KV`.
pub fn default_model() -> &'static ModelConfig {
    env_override(&MODEL_ENV, "OPT4GPTQ_MODEL", |raw| {
        registry_by_name(raw).ok_or_else(|| {
            format!(
                "OPT4GPTQ_MODEL={raw:?} is not a registered model config (expected {}|auto); \
                 falling back to tiny-mha",
                registry_names().join("|")
            )
        })
    })
    .value()
    .copied()
    .unwrap_or(&TINY_MHA)
}

impl Default for ModelConfig {
    /// The process default (env-overridable) — every test or bench that
    /// spreads `..Default::default()` follows `OPT4GPTQ_MODEL`, which
    /// is what the CI model-shape matrix flips.
    fn default() -> Self {
        *default_model()
    }
}

/// The six models of the paper's evaluation, in the paper's order
/// (Figures 2–3 and Tables I–II iterate Qwen-4B, Qwen-1.8B, LLaMa-13B,
/// CodeLlama-7B, Llama-2-7B, Meta-Llama-3-8B).  All keep `d_head =
/// d_model / n_heads = 128`; the execution envelope is nominal (these
/// drive `SimBackend` perf modeling, not weight synthesis).
pub const PAPER_MODELS: [ModelSpec; 6] = [
    ModelSpec {
        name: "Qwen1.5-4B-Chat-GPTQ-Int4",
        n_layers: 40, d_model: 2560, n_heads: 20, n_kv_heads: 20,
        d_ff: 6912, vocab: 151936, group_size: 128,
        rope: true, max_seq: 4096, max_batch: 64, seed: 0x0c17_0b0d,
    },
    ModelSpec {
        name: "Qwen1.5-1.8B-Chat-GPTQ-Int4",
        n_layers: 24, d_model: 2048, n_heads: 16, n_kv_heads: 16,
        d_ff: 5504, vocab: 151936, group_size: 128,
        rope: true, max_seq: 4096, max_batch: 64, seed: 0x0c17_0b0d,
    },
    ModelSpec {
        name: "LLaMa-13B-GPTQ",
        n_layers: 40, d_model: 5120, n_heads: 40, n_kv_heads: 40,
        d_ff: 13824, vocab: 32000, group_size: 128,
        rope: true, max_seq: 4096, max_batch: 64, seed: 0x0c17_0b0d,
    },
    ModelSpec {
        name: "CodeLlama-7B-GPTQ",
        n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32,
        d_ff: 11008, vocab: 32016, group_size: 128,
        rope: true, max_seq: 4096, max_batch: 64, seed: 0x0c17_0b0d,
    },
    ModelSpec {
        name: "Llama-2-7B-GPTQ",
        n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32,
        d_ff: 11008, vocab: 32000, group_size: 128,
        rope: true, max_seq: 4096, max_batch: 64, seed: 0x0c17_0b0d,
    },
    ModelSpec {
        name: "Meta-Llama-3-8B-GPTQ",
        n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 8,
        d_ff: 14336, vocab: 128256, group_size: 128,
        rope: true, max_seq: 4096, max_batch: 64, seed: 0x0c17_0b0d,
    },
];

/// Resolve a paper-checkpoint name (perf figures, `simulate`/`accuracy`).
pub fn by_name(name: &str) -> Option<&'static ModelSpec> {
    PAPER_MODELS.iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_model_names() {
        let approx: Vec<(f64, f64)> = PAPER_MODELS
            .iter()
            .map(|m| (m.params_b(), expected(m.name)))
            .collect();
        for ((got, want), m) in approx.iter().zip(PAPER_MODELS.iter()) {
            assert!(
                (got - want).abs() / want < 0.20,
                "{}: computed {got:.2}B vs nominal {want}B",
                m.name
            );
        }
        fn expected(name: &str) -> f64 {
            if name.contains("13B") { 13.0 }
            else if name.contains("1.8B") { 1.8 }
            else if name.contains("8B") { 8.0 }
            else if name.contains("7B") { 6.7 }
            else { 3.9 }
        }
    }

    #[test]
    fn gemm_shapes_align_with_kernel_constraints() {
        use crate::dcusim::kernels::gemv::{K_SLAB, N_TILE};
        for m in PAPER_MODELS {
            for p in m.layer_gemms(1) {
                assert_eq!(p.k % K_SLAB, 0, "{}: K={} not /{K_SLAB}", m.name, p.k);
                assert_eq!(p.n % N_TILE, 0, "{}: N={} not /{N_TILE}", m.name, p.n);
                assert_eq!(p.k % p.group_size, 0);
            }
        }
    }

    #[test]
    fn llama3_uses_gqa() {
        let m = by_name("Meta-Llama-3-8B-GPTQ").unwrap();
        assert_eq!(m.n_kv_heads, 8);
        assert_eq!(m.kv_dim(), 1024);
        assert_eq!(m.gqa_ratio(), 4);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("LLaMa-13B-GPTQ").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn thirteen_b_has_most_gemm_work() {
        let work = |m: &ModelSpec| -> u64 {
            m.layer_gemms(1).iter().map(|p| p.flops()).sum::<u64>() * m.n_layers as u64
        };
        let m13 = by_name("LLaMa-13B-GPTQ").unwrap();
        for m in PAPER_MODELS.iter() {
            if m.name != m13.name {
                assert!(work(m13) > work(m), "{}", m.name);
            }
        }
    }

    #[test]
    fn every_named_config_passes_the_load_time_constraint_check() {
        // `registry()` panics on the first violation; resolving it (and
        // every name) is the assertion.
        for m in registry() {
            assert!(registry_by_name(m.name).is_some(), "{} must resolve", m.name);
            m.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
        }
        for m in PAPER_MODELS.iter() {
            assert!(static_by_name(m.name).is_some(), "{} must resolve", m.name);
        }
        assert!(registry_by_name("Llama-2-7B-GPTQ").is_none(), "paper specs are not servable");
    }

    #[test]
    fn tiny_mha_is_the_pre_registry_default_shape() {
        // The golden contract: `tiny-mha` must keep the exact dims +
        // seed the pre-registry `CpuModelConfig::default()` carried, or
        // every recorded token/logit golden silently re-bases.
        let m = TINY_MHA;
        assert_eq!(
            (m.vocab, m.d_model, m.n_layers, m.n_heads, m.n_kv_heads, m.d_ff, m.group_size),
            (256, 64, 2, 4, 4, 128, 32)
        );
        assert_eq!((m.max_seq, m.max_batch, m.seed), (256, 8, 0x0c17_0b0d));
        assert!(!m.rope);
        assert_eq!(m.kv_dim(), m.d_model, "MHA stores full-width K/V rows");
    }

    #[test]
    fn tiny_gqa_shrinks_the_pool_by_the_head_ratio() {
        let m = TINY_GQA;
        assert!(m.rope);
        assert_eq!(m.gqa_ratio(), 4);
        assert_eq!(m.kv_dim(), 16);
        assert_eq!(m.d_head(), TINY_MHA.d_head(), "GQA shares KV heads, not narrower ones");
        // The capacity multiplier the kv_cache bench gates (≥ 1.9× at
        // equal dtype) in its pure-arithmetic form.
        for dtype in crate::engine::KvDtype::ALL {
            let mha = dtype.row_bytes(TINY_MHA.kv_dim());
            let gqa = dtype.row_bytes(m.kv_dim());
            assert!(
                mha as f64 / gqa as f64 >= 1.9,
                "{dtype}: {mha}B vs {gqa}B per row is under the 1.9x floor"
            );
        }
    }

    #[test]
    fn invalid_shapes_are_rejected_with_the_violated_constraint() {
        let bad_heads = ModelConfig { n_heads: 3, ..TINY_MHA };
        assert!(bad_heads.validate().unwrap_err().contains("n_heads"));
        let bad_kv = ModelConfig { n_kv_heads: 3, ..TINY_MHA };
        assert!(bad_kv.validate().unwrap_err().contains("n_kv_heads"));
        let bad_group = ModelConfig { group_size: 48, ..TINY_MHA };
        assert!(bad_group.validate().unwrap_err().contains("group size"));
        // d_head 64/4 = 16 is even; force odd via n_heads 64 → d_head 1.
        let odd_head = ModelConfig { n_heads: 64, n_kv_heads: 64, rope: true, ..TINY_MHA };
        assert!(odd_head.validate().unwrap_err().contains("even"));
    }
}

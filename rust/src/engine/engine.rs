//! The engine step loop: schedule → execute one mixed batch → sample →
//! account.
//!
//! Every step is a single [`Backend::step`] call carrying the prefill
//! chunks the scheduler fit under the token budget *plus* the whole
//! decode batch.  Prefill progress is tracked per sequence
//! ([`super::sequence::Sequence::prefill_pos`]); a sequence joins the
//! decode batch only after its final chunk executes and its first token
//! is sampled from that chunk's logits.
//!
//! Requests carry a virtual arrival time: until the engine clock
//! reaches it, a request sits in a pending set the scheduler never
//! sees.  When everything admitted has drained and arrivals remain, the
//! clock jumps forward to the next one.  Swap-preemption plumbing lives
//! here too, with a strict drain order per step: freshly swapped-in
//! tables reach the backend (spill restored) *before* the step
//! executes, and swap-out spill copies happen *before* freed blocks are
//! released (poisoned/recycled) after it.

use std::collections::HashMap;

use anyhow::bail;

use crate::rng::Rng;
use crate::Result;

use super::backend::{Backend, DecodeDesc, PrefillDesc, StepError};
use super::fault::FaultSeam;
use super::metrics::Metrics;
use super::request::{Request, RequestOutcome, RequestOutput};
use super::sampler;
use super::scheduler::{PrefillChunk, ScheduledWork, Scheduler};
use super::sequence::SeqState;
use super::EngineConfig;

/// Consecutive transient step failures tolerated before the batch is
/// failed as if the error were permanent.
const MAX_STEP_RETRIES: u32 = 8;
/// First retry backoff, virtual seconds; doubles per consecutive
/// failure up to [`RETRY_BACKOFF_CAP`].
const RETRY_BACKOFF_BASE: f64 = 0.05;
const RETRY_BACKOFF_CAP: f64 = 1.0;
/// Clock advance per admission pass stalled by an injected allocation
/// refusal (the scheduler returned Idle with work still queued).
const FAULT_STALL_BACKOFF: f64 = 0.01;
/// Consecutive stalled admission passes tolerated before the run is
/// declared wedged (only reachable with an `alloc` fault rate of 1).
const MAX_FAULT_STALLS: usize = 10_000;

/// Result of a full engine run.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Per-request outputs of **completed** requests only.
    pub outputs: Vec<RequestOutput>,
    /// Every request's terminal [`RequestOutcome`], sorted by id —
    /// exactly one entry per request the engine ever saw, whether it
    /// completed, was rejected/shed, timed out past its deadline, or
    /// failed on a permanent backend error.
    pub outcomes: Vec<(usize, RequestOutcome)>,
    pub metrics: Metrics,
}

/// The serving engine: owns the scheduler and a backend.
pub struct Engine<B: Backend> {
    pub cfg: EngineConfig,
    pub scheduler: Scheduler,
    pub backend: B,
    /// Virtual (sim) or accumulated-wall (PJRT) clock, seconds.
    pub clock: f64,
    pub metrics: Metrics,
    rngs: HashMap<usize, Rng>,
    outputs: Vec<RequestOutput>,
    /// Requests whose arrival time the clock has not reached yet —
    /// invisible to the scheduler until then.
    pending: Vec<Request>,
    /// Terminal outcome per request id, in resolution order.
    outcomes: Vec<(usize, RequestOutcome)>,
    /// Transient step failures since the last successful step; resets
    /// on success, escalates to batch failure at [`MAX_STEP_RETRIES`].
    consecutive_step_failures: u32,
    /// Consecutive admission passes stalled by injected alloc faults.
    fault_stalls: usize,
}

impl<B: Backend> Engine<B> {
    pub fn new(mut cfg: EngineConfig, mut backend: B) -> Engine<B> {
        cfg.max_batch = cfg.max_batch.min(backend.max_batch());
        cfg.max_seq_len = cfg.max_seq_len.min(backend.max_seq_len());
        // Announce the paged-KV geometry: backends owning physical K/V
        // size their block pool to the manager's, so every BlockId a
        // table can carry is addressable.
        backend.bind_kv(cfg.total_blocks, cfg.block_size, cfg.kv_dtype);
        Engine {
            scheduler: Scheduler::new(cfg),
            backend,
            clock: 0.0,
            metrics: Metrics::default(),
            rngs: HashMap::new(),
            outputs: Vec::new(),
            pending: Vec::new(),
            outcomes: Vec::new(),
            consecutive_step_failures: 0,
            fault_stalls: 0,
            cfg,
        }
    }

    pub fn add_request(&mut self, req: Request) {
        self.rngs.insert(req.id, Rng::new(req.sampling.seed ^ req.id as u64));
        self.metrics.prompt_tokens += req.prompt.len();
        if req.arrival <= self.clock {
            self.scheduler.add_request(&req);
        } else {
            self.pending.push(req);
        }
    }

    /// Move pending requests whose arrival the clock has reached into
    /// the scheduler's queue.
    fn admit_arrivals(&mut self) {
        let clock = self.clock;
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].arrival <= clock {
                let req = self.pending.swap_remove(i);
                self.scheduler.add_request(&req);
            } else {
                i += 1;
            }
        }
    }

    /// Run one engine step.  Returns false when there is no work left.
    pub fn step(&mut self) -> Result<bool> {
        loop {
            self.admit_arrivals();
            self.expire_deadlines();
            // Deadline retirements free blocks: forward them to the
            // backend *before* schedule() can hand the same ids out
            // again, or the release-time poison would clobber live K/V.
            self.drain_releases();
            let work = self.scheduler.schedule(self.clock);
            // Resolve anything add_request shed or schedule() rejected
            // (oversized / provably never admittable) this pass.
            self.drain_rejections();
            match work {
                ScheduledWork::Idle => {
                    self.drain_releases();
                    if self.scheduler.has_work() {
                        // An injected allocation refusal stalled
                        // admission (a full pool would have produced a
                        // Step or a rejection instead): back the clock
                        // off and retry, with a wedge cap so an
                        // always-firing fault cannot spin forever.
                        self.fault_stalls += 1;
                        if self.fault_stalls > MAX_FAULT_STALLS {
                            bail!("admission wedged: {MAX_FAULT_STALLS} consecutive injected allocation stalls");
                        }
                        self.clock += FAULT_STALL_BACKOFF;
                        continue;
                    }
                    // Nothing runnable now; if future arrivals remain,
                    // jump the clock to the next one and retry.
                    let next =
                        self.pending.iter().map(|r| r.arrival).fold(f64::INFINITY, f64::min);
                    if next.is_finite() {
                        self.clock = self.clock.max(next);
                        continue;
                    }
                    return Ok(false);
                }
                ScheduledWork::Step { mut prefills, decodes } => {
                    self.fault_stalls = 0;
                    let failed_restores = self.restore_swapped();
                    if !failed_restores.is_empty() {
                        // A failed restore demoted its sequence to
                        // recompute; its chunk must not execute through
                        // the just-freed table.
                        prefills.retain(|c| !failed_restores.contains(&c.seq_id));
                    }
                    if prefills.is_empty() && decodes.is_empty() {
                        // The whole batch was failed restores.
                        self.drain_releases();
                        continue;
                    }
                    self.run_step(prefills, decodes)?;
                    self.metrics.engine_steps += 1;
                    self.drain_releases();
                    return Ok(true);
                }
            }
        }
    }

    /// Cancel every request whose deadline the clock has passed —
    /// queued, mid-prefill, decoding, preempted, swapped, or not yet
    /// admitted — with full block/spill reclamation.
    fn expire_deadlines(&mut self) {
        let clock = self.clock;
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].deadline.map_or(false, |d| d < clock) {
                let req = self.pending.swap_remove(i);
                self.resolve(req.id, RequestOutcome::TimedOut);
            } else {
                i += 1;
            }
        }
        let mut expired: Vec<usize> = self
            .scheduler
            .seqs
            .iter()
            .filter(|(_, s)| s.state != SeqState::Finished)
            .filter(|(_, s)| s.deadline.map_or(false, |d| d < clock))
            .map(|(&id, _)| id)
            .collect();
        // The seq map is a HashMap: sort so retirement (and thus block
        // free order) is replay-deterministic.
        expired.sort_unstable();
        for id in expired {
            self.scheduler.retire(id);
            self.resolve(id, RequestOutcome::TimedOut);
        }
    }

    /// Record a request's terminal outcome and bump its metric.
    fn resolve(&mut self, id: usize, outcome: RequestOutcome) {
        match &outcome {
            RequestOutcome::Completed => {}
            RequestOutcome::Rejected { .. } => self.metrics.rejected_requests += 1,
            RequestOutcome::TimedOut => self.metrics.timed_out_requests += 1,
            RequestOutcome::Failed { .. } => self.metrics.failed_requests += 1,
        }
        self.outcomes.push((id, outcome));
    }

    /// Turn scheduler-side rejections (shed / oversized / never-fit)
    /// into typed outcomes.
    fn drain_rejections(&mut self) {
        for (id, reason) in self.scheduler.take_rejected() {
            self.resolve(id, RequestOutcome::Rejected { reason });
        }
    }

    /// Drive to completion; returns outputs + metrics.
    pub fn run(&mut self) -> Result<EngineReport> {
        while self.step()? {}
        self.metrics.elapsed = self.clock;
        self.metrics.preemptions = self.scheduler.preemption_count;
        self.metrics.prefill_tokens_skipped = self.scheduler.prefill_tokens_skipped;
        self.metrics.swap_outs = self.scheduler.swap_out_count;
        self.metrics.swap_ins = self.scheduler.swap_in_count;
        self.metrics.swap_restored_tokens = self.scheduler.swap_restored_tokens;
        if let Some(kv) = self.backend.kv_stats() {
            self.metrics.kv_pool_bytes = kv.pool_bytes;
            self.metrics.kv_bytes_per_token = kv.bytes_per_token;
            self.metrics.kv_spill_peak_bytes = kv.spill_peak_bytes;
        }
        self.metrics.shed_requests = self.scheduler.shed_count;
        if let Err(e) = self.audit() {
            bail!("post-drain invariant audit failed: {e}");
        }
        let mut outcomes = std::mem::take(&mut self.outcomes);
        outcomes.sort_by_key(|&(id, _)| id);
        Ok(EngineReport {
            outputs: std::mem::take(&mut self.outputs),
            outcomes,
            metrics: self.metrics.clone(),
        })
    }

    /// Post-drain invariant auditor: after a run (or any quiescent
    /// point with no live sequences) the scheduler queues must be
    /// consistent, every KV block must be back on the free list with
    /// no leaked tables or spill reservations, the backend must hold
    /// zero spill bytes, and — on backends owning a physical pool, in
    /// debug builds — every free block's K/V rows must be poison or
    /// virgin (nothing live leaked into freed memory).
    pub fn audit(&self) -> std::result::Result<(), String> {
        self.scheduler.check_invariants()?;
        self.scheduler.blocks.assert_drained()?;
        if let Some(kv) = self.backend.kv_stats() {
            if kv.spill_bytes != 0 {
                return Err(format!(
                    "backend still holds {} spill bytes after drain",
                    kv.spill_bytes
                ));
            }
        }
        if let Some(pool) = self.backend.paged_kv() {
            pool.audit(self.scheduler.blocks.free_list())?;
        }
        Ok(())
    }

    /// Hand freshly swapped-in sequences' new block tables to the
    /// backend so it can restore their spilled K/V — strictly before
    /// the step executes through those tables.  A restore that fails
    /// (injected [`FaultSeam::SpillIn`] or a backend error) is
    /// unrecoverable for that spill: the entry is dropped and the
    /// sequence demoted to recompute-from-scratch — never re-swapped,
    /// since its blocks were never restored.  Returns the demoted ids
    /// so the caller can strip their chunks from the batch.
    fn restore_swapped(&mut self) -> Vec<usize> {
        let mut failed = Vec::new();
        for (seq_id, blocks) in self.scheduler.blocks.take_swap_ins() {
            let res = if self.scheduler.faults.fire(FaultSeam::SpillIn) {
                Err(StepError::Transient("injected spill restore fault".into()))
            } else {
                self.backend.swap_in(seq_id, &blocks)
            };
            if res.is_err() {
                self.backend.drop_spill(seq_id);
                self.scheduler.fail_restore(seq_id);
                self.metrics.spill_faults += 1;
                failed.push(seq_id);
            }
        }
        failed
    }

    /// Forward blocks/sequences the scheduler released during this step
    /// to the backend.  Runs after execution and before the next
    /// `schedule()` can re-allocate the freed blocks, so a paged backend
    /// may safely poison or recycle the memory.
    fn drain_releases(&mut self) {
        // Spill swap-out victims' K/V first: their freed blocks are in
        // the released list below, and the copy must happen before the
        // backend can poison or rewrite that memory.  A spill write
        // that fails (injected [`FaultSeam::SpillOut`] or a backend
        // error) moved no bytes — the victim's K/V is lost with its
        // blocks, so it is demoted to recompute on the spot.
        for (seq_id, blocks) in self.scheduler.blocks.take_swap_outs() {
            let res = if self.scheduler.faults.fire(FaultSeam::SpillOut) {
                Err(StepError::Transient("injected spill write fault".into()))
            } else {
                self.backend.swap_out(seq_id, &blocks)
            };
            match res {
                Ok(bytes) => self.metrics.swap_spilled_bytes += bytes,
                Err(_) => {
                    self.backend.drop_spill(seq_id);
                    self.scheduler.demote_swap(seq_id);
                    self.metrics.spill_faults += 1;
                }
            }
        }
        let (blocks, seqs) = self.scheduler.blocks.take_released();
        if !blocks.is_empty() {
            self.backend.release_blocks(&blocks);
        }
        for id in seqs {
            self.backend.release_seq(id);
        }
    }

    /// Execute one mixed batch: prefill chunks + decode rows in a single
    /// backend call, then sample, advance prefill cursors and account.
    fn run_step(&mut self, prefills: Vec<PrefillChunk>, decodes: Vec<usize>) -> Result<()> {
        // Fault draws happen first (they need `&mut` on the schedule's
        // draw state, which the descriptors below borrow): one
        // permanent and one transient draw per step, each stream
        // advancing exactly once so a plan replays identically.
        let inject_permanent = self.scheduler.faults.fire(FaultSeam::StepPermanent);
        let inject_transient = self.scheduler.faults.fire(FaultSeam::StepTransient);
        // Only each chunk's own span is materialized (owned buffers the
        // descriptors borrow from while the backend runs) — never the
        // whole effective prompt per step.
        let chunk_tokens: Vec<Vec<u32>> = prefills
            .iter()
            .map(|c| self.scheduler.seqs[&c.seq_id].effective_slice(c.start, c.len))
            .collect();
        let prefill_descs: Vec<PrefillDesc<'_>> = prefills
            .iter()
            .zip(&chunk_tokens)
            .map(|(c, tokens)| PrefillDesc {
                seq_id: c.seq_id,
                tokens: tokens.as_slice(),
                start: c.start,
                is_last: c.is_last,
                block_table: self
                    .scheduler
                    .blocks
                    .table(c.seq_id)
                    .expect("prefill without allocation"),
            })
            .collect();
        let decode_descs: Vec<DecodeDesc<'_>> = decodes
            .iter()
            .map(|id| {
                let s = &self.scheduler.seqs[id];
                DecodeDesc {
                    seq_id: *id,
                    // position() counts the fed token, whose K/V entry
                    // lands one past the materialized context.
                    context_len: s.position() - 1,
                    token: s.last_token(),
                    block_table: self
                        .scheduler
                        .blocks
                        .table(*id)
                        .expect("decode without allocation"),
                }
            })
            .collect();
        // Nothing engine-side has mutated yet — scheduler cursors, the
        // clock and all RNG streams are exactly as schedule() left
        // them.  That is what makes a failed step *discardable*: the
        // recovery below re-drives the ordinary preemption machinery
        // and the retried work replays bit-identically.
        let result = if inject_permanent {
            Err(StepError::Permanent("injected permanent backend fault".into()))
        } else if inject_transient {
            Err(StepError::Transient("injected transient backend fault".into()))
        } else {
            self.backend.step(&prefill_descs, &decode_descs)
        };
        let mut out = match result {
            Ok(out) => out,
            Err(err) => {
                drop(prefill_descs);
                drop(decode_descs);
                return self.recover_step_failure(&prefills, &decodes, err);
            }
        };
        self.consecutive_step_failures = 0;
        debug_assert_eq!(out.prefill_logits.len(), prefills.len());
        debug_assert_eq!(out.decode_logits.len(), decodes.len());
        drop(prefill_descs);
        drop(decode_descs);
        self.clock += out.secs;
        if !prefills.is_empty() {
            self.metrics.prefill_steps += 1;
            self.metrics.prefill_chunks += prefills.len();
        }
        if !decodes.is_empty() {
            self.metrics.decode_steps += 1;
            self.metrics.decode_batch_sum += decodes.len();
        }

        // Prefill bookkeeping: advance every chunk's cursor; final
        // chunks sample their first token and join the decode batch.
        for (i, chunk) in prefills.iter().enumerate() {
            // An earlier append in this same loop may have preempted
            // this chunk's sequence (KV exhaustion); its cursor must
            // not move — recompute restarts, swap resumes from where
            // the cursor froze.
            if self.scheduler.seqs[&chunk.seq_id].state != SeqState::Prefilling {
                continue;
            }
            self.scheduler.advance_prefill(chunk);
            if !chunk.is_last {
                continue;
            }
            let logits = std::mem::take(&mut out.prefill_logits[i])
                .expect("final chunk must produce logits");
            let id = chunk.seq_id;
            {
                let seq = self.scheduler.seqs.get_mut(&id).unwrap();
                let rng = self.rngs.get_mut(&id).unwrap();
                let t = sampler::sample(&logits, &seq.sampling, rng);
                seq.generated.push(t);
                if seq.first_token_time.is_none() {
                    seq.first_token_time = Some(self.clock);
                    self.metrics.ttfts.push(self.clock - seq.arrival);
                }
            }
            self.metrics.output_tokens += 1;
            if !self.scheduler.append_token(id) {
                // Self-preempted: will re-run later; nothing else to do.
                continue;
            }
            self.scheduler.promote_to_running(id);
            self.maybe_finish(id);
        }

        for (id, logits) in decodes.into_iter().zip(out.decode_logits) {
            // The sequence may have been preempted by an earlier seq in
            // this same loop (KV exhaustion); skip it then.
            if self.scheduler.seqs[&id].state != SeqState::Running {
                continue;
            }
            let seq = self.scheduler.seqs.get_mut(&id).unwrap();
            let rng = self.rngs.get_mut(&id).unwrap();
            let t = sampler::sample(&logits, &seq.sampling, rng);
            seq.generated.push(t);
            self.metrics.output_tokens += 1;
            if !self.scheduler.append_token(id) {
                continue;
            }
            self.maybe_finish(id);
        }
        Ok(())
    }

    /// A backend step failed before any of its output was consumed.
    ///
    /// Transient: discard, preempt every live batch member through the
    /// regular swap/recompute machinery, bump the bounded exponential
    /// backoff and retry on the next step — the resumed work replays
    /// through the same RNG streams, so eventually-completed tokens
    /// stay bit-identical to a fault-free run.  Permanent (or a
    /// transient streak hitting [`MAX_STEP_RETRIES`]): every batch
    /// member resolves as [`RequestOutcome::Failed`] with full
    /// reclamation, and the engine keeps serving everyone else.
    fn recover_step_failure(
        &mut self,
        prefills: &[PrefillChunk],
        decodes: &[usize],
        err: StepError,
    ) -> Result<()> {
        let mut batch: Vec<usize> =
            prefills.iter().map(|c| c.seq_id).chain(decodes.iter().copied()).collect();
        batch.sort_unstable();
        batch.dedup();
        if err.is_transient() {
            self.consecutive_step_failures += 1;
            if self.consecutive_step_failures < MAX_STEP_RETRIES {
                self.metrics.step_retries += 1;
                self.scheduler.preempt_for_retry(&batch);
                let exp = (self.consecutive_step_failures - 1).min(30);
                self.clock +=
                    (RETRY_BACKOFF_BASE * f64::powi(2.0, exp as i32)).min(RETRY_BACKOFF_CAP);
                return Ok(());
            }
        }
        let reason = if err.is_transient() {
            format!("retries exhausted after {MAX_STEP_RETRIES} transient errors: {}", err.reason())
        } else {
            err.reason().to_string()
        };
        self.consecutive_step_failures = 0;
        for id in batch {
            self.scheduler.retire(id);
            self.resolve(id, RequestOutcome::Failed { reason: reason.clone() });
        }
        Ok(())
    }

    fn maybe_finish(&mut self, id: usize) {
        let done = {
            let seq = &self.scheduler.seqs[&id];
            seq.is_done(self.cfg.max_seq_len)
        };
        if let Some(reason) = done {
            self.scheduler.finish(id);
            let seq = &self.scheduler.seqs[&id];
            let latency = self.clock - seq.arrival;
            let ttft = seq.first_token_time.unwrap_or(self.clock) - seq.arrival;
            self.metrics.latencies.push(latency);
            self.metrics.queue_times.push(seq.admitted_time.unwrap_or(seq.arrival) - seq.arrival);
            if seq.generated.len() > 1 {
                self.metrics.tpots.push((latency - ttft) / (seq.generated.len() - 1) as f64);
            }
            self.outputs.push(RequestOutput {
                id,
                prompt_len: seq.prompt.len(),
                tokens: seq.generated.clone(),
                finish: reason,
                ttft,
                latency,
                preemptions: seq.preemptions,
            });
            self.metrics.goodput_tokens += self.scheduler.seqs[&id].generated.len();
            self.resolve(id, RequestOutcome::Completed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::SimBackend;
    use crate::engine::request::{FinishReason, SamplingParams};
    use crate::models::by_name;
    use crate::OptConfig;

    fn engine(max_batch: usize) -> Engine<SimBackend> {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, max_batch);
        Engine::new(
            EngineConfig { max_batch, total_blocks: 2048, ..Default::default() },
            be,
        )
    }

    fn req(id: usize, plen: usize, gen: usize) -> Request {
        Request::new(
            id,
            vec![3; plen],
            SamplingParams { max_tokens: gen, ..Default::default() },
        )
    }

    #[test]
    fn single_request_completes_exactly() {
        let mut e = engine(4);
        e.add_request(req(0, 10, 7));
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 1);
        let out = &report.outputs[0];
        assert_eq!(out.tokens.len(), 7);
        assert_eq!(out.finish, FinishReason::MaxTokens);
        assert!(out.ttft > 0.0 && out.latency >= out.ttft);
        assert_eq!(report.metrics.output_tokens, 7);
    }

    #[test]
    fn batch_of_requests_all_complete() {
        let mut e = engine(8);
        let mut expected = 0;
        for i in 0..16 {
            let gen = 4 + i % 5;
            expected += gen;
            e.add_request(req(i, 8 + i, gen));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 16);
        assert_eq!(report.metrics.output_tokens, expected);
        assert!(report.metrics.throughput() > 0.0);
        e.scheduler.check_invariants().unwrap();
    }

    #[test]
    fn continuous_batching_interleaves() {
        // More requests than batch: some must wait, all finish, and the
        // mean decode batch must exceed 1 (they really ran together).
        let mut e = engine(4);
        for i in 0..8 {
            e.add_request(req(i, 16, 32));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 8);
        assert!(report.metrics.mean_decode_batch() > 1.5,
                "mean decode batch {}", report.metrics.mean_decode_batch());
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut e = engine(4);
            for i in 0..6 {
                e.add_request(Request::new(
                    i,
                    vec![1; 10],
                    SamplingParams { max_tokens: 10, temperature: 0.9, top_k: 20, seed: 4, ..Default::default() },
                ));
            }
            let r = e.run().unwrap();
            (r.metrics.elapsed, r.outputs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>())
        };
        let (t1, toks1) = run();
        let (t2, toks2) = run();
        assert_eq!(t1, t2);
        assert_eq!(toks1, toks2);
    }

    #[test]
    fn preemption_path_still_completes_everything() {
        // Tiny KV pool forces preemptions.
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                block_size: 4,
                total_blocks: 40,
                max_seq_len: 128,
                prefill_budget: 64,
                // env-inherited: runs on both skip and recompute paths
                ..Default::default()
            },
            be,
        );
        for i in 0..6 {
            // distinct prompts: no prefix sharing, maximal KV pressure
            let mut r = req(i, 12, 30);
            r.prompt = vec![i as u32 + 1; 12];
            e.add_request(r);
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 6);
        for o in &report.outputs {
            assert_eq!(o.tokens.len(), 30, "req {} generated {}", o.id, o.tokens.len());
        }
        assert!(report.metrics.preemptions > 0, "this config must preempt");
        e.scheduler.check_invariants().unwrap();
    }

    #[test]
    fn chunked_prefill_conserves_tokens_across_budgets() {
        // Any token budget — including budgets below the block size —
        // must leave accounting exact and finish every request.
        for budget in [1, 3, 16, 50, 1000] {
            let m = by_name("Llama-2-7B-GPTQ").unwrap();
            let be = SimBackend::new(m, OptConfig::BASELINE, 4);
            let mut e = Engine::new(
                EngineConfig {
                    max_batch: 4,
                    total_blocks: 2048,
                    prefill_budget: budget,
                    ..Default::default()
                },
                be,
            );
            for i in 0..6 {
                e.add_request(req(i, 40 + i, 5));
            }
            let report = e.run().unwrap();
            assert_eq!(report.outputs.len(), 6, "budget {budget}");
            assert_eq!(report.metrics.output_tokens, 30, "budget {budget}");
            if budget < 40 {
                assert!(
                    report.metrics.prefill_chunks > 6,
                    "budget {budget} must chunk long prompts: {} chunks",
                    report.metrics.prefill_chunks
                );
            }
            e.scheduler.check_invariants().unwrap();
        }
    }

    #[test]
    fn shared_prompts_skip_prefill_tokens() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                prefill_budget: 32,
                prefix_skip: true,
                ..Default::default()
            },
            be,
        );
        // Identical 32-token prompts.  Budget 32 staggers the two
        // admissions across steps, so the second arrives after the
        // first's prefix blocks are computed and skips them.
        for i in 0..2 {
            e.add_request(req(i, 32, 4));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 2);
        assert!(
            report.metrics.prefill_tokens_skipped > 0,
            "second identical prompt must skip its cached prefix"
        );
        e.scheduler.check_invariants().unwrap();
    }

    #[test]
    fn arrival_clock_gates_admission() {
        let mut e = engine(4);
        e.add_request(req(0, 8, 3));
        let mut late = req(1, 8, 3);
        late.arrival = 10.0;
        e.add_request(late);
        // Request 0 finishes in well under 10 virtual seconds; the
        // engine must then jump the clock to request 1's arrival
        // instead of going idle.
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 2);
        assert!(report.metrics.elapsed >= 10.0, "clock must reach the late arrival");
        let out1 = report.outputs.iter().find(|o| o.id == 1).unwrap();
        assert!(
            out1.ttft < 5.0,
            "ttft {} must be measured from arrival, not from t=0",
            out1.ttft
        );
        assert_eq!(e.scheduler.seqs[&1].admitted_time, Some(10.0));
    }

    #[test]
    fn swap_and_recompute_preemption_generate_identical_tokens() {
        // Same block-pressured workload through both preemption paths;
        // sampled tokens must agree bit-for-bit with each other (and
        // they both must actually preempt for the run to prove much).
        let run = |swap: bool| {
            let m = by_name("Llama-2-7B-GPTQ").unwrap();
            let be = SimBackend::new(m, OptConfig::BASELINE, 4);
            let mut e = Engine::new(
                EngineConfig {
                    max_batch: 4,
                    block_size: 4,
                    total_blocks: 40,
                    max_seq_len: 128,
                    prefill_budget: 64,
                    prefix_skip: true,
                    swap_preempt: swap,
                    kv_dtype: crate::engine::KvDtype::F32,
                    max_waiting: usize::MAX,
                    // Pinned: the swap-vs-recompute parity claim is about
                    // preemption alone, not preemption-under-faults (the
                    // fault×preemption cross is covered by serve_chaos).
                    faults: crate::engine::FaultPlan::NONE,
                },
                be,
            );
            for i in 0..6 {
                let mut r = req(i, 12, 30);
                r.prompt = vec![i as u32 + 1; 12];
                r.sampling.temperature = 0.8;
                r.sampling.top_k = 32;
                r.sampling.seed = 7;
                e.add_request(r);
            }
            let report = e.run().unwrap();
            assert!(report.metrics.preemptions > 0, "this config must preempt");
            e.scheduler.check_invariants().unwrap();
            let mut toks: Vec<(usize, Vec<u32>)> =
                report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
            toks.sort();
            (toks, report.metrics.swap_outs)
        };
        let (swap_toks, swap_outs) = run(true);
        let (recompute_toks, no_swap_outs) = run(false);
        assert!(swap_outs > 0, "swap mode must actually swap");
        assert_eq!(no_swap_outs, 0);
        assert_eq!(swap_toks, recompute_toks);
    }

    #[test]
    fn deadline_cancellation_reclaims_and_reports() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                // Pinned: the goodput-vs-throughput assertion needs the
                // doomed request to sample at least one token before its
                // deadline, which an env-injected first-step fault would
                // prevent.
                faults: crate::engine::FaultPlan::NONE,
                ..Default::default()
            },
            be,
        );
        e.add_request(req(0, 8, 5));
        let mut doomed = req(1, 8, 10_000);
        doomed.deadline = Some(0.001); // expires after the first step
        e.add_request(doomed);
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 1, "only the undoomed request completes");
        assert_eq!(report.outputs[0].id, 0);
        assert_eq!(report.outcomes.len(), 2);
        assert_eq!(report.outcomes[0], (0, RequestOutcome::Completed));
        assert_eq!(report.outcomes[1], (1, RequestOutcome::TimedOut));
        assert_eq!(report.metrics.timed_out_requests, 1);
        assert!(report.metrics.goodput_tokens < report.metrics.output_tokens,
                "tokens generated for the doomed request must not count as goodput");
        e.audit().unwrap();
    }

    #[test]
    fn transient_faults_retry_to_bit_identical_completion() {
        let run = |faults: crate::engine::FaultPlan| {
            let m = by_name("Llama-2-7B-GPTQ").unwrap();
            let be = SimBackend::new(m, OptConfig::BASELINE, 4);
            let mut e = Engine::new(
                EngineConfig {
                    max_batch: 4,
                    block_size: 4,
                    total_blocks: 64,
                    max_seq_len: 128,
                    prefill_budget: 64,
                    faults,
                    ..Default::default()
                },
                be,
            );
            for i in 0..6 {
                let mut r = req(i, 12, 20);
                r.prompt = vec![i as u32 + 1; 12];
                r.sampling.temperature = 0.8;
                r.sampling.top_k = 32;
                r.sampling.seed = 11;
                e.add_request(r);
            }
            let report = e.run().unwrap();
            e.audit().unwrap();
            let mut toks: Vec<(usize, Vec<u32>)> =
                report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
            toks.sort();
            (toks, report)
        };
        let plan = crate::engine::FaultPlan {
            seed: 99,
            step_transient: 0.25,
            spill_out: 0.25,
            spill_in: 0.25,
            alloc: 0.1,
            ..crate::engine::FaultPlan::NONE
        };
        let (faulty_toks, faulty) = run(plan);
        let (clean_toks, clean) = run(crate::engine::FaultPlan::NONE);
        assert_eq!(faulty.outputs.len(), 6, "recoverable faults must not lose requests");
        assert!(faulty.outcomes.iter().all(|(_, o)| *o == RequestOutcome::Completed));
        assert_eq!(faulty_toks, clean_toks, "retried tokens must replay bit-identically");
        assert!(faulty.metrics.step_retries > 0, "plan must actually fire");
        assert_eq!(clean.metrics.step_retries, 0);
        assert_eq!(faulty.metrics.goodput_tokens, faulty.metrics.output_tokens);
    }

    #[test]
    fn permanent_fault_fails_the_batch_and_serving_continues() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                faults: crate::engine::FaultPlan {
                    seed: 3,
                    step_permanent: 1.0,
                    ..crate::engine::FaultPlan::NONE
                },
                ..Default::default()
            },
            be,
        );
        for i in 0..5 {
            e.add_request(req(i, 8, 6));
        }
        let report = e.run().unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.outcomes.len(), 5, "every request still gets a typed outcome");
        for (_, o) in &report.outcomes {
            assert!(matches!(o, RequestOutcome::Failed { .. }), "got {o:?}");
        }
        assert_eq!(report.metrics.failed_requests, 5);
        e.audit().unwrap();
    }

    #[test]
    fn transient_streak_exhausts_retries_into_failure() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                faults: crate::engine::FaultPlan {
                    seed: 3,
                    step_transient: 1.0,
                    ..crate::engine::FaultPlan::NONE
                },
                ..Default::default()
            },
            be,
        );
        e.add_request(req(0, 8, 6));
        let report = e.run().unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.outcomes.len(), 1);
        let (id, outcome) = &report.outcomes[0];
        assert_eq!(*id, 0);
        let RequestOutcome::Failed { reason } = outcome else {
            panic!("expected Failed, got {outcome:?}")
        };
        assert!(reason.contains("retries exhausted"), "reason: {reason}");
        assert!(report.metrics.step_retries >= (MAX_STEP_RETRIES - 1) as usize);
        e.audit().unwrap();
    }

    #[test]
    fn shed_requests_surface_as_rejected_outcomes() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig { max_batch: 4, total_blocks: 2048, max_waiting: 1, ..Default::default() },
            be,
        );
        for i in 0..3 {
            e.add_request(req(i, 8, 5));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 1);
        assert_eq!(report.outputs[0].id, 0);
        assert_eq!(report.metrics.shed_requests, 2);
        assert_eq!(report.metrics.rejected_requests, 2);
        for id in [1usize, 2] {
            let (_, o) = report.outcomes.iter().find(|(i, _)| *i == id).unwrap();
            let RequestOutcome::Rejected { reason } = o else {
                panic!("expected Rejected for {id}, got {o:?}")
            };
            assert!(reason.contains("shed"), "reason: {reason}");
        }
        e.audit().unwrap();
    }

    #[test]
    fn optimized_config_yields_higher_throughput() {
        let m = by_name("LLaMa-13B-GPTQ").unwrap();
        let mut results = Vec::new();
        for opt in [OptConfig::BASELINE, OptConfig::OPT4GPTQ] {
            let be = SimBackend::new(m, opt, 32);
            // Pinned fault-free: the strict opt>base throughput comparison
            // is about the cost model; injected retry backoffs would add
            // schedule-dependent noise to both sides.
            let mut e = Engine::new(
                EngineConfig {
                    faults: crate::engine::FaultPlan::NONE,
                    ..Default::default()
                },
                be,
            );
            for i in 0..32 {
                e.add_request(req(i, 32, 16));
            }
            results.push(e.run().unwrap().metrics.throughput());
        }
        assert!(results[1] > results[0], "opt {} <= base {}", results[1], results[0]);
    }
}

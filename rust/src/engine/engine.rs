//! The engine step loop: schedule → execute one mixed batch → sample →
//! account.
//!
//! Every step is a single [`Backend::step`] call carrying the prefill
//! chunks the scheduler fit under the token budget *plus* the whole
//! decode batch.  Prefill progress is tracked per sequence
//! ([`super::sequence::Sequence::prefill_pos`]); a sequence joins the
//! decode batch only after its final chunk executes and its first token
//! is sampled from that chunk's logits.
//!
//! Requests carry a virtual arrival time: until the engine clock
//! reaches it, a request sits in a pending set the scheduler never
//! sees.  When everything admitted has drained and arrivals remain, the
//! clock jumps forward to the next one.  Swap-preemption plumbing lives
//! here too, with a strict drain order per step: freshly swapped-in
//! tables reach the backend (spill restored) *before* the step
//! executes, and swap-out spill copies happen *before* freed blocks are
//! released (poisoned/recycled) after it.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::bail;

use crate::rng::Rng;
use crate::Result;

use super::backend::{Backend, DecodeDesc, PrefillDesc, StepError};
use super::block_manager::BlockManager;
use super::fault::FaultSeam;
use super::metrics::Metrics;
use super::persist::{self, ConfigFingerprint, EngineSnapshot, PendingSnap, SchedSnap, SeqSnap};
use super::request::{Request, RequestOutcome, RequestOutput};
use super::sampler;
use super::scheduler::{PrefillChunk, ScheduledWork, Scheduler};
use super::sequence::SeqState;
use super::EngineConfig;

/// Consecutive transient step failures tolerated before the batch is
/// failed as if the error were permanent.
const MAX_STEP_RETRIES: u32 = 8;
/// First retry backoff, virtual seconds; doubles per consecutive
/// failure up to [`RETRY_BACKOFF_CAP`].
const RETRY_BACKOFF_BASE: f64 = 0.05;
const RETRY_BACKOFF_CAP: f64 = 1.0;
/// Clock advance per admission pass stalled by an injected allocation
/// refusal (the scheduler returned Idle with work still queued).
const FAULT_STALL_BACKOFF: f64 = 0.01;
/// Consecutive stalled admission passes tolerated before the run is
/// declared wedged (only reachable with an `alloc` fault rate of 1).
const MAX_FAULT_STALLS: usize = 10_000;

/// Result of a full engine run.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Per-request outputs of **completed** requests only.
    pub outputs: Vec<RequestOutput>,
    /// Every request's terminal [`RequestOutcome`], sorted by id —
    /// exactly one entry per request the engine ever saw, whether it
    /// completed, was rejected/shed, timed out past its deadline, or
    /// failed on a permanent backend error.
    pub outcomes: Vec<(usize, RequestOutcome)>,
    pub metrics: Metrics,
}

/// The serving engine: owns the scheduler and a backend.
pub struct Engine<B: Backend> {
    pub cfg: EngineConfig,
    pub scheduler: Scheduler,
    pub backend: B,
    /// Virtual (sim) or accumulated-wall (PJRT) clock, seconds.
    pub clock: f64,
    pub metrics: Metrics,
    rngs: HashMap<usize, Rng>,
    outputs: Vec<RequestOutput>,
    /// Requests whose arrival time the clock has not reached yet —
    /// invisible to the scheduler until then.
    pending: Vec<Request>,
    /// Terminal outcome per request id, in resolution order.
    outcomes: Vec<(usize, RequestOutcome)>,
    /// Transient step failures since the last successful step; resets
    /// on success, escalates to batch failure at [`MAX_STEP_RETRIES`].
    consecutive_step_failures: u32,
    /// Consecutive admission passes stalled by injected alloc faults.
    fault_stalls: usize,
    /// Checkpoint directory (None = checkpointing off).
    persist_dir: Option<PathBuf>,
    /// Steps between snapshot commits when checkpointing is on.
    checkpoint_every: usize,
    steps_since_checkpoint: usize,
    /// Sequence number the next snapshot file will use.
    snap_seq: u64,
    /// Request ids queued by [`Engine::cancel`]; drained at the next
    /// step boundary.
    cancel_queue: Vec<usize>,
}

impl<B: Backend> Engine<B> {
    pub fn new(mut cfg: EngineConfig, mut backend: B) -> Engine<B> {
        cfg.max_batch = cfg.max_batch.min(backend.max_batch());
        cfg.max_seq_len = cfg.max_seq_len.min(backend.max_seq_len());
        // Announce the paged-KV geometry: backends owning physical K/V
        // size their block pool to the manager's, so every BlockId a
        // table can carry is addressable.
        backend.bind_kv(cfg.total_blocks, cfg.block_size, cfg.kv_dtype);
        Engine {
            scheduler: Scheduler::new(cfg),
            backend,
            clock: 0.0,
            metrics: Metrics::default(),
            rngs: HashMap::new(),
            outputs: Vec::new(),
            pending: Vec::new(),
            outcomes: Vec::new(),
            consecutive_step_failures: 0,
            fault_stalls: 0,
            persist_dir: None,
            checkpoint_every: 0,
            steps_since_checkpoint: 0,
            snap_seq: 0,
            cancel_queue: Vec::new(),
            cfg,
        }
    }

    /// Turn on crash-consistent checkpointing: every `every` successful
    /// steps the full engine state is committed to `dir` (atomic
    /// rename; the latest few snapshots are retained).  Numbering
    /// continues from whatever snapshots `dir` already holds, so a
    /// restored engine keeps appending to the same history.  A no-op
    /// when `OPT4GPTQ_PERSIST` turned persistence off.
    pub fn enable_checkpoints(&mut self, dir: impl Into<PathBuf>, every: usize) {
        if !super::persist_default() {
            return;
        }
        let dir = dir.into();
        self.snap_seq = persist::next_seq(&dir);
        self.persist_dir = Some(dir);
        self.checkpoint_every = every.max(1);
        self.steps_since_checkpoint = 0;
    }

    pub fn add_request(&mut self, req: Request) {
        self.rngs.insert(req.id, Rng::new(req.sampling.seed ^ req.id as u64));
        self.metrics.prompt_tokens += req.prompt.len();
        if req.arrival <= self.clock {
            self.scheduler.add_request(&req);
        } else {
            self.pending.push(req);
        }
    }

    /// Move pending requests whose arrival the clock has reached into
    /// the scheduler's queue.
    fn admit_arrivals(&mut self) {
        let clock = self.clock;
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].arrival <= clock {
                let req = self.pending.swap_remove(i);
                self.scheduler.add_request(&req);
            } else {
                i += 1;
            }
        }
    }

    /// Run one engine step.  Returns false when there is no work left.
    pub fn step(&mut self) -> Result<bool> {
        loop {
            self.admit_arrivals();
            self.expire_deadlines();
            self.drain_cancellations();
            // Deadline retirements free blocks: forward them to the
            // backend *before* schedule() can hand the same ids out
            // again, or the release-time poison would clobber live K/V.
            self.drain_releases();
            let work = self.scheduler.schedule(self.clock);
            // Resolve anything add_request shed or schedule() rejected
            // (oversized / provably never admittable) this pass.
            self.drain_rejections();
            match work {
                ScheduledWork::Idle => {
                    self.drain_releases();
                    if self.scheduler.has_work() {
                        // An injected allocation refusal stalled
                        // admission (a full pool would have produced a
                        // Step or a rejection instead): back the clock
                        // off and retry, with a wedge cap so an
                        // always-firing fault cannot spin forever.
                        self.fault_stalls += 1;
                        if self.fault_stalls > MAX_FAULT_STALLS {
                            bail!("admission wedged: {MAX_FAULT_STALLS} consecutive injected allocation stalls");
                        }
                        self.clock += FAULT_STALL_BACKOFF;
                        continue;
                    }
                    // Nothing runnable now; if future arrivals remain,
                    // jump the clock to the next one and retry.
                    let next =
                        self.pending.iter().map(|r| r.arrival).fold(f64::INFINITY, f64::min);
                    if next.is_finite() {
                        self.clock = self.clock.max(next);
                        continue;
                    }
                    return Ok(false);
                }
                ScheduledWork::Step { mut prefills, decodes } => {
                    self.fault_stalls = 0;
                    let failed_restores = self.restore_swapped();
                    if !failed_restores.is_empty() {
                        // A failed restore demoted its sequence to
                        // recompute; its chunk must not execute through
                        // the just-freed table.
                        prefills.retain(|c| !failed_restores.contains(&c.seq_id));
                    }
                    if prefills.is_empty() && decodes.is_empty() {
                        // The whole batch was failed restores.
                        self.drain_releases();
                        continue;
                    }
                    self.run_step(prefills, decodes)?;
                    self.metrics.engine_steps += 1;
                    self.drain_releases();
                    // Quiescent point: all releases forwarded, no logs
                    // pending — exactly the state a snapshot can
                    // capture and a restore can resume from.
                    self.maybe_checkpoint()?;
                    return Ok(true);
                }
            }
        }
    }

    /// Cancel every request whose deadline the clock has passed —
    /// queued, mid-prefill, decoding, preempted, swapped, or not yet
    /// admitted — with full block/spill reclamation.
    fn expire_deadlines(&mut self) {
        let clock = self.clock;
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].deadline.map_or(false, |d| d < clock) {
                let req = self.pending.swap_remove(i);
                self.resolve(req.id, RequestOutcome::TimedOut);
            } else {
                i += 1;
            }
        }
        let mut expired: Vec<usize> = self
            .scheduler
            .seqs
            .iter()
            .filter(|(_, s)| s.state != SeqState::Finished)
            .filter(|(_, s)| s.deadline.map_or(false, |d| d < clock))
            .map(|(&id, _)| id)
            .collect();
        // The seq map is a HashMap: sort so retirement (and thus block
        // free order) is replay-deterministic.
        expired.sort_unstable();
        for id in expired {
            self.scheduler.retire(id);
            self.resolve(id, RequestOutcome::TimedOut);
        }
    }

    /// Cooperatively cancel a request (front-end abort).  Queued here
    /// and drained at the next step boundary — never mid-batch — so
    /// the cancelled sequence's blocks and spill entries go through
    /// the regular reclamation machinery.  Unknown or already-finished
    /// ids are ignored.
    pub fn cancel(&mut self, id: usize) {
        self.cancel_queue.push(id);
    }

    /// Resolve queued [`Engine::cancel`] calls: wherever the request is
    /// — pending, waiting, swapped, or mid-generation — it retires with
    /// full block/spill reclamation and a typed
    /// [`RequestOutcome::Cancelled`].
    fn drain_cancellations(&mut self) {
        if self.cancel_queue.is_empty() {
            return;
        }
        let mut ids = std::mem::take(&mut self.cancel_queue);
        ids.sort_unstable();
        ids.dedup();
        for id in ids {
            if let Some(pos) = self.pending.iter().position(|r| r.id == id) {
                self.pending.swap_remove(pos);
                self.resolve(id, RequestOutcome::Cancelled);
            } else if self.scheduler.seqs.get(&id).is_some_and(|s| s.state != SeqState::Finished) {
                self.scheduler.retire(id);
                self.resolve(id, RequestOutcome::Cancelled);
            }
        }
    }

    /// Record a request's terminal outcome and bump its metric.
    fn resolve(&mut self, id: usize, outcome: RequestOutcome) {
        match &outcome {
            RequestOutcome::Completed => {}
            RequestOutcome::Rejected { .. } => self.metrics.rejected_requests += 1,
            RequestOutcome::TimedOut => self.metrics.timed_out_requests += 1,
            RequestOutcome::Cancelled => self.metrics.cancelled_requests += 1,
            RequestOutcome::Failed { .. } => self.metrics.failed_requests += 1,
        }
        self.outcomes.push((id, outcome));
    }

    /// Turn scheduler-side rejections (shed / oversized / never-fit)
    /// into typed outcomes.
    fn drain_rejections(&mut self) {
        for (id, reason) in self.scheduler.take_rejected() {
            self.resolve(id, RequestOutcome::Rejected { reason });
        }
    }

    /// Drive to completion; returns outputs + metrics.
    pub fn run(&mut self) -> Result<EngineReport> {
        while self.step()? {}
        self.metrics.elapsed = self.clock;
        self.metrics.preemptions = self.scheduler.preemption_count;
        self.metrics.prefill_tokens_skipped = self.scheduler.prefill_tokens_skipped;
        self.metrics.swap_outs = self.scheduler.swap_out_count;
        self.metrics.swap_ins = self.scheduler.swap_in_count;
        self.metrics.swap_restored_tokens = self.scheduler.swap_restored_tokens;
        if let Some(kv) = self.backend.kv_stats() {
            self.metrics.kv_pool_bytes = kv.pool_bytes;
            self.metrics.kv_bytes_per_token = kv.bytes_per_token;
            self.metrics.kv_spill_peak_bytes = kv.spill_peak_bytes;
        }
        self.metrics.shed_requests = self.scheduler.shed_count;
        if let Err(e) = self.audit() {
            bail!("post-drain invariant audit failed: {e}");
        }
        let mut outcomes = std::mem::take(&mut self.outcomes);
        outcomes.sort_by_key(|&(id, _)| id);
        Ok(EngineReport {
            outputs: std::mem::take(&mut self.outputs),
            outcomes,
            metrics: self.metrics.clone(),
        })
    }

    /// Post-drain invariant auditor: after a run (or any quiescent
    /// point with no live sequences) the scheduler queues must be
    /// consistent, every KV block must be back on the free list with
    /// no leaked tables or spill reservations, the backend must hold
    /// zero spill bytes, and — on backends owning a physical pool, in
    /// debug builds — every free block's K/V rows must be poison or
    /// virgin (nothing live leaked into freed memory).
    pub fn audit(&self) -> std::result::Result<(), String> {
        self.scheduler.check_invariants()?;
        self.scheduler.blocks.assert_drained()?;
        if let Some(kv) = self.backend.kv_stats() {
            if kv.spill_bytes != 0 {
                return Err(format!(
                    "backend still holds {} spill bytes after drain",
                    kv.spill_bytes
                ));
            }
        }
        if let Some(pool) = self.backend.paged_kv() {
            pool.audit(self.scheduler.blocks.free_list())?;
        }
        Ok(())
    }

    /// Commit a snapshot if checkpointing is on and the interval is
    /// due.  The two crash seams bracket the commit:
    /// [`FaultSeam::CrashBeforeCommit`] kills the process (an `Err`
    /// the caller treats as death) with the previous snapshot still
    /// the newest on disk; [`FaultSeam::CrashAfterCommit`] kills it
    /// just after the rename, so restart resumes from the state this
    /// very step produced.  Either way [`Engine::restore`] must drive
    /// the run to the same completed tokens.
    fn maybe_checkpoint(&mut self) -> Result<()> {
        let Some(dir) = self.persist_dir.clone() else { return Ok(()) };
        self.steps_since_checkpoint += 1;
        if self.steps_since_checkpoint < self.checkpoint_every {
            return Ok(());
        }
        self.steps_since_checkpoint = 0;
        if self.scheduler.faults.fire(FaultSeam::CrashBeforeCommit) {
            bail!("injected crash before checkpoint commit (seam crash_before)");
        }
        let snap = match self.snapshot() {
            Ok(s) => s,
            Err(e) => bail!("checkpoint serialization failed: {e}"),
        };
        persist::write_snapshot(&dir, self.snap_seq, &snap)?;
        self.snap_seq += 1;
        self.metrics.checkpoints_written += 1;
        if self.scheduler.faults.fire(FaultSeam::CrashAfterCommit) {
            bail!("injected crash after checkpoint commit (seam crash_after)");
        }
        Ok(())
    }

    /// Capture the full engine state at the current (quiescent) step
    /// boundary.  Fails if any release/swap log is undrained — the
    /// engine only calls this right after [`Engine::drain_releases`],
    /// but an external caller could not.
    pub fn snapshot(&self) -> std::result::Result<EngineSnapshot, String> {
        let blocks = self.scheduler.blocks.export_state()?;
        let (waiting, running, prefilling) = self.scheduler.export_queues()?;
        let mut sequences: Vec<SeqSnap> = Vec::with_capacity(self.scheduler.seqs.len());
        let mut ids: Vec<usize> = self.scheduler.seqs.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let rng = self
                .rngs
                .get(&id)
                .ok_or_else(|| format!("sequence {id} has no RNG stream"))?;
            sequences.push(SeqSnap { seq: self.scheduler.seqs[&id].clone(), rng: rng.state() });
        }
        let mut pending: Vec<PendingSnap> = Vec::with_capacity(self.pending.len());
        let mut preqs: Vec<&Request> = self.pending.iter().collect();
        preqs.sort_unstable_by_key(|r| r.id);
        for req in preqs {
            let rng = self
                .rngs
                .get(&req.id)
                .ok_or_else(|| format!("pending request {} has no RNG stream", req.id))?;
            pending.push(PendingSnap { req: req.clone(), rng: rng.state() });
        }
        let (fault_draws, fault_fired) = self.scheduler.faults.draw_state();
        let s = &self.scheduler;
        let sched = SchedSnap {
            preemption_count: s.preemption_count,
            prefill_tokens_skipped: s.prefill_tokens_skipped,
            swap_out_count: s.swap_out_count,
            swap_out_mid_prefill: s.swap_out_mid_prefill,
            swap_out_mid_decode: s.swap_out_mid_decode,
            swap_in_count: s.swap_in_count,
            swap_restored_tokens: s.swap_restored_tokens,
            shed_count: s.shed_count,
            fault_draws,
            fault_fired,
        };
        // Pack every live block's K/V rows in one export, ascending id
        // — restore replays the same order, so payload and block list
        // stay aligned.
        let kv_blocks: Vec<usize> = blocks
            .blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.0 > 0)
            .map(|(i, _)| i)
            .collect();
        let kv_payload =
            if kv_blocks.is_empty() { None } else { self.backend.export_kv(&kv_blocks) };
        let spills = blocks
            .swapped
            .iter()
            .map(|&(id, n)| (id, n, self.backend.export_spill(id)))
            .collect();
        Ok(EngineSnapshot {
            config: ConfigFingerprint::of(&self.cfg),
            clock: self.clock,
            consecutive_step_failures: self.consecutive_step_failures,
            fault_stalls: self.fault_stalls,
            sequences,
            pending,
            waiting,
            running,
            prefilling,
            sched,
            blocks,
            outcomes: self.outcomes.clone(),
            outputs: self.outputs.clone(),
            metrics: self.metrics.clone(),
            kv_blocks,
            kv_payload,
            spills,
        })
    }

    /// Build an engine resumed from the newest valid snapshot in `dir`
    /// (torn or corrupt trailing commits are skipped).  The engine
    /// continues mid-prompt and mid-decode exactly where the snapshot
    /// was taken: with the same (or a crash-free) fault plan it
    /// produces tokens bit-identical to the uninterrupted run.  The
    /// same path rehydrates computed shared-prefix blocks for a fresh
    /// serving process — new requests over the same system prompt skip
    /// the cached span without re-prefilling.
    pub fn restore(cfg: EngineConfig, backend: B, dir: &Path) -> Result<Engine<B>> {
        let (seq_no, snap) = match persist::load_latest(dir) {
            Ok(Some(x)) => x,
            Ok(None) => bail!("no snapshot found in {}", dir.display()),
            Err(e) => bail!("{e}"),
        };
        let mut engine = Engine::new(cfg, backend);
        if let Err(e) = engine.apply_snapshot(snap) {
            bail!("restore from snapshot {seq_no} failed: {e}");
        }
        Ok(engine)
    }

    /// Rehydrate this (freshly constructed) engine from a snapshot.
    fn apply_snapshot(&mut self, snap: EngineSnapshot) -> std::result::Result<(), String> {
        let fp = ConfigFingerprint::of(&self.cfg);
        snap.config.check(&fp).map_err(|e| e.to_string())?;
        self.clock = snap.clock;
        self.consecutive_step_failures = snap.consecutive_step_failures;
        self.fault_stalls = snap.fault_stalls;
        self.scheduler.blocks = BlockManager::import_state(snap.blocks)?;
        self.rngs.clear();
        let mut live = 0usize;
        for s in snap.sequences {
            if s.seq.state != SeqState::Finished {
                live += 1;
            }
            self.rngs.insert(s.seq.id, Rng::from_state(s.rng.0, s.rng.1));
            self.scheduler.seqs.insert(s.seq.id, s.seq);
        }
        self.pending.clear();
        for p in snap.pending {
            live += 1;
            self.rngs.insert(p.req.id, Rng::from_state(p.rng.0, p.rng.1));
            self.pending.push(p.req);
        }
        self.scheduler.import_queues(snap.waiting, snap.running, snap.prefilling)?;
        let sc = snap.sched;
        self.scheduler.preemption_count = sc.preemption_count;
        self.scheduler.prefill_tokens_skipped = sc.prefill_tokens_skipped;
        self.scheduler.swap_out_count = sc.swap_out_count;
        self.scheduler.swap_out_mid_prefill = sc.swap_out_mid_prefill;
        self.scheduler.swap_out_mid_decode = sc.swap_out_mid_decode;
        self.scheduler.swap_in_count = sc.swap_in_count;
        self.scheduler.swap_restored_tokens = sc.swap_restored_tokens;
        self.scheduler.shed_count = sc.shed_count;
        self.scheduler.faults.set_draw_state(sc.fault_draws, sc.fault_fired);
        self.outcomes = snap.outcomes;
        self.outputs = snap.outputs;
        self.metrics = snap.metrics;
        self.metrics.restored_requests = live;
        if let Some(payload) = &snap.kv_payload {
            self.backend.import_kv(&snap.kv_blocks, payload);
        }
        for (id, n, payload) in snap.spills {
            self.backend.import_spill(id, n, payload);
        }
        Ok(())
    }

    /// Hand freshly swapped-in sequences' new block tables to the
    /// backend so it can restore their spilled K/V — strictly before
    /// the step executes through those tables.  A restore that fails
    /// (injected [`FaultSeam::SpillIn`] or a backend error) is
    /// unrecoverable for that spill: the entry is dropped and the
    /// sequence demoted to recompute-from-scratch — never re-swapped,
    /// since its blocks were never restored.  Returns the demoted ids
    /// so the caller can strip their chunks from the batch.
    fn restore_swapped(&mut self) -> Vec<usize> {
        let mut failed = Vec::new();
        for (seq_id, blocks) in self.scheduler.blocks.take_swap_ins() {
            let res = if self.scheduler.faults.fire(FaultSeam::SpillIn) {
                Err(StepError::Transient("injected spill restore fault".into()))
            } else {
                self.backend.swap_in(seq_id, &blocks)
            };
            if res.is_err() {
                self.backend.drop_spill(seq_id);
                self.scheduler.fail_restore(seq_id);
                self.metrics.spill_faults += 1;
                failed.push(seq_id);
            }
        }
        failed
    }

    /// Forward blocks/sequences the scheduler released during this step
    /// to the backend.  Runs after execution and before the next
    /// `schedule()` can re-allocate the freed blocks, so a paged backend
    /// may safely poison or recycle the memory.
    fn drain_releases(&mut self) {
        // Spill swap-out victims' K/V first: their freed blocks are in
        // the released list below, and the copy must happen before the
        // backend can poison or rewrite that memory.  A spill write
        // that fails (injected [`FaultSeam::SpillOut`] or a backend
        // error) moved no bytes — the victim's K/V is lost with its
        // blocks, so it is demoted to recompute on the spot.
        for (seq_id, blocks) in self.scheduler.blocks.take_swap_outs() {
            let res = if self.scheduler.faults.fire(FaultSeam::SpillOut) {
                Err(StepError::Transient("injected spill write fault".into()))
            } else {
                self.backend.swap_out(seq_id, &blocks)
            };
            match res {
                Ok(bytes) => self.metrics.swap_spilled_bytes += bytes,
                Err(_) => {
                    self.backend.drop_spill(seq_id);
                    self.scheduler.demote_swap(seq_id);
                    self.metrics.spill_faults += 1;
                }
            }
        }
        let (blocks, seqs) = self.scheduler.blocks.take_released();
        if !blocks.is_empty() {
            self.backend.release_blocks(&blocks);
        }
        for id in seqs {
            self.backend.release_seq(id);
        }
    }

    /// Execute one mixed batch: prefill chunks + decode rows in a single
    /// backend call, then sample, advance prefill cursors and account.
    fn run_step(&mut self, prefills: Vec<PrefillChunk>, decodes: Vec<usize>) -> Result<()> {
        // Fault draws happen first (they need `&mut` on the schedule's
        // draw state, which the descriptors below borrow): one
        // permanent and one transient draw per step, each stream
        // advancing exactly once so a plan replays identically.
        let inject_permanent = self.scheduler.faults.fire(FaultSeam::StepPermanent);
        let inject_transient = self.scheduler.faults.fire(FaultSeam::StepTransient);
        // Unlike the two step seams above (which fail the call from
        // outside), this one corrupts data *inside* the backend's
        // forward pass — the error must be detected by the backend's
        // own output check, not injected at the call site.
        if self.scheduler.faults.fire(FaultSeam::MidLayerPoison) {
            self.backend.inject_fault();
        }
        // Only each chunk's own span is materialized (owned buffers the
        // descriptors borrow from while the backend runs) — never the
        // whole effective prompt per step.
        let chunk_tokens: Vec<Vec<u32>> = prefills
            .iter()
            .map(|c| self.scheduler.seqs[&c.seq_id].effective_slice(c.start, c.len))
            .collect();
        let prefill_descs: Vec<PrefillDesc<'_>> = prefills
            .iter()
            .zip(&chunk_tokens)
            .map(|(c, tokens)| PrefillDesc {
                seq_id: c.seq_id,
                tokens: tokens.as_slice(),
                start: c.start,
                is_last: c.is_last,
                block_table: self
                    .scheduler
                    .blocks
                    .table(c.seq_id)
                    .expect("prefill without allocation"),
            })
            .collect();
        let decode_descs: Vec<DecodeDesc<'_>> = decodes
            .iter()
            .map(|id| {
                let s = &self.scheduler.seqs[id];
                DecodeDesc {
                    seq_id: *id,
                    // position() counts the fed token, whose K/V entry
                    // lands one past the materialized context.
                    context_len: s.position() - 1,
                    token: s.last_token(),
                    block_table: self
                        .scheduler
                        .blocks
                        .table(*id)
                        .expect("decode without allocation"),
                }
            })
            .collect();
        // Nothing engine-side has mutated yet — scheduler cursors, the
        // clock and all RNG streams are exactly as schedule() left
        // them.  That is what makes a failed step *discardable*: the
        // recovery below re-drives the ordinary preemption machinery
        // and the retried work replays bit-identically.
        let result = if inject_permanent {
            Err(StepError::Permanent("injected permanent backend fault".into()))
        } else if inject_transient {
            Err(StepError::Transient("injected transient backend fault".into()))
        } else {
            self.backend.step(&prefill_descs, &decode_descs)
        };
        let mut out = match result {
            Ok(out) => out,
            Err(err) => {
                drop(prefill_descs);
                drop(decode_descs);
                return self.recover_step_failure(&prefills, &decodes, err);
            }
        };
        self.consecutive_step_failures = 0;
        debug_assert_eq!(out.prefill_logits.len(), prefills.len());
        debug_assert_eq!(out.decode_logits.len(), decodes.len());
        drop(prefill_descs);
        drop(decode_descs);
        self.clock += out.secs;
        if !prefills.is_empty() {
            self.metrics.prefill_steps += 1;
            self.metrics.prefill_chunks += prefills.len();
        }
        if !decodes.is_empty() {
            self.metrics.decode_steps += 1;
            self.metrics.decode_batch_sum += decodes.len();
        }

        // Prefill bookkeeping: advance every chunk's cursor; final
        // chunks sample their first token and join the decode batch.
        for (i, chunk) in prefills.iter().enumerate() {
            // An earlier append in this same loop may have preempted
            // this chunk's sequence (KV exhaustion); its cursor must
            // not move — recompute restarts, swap resumes from where
            // the cursor froze.
            if self.scheduler.seqs[&chunk.seq_id].state != SeqState::Prefilling {
                continue;
            }
            self.scheduler.advance_prefill(chunk);
            if !chunk.is_last {
                continue;
            }
            let logits = std::mem::take(&mut out.prefill_logits[i])
                .expect("final chunk must produce logits");
            let id = chunk.seq_id;
            {
                let seq = self.scheduler.seqs.get_mut(&id).unwrap();
                let rng = self.rngs.get_mut(&id).unwrap();
                let t = sampler::sample(&logits, &seq.sampling, rng);
                seq.generated.push(t);
                if seq.first_token_time.is_none() {
                    seq.first_token_time = Some(self.clock);
                    self.metrics.ttfts.push(self.clock - seq.arrival);
                }
            }
            self.metrics.output_tokens += 1;
            if !self.scheduler.append_token(id) {
                // Self-preempted: will re-run later; nothing else to do.
                continue;
            }
            self.scheduler.promote_to_running(id);
            self.maybe_finish(id);
        }

        for (id, logits) in decodes.into_iter().zip(out.decode_logits) {
            // The sequence may have been preempted by an earlier seq in
            // this same loop (KV exhaustion); skip it then.
            if self.scheduler.seqs[&id].state != SeqState::Running {
                continue;
            }
            let seq = self.scheduler.seqs.get_mut(&id).unwrap();
            let rng = self.rngs.get_mut(&id).unwrap();
            let t = sampler::sample(&logits, &seq.sampling, rng);
            seq.generated.push(t);
            self.metrics.output_tokens += 1;
            if !self.scheduler.append_token(id) {
                continue;
            }
            self.maybe_finish(id);
        }
        Ok(())
    }

    /// A backend step failed before any of its output was consumed.
    ///
    /// Transient: discard, preempt every live batch member through the
    /// regular swap/recompute machinery, bump the bounded exponential
    /// backoff and retry on the next step — the resumed work replays
    /// through the same RNG streams, so eventually-completed tokens
    /// stay bit-identical to a fault-free run.  Permanent (or a
    /// transient streak hitting [`MAX_STEP_RETRIES`]): every batch
    /// member resolves as [`RequestOutcome::Failed`] with full
    /// reclamation, and the engine keeps serving everyone else.
    fn recover_step_failure(
        &mut self,
        prefills: &[PrefillChunk],
        decodes: &[usize],
        err: StepError,
    ) -> Result<()> {
        let mut batch: Vec<usize> =
            prefills.iter().map(|c| c.seq_id).chain(decodes.iter().copied()).collect();
        batch.sort_unstable();
        batch.dedup();
        if err.is_transient() {
            self.consecutive_step_failures += 1;
            if self.consecutive_step_failures < MAX_STEP_RETRIES {
                self.metrics.step_retries += 1;
                self.scheduler.preempt_for_retry(&batch);
                let exp = (self.consecutive_step_failures - 1).min(30);
                self.clock +=
                    (RETRY_BACKOFF_BASE * f64::powi(2.0, exp as i32)).min(RETRY_BACKOFF_CAP);
                return Ok(());
            }
        }
        let reason = if err.is_transient() {
            format!("retries exhausted after {MAX_STEP_RETRIES} transient errors: {}", err.reason())
        } else {
            err.reason().to_string()
        };
        self.consecutive_step_failures = 0;
        for id in batch {
            self.scheduler.retire(id);
            self.resolve(id, RequestOutcome::Failed { reason: reason.clone() });
        }
        Ok(())
    }

    fn maybe_finish(&mut self, id: usize) {
        let done = {
            let seq = &self.scheduler.seqs[&id];
            seq.is_done(self.cfg.max_seq_len)
        };
        if let Some(reason) = done {
            self.scheduler.finish(id);
            let seq = &self.scheduler.seqs[&id];
            let latency = self.clock - seq.arrival;
            let ttft = seq.first_token_time.unwrap_or(self.clock) - seq.arrival;
            self.metrics.latencies.push(latency);
            self.metrics.queue_times.push(seq.admitted_time.unwrap_or(seq.arrival) - seq.arrival);
            if seq.generated.len() > 1 {
                self.metrics.tpots.push((latency - ttft) / (seq.generated.len() - 1) as f64);
            }
            self.outputs.push(RequestOutput {
                id,
                prompt_len: seq.prompt.len(),
                tokens: seq.generated.clone(),
                finish: reason,
                ttft,
                latency,
                preemptions: seq.preemptions,
            });
            self.metrics.goodput_tokens += self.scheduler.seqs[&id].generated.len();
            self.resolve(id, RequestOutcome::Completed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::SimBackend;
    use crate::engine::request::{FinishReason, SamplingParams};
    use crate::models::by_name;
    use crate::OptConfig;

    fn engine(max_batch: usize) -> Engine<SimBackend> {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, max_batch);
        Engine::new(
            EngineConfig { max_batch, total_blocks: 2048, ..Default::default() },
            be,
        )
    }

    fn req(id: usize, plen: usize, gen: usize) -> Request {
        Request::new(
            id,
            vec![3; plen],
            SamplingParams { max_tokens: gen, ..Default::default() },
        )
    }

    #[test]
    fn single_request_completes_exactly() {
        let mut e = engine(4);
        e.add_request(req(0, 10, 7));
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 1);
        let out = &report.outputs[0];
        assert_eq!(out.tokens.len(), 7);
        assert_eq!(out.finish, FinishReason::MaxTokens);
        assert!(out.ttft > 0.0 && out.latency >= out.ttft);
        assert_eq!(report.metrics.output_tokens, 7);
    }

    #[test]
    fn batch_of_requests_all_complete() {
        let mut e = engine(8);
        let mut expected = 0;
        for i in 0..16 {
            let gen = 4 + i % 5;
            expected += gen;
            e.add_request(req(i, 8 + i, gen));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 16);
        assert_eq!(report.metrics.output_tokens, expected);
        assert!(report.metrics.throughput() > 0.0);
        e.scheduler.check_invariants().unwrap();
    }

    #[test]
    fn continuous_batching_interleaves() {
        // More requests than batch: some must wait, all finish, and the
        // mean decode batch must exceed 1 (they really ran together).
        let mut e = engine(4);
        for i in 0..8 {
            e.add_request(req(i, 16, 32));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 8);
        assert!(report.metrics.mean_decode_batch() > 1.5,
                "mean decode batch {}", report.metrics.mean_decode_batch());
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut e = engine(4);
            for i in 0..6 {
                e.add_request(Request::new(
                    i,
                    vec![1; 10],
                    SamplingParams { max_tokens: 10, temperature: 0.9, top_k: 20, seed: 4, ..Default::default() },
                ));
            }
            let r = e.run().unwrap();
            (r.metrics.elapsed, r.outputs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>())
        };
        let (t1, toks1) = run();
        let (t2, toks2) = run();
        assert_eq!(t1, t2);
        assert_eq!(toks1, toks2);
    }

    #[test]
    fn preemption_path_still_completes_everything() {
        // Tiny KV pool forces preemptions.
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                block_size: 4,
                total_blocks: 40,
                max_seq_len: 128,
                prefill_budget: 64,
                // env-inherited: runs on both skip and recompute paths
                ..Default::default()
            },
            be,
        );
        for i in 0..6 {
            // distinct prompts: no prefix sharing, maximal KV pressure
            let mut r = req(i, 12, 30);
            r.prompt = vec![i as u32 + 1; 12];
            e.add_request(r);
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 6);
        for o in &report.outputs {
            assert_eq!(o.tokens.len(), 30, "req {} generated {}", o.id, o.tokens.len());
        }
        assert!(report.metrics.preemptions > 0, "this config must preempt");
        e.scheduler.check_invariants().unwrap();
    }

    #[test]
    fn chunked_prefill_conserves_tokens_across_budgets() {
        // Any token budget — including budgets below the block size —
        // must leave accounting exact and finish every request.
        for budget in [1, 3, 16, 50, 1000] {
            let m = by_name("Llama-2-7B-GPTQ").unwrap();
            let be = SimBackend::new(m, OptConfig::BASELINE, 4);
            let mut e = Engine::new(
                EngineConfig {
                    max_batch: 4,
                    total_blocks: 2048,
                    prefill_budget: budget,
                    ..Default::default()
                },
                be,
            );
            for i in 0..6 {
                e.add_request(req(i, 40 + i, 5));
            }
            let report = e.run().unwrap();
            assert_eq!(report.outputs.len(), 6, "budget {budget}");
            assert_eq!(report.metrics.output_tokens, 30, "budget {budget}");
            if budget < 40 {
                assert!(
                    report.metrics.prefill_chunks > 6,
                    "budget {budget} must chunk long prompts: {} chunks",
                    report.metrics.prefill_chunks
                );
            }
            e.scheduler.check_invariants().unwrap();
        }
    }

    #[test]
    fn shared_prompts_skip_prefill_tokens() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                prefill_budget: 32,
                prefix_skip: true,
                ..Default::default()
            },
            be,
        );
        // Identical 32-token prompts.  Budget 32 staggers the two
        // admissions across steps, so the second arrives after the
        // first's prefix blocks are computed and skips them.
        for i in 0..2 {
            e.add_request(req(i, 32, 4));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 2);
        assert!(
            report.metrics.prefill_tokens_skipped > 0,
            "second identical prompt must skip its cached prefix"
        );
        e.scheduler.check_invariants().unwrap();
    }

    #[test]
    fn arrival_clock_gates_admission() {
        let mut e = engine(4);
        e.add_request(req(0, 8, 3));
        let mut late = req(1, 8, 3);
        late.arrival = 10.0;
        e.add_request(late);
        // Request 0 finishes in well under 10 virtual seconds; the
        // engine must then jump the clock to request 1's arrival
        // instead of going idle.
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 2);
        assert!(report.metrics.elapsed >= 10.0, "clock must reach the late arrival");
        let out1 = report.outputs.iter().find(|o| o.id == 1).unwrap();
        assert!(
            out1.ttft < 5.0,
            "ttft {} must be measured from arrival, not from t=0",
            out1.ttft
        );
        assert_eq!(e.scheduler.seqs[&1].admitted_time, Some(10.0));
    }

    #[test]
    fn swap_and_recompute_preemption_generate_identical_tokens() {
        // Same block-pressured workload through both preemption paths;
        // sampled tokens must agree bit-for-bit with each other (and
        // they both must actually preempt for the run to prove much).
        let run = |swap: bool| {
            let m = by_name("Llama-2-7B-GPTQ").unwrap();
            let be = SimBackend::new(m, OptConfig::BASELINE, 4);
            let mut e = Engine::new(
                EngineConfig {
                    model: Default::default(),
                    max_batch: 4,
                    block_size: 4,
                    total_blocks: 40,
                    max_seq_len: 128,
                    prefill_budget: 64,
                    prefix_skip: true,
                    swap_preempt: swap,
                    kv_dtype: crate::engine::KvDtype::F32,
                    max_waiting: usize::MAX,
                    // Pinned: the swap-vs-recompute parity claim is about
                    // preemption alone, not preemption-under-faults (the
                    // fault×preemption cross is covered by serve_chaos).
                    faults: crate::engine::FaultPlan::NONE,
                },
                be,
            );
            for i in 0..6 {
                let mut r = req(i, 12, 30);
                r.prompt = vec![i as u32 + 1; 12];
                r.sampling.temperature = 0.8;
                r.sampling.top_k = 32;
                r.sampling.seed = 7;
                e.add_request(r);
            }
            let report = e.run().unwrap();
            assert!(report.metrics.preemptions > 0, "this config must preempt");
            e.scheduler.check_invariants().unwrap();
            let mut toks: Vec<(usize, Vec<u32>)> =
                report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
            toks.sort();
            (toks, report.metrics.swap_outs)
        };
        let (swap_toks, swap_outs) = run(true);
        let (recompute_toks, no_swap_outs) = run(false);
        assert!(swap_outs > 0, "swap mode must actually swap");
        assert_eq!(no_swap_outs, 0);
        assert_eq!(swap_toks, recompute_toks);
    }

    #[test]
    fn deadline_cancellation_reclaims_and_reports() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                // Pinned: the goodput-vs-throughput assertion needs the
                // doomed request to sample at least one token before its
                // deadline, which an env-injected first-step fault would
                // prevent.
                faults: crate::engine::FaultPlan::NONE,
                ..Default::default()
            },
            be,
        );
        e.add_request(req(0, 8, 5));
        let mut doomed = req(1, 8, 10_000);
        doomed.deadline = Some(0.001); // expires after the first step
        e.add_request(doomed);
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 1, "only the undoomed request completes");
        assert_eq!(report.outputs[0].id, 0);
        assert_eq!(report.outcomes.len(), 2);
        assert_eq!(report.outcomes[0], (0, RequestOutcome::Completed));
        assert_eq!(report.outcomes[1], (1, RequestOutcome::TimedOut));
        assert_eq!(report.metrics.timed_out_requests, 1);
        assert!(report.metrics.goodput_tokens < report.metrics.output_tokens,
                "tokens generated for the doomed request must not count as goodput");
        e.audit().unwrap();
    }

    #[test]
    fn transient_faults_retry_to_bit_identical_completion() {
        let run = |faults: crate::engine::FaultPlan| {
            let m = by_name("Llama-2-7B-GPTQ").unwrap();
            let be = SimBackend::new(m, OptConfig::BASELINE, 4);
            let mut e = Engine::new(
                EngineConfig {
                    max_batch: 4,
                    block_size: 4,
                    total_blocks: 64,
                    max_seq_len: 128,
                    prefill_budget: 64,
                    faults,
                    ..Default::default()
                },
                be,
            );
            for i in 0..6 {
                let mut r = req(i, 12, 20);
                r.prompt = vec![i as u32 + 1; 12];
                r.sampling.temperature = 0.8;
                r.sampling.top_k = 32;
                r.sampling.seed = 11;
                e.add_request(r);
            }
            let report = e.run().unwrap();
            e.audit().unwrap();
            let mut toks: Vec<(usize, Vec<u32>)> =
                report.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
            toks.sort();
            (toks, report)
        };
        let plan = crate::engine::FaultPlan {
            seed: 99,
            step_transient: 0.25,
            spill_out: 0.25,
            spill_in: 0.25,
            alloc: 0.1,
            ..crate::engine::FaultPlan::NONE
        };
        let (faulty_toks, faulty) = run(plan);
        let (clean_toks, clean) = run(crate::engine::FaultPlan::NONE);
        assert_eq!(faulty.outputs.len(), 6, "recoverable faults must not lose requests");
        assert!(faulty.outcomes.iter().all(|(_, o)| *o == RequestOutcome::Completed));
        assert_eq!(faulty_toks, clean_toks, "retried tokens must replay bit-identically");
        assert!(faulty.metrics.step_retries > 0, "plan must actually fire");
        assert_eq!(clean.metrics.step_retries, 0);
        assert_eq!(faulty.metrics.goodput_tokens, faulty.metrics.output_tokens);
    }

    #[test]
    fn permanent_fault_fails_the_batch_and_serving_continues() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                faults: crate::engine::FaultPlan {
                    seed: 3,
                    step_permanent: 1.0,
                    ..crate::engine::FaultPlan::NONE
                },
                ..Default::default()
            },
            be,
        );
        for i in 0..5 {
            e.add_request(req(i, 8, 6));
        }
        let report = e.run().unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.outcomes.len(), 5, "every request still gets a typed outcome");
        for (_, o) in &report.outcomes {
            assert!(matches!(o, RequestOutcome::Failed { .. }), "got {o:?}");
        }
        assert_eq!(report.metrics.failed_requests, 5);
        e.audit().unwrap();
    }

    #[test]
    fn transient_streak_exhausts_retries_into_failure() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                faults: crate::engine::FaultPlan {
                    seed: 3,
                    step_transient: 1.0,
                    ..crate::engine::FaultPlan::NONE
                },
                ..Default::default()
            },
            be,
        );
        e.add_request(req(0, 8, 6));
        let report = e.run().unwrap();
        assert!(report.outputs.is_empty());
        assert_eq!(report.outcomes.len(), 1);
        let (id, outcome) = &report.outcomes[0];
        assert_eq!(*id, 0);
        let RequestOutcome::Failed { reason } = outcome else {
            panic!("expected Failed, got {outcome:?}")
        };
        assert!(reason.contains("retries exhausted"), "reason: {reason}");
        assert!(report.metrics.step_retries >= (MAX_STEP_RETRIES - 1) as usize);
        e.audit().unwrap();
    }

    #[test]
    fn shed_requests_surface_as_rejected_outcomes() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig { max_batch: 4, total_blocks: 2048, max_waiting: 1, ..Default::default() },
            be,
        );
        for i in 0..3 {
            e.add_request(req(i, 8, 5));
        }
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 1);
        assert_eq!(report.outputs[0].id, 0);
        assert_eq!(report.metrics.shed_requests, 2);
        assert_eq!(report.metrics.rejected_requests, 2);
        for id in [1usize, 2] {
            let (_, o) = report.outcomes.iter().find(|(i, _)| *i == id).unwrap();
            let RequestOutcome::Rejected { reason } = o else {
                panic!("expected Rejected for {id}, got {o:?}")
            };
            assert!(reason.contains("shed"), "reason: {reason}");
        }
        e.audit().unwrap();
    }

    #[test]
    fn cooperative_cancellation_reclaims_and_reports() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let be = SimBackend::new(m, OptConfig::BASELINE, 4);
        let mut e = Engine::new(
            EngineConfig {
                max_batch: 4,
                total_blocks: 2048,
                faults: crate::engine::FaultPlan::NONE,
                ..Default::default()
            },
            be,
        );
        e.add_request(req(0, 8, 5));
        e.add_request(req(1, 8, 10_000)); // would decode ~forever
        let mut late = req(2, 8, 5);
        late.arrival = 1e9; // pending when cancelled
        e.add_request(late);
        // Let both admitted requests get going, then abort mid-decode.
        for _ in 0..3 {
            assert!(e.step().unwrap());
        }
        e.cancel(1);
        e.cancel(2);
        e.cancel(999); // unknown id: ignored
        let report = e.run().unwrap();
        assert_eq!(report.outputs.len(), 1, "only request 0 completes");
        assert_eq!(report.outputs[0].id, 0);
        assert_eq!(report.outcomes.len(), 3);
        assert_eq!(report.outcomes[0], (0, RequestOutcome::Completed));
        assert_eq!(report.outcomes[1], (1, RequestOutcome::Cancelled));
        assert_eq!(report.outcomes[2], (2, RequestOutcome::Cancelled));
        assert_eq!(report.metrics.cancelled_requests, 2);
        assert!(
            report.metrics.goodput_tokens < report.metrics.output_tokens,
            "tokens generated for the aborted request must not count as goodput"
        );
        e.audit().unwrap();
    }

    #[test]
    fn cancelling_a_finished_request_is_a_noop() {
        let mut e = engine(4);
        e.add_request(req(0, 8, 3));
        let report1 = {
            while e.step().unwrap() {}
            e.cancel(0); // already finished
            e.run().unwrap()
        };
        assert_eq!(report1.outcomes, vec![(0, RequestOutcome::Completed)]);
        assert_eq!(report1.metrics.cancelled_requests, 0);
    }

    #[test]
    fn mid_flight_checkpoint_restores_bit_identically() {
        let dir = std::env::temp_dir().join(format!("o4g-engine-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = EngineConfig {
            max_batch: 4,
            block_size: 4,
            total_blocks: 64,
            max_seq_len: 128,
            prefill_budget: 16,
            faults: crate::engine::FaultPlan::NONE,
            ..Default::default()
        };
        let add_all = |e: &mut Engine<SimBackend>| {
            for i in 0..6 {
                let mut r = req(i, 12, 20);
                r.prompt = vec![i as u32 + 1; 12];
                r.sampling.temperature = 0.8;
                r.sampling.top_k = 32;
                r.sampling.seed = 13;
                if i == 5 {
                    r.arrival = 1e7; // stays pending across the snapshot
                }
                e.add_request(r);
            }
        };
        let m = by_name("Llama-2-7B-GPTQ").unwrap();

        // Reference: uninterrupted run.
        let mut reference = Engine::new(cfg, SimBackend::new(m, OptConfig::BASELINE, 4));
        add_all(&mut reference);
        let want = reference.run().unwrap();

        // Checkpointed run: same workload, snapshot every 3 steps.
        let mut live = Engine::new(cfg, SimBackend::new(m, OptConfig::BASELINE, 4));
        live.enable_checkpoints(&dir, 3);
        add_all(&mut live);
        // Drive a prefix of the run (guaranteed mid-flight: request 5
        // is still pending, most of 0..5 still decoding), then abandon
        // the engine — simulating a crash after its last commit.
        for _ in 0..7 {
            assert!(live.step().unwrap());
        }
        assert!(live.metrics.checkpoints_written >= 2);
        drop(live);

        // Restore and finish; completed tokens must match the
        // reference bit-for-bit, and the auditor must stay green.
        let mut restored =
            Engine::<SimBackend>::restore(cfg, SimBackend::new(m, OptConfig::BASELINE, 4), &dir)
                .unwrap();
        assert!(restored.metrics.restored_requests > 0);
        let got = restored.run().unwrap();
        restored.audit().unwrap();
        let key = |r: &EngineReport| {
            let mut t: Vec<(usize, Vec<u32>)> =
                r.outputs.iter().map(|o| (o.id, o.tokens.clone())).collect();
            t.sort();
            t
        };
        assert_eq!(key(&got), key(&want), "restored run must replay bit-identically");
        assert_eq!(got.outcomes, want.outcomes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_without_snapshots_is_an_error() {
        let dir = std::env::temp_dir().join(format!("o4g-engine-empty-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let err = Engine::<SimBackend>::restore(
            EngineConfig::default(),
            SimBackend::new(m, OptConfig::BASELINE, 4),
            &dir,
        )
        .unwrap_err();
        assert!(err.to_string().contains("no snapshot"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_rejects_mismatched_geometry() {
        let dir = std::env::temp_dir().join(format!("o4g-engine-fp-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = EngineConfig {
            max_batch: 4,
            total_blocks: 256,
            faults: crate::engine::FaultPlan::NONE,
            ..Default::default()
        };
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let mut e = Engine::new(cfg, SimBackend::new(m, OptConfig::BASELINE, 4));
        e.enable_checkpoints(&dir, 1);
        e.add_request(req(0, 8, 6));
        assert!(e.step().unwrap());
        assert_eq!(e.metrics.checkpoints_written, 1);
        let bad_cfg = EngineConfig { total_blocks: 128, ..cfg };
        let err = Engine::<SimBackend>::restore(
            bad_cfg,
            SimBackend::new(m, OptConfig::BASELINE, 4),
            &dir,
        )
        .unwrap_err();
        assert!(err.to_string().contains("config mismatch"), "{err}");
        // Restoring under a *different model* must be refused with a
        // message that names both registry entries, so the operator can
        // see which `--model` the snapshot wants.
        let other = if cfg.model == crate::models::TINY_GQA {
            crate::models::TINY_MHA
        } else {
            crate::models::TINY_GQA
        };
        let bad_model = EngineConfig { model: other, ..cfg };
        let err = Engine::<SimBackend>::restore(
            bad_model,
            SimBackend::new(m, OptConfig::BASELINE, 4),
            &dir,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("config mismatch"), "{msg}");
        assert!(
            msg.contains(cfg.model.name) && msg.contains(other.name),
            "mismatch message must name both models: {msg}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_seams_kill_the_run_and_restore_recovers() {
        let dir = std::env::temp_dir().join(format!("o4g-engine-crash-{}", std::process::id()));
        for (plan, expect_snapshot) in [
            (
                crate::engine::FaultPlan {
                    seed: 5,
                    crash_after: 1.0,
                    ..crate::engine::FaultPlan::NONE
                },
                true,
            ),
            (
                crate::engine::FaultPlan {
                    seed: 5,
                    crash_before: 1.0,
                    ..crate::engine::FaultPlan::NONE
                },
                false,
            ),
        ] {
            let _ = std::fs::remove_dir_all(&dir);
            let m = by_name("Llama-2-7B-GPTQ").unwrap();
            let cfg = EngineConfig { max_batch: 4, total_blocks: 256, faults: plan, ..Default::default() };
            let mut e = Engine::new(cfg, SimBackend::new(m, OptConfig::BASELINE, 4));
            e.enable_checkpoints(&dir, 2);
            for i in 0..3 {
                e.add_request(req(i, 8, 12));
            }
            let err = e.run().unwrap_err().to_string();
            assert!(err.contains("injected crash"), "{err}");
            assert_eq!(
                e.metrics.checkpoints_written > 0,
                expect_snapshot,
                "crash_after commits first, crash_before dies first"
            );
            if expect_snapshot {
                // Restart with a crash-free plan resumes from the commit.
                let clean = EngineConfig { faults: crate::engine::FaultPlan::NONE, ..cfg };
                let mut restored = Engine::<SimBackend>::restore(
                    clean,
                    SimBackend::new(m, OptConfig::BASELINE, 4),
                    &dir,
                )
                .unwrap();
                let report = restored.run().unwrap();
                assert_eq!(report.outputs.len(), 3);
                restored.audit().unwrap();
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn optimized_config_yields_higher_throughput() {
        let m = by_name("LLaMa-13B-GPTQ").unwrap();
        let mut results = Vec::new();
        for opt in [OptConfig::BASELINE, OptConfig::OPT4GPTQ] {
            let be = SimBackend::new(m, opt, 32);
            // Pinned fault-free: the strict opt>base throughput comparison
            // is about the cost model; injected retry backoffs would add
            // schedule-dependent noise to both sides.
            let mut e = Engine::new(
                EngineConfig {
                    faults: crate::engine::FaultPlan::NONE,
                    ..Default::default()
                },
                be,
            );
            for i in 0..32 {
                e.add_request(req(i, 32, 16));
            }
            results.push(e.run().unwrap().metrics.throughput());
        }
        assert!(results[1] > results[0], "opt {} <= base {}", results[1], results[0]);
    }
}

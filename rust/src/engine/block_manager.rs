//! Paged KV-cache block manager (the PagedAttention memory layer).
//!
//! KV storage is carved into fixed-size blocks of `block_size` tokens;
//! each sequence owns a block table mapping its logical positions onto
//! physical blocks.  Blocks are reference-counted so identical prompt
//! prefixes can share physical blocks (prefix caching); copy-on-write is
//! not needed here (no beam search), but freeing, reuse and the
//! out-of-memory/preemption path are fully modelled — they shape the
//! scheduler behaviour the paper's throughput runs exercise.

use std::collections::HashMap;

/// Physical block id.
pub type BlockId = usize;

#[derive(Debug, Clone)]
struct Block {
    refcount: usize,
    /// Hash of the full token prefix this block completes (prefix cache
    /// key); None for blocks still being filled.
    prefix_hash: Option<u64>,
    /// True once the owning sequence's prefill has materialized every
    /// position of this block in the paged K/V pool.  A prefix-cache hit
    /// on a *computed* block can skip recomputation entirely; a hit on a
    /// block whose owner is still mid-prefill shares the memory but must
    /// recompute (the values do not exist yet).
    computed: bool,
}

/// Allocator + per-sequence block tables.
#[derive(Debug)]
pub struct BlockManager {
    pub block_size: usize,
    blocks: Vec<Block>,
    free: Vec<BlockId>,
    /// prefix hash -> physical block (prefix cache).
    prefix_index: HashMap<u64, BlockId>,
    /// sequence id -> block table.
    tables: HashMap<usize, Vec<BlockId>>,
    /// Cache hit statistics.
    pub prefix_hits: usize,
    /// Blocks whose refcount reached zero since the last
    /// [`BlockManager::take_released`] drain.  The engine forwards these
    /// to [`crate::engine::Backend::release_blocks`] at the end of each
    /// step — before any of them can be re-allocated by the next
    /// `schedule()` — so paged backends can poison/recycle the memory.
    freed_log: Vec<BlockId>,
    /// Sequence ids fully freed (finished or preempted) since the last
    /// drain; forwarded to [`crate::engine::Backend::release_seq`].
    released_seqs: Vec<usize>,
    /// Sequences swapped out to the host-side spill pool: id → number of
    /// blocks whose contents live in the backend's spill buffer.  A
    /// swapped sequence holds **no** physical blocks (its table is gone),
    /// but its K/V is preserved — unlike a recompute-preempted sequence.
    swapped: HashMap<usize, usize>,
    /// (seq, table) pairs swapped out since the last
    /// [`BlockManager::take_swap_outs`] drain.  The engine forwards these
    /// to [`crate::engine::Backend::swap_out`] **before** draining
    /// `freed_log` — the spill copy must read the blocks ahead of the
    /// poison/recycle pass.
    swap_out_log: Vec<(usize, Vec<BlockId>)>,
    /// (seq, restore-span) pairs swapped back in since the last
    /// [`BlockManager::take_swap_ins`] drain; the engine forwards these
    /// to [`crate::engine::Backend::swap_in`] before the resuming step.
    swap_in_log: Vec<(usize, Vec<BlockId>)>,
}

/// The allocator's full accounting state as plain data — what a
/// checkpoint serializes.  Map-backed fields are exported as key-sorted
/// vectors so snapshot bytes are deterministic; the free list keeps its
/// exact stack order, because block *placement* (which physical id the
/// next `free.pop()` hands out) must replay bit-identically after a
/// restore.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockManagerState {
    pub block_size: usize,
    /// Per block, indexed by [`BlockId`]: (refcount, prefix_hash, computed).
    pub blocks: Vec<(usize, Option<u64>, bool)>,
    /// Free list in stack (pop) order.
    pub free: Vec<BlockId>,
    pub prefix_index: Vec<(u64, BlockId)>,
    pub tables: Vec<(usize, Vec<BlockId>)>,
    /// Swapped-out sequences: (seq id, spilled block count).
    pub swapped: Vec<(usize, usize)>,
    pub prefix_hits: usize,
}

impl BlockManager {
    pub fn new(total_blocks: usize, block_size: usize) -> BlockManager {
        assert!(block_size > 0 && total_blocks > 0);
        BlockManager {
            block_size,
            blocks: (0..total_blocks)
                .map(|_| Block { refcount: 0, prefix_hash: None, computed: false })
                .collect(),
            free: (0..total_blocks).rev().collect(),
            prefix_index: HashMap::new(),
            tables: HashMap::new(),
            prefix_hits: 0,
            freed_log: Vec::new(),
            released_seqs: Vec::new(),
            swapped: HashMap::new(),
            swap_out_log: Vec::new(),
            swap_in_log: Vec::new(),
        }
    }

    /// Drain the release logs: (physically freed blocks, retired
    /// sequence ids).  Callers must drain before re-allocating the freed
    /// blocks if they mirror block contents elsewhere (the engine drains
    /// once per step, after execution and before the next `schedule()`).
    pub fn take_released(&mut self) -> (Vec<BlockId>, Vec<usize>) {
        (std::mem::take(&mut self.freed_log), std::mem::take(&mut self.released_seqs))
    }

    /// Drain the swap-out log: (seq, its former table) per swap-out.
    /// Must be drained **before** [`BlockManager::take_released`] each
    /// step — the backend's spill copy has to read the blocks before the
    /// release pass poisons them.
    pub fn take_swap_outs(&mut self) -> Vec<(usize, Vec<BlockId>)> {
        std::mem::take(&mut self.swap_out_log)
    }

    /// Drain the swap-in log: (seq, blocks to restore into) per swap-in.
    pub fn take_swap_ins(&mut self) -> Vec<(usize, Vec<BlockId>)> {
        std::mem::take(&mut self.swap_in_log)
    }

    /// Is this sequence currently swapped out (K/V preserved in the
    /// backend spill pool, no physical blocks held)?
    pub fn is_swapped(&self, seq_id: usize) -> bool {
        self.swapped.contains_key(&seq_id)
    }

    /// Evict a sequence's blocks to the spill pool: the table is freed
    /// exactly like [`BlockManager::free_sequence`] (shared prefix blocks
    /// just drop a reference; private ones return to the free list), but
    /// the sequence is recorded as swapped and the (seq, table) pair is
    /// logged so the backend copies the contents out before the freed
    /// blocks are poisoned or recycled.  No `released_seqs` entry is
    /// pushed — the backend must keep the spill alive for the swap-in.
    pub fn swap_out(&mut self, seq_id: usize) {
        let table = self.tables.remove(&seq_id).expect("swap_out of unallocated sequence");
        self.swapped.insert(seq_id, table.len());
        for &b in &table {
            self.release_block(b);
        }
        self.swap_out_log.push((seq_id, table));
    }

    /// Can the swapped-out sequence resume right now on a table covering
    /// `total_tokens` positions?
    pub fn can_swap_in(&self, seq_id: usize, total_tokens: usize) -> bool {
        match self.swapped.get(&seq_id) {
            Some(&n) => n.max(self.blocks_needed(total_tokens)) <= self.free.len(),
            None => false,
        }
    }

    /// Resume a swapped-out sequence: allocate a fresh private table
    /// covering `total_tokens` positions (at least as many blocks as
    /// were spilled), log the restore span, and hand the table back to
    /// the sequence.  The first `n_spilled` blocks receive the spilled
    /// contents (table order is preserved, so logical positions land
    /// where they were); any extra blocks cover positions the resumed
    /// prefill is about to write.  Returns false when the pool cannot
    /// hold the table yet.
    ///
    /// Restored blocks are private and uncomputed: the prefix-cache
    /// association was dropped at swap-out and is not resurrected
    /// (`mark_computed` re-marks them as the resumed prefill advances,
    /// but without a hash they are never prefix-hit).
    pub fn swap_in(&mut self, seq_id: usize, total_tokens: usize) -> bool {
        if !self.can_swap_in(seq_id, total_tokens) {
            return false;
        }
        let n_spilled = self.swapped.remove(&seq_id).expect("checked by can_swap_in");
        let needed = n_spilled.max(self.blocks_needed(total_tokens));
        let mut table = Vec::with_capacity(needed);
        for _ in 0..needed {
            let b = self.free.pop().expect("checked by can_swap_in");
            // Freed earlier in this drain window → it must leave the
            // freed log, or the end-of-step drain would poison a block
            // the restore just wrote (see append_token).
            self.freed_log.retain(|&x| x != b);
            self.blocks[b].refcount = 1;
            self.blocks[b].prefix_hash = None;
            self.blocks[b].computed = false;
            table.push(b);
        }
        self.swap_in_log.push((seq_id, table[..n_spilled].to_vec()));
        self.tables.insert(seq_id, table);
        true
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_needed(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a sequence of `tokens` tokens be admitted right now?
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_needed(tokens) <= self.free.len()
    }

    /// Allocate the block table for a new sequence's prompt, reusing
    /// prefix-cached blocks for fully-filled prefix blocks.
    ///
    /// On success returns `Some(cached_len)`: the number of leading
    /// prompt tokens whose K/V already live in fully-shared **and fully
    /// computed** prefix blocks — the span a prefix-aware prefill may
    /// skip outright.  A hit on a block whose owner is still mid-prefill
    /// shares the memory (refcount bump) but contributes nothing to
    /// `cached_len`: its values are not materialized yet.  Returns
    /// `None` on out-of-memory (everything rolled back).
    pub fn allocate(&mut self, seq_id: usize, prompt: &[u32]) -> Option<usize> {
        assert!(!self.tables.contains_key(&seq_id), "sequence already allocated");
        let needed = self.blocks_needed(prompt.len().max(1));
        let mut table = Vec::with_capacity(needed);
        let mut hasher: u64 = 0xcbf2_9ce4_8422_2325;
        let mut cached_blocks = 0usize;
        let mut leading_run = true;
        for bi in 0..needed {
            let start = bi * self.block_size;
            let end = ((bi + 1) * self.block_size).min(prompt.len());
            let full = end - start == self.block_size;
            let key = if full {
                for &t in &prompt[start..end] {
                    hasher ^= t as u64;
                    hasher = hasher.wrapping_mul(0x100_0000_01b3);
                }
                Some(hasher)
            } else {
                None
            };
            if let Some(k) = key {
                if let Some(&b) = self.prefix_index.get(&k) {
                    self.blocks[b].refcount += 1;
                    self.prefix_hits += 1;
                    if leading_run && self.blocks[b].computed {
                        cached_blocks += 1;
                    } else {
                        leading_run = false;
                    }
                    table.push(b);
                    continue;
                }
            }
            leading_run = false;
            match self.free.pop() {
                Some(b) => {
                    // Reclaimed within this drain window: the block must
                    // leave the freed log (see append_token).
                    self.freed_log.retain(|&x| x != b);
                    self.blocks[b].refcount = 1;
                    self.blocks[b].prefix_hash = key;
                    self.blocks[b].computed = false;
                    if let Some(k) = key {
                        self.prefix_index.insert(k, b);
                    }
                    table.push(b);
                }
                None => {
                    // Out of memory: roll back everything this call took.
                    // `release_block` handles both cases uniformly —
                    // prefix-shared blocks drop back to their prior
                    // refcount, and freshly-taken blocks (including ones
                    // just entered into the prefix index above) return
                    // to the free list with their index entry removed,
                    // so no dangling prefix entry can survive a failed
                    // allocation (`check_invariants` pins this).
                    for &b in table.iter() {
                        self.release_block(b);
                    }
                    return None;
                }
            }
        }
        self.tables.insert(seq_id, table);
        Some((cached_blocks * self.block_size).min(prompt.len()))
    }

    /// Record prefill progress: every table block fully covered by the
    /// first `upto_tokens` positions is now materialized in the paged
    /// pool, so future prefix-cache hits on it may skip recomputation.
    /// Idempotent; partial tail blocks stay uncomputed (they carry no
    /// prefix hash and can never be hit anyway).
    pub fn mark_computed(&mut self, seq_id: usize, upto_tokens: usize) {
        let table = self.tables.get(&seq_id).expect("unknown sequence");
        for (bi, &b) in table.iter().enumerate() {
            if (bi + 1) * self.block_size > upto_tokens {
                break;
            }
            self.blocks[b].computed = true;
        }
    }

    /// Append one generated token; allocates a fresh block at block
    /// boundaries.  Returns false when out of blocks (caller preempts).
    pub fn append_token(&mut self, seq_id: usize, total_tokens: usize) -> bool {
        let needed = self.blocks_needed(total_tokens);
        let table = self.tables.get_mut(&seq_id).expect("unknown sequence");
        debug_assert!(needed >= table.len());
        if needed == table.len() {
            return true;
        }
        match self.free.pop() {
            Some(b) => {
                // A block freed earlier in this drain window is being
                // handed to a new owner: it must leave the freed log, or
                // the end-of-step drain would report (and debug-poison)
                // a block a live table references.
                self.freed_log.retain(|&x| x != b);
                self.blocks[b].refcount = 1;
                self.blocks[b].prefix_hash = None;
                self.blocks[b].computed = false;
                table.push(b);
                true
            }
            None => false,
        }
    }

    fn release_block(&mut self, b: BlockId) {
        let blk = &mut self.blocks[b];
        assert!(blk.refcount > 0, "double free of block {b}");
        blk.refcount -= 1;
        if blk.refcount == 0 {
            if let Some(k) = blk.prefix_hash.take() {
                self.prefix_index.remove(&k);
            }
            blk.computed = false;
            self.free.push(b);
            self.freed_log.push(b);
        }
    }

    /// Free a sequence's entire table (finish or preemption).  A
    /// sequence freed while swapped out holds no blocks, but its spill
    /// entry must still be retired (the `released_seqs` drain tells the
    /// backend to drop the buffer).
    pub fn free_sequence(&mut self, seq_id: usize) {
        if let Some(table) = self.tables.remove(&seq_id) {
            self.released_seqs.push(seq_id);
            for b in table {
                self.release_block(b);
            }
        } else if self.swapped.remove(&seq_id).is_some() {
            self.released_seqs.push(seq_id);
        }
    }

    pub fn table(&self, seq_id: usize) -> Option<&[BlockId]> {
        self.tables.get(&seq_id).map(|t| t.as_slice())
    }

    /// Blocks currently free — the post-drain auditor cross-checks these
    /// ids against the paged pool's poison state.
    pub fn free_list(&self) -> &[BlockId] {
        &self.free
    }

    /// Forget a swap-out whose spill write failed: the sequence is no
    /// longer swapped (its K/V is gone; the caller demotes it to a
    /// recompute preemption — the blocks themselves were already freed
    /// by [`BlockManager::swap_out`]).  Returns false when the sequence
    /// was not swapped.
    pub fn abort_swap(&mut self, seq_id: usize) -> bool {
        self.swapped.remove(&seq_id).is_some()
    }

    /// End-of-run audit: after the engine drains, no sequence may hold a
    /// block table or a spill reservation, every block must be back on
    /// the free list, and every release/swap log must have been
    /// forwarded to the backend.  Includes the full
    /// [`BlockManager::check_invariants`] pass.
    pub fn assert_drained(&self) -> Result<(), String> {
        self.check_invariants()?;
        if !self.tables.is_empty() {
            let mut ids: Vec<_> = self.tables.keys().copied().collect();
            ids.sort_unstable();
            return Err(format!("leaked block tables for sequences {ids:?}"));
        }
        if !self.swapped.is_empty() {
            let mut ids: Vec<_> = self.swapped.keys().copied().collect();
            ids.sort_unstable();
            return Err(format!("leaked spill reservations for sequences {ids:?}"));
        }
        if self.free.len() != self.blocks.len() {
            return Err(format!(
                "{} of {} blocks leaked (free list holds {})",
                self.blocks.len() - self.free.len(),
                self.blocks.len(),
                self.free.len()
            ));
        }
        if !self.freed_log.is_empty()
            || !self.released_seqs.is_empty()
            || !self.swap_out_log.is_empty()
            || !self.swap_in_log.is_empty()
        {
            return Err("undrained release/swap logs".into());
        }
        Ok(())
    }

    /// Export the full accounting state for a checkpoint.  Only legal at
    /// a quiescent point: every release/swap log must have been drained
    /// (the engine checkpoints after its end-of-step drain), or the
    /// snapshot would silently drop backend work in flight.
    pub fn export_state(&self) -> Result<BlockManagerState, String> {
        if !self.freed_log.is_empty()
            || !self.released_seqs.is_empty()
            || !self.swap_out_log.is_empty()
            || !self.swap_in_log.is_empty()
        {
            return Err("cannot snapshot with undrained release/swap logs".into());
        }
        let mut prefix_index: Vec<(u64, BlockId)> =
            self.prefix_index.iter().map(|(&k, &b)| (k, b)).collect();
        prefix_index.sort_unstable();
        let mut tables: Vec<(usize, Vec<BlockId>)> =
            self.tables.iter().map(|(&id, t)| (id, t.clone())).collect();
        tables.sort_unstable_by_key(|(id, _)| *id);
        let mut swapped: Vec<(usize, usize)> =
            self.swapped.iter().map(|(&id, &n)| (id, n)).collect();
        swapped.sort_unstable();
        Ok(BlockManagerState {
            block_size: self.block_size,
            blocks: self
                .blocks
                .iter()
                .map(|b| (b.refcount, b.prefix_hash, b.computed))
                .collect(),
            free: self.free.clone(),
            prefix_index,
            tables,
            swapped,
            prefix_hits: self.prefix_hits,
        })
    }

    /// Rebuild an allocator from persisted [`Self::export_state`] output,
    /// validating internal consistency before handing it back (a corrupt
    /// or hand-edited snapshot must fail restore, not corrupt serving).
    pub fn import_state(state: BlockManagerState) -> Result<BlockManager, String> {
        if state.block_size == 0 || state.blocks.is_empty() {
            return Err("snapshot block geometry is degenerate".into());
        }
        let bm = BlockManager {
            block_size: state.block_size,
            blocks: state
                .blocks
                .into_iter()
                .map(|(refcount, prefix_hash, computed)| Block { refcount, prefix_hash, computed })
                .collect(),
            free: state.free,
            prefix_index: state.prefix_index.into_iter().collect(),
            tables: state.tables.into_iter().collect(),
            prefix_hits: state.prefix_hits,
            freed_log: Vec::new(),
            released_seqs: Vec::new(),
            swapped: state.swapped.into_iter().collect(),
            swap_out_log: Vec::new(),
            swap_in_log: Vec::new(),
        };
        bm.check_invariants().map_err(|e| format!("snapshot allocator state invalid: {e}"))?;
        Ok(bm)
    }

    /// Invariant check used by property tests: refcounts, free list and
    /// tables must be mutually consistent.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut counted: HashMap<BlockId, usize> = HashMap::new();
        for table in self.tables.values() {
            for &b in table {
                *counted.entry(b).or_default() += 1;
            }
        }
        for (b, blk) in self.blocks.iter().enumerate() {
            let c = counted.get(&b).copied().unwrap_or(0);
            if blk.refcount != c {
                return Err(format!("block {b}: refcount {} != table refs {c}", blk.refcount));
            }
            let in_free = self.free.contains(&b);
            if (blk.refcount == 0) != in_free {
                return Err(format!("block {b}: refcount {} vs free-list {in_free}", blk.refcount));
            }
            if blk.refcount == 0 && blk.computed {
                return Err(format!("freed block {b} still marked computed"));
            }
        }
        let used: usize = self.blocks.iter().filter(|b| b.refcount > 0).count();
        if used + self.free.len() != self.blocks.len() {
            return Err("used + free != total".into());
        }
        // The prefix cache may only point at live blocks that still carry
        // the hash they were indexed under (a failed allocation's
        // rollback must not leave entries dangling at freed blocks).
        for (&k, &b) in &self.prefix_index {
            let blk = &self.blocks[b];
            if blk.refcount == 0 {
                return Err(format!("prefix index {k:#x} points at freed block {b}"));
            }
            if blk.prefix_hash != Some(k) {
                return Err(format!(
                    "prefix index {k:#x} -> block {b} carrying hash {:?}",
                    blk.prefix_hash
                ));
            }
        }
        // And every indexed hash on a live block must be findable.
        for (b, blk) in self.blocks.iter().enumerate() {
            if blk.refcount > 0 {
                if let Some(k) = blk.prefix_hash {
                    if self.prefix_index.get(&k) != Some(&b) {
                        return Err(format!("block {b} hash {k:#x} missing from prefix index"));
                    }
                }
            }
        }
        // A swapped-out sequence lives in the spill pool, not the block
        // pool: it must hold no table.
        for &id in self.swapped.keys() {
            if self.tables.contains_key(&id) {
                return Err(format!("swapped seq {id} still holds a block table"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut bm = BlockManager::new(16, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4, 5]).is_some());
        assert_eq!(bm.table(1).unwrap().len(), 2);
        assert_eq!(bm.free_blocks(), 14);
        bm.free_sequence(1);
        assert_eq!(bm.free_blocks(), 16);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn append_allocates_at_boundaries() {
        let mut bm = BlockManager::new(8, 4);
        assert!(bm.allocate(1, &[1, 2, 3]).is_some());
        assert_eq!(bm.table(1).unwrap().len(), 1);
        assert!(bm.append_token(1, 4)); // fills block 0
        assert_eq!(bm.table(1).unwrap().len(), 1);
        assert!(bm.append_token(1, 5)); // needs block 1
        assert_eq!(bm.table(1).unwrap().len(), 2);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn out_of_memory_reported_and_rolled_back() {
        let mut bm = BlockManager::new(2, 4);
        assert!(bm.allocate(1, &[1, 1, 1, 1, 2, 2, 2, 2]).is_some()); // uses both blocks
        // different content -> no prefix sharing -> must fail
        assert!(bm.allocate(2, &[9, 9, 9, 9, 8, 8, 8, 8]).is_none());
        assert!(bm.table(2).is_none());
        bm.check_invariants().unwrap();
        bm.free_sequence(1);
        assert!(bm.allocate(2, &[9, 9, 9, 9, 8, 8, 8, 8]).is_some());
        bm.check_invariants().unwrap();
    }

    #[test]
    fn prefix_sharing_reuses_full_blocks() {
        let mut bm = BlockManager::new(16, 4);
        let prompt: Vec<u32> = (0..8).collect();
        assert!(bm.allocate(1, &prompt).is_some());
        let before = bm.free_blocks();
        assert!(bm.allocate(2, &prompt).is_some());
        // Both full blocks shared: no new blocks consumed.
        assert_eq!(bm.free_blocks(), before);
        assert_eq!(bm.prefix_hits, 2);
        assert_eq!(bm.table(1).unwrap(), bm.table(2).unwrap());
        bm.check_invariants().unwrap();
        // Freeing one keeps the shared blocks alive for the other.
        bm.free_sequence(1);
        bm.check_invariants().unwrap();
        assert!(bm.table(2).is_some());
        bm.free_sequence(2);
        assert_eq!(bm.free_blocks(), 16);
    }

    #[test]
    fn divergent_prompts_do_not_share() {
        let mut bm = BlockManager::new(16, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4]).is_some());
        assert!(bm.allocate(2, &[1, 2, 3, 9]).is_some());
        assert_ne!(bm.table(1).unwrap(), bm.table(2).unwrap());
        bm.check_invariants().unwrap();
    }

    #[test]
    fn partial_tail_block_is_private() {
        let mut bm = BlockManager::new(16, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4, 5]).is_some()); // 1 full + 1 partial
        assert!(bm.allocate(2, &[1, 2, 3, 4, 5]).is_some());
        let t1 = bm.table(1).unwrap();
        let t2 = bm.table(2).unwrap();
        assert_eq!(t1[0], t2[0], "full prefix block shared");
        assert_ne!(t1[1], t2[1], "partial tail must be private");
        bm.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "already allocated")]
    fn double_allocate_panics() {
        let mut bm = BlockManager::new(4, 4);
        bm.allocate(1, &[1]);
        bm.allocate(1, &[1]);
    }

    #[test]
    fn oom_rollback_leaves_no_dangling_prefix_entry() {
        let mut bm = BlockManager::new(3, 4);
        assert!(bm.allocate(1, &[1, 1, 1, 1, 2, 2, 2, 2]).is_some()); // 2 full blocks
        // Seq 2 needs 3 blocks: its first full block is allocated *and*
        // prefix-indexed before the pool runs dry on the second — the
        // rollback must also retract that index entry.
        assert!(bm.allocate(2, &[5, 5, 5, 5, 6, 6, 6, 6, 7, 7, 7, 7]).is_none());
        assert!(bm.table(2).is_none());
        assert_eq!(bm.free_blocks(), 1);
        bm.check_invariants().unwrap();
        // A later identical prompt must take a *fresh* block, not "hit"
        // the rolled-back (freed) one through a stale index entry.
        let hits_before = bm.prefix_hits;
        assert!(bm.allocate(3, &[5, 5, 5, 5]).is_some());
        assert_eq!(bm.prefix_hits, hits_before, "prefix hit on a rolled-back block");
        bm.check_invariants().unwrap();
    }

    #[test]
    fn oom_rollback_keeps_shared_prefix_blocks_alive() {
        let mut bm = BlockManager::new(3, 4);
        let prompt: Vec<u32> = (0..8).collect();
        assert!(bm.allocate(1, &prompt).is_some());
        // Seq 2 shares both full blocks, then fails on its private tail.
        let mut longer: Vec<u32> = prompt.clone();
        longer.extend([9, 9, 9, 9, 8]); // 4 blocks total > 3 available
        assert!(bm.allocate(2, &longer).is_none());
        bm.check_invariants().unwrap();
        // Seq 1's shared blocks survived the rollback untouched.
        assert_eq!(bm.table(1).unwrap().len(), 2);
        assert!(bm.allocate(3, &prompt).is_some(), "prefix cache must still serve the survivor");
        assert!(bm.prefix_hits >= 4);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn release_logs_report_physical_frees_once() {
        let mut bm = BlockManager::new(8, 4);
        let prompt: Vec<u32> = (0..8).collect();
        assert!(bm.allocate(1, &prompt).is_some());
        assert!(bm.allocate(2, &prompt).is_some()); // fully shared
        bm.take_released(); // discard allocation-era noise (none expected)
        bm.free_sequence(1);
        let (freed, seqs) = bm.take_released();
        assert!(freed.is_empty(), "shared blocks are not physically free yet");
        assert_eq!(seqs, vec![1]);
        bm.free_sequence(2);
        let (freed, seqs) = bm.take_released();
        assert_eq!(freed.len(), 2, "last reference frees both blocks");
        assert_eq!(seqs, vec![2]);
        let (freed, seqs) = bm.take_released();
        assert!(freed.is_empty() && seqs.is_empty(), "drain must not repeat");
    }

    #[test]
    fn reused_block_leaves_the_freed_log_before_the_drain() {
        // Free a sequence and re-allocate its block within the same
        // drain window (exactly what preempt-then-retry does inside one
        // engine step): the drain must NOT report the reused block, or
        // the backend would poison memory a live table references.
        let mut bm = BlockManager::new(1, 4);
        assert!(bm.allocate(1, &[1, 2, 3]).is_some());
        let b = bm.table(1).unwrap()[0];
        bm.free_sequence(1);
        assert!(bm.allocate(2, &[7, 8, 9]).is_some());
        assert_eq!(bm.table(2).unwrap()[0], b, "the single block must be reused");
        let (freed, seqs) = bm.take_released();
        assert!(freed.is_empty(), "reused block must not be reported as freed: {freed:?}");
        assert_eq!(seqs, vec![1]);
        bm.check_invariants().unwrap();
    }

    #[test]
    fn cached_len_counts_only_computed_shared_blocks() {
        let mut bm = BlockManager::new(16, 4);
        let prompt: Vec<u32> = (0..10).collect(); // 2 full blocks + tail
        assert_eq!(bm.allocate(1, &prompt), Some(0), "fresh blocks cannot be cached");
        // Seq 2 hits both full blocks, but seq 1 has not prefilled yet:
        // memory is shared, compute is not skippable.
        assert_eq!(bm.allocate(2, &prompt), Some(0), "uncomputed hits must not count");
        bm.free_sequence(2);
        // Seq 1's prefill passes the first block only.
        bm.mark_computed(1, 5);
        assert_eq!(bm.allocate(3, &prompt), Some(4), "one computed block = 4 tokens");
        bm.free_sequence(3);
        // Full prefill: both full blocks are now skippable.
        bm.mark_computed(1, 10);
        assert_eq!(bm.allocate(4, &prompt), Some(8));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn cached_len_is_clamped_and_reset_on_free() {
        let mut bm = BlockManager::new(16, 4);
        let prompt: Vec<u32> = (0..8).collect(); // exactly 2 full blocks
        assert_eq!(bm.allocate(1, &prompt), Some(0));
        bm.mark_computed(1, 8);
        // Fully-cached prompt: cached_len covers the whole prompt (the
        // scheduler clamps to len-1 to keep logits computable).
        assert_eq!(bm.allocate(2, &prompt), Some(8));
        bm.free_sequence(1);
        bm.free_sequence(2);
        bm.check_invariants().unwrap();
        // All references dropped: the computed flag must not survive
        // into a recycled block.
        assert_eq!(bm.allocate(3, &prompt), Some(0), "freed blocks must forget computed state");
        bm.check_invariants().unwrap();
    }

    #[test]
    fn cached_len_stops_at_first_gap() {
        let mut bm = BlockManager::new(16, 4);
        let a: Vec<u32> = (0..8).collect();
        assert_eq!(bm.allocate(1, &a), Some(0));
        bm.mark_computed(1, 8);
        // Same first block, divergent second block: the leading cached
        // run must stop at the divergence even though block 0 is hit.
        let b: Vec<u32> = vec![0, 1, 2, 3, 9, 9, 9, 9];
        assert_eq!(bm.allocate(2, &b), Some(4));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn swap_out_frees_blocks_and_logs_the_table() {
        let mut bm = BlockManager::new(8, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4, 5]).is_some());
        let table = bm.table(1).unwrap().to_vec();
        bm.take_released();
        bm.swap_out(1);
        assert!(bm.is_swapped(1));
        assert!(bm.table(1).is_none());
        assert_eq!(bm.free_blocks(), 8, "swapped seq must hold no blocks");
        // The spill copy sees the exact former table; the freed blocks
        // are reported separately (the drain order is the engine's job).
        assert_eq!(bm.take_swap_outs(), vec![(1, table)]);
        let (freed, seqs) = bm.take_released();
        assert_eq!(freed.len(), 2);
        assert!(seqs.is_empty(), "swap-out must NOT retire the seq (spill stays alive)");
        bm.check_invariants().unwrap();
    }

    #[test]
    fn swap_in_restores_onto_fresh_blocks() {
        let mut bm = BlockManager::new(4, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4, 5]).is_some());
        bm.swap_out(1);
        assert!(bm.can_swap_in(1, 5));
        assert!(bm.swap_in(1, 5));
        assert!(!bm.is_swapped(1));
        assert_eq!(bm.table(1).unwrap().len(), 2);
        let ins = bm.take_swap_ins();
        assert_eq!(ins.len(), 1);
        assert_eq!(ins[0].0, 1);
        assert_eq!(ins[0].1, bm.table(1).unwrap()[..2].to_vec());
        bm.check_invariants().unwrap();
        // Restored blocks are private and uncomputed: an identical
        // prompt cannot prefix-hit them.
        assert_eq!(bm.allocate(2, &[1, 2, 3, 4, 5]), Some(0));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn swap_in_grows_the_table_when_the_resume_needs_more_room() {
        // A self-preempted decode can be swapped with its table one
        // block short of the next position (the failed append): swap-in
        // must cover `total_tokens`, not just the spilled span.
        let mut bm = BlockManager::new(4, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4, 5, 6, 7, 8]).is_some()); // exactly 2 blocks
        bm.swap_out(1);
        assert!(bm.swap_in(1, 9)); // resume must write position 8
        assert_eq!(bm.table(1).unwrap().len(), 3, "one extra block past the spill");
        assert_eq!(bm.take_swap_ins()[0].1.len(), 2, "restore span is the spilled blocks only");
        bm.check_invariants().unwrap();
    }

    #[test]
    fn swap_in_waits_for_room() {
        let mut bm = BlockManager::new(2, 4);
        assert!(bm.allocate(1, &[1, 1, 1, 1, 2, 2, 2, 2]).is_some());
        bm.swap_out(1);
        assert!(bm.allocate(2, &[9, 9, 9, 9, 8, 8, 8, 8]).is_some()); // takes the whole pool
        assert!(!bm.can_swap_in(1, 8));
        assert!(!bm.swap_in(1, 8));
        assert!(bm.is_swapped(1), "failed swap-in must leave the spill record intact");
        bm.free_sequence(2);
        assert!(bm.swap_in(1, 8));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn freeing_a_swapped_sequence_retires_its_spill() {
        let mut bm = BlockManager::new(4, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4]).is_some());
        bm.swap_out(1);
        bm.take_released();
        bm.free_sequence(1); // finished/rejected while swapped out
        assert!(!bm.is_swapped(1));
        let (freed, seqs) = bm.take_released();
        assert!(freed.is_empty(), "no physical blocks were held");
        assert_eq!(seqs, vec![1], "the backend must be told to drop the spill");
        assert!(!bm.can_swap_in(1, 4));
        bm.check_invariants().unwrap();
    }

    #[test]
    fn swap_in_reusing_a_just_freed_block_leaves_the_freed_log() {
        // Swap-in inside the same drain window as a free (one engine
        // step): the reused block must leave the freed log, or the
        // end-of-step poison pass would clobber the restored K/V.
        let mut bm = BlockManager::new(1, 4);
        assert!(bm.allocate(1, &[1, 2, 3]).is_some());
        bm.swap_out(1);
        assert!(bm.swap_in(1, 3));
        let (freed, _) = bm.take_released();
        assert!(freed.is_empty(), "reused block must not be poisoned: {freed:?}");
        bm.check_invariants().unwrap();
    }

    #[test]
    fn swap_preserves_shared_prefix_references() {
        let mut bm = BlockManager::new(8, 4);
        let prompt: Vec<u32> = (0..8).collect();
        assert!(bm.allocate(1, &prompt).is_some());
        bm.mark_computed(1, 8);
        assert!(bm.allocate(2, &prompt).is_some()); // fully shared
        bm.take_released();
        bm.swap_out(2);
        // Seq 2's references were shared: nothing is physically freed,
        // and seq 1's table is untouched.
        let (freed, _) = bm.take_released();
        assert!(freed.is_empty(), "shared blocks must survive a peer's swap-out");
        assert_eq!(bm.table(1).unwrap().len(), 2);
        assert!(bm.swap_in(2, 8));
        assert_ne!(bm.table(1).unwrap(), bm.table(2).unwrap(), "restored table is private");
        bm.check_invariants().unwrap();
    }

    #[test]
    fn abort_swap_forgets_the_spill_reservation() {
        let mut bm = BlockManager::new(4, 4);
        assert!(bm.allocate(1, &[1, 2, 3, 4]).is_some());
        bm.swap_out(1);
        assert!(bm.abort_swap(1), "swapped seq must be abortable");
        assert!(!bm.is_swapped(1));
        assert!(!bm.can_swap_in(1, 4), "aborted swap cannot be restored");
        assert!(!bm.abort_swap(1), "abort is not repeatable");
        bm.take_swap_outs();
        bm.take_released();
        bm.assert_drained().unwrap();
    }

    #[test]
    fn assert_drained_catches_every_leak_class() {
        // Clean pool drains.
        let mut bm = BlockManager::new(4, 4);
        bm.assert_drained().unwrap();
        // A live table is a leak.
        assert!(bm.allocate(1, &[1, 2, 3]).is_some());
        assert!(bm.assert_drained().unwrap_err().contains("block tables"));
        // A spill reservation is a leak.
        bm.swap_out(1);
        bm.take_swap_outs();
        bm.take_released();
        assert!(bm.assert_drained().unwrap_err().contains("spill reservations"));
        // An unforwarded log is a leak.
        assert!(bm.swap_in(1, 3));
        bm.free_sequence(1);
        assert!(bm.assert_drained().unwrap_err().contains("undrained"));
        bm.take_swap_ins();
        bm.take_released();
        bm.assert_drained().unwrap();
    }

    #[test]
    fn export_import_roundtrips_exact_state() {
        let mut bm = BlockManager::new(8, 4);
        let prompt: Vec<u32> = (0..8).collect();
        assert!(bm.allocate(1, &prompt).is_some());
        bm.mark_computed(1, 8);
        assert!(bm.allocate(2, &prompt).is_some()); // shared, prefix hits
        assert!(bm.allocate(3, &[9, 9, 9]).is_some());
        bm.swap_out(3);
        bm.take_swap_outs();
        bm.take_released();
        let state = bm.export_state().unwrap();
        let restored = BlockManager::import_state(state.clone()).unwrap();
        // The restored allocator exports the identical state (free-list
        // order included — block placement must replay bit-identically).
        assert_eq!(restored.export_state().unwrap(), state);
        assert_eq!(restored.free_list(), bm.free_list());
        assert_eq!(restored.table(1), bm.table(1));
        assert!(restored.is_swapped(3));
        assert_eq!(restored.prefix_hits, bm.prefix_hits);
        restored.check_invariants().unwrap();
    }

    #[test]
    fn export_refuses_undrained_logs() {
        let mut bm = BlockManager::new(4, 4);
        assert!(bm.allocate(1, &[1, 2, 3]).is_some());
        bm.free_sequence(1);
        let err = bm.export_state().unwrap_err();
        assert!(err.contains("undrained"), "{err}");
        bm.take_released();
        bm.export_state().unwrap();
    }

    #[test]
    fn import_rejects_corrupt_state() {
        let mut bm = BlockManager::new(4, 4);
        assert!(bm.allocate(1, &[1, 2, 3]).is_some());
        let good = bm.export_state().unwrap();
        // Refcount tampered: table refs no longer match.
        let mut bad = good.clone();
        bad.blocks[bm.table(1).unwrap()[0]].0 += 1;
        assert!(BlockManager::import_state(bad).is_err());
        // Free-list entry pointing at a held block.
        let mut bad = good.clone();
        bad.free.push(bm.table(1).unwrap()[0]);
        assert!(BlockManager::import_state(bad).is_err());
        // Degenerate geometry.
        let mut bad = good;
        bad.blocks.clear();
        assert!(BlockManager::import_state(bad).is_err());
    }

    #[test]
    fn mark_computed_ignores_partial_tail() {
        let mut bm = BlockManager::new(16, 4);
        let prompt: Vec<u32> = (0..6).collect(); // 1 full + 1 partial
        assert_eq!(bm.allocate(1, &prompt), Some(0));
        bm.mark_computed(1, 6); // tail block only half-covered
        assert_eq!(bm.allocate(2, &prompt), Some(4), "partial tail can never be cached");
        bm.check_invariants().unwrap();
    }
}

//! Serving metrics: the quantities Figures 2–3 report, plus the
//! per-request SLO quantities (TTFT/TPOT/queue time) of trace-driven
//! serving.

/// p50/p95/p99 of one metric's per-request samples, computed with a
/// single sort.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Quantiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Quantiles {
    /// All-zero for an empty sample set; a single sample pins all three.
    pub fn compute(samples: &[f64]) -> Quantiles {
        if samples.is_empty() {
            return Quantiles::default();
        }
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Quantiles {
            p50: crate::benchkit::percentile(&xs, 0.50),
            p95: crate::benchkit::percentile(&xs, 0.95),
            p99: crate::benchkit::percentile(&xs, 0.99),
        }
    }
}

/// Aggregated over one engine run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    /// Virtual (SimBackend) or wall (PjrtBackend) seconds elapsed.
    pub elapsed: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub engine_steps: usize,
    pub prefill_steps: usize,
    pub decode_steps: usize,
    pub preemptions: usize,
    /// Prefill chunk executions (a one-shot prefill counts as one chunk).
    pub prefill_chunks: usize,
    /// Prompt tokens never sent to the backend: their K/V already lived
    /// in fully-computed shared prefix blocks (prefix-aware prefill).
    /// Counted per *admission* — a preempted sequence that re-prefills
    /// and skips again counts again, exactly like the recompute work a
    /// preemption duplicates — so under heavy preemption this can
    /// legitimately exceed `prompt_tokens`.
    pub prefill_tokens_skipped: usize,
    /// Sum of decode batch sizes (for mean batch occupancy).
    pub decode_batch_sum: usize,
    /// Per-request end-to-end latencies, seconds.
    pub latencies: Vec<f64>,
    /// Per-request time-to-first-token, seconds.
    pub ttfts: Vec<f64>,
    /// Per-request queue time (arrival → first admission), seconds.
    pub queue_times: Vec<f64>,
    /// Per-request mean time-per-output-token after the first, seconds
    /// (requests generating a single token contribute no sample).
    pub tpots: Vec<f64>,
    /// Preemptions that spilled K/V to the host pool instead of
    /// discarding it (a subset of `preemptions`).
    pub swap_outs: usize,
    /// Swapped victims resumed by restoring their spill.
    pub swap_ins: usize,
    /// Tokens restored from spill rather than recomputed.
    pub swap_restored_tokens: usize,
    /// Total **packed** bytes moved by swap-outs over the run (the
    /// spill-traffic volume — shrinks with [`super::KvDtype`]).
    pub swap_spilled_bytes: usize,
    /// Bytes the paged K/V pool holds (both sides, all layers,
    /// dtype-aware; 0 when the backend has no KV accounting).
    pub kv_pool_bytes: usize,
    /// Bytes one resident token costs across both sides and all layers.
    pub kv_bytes_per_token: usize,
    /// High-water mark of the host-side spill pool.
    pub kv_spill_peak_bytes: usize,
    /// Requests shed from the bounded waiting queue (a subset of
    /// `rejected_requests`).
    pub shed_requests: usize,
    /// Requests resolved as [`super::RequestOutcome::Rejected`]
    /// (oversized, provably never admittable, or shed).
    pub rejected_requests: usize,
    /// Requests cancelled past their deadline
    /// ([`super::RequestOutcome::TimedOut`]).
    pub timed_out_requests: usize,
    /// Requests cooperatively cancelled through `Engine::cancel`
    /// ([`super::RequestOutcome::Cancelled`]).
    pub cancelled_requests: usize,
    /// Requests resolved as [`super::RequestOutcome::Failed`] by a
    /// permanent (or retry-exhausted) backend error.
    pub failed_requests: usize,
    /// Engine steps discarded and re-driven after a transient backend
    /// error (each bumps the retry backoff).
    pub step_retries: usize,
    /// Swap spill writes/restores that failed and were recovered by
    /// demoting the victim to recompute.
    pub spill_faults: usize,
    /// Snapshots committed (atomic rename completed) over the run.
    pub checkpoints_written: usize,
    /// In-flight requests rehydrated from a snapshot by `Engine::restore`
    /// (pending + waiting + prefilling + running + swapped; completed
    /// requests are carried over but not counted here).
    pub restored_requests: usize,
    /// Output tokens delivered by *completed* requests only — tokens
    /// generated for requests that later timed out, failed or were
    /// preempt-discarded never count.  `output_tokens` is raw
    /// throughput; this is goodput.
    pub goodput_tokens: usize,
}

impl Metrics {
    /// Generation throughput, tokens/s (the paper's Figure 2 metric).
    pub fn throughput(&self) -> f64 {
        if self.elapsed <= 0.0 {
            return 0.0;
        }
        self.output_tokens as f64 / self.elapsed
    }

    /// Goodput, tokens/s: only tokens delivered by requests that
    /// actually completed.  Equals [`Metrics::throughput`] on a
    /// fault-free run with no deadlines; diverges exactly by the work
    /// wasted on timed-out/failed/shed requests and discarded retries.
    pub fn goodput(&self) -> f64 {
        if self.elapsed <= 0.0 {
            return 0.0;
        }
        self.goodput_tokens as f64 / self.elapsed
    }

    /// Total throughput including prompt processing (vLLM also reports
    /// this as "total tokens/s").
    pub fn total_throughput(&self) -> f64 {
        if self.elapsed <= 0.0 {
            return 0.0;
        }
        (self.prompt_tokens + self.output_tokens) as f64 / self.elapsed
    }

    /// Mean end-to-end request latency, seconds (the Figure 3 metric).
    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        self.latencies.iter().sum::<f64>() / self.latencies.len() as f64
    }

    pub fn p95_latency(&self) -> f64 {
        self.latency_quantiles().p95
    }

    /// p50/p95/p99 end-to-end latency (one sort for all three).
    pub fn latency_quantiles(&self) -> Quantiles {
        Quantiles::compute(&self.latencies)
    }

    /// p50/p95/p99 time-to-first-token.
    pub fn ttft_quantiles(&self) -> Quantiles {
        Quantiles::compute(&self.ttfts)
    }

    /// p50/p95/p99 time-per-output-token.
    pub fn tpot_quantiles(&self) -> Quantiles {
        Quantiles::compute(&self.tpots)
    }

    /// p50/p95/p99 queue time (arrival → first admission).
    pub fn queue_time_quantiles(&self) -> Quantiles {
        Quantiles::compute(&self.queue_times)
    }

    pub fn mean_ttft(&self) -> f64 {
        if self.ttfts.is_empty() {
            return 0.0;
        }
        self.ttfts.iter().sum::<f64>() / self.ttfts.len() as f64
    }

    pub fn mean_tpot(&self) -> f64 {
        if self.tpots.is_empty() {
            return 0.0;
        }
        self.tpots.iter().sum::<f64>() / self.tpots.len() as f64
    }

    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_batch_sum as f64 / self.decode_steps as f64
    }

    /// Fraction of prompt tokens served straight from the prefix cache
    /// (skipped, never recomputed) — the prefix hit rate of this run.
    /// Clamped to 1.0: preemption re-admissions skip (and count) the
    /// same prompt tokens again while `prompt_tokens` counts them once.
    pub fn prefix_skip_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        (self.prefill_tokens_skipped as f64 / self.prompt_tokens as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics { elapsed: 2.0, output_tokens: 100, prompt_tokens: 60, ..Default::default() };
        assert_eq!(m.throughput(), 50.0);
        assert_eq!(m.total_throughput(), 80.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.p95_latency(), 0.0);
    }

    #[test]
    fn goodput_math() {
        let m = Metrics {
            elapsed: 2.0,
            output_tokens: 100,
            goodput_tokens: 80,
            ..Default::default()
        };
        assert_eq!(m.throughput(), 50.0);
        assert_eq!(m.goodput(), 40.0);
        assert_eq!(Metrics::default().goodput(), 0.0);
    }

    #[test]
    fn prefix_skip_rate_math() {
        let m = Metrics { prompt_tokens: 80, prefill_tokens_skipped: 20, ..Default::default() };
        assert_eq!(m.prefix_skip_rate(), 0.25);
        assert_eq!(Metrics::default().prefix_skip_rate(), 0.0);
    }

    #[test]
    fn latency_stats() {
        let m = Metrics {
            latencies: vec![1.0, 2.0, 3.0],
            ..Default::default()
        };
        assert!((m.mean_latency() - 2.0).abs() < 1e-12);
        assert!(m.p95_latency() >= 2.0);
    }

    #[test]
    fn quantiles_of_empty_are_zero() {
        assert_eq!(Quantiles::compute(&[]), Quantiles::default());
        let m = Metrics::default();
        assert_eq!(m.ttft_quantiles(), Quantiles::default());
        assert_eq!(m.tpot_quantiles(), Quantiles::default());
        assert_eq!(m.queue_time_quantiles(), Quantiles::default());
        assert_eq!(m.mean_tpot(), 0.0);
    }

    #[test]
    fn quantiles_of_single_sample_pin_all_three() {
        let q = Quantiles::compute(&[4.5]);
        assert_eq!(q, Quantiles { p50: 4.5, p95: 4.5, p99: 4.5 });
    }

    #[test]
    fn quantiles_of_tied_samples_are_the_tie() {
        let q = Quantiles::compute(&[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(q, Quantiles { p50: 2.0, p95: 2.0, p99: 2.0 });
    }

    #[test]
    fn quantiles_are_ordered_and_sort_input() {
        // Deliberately unsorted input: compute() must sort internally.
        let q = Quantiles::compute(&[9.0, 1.0, 5.0, 3.0, 7.0, 2.0, 8.0, 4.0, 6.0, 10.0]);
        assert!(q.p50 <= q.p95 && q.p95 <= q.p99);
        assert!(q.p50 >= 5.0 && q.p50 <= 6.0, "p50 {}", q.p50);
        assert!(q.p99 <= 10.0);
    }
}

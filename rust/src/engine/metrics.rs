//! Serving metrics: the quantities Figures 2–3 report.

/// Aggregated over one engine run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Virtual (SimBackend) or wall (PjrtBackend) seconds elapsed.
    pub elapsed: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub engine_steps: usize,
    pub prefill_steps: usize,
    pub decode_steps: usize,
    pub preemptions: usize,
    /// Prefill chunk executions (a one-shot prefill counts as one chunk).
    pub prefill_chunks: usize,
    /// Prompt tokens never sent to the backend: their K/V already lived
    /// in fully-computed shared prefix blocks (prefix-aware prefill).
    /// Counted per *admission* — a preempted sequence that re-prefills
    /// and skips again counts again, exactly like the recompute work a
    /// preemption duplicates — so under heavy preemption this can
    /// legitimately exceed `prompt_tokens`.
    pub prefill_tokens_skipped: usize,
    /// Sum of decode batch sizes (for mean batch occupancy).
    pub decode_batch_sum: usize,
    /// Per-request end-to-end latencies, seconds.
    pub latencies: Vec<f64>,
    /// Per-request time-to-first-token, seconds.
    pub ttfts: Vec<f64>,
}

impl Metrics {
    /// Generation throughput, tokens/s (the paper's Figure 2 metric).
    pub fn throughput(&self) -> f64 {
        if self.elapsed <= 0.0 {
            return 0.0;
        }
        self.output_tokens as f64 / self.elapsed
    }

    /// Total throughput including prompt processing (vLLM also reports
    /// this as "total tokens/s").
    pub fn total_throughput(&self) -> f64 {
        if self.elapsed <= 0.0 {
            return 0.0;
        }
        (self.prompt_tokens + self.output_tokens) as f64 / self.elapsed
    }

    /// Mean end-to-end request latency, seconds (the Figure 3 metric).
    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        self.latencies.iter().sum::<f64>() / self.latencies.len() as f64
    }

    pub fn p95_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut xs = self.latencies.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::benchkit::percentile(&xs, 0.95)
    }

    pub fn mean_ttft(&self) -> f64 {
        if self.ttfts.is_empty() {
            return 0.0;
        }
        self.ttfts.iter().sum::<f64>() / self.ttfts.len() as f64
    }

    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_batch_sum as f64 / self.decode_steps as f64
    }

    /// Fraction of prompt tokens served straight from the prefix cache
    /// (skipped, never recomputed) — the prefix hit rate of this run.
    /// Clamped to 1.0: preemption re-admissions skip (and count) the
    /// same prompt tokens again while `prompt_tokens` counts them once.
    pub fn prefix_skip_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        (self.prefill_tokens_skipped as f64 / self.prompt_tokens as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics { elapsed: 2.0, output_tokens: 100, prompt_tokens: 60, ..Default::default() };
        assert_eq!(m.throughput(), 50.0);
        assert_eq!(m.total_throughput(), 80.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.p95_latency(), 0.0);
    }

    #[test]
    fn prefix_skip_rate_math() {
        let m = Metrics { prompt_tokens: 80, prefill_tokens_skipped: 20, ..Default::default() };
        assert_eq!(m.prefix_skip_rate(), 0.25);
        assert_eq!(Metrics::default().prefix_skip_rate(), 0.0);
    }

    #[test]
    fn latency_stats() {
        let m = Metrics {
            latencies: vec![1.0, 2.0, 3.0],
            ..Default::default()
        };
        assert!((m.mean_latency() - 2.0).abs() < 1e-12);
        assert!(m.p95_latency() >= 2.0);
    }
}

//! In-crate executable backend: a real tiny quantized transformer run
//! entirely through the fused CPU kernels over physically-paged K/V.
//!
//! Unlike [`super::backend::SimBackend`] (virtual clock, synthesized
//! logits) and the PJRT path (external AOT artifacts), [`CpuBackend`]
//! executes genuine math end-to-end with no artifacts and no external
//! crates: embeddings → `n_layers` pre-norm blocks (causal attention
//! over a **paged** KV cache + SiLU-gated MLP) → quantized lm_head.
//! Every projection is a 4-bit GPTQ tensor evaluated through
//! [`crate::gptq::fused`] — decode steps exercise the `M = batch` fused
//! GEMM path, prefills the `M = prompt_len` path, and the per-layer
//! output projection carries a real act-order (`b_q_perm`) checkpoint so
//! the gather branch runs on every token.  Every weight is held as a
//! [`PreparedTensor`]: the vector-friendly swizzled prepack the
//! runtime-dispatched kernel (scalar, AVX2 or AVX-512) wants — at the
//! lane width the resolved dispatch streams — is computed once at model
//! build, never on the serve path.
//!
//! The architecture comes from the unified
//! [`crate::models::ModelConfig`] registry (`serve --model`,
//! `OPT4GPTQ_MODEL`): **grouped-query attention** when `n_kv_heads <
//! n_heads` (the K/V projections and the paged pool are `kv_dim =
//! n_kv_heads · d_head` wide; Q head `h` reads KV head `h /
//! gqa_ratio` during the tile walk, at every [`KvDtype`]) and
//! **rotary position embeddings** when `cfg.rope` (applied at append
//! time: K rows are rotated by their absolute position *before*
//! `kv.write`, so the cache stores pre-rotated keys and a Q copy is
//! rotated per pass — a pure function of `(position, values)`, which
//! keeps chunked prefill, prefix skip and swap replay bit-identical).
//! With `n_kv_heads == n_heads` and RoPE off the code runs the exact
//! pre-registry FP operation sequence (learned additive positions,
//! full-width K/V rows), so every golden recorded against the old
//! `tiny-mha` model stays valid bit for bit.
//!
//! KV layout: a [`PagedKvCache`] pool `[n_blocks × n_layers × block_size
//! × kv_dim]` per cache side — dtype-parameterized ([`KvDtype`]: f32,
//! f16, or 4-bit `kv4`), addressed exclusively through the block tables
//! the engine hands down in [`PrefillDesc`]/[`DecodeDesc`] — the same
//! tables [`super::block_manager::BlockManager`] allocates, so a
//! prefix-cache hit aliases real (packed) memory here and attention
//! walks the table block-by-block: each (block, layer) tile is
//! dequantized **once per pass** into a reused scratch tile (the
//! SMB-Opt pattern applied to the cache; the f32 pool borrows the tile
//! zero-copy), then every head reads from the scratch.  The
//! per-sequence block walks of a batch are independent, so the batch is
//! split across **scoped threads** (the same machinery as the fused
//! GEMM column split, worker count from the shared `hw_threads`
//! resolution) in contiguous row ranges — bit-identical to the serial
//! walk because no row's arithmetic changes, engaged only past a work
//! floor so tiny batches stay spawn-free.  Blocks the allocator retires
//! come back through [`Backend::release_blocks`]; debug builds poison
//! them — NaN fill for f32/f16, the reserved NaN scale pattern for kv4
//! — so a read through a stale table fails parity tests loudly at every
//! dtype.
//!
//! The engine's scheduler/block-manager/sampler stack drives this backend
//! exactly as it drives the simulated one; `rust/tests/backend_integration.rs`
//! pins the cross-backend behaviour (determinism, preemption survival,
//! exact token accounting, physical prefix sharing) and the KV-cache
//! consistency of prefill-vs-decode.

use std::time::Instant;

use anyhow::bail;

use crate::gptq::{
    gemm_fused_prepared, quantize_gptq, quantize_rtn, GptqConfig, Matrix, PreparedTensor,
};
use crate::rng::Rng;
use crate::Result;

use super::backend::{Backend, DecodeDesc, KvStats, PrefillDesc, StepError, StepOutput};
use super::block_manager::BlockId;
use super::kv::{KvDtype, KvSpill, PagedKvCache};

/// Block size used when the backend is driven directly (tests, examples)
/// before/without an engine calling [`Backend::bind_kv`].
pub const DEFAULT_BLOCK_SIZE: usize = 16;

/// The executable model configuration is the unified registry type —
/// the historical name is kept as an alias so backend-centric call
/// sites keep reading naturally (`CpuModelConfig::default()` is
/// `models::default_model()`, i.e. `tiny-mha` unless `OPT4GPTQ_MODEL`
/// says otherwise).
pub type CpuModelConfig = crate::models::ModelConfig;

/// One transformer block's quantized projections.  Each is a
/// [`PreparedTensor`]: the vector-friendly swizzled prepack the active
/// kernel wants is computed **here, once, at model build** — serve-path
/// projections never re-swizzle.
struct LayerWeights {
    wq: PreparedTensor,
    wk: PreparedTensor,
    wv: PreparedTensor,
    /// Output projection — quantized with `act_order: true`, so this
    /// tensor ships a real `b_q_perm` and every forward pass exercises
    /// the fused kernel's gather branch.
    wo: PreparedTensor,
    w_gate: PreparedTensor,
    w_up: PreparedTensor,
    w_down: PreparedTensor,
}

/// One sequence's span of work inside a forward pass: `tokens[i]` lands
/// at position `start + i` of the table-addressed cache.
struct SeqSpan<'a> {
    table: &'a [BlockId],
    start: usize,
    tokens: &'a [u32],
}

/// Fused-kernel CPU execution backend (see module docs).
pub struct CpuBackend {
    pub cfg: CpuModelConfig,
    embed: Matrix,
    pos: Matrix,
    layers: Vec<LayerWeights>,
    lm_head: PreparedTensor,
    kv: PagedKvCache,
    /// Host-side spill pool: per swapped-out sequence, its blocks'
    /// **packed** K/V copied out of the paged pool (the "CPU swap space"
    /// of vLLM-style preemption-by-swap) — spill volume shrinks with the
    /// KV dtype.
    spill: std::collections::HashMap<usize, KvSpill>,
    spill_bytes: usize,
    spill_peak_bytes: usize,
    /// One-shot injected fault ([`Backend::inject_fault`]): the next
    /// forward pass NaN-poisons its first query tile mid-layer, so the
    /// corruption must be caught by this backend's own output
    /// validation, not by any engine seam check.
    poison_armed: bool,
    /// Test hook: pin the attention block-walk worker count (bypassing
    /// the `attention_workers` heuristic) so parallel-vs-serial bitwise
    /// tests can force both paths deterministically.
    att_workers_override: Option<usize>,
}

fn quantized(rng: &mut Rng, k: usize, n: usize, g: usize, std: f32) -> PreparedTensor {
    let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, std));
    PreparedTensor::new(quantize_rtn(&w, g))
}

impl CpuBackend {
    pub fn new(cfg: CpuModelConfig) -> Result<CpuBackend> {
        // Registry-wide kernel constraints first (d_model % n_heads,
        // n_heads % n_kv_heads, group divisibility, even RoPE d_head)…
        if let Err(e) = cfg.validate() {
            bail!("model config {:?}: {e}", cfg.name);
        }
        // …then the executable-path extras the packed layout needs.
        for (name, dim) in [
            ("vocab", cfg.vocab),
            ("d_model", cfg.d_model),
            ("d_ff", cfg.d_ff),
            ("kv_dim", cfg.kv_dim()),
        ] {
            if dim == 0 || dim % 8 != 0 {
                bail!("{name} = {dim} must be a non-zero multiple of 8 (packed layout)");
            }
        }
        if cfg.group_size % 8 != 0 {
            bail!(
                "group size {} must be a multiple of 8 dividing d_model {} and d_ff {}",
                cfg.group_size,
                cfg.d_model,
                cfg.d_ff
            );
        }
        if cfg.max_batch == 0 || cfg.max_seq < 2 || cfg.n_layers == 0 {
            bail!("max_batch/max_seq/n_layers must be positive (max_seq >= 2)");
        }

        let mut rng = Rng::new(cfg.seed);
        let d = cfg.d_model;
        let kv_dim = cfg.kv_dim();
        let proj_std = 1.0 / (d as f32).sqrt();
        let embed = Matrix::from_vec(cfg.vocab, d, rng.normal_vec_f32(cfg.vocab * d, 0.5));
        // The learned-position table is always drawn — keeping the RNG
        // stream identical whether RoPE is on or off — but only *added*
        // when `!cfg.rope` (see `forward`).
        let pos = Matrix::from_vec(cfg.max_seq, d, rng.normal_vec_f32(cfg.max_seq * d, 0.1));

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            // Act-order checkpoint for the output projection: quantize
            // against correlated calibration activations so desc_act has
            // a real Hessian-diagonal ordering to follow.
            let wo_dense = Matrix::from_vec(d, d, rng.normal_vec_f32(d * d, proj_std));
            let calib = Matrix::from_vec(64, d, rng.normal_vec_f32(64 * d, 1.0));
            let wo = PreparedTensor::new(quantize_gptq(
                wo_dense,
                &calib,
                GptqConfig { group_size: cfg.group_size, percdamp: 0.01, act_order: true },
            ));
            layers.push(LayerWeights {
                wq: quantized(&mut rng, d, d, cfg.group_size, proj_std),
                // K/V project to kv_dim: `n_kv_heads · d_head` — full
                // width for MHA (identical RNG draws to the
                // pre-registry model), narrower under GQA.
                wk: quantized(&mut rng, d, kv_dim, cfg.group_size, proj_std),
                wv: quantized(&mut rng, d, kv_dim, cfg.group_size, proj_std),
                wo,
                w_gate: quantized(&mut rng, d, cfg.d_ff, cfg.group_size, proj_std),
                w_up: quantized(&mut rng, d, cfg.d_ff, cfg.group_size, proj_std),
                w_down: quantized(
                    &mut rng,
                    cfg.d_ff,
                    d,
                    cfg.group_size,
                    1.0 / (cfg.d_ff as f32).sqrt(),
                ),
            });
        }
        let lm_head = quantized(&mut rng, d, cfg.vocab, cfg.group_size, proj_std);

        Ok(CpuBackend {
            cfg,
            embed,
            pos,
            layers,
            lm_head,
            // Empty pool; grown by bind_kv or on demand (direct use).
            // Directly-driven backends (tests, benches) honor the
            // OPT4GPTQ_KV default so the CI dtype matrix reaches them;
            // an engine's bind_kv re-pools with its configured dtype.
            // Row width is kv_dim — the GQA pool shrink.
            kv: PagedKvCache::with_dtype(
                0,
                DEFAULT_BLOCK_SIZE,
                cfg.n_layers,
                kv_dim,
                super::kv_dtype_default(),
            ),
            spill: std::collections::HashMap::new(),
            spill_bytes: 0,
            spill_peak_bytes: 0,
            poison_armed: false,
            att_workers_override: None,
        })
    }

    /// Read-only view of the paged K/V pool (tests inspect physical
    /// sharing through this).
    pub fn kv(&self) -> &PagedKvCache {
        &self.kv
    }

    /// Pin the attention block-walk worker count (tests only): `Some(1)`
    /// forces the serial walk, `Some(n)` forces an `n`-way row split
    /// regardless of the work-floor heuristic.
    pub fn set_att_workers(&mut self, workers: Option<usize>) {
        self.att_workers_override = workers;
    }

    /// Check a span's tokens and table before any math runs.
    fn validate_span(&self, span: &SeqSpan<'_>) -> Result<()> {
        let cfg = &self.cfg;
        let bs = self.kv.block_size();
        let end = span.start + span.tokens.len();
        if end > cfg.max_seq {
            bail!("positions {}..{} exceed max_seq {}", span.start, end, cfg.max_seq);
        }
        if end.div_ceil(bs) > span.table.len() {
            bail!(
                "block table of {} blocks (x{bs} tokens) cannot address position {}",
                span.table.len(),
                end - 1
            );
        }
        // Blocks holding already-materialized context will be *read* by
        // attention and must exist in the pool; blocks that are only
        // written may still grow it (direct-use auto-sizing).  A context
        // id past the pool means a corrupt table, not a growth request.
        let context_blocks = span.start.div_ceil(bs).min(span.table.len());
        for &blk in &span.table[..context_blocks] {
            if blk >= self.kv.n_blocks() {
                bail!(
                    "context block {blk} outside the {}-block pool (corrupt table?)",
                    self.kv.n_blocks()
                );
            }
        }
        for &tok in span.tokens {
            if tok as usize >= cfg.vocab {
                bail!("token {tok} outside vocab {}", cfg.vocab);
            }
        }
        Ok(())
    }

    /// Run every span's tokens through all layers in one batch, writing
    /// each token's K/V through its span's block table and attending
    /// causally over the span's `0..=position` prefix.  Returns the
    /// final-norm hidden states, one row per token (spans concatenated
    /// in order).
    fn forward(&mut self, spans: &[SeqSpan<'_>]) -> Result<Matrix> {
        let cfg = self.cfg;
        let d = cfg.d_model;
        for span in spans {
            self.validate_span(span)?;
        }
        // Flattened (span, position, token) rows.
        let rows: Vec<(usize, usize, u32)> = spans
            .iter()
            .enumerate()
            .flat_map(|(si, s)| {
                s.tokens.iter().enumerate().map(move |(i, &tok)| (si, s.start + i, tok))
            })
            .collect();
        let t = rows.len();

        let mut h = Matrix::zeros(t, d);
        for (i, &(_, pos, tok)) in rows.iter().enumerate() {
            let row = &mut h.data[i * d..(i + 1) * d];
            row.copy_from_slice(self.embed.row(tok as usize));
            if !cfg.rope {
                // Learned additive positions (the pre-registry model);
                // under RoPE position enters through the Q/K rotation
                // instead, so the embedding is position-free.
                for (c, hv) in row.iter_mut().enumerate() {
                    *hv += self.pos.at(pos, c);
                }
            }
        }

        let poison = std::mem::take(&mut self.poison_armed);
        // Batch-parallel attention: split the independent per-sequence
        // block walks across scoped threads once the batch is wide
        // enough and the score work passes the floor (score elements ~
        // sum of context lengths × d_model).
        let att_work: usize = rows.iter().map(|&(_, pos, _)| pos + 1).sum::<usize>() * d;
        let workers =
            self.att_workers_override.unwrap_or_else(|| attention_workers(t, att_work));

        for li in 0..cfg.n_layers {
            // ---- attention ----
            let a = rmsnorm_rows(&h);
            let (mut qm, mut km, vm) = {
                let lw = &self.layers[li];
                (
                    gemm_fused_prepared(&a, &lw.wq),
                    gemm_fused_prepared(&a, &lw.wk),
                    gemm_fused_prepared(&a, &lw.wv),
                )
            };
            if poison && li == 0 {
                // Injected mid-layer fault: corrupt the first query tile
                // *between* the QKV projection and attention.  The NaNs
                // ride the residual stream into the logits, where the
                // finite check in `step` fails the batch loudly — and
                // because only an activation (never the K/V pool) is
                // poisoned, the cache stays clean and the post-drain
                // audit passes after the failure is reclaimed.  Applied
                // before the RoPE rotation (NaN survives rotation), so
                // the fault fires identically with RoPE on.
                let tile = &mut qm.data[..d];
                tile.fill(f32::NAN);
            }
            if cfg.rope {
                // Rotate at append time: K rows by their absolute
                // position *before* kv.write (the cache stores
                // pre-rotated keys — a pure function of (position,
                // values), so chunked prefill, prefix skip and swap
                // replay stay bit-identical), and the Q rows in place
                // for this pass's score walk.
                let kvd = cfg.kv_dim();
                let hd = cfg.d_head();
                for (i, &(_, pos, _)) in rows.iter().enumerate() {
                    rope_rotate_row(&mut km.data[i * kvd..(i + 1) * kvd], hd, pos);
                    rope_rotate_row(&mut qm.data[i * d..(i + 1) * d], hd, pos);
                }
            }
            for (i, &(si, pos, _)) in rows.iter().enumerate() {
                self.kv.write(spans[si].table, pos, li, km.row(i), vm.row(i));
            }
            let mut att = Matrix::zeros(t, d);
            attend_batch(&cfg, &self.kv, spans, &rows, &qm, li, &mut att, workers);
            let o = gemm_fused_prepared(&att, &self.layers[li].wo);
            add_assign(&mut h, &o);

            // ---- MLP ----
            let m = rmsnorm_rows(&h);
            let lw = &self.layers[li];
            let mut ff = gemm_fused_prepared(&m, &lw.w_gate);
            let up = gemm_fused_prepared(&m, &lw.w_up);
            for (f, &u) in ff.data.iter_mut().zip(&up.data) {
                *f = silu(*f) * u;
            }
            let down = gemm_fused_prepared(&ff, &lw.w_down);
            add_assign(&mut h, &down);
        }
        Ok(rmsnorm_rows(&h))
    }
}

impl Backend for CpuBackend {
    fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    fn max_seq_len(&self) -> usize {
        self.cfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn bind_kv(&mut self, total_blocks: usize, block_size: usize, dtype: KvDtype) {
        self.kv = PagedKvCache::with_dtype(
            total_blocks,
            block_size,
            self.cfg.n_layers,
            self.cfg.kv_dim(),
            dtype,
        );
        self.spill.clear();
        self.spill_bytes = 0;
        self.spill_peak_bytes = 0;
    }

    fn step(
        &mut self,
        prefills: &[PrefillDesc<'_>],
        decodes: &[DecodeDesc<'_>],
    ) -> Result<StepOutput, StepError> {
        let t0 = Instant::now();
        if prefills.is_empty() && decodes.is_empty() {
            return Err(StepError::Permanent("empty backend step".into()));
        }
        for p in prefills {
            if p.tokens.is_empty() {
                return Err(StepError::Permanent("cannot prefill an empty chunk".into()));
            }
        }
        // One forward pass over everything: prefill chunks (each starting
        // at its `start` position — cached-prefix tokens never appear)
        // followed by the decode rows.  The fed decode token's K/V entry
        // lands at `context_len`, one past the materialized context.
        let fed: Vec<[u32; 1]> = decodes.iter().map(|e| [e.token]).collect();
        let mut spans: Vec<SeqSpan<'_>> = Vec::with_capacity(prefills.len() + decodes.len());
        for p in prefills {
            spans.push(SeqSpan { table: p.block_table, start: p.start, tokens: p.tokens });
        }
        for (e, tok) in decodes.iter().zip(&fed) {
            spans.push(SeqSpan { table: e.block_table, start: e.context_len, tokens: tok });
        }
        // Validation/shape failures are non-retryable by construction —
        // the same descriptors would fail again (forward fails *before*
        // writing any K/V, so a Permanent step never half-mutates the
        // pool).
        let hidden = self.forward(&spans).map_err(|e| StepError::Permanent(e.to_string()))?;

        // lm_head only for rows that produce logits: the last token of
        // every final chunk plus every decode row — batched into one
        // fused GEMM (mid-prompt chunks skip the head entirely).
        let mut head_rows: Vec<usize> = Vec::new();
        let mut off = 0;
        let mut last_row: Vec<Option<usize>> = Vec::with_capacity(prefills.len());
        for p in prefills {
            last_row.push(p.is_last.then(|| head_rows.len()));
            if p.is_last {
                head_rows.push(off + p.tokens.len() - 1);
            }
            off += p.tokens.len();
        }
        let decode_row0 = head_rows.len();
        for i in 0..decodes.len() {
            head_rows.push(off + i);
        }
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        let logits = if head_rows.is_empty() {
            Matrix::zeros(0, v)
        } else if prefills.is_empty() {
            // Pure decode: the head rows are exactly the hidden rows in
            // order — run the lm_head on `hidden` directly, no gather
            // copy on the steady-state decode path.
            gemm_fused_prepared(&hidden, &self.lm_head)
        } else {
            let mut gathered = Matrix::zeros(head_rows.len(), d);
            for (ri, &hr) in head_rows.iter().enumerate() {
                gathered.data[ri * d..(ri + 1) * d].copy_from_slice(hidden.row(hr));
            }
            gemm_fused_prepared(&gathered, &self.lm_head)
        };
        // Output validation: real math over healthy weights and K/V is
        // always finite here, so any NaN/inf in the head means corrupted
        // state upstream — an injected mid-layer poison, or a stale
        // table reading a released (debug-poisoned) block.  Fail the
        // batch loudly rather than sample garbage tokens.
        if logits.data.iter().any(|x| !x.is_finite()) {
            return Err(StepError::Permanent(
                "non-finite logits: corrupted activation or K/V reached the lm_head".into(),
            ));
        }
        let prefill_logits = last_row
            .into_iter()
            .map(|r| r.map(|ri| logits.data[ri * v..(ri + 1) * v].to_vec()))
            .collect();
        let decode_logits = (0..decodes.len())
            .map(|i| {
                let ri = decode_row0 + i;
                logits.data[ri * v..(ri + 1) * v].to_vec()
            })
            .collect();
        Ok(StepOutput { prefill_logits, decode_logits, secs: t0.elapsed().as_secs_f64() })
    }

    fn release_blocks(&mut self, blocks: &[BlockId]) {
        // Returned memory: debug builds poison it (stale reads -> NaN).
        self.kv.release_blocks(blocks);
    }

    fn release_seq(&mut self, seq_id: usize) {
        // A sequence that finished (or was rejected) while swapped out
        // never swaps back in; drop its spill.
        self.drop_spill(seq_id);
    }

    fn drop_spill(&mut self, seq_id: usize) {
        if let Some(old) = self.spill.remove(&seq_id) {
            self.spill_bytes -= old.bytes();
        }
    }

    fn swap_out(&mut self, seq_id: usize, blocks: &[BlockId]) -> Result<usize, StepError> {
        // Runs before release_blocks poisons these ids (engine drain
        // order), so the copy reads intact K/V — still packed, so the
        // bytes moved shrink with the pool dtype.
        let spill = self.kv.spill_blocks(blocks);
        let bytes = spill.bytes();
        if let Some(old) = self.spill.insert(seq_id, spill) {
            self.spill_bytes -= old.bytes();
        }
        self.spill_bytes += bytes;
        self.spill_peak_bytes = self.spill_peak_bytes.max(self.spill_bytes);
        Ok(bytes)
    }

    fn swap_in(&mut self, seq_id: usize, blocks: &[BlockId]) -> Result<(), StepError> {
        let spill = self.spill.remove(&seq_id).ok_or_else(|| {
            StepError::Permanent(format!("swap_in for seq {seq_id} without a spill entry"))
        })?;
        self.spill_bytes -= spill.bytes();
        self.kv.restore_blocks(blocks, &spill);
        Ok(())
    }

    fn paged_kv(&self) -> Option<&PagedKvCache> {
        Some(&self.kv)
    }

    fn export_kv(&self, blocks: &[BlockId]) -> Option<KvSpill> {
        // Same packed path as swap-out, but non-consuming: the blocks
        // stay resident, the snapshot carries a copy.
        Some(self.kv.spill_blocks(blocks))
    }

    fn import_kv(&mut self, blocks: &[BlockId], payload: &KvSpill) {
        self.kv.restore_blocks(blocks, payload);
    }

    fn export_spill(&self, seq_id: usize) -> Option<KvSpill> {
        self.spill.get(&seq_id).cloned()
    }

    fn import_spill(&mut self, seq_id: usize, n_blocks: usize, payload: Option<KvSpill>) {
        let spill = payload.expect("CpuBackend snapshots always carry spill payloads");
        assert_eq!(spill.n_blocks(), n_blocks, "spill payload/block-count mismatch");
        let bytes = spill.bytes();
        if let Some(old) = self.spill.insert(seq_id, spill) {
            self.spill_bytes -= old.bytes();
        }
        self.spill_bytes += bytes;
        self.spill_peak_bytes = self.spill_peak_bytes.max(self.spill_bytes);
    }

    fn inject_fault(&mut self) {
        self.poison_armed = true;
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(KvStats {
            pool_bytes: self.kv.bytes(),
            bytes_per_token: self.kv.bytes_per_token(),
            spill_bytes: self.spill_bytes,
            spill_peak_bytes: self.spill_peak_bytes,
        })
    }
}

/// Row-wise RMSNorm (unit gain).
fn rmsnorm_rows(x: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (o, &v) in out.data[r * x.cols..(r + 1) * x.cols].iter_mut().zip(row) {
            *o = v * inv;
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn add_assign(a: &mut Matrix, b: &Matrix) {
    debug_assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// Rotate one `d_head`-wide chunk in place by RoPE angle(s) for
/// absolute position `pos` (half-split pairing: lane `i` rotates with
/// lane `i + d_head/2`, frequency `10000^(-2i/d_head)` — the
/// Llama/GPT-NeoX convention).
fn rope_rotate_head(chunk: &mut [f32], pos: usize) {
    let hd = chunk.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = 10000f32.powf(-((2 * i) as f32) / hd as f32);
        let theta = pos as f32 * freq;
        let (sin, cos) = theta.sin_cos();
        let a = chunk[i];
        let b = chunk[i + half];
        chunk[i] = a * cos - b * sin;
        chunk[i + half] = b * cos + a * sin;
    }
}

/// Apply [`rope_rotate_head`] to every `d_head`-wide head chunk of a
/// projected Q or K row (row length must be a multiple of `hd`).
fn rope_rotate_row(row: &mut [f32], hd: usize, pos: usize) {
    for chunk in row.chunks_exact_mut(hd) {
        rope_rotate_head(chunk, pos);
    }
}

/// Work floor (in score elements ≈ Σ context × d_model) below which the
/// attention block walk stays serial — thread spawn overhead dwarfs the
/// math for single decodes and short prompts.
const ATT_MIN_WORK: usize = 1 << 16;

/// Worker count for the batch-parallel attention walk: serial for
/// single-row batches or sub-floor work, otherwise the shared
/// `hw_threads` resolution capped by the row count.
fn attention_workers(rows: usize, score_elems: usize) -> usize {
    if rows < 2 || score_elems < ATT_MIN_WORK {
        1
    } else {
        crate::gptq::fused::hw_threads().min(rows)
    }
}

/// Run [`attend`] for every row of the batch, splitting the independent
/// per-sequence block walks across scoped threads in contiguous row
/// ranges (the same machinery as the fused GEMM column split).  Each
/// worker owns its output rows via `split_at_mut` and its own scratch
/// tiles; no row's arithmetic changes, so the result is bit-identical
/// to the serial walk at any worker count.
#[allow(clippy::too_many_arguments)]
fn attend_batch(
    cfg: &CpuModelConfig,
    kv: &PagedKvCache,
    spans: &[SeqSpan<'_>],
    rows: &[(usize, usize, u32)],
    qm: &Matrix,
    layer: usize,
    att: &mut Matrix,
    workers: usize,
) {
    let d = cfg.d_model;
    let t = rows.len();
    let workers = workers.max(1).min(t.max(1));
    if workers <= 1 {
        let mut k_tile = vec![0.0f32; kv.tile_len()];
        let mut v_tile = vec![0.0f32; kv.tile_len()];
        for (i, &(si, pos, _)) in rows.iter().enumerate() {
            attend(
                cfg,
                kv,
                spans[si].table,
                layer,
                qm.row(i),
                pos + 1,
                &mut att.data[i * d..(i + 1) * d],
                &mut k_tile,
                &mut v_tile,
            );
        }
        return;
    }
    let base = t / workers;
    let extra = t % workers;
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = &mut att.data;
        let mut row0 = 0usize;
        for w in 0..workers {
            let take = base + usize::from(w < extra);
            let (chunk, tail) = rest.split_at_mut(take * d);
            rest = tail;
            let r0 = row0;
            row0 += take;
            s.spawn(move || {
                let mut k_tile = vec![0.0f32; kv.tile_len()];
                let mut v_tile = vec![0.0f32; kv.tile_len()];
                for j in 0..take {
                    let i = r0 + j;
                    let (si, pos, _) = rows[i];
                    attend(
                        cfg,
                        kv,
                        spans[si].table,
                        layer,
                        qm.row(i),
                        pos + 1,
                        &mut chunk[j * d..(j + 1) * d],
                        &mut k_tile,
                        &mut v_tile,
                    );
                }
            });
        }
    });
}

/// Multi-head causal attention for one query row over the cached
/// `0..ctx` positions addressed through `table`, walking the paged pool
/// block-by-block; accumulates into `out` (zeroed by the caller).
///
/// The walk is **tile-at-a-time**: each (block, layer) tile is
/// dequantized once into the caller's scratch (`k_tile`/`v_tile`,
/// length ≥ [`PagedKvCache::tile_len`]) and *all* heads read from the
/// scratch — the quantized pool is touched once per block per pass, not
/// once per head.  For the f32 pool the "dequantization" is a zero-copy
/// borrow, and the per-output-element FP operation sequence is exactly
/// the pre-tile per-head walk's, so f32 logits stay bit-identical to the
/// seed backend.
///
/// **GQA**: cached rows are `kv_dim = n_kv_heads · d_head` wide; Q head
/// `h` reads KV head `h / gqa_ratio`.  With `n_kv_heads == n_heads` the
/// ratio is 1 and every index reduces to the full-width MHA walk —
/// the identical slice offsets, so the same FP sequence bit for bit.
#[allow(clippy::too_many_arguments)]
fn attend(
    cfg: &CpuModelConfig,
    kv: &PagedKvCache,
    table: &[BlockId],
    layer: usize,
    qv: &[f32],
    ctx: usize,
    out: &mut [f32],
    k_tile: &mut [f32],
    v_tile: &mut [f32],
) {
    let hd = cfg.d_head();
    let nh = cfg.n_heads;
    let kvd = cfg.kv_dim();
    let group = cfg.gqa_ratio();
    let scale = 1.0 / (hd as f32).sqrt();
    let bs = kv.block_size();
    // Per-head score rows, position-major within a head: head `h`'s
    // score for position `p` lives at `h * ctx + p` (each head's row is
    // filled in ascending-p order, exactly as the per-head walk did).
    let mut scores = vec![0.0f32; nh * ctx];
    let mut maxs = vec![f32::NEG_INFINITY; nh];
    // Score pass: table-ordered block walk over the K pool, one tile
    // dequant per block.
    let mut p = 0;
    'k_walk: for &blk in table {
        if p >= ctx {
            break;
        }
        let kt = kv.k_block(blk, layer, k_tile);
        for pb in 0..bs {
            if p >= ctx {
                break 'k_walk;
            }
            let krow = &kt[pb * kvd..pb * kvd + kvd];
            for head in 0..nh {
                let qh = &qv[head * hd..head * hd + hd];
                let koff = (head / group) * hd;
                let kh = &krow[koff..koff + hd];
                let s = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                scores[head * ctx + p] = s;
                maxs[head] = maxs[head].max(s);
            }
            p += 1;
        }
    }
    let mut invs = vec![0.0f32; nh];
    for head in 0..nh {
        let max_s = maxs[head];
        let mut denom = 0.0f32;
        for s in scores[head * ctx..head * ctx + ctx].iter_mut() {
            *s = (*s - max_s).exp();
            denom += *s;
        }
        invs[head] = 1.0 / denom;
    }
    // Value pass: same walk over the V pool.
    let mut p = 0;
    'v_walk: for &blk in table {
        if p >= ctx {
            break;
        }
        let vt = kv.v_block(blk, layer, v_tile);
        for pb in 0..bs {
            if p >= ctx {
                break 'v_walk;
            }
            let vrow = &vt[pb * kvd..pb * kvd + kvd];
            for head in 0..nh {
                let w = scores[head * ctx + p] * invs[head];
                let oh = &mut out[head * hd..head * hd + hd];
                let voff = (head / group) * hd;
                let vh = &vrow[voff..voff + hd];
                for (o, &vv) in oh.iter_mut().zip(vh) {
                    *o += w * vv;
                }
            }
            p += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> CpuBackend {
        CpuBackend::new(CpuModelConfig::default()).unwrap()
    }

    fn prefill_desc<'a>(tokens: &'a [u32], table: &'a [BlockId]) -> PrefillDesc<'a> {
        PrefillDesc { seq_id: 0, tokens, start: 0, is_last: true, block_table: table }
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn same_seed_same_logits() {
        let mut a = backend();
        let mut b = backend();
        let prompt = [10u32, 250, 3, 77];
        let (la, _) = a.prefill(prefill_desc(&prompt, &[0])).unwrap();
        let (lb, _) = b.prefill(prefill_desc(&prompt, &[0])).unwrap();
        assert_eq!(la, lb, "same config must give bit-identical logits");
        assert_eq!(la.len(), 256);
        assert!(la.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn logits_do_not_depend_on_physical_block_placement() {
        // The same tokens through a *different* physical table must give
        // bit-identical logits: attention order is positional, not
        // physical (the property block-table scatter relies on).
        let mut a = backend();
        let mut b = backend();
        let prompt: Vec<u32> = (0..40).map(|i| (i * 3) as u32).collect(); // 3 blocks of 16
        let (la, _) = a.prefill(prefill_desc(&prompt, &[0, 1, 2])).unwrap();
        let (lb, _) = b.prefill(prefill_desc(&prompt, &[7, 2, 5])).unwrap();
        assert_eq!(la, lb, "physical placement leaked into the math");
    }

    #[test]
    fn different_seed_different_logits() {
        let mut a = backend();
        let mut b = CpuBackend::new(CpuModelConfig { seed: 99, ..Default::default() }).unwrap();
        let (la, _) = a.prefill(prefill_desc(&[1, 2, 3], &[0])).unwrap();
        let (lb, _) = b.prefill(prefill_desc(&[1, 2, 3], &[0])).unwrap();
        assert_ne!(la, lb);
    }

    #[test]
    fn prefill_then_decode_matches_longer_prefill() {
        // KV-cache correctness: prefill(p[..n]) + decode(p[n-1]) must
        // reproduce prefill(p[..n]) exactly (same math, same cache).
        let prompt = [10u32, 20, 30, 40, 50];
        let mut a = backend();
        let (logits_full, _) = a.prefill(prefill_desc(&prompt, &[0])).unwrap();

        let mut b = backend();
        let (_, _) = b.prefill(prefill_desc(&prompt[..4], &[1])).unwrap();
        let (rows, _) = b
            .decode(&[DecodeDesc { seq_id: 0, context_len: 4, token: 50, block_table: &[1] }])
            .unwrap();
        let diff = max_diff(&logits_full, &rows[0]);
        assert!(diff < 1e-4, "prefill-vs-decode max diff {diff}");
    }

    #[test]
    fn batch_sequences_are_independent() {
        let mut be = backend();
        be.prefill(prefill_desc(&[1, 2, 3], &[0])).unwrap();
        be.prefill(prefill_desc(&[9, 8, 7, 6], &[1])).unwrap();
        let (single, _) = be
            .decode(&[DecodeDesc { seq_id: 0, context_len: 3, token: 3, block_table: &[0] }])
            .unwrap();
        // Redo seq 0's cache state, then decode both sequences together.
        be.prefill(prefill_desc(&[1, 2, 3], &[0])).unwrap();
        let (both, _) = be
            .decode(&[
                DecodeDesc { seq_id: 0, context_len: 3, token: 3, block_table: &[0] },
                DecodeDesc { seq_id: 1, context_len: 4, token: 6, block_table: &[1] },
            ])
            .unwrap();
        assert_eq!(single[0], both[0], "seq 0 must not see seq 1");
    }

    #[test]
    fn shared_prefix_block_is_physically_shared() {
        // Two tables sharing their first BlockId read/write the same
        // memory: prefilling B after A leaves A's block contents intact
        // (identical prefix -> identical K/V) and produces identical
        // logits for identical prompts.
        let mut be = backend();
        let prompt: Vec<u32> = (0..16).map(|i| (7 * i + 1) as u32).collect(); // exactly 1 block
        let mut full = prompt.clone();
        full.push(200);
        let (la, _) = be.prefill(prefill_desc(&full, &[0, 1])).unwrap();
        // B shares block 0 (the full prefix), private tail block 2.
        let (lb, _) = be.prefill(prefill_desc(&full, &[0, 2])).unwrap();
        assert_eq!(la, lb, "shared physical prefix must not perturb the math");
    }

    #[test]
    fn released_blocks_are_poisoned_in_debug() {
        let mut be = backend();
        be.prefill(prefill_desc(&[5, 6, 7], &[0])).unwrap();
        be.release_blocks(&[0]);
        if cfg!(debug_assertions) {
            assert!(
                be.kv().k_row(0, 0, 0).iter().all(|x| x.is_nan()),
                "freed block must be poisoned in debug builds"
            );
            // A decode whose table points at the freed block must now
            // produce NaN logits (loud failure), not stale values.
            let (rows, _) = be
                .decode(&[DecodeDesc { seq_id: 0, context_len: 3, token: 1, block_table: &[0] }])
                .unwrap();
            assert!(rows[0].iter().any(|v| v.is_nan()), "stale read must be loud");
        }
        // Re-prefilling the recycled block overwrites the poison fully.
        let (l, _) = be.prefill(prefill_desc(&[5, 6, 7], &[0])).unwrap();
        assert!(l.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn injected_poison_fails_loudly_at_every_dtype() {
        let prompt: Vec<u32> = (0..12).map(|i| ((i * 5 + 3) % 256) as u32).collect();
        for dtype in KvDtype::ALL {
            let mut be = backend();
            be.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            be.inject_fault();
            let err = be.prefill(prefill_desc(&prompt, &[0])).unwrap_err();
            assert!(
                err.to_string().contains("non-finite logits"),
                "{dtype}: poison must surface as a typed logits failure, got: {err}"
            );
            // One-shot: the next pass over the same (recycled) block is
            // clean again — re-prefill overwrites every row it touched.
            let (l, _) = be.prefill(prefill_desc(&prompt, &[0])).unwrap();
            assert!(l.iter().all(|v| v.is_finite()), "{dtype}: fault must disarm after firing");
        }
    }

    #[test]
    fn kv_export_import_roundtrips_at_every_dtype() {
        let prompt: Vec<u32> = (0..20).map(|i| ((i * 9 + 1) % 256) as u32).collect();
        for dtype in KvDtype::ALL {
            let mut a = backend();
            a.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            a.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();
            // Non-consuming export: the source pool keeps decoding.
            let payload = a.export_kv(&[0, 1]).unwrap();
            let dec = |table: &'static [BlockId]| DecodeDesc {
                seq_id: 0,
                context_len: 20,
                token: 9,
                block_table: table,
            };
            let (rows_a, _) = a.decode(&[dec(&[0, 1])]).unwrap();
            // Fresh backend, same weights: restore the packed payload
            // onto a *different* physical table and decode through it.
            let mut b = backend();
            b.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            b.import_kv(&[3, 5], &payload);
            let (rows_b, _) = b.decode(&[dec(&[3, 5])]).unwrap();
            assert_eq!(rows_a[0], rows_b[0], "{dtype}: restored K/V must decode bit-identically");
        }
    }

    #[test]
    fn spill_entries_survive_export_import() {
        let prompt: Vec<u32> = (0..16).map(|i| ((i * 3 + 2) % 256) as u32).collect();
        let mut a = backend();
        a.bind_kv(8, DEFAULT_BLOCK_SIZE, KvDtype::F16);
        a.prefill(prefill_desc(&prompt, &[0])).unwrap();
        a.swap_out(4, &[0]).unwrap();
        let payload = a.export_spill(4);
        assert!(payload.is_some(), "CpuBackend spills carry real payloads");
        let mut b = backend();
        b.bind_kv(8, DEFAULT_BLOCK_SIZE, KvDtype::F16);
        b.import_spill(4, 1, payload);
        b.swap_in(4, &[2]).unwrap();
        let (ra, _) = a
            .decode(&[DecodeDesc { seq_id: 4, context_len: 16, token: 1, block_table: &[0, 1] }])
            .unwrap();
        let (rb, _) = b
            .decode(&[DecodeDesc { seq_id: 4, context_len: 16, token: 1, block_table: &[2, 3] }])
            .unwrap();
        assert_eq!(ra[0], rb[0], "spill restored through a snapshot must decode identically");
        assert_eq!(b.kv_stats().unwrap().spill_bytes, 0, "swap-in consumed the imported entry");
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut be = backend();
        assert!(be.prefill(prefill_desc(&[], &[0])).is_err());
        assert!(be.prefill(prefill_desc(&[300], &[0])).is_err(), "token outside vocab");
        let long = vec![1u32; 17];
        assert!(
            be.prefill(prefill_desc(&long, &[0])).is_err(),
            "block table too short for the prompt"
        );
        assert!(
            be.decode(&[DecodeDesc { seq_id: 0, context_len: 16, token: 1, block_table: &[0] }])
                .is_err(),
            "decode landing past the table must fail"
        );
        assert!(CpuBackend::new(CpuModelConfig { d_model: 60, ..Default::default() }).is_err());
        assert!(CpuBackend::new(CpuModelConfig { group_size: 48, ..Default::default() })
            .is_err());
    }

    #[test]
    fn bind_kv_sets_geometry() {
        let mut be = backend();
        be.bind_kv(32, 4, KvDtype::F32);
        assert_eq!(be.kv().n_blocks(), 32);
        assert_eq!(be.kv().block_size(), 4);
        assert_eq!(be.kv().dtype(), KvDtype::F32);
        // 5 tokens now need 2 blocks of 4.
        assert!(be.prefill(prefill_desc(&[1, 2, 3, 4, 5], &[0])).is_err());
        assert!(be.prefill(prefill_desc(&[1, 2, 3, 4, 5], &[0, 1])).is_ok());
        // Rebinding with a compressed dtype re-pools at the new width.
        be.bind_kv(32, 4, KvDtype::Kv4);
        assert_eq!(be.kv().dtype(), KvDtype::Kv4);
        assert_eq!(
            be.kv().bytes(),
            32 * KvDtype::Kv4.block_bytes(4, be.cfg.n_layers, be.cfg.kv_dim())
        );
    }

    #[test]
    fn every_dtype_generates_finite_discriminating_logits() {
        let prompt: Vec<u32> = (0..24).map(|i| ((i * 13 + 5) % 256) as u32).collect();
        for dtype in KvDtype::ALL {
            let mut be = backend();
            be.bind_kv(16, DEFAULT_BLOCK_SIZE, dtype);
            let (l, _) = be.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();
            assert!(l.iter().all(|v| v.is_finite()), "{dtype} produced non-finite logits");
            let lo = l.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(hi - lo > 0.05, "{dtype} logit range {} too flat", hi - lo);
        }
    }

    #[test]
    fn f32_dtype_is_bit_identical_to_the_unbound_pool() {
        // The F32 pool (and the tile-at-a-time walk it takes) must
        // reproduce the pre-dtype backend exactly — same math, same
        // per-element FP operation order.
        let prompt: Vec<u32> = (0..40).map(|i| ((i * 11 + 3) % 256) as u32).collect();
        let mut a = backend(); // default pool: f32 (absent OPT4GPTQ_KV)
        let mut b = backend();
        b.bind_kv(8, DEFAULT_BLOCK_SIZE, KvDtype::F32);
        let (la, _) = a.prefill(prefill_desc(&prompt, &[0, 1, 2])).unwrap();
        let (lb, _) = b.prefill(prefill_desc(&prompt, &[0, 1, 2])).unwrap();
        if a.kv().dtype() == KvDtype::F32 {
            assert_eq!(la, lb);
        }
    }

    #[test]
    fn compressed_dtypes_track_f32_logits() {
        // Sanity bound here (the committed regression pins live in
        // eval::numerics::kv_dtype_drift): quantized-KV logits must stay
        // close enough to f32 that generation is usable.
        let prompt: Vec<u32> = (0..32).map(|i| ((i * 7 + 9) % 256) as u32).collect();
        let mut f32_be = backend();
        f32_be.bind_kv(8, DEFAULT_BLOCK_SIZE, KvDtype::F32);
        let (base, _) = f32_be.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();
        let denom = base.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        for (dtype, bound) in [(KvDtype::F16, 1e-2f32), (KvDtype::Kv4, 0.35f32)] {
            let mut be = backend();
            be.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            let (l, _) = be.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();
            let drift = max_diff(&base, &l) / denom;
            assert!(drift <= bound, "{dtype} relative drift {drift} exceeds {bound}");
        }
    }

    #[test]
    fn chunked_prefill_is_bit_identical_at_every_dtype() {
        // Within a dtype, chunking must still be invisible: per-row
        // write-once quantization makes stored K/V a pure function of
        // the row, never of chunk boundaries.
        let prompt: Vec<u32> = (0..40).map(|i| ((i * 17 + 2) % 256) as u32).collect();
        for dtype in KvDtype::ALL {
            let mut a = backend();
            a.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            let (one_shot, _) = a.prefill(prefill_desc(&prompt, &[0, 1, 2])).unwrap();
            let mut b = backend();
            b.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            let mut pos = 0usize;
            let mut last = Vec::new();
            for len in [3usize, 5, 8, 24] {
                let end = pos + len;
                let out = b
                    .step(
                        &[PrefillDesc {
                            seq_id: 0,
                            tokens: &prompt[pos..end],
                            start: pos,
                            is_last: end == prompt.len(),
                            block_table: &[0, 1, 2],
                        }],
                        &[],
                    )
                    .unwrap();
                if end == prompt.len() {
                    last = out.prefill_logits[0].clone().expect("final chunk logits");
                }
                pos = end;
            }
            assert_eq!(last, one_shot, "{dtype}: chunking must stay invisible");
        }
    }

    #[test]
    fn swap_roundtrip_is_bit_exact_at_every_dtype() {
        // spill → poison → restore at different physical blocks must
        // reproduce the exact packed K/V (restore is a copy, never a
        // requantization), so post-swap decodes match unpreempted ones.
        let prompt: Vec<u32> = (0..24).map(|i| ((i * 19 + 4) % 256) as u32).collect();
        for dtype in KvDtype::ALL {
            let mut a = backend();
            a.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            a.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();
            let (want, _) = a
                .decode(&[DecodeDesc { seq_id: 0, context_len: 24, token: 9, block_table: &[0, 1] }])
                .unwrap();

            let mut b = backend();
            b.bind_kv(8, DEFAULT_BLOCK_SIZE, dtype);
            b.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();
            let bytes = b.swap_out(0, &[0, 1]).unwrap();
            assert_eq!(bytes, 2 * dtype.block_bytes(DEFAULT_BLOCK_SIZE, b.cfg.n_layers, b.cfg.kv_dim()));
            assert_eq!(b.kv_stats().unwrap().spill_bytes, bytes);
            b.release_blocks(&[0, 1]); // poison the originals
            b.swap_in(0, &[3, 5]).unwrap(); // restore elsewhere
            assert_eq!(b.kv_stats().unwrap().spill_bytes, 0);
            assert_eq!(b.kv_stats().unwrap().spill_peak_bytes, bytes);
            let (got, _) = b
                .decode(&[DecodeDesc { seq_id: 0, context_len: 24, token: 9, block_table: &[3, 5] }])
                .unwrap();
            assert_eq!(got[0], want[0], "{dtype}: swap round trip must be invisible");
        }
    }

    #[test]
    fn swap_in_without_spill_is_a_typed_error() {
        let mut be = backend();
        be.bind_kv(8, DEFAULT_BLOCK_SIZE, KvDtype::F32);
        let err = be.swap_in(42, &[0]).unwrap_err();
        assert!(!err.is_transient(), "missing spill is not retryable");
        // drop_spill is idempotent and zeroes the accounting.
        be.prefill(prefill_desc(&[1, 2, 3], &[0])).unwrap();
        be.swap_out(0, &[0]).unwrap();
        assert!(be.kv_stats().unwrap().spill_bytes > 0);
        be.drop_spill(0);
        be.drop_spill(0);
        assert_eq!(be.kv_stats().unwrap().spill_bytes, 0);
        assert!(be.swap_in(0, &[1]).is_err(), "dropped spill cannot be restored");
    }

    #[test]
    fn wo_carries_act_order_perm() {
        let be = backend();
        for lw in &be.layers {
            assert!(lw.wo.perm().is_some(), "wo must be an act-order checkpoint");
        }
    }

    #[test]
    fn weights_are_prepacked_for_the_active_kernel() {
        // Model build must cache the swizzle exactly when the dispatched
        // kernel streams it — at that kernel's lane width — so the serve
        // path never re-swizzles.
        let be = backend();
        let want = crate::gptq::active_kernel().swizzle_width().is_some();
        assert_eq!(be.lm_head.is_swizzled(), want);
        for lw in &be.layers {
            for w in [&lw.wq, &lw.wk, &lw.wv, &lw.wo, &lw.w_gate, &lw.w_up, &lw.w_down] {
                assert_eq!(w.is_swizzled(), want);
            }
        }
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_one_shot() {
        // Splitting a prompt into chunks (block-aligned or not, chunk
        // sizes below the block size included) must reproduce the
        // one-shot prefill logits bit for bit: earlier chunks' K/V is
        // read back through the table exactly as the one-shot pass
        // computes it in-flight.
        let prompt: Vec<u32> = (0..40).map(|i| ((i * 11 + 3) % 256) as u32).collect();
        let mut a = backend(); // block size 16 -> 3 blocks
        let (one_shot, _) = a.prefill(prefill_desc(&prompt, &[0, 1, 2])).unwrap();
        for chunks in [vec![16, 24], vec![16, 16, 8], vec![3, 5, 8, 24], vec![1; 40]] {
            let mut b = backend();
            let mut pos = 0usize;
            let mut last = Vec::new();
            for len in &chunks {
                let end = pos + len;
                let out = b
                    .step(
                        &[PrefillDesc {
                            seq_id: 0,
                            tokens: &prompt[pos..end],
                            start: pos,
                            is_last: end == prompt.len(),
                            block_table: &[0, 1, 2],
                        }],
                        &[],
                    )
                    .unwrap();
                if end == prompt.len() {
                    last = out.prefill_logits[0].clone().expect("final chunk logits");
                } else {
                    assert!(out.prefill_logits[0].is_none(), "mid chunk must skip the head");
                }
                pos = end;
            }
            assert_eq!(last, one_shot, "chunks {chunks:?} diverged from one-shot prefill");
        }
    }

    #[test]
    fn prefix_skip_is_bit_identical_to_recompute() {
        // Sequence A fills blocks [0, 1] with the shared prefix; a
        // prefix-skip prefill of B (start = 32, sharing those blocks)
        // must give logits bit-identical to B's full recompute.
        let shared: Vec<u32> = (0..32).map(|i| ((i * 7 + 1) % 256) as u32).collect();
        let mut full = shared.clone();
        full.extend((0..9).map(|i| ((i * 29 + 5) % 256) as u32));
        let mut be = backend();
        be.prefill(prefill_desc(&shared, &[0, 1])).unwrap();
        // Full recompute through a table sharing the prefix blocks (what
        // OPT4GPTQ_PREFIX_SKIP=0 does): rewrites identical K/V.
        let (recompute, _) = be.prefill(prefill_desc(&full, &[0, 1, 2])).unwrap();
        // Prefix-skip: the backend never sees the first 32 tokens.
        let out = be
            .step(
                &[PrefillDesc {
                    seq_id: 1,
                    tokens: &full[32..],
                    start: 32,
                    is_last: true,
                    block_table: &[0, 1, 3],
                }],
                &[],
            )
            .unwrap();
        let skipped = out.prefill_logits[0].clone().unwrap();
        assert_eq!(skipped, recompute, "skipping the cached prefix changed the logits");
    }

    #[test]
    fn mixed_step_matches_separate_calls() {
        // A chunk and a decode folded into one step must equal the same
        // work issued as separate calls (row-independent math).
        let prompt: Vec<u32> = (0..20).map(|i| ((i * 5 + 2) % 256) as u32).collect();
        let mut a = backend();
        a.prefill(prefill_desc(&[9, 8, 7], &[3])).unwrap();
        let (dec_alone, _) = a
            .decode(&[DecodeDesc { seq_id: 0, context_len: 3, token: 7, block_table: &[3] }])
            .unwrap();
        let (pre_alone, _) = a.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();

        let mut b = backend();
        b.prefill(prefill_desc(&[9, 8, 7], &[3])).unwrap();
        let out = b
            .step(
                &[PrefillDesc {
                    seq_id: 1,
                    tokens: &prompt,
                    start: 0,
                    is_last: true,
                    block_table: &[0, 1],
                }],
                &[DecodeDesc { seq_id: 0, context_len: 3, token: 7, block_table: &[3] }],
            )
            .unwrap();
        assert_eq!(out.prefill_logits[0].as_ref().unwrap(), &pre_alone);
        assert_eq!(out.decode_logits[0], dec_alone[0]);
    }

    #[test]
    fn logits_spread_enough_to_sample() {
        // Degenerate (near-constant) logits would make every request
        // generate the same token forever; check the head discriminates.
        let mut be = backend();
        let (l, _) = be.prefill(prefill_desc(&[42, 17, 99], &[0])).unwrap();
        let lo = l.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(hi - lo > 0.05, "logit range {} too flat", hi - lo);
    }

    #[test]
    fn attend_matches_a_naive_softmax_reference() {
        // Independent recomputation of attend's math (no tiles, no
        // streaming max, plain per-position softmax) — pins the
        // semantics at both an MHA and a GQA geometry; the tolerance
        // absorbs the different summation order.
        for cfg in [crate::models::TINY_MHA, crate::models::TINY_GQA] {
            let bs = 4;
            let mut kv = PagedKvCache::with_dtype(3, bs, 1, cfg.kv_dim(), KvDtype::F32);
            let table = [2, 0, 1];
            let ctx = 11;
            let mut rng = Rng::new(7);
            let mut krows: Vec<Vec<f32>> = Vec::new();
            let mut vrows: Vec<Vec<f32>> = Vec::new();
            for p in 0..ctx {
                let k = rng.normal_vec_f32(cfg.kv_dim(), 1.0);
                let v = rng.normal_vec_f32(cfg.kv_dim(), 1.0);
                kv.write(&table, p, 0, &k, &v);
                krows.push(k);
                vrows.push(v);
            }
            let q = rng.normal_vec_f32(cfg.d_model, 1.0);
            let mut out = vec![0.0f32; cfg.d_model];
            let mut kt = vec![0.0f32; kv.tile_len()];
            let mut vt = vec![0.0f32; kv.tile_len()];
            attend(&cfg, &kv, &table, 0, &q, ctx, &mut out, &mut kt, &mut vt);
            let hd = cfg.d_head();
            for head in 0..cfg.n_heads {
                let qh = &q[head * hd..(head + 1) * hd];
                let koff = (head / cfg.gqa_ratio()) * hd;
                let scores: Vec<f32> = (0..ctx)
                    .map(|p| {
                        let kh = &krows[p][koff..koff + hd];
                        qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>()
                            / (hd as f32).sqrt()
                    })
                    .collect();
                let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
                let denom: f32 = exps.iter().sum();
                for c in 0..hd {
                    let want: f32 = (0..ctx)
                        .map(|p| exps[p] / denom * vrows[p][koff + c])
                        .sum();
                    let got = out[head * hd + c];
                    assert!(
                        (want - got).abs() < 1e-4,
                        "{}: head {head} lane {c}: got {got}, reference {want}",
                        cfg.name
                    );
                }
            }
        }
    }

    #[test]
    fn gqa_attention_equals_mha_with_duplicated_kv_heads() {
        // The GQA reduction pin: Q head `h` reading shared KV head
        // `h / gqa_ratio` must equal plain MHA over a cache whose rows
        // duplicate that shared head to full width — value-identical
        // inputs per head, identical FP sequence, so bitwise equal.
        let mha = crate::models::TINY_MHA;
        let gqa = CpuModelConfig { n_kv_heads: 1, ..mha };
        let bs = 4;
        let table = [0, 1];
        let ctx = 7;
        let mut kv_g = PagedKvCache::with_dtype(2, bs, 1, gqa.kv_dim(), KvDtype::F32);
        let mut kv_m = PagedKvCache::with_dtype(2, bs, 1, mha.kv_dim(), KvDtype::F32);
        let mut rng = Rng::new(42);
        for p in 0..ctx {
            let k1 = rng.normal_vec_f32(gqa.kv_dim(), 1.0);
            let v1 = rng.normal_vec_f32(gqa.kv_dim(), 1.0);
            kv_g.write(&table, p, 0, &k1, &v1);
            let k4: Vec<f32> = k1.iter().cycle().take(mha.kv_dim()).cloned().collect();
            let v4: Vec<f32> = v1.iter().cycle().take(mha.kv_dim()).cloned().collect();
            kv_m.write(&table, p, 0, &k4, &v4);
        }
        let q = rng.normal_vec_f32(mha.d_model, 1.0);
        let mut out_g = vec![0.0f32; mha.d_model];
        let mut out_m = vec![0.0f32; mha.d_model];
        let mut kt_g = vec![0.0f32; kv_g.tile_len()];
        let mut vt_g = vec![0.0f32; kv_g.tile_len()];
        let mut kt_m = vec![0.0f32; kv_m.tile_len()];
        let mut vt_m = vec![0.0f32; kv_m.tile_len()];
        attend(&gqa, &kv_g, &table, 0, &q, ctx, &mut out_g, &mut kt_g, &mut vt_g);
        attend(&mha, &kv_m, &table, 0, &q, ctx, &mut out_m, &mut kt_m, &mut vt_m);
        assert_eq!(out_g, out_m, "GQA must equal MHA over duplicated KV heads, bit for bit");
    }

    #[test]
    fn rope_rotation_is_position_zero_identity_and_norm_preserving() {
        let before: Vec<f32> = (0..16).map(|i| (i as f32) * 0.25 - 2.0).collect();
        let mut at0 = before.clone();
        rope_rotate_head(&mut at0, 0);
        assert_eq!(at0, before, "position 0 must be the identity rotation");
        let mut at5 = before.clone();
        rope_rotate_head(&mut at5, 5);
        assert_ne!(at5, before, "a nonzero position must actually rotate");
        let n_before: f32 = before.iter().map(|x| x * x).sum();
        let n_after: f32 = at5.iter().map(|x| x * x).sum();
        assert!(
            (n_before - n_after).abs() < 1e-3 * n_before.max(1.0),
            "rotation must preserve the norm: {n_before} vs {n_after}"
        );
        // Row form: each head chunk rotates independently — a row of
        // two identical chunks stays two identical chunks.
        let mut row: Vec<f32> = before.iter().chain(before.iter()).cloned().collect();
        rope_rotate_row(&mut row, 16, 5);
        assert_eq!(&row[..16], &row[16..], "head chunks must rotate independently");
        assert_eq!(&row[..16], &at5[..], "row form must match the head form");
    }

    #[test]
    fn tiny_gqa_serves_finite_discriminating_logits_at_every_dtype() {
        // End-to-end at the GQA + RoPE registry entry: pool rows are
        // kv_dim (= 16) wide — a quarter of the MHA pool — and the walk
        // must stay numerically healthy at every cache dtype.
        let prompt: Vec<u32> = (0..24).map(|i| ((i * 13 + 5) % 256) as u32).collect();
        for dtype in KvDtype::ALL {
            let mut be = CpuBackend::new(crate::models::TINY_GQA).unwrap();
            be.bind_kv(16, DEFAULT_BLOCK_SIZE, dtype);
            assert_eq!(
                be.kv().bytes(),
                16 * dtype.block_bytes(DEFAULT_BLOCK_SIZE, be.cfg.n_layers, be.cfg.kv_dim()),
                "{dtype}: pool must be sized by kv_dim, not d_model"
            );
            let (l, _) = be.prefill(prefill_desc(&prompt, &[0, 1])).unwrap();
            assert!(l.iter().all(|v| v.is_finite()), "{dtype}: non-finite logits at tiny-gqa");
            let lo = l.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(hi - lo > 0.05, "{dtype}: tiny-gqa logit range {} too flat", hi - lo);
        }
    }

    #[test]
    fn batch_parallel_attention_is_bit_identical_to_serial() {
        // The scoped-thread row split must not change any row's
        // arithmetic: a forced 4-way split reproduces the forced-serial
        // walk bit for bit, at an MHA and a GQA + RoPE geometry, for
        // both a batched prefill and a batched decode.
        for cfg in [crate::models::TINY_MHA, crate::models::TINY_GQA] {
            let mut serial = CpuBackend::new(cfg).unwrap();
            let mut parallel = CpuBackend::new(cfg).unwrap();
            serial.set_att_workers(Some(1));
            parallel.set_att_workers(Some(4));
            let prompts: Vec<Vec<u32>> = (0..3)
                .map(|s| (0..20).map(|i| ((i * 7 + s * 31 + 3) % 256) as u32).collect())
                .collect();
            let tables: [&[BlockId]; 3] = [&[0, 1], &[2, 3], &[4, 5]];
            let prefills: Vec<PrefillDesc<'_>> = prompts
                .iter()
                .zip(&tables)
                .enumerate()
                .map(|(s, (p, t))| PrefillDesc {
                    seq_id: s,
                    tokens: p,
                    start: 0,
                    is_last: true,
                    block_table: *t,
                })
                .collect();
            let out_s = serial.step(&prefills, &[]).unwrap();
            let out_p = parallel.step(&prefills, &[]).unwrap();
            assert_eq!(
                out_s.prefill_logits, out_p.prefill_logits,
                "{}: parallel prefill walk diverged from serial",
                cfg.name
            );
            let decodes: Vec<DecodeDesc<'_>> = (0..3)
                .map(|s| DecodeDesc {
                    seq_id: s,
                    context_len: 20,
                    token: (s * 17 + 1) as u32,
                    block_table: tables[s],
                })
                .collect();
            let (ds, _) = serial.decode(&decodes).unwrap();
            let (dp, _) = parallel.decode(&decodes).unwrap();
            assert_eq!(ds, dp, "{}: parallel decode walk diverged from serial", cfg.name);
        }
    }

    #[test]
    fn attention_worker_heuristic_guards_tiny_batches() {
        assert_eq!(attention_workers(1, usize::MAX), 1, "single row stays serial");
        assert_eq!(attention_workers(8, 10), 1, "sub-floor work stays serial");
        let w = attention_workers(4, ATT_MIN_WORK);
        assert!((1..=4).contains(&w), "workers must be capped by the row count, got {w}");
    }
}

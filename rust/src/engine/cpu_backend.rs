//! In-crate executable backend: a real tiny quantized transformer run
//! entirely through the fused CPU kernels.
//!
//! Unlike [`super::backend::SimBackend`] (virtual clock, synthesized
//! logits) and the PJRT path (external AOT artifacts), [`CpuBackend`]
//! executes genuine math end-to-end with no artifacts and no external
//! crates: embeddings → `n_layers` pre-norm blocks (multi-head causal
//! attention over a dense per-slot KV cache + SiLU-gated MLP) → quantized
//! lm_head.  Every projection is a 4-bit GPTQ tensor evaluated through
//! [`crate::gptq::fused`] — decode steps exercise the `M = batch` fused
//! GEMM path, prefills the `M = prompt_len` path, and the per-layer
//! output projection carries a real act-order (`b_q_perm`) checkpoint so
//! the gather branch runs on every token.
//!
//! The engine's scheduler/block-manager/sampler stack drives this backend
//! exactly as it drives the simulated one; `rust/tests/backend_integration.rs`
//! pins the cross-backend behaviour (determinism, preemption survival,
//! exact token accounting) and the KV-cache consistency of
//! prefill-vs-decode.
//!
//! KV layout: dense `f32[n_layers, max_batch, max_seq, d_model]` per
//! cache side, lane = engine backend slot (same convention as the PJRT
//! backend); the engine's paged block tables map onto these dense
//! regions.

use std::time::Instant;

use anyhow::bail;

use crate::gptq::{
    gemm_fused, gemv_fused, quantize_gptq, quantize_rtn, GptqConfig, Matrix, QuantizedTensor,
};
use crate::rng::Rng;
use crate::Result;

use super::backend::{Backend, DecodeEntry};

/// Architecture of the tiny executable model (all dims kernel-aligned:
/// multiples of 8 for the packed layout, `group_size` dividing both
/// `d_model` and `d_ff`).
#[derive(Debug, Clone, Copy)]
pub struct CpuModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub group_size: usize,
    pub max_seq: usize,
    pub max_batch: usize,
    /// Weight-synthesis seed: two backends with the same config produce
    /// bit-identical logits.
    pub seed: u64,
}

impl Default for CpuModelConfig {
    fn default() -> Self {
        CpuModelConfig {
            vocab: 256, // byte tokenizer range
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 128,
            group_size: 32,
            max_seq: 256,
            max_batch: 8,
            seed: 0x0c17_0b0d,
        }
    }
}

impl CpuModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// One transformer block's quantized projections.
struct LayerWeights {
    wq: QuantizedTensor,
    wk: QuantizedTensor,
    wv: QuantizedTensor,
    /// Output projection — quantized with `act_order: true`, so this
    /// tensor ships a real `b_q_perm` and every forward pass exercises
    /// the fused kernel's gather branch.
    wo: QuantizedTensor,
    w_gate: QuantizedTensor,
    w_up: QuantizedTensor,
    w_down: QuantizedTensor,
}

/// Fused-kernel CPU execution backend (see module docs).
pub struct CpuBackend {
    pub cfg: CpuModelConfig,
    embed: Matrix,
    pos: Matrix,
    layers: Vec<LayerWeights>,
    lm_head: QuantizedTensor,
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
}

fn quantized(rng: &mut Rng, k: usize, n: usize, g: usize, std: f32) -> QuantizedTensor {
    let w = Matrix::from_vec(k, n, rng.normal_vec_f32(k * n, std));
    quantize_rtn(&w, g)
}

fn kv_offset(cfg: &CpuModelConfig, layer: usize, slot: usize, pos: usize) -> usize {
    ((layer * cfg.max_batch + slot) * cfg.max_seq + pos) * cfg.d_model
}

impl CpuBackend {
    pub fn new(cfg: CpuModelConfig) -> Result<CpuBackend> {
        if cfg.d_model % cfg.n_heads.max(1) != 0 || cfg.n_heads == 0 {
            bail!("d_model {} must split evenly over {} heads", cfg.d_model, cfg.n_heads);
        }
        for (name, dim) in [("vocab", cfg.vocab), ("d_model", cfg.d_model), ("d_ff", cfg.d_ff)] {
            if dim == 0 || dim % 8 != 0 {
                bail!("{name} = {dim} must be a non-zero multiple of 8 (packed layout)");
            }
        }
        if cfg.group_size == 0
            || cfg.group_size % 8 != 0
            || cfg.d_model % cfg.group_size != 0
            || cfg.d_ff % cfg.group_size != 0
        {
            bail!(
                "group size {} must be a multiple of 8 dividing d_model {} and d_ff {}",
                cfg.group_size,
                cfg.d_model,
                cfg.d_ff
            );
        }
        if cfg.max_batch == 0 || cfg.max_seq < 2 || cfg.n_layers == 0 {
            bail!("max_batch/max_seq/n_layers must be positive (max_seq >= 2)");
        }

        let mut rng = Rng::new(cfg.seed);
        let d = cfg.d_model;
        let proj_std = 1.0 / (d as f32).sqrt();
        let embed = Matrix::from_vec(cfg.vocab, d, rng.normal_vec_f32(cfg.vocab * d, 0.5));
        let pos = Matrix::from_vec(cfg.max_seq, d, rng.normal_vec_f32(cfg.max_seq * d, 0.1));

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            // Act-order checkpoint for the output projection: quantize
            // against correlated calibration activations so desc_act has
            // a real Hessian-diagonal ordering to follow.
            let wo_dense = Matrix::from_vec(d, d, rng.normal_vec_f32(d * d, proj_std));
            let calib = Matrix::from_vec(64, d, rng.normal_vec_f32(64 * d, 1.0));
            let wo = quantize_gptq(
                wo_dense,
                &calib,
                GptqConfig { group_size: cfg.group_size, percdamp: 0.01, act_order: true },
            );
            layers.push(LayerWeights {
                wq: quantized(&mut rng, d, d, cfg.group_size, proj_std),
                wk: quantized(&mut rng, d, d, cfg.group_size, proj_std),
                wv: quantized(&mut rng, d, d, cfg.group_size, proj_std),
                wo,
                w_gate: quantized(&mut rng, d, cfg.d_ff, cfg.group_size, proj_std),
                w_up: quantized(&mut rng, d, cfg.d_ff, cfg.group_size, proj_std),
                w_down: quantized(
                    &mut rng,
                    cfg.d_ff,
                    d,
                    cfg.group_size,
                    1.0 / (cfg.d_ff as f32).sqrt(),
                ),
            });
        }
        let lm_head = quantized(&mut rng, d, cfg.vocab, cfg.group_size, proj_std);

        let cache_len = cfg.n_layers * cfg.max_batch * cfg.max_seq * d;
        Ok(CpuBackend {
            cfg,
            embed,
            pos,
            layers,
            lm_head,
            k_cache: vec![0.0; cache_len],
            v_cache: vec![0.0; cache_len],
        })
    }

    /// Run one batch of `(slot, position, token)` rows through all
    /// layers, writing each row's K/V at its position and attending
    /// causally over `0..=position`.  Returns the final-norm hidden
    /// states, `[rows, d_model]`.
    fn forward(&mut self, rows: &[(usize, usize, u32)]) -> Result<Matrix> {
        let cfg = self.cfg;
        let d = cfg.d_model;
        let t = rows.len();

        let mut h = Matrix::zeros(t, d);
        for (i, &(slot, pos, tok)) in rows.iter().enumerate() {
            if tok as usize >= cfg.vocab {
                bail!("token {tok} outside vocab {}", cfg.vocab);
            }
            if slot >= cfg.max_batch {
                bail!("slot {slot} outside max_batch {}", cfg.max_batch);
            }
            if pos >= cfg.max_seq {
                bail!("position {pos} outside max_seq {}", cfg.max_seq);
            }
            for c in 0..d {
                h.data[i * d + c] = self.embed.at(tok as usize, c) + self.pos.at(pos, c);
            }
        }

        for li in 0..cfg.n_layers {
            // ---- attention ----
            let a = rmsnorm_rows(&h);
            let (qm, km, vm) = {
                let lw = &self.layers[li];
                (gemm_fused(&a, &lw.wq), gemm_fused(&a, &lw.wk), gemm_fused(&a, &lw.wv))
            };
            for (i, &(slot, pos, _)) in rows.iter().enumerate() {
                let off = kv_offset(&cfg, li, slot, pos);
                self.k_cache[off..off + d].copy_from_slice(km.row(i));
                self.v_cache[off..off + d].copy_from_slice(vm.row(i));
            }
            let mut att = Matrix::zeros(t, d);
            for (i, &(slot, pos, _)) in rows.iter().enumerate() {
                attend(
                    &cfg,
                    &self.k_cache,
                    &self.v_cache,
                    li,
                    slot,
                    qm.row(i),
                    pos + 1,
                    &mut att.data[i * d..(i + 1) * d],
                );
            }
            let o = gemm_fused(&att, &self.layers[li].wo);
            add_assign(&mut h, &o);

            // ---- MLP ----
            let m = rmsnorm_rows(&h);
            let lw = &self.layers[li];
            let mut ff = gemm_fused(&m, &lw.w_gate);
            let up = gemm_fused(&m, &lw.w_up);
            for (f, &u) in ff.data.iter_mut().zip(&up.data) {
                *f = silu(*f) * u;
            }
            let down = gemm_fused(&ff, &lw.w_down);
            add_assign(&mut h, &down);
        }
        Ok(rmsnorm_rows(&h))
    }
}

impl Backend for CpuBackend {
    fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    fn max_seq_len(&self) -> usize {
        self.cfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn prefill(&mut self, slot: usize, tokens: &[u32]) -> Result<(Vec<f32>, f64)> {
        let t0 = Instant::now();
        if tokens.is_empty() {
            bail!("cannot prefill an empty prompt");
        }
        if tokens.len() > self.cfg.max_seq {
            bail!("prompt of {} tokens exceeds max_seq {}", tokens.len(), self.cfg.max_seq);
        }
        let rows: Vec<(usize, usize, u32)> =
            tokens.iter().enumerate().map(|(i, &tok)| (slot, i, tok)).collect();
        let hidden = self.forward(&rows)?;
        let logits = gemv_fused(hidden.row(tokens.len() - 1), &self.lm_head);
        Ok((logits, t0.elapsed().as_secs_f64()))
    }

    fn decode(&mut self, batch: &[DecodeEntry]) -> Result<(Vec<Vec<f32>>, f64)> {
        let t0 = Instant::now();
        assert!(!batch.is_empty());
        let mut rows = Vec::with_capacity(batch.len());
        for e in batch {
            // The engine's `position` counts the fed token, whose cache
            // index is therefore `position - 1`.
            if e.position == 0 {
                bail!("decode position must count the fed token (got 0)");
            }
            rows.push((e.slot, e.position - 1, e.token));
        }
        let hidden = self.forward(&rows)?;
        let logits = gemm_fused(&hidden, &self.lm_head);
        let v = self.cfg.vocab;
        let out = (0..batch.len()).map(|i| logits.data[i * v..(i + 1) * v].to_vec()).collect();
        Ok((out, t0.elapsed().as_secs_f64()))
    }

    fn release(&mut self, _slot: usize) {
        // Positions are fully overwritten on slot reuse (prefill rewrites
        // 0..prompt_len and decodes extend monotonically), so no wipe is
        // needed; keeping stale lanes also mirrors the PJRT backend.
    }
}

/// Row-wise RMSNorm (unit gain).
fn rmsnorm_rows(x: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (o, &v) in out.data[r * x.cols..(r + 1) * x.cols].iter_mut().zip(row) {
            *o = v * inv;
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn add_assign(a: &mut Matrix, b: &Matrix) {
    debug_assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// Multi-head causal attention for one query row over the cached
/// `0..ctx` positions of `(layer, slot)`; accumulates into `out`
/// (zeroed by the caller).
#[allow(clippy::too_many_arguments)]
fn attend(
    cfg: &CpuModelConfig,
    k_cache: &[f32],
    v_cache: &[f32],
    layer: usize,
    slot: usize,
    qv: &[f32],
    ctx: usize,
    out: &mut [f32],
) {
    let d = cfg.d_model;
    let hd = cfg.d_head();
    let scale = 1.0 / (hd as f32).sqrt();
    let base = (layer * cfg.max_batch + slot) * cfg.max_seq * d;
    let mut scores = vec![0.0f32; ctx];
    for head in 0..cfg.n_heads {
        let hoff = head * hd;
        let qh = &qv[hoff..hoff + hd];
        let mut max_s = f32::NEG_INFINITY;
        for (p, s) in scores.iter_mut().enumerate() {
            let krow = &k_cache[base + p * d + hoff..base + p * d + hoff + hd];
            *s = qh.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
            max_s = max_s.max(*s);
        }
        let mut denom = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - max_s).exp();
            denom += *s;
        }
        let inv = 1.0 / denom;
        for (p, &sw) in scores.iter().enumerate() {
            let w = sw * inv;
            let vrow = &v_cache[base + p * d + hoff..base + p * d + hoff + hd];
            for (o, &vv) in out[hoff..hoff + hd].iter_mut().zip(vrow) {
                *o += w * vv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> CpuBackend {
        CpuBackend::new(CpuModelConfig::default()).unwrap()
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn same_seed_same_logits() {
        let mut a = backend();
        let mut b = backend();
        let prompt = [10u32, 250, 3, 77];
        let (la, _) = a.prefill(0, &prompt).unwrap();
        let (lb, _) = b.prefill(0, &prompt).unwrap();
        assert_eq!(la, lb, "same config must give bit-identical logits");
        assert_eq!(la.len(), 256);
        assert!(la.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn different_seed_different_logits() {
        let mut a = backend();
        let mut b = CpuBackend::new(CpuModelConfig { seed: 99, ..Default::default() }).unwrap();
        let (la, _) = a.prefill(0, &[1, 2, 3]).unwrap();
        let (lb, _) = b.prefill(0, &[1, 2, 3]).unwrap();
        assert_ne!(la, lb);
    }

    #[test]
    fn prefill_then_decode_matches_longer_prefill() {
        // KV-cache correctness: prefill(p[..n]) + decode(p[n-1]) must
        // reproduce prefill(p[..n]) exactly (same math, same cache).
        let prompt = [10u32, 20, 30, 40, 50];
        let mut a = backend();
        let (logits_full, _) = a.prefill(0, &prompt).unwrap();

        let mut b = backend();
        let (_, _) = b.prefill(1, &prompt[..4]).unwrap();
        let (rows, _) = b
            .decode(&[DecodeEntry { slot: 1, position: 5, token: 50 }])
            .unwrap();
        let diff = max_diff(&logits_full, &rows[0]);
        assert!(diff < 1e-4, "prefill-vs-decode max diff {diff}");
    }

    #[test]
    fn batch_lanes_are_independent() {
        let mut be = backend();
        be.prefill(0, &[1, 2, 3]).unwrap();
        be.prefill(1, &[9, 8, 7, 6]).unwrap();
        let (single, _) = be
            .decode(&[DecodeEntry { slot: 0, position: 4, token: 3 }])
            .unwrap();
        // Redo slot 0's cache state, then decode both lanes together.
        be.prefill(0, &[1, 2, 3]).unwrap();
        let (both, _) = be
            .decode(&[
                DecodeEntry { slot: 0, position: 4, token: 3 },
                DecodeEntry { slot: 1, position: 5, token: 6 },
            ])
            .unwrap();
        assert_eq!(single[0], both[0], "lane 0 must not see lane 1");
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut be = backend();
        assert!(be.prefill(0, &[]).is_err());
        assert!(be.prefill(0, &[300]).is_err(), "token outside vocab");
        assert!(be.decode(&[DecodeEntry { slot: 0, position: 0, token: 1 }]).is_err());
        assert!(CpuBackend::new(CpuModelConfig { d_model: 60, ..Default::default() }).is_err());
        assert!(CpuBackend::new(CpuModelConfig { group_size: 48, ..Default::default() })
            .is_err());
    }

    #[test]
    fn wo_carries_act_order_perm() {
        let be = backend();
        for lw in &be.layers {
            assert!(lw.wo.perm.is_some(), "wo must be an act-order checkpoint");
        }
    }

    #[test]
    fn logits_spread_enough_to_sample() {
        // Degenerate (near-constant) logits would make every request
        // generate the same token forever; check the head discriminates.
        let mut be = backend();
        let (l, _) = be.prefill(0, &[42, 17, 99]).unwrap();
        let lo = l.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(hi - lo > 0.05, "logit range {} too flat", hi - lo);
    }
}

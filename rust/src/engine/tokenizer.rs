//! Byte-level tokenizer for the executable tiny model (vocab = 256).
//!
//! Every byte is a token, so encode/decode is total and lossless — enough
//! to serve real text through the PJRT path without shipping a BPE
//! vocabulary.  A couple of convenience specials live in the printable
//! range the tiny corpus never uses.

/// Byte-level tokenizer (identity over bytes).
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "Hello, Opt4GPTQ!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer;
        let s = "量化 – héllo";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokens_bounded_by_vocab() {
        let t = ByteTokenizer;
        assert!(t.encode("any text at all").iter().all(|&x| x < 256));
    }
}

//! Token sampling: greedy, temperature, top-k (deterministic via seeded
//! RNG per sequence).

use crate::rng::Rng;

use super::request::SamplingParams;

/// Sample the next token from a logits row.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Temperature softmax over the (optionally top-k-truncated) logits.
    // Perf (§Perf item 2): O(V) partition via select_nth_unstable instead
    // of sorting the whole vocabulary — the sampler sits on the per-token
    // hot path.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if params.top_k > 0 && params.top_k < logits.len() {
        let k = params.top_k;
        idx.select_nth_unstable_by(k, |&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        idx.truncate(k);
    }
    let inv_t = 1.0 / params.temperature;
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - max) * inv_t) as f64).exp())
        .collect();
    idx[rng.categorical(&weights)] as u32
}

/// First-max argmax (ties resolve to the lowest index — deterministic).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let p = SamplingParams::default();
        assert_eq!(sample(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn argmax_ties_resolve_low() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(1);
        let logits = vec![10.0, 9.0, -50.0, -60.0];
        let p = SamplingParams { temperature: 1.0, top_k: 2, ..Default::default() };
        for _ in 0..200 {
            let t = sample(&logits, &p, &mut rng);
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(2);
        let logits = vec![1.0, 1.5, 0.5];
        let p = SamplingParams { temperature: 0.05, ..Default::default() };
        let hits = (0..100).filter(|_| sample(&logits, &p, &mut rng) == 1).count();
        assert!(hits > 95, "hits={hits}");
    }

    #[test]
    fn deterministic_given_seed() {
        let logits = vec![0.3, 0.2, 0.9, 0.1];
        let p = SamplingParams { temperature: 0.8, top_k: 3, ..Default::default() };
        let a: Vec<u32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample(&logits, &p, &mut rng)).collect()
        };
        let b: Vec<u32> = {
            let mut rng = Rng::new(9);
            (0..20).map(|_| sample(&logits, &p, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }
}

//! Continuous-batching scheduler (vLLM-style).
//!
//! Policy, mirroring vLLM v0's core loop:
//!
//! 1. Prefill-priority admission: while there is batch room and enough
//!    KV blocks, admit waiting (or preempted) sequences — up to
//!    `max_prefills_per_step` per step.  Admission allocates the block
//!    table the backend will execute through (no backend slots — the
//!    table *is* the sequence's identity in KV storage).
//! 2. Otherwise decode every running sequence as one batch.
//! 3. On KV exhaustion while appending a generated token, preempt the
//!    most recently arrived running sequence (recompute semantics: its
//!    blocks are freed and it re-prefills later with its generated
//!    tokens folded into the prompt).

use std::collections::{HashMap, VecDeque};

use super::block_manager::BlockManager;
use super::request::Request;
use super::sequence::{SeqState, Sequence};
use super::EngineConfig;

pub type SchedulerConfig = EngineConfig;

/// What the engine should run this step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduledWork {
    /// Run these sequences' prompts (then they join the decode batch).
    Prefills(Vec<usize>),
    /// Decode one token for each of these sequences.
    Decode(Vec<usize>),
    /// Nothing runnable (all queues empty).
    Idle,
}

#[derive(Debug)]
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    pub blocks: BlockManager,
    pub seqs: HashMap<usize, Sequence>,
    waiting: VecDeque<usize>,
    running: Vec<usize>,
    pub preemption_count: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            blocks: BlockManager::new(cfg.total_blocks, cfg.block_size),
            seqs: HashMap::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            preemption_count: 0,
            cfg,
        }
    }

    pub fn add_request(&mut self, req: &Request) {
        let seq = Sequence::new(req);
        self.waiting.push_back(seq.id);
        self.seqs.insert(seq.id, seq);
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Decide the next step's work.
    pub fn schedule(&mut self) -> ScheduledWork {
        // Admission: prefill while there is batch room and KV blocks.
        let mut prefills = Vec::new();
        while prefills.len() < self.cfg.max_prefills_per_step
            && self.running.len() + prefills.len() < self.cfg.max_batch
        {
            let Some(&cand) = self.waiting.front() else { break };
            let prompt = self.seqs[&cand].effective_prompt();
            if prompt.len() + 1 > self.cfg.max_seq_len {
                // Oversized request: reject by finishing immediately.
                self.waiting.pop_front();
                let seq = self.seqs.get_mut(&cand).unwrap();
                seq.state = SeqState::Finished;
                continue;
            }
            if !self.blocks.can_allocate(prompt.len() + 1) {
                break; // no KV room; decode instead (frees blocks later)
            }
            self.waiting.pop_front();
            assert!(self.blocks.allocate(cand, &prompt));
            let seq = self.seqs.get_mut(&cand).unwrap();
            seq.state = SeqState::Prefilling;
            prefills.push(cand);
        }
        if !prefills.is_empty() {
            return ScheduledWork::Prefills(prefills);
        }
        if !self.running.is_empty() {
            return ScheduledWork::Decode(self.running.clone());
        }
        if !self.waiting.is_empty() {
            // Nothing running, yet the head of the queue cannot be
            // admitted: only possible when the prompt alone exceeds KV
            // capacity.  Reject it to guarantee progress.
            let id = self.waiting.pop_front().unwrap();
            self.seqs.get_mut(&id).unwrap().state = SeqState::Finished;
            return self.schedule();
        }
        ScheduledWork::Idle
    }

    /// Mark a prefilled sequence as part of the decode batch.
    pub fn promote_to_running(&mut self, id: usize) {
        let seq = self.seqs.get_mut(&id).expect("unknown seq");
        debug_assert_eq!(seq.state, SeqState::Prefilling);
        seq.state = SeqState::Running;
        self.running.push(id);
    }

    /// Reserve KV room for one appended token; preempts the youngest
    /// other running sequence on exhaustion.  Returns false if `id`
    /// itself had to be preempted (no other victim available).
    pub fn append_token(&mut self, id: usize) -> bool {
        loop {
            let total = self.seqs[&id].total_tokens();
            if self.blocks.append_token(id, total) {
                return true;
            }
            // Out of blocks: preempt the most recent *other* running seq.
            let victim = self
                .running
                .iter()
                .copied()
                .filter(|&v| v != id)
                .max_by_key(|&v| {
                    // youngest = largest arrival, break ties by id
                    let s = &self.seqs[&v];
                    (s.arrival.to_bits(), s.id)
                });
            match victim {
                Some(v) => self.preempt(v),
                None => {
                    self.preempt(id);
                    return false;
                }
            }
        }
    }

    fn preempt(&mut self, id: usize) {
        self.running.retain(|&r| r != id);
        self.blocks.free_sequence(id);
        self.seqs.get_mut(&id).expect("unknown seq").preempt();
        self.preemption_count += 1;
        // Preempted sequences go to the *front*: they already hold
        // generated tokens and should resume first (vLLM recompute).
        self.waiting.push_front(id);
    }

    /// Finish a sequence: free its KV blocks (the engine drains the
    /// resulting block/sequence releases to the backend after the step).
    pub fn finish(&mut self, id: usize) {
        self.running.retain(|&r| r != id);
        self.blocks.free_sequence(id);
        self.seqs.get_mut(&id).expect("unknown seq").state = SeqState::Finished;
    }

    /// Property-test hook: internal queues must be consistent.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.blocks.check_invariants()?;
        for &id in &self.running {
            let s = &self.seqs[&id];
            if s.state != SeqState::Running {
                return Err(format!("running seq {id} in state {:?}", s.state));
            }
            if self.blocks.table(id).is_none() {
                return Err(format!("running seq {id} has no block table"));
            }
        }
        // Prefilling sequences occupy batch room too.
        let prefilling =
            self.seqs.values().filter(|s| s.state == SeqState::Prefilling).count();
        if self.running.len() + prefilling > self.cfg.max_batch {
            return Err("decode batch exceeds max_batch".into());
        }
        // Waiting/preempted/finished sequences must hold no KV blocks.
        for (id, s) in &self.seqs {
            let holds_blocks = self.blocks.table(*id).is_some();
            let may_hold =
                matches!(s.state, SeqState::Running | SeqState::Prefilling);
            if holds_blocks && !may_hold {
                return Err(format!("seq {id} in state {:?} still holds blocks", s.state));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::SamplingParams;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            max_batch: 4,
            block_size: 4,
            total_blocks: 16,
            max_seq_len: 64,
            max_prefills_per_step: 2,
        }
    }

    fn req(id: usize, prompt_len: usize, max_tokens: usize) -> Request {
        Request::new(
            id,
            vec![7; prompt_len],
            SamplingParams { max_tokens, ..Default::default() },
        )
    }

    #[test]
    fn admits_up_to_max_prefills() {
        let mut s = Scheduler::new(cfg());
        for i in 0..3 {
            s.add_request(&req(i, 4, 4));
        }
        match s.schedule() {
            ScheduledWork::Prefills(p) => assert_eq!(p, vec![0, 1]),
            w => panic!("expected prefills, got {w:?}"),
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn decodes_after_promotion() {
        let mut s = Scheduler::new(cfg());
        s.add_request(&req(0, 4, 4));
        let ScheduledWork::Prefills(p) = s.schedule() else { panic!() };
        for id in p {
            s.seqs.get_mut(&id).unwrap().generated.push(1);
            assert!(s.append_token(id));
            s.promote_to_running(id);
        }
        // no more waiting -> decode
        match s.schedule() {
            ScheduledWork::Decode(d) => assert_eq!(d, vec![0]),
            w => panic!("expected decode, got {w:?}"),
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn oversized_prompt_is_rejected_not_deadlocked() {
        let mut s = Scheduler::new(cfg());
        s.add_request(&req(0, 100, 4)); // exceeds max_seq_len
        assert_eq!(s.schedule(), ScheduledWork::Idle);
        assert_eq!(s.seqs[&0].state, SeqState::Finished);
    }

    #[test]
    fn kv_exhaustion_preempts_youngest() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            block_size: 4,
            total_blocks: 4,
            max_seq_len: 64,
            max_prefills_per_step: 2,
        });
        // Distinct prompt contents so the prefix cache cannot share blocks.
        let mut r0 = req(0, 7, 30);
        r0.prompt = vec![1; 7];
        let mut r1 = req(1, 7, 30);
        r1.prompt = vec![2; 7];
        s.add_request(&Request { arrival: 0.0, ..r0 });
        s.add_request(&Request { arrival: 1.0, ..r1 });
        let ScheduledWork::Prefills(p) = s.schedule() else { panic!() };
        assert_eq!(p.len(), 2);
        for id in p {
            s.seqs.get_mut(&id).unwrap().generated.push(1);
            assert!(s.append_token(id));
            s.promote_to_running(id);
        }
        // Each seq has 8 tokens in 2 blocks; all 4 blocks used.  The next
        // append on seq 0 must preempt seq 1 (younger).
        s.seqs.get_mut(&0).unwrap().generated.push(2);
        assert!(s.append_token(0));
        assert_eq!(s.seqs[&1].state, SeqState::Preempted);
        assert_eq!(s.num_running(), 1);
        assert_eq!(s.preemption_count, 1);
        s.check_invariants().unwrap();
        // Preempted sequence re-queues at the front with its tokens.
        assert_eq!(s.num_waiting(), 1);
        assert_eq!(s.seqs[&1].effective_prompt().len(), 8);
    }

    #[test]
    fn finish_releases_blocks_and_reports_them() {
        let mut s = Scheduler::new(cfg());
        s.add_request(&req(0, 4, 4));
        let ScheduledWork::Prefills(_) = s.schedule() else { panic!() };
        let free_before = s.blocks.free_blocks();
        s.promote_to_running(0);
        s.blocks.take_released(); // discard pre-finish noise
        s.finish(0);
        assert!(s.blocks.free_blocks() > free_before);
        assert_eq!(s.num_running(), 0);
        let (freed, seqs) = s.blocks.take_released();
        assert!(!freed.is_empty(), "finish must report physically freed blocks");
        assert_eq!(seqs, vec![0]);
        s.check_invariants().unwrap();
        // batch room is reusable
        s.add_request(&req(5, 4, 4));
        assert!(matches!(s.schedule(), ScheduledWork::Prefills(_)));
    }
}

//! Continuous-batching scheduler (vLLM-style, chunked-prefill mode).
//!
//! Every engine step is one **mixed batch**: the full decode batch plus
//! as many prefill chunk tokens as the per-step token budget
//! (`prefill_budget`) allows, executed by the backend in a single call.
//! Policy:
//!
//! 1. Continue partially-prefilled sequences first (one block-aligned
//!    chunk each, in admission order), then admit queued sequences while
//!    budget and batch room remain.  Admission order is priority (higher
//!    first), then resumed victims ahead of fresh peers, then FCFS by
//!    arrival, then id.  A fresh prompt is additionally held back by a
//!    **fairness guard**: it is only admitted when, after its
//!    allocation, every running decode could still append one token —
//!    so a prefill wave cannot starve the decode batch into a
//!    preemption storm (resumed victims are exempt; they must get back
//!    in to make progress).  Admission allocates the block table the
//!    backend will execute through, and the allocator reports
//!    `cached_len` — the leading tokens whose K/V already live in
//!    fully-computed shared prefix blocks.  With `prefix_skip` on,
//!    those tokens are *never sent to the backend*: the first chunk
//!    starts at `cached_len` (clamped to keep at least the final token
//!    computable for logits).
//! 2. Chunk bounds are block-aligned whenever that still makes progress
//!    (a budget smaller than the block size degrades to unaligned but
//!    still bit-identical chunks).
//! 3. On KV exhaustion while appending a generated token, preempt the
//!    lowest-priority, most recently arrived running or prefilling
//!    sequence whose priority does not exceed the appender's.  With
//!    [`EngineConfig::swap_preempt`] on (the default), the victim's K/V
//!    is **swapped out** — the block manager logs its table for the
//!    engine to spill, and the sequence keeps its exact prefill cursor,
//!    so the resume restores the spill onto fresh blocks and recomputes
//!    nothing.  With it off (or when the victim has nothing
//!    materialized), classic recompute: blocks freed, progress reset,
//!    generated tokens folded into the prompt for re-prefill.

use std::cmp::Reverse;
use std::collections::HashMap;

use super::block_manager::BlockManager;
use super::fault::{FaultSchedule, FaultSeam};
use super::request::Request;
use super::sequence::{SeqState, Sequence};
use super::EngineConfig;

pub type SchedulerConfig = EngineConfig;

/// One prefill chunk scheduled for the coming step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillChunk {
    pub seq_id: usize,
    /// Position of the chunk's first token (cached prefix + prior
    /// chunks).
    pub start: usize,
    /// Tokens in this chunk (≥ 1).
    pub len: usize,
    /// True when the chunk reaches the end of the effective prompt.
    pub is_last: bool,
}

/// What the engine should run this step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduledWork {
    /// One mixed backend step: prefill chunks under the token budget
    /// plus the whole decode batch (either part may be empty, not both).
    Step { prefills: Vec<PrefillChunk>, decodes: Vec<usize> },
    /// Nothing runnable (all queues empty).
    Idle,
}

#[derive(Debug)]
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    pub blocks: BlockManager,
    pub seqs: HashMap<usize, Sequence>,
    /// Queued sequence ids (fresh, preempted, and swapped alike);
    /// re-sorted into admission order at the top of every [`schedule`].
    waiting: Vec<usize>,
    running: Vec<usize>,
    /// Admitted sequences whose prompts are mid-prefill, in admission
    /// order (each gets at most one chunk per step).
    prefilling: Vec<usize>,
    pub preemption_count: usize,
    /// Prompt tokens never sent to the backend because their K/V was
    /// already cached (summed over all admissions).
    pub prefill_tokens_skipped: usize,
    /// Preemptions that spilled K/V instead of discarding it.
    pub swap_out_count: usize,
    /// Swap-outs that hit a sequence mid-prefill / mid-decode.
    pub swap_out_mid_prefill: usize,
    pub swap_out_mid_decode: usize,
    /// Swapped victims resumed by restoring their spill.
    pub swap_in_count: usize,
    /// Tokens whose K/V was restored from spill rather than recomputed
    /// (summed over all swap-ins).
    pub swap_restored_tokens: usize,
    /// Deterministic fault plan ([`super::fault`]): the scheduler owns
    /// the per-run draw state so every seam — here and in the engine —
    /// consumes one replayable stream.
    pub faults: FaultSchedule,
    /// Sequences resolved as Rejected since the last
    /// [`Scheduler::take_rejected`] drain, with the typed reason
    /// (oversized / never-fitting / shed).
    rejected: Vec<(usize, String)>,
    /// Fresh requests shed from the bounded waiting queue.
    pub shed_count: usize,
    /// An injected allocation refusal stalled the current `schedule`
    /// pass: the empty step is a transient fault, not a capacity proof,
    /// so the progress-guarantee reject must not fire.
    fault_stalled: bool,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            blocks: BlockManager::new(cfg.total_blocks, cfg.block_size),
            seqs: HashMap::new(),
            waiting: Vec::new(),
            running: Vec::new(),
            prefilling: Vec::new(),
            preemption_count: 0,
            prefill_tokens_skipped: 0,
            swap_out_count: 0,
            swap_out_mid_prefill: 0,
            swap_out_mid_decode: 0,
            swap_in_count: 0,
            swap_restored_tokens: 0,
            faults: FaultSchedule::new(cfg.faults),
            rejected: Vec::new(),
            shed_count: 0,
            fault_stalled: false,
            cfg,
        }
    }

    pub fn add_request(&mut self, req: &Request) {
        let seq = Sequence::new(req);
        self.waiting.push(seq.id);
        self.seqs.insert(seq.id, seq);
        // Bounded waiting queue with priority load-shedding: only
        // *fresh* requests count against (and may be shed from) the
        // bound — preempted/swapped re-entries must always requeue, or
        // eviction would become silent request loss.  The shed victim is
        // the least valuable fresh waiter (lowest priority, then
        // youngest arrival, then largest id) — possibly the newcomer.
        let fresh: Vec<usize> = self
            .waiting
            .iter()
            .copied()
            .filter(|w| self.seqs[w].state == SeqState::Waiting)
            .collect();
        if fresh.len() > self.cfg.max_waiting {
            let &victim = fresh
                .iter()
                .max_by_key(|&&w| {
                    let s = &self.seqs[&w];
                    (Reverse(s.priority), s.arrival.to_bits(), s.id)
                })
                .expect("fresh is nonempty past the bound");
            self.waiting.retain(|&w| w != victim);
            self.shed_count += 1;
            self.reject(
                victim,
                format!("shed: waiting queue full (max_waiting={})", self.cfg.max_waiting),
            );
        }
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn num_prefilling(&self) -> usize {
        self.prefilling.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty() || !self.prefilling.is_empty()
    }

    /// The next block-aligned chunk of `id`'s prompt under `budget`
    /// remaining tokens (caller guarantees `budget >= 1` and that the
    /// sequence has prefill work left).
    fn next_chunk(&self, id: usize, budget: usize) -> PrefillChunk {
        let seq = &self.seqs[&id];
        let pos = seq.prefill_pos;
        let prompt_len = seq.total_tokens();
        debug_assert!(pos < prompt_len, "chunking a completed prefill");
        let mut end = pos + (prompt_len - pos).min(budget);
        if end < prompt_len {
            // Align the boundary down to a block edge when that still
            // makes progress; tiny budgets (< block_size) proceed
            // unaligned rather than stalling.
            let aligned = end - end % self.cfg.block_size;
            if aligned > pos {
                end = aligned;
            }
        }
        PrefillChunk { seq_id: id, start: pos, len: end - pos, is_last: end == prompt_len }
    }

    /// Decide the next step's work.  `now` is the engine clock, stamped
    /// onto each sequence's first admission for queue-time accounting.
    pub fn schedule(&mut self, now: f64) -> ScheduledWork {
        self.fault_stalled = false;
        // Admission order: priority (higher first), resumed victims
        // ahead of fresh peers, then FCFS by arrival, then id.  The
        // sort key is total and deterministic (ids are unique).
        self.waiting.sort_by_key(|&id| {
            let s = &self.seqs[&id];
            let fresh = (s.state == SeqState::Waiting) as u8;
            (Reverse(s.priority), fresh, s.arrival.to_bits(), s.id)
        });
        let mut budget = self.cfg.prefill_budget.max(1);
        let mut prefills = Vec::new();
        // 1. Continue in-flight prefills, one chunk each.
        for &id in &self.prefilling {
            if budget == 0 {
                break;
            }
            let chunk = self.next_chunk(id, budget);
            budget -= chunk.len;
            prefills.push(chunk);
        }
        // 2. Admit queued sequences while budget and batch room remain.
        while budget > 0 && self.running.len() + self.prefilling.len() < self.cfg.max_batch {
            let Some(&cand) = self.waiting.first() else { break };
            if self.faults.fire(FaultSeam::Alloc) {
                // Injected block-allocation refusal: defer this
                // admission wave exactly as a full pool would.  The
                // stall flag keeps the progress-guarantee reject from
                // mistaking the transient fault for a capacity proof.
                self.fault_stalled = true;
                break;
            }
            if self.seqs[&cand].state == SeqState::Swapped {
                // Resume a swapped victim: fresh blocks, spill restored
                // by the engine before the step, cursor untouched.
                let total = self.seqs[&cand].total_tokens();
                if !self.blocks.can_swap_in(cand, total) {
                    break; // no KV room; decodes will free blocks later
                }
                self.waiting.remove(0);
                assert!(self.blocks.swap_in(cand, total), "can_swap_in checked");
                self.swap_in_count += 1;
                let seq = self.seqs.get_mut(&cand).unwrap();
                seq.state = SeqState::Prefilling;
                seq.admitted_time.get_or_insert(now);
                self.swap_restored_tokens += seq.prefill_pos;
                self.prefilling.push(cand);
                let chunk = self.next_chunk(cand, budget);
                budget -= chunk.len;
                prefills.push(chunk);
                continue;
            }
            let fresh = self.seqs[&cand].state == SeqState::Waiting;
            let prompt = self.seqs[&cand].effective_prompt();
            if prompt.len() + 1 > self.cfg.max_seq_len {
                // Oversized request: reject by finishing immediately.
                self.waiting.remove(0);
                let reason = format!(
                    "oversized: {} effective prompt tokens + 1 generated exceed max_seq_len {}",
                    prompt.len(),
                    self.cfg.max_seq_len
                );
                self.reject(cand, reason);
                continue;
            }
            if !self.blocks.can_allocate(prompt.len() + 1) {
                break; // no KV room; decodes will free blocks later
            }
            // Fairness guard: admit a *fresh* prompt only if, after its
            // allocation, every running decode could still append one
            // token.  Resumed (preempted) victims are exempt.
            if fresh
                && self.blocks.blocks_needed(prompt.len() + 1) + self.running.len()
                    > self.blocks.free_blocks()
            {
                break;
            }
            self.waiting.remove(0);
            let cached = self.blocks.allocate(cand, &prompt).expect("can_allocate checked");
            // Keep at least the final prompt token computable: its
            // hidden state feeds the lm_head for the first sampled
            // token.  With prefix_skip off, recompute everything (the
            // blocks are still shared — memory wins survive).
            let cached =
                if self.cfg.prefix_skip { cached.min(prompt.len().saturating_sub(1)) } else { 0 };
            self.prefill_tokens_skipped += cached;
            let seq = self.seqs.get_mut(&cand).unwrap();
            seq.state = SeqState::Prefilling;
            seq.admitted_time.get_or_insert(now);
            seq.cached_len = cached;
            seq.prefill_pos = cached;
            self.prefilling.push(cand);
            let chunk = self.next_chunk(cand, budget);
            budget -= chunk.len;
            prefills.push(chunk);
        }
        let decodes = self.running.clone();
        if prefills.is_empty() && decodes.is_empty() {
            if !self.waiting.is_empty() {
                if self.fault_stalled {
                    // The empty step came from an injected allocation
                    // refusal, not a capacity proof: idle this step and
                    // let the engine's backoff retry admission.
                    return ScheduledWork::Idle;
                }
                // Nothing running, yet the head of the queue cannot be
                // admitted: the prompt (or a swapped victim's grown
                // table) exceeds KV capacity outright.  Reject it to
                // guarantee progress.
                let id = self.waiting.remove(0);
                let s = &self.seqs[&id];
                let needed = self.blocks.blocks_needed(s.total_tokens() + 1);
                let reason = format!(
                    "cannot ever fit: needs {needed} KV blocks, pool holds {}",
                    self.cfg.total_blocks
                );
                self.reject(id, reason);
                return self.schedule(now);
            }
            return ScheduledWork::Idle;
        }
        ScheduledWork::Step { prefills, decodes }
    }

    /// Reject a queued sequence outright (oversized, provably never
    /// admittable, or shed from a full waiting queue): any blocks/spill
    /// are retired, the typed reason is logged for the engine to drain
    /// into a [`super::RequestOutcome::Rejected`], and the sequence
    /// finishes with whatever it generated.
    fn reject(&mut self, id: usize, reason: String) {
        self.blocks.free_sequence(id);
        self.seqs.get_mut(&id).expect("unknown seq").state = SeqState::Finished;
        self.rejected.push((id, reason));
    }

    /// Drain the typed rejections since the last call (the engine turns
    /// each into a `RequestOutcome::Rejected` and a metrics tick).
    pub fn take_rejected(&mut self) -> Vec<(usize, String)> {
        std::mem::take(&mut self.rejected)
    }

    /// Exact queue membership, in stored order, for checkpointing:
    /// `(waiting, running, prefilling)`.  Waiting order is re-derived by
    /// the deterministic admission sort anyway, but running/prefilling
    /// order is load-bearing (decode batch layout, chunk rotation), so
    /// all three round-trip verbatim.  Refuses while undrained
    /// rejections exist — a snapshot must not silently drop them.
    pub fn export_queues(&self) -> Result<(Vec<usize>, Vec<usize>, Vec<usize>), String> {
        if !self.rejected.is_empty() {
            return Err("cannot snapshot with undrained rejections".into());
        }
        Ok((self.waiting.clone(), self.running.clone(), self.prefilling.clone()))
    }

    /// Rehydrate queue membership from a snapshot (the restore path).
    /// The caller installs `seqs` and `blocks` first; membership is
    /// validated against them via [`Scheduler::check_invariants`].
    pub fn import_queues(
        &mut self,
        waiting: Vec<usize>,
        running: Vec<usize>,
        prefilling: Vec<usize>,
    ) -> Result<(), String> {
        for &id in waiting.iter().chain(&running).chain(&prefilling) {
            if !self.seqs.contains_key(&id) {
                return Err(format!("snapshot queues reference unknown seq {id}"));
            }
        }
        self.waiting = waiting;
        self.running = running;
        self.prefilling = prefilling;
        self.check_invariants()
            .map_err(|e| format!("snapshot scheduler state invalid: {e}"))
    }

    /// Retire a sequence from every queue with full block/spill
    /// reclamation — the deadline-cancel and permanent-failure path.
    /// The engine drains the resulting block/sequence releases to the
    /// backend after the step and records the outcome (TimedOut or
    /// Failed) itself.
    pub fn retire(&mut self, id: usize) {
        self.waiting.retain(|&w| w != id);
        self.running.retain(|&r| r != id);
        self.prefilling.retain(|&p| p != id);
        self.blocks.free_sequence(id);
        self.seqs.get_mut(&id).expect("unknown seq").state = SeqState::Finished;
    }

    /// A swap-out's spill write failed before any bytes moved: forget
    /// the spill reservation and demote the victim (already queued by
    /// the preemption) to a recompute — its K/V is gone, so resuming at
    /// the frozen cursor would read garbage.
    pub fn demote_swap(&mut self, id: usize) {
        assert!(self.blocks.abort_swap(id), "demoting a non-swapped sequence");
        let seq = self.seqs.get_mut(&id).expect("unknown seq");
        debug_assert_eq!(seq.state, SeqState::Swapped);
        seq.demote_to_recompute();
    }

    /// A swapped victim's restore failed after re-admission: free the
    /// freshly-allocated table, demote to recompute and requeue.  The
    /// engine drops the backend's (unusable) spill entry itself.
    pub fn fail_restore(&mut self, id: usize) {
        self.prefilling.retain(|&p| p != id);
        self.blocks.free_sequence(id);
        let seq = self.seqs.get_mut(&id).expect("unknown seq");
        // The restore never happened: take the restored-token credit
        // back so the swap stats stay honest.
        self.swap_restored_tokens -= seq.prefill_pos;
        seq.demote_to_recompute();
        self.waiting.push(id);
    }

    /// Record that a chunk executed: advance the sequence's prefill
    /// cursor and mark the blocks it fully covered as computed (so
    /// future prefix-cache hits on them can skip recomputation).
    pub fn advance_prefill(&mut self, chunk: &PrefillChunk) {
        let seq = self.seqs.get_mut(&chunk.seq_id).expect("unknown seq");
        debug_assert_eq!(seq.state, SeqState::Prefilling);
        debug_assert_eq!(seq.prefill_pos, chunk.start);
        seq.prefill_pos += chunk.len;
        self.blocks.mark_computed(chunk.seq_id, seq.prefill_pos);
    }

    /// Mark a fully-prefilled sequence as part of the decode batch
    /// (called after its first token was sampled and appended, so
    /// exactly that one token is still un-materialized — the next
    /// decode step feeds it).
    pub fn promote_to_running(&mut self, id: usize) {
        self.prefilling.retain(|&p| p != id);
        let seq = self.seqs.get_mut(&id).expect("unknown seq");
        debug_assert_eq!(seq.state, SeqState::Prefilling);
        debug_assert_eq!(seq.prefill_remaining(), 1, "promoting a mid-prefill sequence");
        seq.state = SeqState::Running;
        self.running.push(id);
    }

    /// Reserve KV room for one appended token; preempts the
    /// lowest-priority, youngest other running or prefilling sequence
    /// on exhaustion — never one of strictly higher priority than the
    /// appender.  Returns false if `id` itself had to be preempted (no
    /// eligible victim available).
    pub fn append_token(&mut self, id: usize) -> bool {
        let appender_priority = self.seqs[&id].priority;
        // Injected allocation refusal: treat exactly one allocator call
        // as failed, driving the identical preemption machinery a full
        // pool would.
        let mut injected = self.faults.fire(FaultSeam::Alloc);
        loop {
            let total = self.seqs[&id].total_tokens();
            if !injected && self.blocks.append_token(id, total) {
                return true;
            }
            injected = false;
            // Out of blocks: evict the least-valuable *other* victim.
            let victim = self
                .running
                .iter()
                .chain(self.prefilling.iter())
                .copied()
                .filter(|&v| v != id && self.seqs[&v].priority <= appender_priority)
                .min_by_key(|&v| {
                    // lowest priority, then youngest (largest arrival),
                    // then largest id
                    let s = &self.seqs[&v];
                    (s.priority, Reverse(s.arrival.to_bits()), Reverse(s.id))
                });
            match victim {
                Some(v) => self.preempt(v),
                None => {
                    self.preempt(id);
                    return false;
                }
            }
        }
    }

    /// Transient-step recovery: the engine discarded a failed step's
    /// output, so every batch member still live is preempted through
    /// the regular swap/recompute machinery — the retry then resumes
    /// them exactly like any other preemption victim, which is what
    /// keeps the eventually-completed tokens bit-identical to a
    /// fault-free run.
    pub fn preempt_for_retry(&mut self, ids: &[usize]) {
        for &id in ids {
            if self.running.contains(&id) || self.prefilling.contains(&id) {
                self.preempt(id);
            }
        }
    }

    fn preempt(&mut self, id: usize) {
        self.running.retain(|&r| r != id);
        self.prefilling.retain(|&p| p != id);
        let seq = self.seqs.get_mut(&id).expect("unknown seq");
        // Tokens whose K/V is actually materialized (a decode victim's
        // last sampled token never was; a prefill victim stops at its
        // cursor).  Nothing materialized → spilling is pointless, fall
        // back to recompute even in swap mode.
        let materialized = match seq.state {
            SeqState::Prefilling => seq.prefill_pos,
            _ => seq.total_tokens() - 1,
        };
        if self.cfg.swap_preempt && materialized > 0 {
            if seq.state == SeqState::Prefilling {
                self.swap_out_mid_prefill += 1;
            } else {
                self.swap_out_mid_decode += 1;
            }
            seq.swap_out();
            // Logs the table for the engine to spill *before* the freed
            // blocks can be poisoned or rewritten.
            self.blocks.swap_out(id);
            self.swap_out_count += 1;
        } else {
            seq.preempt();
            self.blocks.free_sequence(id);
        }
        self.preemption_count += 1;
        // Re-queue; the admission sort puts resumed victims ahead of
        // fresh peers of equal priority (vLLM resume-first).
        self.waiting.push(id);
    }

    /// Finish a sequence: free its KV blocks (the engine drains the
    /// resulting block/sequence releases to the backend after the step).
    pub fn finish(&mut self, id: usize) {
        self.running.retain(|&r| r != id);
        self.prefilling.retain(|&p| p != id);
        self.blocks.free_sequence(id);
        self.seqs.get_mut(&id).expect("unknown seq").state = SeqState::Finished;
    }

    /// Property-test hook: internal queues must be consistent.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.blocks.check_invariants()?;
        for &id in &self.running {
            let s = &self.seqs[&id];
            if s.state != SeqState::Running {
                return Err(format!("running seq {id} in state {:?}", s.state));
            }
            if self.blocks.table(id).is_none() {
                return Err(format!("running seq {id} has no block table"));
            }
        }
        for &id in &self.prefilling {
            let s = &self.seqs[&id];
            if s.state != SeqState::Prefilling {
                return Err(format!("prefilling seq {id} in state {:?}", s.state));
            }
            if self.blocks.table(id).is_none() {
                return Err(format!("prefilling seq {id} has no block table"));
            }
            if s.prefill_pos < s.cached_len {
                return Err(format!("seq {id}: prefill_pos behind cached_len"));
            }
        }
        // Every Prefilling-state sequence must be tracked in the list.
        let prefilling =
            self.seqs.values().filter(|s| s.state == SeqState::Prefilling).count();
        if prefilling != self.prefilling.len() {
            return Err(format!(
                "{} sequences in Prefilling state but {} tracked",
                prefilling,
                self.prefilling.len()
            ));
        }
        // Prefilling sequences occupy batch room too.
        if self.running.len() + self.prefilling.len() > self.cfg.max_batch {
            return Err("decode batch exceeds max_batch".into());
        }
        // Waiting/preempted/swapped/finished sequences must hold no KV
        // blocks; swapped ones must be queued with a live spill record.
        for (id, s) in &self.seqs {
            let holds_blocks = self.blocks.table(*id).is_some();
            let may_hold =
                matches!(s.state, SeqState::Running | SeqState::Prefilling);
            if holds_blocks && !may_hold {
                return Err(format!("seq {id} in state {:?} still holds blocks", s.state));
            }
            if s.state == SeqState::Swapped {
                if !self.waiting.contains(id) {
                    return Err(format!("swapped seq {id} not in waiting queue"));
                }
                if !self.blocks.is_swapped(*id) {
                    return Err(format!("swapped seq {id} has no spill record"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::fault::FaultPlan;
    use crate::engine::request::SamplingParams;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            max_batch: 4,
            block_size: 4,
            total_blocks: 16,
            max_seq_len: 64,
            prefill_budget: 8,
            // Pinned on purpose: these are unit tests OF the skip and
            // recompute mechanisms, independent of the
            // OPT4GPTQ_PREFIX_SKIP / OPT4GPTQ_SWAP / OPT4GPTQ_FAULTS
            // env hatches.
            prefix_skip: true,
            swap_preempt: false,
            kv_dtype: super::KvDtype::F32,
            max_waiting: usize::MAX,
            faults: FaultPlan::NONE,
        }
    }

    fn req(id: usize, prompt_len: usize, max_tokens: usize) -> Request {
        Request::new(
            id,
            vec![7; prompt_len],
            SamplingParams { max_tokens, ..Default::default() },
        )
    }

    /// Drive every scheduled chunk to completion as the engine would,
    /// without a backend: advance, then (on last chunks) append the
    /// first sampled token and promote.
    fn run_prefills(s: &mut Scheduler, prefills: &[PrefillChunk]) {
        for c in prefills {
            s.advance_prefill(c);
            if c.is_last {
                s.seqs.get_mut(&c.seq_id).unwrap().generated.push(1);
                assert!(s.append_token(c.seq_id));
                s.promote_to_running(c.seq_id);
            }
        }
    }

    #[test]
    fn admits_under_token_budget() {
        let mut s = Scheduler::new(cfg());
        for i in 0..3 {
            s.add_request(&req(i, 4, 4));
        }
        // Budget 8 = two 4-token prompts; the third waits.
        match s.schedule(0.0) {
            ScheduledWork::Step { prefills, decodes } => {
                assert_eq!(
                    prefills,
                    vec![
                        PrefillChunk { seq_id: 0, start: 0, len: 4, is_last: true },
                        PrefillChunk { seq_id: 1, start: 0, len: 4, is_last: true },
                    ]
                );
                assert!(decodes.is_empty());
            }
            w => panic!("expected step, got {w:?}"),
        }
        assert_eq!(s.num_waiting(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn long_prompt_is_chunked_block_aligned_across_steps() {
        let mut s = Scheduler::new(SchedulerConfig { max_seq_len: 64, ..cfg() });
        s.add_request(&req(0, 10, 4)); // 10 tokens, budget 8, block 4
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 0, start: 0, len: 8, is_last: false }]);
        run_prefills(&mut s, &prefills);
        s.check_invariants().unwrap();
        // Next step finishes the prompt (2 remaining) and has room to
        // admit more — none waiting, so just the tail chunk.
        let ScheduledWork::Step { prefills, decodes } = s.schedule(0.0) else { panic!() };
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 0, start: 8, len: 2, is_last: true }]);
        assert!(decodes.is_empty());
        run_prefills(&mut s, &prefills);
        // Fully prefilled: next step is a pure decode.
        let ScheduledWork::Step { prefills, decodes } = s.schedule(0.0) else { panic!() };
        assert!(prefills.is_empty());
        assert_eq!(decodes, vec![0]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn budget_below_block_size_still_progresses() {
        let mut s = Scheduler::new(SchedulerConfig { prefill_budget: 3, ..cfg() });
        s.add_request(&req(0, 6, 4));
        let mut starts = Vec::new();
        loop {
            let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
            if prefills.is_empty() {
                break;
            }
            assert_eq!(prefills.len(), 1);
            assert!(prefills[0].len <= 3);
            starts.push((prefills[0].start, prefills[0].len));
            let done = prefills[0].is_last;
            run_prefills(&mut s, &prefills);
            if done {
                break;
            }
        }
        // 6 tokens under budget 3: every token is scheduled exactly
        // once, in order, with no chunk exceeding the budget.
        let total: usize = starts.iter().map(|(_, l)| l).sum();
        assert_eq!(total, 6);
        assert_eq!(starts.first().unwrap().0, 0);
        for w in starts.windows(2) {
            assert_eq!(w[0].0 + w[0].1, w[1].0, "chunks must be contiguous");
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn decodes_mix_with_prefill_chunks() {
        let mut s = Scheduler::new(cfg());
        s.add_request(&req(0, 4, 4));
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        run_prefills(&mut s, &prefills);
        // Seq 0 is decoding; a new long prompt arrives: one mixed step.
        // Distinct content — no prefix sharing with seq 0's blocks.
        let mut r1 = req(1, 10, 4);
        r1.prompt = (100..110).collect();
        s.add_request(&r1);
        let ScheduledWork::Step { prefills, decodes } = s.schedule(0.0) else { panic!() };
        assert_eq!(decodes, vec![0]);
        assert_eq!(prefills.len(), 1);
        assert_eq!(prefills[0].seq_id, 1);
        assert!(!prefills[0].is_last, "10 tokens under budget 8 must chunk");
        s.check_invariants().unwrap();
    }

    #[test]
    fn cached_prefix_is_skipped_at_admission() {
        let mut s = Scheduler::new(SchedulerConfig { prefill_budget: 64, ..cfg() });
        s.add_request(&req(0, 10, 4)); // 2 full blocks + tail
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        assert_eq!(prefills[0], PrefillChunk { seq_id: 0, start: 0, len: 10, is_last: true });
        run_prefills(&mut s, &prefills);
        assert_eq!(s.prefill_tokens_skipped, 0);
        // Identical prompt: the two full blocks are computed now, so the
        // second sequence's first chunk starts at 8.
        s.add_request(&req(1, 10, 4));
        let ScheduledWork::Step { prefills, decodes } = s.schedule(0.0) else { panic!() };
        assert_eq!(decodes, vec![0]);
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 1, start: 8, len: 2, is_last: true }]);
        assert_eq!(s.prefill_tokens_skipped, 8);
        assert_eq!(s.seqs[&1].cached_len, 8);
        s.check_invariants().unwrap();
    }

    #[test]
    fn fully_cached_prompt_still_computes_the_last_token() {
        let mut s = Scheduler::new(SchedulerConfig { prefill_budget: 64, ..cfg() });
        let mut r0 = req(0, 8, 4); // exactly 2 full blocks
        r0.prompt = (0..8).collect();
        s.add_request(&r0);
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        run_prefills(&mut s, &prefills);
        let mut r1 = req(1, 8, 4);
        r1.prompt = (0..8).collect();
        s.add_request(&r1);
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        // Whole prompt cached: clamp keeps the final token computable.
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 1, start: 7, len: 1, is_last: true }]);
        assert_eq!(s.prefill_tokens_skipped, 7);
        s.check_invariants().unwrap();
    }

    #[test]
    fn prefix_skip_off_recomputes_everything() {
        let mut s = Scheduler::new(SchedulerConfig {
            prefill_budget: 64,
            prefix_skip: false,
            ..cfg()
        });
        s.add_request(&req(0, 10, 4));
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        run_prefills(&mut s, &prefills);
        s.add_request(&req(1, 10, 4));
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 1, start: 0, len: 10, is_last: true }]);
        assert_eq!(s.prefill_tokens_skipped, 0, "escape hatch must force full recompute");
        s.check_invariants().unwrap();
    }

    #[test]
    fn oversized_prompt_is_rejected_not_deadlocked() {
        let mut s = Scheduler::new(cfg());
        s.add_request(&req(0, 100, 4)); // exceeds max_seq_len
        assert_eq!(s.schedule(0.0), ScheduledWork::Idle);
        assert_eq!(s.seqs[&0].state, SeqState::Finished);
    }

    #[test]
    fn kv_exhaustion_preempts_youngest_and_resets_progress() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            block_size: 4,
            total_blocks: 4,
            max_seq_len: 64,
            prefill_budget: 32,
            prefix_skip: true,
            swap_preempt: false, // this test pins recompute semantics
            kv_dtype: super::KvDtype::F32,
            max_waiting: usize::MAX,
            faults: FaultPlan::NONE,
        });
        // Distinct prompt contents so the prefix cache cannot share blocks.
        let mut r0 = req(0, 7, 30);
        r0.prompt = vec![1; 7];
        let mut r1 = req(1, 7, 30);
        r1.prompt = vec![2; 7];
        s.add_request(&Request { arrival: 0.0, ..r0 });
        s.add_request(&Request { arrival: 1.0, ..r1 });
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        assert_eq!(prefills.len(), 2);
        run_prefills(&mut s, &prefills);
        // Each seq has 8 tokens in 2 blocks; all 4 blocks used.  The next
        // append on seq 0 must preempt seq 1 (younger).
        s.seqs.get_mut(&0).unwrap().generated.push(2);
        assert!(s.append_token(0));
        assert_eq!(s.seqs[&1].state, SeqState::Preempted);
        assert_eq!(s.num_running(), 1);
        assert_eq!(s.preemption_count, 1);
        s.check_invariants().unwrap();
        // Preempted sequence re-queues at the front with its tokens and
        // zeroed prefill progress.
        assert_eq!(s.num_waiting(), 1);
        assert_eq!(s.seqs[&1].effective_prompt().len(), 8);
        assert_eq!(s.seqs[&1].prefill_pos, 0);
    }

    #[test]
    fn finish_releases_blocks_and_reports_them() {
        let mut s = Scheduler::new(cfg());
        s.add_request(&req(0, 4, 4));
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        let free_before = s.blocks.free_blocks();
        run_prefills(&mut s, &prefills);
        s.blocks.take_released(); // discard pre-finish noise
        s.finish(0);
        assert!(s.blocks.free_blocks() > free_before);
        assert_eq!(s.num_running(), 0);
        let (freed, seqs) = s.blocks.take_released();
        assert!(!freed.is_empty(), "finish must report physically freed blocks");
        assert_eq!(seqs, vec![0]);
        s.check_invariants().unwrap();
        // batch room is reusable
        s.add_request(&req(5, 4, 4));
        assert!(matches!(s.schedule(0.0), ScheduledWork::Step { .. }));
    }

    #[test]
    fn swap_preempt_keeps_progress_and_resumes_without_recompute() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            block_size: 4,
            total_blocks: 4,
            max_seq_len: 64,
            prefill_budget: 32,
            prefix_skip: true,
            swap_preempt: true,
            kv_dtype: super::KvDtype::F32,
            max_waiting: usize::MAX,
            faults: FaultPlan::NONE,
        });
        let mut r0 = req(0, 7, 30);
        r0.prompt = vec![1; 7];
        let mut r1 = req(1, 7, 30);
        r1.prompt = vec![2; 7];
        s.add_request(&Request { arrival: 0.0, ..r0 });
        s.add_request(&Request { arrival: 1.0, ..r1 });
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        run_prefills(&mut s, &prefills);
        // All 4 blocks used; appending to seq 0 evicts seq 1 — but as a
        // swap, not a recompute: the cursor freezes one short of total.
        s.seqs.get_mut(&0).unwrap().generated.push(2);
        assert!(s.append_token(0));
        assert_eq!(s.seqs[&1].state, SeqState::Swapped);
        assert_eq!(s.seqs[&1].prefill_pos, 7, "everything but the last sampled token");
        assert_eq!((s.swap_out_count, s.swap_out_mid_decode), (1, 1));
        assert_eq!(s.preemption_count, 1);
        let spilled = s.blocks.take_swap_outs();
        assert_eq!(spilled.len(), 1);
        assert_eq!(spilled[0].0, 1);
        assert_eq!(spilled[0].1.len(), 2, "2 blocks of K/V to spill");
        s.check_invariants().unwrap();
        // Room frees up: the resume is a single-token final chunk at the
        // frozen cursor — no recompute of the swapped span.
        s.finish(0);
        let ScheduledWork::Step { prefills, .. } = s.schedule(5.0) else { panic!() };
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 1, start: 7, len: 1, is_last: true }]);
        assert_eq!((s.swap_in_count, s.swap_restored_tokens), (1, 7));
        assert_eq!(s.seqs[&1].admitted_time, Some(0.0), "first admission, not the resume");
        let restored = s.blocks.take_swap_ins();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored[0].1.len(), 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn swap_preempt_mid_prefill_keeps_cursor() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            block_size: 4,
            total_blocks: 5,
            max_seq_len: 64,
            prefill_budget: 4,
            prefix_skip: true,
            swap_preempt: true,
            kv_dtype: super::KvDtype::F32,
            max_waiting: usize::MAX,
            faults: FaultPlan::NONE,
        });
        let mut r0 = req(0, 7, 30);
        r0.prompt = vec![1; 7];
        s.add_request(&r0);
        // Budget 4: two chunks to finish seq 0's prompt.
        for _ in 0..2 {
            let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
            run_prefills(&mut s, &prefills);
        }
        assert_eq!(s.num_running(), 1);
        // Seq 1 arrives and gets one 4-token chunk in, then stalls.
        let mut r1 = req(1, 7, 30);
        r1.prompt = vec![2; 7];
        s.add_request(&Request { arrival: 1.0, ..r1 });
        let ScheduledWork::Step { prefills, decodes } = s.schedule(1.0) else { panic!() };
        assert_eq!(decodes, vec![0]);
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 1, start: 0, len: 4, is_last: false }]);
        s.advance_prefill(&prefills[0]);
        // Seq 0 keeps decoding until the pool runs dry; the mid-prefill
        // seq 1 is the only eligible victim.
        for _ in 0..5 {
            s.seqs.get_mut(&0).unwrap().generated.push(9);
            assert!(s.append_token(0));
        }
        assert_eq!(s.seqs[&1].state, SeqState::Swapped);
        assert_eq!(s.seqs[&1].prefill_pos, 4, "chunk cursor frozen, not reset");
        assert_eq!((s.swap_out_count, s.swap_out_mid_prefill), (1, 1));
        s.check_invariants().unwrap();
        // On resume the next chunk continues exactly at the cursor.
        s.finish(0);
        let ScheduledWork::Step { prefills, .. } = s.schedule(9.0) else { panic!() };
        assert_eq!(prefills, vec![PrefillChunk { seq_id: 1, start: 4, len: 3, is_last: true }]);
        assert_eq!(s.swap_restored_tokens, 4);
        s.check_invariants().unwrap();
    }

    #[test]
    fn admission_is_priority_then_fcfs() {
        let mut s = Scheduler::new(SchedulerConfig { prefill_budget: 64, ..cfg() });
        let mk = |id: usize, fill: u32, arrival: f64, priority: i32| {
            let mut r = req(id, 4, 4);
            r.prompt = vec![fill; 4];
            r.arrival = arrival;
            r.priority = priority;
            r
        };
        s.add_request(&mk(0, 10, 0.0, 0));
        s.add_request(&mk(1, 20, 1.0, 1));
        s.add_request(&mk(2, 30, 0.5, 0));
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        let order: Vec<usize> = prefills.iter().map(|c| c.seq_id).collect();
        assert_eq!(order, vec![1, 0, 2], "priority first, then FCFS by arrival");
        s.check_invariants().unwrap();
    }

    #[test]
    fn fairness_guard_defers_fresh_prompts_without_decode_headroom() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 4,
            block_size: 4,
            total_blocks: 4,
            max_seq_len: 64,
            prefill_budget: 32,
            prefix_skip: true,
            swap_preempt: true,
            kv_dtype: super::KvDtype::F32,
            max_waiting: usize::MAX,
            faults: FaultPlan::NONE,
        });
        let mut r0 = req(0, 7, 30);
        r0.prompt = vec![1; 7];
        s.add_request(&r0);
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        run_prefills(&mut s, &prefills);
        // 2 of 4 blocks free.  Seq 1's allocation alone would fit
        // (can_allocate passes), but it would leave the running decode
        // with no append headroom — deferred, not admitted.
        let mut r1 = req(1, 7, 30);
        r1.prompt = vec![2; 7];
        s.add_request(&Request { arrival: 1.0, ..r1 });
        let ScheduledWork::Step { prefills, decodes } = s.schedule(1.0) else { panic!() };
        assert!(prefills.is_empty(), "fresh prompt must wait for headroom");
        assert_eq!(decodes, vec![0]);
        assert_eq!(s.seqs[&1].state, SeqState::Waiting);
        s.check_invariants().unwrap();
        // Once the decode finishes, the guard clears.
        s.finish(0);
        let ScheduledWork::Step { prefills, .. } = s.schedule(2.0) else { panic!() };
        assert_eq!(prefills.len(), 1);
        assert_eq!(prefills[0].seq_id, 1);
    }

    #[test]
    fn preemption_never_evicts_higher_priority_victims() {
        let build = || {
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: 2,
                block_size: 4,
                total_blocks: 4,
                max_seq_len: 64,
                prefill_budget: 32,
                prefix_skip: true,
                swap_preempt: false,
                kv_dtype: super::KvDtype::F32,
                max_waiting: usize::MAX,
                faults: FaultPlan::NONE,
            });
            let mut r0 = req(0, 7, 30);
            r0.prompt = vec![1; 7];
            let mut r1 = req(1, 7, 30);
            r1.prompt = vec![2; 7];
            r1.arrival = 1.0;
            r1.priority = 1;
            s.add_request(&r0);
            s.add_request(&r1);
            let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
            run_prefills(&mut s, &prefills);
            s
        };
        // High-priority appender may evict the low-priority peer...
        let mut s = build();
        s.seqs.get_mut(&1).unwrap().generated.push(9);
        assert!(s.append_token(1));
        assert_eq!(s.seqs[&0].state, SeqState::Preempted);
        s.check_invariants().unwrap();
        // ...but a low-priority appender must not touch the
        // high-priority peer: it self-preempts instead.
        let mut s = build();
        s.seqs.get_mut(&0).unwrap().generated.push(9);
        assert!(!s.append_token(0));
        assert_eq!(s.seqs[&0].state, SeqState::Preempted);
        assert_eq!(s.seqs[&1].state, SeqState::Running);
        s.check_invariants().unwrap();
    }

    #[test]
    fn never_fitting_request_is_rejected_with_a_typed_reason() {
        // A prompt whose KV footprint exceeds the whole pool can never be
        // admitted; the progress guard must resolve it as a typed rejection
        // instead of spinning forever (or panicking).
        let mut s = Scheduler::new(SchedulerConfig {
            total_blocks: 2, // pool holds 8 token slots
            ..cfg()
        });
        s.add_request(&req(0, 30, 4)); // needs ceil(31/4) = 8 blocks
        assert!(matches!(s.schedule(0.0), ScheduledWork::Idle));
        let rejected = s.take_rejected();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].0, 0);
        assert!(
            rejected[0].1.contains("cannot ever fit"),
            "unexpected reason: {}",
            rejected[0].1
        );
        assert_eq!(s.seqs[&0].state, SeqState::Finished);
        assert!(s.take_rejected().is_empty(), "rejection drained twice");
        s.check_invariants().unwrap();
    }

    #[test]
    fn oversized_prompt_is_rejected_before_admission() {
        let mut s = Scheduler::new(cfg()); // max_seq_len: 64
        s.add_request(&req(0, 70, 4));
        assert!(matches!(s.schedule(0.0), ScheduledWork::Idle));
        let rejected = s.take_rejected();
        assert_eq!(rejected.len(), 1);
        assert!(rejected[0].1.contains("oversized"), "reason: {}", rejected[0].1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn bounded_waiting_queue_sheds_lowest_priority_fresh_request() {
        let mut s = Scheduler::new(SchedulerConfig { max_waiting: 2, ..cfg() });
        let mut r0 = req(0, 4, 8);
        r0.priority = 5;
        let mut r1 = req(1, 4, 8);
        r1.priority = 1; // lowest priority -> shed victim
        r1.arrival = 0.5;
        let mut r2 = req(2, 4, 8);
        r2.priority = 3;
        r2.arrival = 1.0;
        s.add_request(&r0);
        s.add_request(&r1);
        s.add_request(&r2); // overflows max_waiting = 2
        assert_eq!(s.shed_count, 1);
        let rejected = s.take_rejected();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].0, 1, "shed must pick the lowest-priority waiter");
        assert!(rejected[0].1.contains("shed"), "reason: {}", rejected[0].1);
        assert_eq!(s.seqs[&1].state, SeqState::Finished);
        // Survivors are untouched and still schedulable.
        let ScheduledWork::Step { prefills, .. } = s.schedule(2.0) else {
            panic!("survivors should schedule")
        };
        let ids: Vec<usize> = prefills.iter().map(|p| p.seq_id).collect();
        assert_eq!(ids, vec![0, 2]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn preempted_reentries_do_not_count_toward_the_waiting_bound() {
        // Fill the pool so an append forces a recompute preemption, then
        // verify the preempted sequence re-enters the waiting queue without
        // being shed even though max_waiting is already saturated by fresh
        // arrivals.
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 1,
            total_blocks: 2,
            max_waiting: 1,
            ..cfg()
        });
        s.add_request(&req(0, 4, 30));
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        run_prefills(&mut s, &prefills);
        // Exhaust the pool from under seq 0, then append past its block.
        for t in 5..=9 {
            s.seqs.get_mut(&0).unwrap().generated.push(t);
            if !s.append_token(0) {
                break;
            }
        }
        assert_eq!(s.seqs[&0].state, SeqState::Preempted);
        assert!(s.waiting.contains(&0));
        // A fresh arrival saturates the bound; the preempted seq must not be
        // shed (only FRESH waiters are candidates).
        s.add_request(&req(1, 4, 8));
        assert_eq!(s.shed_count, 0);
        assert!(s.take_rejected().is_empty());
        assert!(s.waiting.contains(&0));
        s.check_invariants().unwrap();
    }

    #[test]
    fn alloc_fault_stalls_admission_instead_of_rejecting() {
        let plan = FaultPlan { seed: 7, alloc: 1.0, ..FaultPlan::NONE };
        let mut s = Scheduler::new(SchedulerConfig { faults: plan, ..cfg() });
        s.add_request(&req(0, 4, 8));
        // Every admission draw fires -> scheduler reports Idle (a transient
        // stall), never a capacity rejection.
        for _ in 0..4 {
            assert!(matches!(s.schedule(0.0), ScheduledWork::Idle));
        }
        assert!(s.take_rejected().is_empty(), "alloc fault must not reject");
        assert_eq!(s.seqs[&0].state, SeqState::Waiting);
        s.check_invariants().unwrap();
    }

    #[test]
    fn alloc_fault_on_append_takes_the_preemption_path() {
        let plan = FaultPlan { seed: 7, alloc: 1.0, ..FaultPlan::NONE };
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 1,
            faults: FaultPlan::NONE, // admit cleanly...
            ..cfg()
        });
        s.add_request(&req(0, 4, 30));
        let ScheduledWork::Step { prefills, .. } = s.schedule(0.0) else { panic!() };
        run_prefills(&mut s, &prefills);
        // ...then flip faults on so the next block allocation is refused.
        s.faults = FaultSchedule::new(plan);
        s.seqs.get_mut(&0).unwrap().generated.push(9);
        assert!(!s.append_token(0), "refused alloc must preempt, not succeed");
        assert_eq!(s.seqs[&0].state, SeqState::Preempted);
        assert!(s.waiting.contains(&0));
        s.check_invariants().unwrap();
    }
}

//! Request / response types of the serving API.

/// Sampling configuration for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy.
    pub temperature: f32,
    /// 0 = disabled.
    pub top_k: usize,
    pub max_tokens: usize,
    /// Stop at this token id (None = run to max_tokens).
    pub stop_token: Option<u32>,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, max_tokens: 128, stop_token: None, seed: 0 }
    }
}

/// An inference request submitted to the engine.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub sampling: SamplingParams,
    /// Virtual arrival time (seconds); 0 for batch workloads.  The
    /// engine keeps a request invisible to the scheduler until the
    /// virtual clock reaches its arrival.
    pub arrival: f64,
    /// Admission priority: higher values are admitted first; ties are
    /// FCFS by arrival, then id.  Preemption never evicts a victim of
    /// strictly higher priority on behalf of a lower-priority appender.
    pub priority: i32,
    /// Absolute virtual/wall deadline (seconds on the engine clock).
    /// When the clock passes it before the request completes, the
    /// request is cancelled wherever it is — pending, waiting, swapped,
    /// or mid-generation — with full block/spill reclamation, and
    /// resolves as [`RequestOutcome::TimedOut`].  `None` = no deadline.
    pub deadline: Option<f64>,
}

impl Request {
    pub fn new(id: usize, prompt: Vec<u32>, sampling: SamplingParams) -> Request {
        Request { id, prompt, sampling, arrival: 0.0, priority: 0, deadline: None }
    }
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    /// Context window exhausted.
    LengthCap,
}

/// How a request resolved.  Every request submitted to the engine ends
/// in exactly one of these (surfaced through
/// [`EngineReport::outcomes`](crate::engine::EngineReport) and the
/// shed/timeout/failure counters in [`crate::engine::Metrics`]); only
/// `Completed` requests appear in `EngineReport::outputs`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Finished normally; tokens are in the matching [`RequestOutput`].
    Completed,
    /// Never admitted: oversized for the pool/context, unable to ever
    /// fit (the scheduler's progress guarantee), or shed from a full
    /// bounded waiting queue.
    Rejected {
        reason: String,
    },
    /// The request's deadline passed before completion; cancelled with
    /// full block/spill reclamation.
    TimedOut,
    /// Cooperatively cancelled through [`Engine::cancel`](crate::engine::Engine::cancel)
    /// (front-end abort); drained at the next step boundary wherever the
    /// request is — pending, waiting, swapped, or mid-generation — with
    /// full block/spill reclamation, exactly like the deadline path.
    Cancelled,
    /// A permanent backend error, or transient step retries exhausted.
    Failed {
        reason: String,
    },
}

impl RequestOutcome {
    /// Short stable label for logs/serve output.
    pub fn label(&self) -> &'static str {
        match self {
            RequestOutcome::Completed => "completed",
            RequestOutcome::Rejected { .. } => "rejected",
            RequestOutcome::TimedOut => "timed-out",
            RequestOutcome::Cancelled => "cancelled",
            RequestOutcome::Failed { .. } => "failed",
        }
    }
}

/// Completed request, as returned by [`crate::engine::Engine`].
#[derive(Debug, Clone, PartialEq)]
pub struct RequestOutput {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Virtual/wall seconds from arrival to first generated token.
    pub ttft: f64,
    /// Virtual/wall seconds from arrival to completion.
    pub latency: f64,
    /// Number of times this sequence was preempted and recomputed.
    pub preemptions: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sampling_is_greedy() {
        let s = SamplingParams::default();
        assert_eq!(s.temperature, 0.0);
        assert_eq!(s.top_k, 0);
    }

    #[test]
    fn request_carries_prompt() {
        let r = Request::new(1, vec![1, 2, 3], SamplingParams::default());
        assert_eq!(r.prompt.len(), 3);
        assert_eq!(r.arrival, 0.0);
        assert_eq!(r.deadline, None);
    }

    #[test]
    fn outcome_labels_are_stable() {
        assert_eq!(RequestOutcome::Completed.label(), "completed");
        assert_eq!(RequestOutcome::Rejected { reason: "x".into() }.label(), "rejected");
        assert_eq!(RequestOutcome::TimedOut.label(), "timed-out");
        assert_eq!(RequestOutcome::Cancelled.label(), "cancelled");
        assert_eq!(RequestOutcome::Failed { reason: "y".into() }.label(), "failed");
    }
}

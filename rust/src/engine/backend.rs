//! Execution backends for the engine.
//!
//! [`Backend`] abstracts "run a prefill / a decode step and tell me how
//! long it took".  The engine's scheduling, paging and sampling logic is
//! identical over both implementations:
//!
//! * [`SimBackend`] — the six paper models on the simulated DCU: step
//!   durations come from [`crate::perfmodel`], logits are synthesized
//!   deterministically (the throughput/latency figures do not depend on
//!   token *identity*, only counts — lengths are forced via
//!   `max_tokens` exactly as vLLM's benchmark_throughput does);
//! * [`super::cpu_backend::CpuBackend`] — a real tiny quantized
//!   transformer executed in-crate through the fused dequant-GEMM
//!   kernels, real logits, wall-clock timings;
//! * `PjrtBackend` (feature `pjrt`) — the AOT tiny model on the PJRT CPU
//!   client, real logits, wall-clock timings.

use crate::models::ModelSpec;
use crate::perfmodel::PerfModel;
use crate::rng::Rng;
use crate::OptConfig;
use crate::Result;

/// One sequence's contribution to a decode batch.
#[derive(Debug, Clone, Copy)]
pub struct DecodeEntry {
    /// Backend slot the sequence occupies.
    pub slot: usize,
    /// Sequence length *counting the fed token* (the engine passes
    /// `Sequence::position()` = prompt + generated): the cache holds
    /// `position - 1` earlier tokens and the fed token's K/V entry lands
    /// at index `position - 1`.
    pub position: usize,
    /// The token to feed.
    pub token: u32,
}

/// A model execution backend.
pub trait Backend {
    /// Max sequences decodable in one step.
    fn max_batch(&self) -> usize;
    /// Max context length per sequence.
    fn max_seq_len(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Run the prompt for the sequence in `slot`; returns (next-token
    /// logits, elapsed seconds).
    fn prefill(&mut self, slot: usize, tokens: &[u32]) -> Result<(Vec<f32>, f64)>;

    /// Run one decode step; returns one logits row per entry plus the
    /// elapsed seconds for the whole batch.
    fn decode(&mut self, batch: &[DecodeEntry]) -> Result<(Vec<Vec<f32>>, f64)>;

    /// Slot released (sequence finished or preempted).
    fn release(&mut self, _slot: usize) {}
}

/// Simulated backend: paper model × optimization config on the DCU model.
pub struct SimBackend {
    pub model: &'static ModelSpec,
    pub opt: OptConfig,
    pub perf: PerfModel,
    max_batch: usize,
    max_seq_len: usize,
    rng: Rng,
    /// Reduced logits vocabulary (full 152k logits per step would only
    /// slow the simulation; token identity is irrelevant here).
    sim_vocab: usize,
}

impl SimBackend {
    pub fn new(model: &'static ModelSpec, opt: OptConfig, max_batch: usize) -> SimBackend {
        SimBackend {
            model,
            opt,
            perf: PerfModel::z100(),
            max_batch,
            max_seq_len: 4096,
            rng: Rng::new(0x5e17_ba5e),
            sim_vocab: 512,
        }
    }

    fn fake_logits(&mut self, n: usize) -> Vec<f32> {
        // Perf (§Perf item 4): token identity is irrelevant for the
        // throughput/latency figures (lengths are forced via max_tokens),
        // so a flat bit-mapped distribution replaces Box–Muller normals —
        // no transcendental calls on the per-step path.
        (0..n)
            .map(|_| (self.rng.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32) - 0.5)
            .collect()
    }
}

impl Backend for SimBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn max_seq_len(&self) -> usize {
        self.max_seq_len
    }

    fn vocab(&self) -> usize {
        self.sim_vocab
    }

    fn prefill(&mut self, _slot: usize, tokens: &[u32]) -> Result<(Vec<f32>, f64)> {
        let secs = self.perf.prefill_seconds(self.model, tokens.len().max(1), self.opt);
        let logits = self.fake_logits(self.sim_vocab);
        Ok((logits, secs))
    }

    fn decode(&mut self, batch: &[DecodeEntry]) -> Result<(Vec<Vec<f32>>, f64)> {
        assert!(!batch.is_empty());
        let mean_ctx =
            batch.iter().map(|e| e.position as f64).sum::<f64>() / batch.len() as f64;
        let secs =
            self.perf
                .decode_step_seconds(self.model, batch.len(), mean_ctx.max(1.0), self.opt);
        let logits = (0..batch.len()).map(|_| self.fake_logits(self.sim_vocab)).collect();
        Ok((logits, secs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    #[test]
    fn sim_backend_times_scale_with_batch() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 32);
        let one = [DecodeEntry { slot: 0, position: 50, token: 1 }];
        let (_, t1) = b.decode(&one).unwrap();
        let many: Vec<DecodeEntry> = (0..32)
            .map(|i| DecodeEntry { slot: i, position: 50, token: 1 })
            .collect();
        let (rows, t32) = b.decode(&many).unwrap();
        assert_eq!(rows.len(), 32);
        assert!(t32 > t1, "batch-32 step should cost more: {t32} vs {t1}");
        assert!(t32 < 32.0 * t1, "but far less than 32 single steps");
    }

    #[test]
    fn optimized_backend_is_faster() {
        let m = by_name("LLaMa-13B-GPTQ").unwrap();
        let mut base = SimBackend::new(m, OptConfig::BASELINE, 32);
        let mut opt = SimBackend::new(m, OptConfig::OPT4GPTQ, 32);
        let batch: Vec<DecodeEntry> =
            (0..32).map(|i| DecodeEntry { slot: i, position: 100, token: 1 }).collect();
        let (_, tb) = base.decode(&batch).unwrap();
        let (_, to) = opt.decode(&batch).unwrap();
        assert!(to < tb);
    }

    #[test]
    fn prefill_longer_prompts_cost_more() {
        let m = by_name("Qwen1.5-4B-Chat-GPTQ-Int4").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 32);
        let (_, t_short) = b.prefill(0, &vec![1; 16]).unwrap();
        let (_, t_long) = b.prefill(0, &vec![1; 512]).unwrap();
        assert!(t_long > t_short);
    }
}

//! Execution backends for the engine.
//!
//! [`Backend`] abstracts "run a prefill / a decode step and tell me how
//! long it took" over a **paged KV contract**: every unit of work arrives
//! as a descriptor carrying the sequence's physical block table and
//! context length, so the memory layout the scheduler reasons about is
//! the same one the backend's kernels read and write.  There is no dense
//! per-slot cache anywhere — a backend that materializes K/V does so in a
//! [`super::kv::PagedKvCache`] addressed through the tables it is handed.
//!
//! The engine's scheduling, paging and sampling logic is identical over
//! all implementations:
//!
//! * [`SimBackend`] — the six paper models on the simulated DCU: step
//!   durations come from [`crate::perfmodel`], logits are synthesized
//!   deterministically (the throughput/latency figures do not depend on
//!   token *identity*, only counts — lengths are forced via
//!   `max_tokens` exactly as vLLM's benchmark_throughput does); block
//!   tables are accepted and ignored (no physical KV);
//! * [`super::cpu_backend::CpuBackend`] — a real tiny quantized
//!   transformer executed in-crate through the fused dequant-GEMM
//!   kernels over physically-paged K/V storage, real logits, wall-clock
//!   timings;
//! * `PjrtBackend` (feature `pjrt`) — the AOT tiny model on the PJRT CPU
//!   client; its HLO artifacts operate on dense lanes, so it maps
//!   sequence ids onto lanes internally.
//!
//! Lifecycle: the engine announces the paged-KV geometry once via
//! [`Backend::bind_kv`], then streams [`PrefillDesc`]/[`DecodeDesc`]
//! work, and after every step returns physically-freed blocks through
//! [`Backend::release_blocks`] (debug builds poison them — see
//! [`super::kv`]) and retired sequence ids through
//! [`Backend::release_seq`].

use crate::models::ModelSpec;
use crate::perfmodel::PerfModel;
use crate::rng::Rng;
use crate::OptConfig;
use crate::Result;

use super::block_manager::BlockId;

/// One sequence's prefill work: run the whole prompt, writing K/V
/// through the block table.
#[derive(Debug, Clone, Copy)]
pub struct PrefillDesc<'a> {
    /// Engine-wide sequence id (stable across preemptions; the unit
    /// [`Backend::release_seq`] later retires).
    pub seq_id: usize,
    /// The prompt tokens; token `i`'s K/V entry lands at position `i`.
    pub tokens: &'a [u32],
    /// Physical block table covering at least `tokens.len()` positions.
    pub block_table: &'a [BlockId],
}

/// One sequence's contribution to a decode batch.
#[derive(Debug, Clone, Copy)]
pub struct DecodeDesc<'a> {
    /// Engine-wide sequence id.
    pub seq_id: usize,
    /// Tokens already materialized in the KV cache: the fed token's K/V
    /// entry lands at position `context_len` and attention covers
    /// positions `0..=context_len`.
    pub context_len: usize,
    /// The token to feed.
    pub token: u32,
    /// Physical block table covering at least `context_len + 1` positions.
    pub block_table: &'a [BlockId],
}

/// A model execution backend (paged-KV batch contract — see module docs).
pub trait Backend {
    /// Max sequences decodable in one step.
    fn max_batch(&self) -> usize;
    /// Max context length per sequence.
    fn max_seq_len(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Announce the paged-KV geometry before any work is scheduled.
    /// Backends owning physical K/V storage size their block pool here;
    /// simulated/dense-lane backends may ignore it.
    fn bind_kv(&mut self, _total_blocks: usize, _block_size: usize) {}

    /// Run one sequence's prompt; returns (next-token logits, elapsed
    /// seconds).
    fn prefill(&mut self, req: PrefillDesc<'_>) -> Result<(Vec<f32>, f64)>;

    /// Run one decode step; returns one logits row per entry plus the
    /// elapsed seconds for the whole batch.
    fn decode(&mut self, batch: &[DecodeDesc<'_>]) -> Result<(Vec<Vec<f32>>, f64)>;

    /// Blocks whose refcount reached zero since the last step: the
    /// memory is returned to the allocator, and paged backends may
    /// recycle or poison it (no live table references these ids).
    fn release_blocks(&mut self, _blocks: &[BlockId]) {}

    /// A sequence finished or was preempted; backends holding
    /// per-sequence state (e.g. dense lane maps) drop it here.
    fn release_seq(&mut self, _seq_id: usize) {}
}

/// Simulated backend: paper model × optimization config on the DCU model.
pub struct SimBackend {
    pub model: &'static ModelSpec,
    pub opt: OptConfig,
    pub perf: PerfModel,
    max_batch: usize,
    max_seq_len: usize,
    rng: Rng,
    /// Reduced logits vocabulary (full 152k logits per step would only
    /// slow the simulation; token identity is irrelevant here).
    sim_vocab: usize,
}

impl SimBackend {
    pub fn new(model: &'static ModelSpec, opt: OptConfig, max_batch: usize) -> SimBackend {
        SimBackend {
            model,
            opt,
            perf: PerfModel::z100(),
            max_batch,
            max_seq_len: 4096,
            rng: Rng::new(0x5e17_ba5e),
            sim_vocab: 512,
        }
    }

    fn fake_logits(&mut self, n: usize) -> Vec<f32> {
        // Perf (§Perf item 4): token identity is irrelevant for the
        // throughput/latency figures (lengths are forced via max_tokens),
        // so a flat bit-mapped distribution replaces Box–Muller normals —
        // no transcendental calls on the per-step path.
        (0..n)
            .map(|_| (self.rng.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32) - 0.5)
            .collect()
    }
}

impl Backend for SimBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn max_seq_len(&self) -> usize {
        self.max_seq_len
    }

    fn vocab(&self) -> usize {
        self.sim_vocab
    }

    fn prefill(&mut self, req: PrefillDesc<'_>) -> Result<(Vec<f32>, f64)> {
        let secs = self.perf.prefill_seconds(self.model, req.tokens.len().max(1), self.opt);
        let logits = self.fake_logits(self.sim_vocab);
        Ok((logits, secs))
    }

    fn decode(&mut self, batch: &[DecodeDesc<'_>]) -> Result<(Vec<Vec<f32>>, f64)> {
        assert!(!batch.is_empty());
        // `context_len + 1` counts the fed token, matching the sequence
        // length the perf model's attention term is parameterized on.
        let mean_ctx = batch.iter().map(|e| (e.context_len + 1) as f64).sum::<f64>()
            / batch.len() as f64;
        let secs =
            self.perf
                .decode_step_seconds(self.model, batch.len(), mean_ctx.max(1.0), self.opt);
        let logits = (0..batch.len()).map(|_| self.fake_logits(self.sim_vocab)).collect();
        Ok((logits, secs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    fn decode_desc(seq_id: usize, context_len: usize) -> DecodeDesc<'static> {
        DecodeDesc { seq_id, context_len, token: 1, block_table: &[] }
    }

    #[test]
    fn sim_backend_times_scale_with_batch() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 32);
        let one = [decode_desc(0, 49)];
        let (_, t1) = b.decode(&one).unwrap();
        let many: Vec<DecodeDesc> = (0..32).map(|i| decode_desc(i, 49)).collect();
        let (rows, t32) = b.decode(&many).unwrap();
        assert_eq!(rows.len(), 32);
        assert!(t32 > t1, "batch-32 step should cost more: {t32} vs {t1}");
        assert!(t32 < 32.0 * t1, "but far less than 32 single steps");
    }

    #[test]
    fn optimized_backend_is_faster() {
        let m = by_name("LLaMa-13B-GPTQ").unwrap();
        let mut base = SimBackend::new(m, OptConfig::BASELINE, 32);
        let mut opt = SimBackend::new(m, OptConfig::OPT4GPTQ, 32);
        let batch: Vec<DecodeDesc> = (0..32).map(|i| decode_desc(i, 99)).collect();
        let (_, tb) = base.decode(&batch).unwrap();
        let (_, to) = opt.decode(&batch).unwrap();
        assert!(to < tb);
    }

    #[test]
    fn prefill_longer_prompts_cost_more() {
        let m = by_name("Qwen1.5-4B-Chat-GPTQ-Int4").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 32);
        let short = vec![1u32; 16];
        let long = vec![1u32; 512];
        let (_, t_short) = b
            .prefill(PrefillDesc { seq_id: 0, tokens: &short, block_table: &[] })
            .unwrap();
        let (_, t_long) = b
            .prefill(PrefillDesc { seq_id: 0, tokens: &long, block_table: &[] })
            .unwrap();
        assert!(t_long > t_short);
    }
}

//! Execution backends for the engine.
//!
//! [`Backend`] abstracts "run a prefill / a decode step and tell me how
//! long it took" over a **paged KV contract**: every unit of work arrives
//! as a descriptor carrying the sequence's physical block table and
//! context length, so the memory layout the scheduler reasons about is
//! the same one the backend's kernels read and write.  There is no dense
//! per-slot cache anywhere — a backend that materializes K/V does so in a
//! [`super::kv::PagedKvCache`] addressed through the tables it is handed.
//!
//! The engine's scheduling, paging and sampling logic is identical over
//! all implementations:
//!
//! * [`SimBackend`] — the six paper models on the simulated DCU: step
//!   durations come from [`crate::perfmodel`], logits are synthesized
//!   deterministically (the throughput/latency figures do not depend on
//!   token *identity*, only counts — lengths are forced via
//!   `max_tokens` exactly as vLLM's benchmark_throughput does); block
//!   tables are accepted and ignored (no physical KV);
//! * [`super::cpu_backend::CpuBackend`] — a real tiny quantized
//!   transformer executed in-crate through the fused dequant-GEMM
//!   kernels over physically-paged K/V storage, real logits, wall-clock
//!   timings;
//! * `PjrtBackend` (feature `pjrt`) — the AOT tiny model on the PJRT CPU
//!   client; its HLO artifacts operate on dense lanes, so it maps
//!   sequence ids onto lanes internally.
//!
//! Lifecycle: the engine announces the paged-KV geometry once via
//! [`Backend::bind_kv`], then drives **mixed steps** through
//! [`Backend::step`] — each step carries the prefill chunks scheduled
//! under the token budget ([`PrefillDesc`], including `start > 0`
//! chunks that resume a partially-prefilled prompt or skip a cached
//! prefix outright) *and* the decode batch ([`DecodeDesc`]) in one
//! call, so backends fold everything into a single forward pass.  After
//! every step the engine returns physically-freed blocks through
//! [`Backend::release_blocks`] (debug builds poison them — see
//! [`super::kv`]) and retired sequence ids through
//! [`Backend::release_seq`].

use crate::models::ModelSpec;
use crate::perfmodel::PerfModel;
use crate::rng::Rng;
use crate::OptConfig;
use crate::Result;

use super::block_manager::BlockId;
use super::kv::{KvDtype, KvSpill, PagedKvCache};

/// A typed failure from a backend seam ([`Backend::step`],
/// [`Backend::swap_out`], [`Backend::swap_in`]) — the error contract the
/// engine's retry/shed/fail lifecycle is built on.  The discriminant is
/// the recovery policy:
///
/// * `Transient` — the step may succeed if re-driven: the engine discards
///   the failed step's partial output, preempts the batch through the
///   normal swap/recompute machinery and retries with bounded backoff.
/// * `Permanent` — retrying is pointless: every sequence scheduled into
///   the failed call resolves as [`super::RequestOutcome::Failed`] (with
///   full block/spill reclamation) and the engine keeps serving the rest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepError {
    Transient(String),
    Permanent(String),
}

impl StepError {
    pub fn is_transient(&self) -> bool {
        matches!(self, StepError::Transient(_))
    }

    pub fn reason(&self) -> &str {
        match self {
            StepError::Transient(r) | StepError::Permanent(r) => r,
        }
    }
}

impl std::fmt::Display for StepError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StepError::Transient(r) => write!(f, "transient backend error: {r}"),
            StepError::Permanent(r) => write!(f, "permanent backend error: {r}"),
        }
    }
}

impl std::error::Error for StepError {}

/// KV-memory accounting a backend can surface after a run (see
/// [`Backend::kv_stats`]): how many bytes the paged pool holds, what one
/// resident token costs, and how much spill traffic preemption moved —
/// all dtype-aware, so the f16/kv4 capacity wins show up as numbers.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvStats {
    /// Bytes held by the paged K/V pool (both sides, all layers).
    pub pool_bytes: usize,
    /// Bytes one resident token costs across both sides and all layers.
    pub bytes_per_token: usize,
    /// Bytes currently parked in the host-side spill pool.
    pub spill_bytes: usize,
    /// High-water mark of the spill pool over the run.
    pub spill_peak_bytes: usize,
}

/// One prefill **chunk**: a contiguous span of a sequence's prompt,
/// written through the block table starting at position `start`.
///
/// A whole-prompt prefill is the special case `start == 0, is_last ==
/// true`.  Chunked prefill sends a long prompt as several descriptors
/// across engine steps; prefix-aware prefill starts the first chunk at
/// `cached_len` (the leading tokens whose K/V already live in shared,
/// fully-computed prefix blocks — the backend never sees them at all).
#[derive(Debug, Clone, Copy)]
pub struct PrefillDesc<'a> {
    /// Engine-wide sequence id (stable across preemptions; the unit
    /// [`Backend::release_seq`] later retires).
    pub seq_id: usize,
    /// This chunk's tokens; token `i`'s K/V entry lands at position
    /// `start + i`, and its attention covers positions `0..=start + i`
    /// (reading earlier chunks' — or a shared prefix's — K/V through the
    /// table).
    pub tokens: &'a [u32],
    /// Position of `tokens[0]`: cached-prefix length plus previously
    /// executed chunk lengths.
    pub start: usize,
    /// True when this chunk reaches the end of the prompt: the backend
    /// must return next-token logits for it (and may skip the lm_head
    /// for chunks that don't).
    pub is_last: bool,
    /// Physical block table covering at least `start + tokens.len()`
    /// positions.
    pub block_table: &'a [BlockId],
}

/// One sequence's contribution to a decode batch.
#[derive(Debug, Clone, Copy)]
pub struct DecodeDesc<'a> {
    /// Engine-wide sequence id.
    pub seq_id: usize,
    /// Tokens already materialized in the KV cache: the fed token's K/V
    /// entry lands at position `context_len` and attention covers
    /// positions `0..=context_len`.
    pub context_len: usize,
    /// The token to feed.
    pub token: u32,
    /// Physical block table covering at least `context_len + 1` positions.
    pub block_table: &'a [BlockId],
}

/// Everything one mixed engine step produced.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// One entry per prefill descriptor, in order: `Some(next-token
    /// logits)` iff the chunk was `is_last`, `None` for mid-prompt
    /// chunks (their only output is K/V written through the table).
    pub prefill_logits: Vec<Option<Vec<f32>>>,
    /// One logits row per decode descriptor, in order.
    pub decode_logits: Vec<Vec<f32>>,
    /// Elapsed seconds (wall or virtual) for the whole step.
    pub secs: f64,
}

/// A model execution backend (paged-KV batch contract — see module docs).
pub trait Backend {
    /// Max sequences decodable in one step.
    fn max_batch(&self) -> usize;
    /// Max context length per sequence.
    fn max_seq_len(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Announce the paged-KV geometry — block count/size and the storage
    /// dtype — before any work is scheduled.  Backends owning physical
    /// K/V storage size their block pool here; simulated/dense-lane
    /// backends may ignore it (though [`SimBackend`] records it to price
    /// spill volume).
    fn bind_kv(&mut self, _total_blocks: usize, _block_size: usize, _dtype: KvDtype) {}

    /// Run one **mixed batch**: every prefill chunk and every decode row
    /// in a single call (backends fold them into one forward pass, so
    /// prefill chunks keep the fused GEMM at M ≫ 1 while decodes ride
    /// along).  Either slice may be empty, but not both.
    ///
    /// Errors are typed ([`StepError`]): `Transient` failures are retried
    /// by the engine after re-driving the preemption machinery, so a
    /// failing backend MUST NOT have committed partial K/V or clock state
    /// for the batch — fail before mutating, or roll back.
    fn step(
        &mut self,
        prefills: &[PrefillDesc<'_>],
        decodes: &[DecodeDesc<'_>],
    ) -> Result<StepOutput, StepError>;

    /// Convenience: run one whole-prompt (or final-chunk) prefill alone;
    /// returns (next-token logits, elapsed seconds).  The descriptor
    /// must have `is_last == true`.
    fn prefill(&mut self, req: PrefillDesc<'_>) -> Result<(Vec<f32>, f64)> {
        let mut out = self.step(std::slice::from_ref(&req), &[])?;
        match out.prefill_logits.pop().flatten() {
            Some(logits) => Ok((logits, out.secs)),
            None => anyhow::bail!("prefill chunk produced no logits (is_last == false?)"),
        }
    }

    /// Convenience: run one pure decode batch; returns one logits row
    /// per entry plus the elapsed seconds for the whole batch.
    fn decode(&mut self, batch: &[DecodeDesc<'_>]) -> Result<(Vec<Vec<f32>>, f64)> {
        let out = self.step(&[], batch)?;
        Ok((out.decode_logits, out.secs))
    }

    /// Blocks whose refcount reached zero since the last step: the
    /// memory is returned to the allocator, and paged backends may
    /// recycle or poison it (no live table references these ids).
    fn release_blocks(&mut self, _blocks: &[BlockId]) {}

    /// A sequence finished or was preempted; backends holding
    /// per-sequence state (e.g. dense lane maps) drop it here.
    fn release_seq(&mut self, _seq_id: usize) {}

    /// A preempted sequence's blocks are being evicted under memory
    /// pressure: copy their contents to a host-side spill pool keyed by
    /// `seq_id` (table order).  The engine calls this at the end of the
    /// preempting step, **before** the same block ids arrive at
    /// [`Backend::release_blocks`] — the data is still intact when the
    /// copy runs.  Returns the **packed** payload size in bytes (spill
    /// volume shrinks with the KV dtype); backends without physical K/V
    /// may return a virtual size, or 0 to opt out of the accounting.  On
    /// `Err` no spill entry may exist for `seq_id` afterwards — the
    /// engine demotes the victim to a recompute preemption instead.
    fn swap_out(&mut self, _seq_id: usize, _blocks: &[BlockId]) -> Result<usize, StepError> {
        Ok(0)
    }

    /// A swapped-out sequence is resuming on freshly-allocated `blocks`
    /// (same table order, different physical ids): restore its spilled
    /// K/V before the step that resumes it executes.  The spill entry is
    /// consumed; [`Backend::release_seq`] drops it for sequences that
    /// finish (or are rejected) while still swapped out.  On `Err` the
    /// restore did not happen — the engine drops the (now unusable)
    /// spill entry via [`Backend::drop_spill`] and demotes the sequence
    /// to recompute.
    fn swap_in(&mut self, _seq_id: usize, _blocks: &[BlockId]) -> Result<(), StepError> {
        Ok(())
    }

    /// Discard a spill entry without restoring it (failed restore,
    /// cancelled swapped-out sequence).  Idempotent; backends without a
    /// spill pool ignore it.
    fn drop_spill(&mut self, _seq_id: usize) {}

    /// The physical paged K/V pool, for backends that own one — lets the
    /// post-drain auditor cross-check the pool's free blocks against the
    /// block manager's free list.  `None` for virtual backends.
    fn paged_kv(&self) -> Option<&PagedKvCache> {
        None
    }

    /// Checkpoint read path: pack the K/V payload of live `blocks`
    /// (table order, non-consuming — the blocks stay resident).  `None`
    /// for backends without physical K/V; a snapshot of those carries
    /// accounting state only.
    fn export_kv(&self, _blocks: &[BlockId]) -> Option<KvSpill> {
        None
    }

    /// Checkpoint restore path: write a packed payload from
    /// [`Backend::export_kv`] back onto freshly-bound `blocks` (same
    /// count and order as the export).  No-op for virtual backends.
    fn import_kv(&mut self, _blocks: &[BlockId], _payload: &KvSpill) {}

    /// Checkpoint read path for a swapped-out sequence's host-side spill
    /// entry (non-consuming).  `None` when the backend keeps no payload
    /// — e.g. [`SimBackend`] prices bytes only, and re-derives them on
    /// [`Backend::import_spill`].
    fn export_spill(&self, _seq_id: usize) -> Option<KvSpill> {
        None
    }

    /// Checkpoint restore path: recreate a swapped-out sequence's spill
    /// entry — `n_blocks` spilled blocks, plus the packed payload when
    /// the exporting backend had one.
    fn import_spill(&mut self, _seq_id: usize, _n_blocks: usize, _payload: Option<KvSpill>) {}

    /// Arm a one-shot injected fault *inside* the next forward pass (the
    /// [`super::fault::FaultSeam::MidLayerPoison`] seam): backends with
    /// real math corrupt one attention tile mid-layer, so the failure
    /// must be caught by their own output validation — not by the
    /// engine's seam checks.  Virtual backends ignore it (their logits
    /// are synthesized, so there is no layer to poison).
    fn inject_fault(&mut self) {}

    /// KV-memory accounting, if this backend tracks it: pool bytes,
    /// bytes per resident token, and spill volume (see [`KvStats`]).
    /// `None` for backends with no KV accounting at all.
    fn kv_stats(&self) -> Option<KvStats> {
        None
    }
}

/// Simulated backend: paper model × optimization config on the DCU model.
pub struct SimBackend {
    pub model: &'static ModelSpec,
    pub opt: OptConfig,
    pub perf: PerfModel,
    max_batch: usize,
    max_seq_len: usize,
    /// Reduced logits vocabulary (full 152k logits per step would only
    /// slow the simulation; token identity is irrelevant here).
    sim_vocab: usize,
    /// Bound paged-KV geometry: no physical pool exists here, but spill
    /// volume is *priced* from it at the paper model's real KV width, so
    /// the trace benches see dtype-proportional swap traffic.
    kv_dtype: KvDtype,
    kv_block_size: usize,
    kv_total_blocks: usize,
    /// Virtual bytes per swapped-out sequence (consumed on swap-in).
    spill_sizes: std::collections::HashMap<usize, usize>,
    spill_bytes: usize,
    spill_peak_bytes: usize,
}

impl SimBackend {
    pub fn new(model: &'static ModelSpec, opt: OptConfig, max_batch: usize) -> SimBackend {
        SimBackend {
            model,
            opt,
            perf: PerfModel::z100(),
            max_batch,
            max_seq_len: 4096,
            sim_vocab: 512,
            kv_dtype: KvDtype::F32,
            kv_block_size: 16,
            kv_total_blocks: 0,
            spill_sizes: std::collections::HashMap::new(),
            spill_bytes: 0,
            spill_peak_bytes: 0,
        }
    }

    /// Synthetic logits as a pure function of (sequence, position).
    ///
    /// Purity is load-bearing: a sequence's logits at position `p` are
    /// the same whether it runs alone, batched, preempted-and-recomputed
    /// or swapped-out-and-resumed — so trace-replay parity tests can
    /// compare scheduling policies on the sim backend exactly as the CPU
    /// backend's real math allows (its rows are batch-independent).  A
    /// flat bit-mapped distribution keeps transcendentals off the
    /// per-step path (lengths are forced via max_tokens anyway).
    fn fake_logits(&self, seq_id: usize, pos: usize) -> Vec<f32> {
        let mut rng = Rng::new(
            0x5e17_ba5e
                ^ (seq_id as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ (pos as u64).wrapping_mul(0xd1b5_4a32_d192_ed03),
        );
        (0..self.sim_vocab)
            .map(|_| (rng.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32) - 0.5)
            .collect()
    }
}

impl Backend for SimBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn max_seq_len(&self) -> usize {
        self.max_seq_len
    }

    fn vocab(&self) -> usize {
        self.sim_vocab
    }

    fn bind_kv(&mut self, total_blocks: usize, block_size: usize, dtype: KvDtype) {
        self.kv_total_blocks = total_blocks;
        self.kv_block_size = block_size.max(1);
        self.kv_dtype = dtype;
        self.spill_sizes.clear();
        self.spill_bytes = 0;
        self.spill_peak_bytes = 0;
    }

    fn swap_out(&mut self, seq_id: usize, blocks: &[BlockId]) -> Result<usize, StepError> {
        // Price the packed payload at the *paper model's* KV width — the
        // simulation has no pool, but the bytes a real swap-out of these
        // blocks would move are fully determined by the geometry.
        let bytes =
            blocks.len() * self.kv_dtype.block_bytes(self.kv_block_size, self.model.n_layers, self.model.kv_dim());
        if let Some(old) = self.spill_sizes.insert(seq_id, bytes) {
            self.spill_bytes -= old;
        }
        self.spill_bytes += bytes;
        self.spill_peak_bytes = self.spill_peak_bytes.max(self.spill_bytes);
        Ok(bytes)
    }

    fn swap_in(&mut self, seq_id: usize, _blocks: &[BlockId]) -> Result<(), StepError> {
        if let Some(bytes) = self.spill_sizes.remove(&seq_id) {
            self.spill_bytes -= bytes;
        }
        Ok(())
    }

    fn drop_spill(&mut self, seq_id: usize) {
        if let Some(bytes) = self.spill_sizes.remove(&seq_id) {
            self.spill_bytes -= bytes;
        }
    }

    fn import_spill(&mut self, seq_id: usize, n_blocks: usize, _payload: Option<KvSpill>) {
        // No payload survives a snapshot of a virtual backend; the
        // priced size is a pure function of geometry, so re-derive it.
        let bytes = n_blocks
            * self.kv_dtype.block_bytes(self.kv_block_size, self.model.n_layers, self.model.kv_dim());
        if let Some(old) = self.spill_sizes.insert(seq_id, bytes) {
            self.spill_bytes -= old;
        }
        self.spill_bytes += bytes;
        self.spill_peak_bytes = self.spill_peak_bytes.max(self.spill_bytes);
    }

    fn release_seq(&mut self, seq_id: usize) {
        self.drop_spill(seq_id);
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(KvStats {
            pool_bytes: self.kv_total_blocks
                * self.kv_dtype.block_bytes(self.kv_block_size, self.model.n_layers, self.model.kv_dim()),
            bytes_per_token: 2 * self.model.n_layers * self.kv_dtype.row_bytes(self.model.kv_dim()),
            spill_bytes: self.spill_bytes,
            spill_peak_bytes: self.spill_peak_bytes,
        })
    }

    fn step(
        &mut self,
        prefills: &[PrefillDesc<'_>],
        decodes: &[DecodeDesc<'_>],
    ) -> Result<StepOutput, StepError> {
        assert!(!prefills.is_empty() || !decodes.is_empty(), "empty backend step");
        let mut secs = 0.0;
        // Each chunk is priced independently as the *incremental* cost of
        // extending that sequence's prefill from `start` to `start + len`
        // (f(end) − f(start)): chunks of one prompt telescope to exactly
        // the one-shot cost f(L) − f(cached_len), so the virtual clock
        // neither rewards chunking for free nor lumps unrelated prompts
        // into one superlinear attention term — and a skipped cached
        // prefix shows the same win a real backend sees.
        for p in prefills {
            let end = p.start + p.tokens.len();
            secs += self.perf.prefill_seconds(self.model, end.max(1), self.opt);
            if p.start > 0 {
                secs -= self.perf.prefill_seconds(self.model, p.start, self.opt);
            }
        }
        if !decodes.is_empty() {
            // `context_len + 1` counts the fed token, matching the
            // sequence length the perf model's attention term is
            // parameterized on.
            let mean_ctx = decodes.iter().map(|e| (e.context_len + 1) as f64).sum::<f64>()
                / decodes.len() as f64;
            secs += self.perf.decode_step_seconds(
                self.model,
                decodes.len(),
                mean_ctx.max(1.0),
                self.opt,
            );
        }
        // Logit positions mirror the real backends: a final chunk samples
        // at its last token's position, a decode row at `context_len` —
        // so a swap-resumed 1-token final chunk reproduces exactly the
        // decode row it replaces.
        let prefill_logits = prefills
            .iter()
            .map(|p| p.is_last.then(|| self.fake_logits(p.seq_id, p.start + p.tokens.len() - 1)))
            .collect();
        let decode_logits =
            decodes.iter().map(|e| self.fake_logits(e.seq_id, e.context_len)).collect();
        Ok(StepOutput { prefill_logits, decode_logits, secs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    fn decode_desc(seq_id: usize, context_len: usize) -> DecodeDesc<'static> {
        DecodeDesc { seq_id, context_len, token: 1, block_table: &[] }
    }

    #[test]
    fn sim_backend_times_scale_with_batch() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 32);
        let one = [decode_desc(0, 49)];
        let (_, t1) = b.decode(&one).unwrap();
        let many: Vec<DecodeDesc> = (0..32).map(|i| decode_desc(i, 49)).collect();
        let (rows, t32) = b.decode(&many).unwrap();
        assert_eq!(rows.len(), 32);
        assert!(t32 > t1, "batch-32 step should cost more: {t32} vs {t1}");
        assert!(t32 < 32.0 * t1, "but far less than 32 single steps");
    }

    #[test]
    fn optimized_backend_is_faster() {
        let m = by_name("LLaMa-13B-GPTQ").unwrap();
        let mut base = SimBackend::new(m, OptConfig::BASELINE, 32);
        let mut opt = SimBackend::new(m, OptConfig::OPT4GPTQ, 32);
        let batch: Vec<DecodeDesc> = (0..32).map(|i| decode_desc(i, 99)).collect();
        let (_, tb) = base.decode(&batch).unwrap();
        let (_, to) = opt.decode(&batch).unwrap();
        assert!(to < tb);
    }

    #[test]
    fn prefill_longer_prompts_cost_more() {
        let m = by_name("Qwen1.5-4B-Chat-GPTQ-Int4").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 32);
        let short = vec![1u32; 16];
        let long = vec![1u32; 512];
        let (_, t_short) = b
            .prefill(PrefillDesc { seq_id: 0, tokens: &short, start: 0, is_last: true, block_table: &[] })
            .unwrap();
        let (_, t_long) = b
            .prefill(PrefillDesc { seq_id: 0, tokens: &long, start: 0, is_last: true, block_table: &[] })
            .unwrap();
        assert!(t_long > t_short);
    }

    #[test]
    fn mixed_step_costs_prefill_plus_decode() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 8);
        let tokens = vec![1u32; 64];
        let chunk = PrefillDesc { seq_id: 0, tokens: &tokens, start: 0, is_last: false, block_table: &[] };
        let dec = [decode_desc(1, 30), decode_desc(2, 40)];
        let out = b.step(&[chunk], &dec).unwrap();
        assert_eq!(out.prefill_logits, vec![None], "mid-prompt chunk returns no logits");
        assert_eq!(out.decode_logits.len(), 2);
        let pre_only = b.step(&[chunk], &[]).unwrap();
        let dec_only = b.step(&[], &dec).unwrap();
        let sum = pre_only.secs + dec_only.secs;
        assert!((out.secs - sum).abs() < 1e-12, "mixed step must cost both parts: {} vs {sum}", out.secs);
    }

    #[test]
    fn sim_logits_are_pure_in_sequence_and_position() {
        // Purity pin (see fake_logits): batch composition, call order and
        // chunk-vs-decode framing must not change a row's logits — the
        // trace-replay parity properties stand on this.
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 8);
        let (alone, _) = b.decode(&[decode_desc(3, 17)]).unwrap();
        let batch: Vec<DecodeDesc> = (0..4).map(|i| decode_desc(i, 17)).collect();
        let (batched, _) = b.decode(&batch).unwrap();
        assert_eq!(alone[0], batched[3], "logits must not depend on batch composition");
        assert_ne!(batched[0], batched[1], "distinct seqs draw distinct logits");
        // A swap-resumed 1-token final chunk reproduces the decode row it
        // replaces: same sequence, same position, same logits.
        let toks = [1u32];
        let chunk =
            PrefillDesc { seq_id: 3, tokens: &toks, start: 17, is_last: true, block_table: &[] };
        let out = b.step(&[chunk], &[]).unwrap();
        assert_eq!(out.prefill_logits[0].as_deref().unwrap(), alone[0].as_slice());
    }

    #[test]
    fn sim_spill_accounting_prices_the_packed_dtype() {
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let blocks = [0usize, 1, 2];
        let mut sizes = Vec::new();
        for dtype in KvDtype::ALL {
            let mut b = SimBackend::new(m, OptConfig::OPT4GPTQ, 8);
            b.bind_kv(64, 16, dtype);
            let bytes = b.swap_out(7, &blocks).unwrap();
            assert_eq!(bytes, 3 * dtype.block_bytes(16, m.n_layers, m.kv_dim()));
            let stats = b.kv_stats().unwrap();
            assert_eq!(stats.spill_bytes, bytes);
            assert_eq!(stats.spill_peak_bytes, bytes);
            assert_eq!(stats.pool_bytes, 64 * dtype.block_bytes(16, m.n_layers, m.kv_dim()));
            // Swap-in consumes the entry; the peak stays.
            b.swap_in(7, &blocks).unwrap();
            let drained = b.kv_stats().unwrap();
            assert_eq!(drained.spill_bytes, 0);
            assert_eq!(drained.spill_peak_bytes, bytes);
            // A re-swap of the same seq replaces, not double-counts.
            b.swap_out(7, &blocks[..2]).unwrap();
            b.swap_out(7, &blocks).unwrap();
            assert_eq!(b.kv_stats().unwrap().spill_bytes, bytes);
            b.release_seq(7);
            assert_eq!(b.kv_stats().unwrap().spill_bytes, 0);
            sizes.push(bytes);
        }
        // Spill volume shrinks with the dtype: f32 > f16 > kv4.
        assert!(sizes[0] > sizes[1] && sizes[1] > sizes[2], "{sizes:?}");
    }

    #[test]
    fn step_error_classifies_and_converts() {
        let t = StepError::Transient("dma stall".into());
        let p = StepError::Permanent("ecc fault".into());
        assert!(t.is_transient() && !p.is_transient());
        assert_eq!(t.reason(), "dma stall");
        // `?` in the conveniences relies on the anyhow conversion; the
        // engine recovers the typed error by downcast.
        let any: anyhow::Error = p.clone().into();
        assert_eq!(any.downcast_ref::<StepError>(), Some(&p));
        assert!(any.to_string().contains("permanent"));
    }

    #[test]
    fn skipped_prefix_reduces_simulated_prefill_cost() {
        // The backend only sees the chunk tokens: a prefix-skip prefill
        // of the tail must be cheaper than the whole prompt.
        let m = by_name("Llama-2-7B-GPTQ").unwrap();
        let mut b = SimBackend::new(m, OptConfig::BASELINE, 8);
        let prompt = vec![1u32; 256];
        let (_, t_full) = b
            .prefill(PrefillDesc { seq_id: 0, tokens: &prompt, start: 0, is_last: true, block_table: &[] })
            .unwrap();
        let (_, t_tail) = b
            .prefill(PrefillDesc { seq_id: 1, tokens: &prompt[192..], start: 192, is_last: true, block_table: &[] })
            .unwrap();
        assert!(t_tail < t_full, "skipping 192 cached tokens must be cheaper: {t_tail} vs {t_full}");
    }
}

//! Physically-paged K/V storage — the memory that block tables address.
//!
//! [`super::block_manager::BlockManager`] owns the *accounting* layer of
//! PagedAttention (block tables, refcounts, the prefix cache); this
//! module owns the *storage* layer those tables point into.  K and V each
//! live in one flat pool laid out as
//!
//! ```text
//! [n_blocks × block_size × n_layers × d]
//! ```
//!
//! so a (block, in-block position, layer) triple names one contiguous
//! `d`-float row.  A sequence reaches position `p` through its table:
//! `block = table[p / block_size]`, `offset = p % block_size`.  Two
//! tables containing the same [`BlockId`] therefore *share physical
//! memory* — a prefix-cache hit in the block manager is a real aliased
//! read here, not a bookkeeping fiction — and attention kernels walk the
//! pool block-by-block exactly as the paper's paged layout prescribes
//! (layers innermost so one token's whole stack is cache-adjacent when a
//! layer loop revisits the same position).
//!
//! Freeing is explicit: when the engine reports blocks whose refcount
//! reached zero ([`PagedKvCache::release_blocks`]), debug builds poison
//! their contents with NaN so any read through a stale table blows up
//! parity tests loudly instead of silently serving a recycled sequence's
//! K/V.  Release is therefore a *return* of memory, not an overwrite
//! convention.

use super::block_manager::BlockId;

/// Flat paged K/V pool (see module docs for the layout).
#[derive(Debug)]
pub struct PagedKvCache {
    block_size: usize,
    n_layers: usize,
    /// Floats per (position, layer) row — `d_model` for MHA backends.
    d: usize,
    n_blocks: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl PagedKvCache {
    pub fn new(n_blocks: usize, block_size: usize, n_layers: usize, d: usize) -> PagedKvCache {
        assert!(block_size > 0 && n_layers > 0 && d > 0);
        let len = n_blocks * block_size * n_layers * d;
        PagedKvCache {
            block_size,
            n_layers,
            d,
            n_blocks,
            k: vec![0.0; len],
            v: vec![0.0; len],
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Bytes held by both pools (capacity accounting for callers).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Grow the pool so every id `< n_blocks` is addressable (no-op when
    /// already large enough; never shrinks).
    pub fn ensure_blocks(&mut self, n_blocks: usize) {
        if n_blocks > self.n_blocks {
            let len = n_blocks * self.block_size * self.n_layers * self.d;
            self.k.resize(len, 0.0);
            self.v.resize(len, 0.0);
            self.n_blocks = n_blocks;
        }
    }

    #[inline]
    fn offset(&self, block: BlockId, pos_in_block: usize, layer: usize) -> usize {
        debug_assert!(pos_in_block < self.block_size && layer < self.n_layers);
        ((block * self.block_size + pos_in_block) * self.n_layers + layer) * self.d
    }

    /// Write one position's K and V rows through a block table.  Grows
    /// the pool on demand so directly-driven backends need no up-front
    /// geometry binding.
    pub fn write(
        &mut self,
        table: &[BlockId],
        pos: usize,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let block = table[pos / self.block_size];
        self.ensure_blocks(block + 1);
        let off = self.offset(block, pos % self.block_size, layer);
        self.k[off..off + self.d].copy_from_slice(k_row);
        self.v[off..off + self.d].copy_from_slice(v_row);
    }

    /// K row of one (block, in-block position, layer) cell, `d` floats.
    #[inline]
    pub fn k_row(&self, block: BlockId, pos_in_block: usize, layer: usize) -> &[f32] {
        let off = self.offset(block, pos_in_block, layer);
        &self.k[off..off + self.d]
    }

    /// V row of one (block, in-block position, layer) cell, `d` floats.
    #[inline]
    pub fn v_row(&self, block: BlockId, pos_in_block: usize, layer: usize) -> &[f32] {
        let off = self.offset(block, pos_in_block, layer);
        &self.v[off..off + self.d]
    }

    /// Copy the given blocks' contents out of the pool (swap-out to a
    /// host-side spill buffer), in table order: entry `i` of the result
    /// holds block `blocks[i]`'s full `[block_size × n_layers × d]`
    /// stride.  Blocks past the pool (allocated but never written) spill
    /// as zeros.  Must run **before** the same blocks are poisoned or
    /// recycled — the engine drains swap-outs ahead of block releases.
    pub fn spill_blocks(&self, blocks: &[BlockId]) -> (Vec<f32>, Vec<f32>) {
        let stride = self.block_size * self.n_layers * self.d;
        let mut k = vec![0.0; blocks.len() * stride];
        let mut v = vec![0.0; blocks.len() * stride];
        for (i, &b) in blocks.iter().enumerate() {
            if b >= self.n_blocks {
                continue; // never written -> spill zeros
            }
            let src = b * stride;
            k[i * stride..(i + 1) * stride].copy_from_slice(&self.k[src..src + stride]);
            v[i * stride..(i + 1) * stride].copy_from_slice(&self.v[src..src + stride]);
        }
        (k, v)
    }

    /// Write spilled contents back into the pool at a (generally new) set
    /// of physical blocks: stride `i` of `k`/`v` lands in `blocks[i]`,
    /// preserving table order — a swapped-in sequence reads the exact
    /// K/V it swapped out, just at different physical addresses.
    pub fn restore_blocks(&mut self, blocks: &[BlockId], k: &[f32], v: &[f32]) {
        let stride = self.block_size * self.n_layers * self.d;
        assert_eq!(k.len(), blocks.len() * stride, "spill/table shape mismatch");
        assert_eq!(v.len(), blocks.len() * stride, "spill/table shape mismatch");
        if let Some(&max) = blocks.iter().max() {
            self.ensure_blocks(max + 1);
        }
        for (i, &b) in blocks.iter().enumerate() {
            let dst = b * stride;
            self.k[dst..dst + stride].copy_from_slice(&k[i * stride..(i + 1) * stride]);
            self.v[dst..dst + stride].copy_from_slice(&v[i * stride..(i + 1) * stride]);
        }
    }

    /// Accept blocks back from the allocator (refcount reached zero).
    /// Debug builds poison the returned memory so stale reads through a
    /// dangling table surface as NaN instead of a recycled sequence's
    /// values; release builds skip the pass (the allocator guarantees no
    /// live table references a freed block).
    pub fn release_blocks(&mut self, blocks: &[BlockId]) {
        if cfg!(debug_assertions) {
            self.poison_blocks(blocks);
        }
    }

    /// Unconditionally fill the given blocks with NaN (test hook; the
    /// debug-build free path routes through here).
    pub fn poison_blocks(&mut self, blocks: &[BlockId]) {
        let stride = self.block_size * self.n_layers * self.d;
        for &b in blocks {
            if b >= self.n_blocks {
                continue; // never written -> nothing to poison
            }
            let off = b * stride;
            self.k[off..off + stride].fill(f32::NAN);
            self.v[off..off + stride].fill(f32::NAN);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(d: usize, fill: f32) -> Vec<f32> {
        vec![fill; d]
    }

    #[test]
    fn write_then_read_roundtrip_through_table() {
        let mut kv = PagedKvCache::new(4, 4, 2, 8);
        let table = [2usize, 0]; // deliberately out of order
        kv.write(&table, 1, 0, &rows(8, 1.5), &rows(8, -2.0));
        kv.write(&table, 5, 1, &rows(8, 3.0), &rows(8, 4.0));
        // pos 1 -> block table[0]=2 offset 1; pos 5 -> table[1]=0 offset 1
        assert_eq!(kv.k_row(2, 1, 0), &rows(8, 1.5)[..]);
        assert_eq!(kv.v_row(2, 1, 0), &rows(8, -2.0)[..]);
        assert_eq!(kv.k_row(0, 1, 1), &rows(8, 3.0)[..]);
        assert_eq!(kv.v_row(0, 1, 1), &rows(8, 4.0)[..]);
    }

    #[test]
    fn shared_block_is_shared_memory() {
        let mut kv = PagedKvCache::new(4, 4, 1, 4);
        let table_a = [1usize, 2];
        let table_b = [1usize, 3]; // shares physical block 1 with a
        kv.write(&table_a, 0, 0, &rows(4, 7.0), &rows(4, 8.0));
        // Reading position 0 through b's table sees a's write.
        assert_eq!(kv.k_row(table_b[0], 0, 0), &rows(4, 7.0)[..]);
    }

    #[test]
    fn grows_on_demand() {
        let mut kv = PagedKvCache::new(0, 4, 1, 4);
        assert_eq!(kv.n_blocks(), 0);
        kv.write(&[5], 2, 0, &rows(4, 1.0), &rows(4, 2.0));
        assert!(kv.n_blocks() >= 6);
        assert_eq!(kv.k_row(5, 2, 0), &rows(4, 1.0)[..]);
        // earlier blocks exist and are zeroed
        assert!(kv.k_row(0, 0, 0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn poison_marks_freed_blocks_with_nan() {
        let mut kv = PagedKvCache::new(2, 4, 2, 4);
        kv.write(&[0], 0, 0, &rows(4, 1.0), &rows(4, 1.0));
        kv.write(&[1], 0, 0, &rows(4, 2.0), &rows(4, 2.0));
        kv.poison_blocks(&[0]);
        assert!(kv.k_row(0, 0, 0).iter().all(|x| x.is_nan()), "freed block must read NaN");
        assert!(kv.v_row(0, 0, 0).iter().all(|x| x.is_nan()));
        // other blocks untouched
        assert_eq!(kv.k_row(1, 0, 0), &rows(4, 2.0)[..]);
        // ids past the pool are ignored, not a panic
        kv.poison_blocks(&[99]);
    }

    #[test]
    fn spill_restore_roundtrip_across_physical_blocks() {
        let mut kv = PagedKvCache::new(4, 2, 2, 4);
        let table = [3usize, 1];
        for pos in 0..4 {
            for layer in 0..2 {
                let fill = (pos * 10 + layer) as f32;
                kv.write(&table, pos, layer, &rows(4, fill), &rows(4, -fill));
            }
        }
        let (sk, sv) = kv.spill_blocks(&table);
        // Swap-out: the old blocks are poisoned (freed), then the spill
        // is restored at *different* physical blocks.
        kv.poison_blocks(&table);
        let new_table = [0usize, 2];
        kv.restore_blocks(&new_table, &sk, &sv);
        for pos in 0..4 {
            for layer in 0..2 {
                let fill = (pos * 10 + layer) as f32;
                let (b, o) = (new_table[pos / 2], pos % 2);
                assert_eq!(kv.k_row(b, o, layer), &rows(4, fill)[..], "pos {pos} layer {layer}");
                assert_eq!(kv.v_row(b, o, layer), &rows(4, -fill)[..]);
            }
        }
    }

    #[test]
    fn spill_restore_survives_poison_of_source() {
        // The exact engine ordering: spill first, poison after — the
        // spilled copy must be NaN-free even though the source block is
        // poisoned before the restore happens.
        let mut kv = PagedKvCache::new(2, 4, 1, 4);
        kv.write(&[0], 1, 0, &rows(4, 5.0), &rows(4, 6.0));
        let (sk, sv) = kv.spill_blocks(&[0]);
        kv.release_blocks(&[0]); // debug builds poison here
        kv.restore_blocks(&[1], &sk, &sv);
        assert!(kv.k_row(1, 1, 0).iter().all(|x| x.is_finite()), "restored K must be NaN-free");
        assert_eq!(kv.k_row(1, 1, 0), &rows(4, 5.0)[..]);
        assert_eq!(kv.v_row(1, 1, 0), &rows(4, 6.0)[..]);
    }

    #[test]
    fn spill_of_never_written_block_is_zeros_and_restore_grows() {
        let kv = PagedKvCache::new(1, 2, 1, 2);
        // Block 7 is past the 1-block pool: allocated on paper, never
        // written — it spills as zeros instead of panicking.
        let (sk, sv) = kv.spill_blocks(&[7]);
        assert!(sk.iter().chain(&sv).all(|&x| x == 0.0));
        let mut kv2 = PagedKvCache::new(1, 2, 1, 2);
        kv2.restore_blocks(&[5], &sk, &sv); // grows the pool on demand
        assert!(kv2.n_blocks() >= 6);
        assert!(kv2.k_row(5, 0, 0).iter().all(|&x| x == 0.0));
    }
}
